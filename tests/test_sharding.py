"""Sharding-rule tests: divisibility fallback, axis dedup, multi-device lowering."""

import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.sharding import DEFAULT_RULES, axis_rules, resolve_spec


class FakeMesh:
    """Duck-typed mesh for resolve_spec (axis names + shape only)."""

    def __init__(self, shape, axes):
        self.axis_names = axes
        self.devices = np.zeros(shape)


MESH = FakeMesh((16, 16), ("data", "model"))
MESH3 = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_divisible_dims_shard():
    spec = resolve_spec((2048, 6144), ("embed", "mlp"), MESH)
    assert spec == P("data", "model")


def test_non_divisible_falls_back_to_replicated():
    # 25 heads on a 16-way model axis (hymba) -> replicated
    spec = resolve_spec((4, 25, 64), ("batch", "heads", None), MESH)
    assert spec[1] is None
    # vocab 32001 (hymba) -> replicated
    spec = resolve_spec((32001, 1600), ("vocab", "embed"), MESH)
    assert spec[0] is None and spec[1] == "data"


def test_axis_used_once_per_tensor():
    # experts takes "model" first; mlp then cannot reuse it
    spec = resolve_spec((16, 6144, 10752), ("experts", "embed", "mlp"), MESH)
    assert spec == P("model", "data", None)


def test_batch_spans_pod_and_data_on_multipod():
    spec = resolve_spec((256, 4096), ("batch", "seq"), MESH3)
    assert spec[0] == ("pod", "data")


def test_batch_prefix_fallback():
    # batch=2 divides pod(2) but not pod*data(32) -> prefix ("pod",)
    spec = resolve_spec((2, 4096), ("batch", "seq"), MESH3)
    assert spec[0] == "pod"


def test_rules_override_context():
    with axis_rules(mlp=()):
        spec = resolve_spec((2048, 6144), ("embed", "mlp"), MESH)
        assert spec == P("data", None)
    spec = resolve_spec((2048, 6144), ("embed", "mlp"), MESH)
    assert spec == P("data", "model")


SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.launch.dryrun import lower_cell  # noqa: F401  (imports set up helpers)
from repro.config import SHAPES
from repro.config.base import ShapeConfig
from repro.configs.qwen3_1p7b import reduced
from repro.launch.sharding import tree_shardings
from repro.launch.steps import batch_axes, input_specs, make_train_step, opt_state_axes
from repro.config.base import TrainConfig, OptimizerConfig
from repro.models.layers import abstract_init
from repro.models.transformer import lm_init

cfg = reduced()
mesh = jax.make_mesh((2, 4), ("data", "model"))
shape = ShapeConfig("t", seq_len=32, global_batch=8, mode="train")
with abstract_init():
    ps, pa = lm_init(cfg, 0)
tc = TrainConfig(optimizer=OptimizerConfig(name="adamw"), microbatches=2)
step, opt_init = make_train_step(cfg, tc)
with mesh:
    p_shard = tree_shardings(mesh, ps, pa)
    specs = input_specs(cfg, shape)
    b_shard = tree_shardings(mesh, specs, batch_axes(cfg, shape))
    opt_shapes = jax.eval_shape(opt_init, ps)
    import repro.launch.dryrun as dr
    o_shard = dr._opt_shardings(mesh, opt_shapes, opt_state_axes(cfg, pa, tc.optimizer), p_shard)
    lowered = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                      out_shardings=(p_shard, o_shard, None)).lower(ps, opt_shapes, specs)
    compiled = lowered.compile()
    print("COMPILED_OK", compiled.memory_analysis().temp_size_in_bytes >= 0)
"""


def test_multidevice_train_step_compiles():
    """8 virtual devices in a subprocess (XLA flag must precede jax import)."""
    out = subprocess.run(
        [sys.executable, "-c", SUBPROC], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"}, cwd="/root/repo", timeout=600)
    assert "COMPILED_OK True" in out.stdout, out.stderr[-2000:]
