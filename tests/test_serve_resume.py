"""Crash-consistent service resume tests: a service killed mid-horizon
(``SimulatedCrash`` — raised past the checkpoint boundary, exactly like a
hard kill) and resumed from the newest committed checkpoint must replay
the REMAINING trace to a trajectory BIT-IDENTICAL to an uninterrupted
run — records, fairness counts, tenant metrics, rescore costs, and the
summary, across schedulers (including the stateful BODS ring)."""

import dataclasses

import numpy as np
import pytest

from repro.checkpoint import committed_steps
from repro.experiment.presets import get_preset
from repro.faults import FaultSpec
from repro.serve.metrics import ServiceMetrics
from repro.serve.service import SchedulerService, SimulatedCrash


def service_spec(scheduler="bods", with_faults=True, num_devices=40):
    spec = get_preset("online-smoke", scheduler=scheduler,
                      num_devices=num_devices, horizon=8_000.0,
                      interarrival=600.0)
    if with_faults:
        spec = spec.replace(faults=FaultSpec(
            seed=3, dropout_rate=0.1, crash_rate=0.002, straggler_rate=0.1,
            num_domains=4, domain_outage_rate=0.02, corrupt_rate=0.05))
    return spec


def record_tuples(service):
    return [(r.job, r.round_idx, r.t_start, r.t_end, r.round_time, r.cost,
             r.fairness, r.loss, r.accuracy, tuple(r.device_ids),
             tuple(r.dropped), tuple(r.corrupt_ids), tuple(r.failed_ids),
             r.degraded, r.rung, r.decision_ms)
            for r in service.engine.records]


def run_reference(spec):
    svc = SchedulerService(spec)
    report = svc.run()
    return svc, report


def crash_and_resume(spec, tmp_path, crash_after, checkpoint_every=2):
    ck = str(tmp_path / f"ck_{crash_after}")
    svc = SchedulerService(spec, checkpoint_dir=ck,
                           checkpoint_every=checkpoint_every,
                           crash_after=crash_after)
    with pytest.raises(SimulatedCrash):
        svc.run()
    resumed = SchedulerService.resume(ck)
    report = resumed.run()
    return resumed, report


@pytest.mark.parametrize("scheduler", ["bods", "random"])
def test_crash_resume_bit_identical(scheduler, tmp_path):
    spec = service_spec(scheduler)
    ref, ref_report = run_reference(spec)
    ref_records = record_tuples(ref)
    assert len(ref_records) > 0

    # kill at several event boundaries: aligned with a checkpoint, one past
    # it, and deep into the horizon
    for crash_after in (4, 5, 11):
        resumed, report = crash_and_resume(spec, tmp_path, crash_after)
        assert record_tuples(resumed) == ref_records, crash_after
        np.testing.assert_array_equal(resumed.engine.counts,
                                      ref.engine.counts)
        assert report.rounds_completed == ref_report.rounds_completed
        assert report.arrivals == ref_report.arrivals
        assert report.departures == ref_report.departures
        assert report.readmissions == ref_report.readmissions
        assert report.tenant_fairness == ref_report.tenant_fairness
        assert resumed.rescore_costs == ref.rescore_costs
        assert resumed.engine.summary() == ref.engine.summary()
        assert {t: dataclasses.asdict(s)
                for t, s in resumed.metrics.tenants.items()} \
            == {t: dataclasses.asdict(s)
                for t, s in ref.metrics.tenants.items()}


def test_resume_without_faults_axis(tmp_path):
    spec = service_spec("random", with_faults=False)
    ref, _ = run_reference(spec)
    resumed, _ = crash_and_resume(spec, tmp_path, 5)
    assert record_tuples(resumed) == record_tuples(ref)


def test_resume_restores_cursor_and_trace(tmp_path):
    spec = service_spec("random")
    ck = str(tmp_path / "ck")
    svc = SchedulerService(spec, checkpoint_dir=ck, checkpoint_every=3,
                           crash_after=7)
    with pytest.raises(SimulatedCrash):
        svc.run()
    # newest committed step is the latest checkpoint boundary <= crash point
    steps = committed_steps(ck)
    assert steps and steps[-1] == 6
    resumed = SchedulerService.resume(ck)
    assert resumed._next_event == 6
    assert resumed.trace is not None
    assert [e.to_dict() for e in resumed.trace] \
        == [e.to_dict() for e in svc.trace]
    # the resumed service keeps checkpointing from where it left off
    resumed.run()
    assert committed_steps(ck)[-1] > 6


def test_checkpoints_are_gcd_to_keep_limit(tmp_path):
    spec = service_spec("random")
    ck = str(tmp_path / "ck")
    svc = SchedulerService(spec, checkpoint_dir=ck, checkpoint_every=1)
    svc.run()
    steps = committed_steps(ck)
    assert len(steps) <= svc._ckpt_manager.keep
    assert steps[-1] == svc._next_event


def test_service_metrics_state_round_trip():
    m = ServiceMetrics()
    m.arrivals, m.departures, m.rejections = 5, 3, 1
    ts = m.tenant("tenant-a", template=1)
    ts.rounds, ts.total_cost, ts.best_accuracy = 2, 3.5, 0.8
    ts.admissions, ts.queued_at = 1, 10.0
    m.decision_latency.add(0.01)
    m.sample_queue_depth(4)
    m2 = ServiceMetrics()
    m2.load_state(m.to_state())
    assert m2.to_state() == m.to_state()
    assert m2.tenants["tenant-a"].rounds == 2
    assert m2.tenants["tenant-a"].best_accuracy == 0.8
    assert m2.tenants["tenant-a"].queued_at == 10.0
    assert m2.decision_latency.samples == [0.01]


def test_crash_before_first_checkpoint_restarts_clean(tmp_path):
    """A crash before any checkpoint commits leaves nothing to resume —
    resume() must fail loudly, not silently restart from scratch."""
    spec = service_spec("random")
    ck = str(tmp_path / "ck")
    svc = SchedulerService(spec, checkpoint_dir=ck, checkpoint_every=50,
                           crash_after=2)
    with pytest.raises(SimulatedCrash):
        svc.run()
    assert committed_steps(ck) == []
    with pytest.raises(FileNotFoundError):
        SchedulerService.resume(ck)
