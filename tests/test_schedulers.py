"""Scheduler tests: plan invariants (property-based) + behavioural checks."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; suite must collect without it
from hypothesis import given, settings, strategies as st

from repro.core.cost import CostModel
from repro.core.devices import DevicePool
from repro.core.plans import random_plans, repair_plan, validate_plan
from repro.core.schedulers import get_scheduler, list_schedulers
from repro.core.schedulers.base import SchedulingContext


def make_ctx(pool, job=0, n_sel=5, occupied=None, counts=None, round_idx=0):
    K = pool.num_devices
    avail = np.ones(K, dtype=bool)
    if occupied is not None:
        avail[occupied] = False
    return SchedulingContext(
        job=job, round_idx=round_idx, tau=5.0, n_sel=n_sel,
        available=avail,
        counts=counts if counts is not None else np.zeros(K),
        expected_times=pool.expected_times(job, 5.0))


FAST_SCHEDULERS = ["random", "greedy", "fedcs", "genetic", "sa", "bods"]


@pytest.mark.parametrize("name", FAST_SCHEDULERS)
def test_plan_invariants_all_schedulers(name):
    """Every scheduler returns exactly n_sel available devices, always."""
    pool = DevicePool.heterogeneous(40, 2, seed=1)
    cm = CostModel(pool)
    cm.calibrate([5.0, 5.0], n_sel=4)
    sched = get_scheduler(name, cost_model=cm, seed=0)
    rng = np.random.default_rng(0)
    counts = np.zeros(40)
    for r in range(8):
        occ = rng.choice(40, rng.integers(0, 20), replace=False)
        ctx = make_ctx(pool, n_sel=4, occupied=occ, counts=counts, round_idx=r)
        plan = sched.schedule(ctx)
        validate_plan(plan, ctx.available, 4)
        sched.observe(ctx, plan, float(rng.random()))
        counts += plan


def test_rlds_plan_invariants():
    pool = DevicePool.heterogeneous(30, 2, seed=1)
    cm = CostModel(pool)
    cm.calibrate([5.0, 5.0], n_sel=3)
    sched = get_scheduler("rlds", cost_model=cm, seed=0, pretrain_rounds=10)
    rng = np.random.default_rng(0)
    for r in range(5):
        occ = rng.choice(30, 10, replace=False)
        ctx = make_ctx(pool, n_sel=3, occupied=occ, round_idx=r)
        plan = sched.schedule(ctx)
        validate_plan(plan, ctx.available, 3)
        sched.observe(ctx, plan, 1.0)


def test_greedy_selects_fastest():
    pool = DevicePool.heterogeneous(30, 1, seed=2)
    cm = CostModel(pool)
    sched = get_scheduler("greedy", cost_model=cm, seed=0)
    ctx = make_ctx(pool, n_sel=5)
    plan = sched.schedule(ctx)
    t = ctx.expected_times
    assert set(np.flatnonzero(plan)) == set(np.argsort(t)[:5])


def test_bods_beats_random_on_estimated_cost():
    """After warm-up, BODS round cost should beat random's average."""
    pool = DevicePool.heterogeneous(60, 1, seed=3)
    cm = CostModel(pool, alpha=4.0, beta=0.25)
    cm.calibrate([5.0], n_sel=6)
    bods = get_scheduler("bods", cost_model=cm, seed=0)
    rng = np.random.default_rng(0)
    counts = np.zeros(60)
    bods_costs, rand_costs = [], []
    for r in range(25):
        ctx = make_ctx(pool, n_sel=6, counts=counts, round_idx=r)
        plan = bods.schedule(ctx)
        c = float(bods._own_cost_of(ctx, plan[None])[0])
        bods.observe(ctx, plan, c)
        bods_costs.append(c)
        rp = random_plans(rng, ctx.available, 6, 1)[0]
        rand_costs.append(float(bods._own_cost_of(ctx, rp[None])[0]))
        counts += plan
    assert np.mean(bods_costs[5:]) < np.mean(rand_costs[5:])


# ---- hypothesis property tests on the plan utilities ----

@settings(max_examples=50, deadline=None)
@given(data=st.data(), k=st.integers(10, 60), n_sel=st.integers(1, 8))
def test_repair_plan_always_feasible(data, k, n_sel):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    available = np.array(data.draw(st.lists(st.booleans(), min_size=k, max_size=k)))
    if available.sum() < n_sel:
        available[:n_sel] = True
    raw = np.array(data.draw(st.lists(st.booleans(), min_size=k, max_size=k)))
    fixed = repair_plan(rng, raw.copy(), available, n_sel)
    validate_plan(fixed, available, n_sel)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31), n_sel=st.integers(1, 10), count=st.integers(1, 8))
def test_random_plans_valid(seed, n_sel, count):
    rng = np.random.default_rng(seed)
    available = rng.random(40) < 0.7
    if available.sum() < n_sel:
        available[:n_sel] = True
    plans = random_plans(rng, available, n_sel, count)
    for p in plans:
        validate_plan(p, available, n_sel)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_fairness_batch_matches_scalar(seed):
    rng = np.random.default_rng(seed)
    pool = DevicePool.heterogeneous(25, 1, seed=0)
    cm = CostModel(pool, delta_fairness=False)
    counts = rng.integers(0, 6, 25).astype(float)
    plans = random_plans(rng, np.ones(25, bool), 5, 4)
    batch = cm.fairness_batch(counts, plans)
    for i, p in enumerate(plans):
        assert batch[i] == pytest.approx(cm.fairness(counts, p))


def test_bods_degenerate_pool_no_nan():
    """Identical available devices / single free device must not produce NaN
    logits in the structured candidate sampler."""
    pool = DevicePool.heterogeneous(20, 1, seed=0)
    pool.a[:] = 1e-3          # all devices identical
    pool.mu[:] = 5.0
    pool.data_sizes[:] = 400.0
    pool.invalidate()         # in-place mutation -> drop SoA caches
    cm = CostModel(pool)
    cm.calibrate([5.0], n_sel=3)
    sched = get_scheduler("bods", cost_model=cm, seed=0)
    ctx = make_ctx(pool, n_sel=3)
    plan = sched.schedule(ctx)
    validate_plan(plan, ctx.available, 3)
    # only n_sel free devices at all: ptp over one value is 0
    occ = np.arange(3, 20)
    ctx2 = make_ctx(pool, n_sel=3, occupied=occ, round_idx=1)
    plan2 = sched.schedule(ctx2)
    validate_plan(plan2, ctx2.available, 3)


def test_baselines_record_estimated_cost():
    """greedy/fedcs/random route their chosen plan through the scoring core."""
    pool = DevicePool.heterogeneous(30, 1, seed=2)
    cm = CostModel(pool)
    cm.calibrate([5.0], n_sel=5)
    for name in ("greedy", "fedcs", "random"):
        sched = get_scheduler(name, cost_model=cm, seed=0)
        assert sched.last_estimated_cost is None
        plan = sched.schedule(make_ctx(pool, n_sel=5))
        validate_plan(plan, np.ones(30, bool), 5)
        assert np.isfinite(sched.last_estimated_cost)
