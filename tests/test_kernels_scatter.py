"""Weighted scatter-add kernel vs oracle sweeps (interpret mode on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.scatter_add import scatter_add

CASES = [
    (3, 17, 64),      # size < lane width (pad path)
    (8, 32, 300),     # size not a multiple of the block
    (1, 5, 1000),     # single row
    (16, 64, 4096),   # multi-tile stream and output
]


@pytest.mark.parametrize("n,k,size", CASES)
def test_scatter_add_matches_oracle(n, k, size):
    rng = np.random.default_rng(n * 1000 + k)
    vals = jnp.asarray(rng.normal(0, 1, (n, k)), jnp.float32)
    # duplicates both within and across rows exercise the accumulation
    idx = jnp.asarray(rng.integers(0, size, (n, k)), jnp.int32)
    w = jnp.asarray(rng.uniform(0.1, 2.0, (n,)), jnp.float32)
    out = scatter_add(vals, idx, w, size, block_s=128, block_k=128,
                      interpret=True)
    exp = ref.scatter_add(vals, idx, w, size)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-5, rtol=1e-5)


def test_scatter_add_negative_idx_is_padding():
    vals = jnp.asarray([[1.0, 2.0, 3.0]])
    idx = jnp.asarray([[0, -1, 2]], jnp.int32)
    w = jnp.asarray([2.0])
    out = scatter_add(vals, idx, w, 4, interpret=True)
    np.testing.assert_allclose(np.asarray(out), [2.0, 0.0, 6.0, 0.0],
                               atol=1e-6)
    exp = ref.scatter_add(vals, idx, w, 4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-6)


def test_scatter_add_all_collisions():
    rng = np.random.default_rng(7)
    vals = jnp.asarray(rng.normal(0, 1, (4, 9)), jnp.float32)
    idx = jnp.zeros((4, 9), jnp.int32)  # everything lands on position 0
    w = jnp.asarray(rng.uniform(0.5, 1.5, (4,)), jnp.float32)
    out = scatter_add(vals, idx, w, 16, interpret=True)
    expected = float((np.asarray(vals) * np.asarray(w)[:, None]).sum())
    assert abs(float(out[0]) - expected) < 1e-4
    np.testing.assert_allclose(np.asarray(out[1:]), 0.0)
