"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step on CPU, asserting output shapes and finiteness (no NaNs)."""

import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import ArchFamily
from repro.models.transformer import (
    init_decode_state,
    lm_apply,
    lm_decode_step,
    lm_init,
    lm_loss,
)

REDUCED_MODULES = {
    "qwen3-1.7b": "repro.configs.qwen3_1p7b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "glm4-9b": "repro.configs.glm4_9b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "hymba-1.5b": "repro.configs.hymba_1p5b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "paligemma-3b": "repro.configs.paligemma_3b",
}


def reduced_cfg(arch):
    return importlib.import_module(REDUCED_MODULES[arch]).reduced()


def make_batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.family == ArchFamily.AUDIO:
        batch["frontend"] = jnp.asarray(
            rng.normal(0, 1, (B, S, cfg.d_model)), jnp.float32)
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    elif cfg.family == ArchFamily.VLM:
        F = cfg.frontend_tokens
        batch["frontend"] = jnp.asarray(
            rng.normal(0, 1, (B, F, cfg.d_model)), jnp.float32)
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        batch["labels"] = batch["tokens"]
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        batch["labels"] = batch["tokens"]
    return batch


@pytest.mark.parametrize("arch", sorted(REDUCED_MODULES))
def test_forward_shapes_and_finiteness(arch):
    cfg = reduced_cfg(arch)
    params, axes = lm_init(cfg, seed=0)
    # axes tree must mirror params tree
    jax.tree_util.tree_map(lambda p, a: None, params,
                           jax.tree_util.tree_map(lambda a: a, axes,
                                                  is_leaf=lambda x: isinstance(x, tuple)))
    batch = make_batch(cfg)
    logits = lm_apply(cfg, params, tokens=batch.get("tokens"),
                      frontend=batch.get("frontend"))
    B = 2
    S_total = 16 + (cfg.frontend_tokens if cfg.family == ArchFamily.VLM else 0)
    assert logits.shape == (B, S_total, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", sorted(REDUCED_MODULES))
def test_one_train_step_no_nans(arch):
    cfg = reduced_cfg(arch)
    params, _ = lm_init(cfg, seed=0)
    batch = make_batch(cfg)

    def loss_fn(p):
        return lm_loss(cfg, p, batch)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss {loss}"
    # SGD step; loss must decrease (learnable) and stay finite
    params2 = jax.tree_util.tree_map(lambda p, g: p - 0.05 * g, params, grads)
    loss2 = loss_fn(params2)
    assert bool(jnp.isfinite(loss2))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), f"{arch}: NaN grads"


@pytest.mark.parametrize("arch", sorted(REDUCED_MODULES))
def test_decode_step(arch):
    cfg = reduced_cfg(arch)
    params, _ = lm_init(cfg, seed=0)
    B, T = 2, 32
    state = init_decode_state(cfg, B, T)
    length = jnp.asarray([3, 5], jnp.int32)
    if cfg.family == ArchFamily.AUDIO:
        tok = jnp.asarray(np.random.default_rng(0).normal(0, 1, (B, cfg.d_model)), jnp.float32)
    else:
        tok = jnp.asarray([1, 2], jnp.int32)
    logits, new_state = lm_decode_step(cfg, params, state, tok, length)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # decode twice more to exercise cache writes
    logits, new_state = lm_decode_step(cfg, params, new_state, tok, length + 1)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ["paper-lenet5", "paper-cnn-b",
                                  "paper-resnet18"])
def test_cnn_conv_impls_agree(arch):
    """The GEMM (im2col) conv lowering must match the historical lax conv
    on full model forwards, and on gradients for pool-free models (max-pool
    backward legitimately routes gradient to a DIFFERENT tied element under
    the two lowerings — both valid subgradients, so lenet5's grads are
    exempt)."""
    from repro.config.registry import get_arch
    from repro.models import cnn_zoo

    cfg = get_arch(arch)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 1, (4, *cfg.input_shape)), jnp.float32)
    y = jnp.asarray(rng.integers(0, cfg.num_classes, 4), jnp.int32)
    params = cnn_zoo.cnn_init(cfg, seed=0)

    def loss_and_grad():
        loss, _ = cnn_zoo.cnn_loss_and_accuracy(params, cfg, x, y)
        g = jax.grad(lambda p: cnn_zoo.cnn_loss_and_accuracy(p, cfg, x, y)[0])(params)
        return cnn_zoo.cnn_apply(params, cfg, x), loss, g

    try:
        cnn_zoo.set_conv_impl("gemm")
        out_g, loss_g, grad_g = loss_and_grad()
        cnn_zoo.set_conv_impl("lax")
        out_l, loss_l, grad_l = loss_and_grad()
    finally:
        cnn_zoo.set_conv_impl("gemm")
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_l),
                               atol=1e-4, rtol=1e-4)
    assert abs(float(loss_g) - float(loss_l)) < 1e-5
    has_pool = any(layer[0] == "convp" for layer in cfg.cnn_spec)
    if not has_pool:
        for a, b in zip(jax.tree_util.tree_leaves(grad_g),
                        jax.tree_util.tree_leaves(grad_l)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4, rtol=1e-3)
