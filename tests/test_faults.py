"""Fault-resilience tests: the ``FaultSpec`` axis, the replayable keyed
``FaultEngine`` schedule, engine-level fault semantics (quarantine
escalation, crashes, domain outages, deadline rounds, degraded fallbacks,
corruption screening), and the fused runtime's in-jit robust aggregation
parity with the host reference."""

import dataclasses

import numpy as np
import pytest

from repro.experiment import ExperimentSpec, JobSpec, PoolSpec
from repro.faults import FaultEngine, FaultSpec

K = 30


def fault_spec(**kw) -> FaultSpec:
    return FaultSpec(seed=5, **kw)


def run_spec(faults, scheduler="random", max_rounds=12, n_sel=4,
             num_devices=K, **overrides):
    spec = ExperimentSpec(
        jobs=tuple(JobSpec(name=f"j{i}", target_metric=0.99,
                           max_rounds=max_rounds) for i in range(2)),
        pool=PoolSpec(num_devices=num_devices, seed=3),
        scheduler=scheduler, runtime="synthetic",
        runtime_kwargs={"seed": 2}, n_sel=n_sel, faults=faults)
    spec = spec.replace(**overrides) if overrides else spec
    return spec.run()


# ---- FaultSpec (the axis) ------------------------------------------------

def test_fault_spec_round_trip_and_validation():
    fs = fault_spec(dropout_rate=0.2, crash_rate=0.01, straggler_rate=0.1,
                    num_domains=4, domain_outage_rate=0.05,
                    corrupt_rate=0.03, corrupt_mode="scale",
                    round_deadline=40.0)
    assert FaultSpec.from_dict(fs.to_dict()) == fs
    assert not fs.inert and FaultSpec().inert
    # domains without an outage rate inject nothing
    assert FaultSpec(num_domains=8).inert
    with pytest.raises(ValueError):
        FaultSpec(corrupt_mode="zeros")
    with pytest.raises(ValueError):
        FaultSpec(dropout_rate=1.5)
    with pytest.raises(ValueError):
        FaultSpec(backoff=0.5)


def test_experiment_axis_and_legacy_alias():
    fs = fault_spec(dropout_rate=0.2)
    spec = ExperimentSpec(jobs=(JobSpec(name="j"),), faults=fs,
                          failure_rate=0.9)
    # the axis wins over the deprecated alias when both are set
    assert spec.effective_faults() is fs
    restored = ExperimentSpec.from_json(spec.to_json())
    assert restored == spec and restored.faults == fs
    # alias alone maps onto fixed-cooldown uniform dropouts
    legacy = ExperimentSpec(jobs=(JobSpec(name="j"),), failure_rate=0.3,
                            failure_cooldown=90.0)
    eff = legacy.effective_faults()
    assert eff.dropout_rate == 0.3 and eff.cooldown == 90.0
    assert eff.backoff == 1.0 and eff.max_cooldown == 90.0
    assert ExperimentSpec(jobs=(JobSpec(name="j"),)).effective_faults() is None


# ---- FaultEngine (the replayable schedule) -------------------------------

def test_keyed_draws_are_replayable_and_order_independent():
    fs = fault_spec(dropout_rate=0.3, crash_rate=0.05, straggler_rate=0.2,
                    num_domains=4, domain_outage_rate=0.1, corrupt_rate=0.2)
    a, b = FaultEngine(fs, K), FaultEngine(fs, K)
    # query in different (job, round) orders: same schedule either way
    for job, r in [(0, 0), (1, 3), (0, 2)]:
        for x, y in zip(a.failure_masks(job, r),
                        reversed_list := list(b.failure_masks(job, r))):
            np.testing.assert_array_equal(x, y)
        np.testing.assert_array_equal(a.straggler_multipliers(job, r),
                                      b.straggler_multipliers(job, r))
    # corrupt masks agree across subsets (keyed over the full device axis)
    ids = np.array([3, 7, 11, 19])
    full = a.corrupt_mask(0, 5, np.arange(K))
    np.testing.assert_array_equal(b.corrupt_mask(0, 5, ids), full[ids])
    # distinct rounds draw distinct faults (not a constant schedule)
    assert any(not np.array_equal(a.failure_masks(0, r)[0],
                                  a.failure_masks(0, r + 1)[0])
               for r in range(5))


def test_domain_outages_are_correlated_and_win_over_transient():
    fs = fault_spec(dropout_rate=0.5, num_domains=3, domain_outage_rate=0.5)
    fe = FaultEngine(fs, K)
    hit_any = False
    for r in range(10):
        transient, _, domain_out = fe.failure_masks(0, r)
        # outage semantics win: no device is both transient and domain-out
        assert not (transient & domain_out).any()
        for d in range(3):
            members = fe.domain == d
            out = domain_out[members]
            assert out.all() or not out.any()   # whole domain or nothing
            hit_any = hit_any or out.any()
    assert hit_any


def test_escalating_quarantine_and_reset():
    fs = fault_spec(dropout_rate=0.5, cooldown=10.0, backoff=2.0,
                    max_cooldown=35.0)
    fe = FaultEngine(fs, K)
    dev = np.array([4])
    assert fe.quarantine_durations(dev) == [10.0]
    assert fe.quarantine_durations(dev) == [20.0]
    assert fe.quarantine_durations(dev) == [35.0]   # capped, not 40
    fe.record_success(dev)                           # readmission resets
    assert fe.quarantine_durations(dev) == [10.0]
    # state round-trips for checkpointing
    fe2 = FaultEngine(fs, K)
    fe2.load_state_dict(fe.state_dict())
    np.testing.assert_array_equal(fe2.strikes, fe.strikes)


def test_straggler_multipliers_scale_compute():
    fe = FaultEngine(fault_spec(straggler_rate=0.5, straggler_slowdown=4.0),
                     K)
    mult = fe.straggler_multipliers(0, 0)
    assert set(np.unique(mult)) <= {1.0, 4.0}
    assert (mult == 4.0).any() and (mult == 1.0).any()
    assert FaultEngine(fault_spec(), K).straggler_multipliers(0, 0) is None


# ---- engine semantics ----------------------------------------------------

def test_crashes_permanently_remove_devices():
    res = run_spec(fault_spec(crash_rate=0.05), max_rounds=15)
    pool = res.spec.build().engine.pool
    eng = res.spec.build().engine
    eng.run()
    assert np.isinf(eng.pool.busy_until).sum() > 0   # someone crashed for good
    # the run still completes with finite metrics
    assert all(np.isfinite(r.accuracy) for r in eng.records)


def test_all_failed_keeps_fastest_and_marks_degraded():
    res = run_spec(fault_spec(dropout_rate=1.0, cooldown=1.0), max_rounds=6)
    assert len(res.records) > 0
    for r in res.records:
        assert r.degraded
        assert len(r.device_ids) == 1                # the fastest reporter
    assert all(v["degraded_rounds"] > 0 for v in res.summary.values())


def test_round_deadline_partial_aggregation():
    slow = run_spec(fault_spec(round_deadline=1e9), max_rounds=8)
    tight_deadline = float(np.median([r.round_time for r in slow.records]))
    tight = run_spec(fault_spec(round_deadline=tight_deadline), max_rounds=8)
    assert all(r.round_time <= tight_deadline + 1e-9 for r in tight.records)
    # the cut stragglers show up as drops, not failures
    assert sum(len(r.dropped) for r in tight.records) > 0
    assert sum(len(r.dropped) for r in slow.records) == 0


def test_corruption_oracle_discard_excludes_fairness_counts():
    res = run_spec(fault_spec(corrupt_rate=0.4), max_rounds=10)
    eng = res.spec.build().engine
    eng.run()
    n_corrupt = sum(len(r.corrupt_ids) for r in eng.records)
    assert n_corrupt > 0
    assert all(v["corrupt_updates"] > 0 for v in eng.summary().values())
    # fairness counts only credit clean survivors: the synthetic runtime
    # does not screen, so record.device_ids excludes corrupt devices
    for r in eng.records:
        assert not np.intersect1d(r.device_ids, r.corrupt_ids).size
    total_counted = sum(len(r.device_ids) for r in eng.records)
    assert float(eng.counts.sum()) == float(total_counted)


def test_degraded_runs_stay_reproducible():
    fs = fault_spec(dropout_rate=0.3, crash_rate=0.01, straggler_rate=0.2,
                    num_domains=4, domain_outage_rate=0.1, corrupt_rate=0.1)
    r1, r2 = run_spec(fs), run_spec(fs)
    assert r1.summary == r2.summary
    for a, b in zip(r1.records, r2.records):
        np.testing.assert_array_equal(a.device_ids, b.device_ids)
        np.testing.assert_array_equal(a.dropped, b.dropped)
        np.testing.assert_array_equal(a.corrupt_ids, b.corrupt_ids)


# ---- robust aggregation (fused runtime) ----------------------------------

def _tiny_fl_setup(num_jobs=1, num_dev=12, seed=0):
    from repro.config.base import JobConfig
    from repro.configs.paper_models import lenet5
    from repro.data.synthetic import make_classification_dataset
    from repro.fl.partition import noniid_partition

    cfg = dataclasses.replace(
        lenet5(), name="tiny", input_shape=(8, 8, 1),
        cnn_spec=(("convp", 4, 3), ("flatten",), ("fc", 16)))
    jobs, datasets = [], []
    for j in range(num_jobs):
        x, y = make_classification_dataset(600, cfg.input_shape,
                                           cfg.num_classes, noise=1.0,
                                           seed=seed + j)
        ex, ey = make_classification_dataset(60, cfg.input_shape,
                                             cfg.num_classes, noise=1.0,
                                             seed=seed + 50 + j)
        part = noniid_partition(y, num_dev, seed=seed + j)
        jobs.append(JobConfig(job_id=j, model=cfg, target_metric=2.0,
                              local_epochs=1, batch_size=4, lr=0.05))
        datasets.append((x, y, part, ex, ey))
    return jobs, datasets


def test_rejection_mask_matches_host_reference():
    import jax.numpy as jnp

    from repro.fl.aggregation import rejection_mask, rejection_mask_host

    rng = np.random.default_rng(0)
    for trial in range(10):
        n, d = int(rng.integers(3, 12)), int(rng.integers(2, 20))
        g = {"w": rng.normal(size=(d,)).astype(np.float32)}
        s = {"w": (g["w"][None]
                   + 0.1 * rng.normal(size=(n, d)).astype(np.float32))}
        w = rng.uniform(0.0, 2.0, size=n).astype(np.float32)
        for i in range(n):
            u = rng.random()
            if u < 0.2:
                s["w"][i] = np.inf
            elif u < 0.4:
                s["w"][i] *= 50.0
        host = rejection_mask_host(g, s, w, 4.0)
        fused = np.asarray(
            rejection_mask(g, s, jnp.asarray(w), jnp.float32(4.0)))
        np.testing.assert_array_equal(host, fused, err_msg=f"trial {trial}")


def test_rejection_mask_keeps_single_survivor():
    """Median-of-one degenerate: with ONE valid lane, its norm IS the
    median, so any mult < 1 would reject the only update available — the
    rule must keep it unconditionally (jit and host must agree)."""
    import jax.numpy as jnp

    from repro.fl.aggregation import rejection_mask, rejection_mask_host

    g = {"w": np.zeros((4,), np.float32)}
    s = {"w": np.stack([np.full((4,), 2.0, np.float32),     # nonzero norm
                        np.full((4,), np.inf, np.float32),  # non-finite
                        np.full((4,), 9.0, np.float32)])}   # zero weight
    for w in ([1.0, 1.0, 0.0],     # lane 1 killed by the finite guard
              [1.0, 0.0, 0.0]):    # lanes 1-2 not participating
        w = np.asarray(w, np.float32)
        host = rejection_mask_host(g, s, w, 0.5)
        fused = np.asarray(rejection_mask(g, s, jnp.asarray(w),
                                          jnp.float32(0.5)))
        np.testing.assert_array_equal(host, [True, False, False], err_msg=str(w))
        np.testing.assert_array_equal(fused, host, err_msg=str(w))


def test_robust_fedavg_guards():
    import jax.numpy as jnp

    from repro.fl.aggregation import robust_fedavg

    g = {"w": jnp.ones((4,), jnp.float32)}
    clean = jnp.stack([jnp.full((4,), v) for v in (1.1, 0.9, 1.05)])
    # a NaN lane must not poison the average (zeroed before FedAvg)
    s = {"w": clean.at[1].set(jnp.nan)}
    new, ok = robust_fedavg(g, s, jnp.ones(3), jnp.float32(4.0))
    assert np.asarray(ok).tolist() == [True, False, True]
    assert np.isfinite(np.asarray(new["w"])).all()
    np.testing.assert_allclose(np.asarray(new["w"]), 1.075, rtol=1e-6)
    # all lanes rejected -> keep the previous global params, not zeros
    s_bad = {"w": jnp.full((3, 4), jnp.nan)}
    new2, ok2 = robust_fedavg(g, s_bad, jnp.ones(3), jnp.float32(4.0))
    assert not np.asarray(ok2).any()
    np.testing.assert_array_equal(np.asarray(new2["w"]),
                                  np.asarray(g["w"]))


def test_fused_robust_screens_injected_corruption():
    from repro.fl.runtime import FusedMultiRuntime

    fs = fault_spec(corrupt_rate=0.4)
    jobs, datasets = _tiny_fl_setup()
    fe = FaultEngine(fs, 12)
    robust = FusedMultiRuntime(jobs, datasets, seed=0, robust=True,
                               fault_engine=fe)
    assert robust.handles_corruption
    jobs2, datasets2 = _tiny_fl_setup()
    plain = FusedMultiRuntime(jobs2, datasets2, seed=0)
    assert not plain.handles_corruption

    rng = np.random.default_rng(1)
    total_rej = 0
    for r in range(6):
        ids = rng.choice(12, 6, replace=False)
        m = robust.run_round(0, ids, r)
        # the runtime recomputes the engine's exact corrupt mask
        expected = int(fe.corrupt_mask(0, r, ids).sum())
        assert int(m["rejected"]) == expected, (r, m["rejected"], expected)
        total_rej += expected
        assert np.isfinite(m["loss"]) and np.isfinite(m["accuracy"])
    assert total_rej > 0 and robust.rejected_total == total_rej


def test_fused_robust_without_corruption_matches_plain_bitwise():
    from repro.fl.runtime import FusedMultiRuntime

    jobs, datasets = _tiny_fl_setup()
    plain = FusedMultiRuntime(jobs, datasets, seed=0)
    jobs2, datasets2 = _tiny_fl_setup()
    robust = FusedMultiRuntime(jobs2, datasets2, seed=0, robust=True)
    rng = np.random.default_rng(2)
    for r in range(4):
        ids = rng.choice(12, 5, replace=False)
        mp = plain.run_round(0, ids, r)
        mr = robust.run_round(0, ids, r)
        assert mp["loss"] == mr["loss"] and mp["accuracy"] == mr["accuracy"]
        assert mr["rejected"] == 0.0


def test_fused_robust_compile_stability():
    from repro.fl.runtime import FusedMultiRuntime, _fused_group_round

    jobs, datasets = _tiny_fl_setup(seed=9)
    fe = FaultEngine(fault_spec(corrupt_rate=0.3), 12)
    fused = FusedMultiRuntime(jobs, datasets, seed=0, buckets=(4, 8, 12),
                              robust=True, fault_engine=fe)
    before = _fused_group_round._cache_size()
    rng = np.random.default_rng(3)
    for r in range(12):
        n = int(rng.integers(1, 13))
        fused.run_round(0, rng.choice(12, n, replace=False), r)
    compiles = _fused_group_round._cache_size() - before
    assert compiles <= len(fused.buckets), (compiles, fused.buckets)
