"""Experiment-API tests: spec JSON round-trip, registry semantics, and
bit-for-bit equivalence between ``spec.run()`` and hand-wired
``MultiJobEngine`` construction with equal seeds."""

import json

import numpy as np
import pytest

from repro.config.base import ArchFamily, JobConfig, ModelConfig
from repro.core.cost import CostModel
from repro.core.devices import DevicePool
from repro.core.multijob import MultiJobEngine
from repro.core.schedulers import get_scheduler
from repro.experiment import (ExperimentResult, ExperimentSpec, JobSpec,
                              PoolSpec, Registry, get_preset, list_presets)
from repro.experiment.registry import RUNTIMES, SCHEDULERS
from repro.fl.runtime import SyntheticRuntime


def tiny_spec(scheduler="random", **overrides):
    spec = ExperimentSpec(
        jobs=tuple(JobSpec(name=f"j{i}", target_metric=0.75, max_rounds=25)
                   for i in range(2)),
        pool=PoolSpec(num_devices=30, seed=3),
        scheduler=scheduler, runtime="synthetic",
        runtime_kwargs={"seed": 2}, n_sel=4)
    return spec.replace(**overrides) if overrides else spec


# ---- serialization ----

def test_spec_json_round_trip():
    spec = tiny_spec("bods", failure_rate=0.1, release_horizon=0.5,
                     scheduler_kwargs={"seed": 7},
                     pool=PoolSpec(num_devices=30, seed=3,
                                   job_weights=(1.0, 2.0)))
    restored = ExperimentSpec.from_json(spec.to_json())
    assert restored == spec
    # a second hop through plain json stays stable
    assert ExperimentSpec.from_dict(json.loads(restored.to_json())) == spec
    # scheduler_kwargs seed overrides (not collides with) scheduler_seed
    restored.build()


def test_train_spec_round_trip_and_dict_replace():
    from repro.experiment import TrainSpec

    spec = tiny_spec(train=TrainSpec(fused=True, buckets=(4, 8), eval_every=3))
    restored = ExperimentSpec.from_json(spec.to_json())
    assert restored == spec
    assert restored.train.buckets == (4, 8)
    # dict form merges over the current nested value (CLI --set train={...})
    spec2 = spec.replace(train={"eval_every": 5})
    assert spec2.train == TrainSpec(fused=True, buckets=(4, 8), eval_every=5)
    spec3 = spec.replace(train={"buckets": [2, 6]})
    assert spec3.train.buckets == (2, 6)


def test_runtime_kwargs_b0_beats_convergence_rate():
    spec = tiny_spec().replace(
        jobs=(JobSpec(name="j", max_rounds=10, convergence_rate=0.1),),
        runtime_kwargs={"b0": 0.3, "seed": 2})
    assert float(spec.build().engine.runtime.b0) == 0.3


def test_spec_rejects_empty_jobs():
    with pytest.raises(ValueError):
        ExperimentSpec(jobs=())


def test_result_round_trip_and_replay(tmp_path):
    spec = tiny_spec()
    result = spec.run()
    path = tmp_path / "result.json"
    result.save(str(path))
    loaded = ExperimentResult.load(str(path))
    assert loaded.spec == spec
    assert loaded.summary == result.summary
    assert len(loaded.records) == len(result.records)
    np.testing.assert_array_equal(loaded.records[0].device_ids,
                                  result.records[0].device_ids)
    # the embedded spec re-runs to identical summary (replayability)
    assert loaded.spec.run().summary == result.summary


# ---- registry ----

def test_registry_rejects_duplicate_and_unknown():
    reg = Registry("thing")

    @reg.register("a")
    def make_a():
        return "a"

    with pytest.raises(ValueError):
        @reg.register("a")
        def make_a2():
            return "a2"

    with pytest.raises(KeyError):
        reg.get("nope")
    assert reg.create("a") == "a"
    assert "a" in reg and reg.names() == ["a"]


def test_builtin_registries_populated():
    for name in ("random", "greedy", "fedcs", "genetic", "sa", "dnn",
                 "bods", "rlds"):
        assert name in SCHEDULERS
    assert "synthetic" in RUNTIMES and "real_fl" in RUNTIMES
    with pytest.raises(KeyError):
        SCHEDULERS.get("not-a-scheduler")
    with pytest.raises(KeyError):
        tiny_spec().replace(runtime="not-a-runtime").build()


# ---- engine equivalence ----

def test_spec_run_matches_hand_wired_engine_bit_for_bit():
    spec = tiny_spec("bods")
    result = spec.run()

    mc = ModelConfig(name="x", family=ArchFamily.CNN, cnn_spec=(("flatten",),),
                     input_shape=(4, 4, 1), num_classes=10)
    jobs = [JobConfig(job_id=i, model=mc, target_metric=0.75, max_rounds=25)
            for i in range(2)]
    pool = DevicePool.heterogeneous(30, 2, seed=3)
    cm = CostModel(pool, alpha=4.0, beta=0.25)
    cm.calibrate([5.0, 5.0], n_sel=4)
    eng = MultiJobEngine(jobs, pool, cm,
                         get_scheduler("bods", cost_model=cm, seed=0),
                         SyntheticRuntime(num_jobs=2, num_devices=30, seed=2),
                         n_sel=4, rng=np.random.default_rng(12345))
    eng.run()

    assert len(result.records) == len(eng.records)
    for a, b in zip(result.records, eng.records):
        assert a.round_time == b.round_time
        assert a.cost == b.cost
        assert a.accuracy == b.accuracy
        np.testing.assert_array_equal(a.device_ids, b.device_ids)
    # summary keys differ only by job name; values must match exactly
    assert list(result.summary.values()) == list(eng.summary().values())


def test_equal_specs_reproduce_exactly():
    r1 = tiny_spec("genetic").run()
    r2 = ExperimentSpec.from_json(tiny_spec("genetic").to_json()).run()
    assert r1.summary == r2.summary


# ---- per-job convergence rates ----

def test_per_job_convergence_rate_reaches_runtime():
    spec = tiny_spec().replace(jobs=(
        JobSpec(name="slow", target_metric=0.75, max_rounds=25,
                convergence_rate=0.05),
        JobSpec(name="fast", target_metric=0.75, max_rounds=25,
                convergence_rate=0.4)))
    exp = spec.build()
    np.testing.assert_allclose(exp.engine.runtime.b0, [0.05, 0.4])
    s = exp.run().summary
    # the fast job must out-converge the slow one over equal round budgets
    assert s["fast"]["best_accuracy"] > s["slow"]["best_accuracy"]


def test_synthetic_runtime_scalar_b0_still_works():
    rt = SyntheticRuntime(num_jobs=2, num_devices=10, b0=0.15, seed=0)
    m = rt.run_round(0, np.arange(5), 0)
    assert 0.0 <= m["accuracy"] <= 1.0


# ---- presets & CLI ----

def test_presets_exist_and_build():
    names = list_presets()
    for expected in ("paper-group-a", "paper-group-b", "quickstart",
                     "real-fl-two-job", "fault-injection"):
        assert expected in names
    spec = get_preset("paper-group-a", scheduler="random", max_rounds=10)
    assert [j.name for j in spec.jobs] == ["vgg16", "cnn-a", "lenet5"]
    assert spec.jobs[0].convergence_rate is not None
    fault = get_preset("fault-injection", scheduler="random")
    assert fault.faults is not None and not fault.faults.inert
    assert fault.effective_faults().dropout_rate > 0
    # fault preset really drops devices, and the run stays finite
    res = fault.replace(jobs=tuple(j for j in tiny_spec().jobs)).run()
    assert sum(len(r.dropped) for r in res.records) > 0
    assert all(np.isfinite(r.accuracy) and np.isfinite(r.loss)
               for r in res.records)


def test_cli_run_and_list(tmp_path, capsys):
    from repro.experiment import cli

    spec_path = tmp_path / "spec.json"
    out_path = tmp_path / "result.json"
    tiny_spec().save(str(spec_path))
    cli.main(["run", str(spec_path), "--out", str(out_path)])
    loaded = ExperimentResult.load(str(out_path))
    assert loaded.summary == tiny_spec().run().summary

    cli.main(["list"])
    out = capsys.readouterr().out
    assert "bods" in out and "real_fl" in out and "quickstart" in out


def test_cli_preset_with_overrides(tmp_path, capsys):
    from repro.experiment import cli

    spec_path = tmp_path / "spec.json"
    cli.main(["preset", "quickstart", "--arg", "scheduler=random",
              "--arg", "max_rounds=5", "--set", "n_sel=4",
              "--out", str(spec_path)])
    spec = ExperimentSpec.load(str(spec_path))
    assert spec.scheduler == "random"
    assert spec.jobs[0].max_rounds == 5
    assert spec.n_sel == 4


# ---- fleet axis ----

def test_fleet_spec_round_trip_and_build():
    from repro.experiment import FleetSpec

    spec = tiny_spec(fleet=FleetSpec(num_devices=80, n_sel=6, candidates=32,
                                     scoring_backend="jax"))
    spec2 = ExperimentSpec.from_json(spec.to_json())
    assert spec2 == spec
    exp = spec.build()
    assert exp.engine.pool.num_devices == 80       # fleet overrides pool
    assert exp.engine.n_sel == 6
    assert exp.engine.cost_model.scoring_backend == "jax"


def test_fleet_candidates_map_to_scheduler_knob():
    from repro.experiment import FleetSpec

    fleet = FleetSpec(candidates=48)
    bods = tiny_spec(scheduler="bods", fleet=fleet).build()
    assert bods.engine.scheduler.num_candidates == 48
    gen = tiny_spec(scheduler="genetic", fleet=fleet).build()
    assert gen.engine.scheduler.population == 48
    # schedulers without a candidate knob just ignore the axis
    tiny_spec(scheduler="greedy", fleet=fleet).build()


def test_top_level_scoring_backend_wins():
    from repro.experiment import FleetSpec

    spec = tiny_spec(fleet=FleetSpec(scoring_backend="numpy"),
                     scoring_backend="jax")
    assert spec.build().engine.cost_model.scoring_backend == "jax"


def test_fleet_scale_preset_runs_end_to_end():
    spec = get_preset("fleet-scale", num_devices=300, scheduler="random",
                      max_rounds=2)
    res = spec.run()
    assert len(res.records) > 0
    assert all("mean_round_time" in v for v in res.summary.values())
