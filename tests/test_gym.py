"""Scheduler-gym tests: engine parity, rollout invariants, trainer smoke,
policy-zoo bit-exact round-trips, and the ExperimentSpec ``policy`` axis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cost import CostModel
from repro.core.devices import DevicePool
from repro.core.multijob import MultiJobEngine
from repro.core.plans import random_plans
from repro.core.schedulers import get_scheduler
from repro.core.schedulers.base import SchedulerBase
from repro.experiment.spec import ExperimentSpec, JobSpec, PoolSpec
from repro.gym import (CURRICULA, EnvConfig, PolicyZoo, TrainConfig,
                       batch_reset, batch_rollout, default_stages, evaluate,
                       reset, save_rlds_params, state_from_pool, step,
                       train_rlds)
from repro.gym.env import _apply_round


def small_cfg(**kw):
    return EnvConfig(**{"num_devices": 24, "num_jobs": 2, "n_sel": 3, **kw})


def make_ctx(pool, job=0, n_sel=3, counts=None, round_idx=0):
    from repro.core.schedulers.base import SchedulingContext

    K = pool.num_devices
    return SchedulingContext(
        job=job, round_idx=round_idx, tau=5.0, n_sel=n_sel,
        available=np.ones(K, dtype=bool),
        counts=counts if counts is not None else np.zeros(K),
        expected_times=pool.expected_times(job, 5.0))


# ---- environment basics --------------------------------------------------

def test_reset_shapes_and_calibration():
    cfg = small_cfg()
    state = reset(cfg, CURRICULA["default"], jax.random.PRNGKey(0))
    assert state.scen.a.shape == (24,) and state.scen.data.shape == (24, 2)
    assert state.scen.shift.shape == (2, 24)   # SoA fast path materialized
    assert state.counts.shape == (2, 24)
    assert float(state.scen.time_scale) > 0
    assert float(state.scen.fairness_scale) > 0
    assert int(state.job) == 0 and int(state.t) == 0
    # derived arrays agree with the raw coefficients
    np.testing.assert_allclose(
        np.asarray(state.scen.exp_base),
        np.asarray(state.scen.taus)[:, None] * np.asarray(state.scen.data).T
        * (np.asarray(state.scen.a) + 1.0 / np.asarray(state.scen.mu)),
        rtol=1e-5)


def test_batch_reset_scenarios_differ():
    cfg = small_cfg()
    states = batch_reset(cfg, CURRICULA["full"], jax.random.PRNGKey(1), 4)
    a = np.asarray(states.scen.a)
    assert a.shape == (4, 24)
    assert not np.allclose(a[0], a[1])  # independent scenario draws
    taus = np.asarray(states.scen.taus)
    assert taus.min() >= 1 and taus.max() <= 10


def test_step_updates_dynamics():
    cfg = small_cfg()
    state = reset(cfg, CURRICULA["default"], jax.random.PRNGKey(2))
    plan = jnp.zeros(24, bool).at[jnp.arange(3)].set(True)
    state2, out = step(cfg, state, plan)
    assert int(state2.job) == 1 and int(state2.t) == 1
    assert int(state2.round_idx[0]) == 1 and int(state2.round_idx[1]) == 0
    assert float(out.round_time) > 0 and np.isfinite(float(out.cost))
    # scheduled devices are busy until their own finish instants
    assert (np.asarray(state2.busy_until)[:3] > 0).all()
    assert np.allclose(np.asarray(state2.counts[0])[:3], 1.0)


def test_rollout_plans_valid_and_vmapped():
    """Every sampled plan: exactly n_sel devices, all available."""
    cfg = small_cfg()
    from repro.core.schedulers.rlds import init_policy

    params = init_policy(jax.random.PRNGKey(0))
    states = batch_reset(cfg, CURRICULA["flaky"], jax.random.PRNGKey(3), 3)
    _, tr = batch_rollout(cfg, params, states, 12)
    assert tr.plan.shape == (3, 12, 24)
    assert bool((tr.plan.sum(-1) == cfg.n_sel).all())
    assert not bool((tr.plan & ~tr.available).any())
    assert bool(jnp.isfinite(tr.cost).all())


# ---- engine parity (satellite: 1e-5 agreement on a fixed seed) -----------

class _Scripted(SchedulerBase):
    name = "scripted"

    def __init__(self, cost_model, plans):
        super().__init__(cost_model)
        self.plans = plans

    def schedule(self, ctx):
        return self.plans[ctx.round_idx]


class _StubRuntime:
    def run_round(self, job, device_ids, round_idx):
        return {"loss": 1.0, "accuracy": 0.0}


def test_gym_step_matches_engine_cost_model():
    """Gym round-time/fairness/cost == MultiJobEngine + CostModel to 1e-5
    when both consume the identical Formula-4 draws."""
    R, K, NSEL, TAU = 8, 40, 5, 3.0
    pool = DevicePool.heterogeneous(K, 1, seed=7)
    cm = CostModel(pool, alpha=4.0, beta=0.25)
    cm.calibrate([TAU], n_sel=NSEL)
    plans = random_plans(np.random.default_rng(3), np.ones(K, bool), NSEL, R)
    job = JobSpec(name="j", max_rounds=R, local_epochs=int(TAU)).to_job_config(0)
    engine = MultiJobEngine([job], pool, cm, _Scripted(cm, plans),
                            _StubRuntime(), n_sel=NSEL)
    engine.run()
    assert len(engine.records) == R

    # An identical pool replays the engine's exact exponential draws (the
    # engine consumed pool.rng once per round, K draws each).
    pool2 = DevicePool.heterogeneous(K, 1, seed=7)
    cfg = EnvConfig(num_devices=K, num_jobs=1, n_sel=NSEL,
                    alpha=4.0, beta=0.25)
    state = state_from_pool(pool2, cm, taus=[TAU])
    no_fail = jnp.ones(K)
    for r, rec in enumerate(engine.records):
        noise = pool2.rng.standard_exponential(K)
        state, out = _apply_round(cfg, state, jnp.asarray(plans[r]),
                                  jnp.asarray(noise, jnp.float32), no_fail)
        assert float(out.round_time) == pytest.approx(rec.round_time, rel=1e-5)
        assert float(out.fairness) == pytest.approx(rec.fairness,
                                                    rel=1e-5, abs=1e-6)
        assert float(out.cost) == pytest.approx(rec.cost, rel=1e-5, abs=1e-6)
    np.testing.assert_allclose(np.asarray(state.counts[0]), engine.counts[0])


def test_gym_cost_honors_absolute_fairness():
    """delta_fairness=False specs: the gym cost uses the absolute Formula-5
    variance, matching CostModel.cost (engine realized-cost form)."""
    K, NSEL = 30, 4
    pool = DevicePool.heterogeneous(K, 1, seed=5)
    cm = CostModel(pool, alpha=4.0, beta=0.25, delta_fairness=False)
    cm.calibrate([2.0], n_sel=NSEL)
    from repro.gym.env import config_from_cost_model

    cfg = config_from_cost_model(cm, n_sel=NSEL)
    assert cfg.delta_fairness is False
    state = state_from_pool(pool, cm, taus=[2.0])
    # seed some counts so absolute and delta fairness genuinely differ
    counts = np.zeros((1, K), np.float32)
    counts[0, :5] = 3.0
    state = state._replace(counts=jnp.asarray(counts))
    plan = np.zeros(K, bool)
    plan[10:10 + NSEL] = True
    noise = np.random.default_rng(0).standard_exponential(K)
    _, out = _apply_round(cfg, state, jnp.asarray(plan),
                          jnp.asarray(noise, jnp.float32), jnp.ones(K))
    times = 2.0 * pool.data_sizes[:, 0] * pool.a + noise * (
        2.0 * pool.data_sizes[:, 0] / pool.mu)
    expect = cm.cost(times, counts[0], plan)
    assert float(out.cost) == pytest.approx(expect, rel=1e-5, abs=1e-6)


# ---- trainer -------------------------------------------------------------

def test_train_rlds_runs_and_changes_params():
    stages = default_stages("default", num_devices=(24,), num_jobs=2)
    tcfg = TrainConfig(num_envs=4, rollout_len=6, iters=3, minibatches=2)
    params, logs = train_rlds(stages, tcfg, seed=0)
    assert len(logs) == 3
    assert all(np.isfinite(l["mean_cost"]) for l in logs)
    from repro.core.schedulers.rlds import init_policy

    fresh = jax.tree_util.tree_map(np.asarray,
                                   init_policy(jax.random.PRNGKey(1)))
    moved = jax.tree_util.tree_map(
        lambda a, b: not np.allclose(np.asarray(a), b), params, fresh)
    assert any(jax.tree_util.tree_leaves(moved))
    ev = evaluate(stages[0][0], stages[0][1], params, seed=1,
                  episodes=4, steps=8)
    assert np.isfinite(ev["mean_cost"])


# ---- policy zoo ----------------------------------------------------------

def _pool_cm(K=24, M=2, n_sel=3, seed=0):
    pool = DevicePool.heterogeneous(K, M, seed=seed)
    cm = CostModel(pool)
    cm.calibrate([5.0] * M, n_sel=n_sel)
    return pool, cm


def _assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("name", ["rlds", "dnn", "bods"])
def test_zoo_bit_exact_roundtrip(name, tmp_path):
    """state_dict -> zoo save -> load into a FRESH scheduler restores every
    array bit-for-bit (RLDS params/opt, DNN ring, BODS observation ring)."""
    pool, cm = _pool_cm()
    kwargs = {"pretrain_rounds": 2} if name == "rlds" else {}
    sched = get_scheduler(name, cost_model=cm, seed=3, **kwargs)
    # Push some real state through the scheduler before snapshotting.
    rng = np.random.default_rng(0)
    counts = np.zeros(24)
    for r in range(3):
        ctx = make_ctx(pool, n_sel=3, counts=counts, round_idx=r)
        plan = sched.schedule(ctx)
        sched.observe(ctx, plan, float(rng.random()))
        counts += plan

    zoo = PolicyZoo(str(tmp_path))
    zoo.save_scheduler("p", sched, meta={"note": "test"})
    fresh = get_scheduler(name, cost_model=cm, seed=99,
                          **({"pretrain_rounds": 0} if name == "rlds" else {}))
    meta = zoo.load_into("p", fresh)
    assert meta == {"note": "test"}
    _assert_trees_equal(sched.state_dict(), fresh.state_dict())


def test_zoo_kind_mismatch_and_unknown(tmp_path):
    pool, cm = _pool_cm()
    zoo = PolicyZoo(str(tmp_path))
    dnn = get_scheduler("dnn", cost_model=cm, seed=0)
    zoo.save_scheduler("d", dnn)
    rlds = get_scheduler("rlds", cost_model=cm, seed=0, pretrain_rounds=0)
    with pytest.raises(ValueError, match="kind"):
        zoo.load_into("d", rlds)
    with pytest.raises(FileNotFoundError, match="no policy"):
        zoo.load_into("nope", rlds)
    greedy = get_scheduler("greedy", cost_model=cm, seed=0)
    with pytest.raises(TypeError, match="state_dict"):
        zoo.load_into("d", greedy)
    assert zoo.names() == ["d"]
    assert zoo.info("d")["kind"] == "dnn"


# ---- lazy RLDS pre-training (satellite) ----------------------------------

def test_rlds_pretrain_is_lazy():
    pool, cm = _pool_cm()
    sched = get_scheduler("rlds", cost_model=cm, seed=0, pretrain_rounds=4)
    # Construction ran NO pre-training rounds: baselines still unset.
    assert not sched._pretrained
    assert np.isnan(sched.baselines).all()
    sched.schedule(make_ctx(pool, n_sel=3))
    assert sched._pretrained
    assert np.isfinite(sched.baselines).any()  # Algorithm 3 ran at first use


def test_rlds_warm_start_skips_pretraining():
    pool, cm = _pool_cm()
    donor = get_scheduler("rlds", cost_model=cm, seed=1, pretrain_rounds=0)
    sched = get_scheduler("rlds", cost_model=cm, seed=2, pretrain_rounds=300)
    sched.load_state_dict(donor.state_dict())
    assert sched._pretrained  # schedule() will never run the 300 rounds
    _assert_trees_equal(sched.params, donor.params)


# ---- ExperimentSpec policy axis ------------------------------------------

def test_spec_policy_axis_loads_gym_policy(tmp_path):
    """A gym-trained policy saved to the zoo loads into spec.build()'s live
    scheduler by name, bit-exactly, with constructor pre-training disabled."""
    stages = default_stages("default", num_devices=(30,), num_jobs=2)
    tcfg = TrainConfig(num_envs=4, rollout_len=4, iters=2, minibatches=2)
    params, _ = train_rlds(stages, tcfg, seed=0)
    zoo = PolicyZoo(str(tmp_path))
    save_rlds_params(zoo, "gym-pol", params, num_jobs=2,
                     meta={"curriculum": "default"})

    spec = ExperimentSpec(
        jobs=tuple(JobSpec(name=f"j{i}", target_metric=0.7, max_rounds=3)
                   for i in range(2)),
        pool=PoolSpec(num_devices=30, seed=3), scheduler="rlds",
        runtime="synthetic", runtime_kwargs={"seed": 2}, n_sel=4,
        policy="gym-pol", policy_dir=str(tmp_path))
    exp = spec.build()
    _assert_trees_equal(exp.engine.scheduler.params, params)
    assert exp.engine.scheduler._pretrained  # warm start replaced Algorithm 3
    result = exp.run()
    assert len(result.records) > 0


def test_spec_policy_axis_json_roundtrip(tmp_path):
    spec = ExperimentSpec(jobs=(JobSpec(name="j"),), scheduler="rlds",
                          policy="some-policy", policy_dir=str(tmp_path))
    restored = ExperimentSpec.from_json(spec.to_json())
    assert restored == spec
    assert restored.policy == "some-policy"


# ---- online-traffic (arrivals) curriculum --------------------------------

def test_arrivals_curriculum_draws_job_windows():
    from repro.gym.scenarios import sample_scenario

    spec = CURRICULA["arrivals"]
    key = jax.random.PRNGKey(0)
    draw = sample_scenario(key, spec, 24, 4)
    start, end = draw.job_start, draw.job_end
    assert start.shape == (4,) and end.shape == (4,)
    # job 0 anchors the episode: live from step 0, never departs
    assert float(start[0]) == 0.0 and not bool(jnp.isfinite(end[0]))
    lo, hi = spec.arrival_window
    assert bool(((start[1:] >= lo) & (start[1:] <= hi)).all())
    assert bool((end[1:] > start[1:]).all())
    # the closed-set default compiles the windows away
    d2 = sample_scenario(key, CURRICULA["default"], 24, 4)
    assert float(jnp.abs(d2.job_start).sum()) == 0.0
    assert not bool(jnp.isfinite(d2.job_end).any())


def test_inactive_job_round_is_noop():
    """A plan-masked (inactive-job) round must leave counts/time untouched
    and contribute zero cost — the empty-plan no-op the arrivals curriculum
    relies on."""
    from repro.gym.env import job_active, random_rollout

    cfg = small_cfg()
    state = reset(cfg, CURRICULA["default"], jax.random.PRNGKey(3))
    # Force every job inactive by pushing all arrivals past the horizon.
    far = jnp.full((cfg.num_jobs,), 1e9, jnp.float32)
    state = state._replace(scen=state.scen._replace(job_start=far))
    assert not bool(job_active(state))
    final, tr = jax.jit(random_rollout, static_argnums=(0, 2))(cfg, state, 6)
    np.testing.assert_array_equal(np.asarray(tr.cost), 0.0)
    np.testing.assert_array_equal(np.asarray(tr.round_time), 0.0)
    np.testing.assert_array_equal(np.asarray(final.counts),
                                  np.asarray(state.counts))


def test_arrivals_rollout_masks_inactive_jobs():
    from repro.core.schedulers.rlds import init_policy

    cfg = small_cfg(num_jobs=4)
    states = batch_reset(cfg, CURRICULA["arrivals"], jax.random.PRNGKey(4), 6)
    params = init_policy(jax.random.PRNGKey(5))
    finals, tr = batch_rollout(cfg, params, states, 40)
    plans = np.asarray(tr.plan)        # (E, T, K)
    jobs = np.asarray(tr.job)          # (E, T)
    start = np.asarray(states.scen.job_start)  # (E, M)
    end = np.asarray(states.scen.job_end)
    # Every round scheduled for a job outside its window must be empty.
    # The env's clock t equals the step index within the rollout here
    # (rollout starts at t=0).
    E, T = jobs.shape
    t = np.arange(T)[None, :]
    active = ((np.take_along_axis(start, jobs, axis=1) <= t)
              & (t < np.take_along_axis(end, jobs, axis=1)))
    assert (plans.sum(-1)[~active] == 0).all()
    # ...and the curriculum actually exercises inactivity AND activity.
    assert bool(active.any()) and bool((~active).any())
