"""Fused training-runtime tests: parity with the unfused path, compile
stability under varying cohort sizes, bucket logic, eval_every, and the
partition-size FedAvg weighting."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import JobConfig
from repro.configs.paper_models import lenet5
from repro.data.synthetic import make_classification_dataset
from repro.fl.aggregation import fedavg
from repro.fl.partition import noniid_partition
from repro.fl.runtime import (FLJobRuntime, FusedMultiRuntime, _fused_group_round,
                              _local_train_batch, bucket_for, default_buckets)

NUM_DEV = 20


def _tiny_cfg():
    """A small CNN so local training is fast; real conv + fc layers."""
    cfg = lenet5()
    return dataclasses.replace(
        cfg, name="tiny", input_shape=(8, 8, 1),
        cnn_spec=(("convp", 4, 3), ("flatten",), ("fc", 16)))


def _setup(num_jobs=1, samples=600, seed=0):
    cfg = _tiny_cfg()
    jobs, datasets = [], []
    for j in range(num_jobs):
        x, y = make_classification_dataset(samples, cfg.input_shape,
                                           cfg.num_classes, noise=1.0,
                                           seed=seed + j)
        ex, ey = make_classification_dataset(120, cfg.input_shape,
                                             cfg.num_classes, noise=1.0,
                                             seed=seed + 50 + j)
        part = noniid_partition(y, NUM_DEV, seed=seed + j)
        jobs.append(JobConfig(job_id=j, model=cfg, target_metric=2.0,
                              local_epochs=2, batch_size=4, lr=0.05))
        datasets.append((x, y, part, ex, ey))
    return jobs, datasets


def test_bucket_helpers():
    assert default_buckets(40) == (4, 8, 16, 32, 40)
    assert default_buckets(64) == (4, 8, 16, 32, 64)
    assert bucket_for(1, (4, 8, 16)) == 4
    assert bucket_for(8, (4, 8, 16)) == 8
    assert bucket_for(9, (4, 8, 16)) == 16
    with pytest.raises(ValueError):
        bucket_for(17, (4, 8, 16))


def test_fused_matches_unfused_per_round():
    """Varying cohort sizes: fused bucketed rounds must reproduce the
    unfused baseline accuracy to 1e-4 at equal seeds."""
    jobs, datasets = _setup()
    unfused = FLJobRuntime(jobs[0], *datasets[0], seed=0)
    fused = FusedMultiRuntime(jobs, datasets, seed=0)
    rng = np.random.default_rng(1)
    for r in range(8):
        n = int(rng.integers(2, 10))
        ids = rng.choice(NUM_DEV, n, replace=False)
        mu = unfused.run_round(0, ids, r)
        mf = fused.run_round(0, ids, r)
        assert abs(mu["accuracy"] - mf["accuracy"]) < 1e-4, (r, mu, mf)
        assert abs(mu["loss"] - mf["loss"]) < 1e-3, (r, mu, mf)


def test_fused_cross_job_batched_lane():
    """Two jobs sharing a model config stack onto one lane; begin_round +
    run_round must batch them and still match per-job unfused training."""
    jobs, datasets = _setup(num_jobs=2)
    fused = FusedMultiRuntime(jobs, datasets, seed=0)
    assert len(fused.groups) == 1 and len(fused.groups[0].job_ids) == 2
    unfused = [FLJobRuntime(j, *d, seed=j.job_id)
               for j, d in zip(jobs, datasets)]
    rng = np.random.default_rng(2)
    for r in range(4):
        cohorts = [rng.choice(NUM_DEV, int(rng.integers(3, 7)), replace=False)
                   for _ in jobs]
        # engine-style: both in-flight rounds announced before any demand
        for j, ids in enumerate(cohorts):
            fused.begin_round(j, ids, r)
        for j, ids in enumerate(cohorts):
            mf = fused.run_round(j, ids, r)
            mu = unfused[j].run_round(j, ids, r)
            assert abs(mu["accuracy"] - mf["accuracy"]) < 1e-4, (j, r)


def test_compile_stability_bounded_by_buckets():
    """20 rounds of jittery cohort sizes must compile at most len(buckets)
    variants of the fused step (probed via the jit cache)."""
    jobs, datasets = _setup(seed=7)
    fused = FusedMultiRuntime(jobs, datasets, seed=0, buckets=(4, 8, 16, 20))
    before = _fused_group_round._cache_size()
    rng = np.random.default_rng(3)
    for r in range(20):
        n = int(rng.integers(1, NUM_DEV + 1))  # every cohort size in play
        ids = rng.choice(NUM_DEV, n, replace=False)
        fused.run_round(0, ids, r)
    compiles = _fused_group_round._cache_size() - before
    assert compiles <= len(fused.buckets), (compiles, fused.buckets)
    # the unfused batch trainer would have compiled once per DISTINCT size;
    # sanity-check the bound is actually tighter than that here
    assert compiles < 20

    # eval_every > 1 puts both step variants (eval / no-eval) in play:
    # the bound doubles but stays bucket-shaped, not cohort-size-shaped.
    jobs2, datasets2 = _setup(seed=8)
    fused2 = FusedMultiRuntime(jobs2, datasets2, seed=0,
                               buckets=(4, 8, 16, 20), eval_every=3)
    before2 = _fused_group_round._cache_size()
    for r in range(20):
        n = int(rng.integers(1, NUM_DEV + 1))
        fused2.run_round(0, rng.choice(NUM_DEV, n, replace=False), r)
    compiles2 = _fused_group_round._cache_size() - before2
    assert compiles2 <= 2 * len(fused2.buckets), (compiles2, fused2.buckets)


def test_eval_every_skips_and_reports_stale_metrics():
    jobs, datasets = _setup(seed=11)
    fused = FusedMultiRuntime(jobs, datasets, seed=0, eval_every=3)
    rng = np.random.default_rng(4)
    metrics = [fused.run_round(0, rng.choice(NUM_DEV, 5, replace=False), r)
               for r in range(7)]
    # rounds 1, 2 reuse round 0's eval; rounds 4, 5 reuse round 3's
    assert metrics[0] == metrics[1] == metrics[2]
    assert metrics[3] == metrics[4] == metrics[5]
    assert metrics[3] != metrics[0]
    assert metrics[6] != metrics[3]


def test_unfused_runtime_weights_by_partition_size():
    """FedAvg must weight devices by their REAL partition sizes, not
    uniformly."""
    jobs, datasets = _setup(seed=13)
    x, y, part, ex, ey = datasets[0]
    sizes = np.full(NUM_DEV, part.shape[1], dtype=np.float64)
    sizes[:NUM_DEV // 2] = part.shape[1] // 3  # half the pool holds less data
    rt = FLJobRuntime(jobs[0], x, y, part, ex, ey, seed=0,
                      partition_sizes=sizes)
    ids = np.asarray([1, 4, 15, 18])  # two small, two full devices
    params0 = jax.tree_util.tree_map(jnp.copy, rt.params)
    rt.run_round(0, ids, 0)
    locals_ = _local_train_batch(
        params0, rt.cfg, rt.x[jnp.asarray(part[ids])],
        rt.y[jnp.asarray(part[ids])], jobs[0].local_epochs,
        jobs[0].batch_size, jobs[0].lr)
    expected = fedavg(locals_, jnp.asarray(sizes[ids], jnp.float32))
    uniform = fedavg(locals_, jnp.ones(len(ids), jnp.float32))
    got = jax.tree_util.tree_leaves(rt.params)
    exp = jax.tree_util.tree_leaves(expected)
    uni = jax.tree_util.tree_leaves(uniform)
    for g, e in zip(got, exp):
        np.testing.assert_allclose(np.asarray(g), np.asarray(e), atol=1e-6)
    assert any(not np.allclose(np.asarray(g), np.asarray(u), atol=1e-6)
               for g, u in zip(got, uni))


def test_engine_announces_realized_cohort_at_launch():
    """The engine must call begin_round at LAUNCH with the same survivor
    cohort it later passes to run_round at the finish event."""
    from repro.core.cost import CostModel
    from repro.core.devices import DevicePool
    from repro.core.multijob import MultiJobEngine
    from repro.core.schedulers.random_sched import RandomScheduler

    calls = {"begin": [], "run": []}

    class Recorder:
        def begin_round(self, job_id, device_ids, round_idx):
            calls["begin"].append((job_id, round_idx, tuple(device_ids)))

        def run_round(self, job_id, device_ids, round_idx):
            calls["run"].append((job_id, round_idx, tuple(device_ids)))
            return {"loss": 1.0, "accuracy": 0.0}

    pool = DevicePool.heterogeneous(12, 1, seed=0)
    jobs = [dataclasses.replace(
        JobConfig(job_id=0, model=_tiny_cfg(), target_metric=0.9),
        max_rounds=4)]
    cm = CostModel(pool)
    eng = MultiJobEngine(jobs, pool, cm, RandomScheduler(cost_model=cm, seed=0),
                        Recorder(), n_sel=3, over_provision=1.5,
                        failure_rate=0.2, rng=np.random.default_rng(0))
    eng.run()
    assert len(calls["begin"]) == len(calls["run"]) == 4
    assert calls["begin"] == calls["run"]  # same cohorts, announced earlier


def test_fused_runtime_rejects_bad_args():
    jobs, datasets = _setup()
    with pytest.raises(ValueError):
        FusedMultiRuntime(jobs, [], seed=0)
    with pytest.raises(ValueError):
        FusedMultiRuntime(jobs, datasets, eval_every=0)
    with pytest.raises(ValueError):
        FLJobRuntime(jobs[0], *datasets[0],
                     partition_sizes=np.ones(NUM_DEV + 1))
