"""Cost-model unit tests: Formulas 2-5 semantics + normalization invariants."""

import numpy as np
import pytest

from repro.core.cost import CostModel
from repro.core.devices import DevicePool


@pytest.fixture
def pool():
    return DevicePool.heterogeneous(num_devices=50, num_jobs=2, seed=0)


def test_shifted_exponential_moments(pool):
    """Formula 4: E[t] = tau*D*(a + 1/mu); min t >= tau*a*D."""
    tau = 5.0
    samples = pool.sample_times(0, tau, size=4000)          # (4000, K)
    d = pool.data_sizes[:, 0]
    shift = tau * pool.a * d
    expected = tau * d * (pool.a + 1.0 / pool.mu)
    assert np.all(samples >= shift[None, :] - 1e-9)
    emp = samples.mean(axis=0)
    np.testing.assert_allclose(emp, expected, rtol=0.15)
    np.testing.assert_allclose(pool.expected_times(0, tau), expected)


def test_round_time_is_max_of_selected(pool):
    cm = CostModel(pool)
    times = pool.expected_times(0, 5.0)
    plan = np.zeros(50, dtype=bool)
    plan[[3, 7, 11]] = True
    assert cm.round_time(times, plan) == times[[3, 7, 11]].max()
    assert cm.round_time(times, np.zeros(50, dtype=bool)) == 0.0


def test_fairness_is_population_variance(pool):
    cm = CostModel(pool)
    counts = np.arange(50, dtype=float)
    plan = np.zeros(50, dtype=bool)
    plan[:10] = True
    assert cm.fairness(counts, plan) == pytest.approx(np.var(counts + plan))


def test_delta_fairness_preserves_argmin(pool):
    """var(s+v) - var(s) shifts all candidates equally -> same argmin."""
    rng = np.random.default_rng(0)
    counts = rng.integers(0, 10, 50).astype(float)
    plans = np.zeros((20, 50), dtype=bool)
    for i in range(20):
        plans[i, rng.choice(50, 5, replace=False)] = True
    cm_abs = CostModel(pool, delta_fairness=False)
    cm_dlt = CostModel(pool, delta_fairness=True)
    t = pool.expected_times(0, 5.0)
    c_abs = cm_abs.cost_batch(t, counts, plans)
    c_dlt = cm_dlt.cost_batch(t, counts, plans)
    assert np.argmin(c_abs) == np.argmin(c_dlt)
    np.testing.assert_allclose(c_abs - c_dlt, (c_abs - c_dlt)[0])


def test_calibration_scales(pool):
    cm = CostModel(pool)
    cm.calibrate([5.0, 5.0], n_sel=5)
    assert cm.time_scale > 0
    assert 0 < cm.fairness_scale <= 0.25
