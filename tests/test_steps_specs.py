"""input_specs / batch_axes / opt_state_axes consistency across every
(arch × shape) cell — structure-level checks, no compilation."""

import jax
import jax.numpy as jnp
import pytest

from repro.config import SHAPES, get_arch, shape_applicable
from repro.config.base import OptimizerConfig
from repro.configs import ASSIGNED_ARCHS
from repro.launch.steps import batch_axes, input_specs, opt_state_axes
from repro.models.layers import abstract_init
from repro.models.transformer import lm_init
from repro.optim import make_optimizer

CELLS = [(a, s) for a in ASSIGNED_ARCHS for s in SHAPES
         if shape_applicable(get_arch(a), SHAPES[s])]


@pytest.mark.parametrize("arch,shape", CELLS)
def test_specs_and_axes_trees_match(arch, shape):
    cfg = get_arch(arch)
    sc = SHAPES[shape]
    specs = input_specs(cfg, sc)
    axes = batch_axes(cfg, sc)
    # every spec leaf must have a same-rank axes entry
    flat_specs = jax.tree_util.tree_leaves(specs)
    flat_axes = jax.tree_util.tree_structure(specs).flatten_up_to(axes)
    assert len(flat_specs) == len(flat_axes)
    for s, a in zip(flat_specs, flat_axes):
        assert len(a) == len(s.shape), (arch, shape, s.shape, a)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_abstract_init_axes_cover_params(arch):
    cfg = get_arch(arch)
    with abstract_init():
        params, axes = lm_init(cfg, 0)
    flat_p = jax.tree_util.tree_leaves(params)
    flat_a = jax.tree_util.tree_structure(params).flatten_up_to(axes)
    assert len(flat_p) == len(flat_a)
    for p, a in zip(flat_p, flat_a):
        assert len(a) == len(p.shape), (arch, p.shape, a)


@pytest.mark.parametrize("opt", ["adamw", "adafactor", "sgd", "momentum"])
def test_opt_state_axes_structure(opt):
    cfg = get_arch("qwen3-1.7b")
    with abstract_init():
        params, axes = lm_init(cfg, 0)
    oc = OptimizerConfig(name=opt)
    init, _ = make_optimizer(oc)
    opt_shapes = jax.eval_shape(init, params)
    o_axes = opt_state_axes(cfg, axes, oc)
    # inner axes tree must flatten against the inner state tree
    if opt in ("adamw",):
        inner_a = jax.tree_util.tree_structure(
            opt_shapes.inner).flatten_up_to(o_axes["inner"])
        inner_s = jax.tree_util.tree_leaves(opt_shapes.inner)
        assert len(inner_a) == len(inner_s)
