"""Elastic-runtime tests: checkpoint/restart recovery with fault injection."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.elastic import ElasticConfig, FailureInjector, run_elastic


class CountingBatcher:
    """Deterministic restartable stream of scalar 'batches'."""

    def __init__(self):
        self.cursor = 0

    def state(self):
        return {"cursor": self.cursor}

    def restore(self, st):
        self.cursor = st["cursor"]

    def __next__(self):
        self.cursor += 1
        return jnp.asarray(float(self.cursor))


def _step(state, batch):
    # state accumulates sum of seen batch values
    return state + batch, {"loss": batch}


def test_run_without_failures(tmp_path):
    out = run_elastic(
        make_state=lambda: jnp.asarray(0.0), step_fn=_step,
        batch_iter=CountingBatcher(), num_steps=30,
        config=ElasticConfig(save_every=10, checkpoint_dir=str(tmp_path)))
    assert out["restarts"] == 0
    assert float(out["state"]) == sum(range(1, 31))


def test_failure_recovery_exact_state(tmp_path):
    inj = FailureInjector(fail_at_steps=[17, 23])
    out = run_elastic(
        make_state=lambda: jnp.asarray(0.0), step_fn=_step,
        batch_iter=CountingBatcher(), num_steps=30,
        config=ElasticConfig(save_every=10, checkpoint_dir=str(tmp_path)),
        injector=inj)
    assert out["restarts"] == 2
    assert inj.injected == [17, 23]
    # replay from the checkpoint cursor makes the final state EXACT
    assert float(out["state"]) == sum(range(1, 31))
    assert out["steps_replayed"] > 0


def test_failure_before_first_checkpoint(tmp_path):
    inj = FailureInjector(fail_at_steps=[3])
    out = run_elastic(
        make_state=lambda: jnp.asarray(0.0), step_fn=_step,
        batch_iter=CountingBatcher(), num_steps=12,
        config=ElasticConfig(save_every=10, checkpoint_dir=str(tmp_path)),
        injector=inj)
    assert out["restarts"] == 1
    assert float(out["state"]) == sum(range(1, 13))


def test_exceeding_max_restarts_raises(tmp_path):
    inj = FailureInjector(fail_at_steps=[2, 3, 4, 5, 6])
    try:
        run_elastic(
            make_state=lambda: jnp.asarray(0.0), step_fn=_step,
            batch_iter=CountingBatcher(), num_steps=10,
            config=ElasticConfig(save_every=100, checkpoint_dir=str(tmp_path),
                                 max_restarts=3),
            injector=inj)
        raised = False
    except RuntimeError:
        raised = True
    assert raised
