"""Flash-attention kernel vs pure-jnp oracle: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention

CASES = [
    # B, S, H, KV, D, causal, window, block
    (2, 128, 4, 2, 64, True, None, 64),
    (1, 256, 8, 8, 32, True, None, 128),
    (2, 128, 4, 1, 64, True, 64, 64),
    (1, 64, 2, 2, 128, False, None, 32),
    (1, 192, 6, 3, 64, True, None, 64),   # uneven block fallback (192 % 64 == 0)
    (3, 64, 4, 4, 16, True, 16, 16),
]


@pytest.mark.parametrize("B,S,H,KV,D,causal,window,blk", CASES)
def test_flash_matches_oracle(B, S, H, KV, D, causal, window, blk):
    rng = np.random.default_rng(hash((B, S, H, KV, D)) % 2**31)
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, KV, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=blk, block_k=blk, interpret=True)
    exp = ref.attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
def test_flash_dtypes(dtype, atol):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (2, 128, 4, 64)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (2, 128, 2, 64)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (2, 128, 2, 64)), dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    exp = ref.attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=atol, rtol=atol)


@pytest.mark.parametrize("B,S,H,KV,D,causal,window", [
    (2, 128, 4, 2, 32, True, None),
    (1, 256, 8, 1, 16, True, 64),
    (2, 128, 5, 5, 16, True, 32),    # hymba-style non-power-of-two heads
    (1, 64, 4, 4, 32, False, None),
])
def test_chunked_ref_matches_dense_ref(B, S, H, KV, D, causal, window):
    """The q-chunked data-plane attention is EXACT vs the dense oracle."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, KV, D)), jnp.float32)
    out = ref.attention(q, k, v, causal=causal, window=window, q_chunk=64)
    exp = ref.attention_dense(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-6, rtol=2e-6)


def test_flash_gradient_matches_reference():
    """custom_vjp bwd falls back to the oracle; grads must match it."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(0, 1, (1, 64, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 64, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 64, 2, 32)), jnp.float32)

    def f_kernel(q, k, v):
        return (flash_attention(q, k, v, block_q=32, block_k=32,
                                interpret=True) ** 2).sum()

    def f_ref(q, k, v):
        return (ref.attention(q, k, v) ** 2).sum()

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4, rtol=5e-4)
