"""Scoring-core parity: numpy == jitted jax == Pallas kernel (interpret).

The batched plan-scoring core (repro/core/scoring.py) is the one inner loop
under every scheduler, so its three backends must agree bit-tightly across
shapes, ragged availability masks, empty plans, and both fairness modes.

Property tests run under hypothesis when available; without it they degrade
to a fixed-seed sweep so the parity contract is enforced either way.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

from repro.core import scoring
from repro.core.cost import CostModel
from repro.core.devices import DevicePool
from repro.core.plans import gumbel_topk_plans, random_plans, validate_plan

TOL = dict(rtol=1e-5, atol=1e-5)


def make_problem(rng, K, P, ragged=True, allow_empty=True, count_hi=50):
    times = rng.uniform(0.1, 100.0, K)
    counts = rng.integers(0, count_hi, K).astype(np.float64)
    density = rng.uniform(0.05, 0.6)
    plans = rng.random((P, K)) < density
    if ragged:  # knock out a random device subset across all plans
        mask = rng.random(K) < 0.8
        plans &= mask[None, :]
    if allow_empty and P > 1:
        plans[rng.integers(0, P)] = False
    return times, counts, plans


# ---- parity properties (hypothesis or fixed-seed sweep) --------------------

def check_numpy_jax_parity(seed, k, p, delta):
    rng = np.random.default_rng(seed)
    times, counts, plans = make_problem(rng, k, p)
    kw = dict(alpha=4.0, beta=0.25, time_scale=3.0, fairness_scale=0.09,
              delta_fairness=delta)
    a = scoring.score_plans(times, counts, plans, backend="numpy", **kw)
    b = scoring.score_plans(times, counts, plans, backend="jax", **kw)
    np.testing.assert_allclose(a, b, **TOL)


def check_pallas_kernel_parity(seed, k, p, delta):
    rng = np.random.default_rng(seed)
    times, counts, plans = make_problem(rng, k, p)
    kw = dict(alpha=4.0, beta=0.25, time_scale=3.0, fairness_scale=0.09,
              delta_fairness=delta)
    a = scoring.score_plans(times, counts, plans, backend="numpy", **kw)
    c = scoring.score_plans_pallas_interpret(times, counts, plans, **kw)
    np.testing.assert_allclose(a, c, **TOL)


def check_random_plans_valid(seed, n_sel, count):
    rng = np.random.default_rng(seed)
    available = rng.random(60) < 0.5
    if available.sum() < n_sel:
        available[:n_sel] = True
    plans = random_plans(rng, available, n_sel, count)
    assert plans.shape == (count, 60)
    for p in plans:
        validate_plan(p, available, n_sel)


def check_gumbel_topk_valid(seed, n_sel, count):
    rng = np.random.default_rng(seed)
    K = 40
    available = rng.random(K) < 0.6
    if available.sum() < n_sel:
        available[:n_sel] = True
    logits = rng.normal(size=(count, K))
    plans = gumbel_topk_plans(rng, logits, available, n_sel)
    for p in plans:
        validate_plan(p, available, n_sel)


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31), k=st.integers(1, 90),
           p=st.integers(1, 12), delta=st.booleans())
    def test_numpy_jax_parity(seed, k, p, delta):
        check_numpy_jax_parity(seed, k, p, delta)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31), k=st.integers(1, 70),
           p=st.integers(1, 8), delta=st.booleans())
    def test_pallas_kernel_parity(seed, k, p, delta):
        check_pallas_kernel_parity(seed, k, p, delta)

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**31), n_sel=st.integers(1, 10),
           count=st.integers(1, 16))
    def test_vectorized_random_plans_valid(seed, n_sel, count):
        check_random_plans_valid(seed, n_sel, count)

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**31), n_sel=st.integers(1, 8),
           count=st.integers(1, 12))
    def test_gumbel_topk_plans_valid(seed, n_sel, count):
        check_gumbel_topk_valid(seed, n_sel, count)

else:  # fixed-seed fallback sweep

    @pytest.mark.parametrize("seed", range(12))
    def test_numpy_jax_parity(seed):
        rng = np.random.default_rng(1000 + seed)
        check_numpy_jax_parity(seed, int(rng.integers(1, 90)),
                               int(rng.integers(1, 12)), bool(seed % 2))

    @pytest.mark.parametrize("seed", range(6))
    def test_pallas_kernel_parity(seed):
        rng = np.random.default_rng(2000 + seed)
        check_pallas_kernel_parity(seed, int(rng.integers(1, 70)),
                                   int(rng.integers(1, 8)), bool(seed % 2))

    @pytest.mark.parametrize("seed", range(10))
    def test_vectorized_random_plans_valid(seed):
        rng = np.random.default_rng(3000 + seed)
        check_random_plans_valid(seed, int(rng.integers(1, 10)),
                                 int(rng.integers(1, 16)))

    @pytest.mark.parametrize("seed", range(10))
    def test_gumbel_topk_plans_valid(seed):
        rng = np.random.default_rng(4000 + seed)
        check_gumbel_topk_valid(seed, int(rng.integers(1, 8)),
                                int(rng.integers(1, 12)))


# ---- deterministic edge cases ---------------------------------------------

def test_empty_plans_score_zero_time():
    times = np.linspace(1, 10, 20)
    counts = np.zeros(20)
    plans = np.zeros((3, 20), dtype=bool)
    for backend in ("numpy", "jax"):
        out = scoring.score_plans(times, counts, plans, alpha=1.0, beta=0.0,
                                  backend=backend)
        np.testing.assert_allclose(out, 0.0, atol=1e-7)
    out = scoring.score_plans_pallas_interpret(times, counts, plans,
                                               alpha=1.0, beta=0.0)
    np.testing.assert_allclose(out, 0.0, atol=1e-7)


def test_large_counts_no_cancellation():
    """Fleet regime: cumulative counts ~1e4 must not destroy f32 parity."""
    rng = np.random.default_rng(3)
    times, counts, plans = make_problem(rng, 256, 16, count_hi=10_000)
    kw = dict(alpha=4.0, beta=0.25, time_scale=3.0, fairness_scale=0.09,
              delta_fairness=True)
    a = scoring.score_plans(times, counts, plans, backend="numpy", **kw)
    b = scoring.score_plans(times, counts, plans, backend="jax", **kw)
    c = scoring.score_plans_pallas_interpret(times, counts, plans, **kw)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)


def test_cost_model_batch_backends_agree():
    """CostModel.cost_batch is the same number on every backend."""
    pool = DevicePool.heterogeneous(64, 2, seed=0)
    rng = np.random.default_rng(1)
    counts = rng.integers(0, 8, 64).astype(float)
    plans = random_plans(rng, np.ones(64, bool), 6, 12)
    t = pool.expected_times(0, 5.0)
    cm = CostModel(pool, alpha=4.0, beta=0.25)
    cm.calibrate([5.0, 5.0], n_sel=6)
    ref = cm.cost_batch(t, counts, plans, backend="numpy")
    for backend in ("jax", "auto", "pallas"):  # pallas falls back off-TPU
        np.testing.assert_allclose(
            cm.cost_batch(t, counts, plans, backend=backend), ref, **TOL)


def test_round_time_and_fairness_batch_parity():
    rng = np.random.default_rng(7)
    times, counts, plans = make_problem(rng, 48, 9)
    rt_np = scoring.round_time_batch(times, plans, backend="numpy")
    rt_jx = scoring.round_time_batch(times, plans, backend="jax")
    np.testing.assert_allclose(rt_np, rt_jx, **TOL)
    for delta in (True, False):
        f_np = scoring.fairness_batch(counts, plans, delta_fairness=delta,
                                      backend="numpy")
        f_jx = scoring.fairness_batch(counts, plans, delta_fairness=delta,
                                      backend="jax")
        np.testing.assert_allclose(f_np, f_jx, **TOL)


def test_auto_dispatch_and_default_backend():
    assert scoring.resolve_backend("auto", 100) == "numpy"
    assert scoring.resolve_backend("auto", 10**7) == "jax"
    scoring.set_default_backend("jax")
    try:
        assert scoring.resolve_backend(None, 100) == "jax"
    finally:
        scoring.set_default_backend("auto")
    with pytest.raises(ValueError):
        scoring.resolve_backend("cuda", 1)


def test_pallas_requires_tpu_else_falls_back(caplog):
    import logging

    scoring._warned_pallas_fallback = False
    with caplog.at_level(logging.WARNING, logger="repro.core.scoring"):
        b = scoring.resolve_backend("pallas", 10**6)
    if scoring._pallas_available():  # pragma: no cover - TPU CI only
        assert b == "pallas"
    else:
        assert b == "jax"
        assert any("falling back" in r.message for r in caplog.records)


def test_gumbel_topk_biases_toward_high_logits():
    rng = np.random.default_rng(0)
    K = 30
    logits = np.zeros(K)
    logits[:5] = 8.0  # strongly preferred
    hits = np.zeros(K)
    for _ in range(50):
        plans = gumbel_topk_plans(rng, np.tile(logits, (4, 1)),
                                  np.ones(K, bool), 5)
        hits += plans.sum(0)
    assert hits[:5].sum() > hits[5:].sum()
