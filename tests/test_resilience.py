"""SLO-driven serve resilience tests (``repro.serve.resilience`` + the
``slo`` spec axis): spec round-trip and inertness, the degradation
ladder's queue/latency rung selection and plan repair, circuit-breaker
lifecycle and persistence, the stalled-round watchdog, bounded
launch/aggregation retries, and kill -9 resume of the full resilience
state on an actively degrading service."""

import dataclasses
import json
import warnings

import numpy as np
import pytest

from repro.core.schedulers.base import SchedulingContext
from repro.experiment.presets import get_preset
from repro.experiment.slo import SLOSpec
from repro.experiment.spec import ExperimentSpec
from repro.serve.resilience import (RUNGS, BreakerBoard, CircuitBreaker,
                                    DecisionGovernor, RoundWatchdog)
from repro.serve.service import SchedulerService, SimulatedCrash


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

class FakeCost:
    """cost_indices stand-in: a plan's cost is its summed expected time."""

    def cost_indices(self, times, counts, idx):
        return np.asarray(times)[np.asarray(idx)].sum(axis=1)


class FakeScheduler:
    """Full-search stand-in: picks the SLOWEST n_sel available devices (so
    greedy/repair rungs are distinguishable from it)."""

    last_estimated_cost = 7.5

    def schedule(self, ctx):
        avail = ctx.available_indices()
        order = np.argsort(ctx.expected_times[avail], kind="stable")
        plan = np.zeros(ctx.available.shape[0], dtype=bool)
        plan[avail[order[-ctx.n_sel:]]] = True
        return plan


class FakeClock:
    """perf_counter stand-in advancing a fixed amount per call."""

    def __init__(self, step_s: float):
        self.t = 0.0
        self.step_s = step_s

    def __call__(self):
        self.t += self.step_s
        return self.t


def make_ctx(job=0, n_sel=3, k=10, available=None, round_idx=0):
    avail = np.ones(k, dtype=bool) if available is None else available
    return SchedulingContext(
        job=job, round_idx=round_idx, tau=1.0, n_sel=n_sel,
        available=avail, counts=np.zeros(k),
        expected_times=np.arange(k, dtype=float) + 1.0)


def governor(clock=None, **slo_kwargs):
    slo = SLOSpec(**slo_kwargs)
    kw = {} if clock is None else {"clock": clock}
    return DecisionGovernor(slo, FakeCost(), **kw)


def small_quickstart(max_rounds=8):
    spec = get_preset("quickstart", n_jobs=2, num_devices=30,
                      max_rounds=max_rounds)
    return spec.replace(jobs=tuple(
        dataclasses.replace(j, target_metric=2.0) for j in spec.jobs))


def record_tuples(records):
    return [(r.job, r.round_idx, r.t_start, r.t_end, r.round_time, r.cost,
             r.fairness, r.loss, r.accuracy, tuple(r.device_ids),
             tuple(r.dropped), tuple(r.corrupt_ids), tuple(r.failed_ids),
             r.degraded, r.rung, r.decision_ms) for r in records]


# ---------------------------------------------------------------------------
# SLOSpec: validation, inertness, JSON round-trip
# ---------------------------------------------------------------------------

def test_slospec_default_is_inert():
    assert SLOSpec().inert
    assert not SLOSpec(max_queue_depth=4).inert
    assert not SLOSpec(decision_deadline_ms=5.0).inert
    assert not SLOSpec(watchdog_rounds=3).inert
    assert not SLOSpec(breaker_threshold=2).inert
    assert not SLOSpec(max_launch_retries=1).inert
    assert not SLOSpec(max_agg_retries=1).inert


@pytest.mark.parametrize("bad", [
    dict(shed_policy="nope"), dict(decision_deadline_ms=0.0),
    dict(deadline_safety=0.0), dict(deadline_safety=1.5),
    dict(latency_window=0), dict(rung_probe_every=0),
    dict(retry_backoff=0.5), dict(breaker_failure_frac=0.0),
    dict(watchdog_rounds=-1), dict(max_agg_retries=-1),
])
def test_slospec_validation(bad):
    with pytest.raises(ValueError):
        SLOSpec(**bad)


def test_slo_axis_json_round_trip():
    spec = small_quickstart().replace(slo={
        "decision_deadline_ms": 12.0, "max_queue_depth": 5,
        "breaker_threshold": 2, "max_launch_retries": 3})
    again = ExperimentSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert again == spec
    assert isinstance(again.slo, SLOSpec)
    assert again.effective_slo() == spec.slo
    # an inert axis is treated as absent
    assert small_quickstart().replace(slo={}).effective_slo() is None


def test_inert_slo_axis_is_bit_identical():
    base = small_quickstart(max_rounds=5)
    recs_off = base.build().run().records
    recs_inert = base.replace(slo={}).build().run().records
    assert record_tuples(recs_off) == record_tuples(recs_inert)


# ---------------------------------------------------------------------------
# governor: rung selection, repair, decide
# ---------------------------------------------------------------------------

def test_queue_rung_ladder():
    gov = governor(max_queue_depth=4)
    for depth, rung in [(0, 0), (2, 0), (3, 1), (4, 1), (5, 2), (50, 2)]:
        gov.queue_depth = depth
        assert gov._queue_rung() == rung, depth


def test_latency_rung_picks_first_fitting_and_probes():
    gov = governor(decision_deadline_ms=10.0, deadline_safety=1.0,
                   rung_probe_every=3)
    gov._lat["full"].append(20.0)   # full doesn't fit the 10ms budget
    assert gov._latency_rung() == 1
    assert gov._latency_rung() == 1
    assert gov._latency_rung() == 0  # every 3rd forced degrade probes up
    assert gov._latency_rung() == 1


def test_repair_drops_trims_and_fills():
    gov = governor(max_queue_depth=4)
    ctx = make_ctx(n_sel=3)
    ctx.available[1] = False
    # unavailable member 1 dropped; survivors kept; the fastest available
    # non-member (0) fills the one-device shortfall
    np.testing.assert_array_equal(
        gov._repair(np.array([1, 5, 7]), ctx), [0, 5, 7])
    # oversized cached plan trimmed to the fastest n_sel
    np.testing.assert_array_equal(
        gov._repair(np.array([2, 4, 6, 8, 9]), ctx), [2, 4, 6])


def test_decide_full_rung_matches_scheduler():
    gov = governor(max_queue_depth=4)
    plan, rung, ms, est = gov.decide(FakeScheduler(), make_ctx(), now=0.0)
    assert rung == "full" and ms is None and est == 7.5
    np.testing.assert_array_equal(np.flatnonzero(plan), [7, 8, 9])
    np.testing.assert_array_equal(gov._last_good[0], [7, 8, 9])


def test_decide_degraded_rungs_and_cache_fallthrough():
    gov = governor(max_queue_depth=4)
    ctx = make_ctx()
    # queue over depth => rung 2; no cache needed for greedy
    gov.queue_depth = 5
    plan, rung, _, est = gov.decide(FakeScheduler(), ctx, now=0.0)
    assert rung == "greedy"
    np.testing.assert_array_equal(np.flatnonzero(plan), [0, 1, 2])
    assert est == pytest.approx(1.0 + 2.0 + 3.0)
    # upper-half depth => rung 1, repair-vs-greedy scored through cost_indices
    gov.queue_depth = 3
    plan, rung, _, est = gov.decide(FakeScheduler(), ctx, now=1.0)
    assert rung == "incremental"
    np.testing.assert_array_equal(np.flatnonzero(plan), [0, 1, 2])
    assert gov.rung_counts["greedy"] == 1
    assert gov.rung_counts["incremental"] == 1


def test_decide_measures_latency_with_injected_clock():
    clock = FakeClock(step_s=0.05)   # every decide measures 50ms
    gov = governor(clock=clock, decision_deadline_ms=10.0,
                   deadline_safety=1.0, rung_probe_every=1000)
    sched = FakeScheduler()
    _, rung, ms, _ = gov.decide(sched, make_ctx(), now=0.0)
    assert rung == "full" and ms == pytest.approx(50.0)
    assert gov.deadline_misses == 1
    # full's window now says 50ms > 10ms budget: degrade; each degraded
    # rung's own measurement then fails too, walking down the ladder.
    for expect in ("incremental", "greedy", "last_good", "last_good"):
        _, rung, _, _ = gov.decide(sched, make_ctx(), now=0.0)
        assert rung == expect
    assert set(RUNGS) == set(gov.rung_counts)


def test_governor_state_round_trip():
    gov = governor(max_queue_depth=4, breaker_threshold=2)
    gov.queue_depth = 5
    gov.decide(FakeScheduler(), make_ctx(), now=0.0)
    gov.breakers.tenant("t-1").record(False, 0.0)
    state = json.loads(json.dumps(gov.state_dict()))  # must be pure JSON
    gov2 = governor(max_queue_depth=4, breaker_threshold=2)
    gov2.load_state_dict(state)
    assert gov2.state_dict() == gov.state_dict()
    np.testing.assert_array_equal(gov2._last_good[0], gov._last_good[0])


# ---------------------------------------------------------------------------
# circuit breakers
# ---------------------------------------------------------------------------

def test_breaker_lifecycle():
    br = CircuitBreaker(threshold=2, cooldown=10.0)
    assert br.record(False, 0.0) is None
    assert br.record(True, 1.0) is None      # success resets the streak
    assert br.record(False, 2.0) is None
    assert br.record(False, 3.0) == "open"   # 2 consecutive failures
    assert br.trips == 1
    assert not br.allow(4.0)                 # cooling down
    assert br.allow(13.5)                    # cooldown elapsed: half-open
    assert br.state == "half_open"
    assert not br.allow(13.6)                # only ONE probe outstanding
    assert br.record(True, 14.0) == "closed"
    # reopen path: a failed probe trips again
    br.record(False, 20.0)
    br.record(False, 21.0)
    assert br.state == "open"
    assert br.allow(31.5) and br.state == "half_open"
    assert br.record(False, 32.0) == "open"
    assert br.trips == 3


def test_breaker_probe_rearms_after_silent_cooldown():
    br = CircuitBreaker(threshold=1, cooldown=5.0)
    br.record(False, 0.0)
    assert br.allow(6.0)          # probe armed at t=6
    assert not br.allow(7.0)      # probe outcome still outstanding
    assert br.allow(11.5)         # no outcome ever arrived: re-arm


def test_breaker_board_state_round_trip():
    board = BreakerBoard(threshold=1, cooldown=5.0)
    board.tenant("t-a").record(False, 1.0)
    board.domain(3).record(False, 2.0)
    assert board.open_counts() == dict(tenants_open=1, domains_open=1,
                                       trips=2)
    board2 = BreakerBoard(threshold=1, cooldown=5.0)
    board2.load_state_dict(json.loads(json.dumps(board.state_dict())))
    assert board2.state_dict() == board.state_dict()
    assert not board2.domain(3).allow(3.0)


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------

class _FakeJob:
    launched, done, parked = True, False, False


class _FakeEngine:
    def __init__(self):
        self.jobs = [_FakeJob(), _FakeJob()]
        self._heap = []
        self._in_flight = {1: {}}


def test_watchdog_counts_consecutive_stalls():
    eng = _FakeEngine()
    dog = RoundWatchdog(threshold=2)
    assert dog.check(eng) == []      # job 0 wedged once: below threshold
    assert dog.check(eng) == [0]     # twice consecutively: reported
    eng._heap.append((1.0, 0, "retry", 0))
    assert dog.check(eng) == []      # a pending event clears the stall
    assert dog.check(eng) == []      # ...and the counter restarted from 0
    dog2 = RoundWatchdog(threshold=2)
    eng._heap.clear()
    dog2.check(eng)
    dog2.load_state_dict(json.loads(json.dumps(dog2.state_dict())))
    assert dog2.check(eng) == [0]


# ---------------------------------------------------------------------------
# bounded retries on the engine
# ---------------------------------------------------------------------------

def test_bounded_launch_retries_clamp_instead_of_waiting():
    # 2 jobs want 6 of 10 devices each: the second always finds a shortage.
    spec = get_preset("quickstart", n_jobs=2, num_devices=10, max_rounds=4,
                      target=2.0).replace(n_sel=6)
    legacy = spec.build().run().records
    assert all(len(r.device_ids) + len(r.dropped) == 6 for r in legacy)
    recs = spec.replace(
        slo={"max_launch_retries": 1,
             "retry_base_delay": 5.0}).build().run().records
    assert len(recs) == len(legacy)
    clamped = [r for r in recs if len(r.device_ids) + len(r.dropped) < 6]
    assert clamped, "retry budget never clamped a shortage round"


def test_bounded_agg_retries_record_degraded_round():
    spec = small_quickstart(max_rounds=3).replace(slo={"max_agg_retries": 1})
    ex = spec.build()
    runtime = ex.engine.runtime
    orig = runtime.run_round
    calls = {"n": 0}

    def flaky(job_id, device_ids, round_idx):
        calls["n"] += 1
        if job_id == 1 and round_idx == 1:
            raise RuntimeError("injected aggregation failure")
        return orig(job_id, device_ids, round_idx)

    runtime.run_round = flaky
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        records = ex.run().records
    bad = [r for r in records if r.job == 1 and r.round_idx == 1]
    assert len(bad) == 1 and bad[0].degraded
    prev = next(r for r in records if r.job == 1 and r.round_idx == 0)
    assert bad[0].loss == prev.loss and bad[0].accuracy == prev.accuracy
    # the failing round was retried max_agg_retries+1 times before degrading
    assert calls["n"] == len(records) + 1


def test_agg_failure_without_retry_budget_still_raises():
    spec = small_quickstart(max_rounds=2)
    ex = spec.build()

    def broken(job_id, device_ids, round_idx):
        raise RuntimeError("boom")

    ex.engine.runtime.run_round = broken
    with pytest.raises(RuntimeError, match="boom"):
        ex.run()


# ---------------------------------------------------------------------------
# the full stack: overloaded service, kill -9, bit-identical resume
# ---------------------------------------------------------------------------

def _overload_spec():
    return get_preset("slo-overload", horizon=5_000.0, num_devices=30)


def _deterministic_summary(svc):
    s = dict(svc.resilience_summary())
    s.pop("rung_latency_ms", None)   # wall clock: not replayable
    return s


def test_degrading_service_survives_kill9_bit_identically(tmp_path):
    spec = _overload_spec()
    ref = SchedulerService(spec)
    ref.run()
    ref_records = record_tuples(ref.engine.records)
    ref_summary = _deterministic_summary(ref)
    # the run must actually exercise the resilience stack
    assert ref_summary["degraded_rounds"] > 0
    assert ref_summary["shed_arrivals"] > 0
    assert all(r[-2] in RUNGS for r in ref_records)

    ck = str(tmp_path / "ck")
    svc = SchedulerService(spec, checkpoint_dir=ck, checkpoint_every=2,
                           crash_after=5)
    with pytest.raises(SimulatedCrash):
        svc.run()
    resumed = SchedulerService.resume(ck)
    resumed.run()
    assert record_tuples(resumed.engine.records) == ref_records
    assert _deterministic_summary(resumed) == ref_summary


def test_service_report_carries_resilience_block():
    report = SchedulerService(_overload_spec()).run()
    res = report.resilience
    assert res is not None
    assert sum(res["rung_counts"].values()) == res["decisions"]
    assert res["degraded_decisions"] > 0
    d = report.to_dict() if hasattr(report, "to_dict") else \
        dataclasses.asdict(report)
    assert d["resilience"]["rung_counts"] == res["rung_counts"]
