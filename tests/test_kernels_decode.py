"""Decode-attention kernel vs oracle: shape/dtype/length sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention

CASES = [
    (2, 4, 2, 64, 128, 64),
    (3, 8, 1, 32, 256, 64),   # MQA (paligemma-style kv=1)
    (2, 8, 8, 128, 64, 32),   # MHA
    (1, 16, 4, 64, 512, 128),
]


@pytest.mark.parametrize("B,H,KV,D,T,blk", CASES)
def test_decode_matches_oracle(B, H, KV, D, T, blk):
    rng = np.random.default_rng(hash((B, H, KV, D, T)) % 2**31)
    q = jnp.asarray(rng.normal(0, 1, (B, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, T, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, T, KV, D)), jnp.float32)
    length = jnp.asarray(rng.integers(1, T + 1, (B,)), jnp.int32)
    out = decode_attention(q, k, v, length, block_k=blk, interpret=True)
    exp = ref.decode_attention(q, k, v, length)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)


def test_decode_bf16():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(0, 1, (2, 4, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(0, 1, (2, 128, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(0, 1, (2, 128, 2, 64)), jnp.bfloat16)
    length = jnp.asarray([64, 128], jnp.int32)
    out = decode_attention(q, k, v, length, block_k=64, interpret=True)
    exp = ref.decode_attention(q, k, v, length)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=3e-2, rtol=3e-2)


def test_decode_length_masking_exact():
    """Tokens past `length` must have exactly zero influence."""
    rng = np.random.default_rng(3)
    B, H, KV, D, T = 1, 2, 1, 16, 64
    q = jnp.asarray(rng.normal(0, 1, (B, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, T, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, T, KV, D)), jnp.float32)
    length = jnp.asarray([17], jnp.int32)
    out1 = decode_attention(q, k, v, length, block_k=16, interpret=True)
    # poison the invalid region
    k2 = k.at[:, 17:].set(1e4)
    v2 = v.at[:, 17:].set(-1e4)
    out2 = decode_attention(q, k2, v2, length, block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)
