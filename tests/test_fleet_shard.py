"""Fleet-axis sharding: scoring/search parity, SoA mirrors, spec plumbing.

The sharded paths (repro/core/shard.py + the ``num_shards`` plumbing
through scoring, the fused searchers, CostModel and FleetSpec) must be
invisible in the results: same scores as the single lane (within f32
resolution), same chosen plans from the searchers, valid plans out of the
sharded candidate ops — at any shard count, with or without real host
devices. In-process tests run the ``emulate`` executor (this process has
however many devices it has); one subprocess test forces an 8-device host
platform and pins the real ``shard_map`` executor against the single lane.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import scoring, search, shard
from repro.core.cost import CostModel
from repro.core.devices import DevicePool
from repro.core.plans import indices_to_plans, random_plan_indices
from repro.core.schedulers import get_scheduler
from repro.core.schedulers.base import SchedulingContext

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

KW = dict(alpha=4.0, beta=0.25, time_scale=3.0, fairness_scale=0.09,
          delta_fairness=True)


def _problem(K=103, P=9, seed=0):
    """Non-power-of-two K so every shard count exercises the padding."""
    rng = np.random.default_rng(seed)
    times = rng.uniform(1.0, 100.0, K)
    counts = rng.integers(0, 50, K).astype(np.float64)
    avail = rng.random(K) < 0.8
    n_sel = max(2, int(avail.sum()) // 4)
    idx = random_plan_indices(rng, avail, n_sel, P)
    return times, counts, avail, n_sel, idx


def _rel(a, b):
    return float(np.max(np.abs(a - b) / np.maximum(np.abs(b), 1e-12)))


# ---- sharded scoring parity (emulated executor, any machine) -------------


class TestShardedScoringParity:
    @pytest.mark.parametrize("N", [1, 2, 8])
    def test_index_form_matches_numpy(self, N):
        times, counts, avail, n_sel, idx = _problem()
        ref = scoring.score_plan_indices(times, counts, idx,
                                         backend="numpy", **KW)
        got = scoring.score_plan_indices(times, counts, idx, backend="jax",
                                         num_shards=N, **KW)
        assert _rel(got, ref) < 1e-5

    @pytest.mark.parametrize("N", [1, 2, 8])
    def test_dense_form_matches_numpy(self, N):
        times, counts, avail, n_sel, idx = _problem()
        plans = indices_to_plans(idx, times.shape[0])
        ref = scoring.score_plans(times, counts, plans,
                                  backend="numpy", **KW)
        got = scoring.score_plans(times, counts, plans, backend="jax",
                                  num_shards=N, **KW)
        assert _rel(got, ref) < 1e-5

    def test_forms_agree_sharded(self):
        times, counts, _, _, idx = _problem(K=257, P=5)
        plans = indices_to_plans(idx, 257)
        d = scoring.score_plans(times, counts, plans, backend="jax",
                                num_shards=4, **KW)
        i = scoring.score_plan_indices(times, counts, idx, backend="jax",
                                       num_shards=4, **KW)
        np.testing.assert_allclose(d, i, rtol=1e-5, atol=1e-7)

    def test_stats_executors_agree(self):
        """emulate and shard_map run the same shard-local math; with one
        device only N=1 can use shard_map, where both must be exact."""
        times, counts, _, _, idx = _problem(K=64, P=4)
        cc = counts - counts.mean()
        a = shard.plan_stats_sharded(times, cc, idx, "index", 1,
                                     executor="shard_map")
        b = shard.plan_stats_sharded(times, cc, idx, "index", 1,
                                     executor="emulate")
        np.testing.assert_array_equal(a, b)


# ---- shard-aware auto dispatch (satellite: resolve_backend) --------------


class TestResolveBackendShardAware:
    def test_single_lane_pins(self):
        assert scoring.resolve_backend("auto", 100) == "numpy"
        assert scoring.resolve_backend(
            "auto", scoring.AUTO_NUMPY_MAX_DENSE + 1) == "jax"
        assert scoring.resolve_backend(
            "auto", scoring.AUTO_NUMPY_MAX_INDEX, form="index") == "numpy"

    def test_sharded_fleet_stays_on_jax(self):
        # Single-lane dispatch would call 1<<19 index elements "numpy"
        # (< AUTO_NUMPY_MAX_INDEX); a sharded fleet must not fall back.
        n = 1 << 19
        assert scoring.resolve_backend("auto", n, form="index") == "numpy"
        assert scoring.resolve_backend("auto", n, form="index",
                                       num_shards=8) == "jax"

    def test_tiny_sharded_problem_still_numpy(self):
        # Per-shard work below jit dispatch overhead -> numpy wins even
        # when shards were requested.
        n = 8 * scoring.MIN_SHARD_ELEMENTS
        assert scoring.resolve_backend("auto", n, form="index",
                                       num_shards=8) == "numpy"
        assert scoring.resolve_backend("auto", n + 8, form="index",
                                       num_shards=8) == "jax"

    def test_explicit_backend_wins(self):
        assert scoring.resolve_backend("numpy", 1 << 22,
                                       num_shards=8) == "numpy"


# ---- sharded plan ops: validity contracts --------------------------------


class TestShardedPlanOps:
    @pytest.mark.parametrize("N", [1, 2, 8])
    def test_random_indices_valid(self, N):
        _, _, avail, n_sel, _ = _problem()
        out = shard.random_plan_indices_sharded(
            np.random.default_rng(1), avail, n_sel, 7, N)
        assert out.shape == (7, n_sel)
        for row in out:
            assert len(set(row.tolist())) == n_sel
            assert avail[row].all()

    @pytest.mark.parametrize("N", [1, 2, 8])
    def test_repair_preserves_valid_selections(self, N):
        rng = np.random.default_rng(2)
        _, _, avail, n_sel, _ = _problem()
        K = avail.shape[0]
        plans = np.zeros((5, K), bool)
        for i in range(5):
            plans[i, rng.choice(K, n_sel + 3, replace=False)] = True
        out = shard.repair_plans_sharded(rng, plans, avail, n_sel, N)
        for i in range(5):
            chosen = set(out[i].tolist())
            assert len(chosen) == n_sel and avail[out[i]].all()
            valid = set(np.flatnonzero(plans[i] & avail).tolist())
            # valid selections outrank noise: they survive up to n_sel
            assert len(chosen & valid) >= min(len(valid), n_sel)

    @pytest.mark.parametrize("N", [1, 2, 8])
    def test_gumbel_topk_valid(self, N):
        rng = np.random.default_rng(3)
        _, _, avail, n_sel, _ = _problem()
        logits = rng.normal(size=(6, avail.shape[0])).astype(np.float32)
        out = shard.gumbel_topk_indices_sharded(rng, logits, avail, n_sel, N)
        for row in out:
            assert len(set(row.tolist())) == n_sel and avail[row].all()

    def test_resolve_num_shards(self):
        assert shard.resolve_num_shards(None) == 1
        assert shard.resolve_num_shards(3) == 3
        assert shard.resolve_num_shards(8, fleet_size=5) == 5
        assert shard.resolve_num_shards("auto") >= 1
        with pytest.raises(ValueError):
            shard.resolve_num_shards(-2)


# ---- fused searchers: shard fallback must not change decisions -----------


class TestSearchShardFallback:
    def test_usable_shards_fallback_rules(self):
        f = search._usable_search_shards
        assert f(1, 32) == 1
        assert f(4, 30) == 1          # rows not divisible
        assert f(4, 32, pairs=True) == 4 or f(4, 32, pairs=True) == 1
        assert f(4, 12, pairs=True) == 1  # 12/4 = 3 rows/shard, odd pairs

    def _scenario(self, K=96, seed=0):
        pool = DevicePool.heterogeneous(K, 2, seed=seed)
        rng = np.random.default_rng(seed + 7)
        counts = rng.integers(0, 8, K).astype(np.float64)
        avail = np.ones(K, bool)
        avail[rng.choice(K, K // 5, replace=False)] = False
        times = pool.expected_times(0, 5.0)

        def ctx():
            return SchedulingContext(
                job=0, round_idx=0, tau=5.0, n_sel=8,
                available=avail.copy(), counts=counts.copy(),
                expected_times=times)

        return pool, ctx

    @pytest.mark.parametrize("name", ["sa", "genetic", "bods"])
    def test_scheduler_decisions_unchanged_by_num_shards(self, name):
        """On a host without enough devices the searchers fall back to the
        single lane — same plans, same costs, no crash."""
        plans = {}
        for n_sh in (1, 4):
            pool, ctx = self._scenario()
            cm = CostModel(pool, alpha=4.0, beta=0.25, num_shards=n_sh)
            cm.calibrate([5.0, 5.0], n_sel=8)
            sched = get_scheduler(name, cost_model=cm, seed=0)
            plans[n_sh] = [sched.schedule(ctx()) for _ in range(3)]
        for a, b in zip(plans[1], plans[4]):
            np.testing.assert_array_equal(a, b)


# ---- DevicePool dtype knob + compact SoA mirrors -------------------------


class TestPoolDtypeAndMirrors:
    def test_time_dtype_knob(self):
        for dt in (np.float64, np.float32):
            pool = DevicePool.heterogeneous(32, 2, seed=0, time_dtype=dt)
            assert pool.busy_until.dtype == dt
            assert pool.expected_times_all([5.0, 5.0]).dtype == dt
            t = pool.sample_times(0, 5.0)
            assert t.dtype == dt
            mask = np.zeros(32, bool)
            mask[:3] = True
            pool.occupy(mask, 7.5)
            assert pool.busy_until.dtype == dt

    def test_bf16_mirror_tolerance(self):
        pool = DevicePool.heterogeneous(256, 2, seed=1)
        f32 = np.asarray(pool.expected_times(0, 5.0), np.float32)
        bf = pool.expected_times_bf16(0, 5.0)
        assert bf.dtype == np.float32  # accumulated back in f32
        rel = np.max(np.abs(bf - f32) / np.maximum(np.abs(f32), 1e-12))
        assert rel < 1e-2  # bf16 has ~3 decimal digits

    def test_bf16_mirror_rebuilt_after_churn(self):
        pool = DevicePool.heterogeneous(8, 1, seed=2)
        before = pool.expected_times_bf16(0, 5.0).copy()
        pool.set_capabilities(np.arange(8), a=np.full(8, 0.5))
        after = pool.expected_times_bf16(0, 5.0)
        assert not np.allclose(before, after)

    def test_int8_plan_mirror_scoring_parity(self):
        times, counts, avail, n_sel, idx = _problem(K=64, P=6)
        p_bool = indices_to_plans(idx, 64)
        p_i8 = indices_to_plans(idx, 64, dtype=np.int8)
        assert p_i8.dtype == np.int8
        a = scoring.score_plans(times, counts, p_bool, backend="jax", **KW)
        b = scoring.score_plans(times, counts, p_i8, backend="jax", **KW)
        np.testing.assert_array_equal(a, b)
        c = scoring.score_plans(times, counts, p_i8, backend="numpy", **KW)
        np.testing.assert_allclose(b, c, rtol=1e-5, atol=1e-7)


# ---- FleetSpec / CLI / CostModel plumbing --------------------------------


def _tiny_spec(**overrides):
    from repro.experiment.spec import ExperimentSpec, JobSpec, PoolSpec

    spec = ExperimentSpec(
        jobs=(JobSpec(name="j0", target_metric=0.75, max_rounds=10),),
        pool=PoolSpec(num_devices=30, seed=3), scheduler="random",
        runtime="synthetic", n_sel=4)
    return spec.replace(**overrides) if overrides else spec


class TestSpecPlumbing:
    def test_num_shards_json_round_trip(self):
        from repro.experiment.spec import ExperimentSpec

        spec = _tiny_spec(fleet={"num_shards": 2})
        back = ExperimentSpec.from_dict(json.loads(spec.to_json()))
        assert back.fleet.num_shards == 2
        assert back.effective_num_shards() == 2

    def test_auto_resolves_to_device_count(self):
        import jax

        spec = _tiny_spec(fleet={"num_shards": "auto"})
        assert spec.effective_num_shards() == min(
            jax.device_count(), spec.effective_num_devices())

    def test_cost_spec_plumbs_num_shards(self):
        from repro.experiment.spec import CostSpec

        pool = DevicePool.heterogeneous(16, 2, seed=0)
        cm = CostSpec(calibrate=False).build(pool, [5.0, 5.0], 4,
                                             num_shards=3)
        assert cm.num_shards == 3

    def test_cli_dotted_set_key(self):
        from repro.experiment.cli import _parse_kv

        out = _parse_kv(["fleet.num_shards=4", "fleet.n_sel=8",
                         "scheduler=sa"])
        assert out == {"fleet": {"num_shards": 4, "n_sel": 8},
                       "scheduler": "sa"}

    def test_cli_dotted_collision_rejected(self):
        from repro.experiment.cli import _parse_kv

        with pytest.raises(SystemExit):
            _parse_kv(["fleet=3", "fleet.num_shards=4"])


# ---- launch bootstrap (no re-exec in-process) ----------------------------


class TestBootstrap:
    def test_env_folds_existing_flags(self, monkeypatch):
        from repro.launch import bootstrap

        monkeypatch.setenv(
            "XLA_FLAGS",
            "--foo=1 --xla_force_host_platform_device_count=2")
        env = bootstrap.host_platform_env(8, tcmalloc=False)
        assert "--foo=1" in env["XLA_FLAGS"]
        assert "--xla_force_host_platform_device_count=8" in env["XLA_FLAGS"]
        assert "device_count=2" not in env["XLA_FLAGS"]

    def test_no_tcmalloc_env_honored(self, monkeypatch):
        from repro.launch import bootstrap

        monkeypatch.setenv("REPRO_NO_TCMALLOC", "1")
        assert bootstrap.find_tcmalloc() is None

    def test_single_shard_is_noop(self):
        from repro.launch import bootstrap

        assert bootstrap.ensure_host_devices(1) is True

    def test_late_call_with_jax_imported_raises(self, monkeypatch):
        from repro.launch import bootstrap

        import jax

        need = jax.device_count() + 1
        monkeypatch.delenv("XLA_FLAGS", raising=False)
        assert "jax" in sys.modules
        with pytest.raises(RuntimeError, match="before\\s+importing jax|"
                                               "before importing"):
            bootstrap.ensure_host_devices(need)


# ---- real shard_map vs single lane (8 forced host devices) ---------------

_SUBPROC = r"""
import sys
assert "jax" not in sys.modules
import numpy as np
import jax
assert jax.device_count() == 8, jax.device_count()

from repro.core import scoring, search
from repro.core.plans import indices_to_plans, random_plan_indices

KW = dict(alpha=4.0, beta=0.25, time_scale=3.0, fairness_scale=0.09,
          delta_fairness=True)
rng = np.random.default_rng(0)
K, P = 4096, 32
times = rng.uniform(1.0, 100.0, K)
counts = rng.integers(0, 50, K).astype(np.float64)
avail = rng.random(K) < 0.9
n_sel = 64
idx = random_plan_indices(rng, avail, n_sel, P)
plans = indices_to_plans(idx, K)

ref_d = scoring.score_plans(times, counts, plans, backend="jax", **KW)
ref_i = scoring.score_plan_indices(times, counts, idx, backend="jax", **KW)
for N in (2, 8):
    for got, ref in [
        (scoring.score_plans(times, counts, plans, backend="jax",
                             num_shards=N, **KW), ref_d),
        (scoring.score_plan_indices(times, counts, idx, backend="jax",
                                    num_shards=N, **KW), ref_i),
    ]:
        rel = float(np.max(np.abs(got - ref) / np.maximum(np.abs(ref),
                                                          1e-12)))
        assert rel < 1e-5, (N, rel)
print("SCORING_OK")

skw = dict(alpha=4.0, beta=0.25, time_scale=3.0, fairness_scale=0.09,
           delta_fairness=True)
base = {}
for N in (1, 2, 8):
    sa = search.sa_search(np.random.default_rng(1), times, counts, avail,
                          n_sel, steps=6, chains=8, t0=1.0, cooling=0.9,
                          num_shards=N, **skw)
    ga = search.ga_search(np.random.default_rng(2), times, counts, avail,
                          n_sel, population=16, generations=4,
                          mutation_rate=0.3, num_shards=N, **skw)
    if N == 1:
        base = {"sa": sa, "ga": ga}
    else:
        assert np.array_equal(sa, base["sa"]), f"sa diverged at N={N}"
        assert np.array_equal(ga, base["ga"]), f"ga diverged at N={N}"
print("SEARCH_OK")

from repro.core.cost import CostModel
from repro.core.devices import DevicePool
from repro.core.schedulers import get_scheduler
from repro.core.schedulers.base import SchedulingContext

def run_bods(num_shards):
    pool = DevicePool.heterogeneous(512, 2, seed=3)
    cm = CostModel(pool, alpha=4.0, beta=0.25, num_shards=num_shards)
    cm.calibrate([5.0, 5.0], n_sel=16)
    r2 = np.random.default_rng(11)
    counts2 = r2.integers(0, 8, 512).astype(np.float64)
    av = np.ones(512, bool)
    av[r2.choice(512, 100, replace=False)] = False
    et = pool.expected_times(0, 5.0)
    sched = get_scheduler("bods", cost_model=cm, seed=0,
                          num_candidates=64, init_points=4)
    out = []
    for r in range(6):
        ctx = SchedulingContext(job=0, round_idx=r, tau=5.0, n_sel=16,
                                available=av.copy(), counts=counts2.copy(),
                                expected_times=et)
        out.append(sched.schedule(ctx))
    return out

p1, p8 = run_bods(1), run_bods(8)
for a, b in zip(p1, p8):
    assert np.array_equal(a, b), "bods diverged"
print("BODS_OK")
"""


@pytest.mark.slow
def test_shard_map_parity_eight_devices():
    """Real shard_map on 8 forced host devices: scoring within relative
    f32 tolerance of the single lane; SA/GA/BODS decisions identical."""
    env = dict(os.environ)
    env.update({
        "PYTHONPATH": os.path.join(REPO, "src"),
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
    })
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True, env=env,
                         cwd=REPO, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    for marker in ("SCORING_OK", "SEARCH_OK", "BODS_OK"):
        assert marker in out.stdout, (marker, out.stdout, out.stderr[-2000:])
