"""Gradient compression tests: top-k semantics + error-feedback convergence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; suite must collect without it
from hypothesis import given, settings, strategies as st

from repro.optim.compression import ErrorFeedbackState, topk_compress, topk_decompress


def test_topk_keeps_largest_magnitudes():
    g = {"w": jnp.asarray([0.1, -5.0, 0.01, 3.0, -0.2])}
    (vals, idx), _ = topk_compress(g, ratio=0.4)
    kept = set(np.asarray(idx["w"]).tolist())
    assert kept == {1, 3}
    dec = topk_decompress(vals, idx, g)
    np.testing.assert_allclose(np.asarray(dec["w"]),
                               [0.0, -5.0, 0.0, 3.0, 0.0])


def test_error_feedback_accumulates_residual():
    g = {"w": jnp.asarray([1.0, 0.5, 0.25, 0.1])}
    ef = ErrorFeedbackState(jax.tree_util.tree_map(jnp.zeros_like, g))
    total = jnp.zeros(4)
    # repeatedly send the same gradient with 25% compression: over steps the
    # error feedback must deliver ALL coordinates (bias -> 0)
    for _ in range(12):
        (vals, idx), ef = topk_compress(g, ratio=0.25, ef=ef)
        total = total + topk_decompress(vals, idx, g)["w"]
    delivered = total / 12
    np.testing.assert_allclose(np.asarray(delivered), np.asarray(g["w"]),
                               atol=0.15)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31), ratio=st.floats(0.05, 1.0))
def test_compress_decompress_subset_identity(seed, ratio):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(0, 1, (40,)))}
    (vals, idx), _ = topk_compress(g, ratio=ratio)
    dec = topk_decompress(vals, idx, g)["w"]
    mask = np.asarray(dec) != 0
    # every delivered coordinate matches the original exactly
    np.testing.assert_allclose(np.asarray(dec)[mask], np.asarray(g["w"])[mask])
    # count = ceil(ratio * 40) (subject to at-least-one)
    assert mask.sum() == max(1, round(ratio * 40))
