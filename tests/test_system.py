"""End-to-end behaviour tests of the MJ-FL system (the paper's claims in
miniature): parallel multi-job execution with real federated training, and
the scheduler-quality ordering on the synthetic convergence model."""

import numpy as np
import pytest

from repro.config.base import JobConfig
from repro.configs.paper_models import cnn_b, lenet5
from repro.core.cost import CostModel
from repro.core.devices import DevicePool
from repro.core.multijob import MultiJobEngine
from repro.core.schedulers import get_scheduler
from repro.data.synthetic import make_classification_dataset
from repro.fl.partition import noniid_partition
from repro.fl.runtime import FLJobRuntime, MultiRuntime, SyntheticRuntime
from repro.config.base import ArchFamily, ModelConfig


def _synthetic_engine(sched_name, seed=1, target=0.8, max_rounds=120):
    jobs = [JobConfig(job_id=i,
                      model=ModelConfig(name=f"j{i}", family=ArchFamily.CNN,
                                        cnn_spec=(("flatten",),),
                                        input_shape=(4, 4, 1), num_classes=10),
                      target_metric=target, max_rounds=max_rounds)
            for i in range(3)]
    pool = DevicePool.heterogeneous(100, 3, seed=seed)
    cm = CostModel(pool, alpha=4.0, beta=0.25)
    cm.calibrate([5.0] * 3, n_sel=10)
    sched = get_scheduler(sched_name, cost_model=cm, seed=0,
                          **({"pretrain_rounds": 100} if sched_name == "rlds" else {}))
    rt = SyntheticRuntime(num_jobs=3, num_devices=100, seed=2)
    eng = MultiJobEngine(jobs, pool, cm, sched, rt, n_sel=10)
    eng.run()
    return eng


def test_proposed_methods_beat_random_on_makespan():
    """Paper's headline: BODS/RLDS reach targets faster than Random."""
    results = {}
    for name in ("random", "bods"):
        eng = _synthetic_engine(name)
        results[name] = max(v["makespan"] for v in eng.summary().values())
    assert results["bods"] < 0.8 * results["random"]


def test_greedy_caps_below_target_under_noniid():
    """Paper: Greedy starves slow devices' data -> accuracy ceiling."""
    eng = _synthetic_engine("greedy")
    best = [v["best_accuracy"] for v in eng.summary().values()]
    assert max(best) < 0.8  # never reaches the 0.8 target
    eng2 = _synthetic_engine("bods")
    best2 = [v["best_accuracy"] for v in eng2.summary().values()]
    assert min(best2) >= 0.8


@pytest.mark.slow
def test_real_multijob_fl_end_to_end():
    """Two REAL FL jobs (LeNet5 + CNN-B on synthetic non-IID shards) trained
    in parallel under BODS: accuracy must rise and devices must be shared."""
    num_devices = 40
    jobs, runtimes = [], []
    for jid, mk in enumerate((lenet5, cnn_b)):
        cfg = mk()
        x, y = make_classification_dataset(6000, cfg.input_shape,
                                           cfg.num_classes, noise=1.2, seed=jid)
        ex, ey = make_classification_dataset(600, cfg.input_shape,
                                             cfg.num_classes, noise=1.2,
                                             seed=100 + jid)
        part = noniid_partition(y, num_devices, seed=jid)
        job = JobConfig(job_id=jid, model=cfg, target_metric=0.95,
                        max_rounds=15, local_epochs=2, batch_size=32, lr=0.02)
        jobs.append(job)
        runtimes.append(FLJobRuntime(job, x, y, part, ex, ey, seed=jid))

    pool = DevicePool.heterogeneous(num_devices, 2, seed=5)
    cm = CostModel(pool, alpha=4.0, beta=0.25)
    cm.calibrate([2.0, 2.0], n_sel=5)
    sched = get_scheduler("bods", cost_model=cm, seed=0)
    eng = MultiJobEngine(jobs, pool, cm, sched, MultiRuntime(runtimes), n_sel=5)
    eng.run()
    s = eng.summary()
    assert len(eng.records) >= 20
    for m, (name, v) in enumerate(s.items()):
        accs = [r.accuracy for r in eng.records if r.job == m]
        # well above the 10-class chance level AND improving over the run
        assert v["best_accuracy"] > 0.2, (name, v)
        assert np.mean(accs[-3:]) > np.mean(accs[:3]), (name, accs)
    # both jobs really ran in parallel on the shared pool
    assert (eng.counts.sum(axis=1) > 0).all()
