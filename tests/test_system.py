"""End-to-end behaviour tests of the MJ-FL system (the paper's claims in
miniature): parallel multi-job execution with real federated training, and
the scheduler-quality ordering on the synthetic convergence model. All
scenarios are declared through the ``ExperimentSpec`` front door — the same
path the examples, benchmarks, and CLI use."""

import numpy as np
import pytest

from repro.experiment import ExperimentSpec, JobSpec, PoolSpec


def _synthetic_spec(sched_name, seed=1, target=0.8, max_rounds=120):
    return ExperimentSpec(
        jobs=tuple(JobSpec(name=f"j{i}", target_metric=target,
                           max_rounds=max_rounds) for i in range(3)),
        pool=PoolSpec(num_devices=100, seed=seed),
        scheduler=sched_name,
        scheduler_kwargs=({"pretrain_rounds": 100} if sched_name == "rlds"
                          else {}),
        runtime="synthetic", runtime_kwargs={"seed": 2}, n_sel=10)


def test_proposed_methods_beat_random_on_makespan():
    """Paper's headline: BODS/RLDS reach targets faster than Random."""
    results = {name: _synthetic_spec(name).run().makespan
               for name in ("random", "bods")}
    assert results["bods"] < 0.8 * results["random"]


def test_greedy_caps_below_target_under_noniid():
    """Paper: Greedy starves slow devices' data -> accuracy ceiling.

    Greedy's ceiling is structural (~0.75-0.77 at any budget); BODS clears
    the 0.8 target given the presets' standard 150-round budget (120 was
    tuned to the pre-fused-search RNG stream and sat one or two rounds shy
    for the slowest job under the fused searchers' stream)."""
    best = [v["best_accuracy"]
            for v in _synthetic_spec("greedy", max_rounds=150).run()
            .summary.values()]
    assert max(best) < 0.8  # never reaches the 0.8 target
    best2 = [v["best_accuracy"]
             for v in _synthetic_spec("bods", max_rounds=150).run()
             .summary.values()]
    assert min(best2) >= 0.8


@pytest.mark.slow
def test_real_multijob_fl_end_to_end():
    """Two REAL FL jobs (LeNet5 + CNN-B on synthetic non-IID shards) trained
    in parallel under BODS: accuracy must rise and devices must be shared."""
    spec = ExperimentSpec(
        jobs=(JobSpec(name="paper-lenet5", model="paper-lenet5",
                      target_metric=0.95, max_rounds=15, local_epochs=2,
                      batch_size=32, lr=0.02),
              JobSpec(name="paper-cnn-b", model="paper-cnn-b",
                      target_metric=0.95, max_rounds=15, local_epochs=2,
                      batch_size=32, lr=0.02)),
        pool=PoolSpec(num_devices=40, seed=5),
        scheduler="bods", runtime="real_fl",
        runtime_kwargs={"samples_per_job": 6000, "eval_samples": 600},
        non_iid=True, n_sel=5)
    exp = spec.build()
    res = exp.run()
    assert len(res.records) >= 20
    for m, (name, v) in enumerate(res.summary.items()):
        accs = [r.accuracy for r in res.records if r.job == m]
        # well above the 10-class chance level AND improving over the run
        assert v["best_accuracy"] > 0.2, (name, v)
        assert np.mean(accs[-3:]) > np.mean(accs[:3]), (name, accs)
    # both jobs really ran in parallel on the shared pool
    assert (exp.engine.counts.sum(axis=1) > 0).all()
