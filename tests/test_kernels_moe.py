"""MoE grouped-matmul kernel vs oracle + end-to-end MoE block checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.moe_gmm import moe_gmm

CASES = [
    (4, 96, 192, 320),
    (2, 128, 256, 256),
    (8, 64, 128, 512),
    (1, 256, 512, 128),
    (3, 100, 130, 70),   # deliberately unaligned dims (tile fallback)
]


@pytest.mark.parametrize("E,C,din,dout", CASES)
def test_gmm_matches_oracle(E, C, din, dout):
    rng = np.random.default_rng(hash((E, C, din)) % 2**31)
    xg = jnp.asarray(rng.normal(0, 1, (E, C, din)), jnp.float32)
    wg = jnp.asarray(rng.normal(0, 0.05, (E, din, dout)), jnp.float32)
    out = moe_gmm(xg, wg, interpret=True)
    exp = ref.moe_gmm(xg, wg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-4, rtol=1e-4)


def test_gmm_bf16():
    rng = np.random.default_rng(0)
    xg = jnp.asarray(rng.normal(0, 1, (4, 96, 192)), jnp.bfloat16)
    wg = jnp.asarray(rng.normal(0, 0.05, (4, 192, 320)), jnp.bfloat16)
    out = moe_gmm(xg, wg, interpret=True)
    exp = ref.moe_gmm(xg, wg)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=2e-2, rtol=2e-2)


def test_moe_block_expert_partition_invariance():
    """Sum of per-shard partial outputs == single-shard full output
    (the shard_map psum identity the EP layout relies on)."""
    from repro.configs.dbrx_132b import reduced
    from repro.models.moe import moe_init, _moe_local

    cfg = reduced()
    rng = np.random.default_rng(0)
    p, _ = moe_init(cfg, rng)
    x = jnp.asarray(rng.normal(0, 1, (2, 16, cfg.d_model)), jnp.float32)
    full = _moe_local(cfg, p, x, 0, cfg.num_experts)
    E_half = cfg.num_experts // 2

    def shard_p(lo, hi):
        # what shard_map hands each model-shard: its expert slice + full router
        return {"router": p["router"], "w_gate": p["w_gate"][lo:hi],
                "w_up": p["w_up"][lo:hi], "w_down": p["w_down"][lo:hi]}

    part = (_moe_local(cfg, shard_p(0, E_half), x, 0, E_half)
            + _moe_local(cfg, shard_p(E_half, 2 * E_half), x, E_half, E_half))
    np.testing.assert_allclose(np.asarray(full), np.asarray(part),
                               atol=1e-5, rtol=1e-5)
