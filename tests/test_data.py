"""Data-pipeline tests: determinism, restartability, token streams."""

import numpy as np

from repro.data.pipeline import Batcher, host_local_batches
from repro.data.synthetic import make_classification_dataset, make_lm_tokens


def test_batcher_deterministic_restart():
    x = np.arange(100, dtype=np.float32)[:, None]
    y = np.arange(100, dtype=np.int32)
    b1 = Batcher(x, y, batch_size=16, seed=3)
    seen = [next(b1) for _ in range(5)]
    state = b1.state()
    tail1 = [next(b1) for _ in range(4)]
    b2 = Batcher(x, y, batch_size=16, seed=3)
    b2.restore(state)
    tail2 = [next(b2) for _ in range(4)]
    for (x1, y1), (x2, y2) in zip(tail1, tail2):
        np.testing.assert_array_equal(x1, x2)
        np.testing.assert_array_equal(y1, y2)


def test_batcher_reshuffles_per_epoch():
    x = np.arange(32, dtype=np.float32)[:, None]
    y = np.arange(32, dtype=np.int32)
    b = Batcher(x, y, batch_size=32, seed=0)
    e0 = next(b)[1].copy()
    e1 = next(b)[1].copy()
    assert not np.array_equal(e0, e1)
    np.testing.assert_array_equal(np.sort(e0), np.sort(e1))


def test_host_local_batches_partition():
    g = np.arange(64).reshape(64, 1)
    parts = [host_local_batches(g, h, 4) for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), g)


def test_lm_tokens_in_range():
    toks = make_lm_tokens(5000, 257, seed=0)
    assert toks.min() >= 0 and toks.max() < 257
    # markov structure: not uniform
    _, counts = np.unique(toks, return_counts=True)
    assert counts.max() > 3 * counts.mean()


def test_classification_learnable_structure():
    x, y = make_classification_dataset(2000, (8, 8, 1), 10, noise=0.5, seed=0)
    # nearest-prototype classification on noiseless prototypes ~ high accuracy
    protos = np.stack([x[y == c].mean(axis=0) for c in range(10)])
    d = ((x[:, None] - protos[None]) ** 2).sum(axis=(2, 3, 4))
    acc = (d.argmin(axis=1) == y).mean()
    assert acc > 0.9
