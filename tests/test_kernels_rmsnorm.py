"""Fused RMSNorm kernel vs oracle sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.rmsnorm import rmsnorm

CASES = [
    ((4, 37, 256), 64),
    ((128, 512), 128),
    ((1, 1, 1024), 8),
    ((3, 5, 7, 64), 16),   # rows not a multiple of block (pad path)
]


@pytest.mark.parametrize("shape,block", CASES)
def test_rmsnorm_matches_oracle(shape, block):
    rng = np.random.default_rng(hash(shape) % 2**31)
    x = jnp.asarray(rng.normal(0, 2, shape), jnp.float32)
    s = jnp.asarray(rng.normal(1, 0.1, shape[-1:]), jnp.float32)
    out = rmsnorm(x, s, block_rows=block, interpret=True)
    exp = ref.rmsnorm(x, s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.bfloat16, 2e-2), (jnp.float32, 1e-5)])
def test_rmsnorm_dtypes(dtype, tol):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 2, (32, 128)), dtype)
    s = jnp.asarray(rng.normal(1, 0.1, (128,)), jnp.float32)
    out = rmsnorm(x, s, interpret=True)
    exp = ref.rmsnorm(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)
