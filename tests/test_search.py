"""Fused-search subsystem tests: vectorized repair properties, per-form
auto-dispatch pinning, fused plan invariants, host-vs-fused behavioural
parity at matched search budgets, and the SA small fixes.

Property tests run under hypothesis when available; without it they
degrade to a fixed-seed sweep (the pattern of tests/test_scoring.py).
"""

import warnings

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

from repro.core import scoring, search
from repro.core.cost import CostModel
from repro.core.devices import DevicePool
from repro.core.plans import (random_plans, repair_plans, validate_plan)
from repro.core.schedulers import get_scheduler
from repro.core.schedulers.base import SchedulingContext


def make_ctx(pool, job=0, n_sel=5, occupied=None, counts=None, round_idx=0):
    K = pool.num_devices
    avail = np.ones(K, dtype=bool)
    if occupied is not None:
        avail[occupied] = False
    return SchedulingContext(
        job=job, round_idx=round_idx, tau=5.0, n_sel=n_sel,
        available=avail,
        counts=counts if counts is not None else np.zeros(K),
        expected_times=pool.expected_times(job, 5.0))


def scenario(K, seed, n_sel, busy_frac=0.2):
    pool = DevicePool.heterogeneous(K, 2, seed=seed)
    cm = CostModel(pool, alpha=4.0, beta=0.25)
    cm.calibrate([5.0, 5.0], n_sel=n_sel)
    rng = np.random.default_rng(seed + 1000)
    counts = rng.integers(0, 8, K).astype(np.float64)
    occ = rng.choice(K, int(K * busy_frac), replace=False)
    return cm, pool, counts, occ


# ---- repair_plans properties ----------------------------------------------

def check_repair_feasible(seed, k, n_sel, p):
    rng = np.random.default_rng(seed)
    n_sel = min(n_sel, k)
    available = rng.random(k) < 0.6
    if available.sum() < n_sel:
        available[rng.choice(k, n_sel, replace=False)] = True
    raw = rng.random((p, k)) < 0.3
    out = repair_plans(rng, raw, available, n_sel)
    for r_raw, r in zip(raw, out):
        validate_plan(r, available, n_sel)
        keep = r_raw & available
        # Valid selections survive: kept entirely when under budget,
        # and nothing outside them is added when over budget.
        if keep.sum() <= n_sel:
            assert np.all(r[keep])
        else:
            assert np.all(keep[r])


def check_repair_idempotent(seed, k, n_sel):
    rng = np.random.default_rng(seed)
    n_sel = min(n_sel, k)
    available = rng.random(k) < 0.7
    if available.sum() < n_sel:
        available[rng.choice(k, n_sel, replace=False)] = True
    valid = random_plans(rng, available, n_sel, 6)
    assert np.array_equal(repair_plans(rng, valid, available, n_sel), valid)


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 2**31), k=st.integers(5, 60),
           n_sel=st.integers(1, 8), p=st.integers(1, 10))
    def test_repair_plans_always_feasible(seed, k, n_sel, p):
        check_repair_feasible(seed, k, n_sel, p)

    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 2**31), k=st.integers(5, 60),
           n_sel=st.integers(1, 8))
    def test_repair_plans_idempotent_on_valid(seed, k, n_sel):
        check_repair_idempotent(seed, k, n_sel)
else:  # pragma: no cover - fixed-seed fallback
    def test_repair_plans_always_feasible():
        rng = np.random.default_rng(0)
        for _ in range(60):
            check_repair_feasible(int(rng.integers(2**31)),
                                  int(rng.integers(5, 60)),
                                  int(rng.integers(1, 8)),
                                  int(rng.integers(1, 10)))

    def test_repair_plans_idempotent_on_valid():
        rng = np.random.default_rng(1)
        for _ in range(60):
            check_repair_idempotent(int(rng.integers(2**31)),
                                    int(rng.integers(5, 60)),
                                    int(rng.integers(1, 8)))


def test_repair_plans_jax_matches_contract():
    """The in-graph twin obeys the same feasibility/idempotence contract."""
    import jax

    rng = np.random.default_rng(3)
    for t in range(10):
        K, n_sel = 40, 6
        avail = rng.random(K) < 0.6
        if avail.sum() < n_sel:
            avail[rng.choice(K, n_sel, replace=False)] = True
        raw = rng.random((8, K)) < 0.3
        key = jax.random.PRNGKey(t)
        out = np.asarray(search.repair_plans_jax(key, raw, avail, n_sel))
        for r_raw, r in zip(raw, out):
            validate_plan(r, avail, n_sel)
            keep = r_raw & avail
            if keep.sum() <= n_sel:
                assert np.all(r[keep])
            else:
                assert np.all(keep[r])
        valid = random_plans(rng, avail, n_sel, 4)
        fixed = np.asarray(search.repair_plans_jax(key, valid, avail, n_sel))
        assert np.array_equal(fixed, valid)


# ---- per-form auto dispatch (calibrated from BENCH_fleet.json) ------------

def test_auto_dispatch_per_form_thresholds():
    # Dense: numpy through the K=1e3/P=256 tie (2.56e5), jax by 4.1e5.
    assert scoring.resolve_backend("auto", 100 * 256, "dense") == "numpy"
    assert scoring.resolve_backend("auto", 1000 * 256, "dense") == "numpy"
    assert scoring.resolve_backend("auto", 100 * 4096, "dense") == "jax"
    assert scoring.resolve_backend("auto", 10_000 * 4096, "dense") == "jax"
    # Index: numpy's gather stays ahead through P*n_sel = 4.1e5 (K=1e4,
    # P=4096) and loses by 4.1e6 (K=1e5, P=4096).
    assert scoring.resolve_backend("auto", 4096 * 100, "index") == "numpy"
    assert scoring.resolve_backend("auto", 4096 * 1000, "index") == "jax"
    # The index threshold sits strictly above the dense one.
    assert scoring.AUTO_NUMPY_MAX_INDEX > scoring.AUTO_NUMPY_MAX_DENSE
    # Back-compat alias still names the dense threshold.
    assert scoring.AUTO_NUMPY_MAX == scoring.AUTO_NUMPY_MAX_DENSE


def test_index_form_dispatch_used_by_score_plan_indices():
    """(P, S) element counts between the two thresholds pick numpy for the
    index form and jax for an equal-sized dense problem."""
    mid = (scoring.AUTO_NUMPY_MAX_DENSE + scoring.AUTO_NUMPY_MAX_INDEX) // 2
    assert scoring.resolve_backend("auto", mid, "index") == "numpy"
    assert scoring.resolve_backend("auto", mid, "dense") == "jax"


# ---- in-graph cost parity against the scoring core ------------------------

@pytest.mark.parametrize("delta", [True, False])
def test_plan_costs_matches_scoring_core(delta):
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    K, P, n_sel = 300, 32, 12
    times = rng.uniform(0.5, 80.0, K)
    counts = rng.integers(0, 40, K).astype(np.float64)
    plans = random_plans(rng, np.ones(K, bool), n_sel, P)
    idx = np.stack([np.flatnonzero(p) for p in plans]).astype(np.int32)
    kw = dict(alpha=4.0, beta=0.25, time_scale=3.0, fairness_scale=0.09,
              delta_fairness=delta)
    want = scoring.score_plans(times, counts, plans, backend="numpy", **kw)
    counts_c = jnp.asarray(counts - counts.mean(), jnp.float32)
    t32 = jnp.asarray(times, jnp.float32)
    dense = np.asarray(search.plan_costs(
        t32, counts_c, jnp.asarray(plans), 4.0, 0.25, 3.0, 0.09, delta))
    byidx = np.asarray(search.plan_costs_idx(
        t32, counts_c, jnp.asarray(idx), 4.0, 0.25, 3.0, 0.09, delta))
    np.testing.assert_allclose(dense, want, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(byidx, want, rtol=2e-4, atol=2e-4)


# ---- fused plan invariants -------------------------------------------------

@pytest.mark.parametrize("name", ["sa", "genetic", "bods"])
def test_fused_plan_invariants(name):
    """The fused searchers return exactly n_sel available devices, always,
    across evolving occupancy/counts."""
    pool = DevicePool.heterogeneous(40, 2, seed=1)
    cm = CostModel(pool)
    cm.calibrate([5.0, 5.0], n_sel=4)
    sched = get_scheduler(name, cost_model=cm, seed=0,
                          search_backend="fused")
    rng = np.random.default_rng(0)
    counts = np.zeros(40)
    for r in range(6):
        occ = rng.choice(40, rng.integers(0, 20), replace=False)
        ctx = make_ctx(pool, n_sel=4, occupied=occ, counts=counts,
                       round_idx=r)
        plan = sched.schedule(ctx)
        validate_plan(plan, ctx.available, 4)
        sched.observe(ctx, plan, float(rng.random()))
        counts += plan


def test_fused_raises_when_pool_too_small():
    pool = DevicePool.heterogeneous(10, 1, seed=0)
    cm = CostModel(pool)
    for name in ("sa", "genetic"):
        sched = get_scheduler(name, cost_model=cm, seed=0,
                              search_backend="fused")
        ctx = make_ctx(pool, n_sel=5, occupied=np.arange(6))
        with pytest.raises(ValueError):
            sched.schedule(ctx)


def test_search_backend_rejects_unknown():
    pool = DevicePool.heterogeneous(10, 1, seed=0)
    cm = CostModel(pool)
    with pytest.raises(ValueError):
        get_scheduler("sa", cost_model=cm, seed=0, search_backend="gpu")


# ---- host-vs-fused behavioural parity (matched budgets, seeded) -----------

def _mean_chosen_cost(name, kw, seeds, K=80, n_sel=8, reps=2):
    cs = []
    for sd in seeds:
        cm, pool, counts, occ = scenario(K, sd, n_sel)
        sched = get_scheduler(name, cost_model=cm, seed=sd, **kw)
        for _ in range(reps):
            ctx = make_ctx(pool, n_sel=n_sel, occupied=occ, counts=counts)
            plan = sched.schedule(ctx)
            validate_plan(plan, ctx.available, n_sel)
            cs.append(sched.last_estimated_cost)
    return float(np.mean(cs))


def test_sa_parity_fused_no_worse_than_host():
    """Matched budget: 8 chains x 25 steps (cooling^8) vs 200 host steps.
    Multi-chain + greedy seeding should dominate the single host chain."""
    seeds = range(8)
    host = _mean_chosen_cost(
        "sa", dict(search_backend="host", steps=200), seeds)
    fused = _mean_chosen_cost(
        "sa", dict(search_backend="fused", steps=25, chains=8,
                   cooling=0.97 ** 8), seeds)
    assert fused <= host * 1.005, (fused, host)


def test_ga_parity_fused_no_worse_than_host():
    seeds = range(8)
    host = _mean_chosen_cost("genetic", dict(search_backend="host"), seeds)
    fused = _mean_chosen_cost("genetic", dict(search_backend="fused"), seeds)
    assert fused <= host * 1.005, (fused, host)


def test_bods_fused_comparable_and_beats_random():
    """BODS picks by EI (not pure cost), so parity is statistical: the
    fused acquisition must stay in the host path's cost band and well
    below random selection."""
    seeds = range(6)
    host = _mean_chosen_cost("bods", dict(search_backend="host"), seeds)
    fused = _mean_chosen_cost("bods", dict(search_backend="fused"), seeds)
    rand = _mean_chosen_cost("random", {}, seeds)
    assert fused <= host * 1.15, (fused, host)
    assert fused < rand, (fused, rand)


# ---- SA small fixes --------------------------------------------------------

def test_sa_host_no_free_device_completes():
    """available == n_sel: no swap is ever possible; the host path must
    return the (only) valid plan instead of breaking mid-schedule."""
    pool = DevicePool.heterogeneous(20, 1, seed=0)
    cm = CostModel(pool)
    cm.calibrate([5.0], n_sel=3)
    sched = get_scheduler("sa", cost_model=cm, seed=0, search_backend="host")
    ctx = make_ctx(pool, n_sel=3, occupied=np.arange(3, 20))
    plan = sched.schedule(ctx)
    validate_plan(plan, ctx.available, 3)
    # Fused path: swaps all mask out, plan still valid.
    schedf = get_scheduler("sa", cost_model=cm, seed=0,
                           search_backend="fused")
    plan = schedf.schedule(make_ctx(pool, n_sel=3, occupied=np.arange(3, 20)))
    validate_plan(plan, np.r_[np.ones(3, bool), np.zeros(17, bool)], 3)


def test_sa_metropolis_exponent_clamped():
    """Pathological cost spikes (t0 ~ 0 -> huge exponent) must not emit
    overflow RuntimeWarnings from np.exp."""
    pool = DevicePool.heterogeneous(30, 1, seed=0)
    cm = CostModel(pool, alpha=100.0, beta=50.0)  # uncalibrated: big costs
    sched = get_scheduler("sa", cost_model=cm, seed=0, search_backend="host",
                          steps=50, t0=1e-12)
    ctx = make_ctx(pool, n_sel=5)
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        with np.errstate(over="raise", invalid="raise"):
            plan = sched.schedule(ctx)
    validate_plan(plan, ctx.available, 5)


# ---- batched all-jobs EI ---------------------------------------------------

def test_ei_scores_jobs_matches_per_job_loop():
    from repro.core.schedulers.bods import MAX_OBS, NUM_FEATURES, _ei_scores

    rng = np.random.default_rng(0)
    M, L, P, d = 3, MAX_OBS, 17, NUM_FEATURES
    F = rng.normal(size=(M, L, d)).astype(np.float32)
    resid = rng.normal(size=(M, L)).astype(np.float32)
    valid = (rng.random((M, L)) < 0.3).astype(np.float32)
    feats = rng.normal(size=(M, P, d)).astype(np.float32)
    cand = rng.normal(size=(M, P)).astype(np.float32)
    batched = np.asarray(search.ei_scores_jobs(
        F, resid, valid, feats, cand, 0.25))
    assert batched.shape == (M, P)
    for m in range(M):
        one = np.asarray(_ei_scores(F[m], resid[m], valid[m],
                                    feats[m], cand[m], 0.25))
        np.testing.assert_allclose(batched[m], one, rtol=1e-5, atol=1e-6)


def test_featurize_plans_matches_host_bods():
    """The in-graph phi(V) must match the host BODSScheduler._featurize
    formula-for-formula (the GP consumes both)."""
    import jax.numpy as jnp

    K, P, n_sel = 60, 16, 6
    cm, pool, counts, occ = scenario(K, 0, n_sel)
    ctx = make_ctx(pool, n_sel=n_sel, occupied=occ, counts=counts)
    sched = get_scheduler("bods", cost_model=cm, seed=0)
    rng = np.random.default_rng(1)
    plans = random_plans(rng, ctx.available, n_sel, P)
    want = sched._featurize(ctx, plans)
    counts_c = jnp.asarray(counts - counts.mean(), jnp.float32)
    got, _, _ = search.featurize_plans(
        jnp.asarray(ctx.expected_times, jnp.float32), counts_c,
        jnp.asarray(counts == 0), jnp.asarray(pool.mu, jnp.float32),
        jnp.asarray(plans), cm.time_scale, cm.fairness_scale, n_sel,
        cm.delta_fairness)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


# ---- experiment-layer wiring ----------------------------------------------

def test_spec_search_backend_axis_roundtrip():
    from repro.experiment.spec import ExperimentSpec, JobSpec

    spec = ExperimentSpec(jobs=(JobSpec(name="a", max_rounds=2),),
                          scheduler="sa")
    assert spec.build().engine.scheduler.search_backend == "fused"
    host = spec.replace(search_backend="host")
    assert host.build().engine.scheduler.search_backend == "host"
    assert ExperimentSpec.from_json(host.to_json()) == host
    nested = spec.replace(fleet={"search_backend": "host"})
    assert nested.effective_search_backend() == "host"
    assert nested.build().engine.scheduler.search_backend == "host"
    # Schedulers without the knob still build with the axis set.
    dnn = spec.replace(scheduler="dnn", search_backend="host")
    dnn.build()


def test_ctx_caches_computed_once():
    pool = DevicePool.heterogeneous(25, 1, seed=0)
    ctx = make_ctx(pool, n_sel=4, occupied=[1, 2])
    t32 = ctx.times32()
    assert t32.dtype == np.float32
    assert ctx.times32() is t32               # cached, not recomputed
    idx = ctx.available_indices()
    assert ctx.available_indices() is idx
    np.testing.assert_array_equal(idx, np.flatnonzero(ctx.available))
