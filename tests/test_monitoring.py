"""Monitoring substrate tests: metrics JSONL, step timing, audit replay."""

import json
import time

import numpy as np

from repro.monitoring import MetricsLogger, SchedulerAudit, StepTimer
from repro.monitoring.audit import replay


def test_metrics_jsonl_roundtrip(tmp_path):
    p = tmp_path / "metrics.jsonl"
    log = MetricsLogger(str(p))
    log.log(1, {"loss": 2.5}, lr=1e-3)
    log.log(2, {"loss": 2.4})
    log.close()
    lines = [json.loads(l) for l in open(p)]
    assert lines[0]["step"] == 1 and lines[0]["loss"] == 2.5 and lines[0]["lr"] == 1e-3
    assert lines[1]["step"] == 2


def test_step_timer_ema_and_stragglers():
    t = StepTimer(ema=0.5, straggler_factor=2.0)
    for _ in range(3):
        with t:
            time.sleep(0.01)
    assert 0.005 < t.ema_s < 0.05
    with t:
        time.sleep(0.08)  # > 2x EMA -> straggler
    assert t.stragglers == 1


def test_audit_log_with_engine(tmp_path):
    from repro.config.base import ArchFamily, JobConfig, ModelConfig
    from repro.core import CostModel, DevicePool, MultiJobEngine, get_scheduler
    from repro.fl.runtime import SyntheticRuntime

    jobs = [JobConfig(job_id=0,
                      model=ModelConfig(name="t", family=ArchFamily.CNN,
                                        cnn_spec=(("flatten",),),
                                        input_shape=(4, 4, 1), num_classes=10),
                      target_metric=0.7, max_rounds=10)]
    pool = DevicePool.heterogeneous(20, 1, seed=0)
    cm = CostModel(pool)
    cm.calibrate([5.0], n_sel=3)
    audit = SchedulerAudit(str(tmp_path / "audit.jsonl"))
    eng = MultiJobEngine(jobs, pool, cm, get_scheduler("random", cost_model=cm),
                         SyntheticRuntime(1, 20), n_sel=3)
    eng.run(on_round=audit.on_round)
    audit.close()
    recs = replay(str(tmp_path / "audit.jsonl"))
    assert len(recs) == len(eng.records)
    assert all(len(r["devices"]) == 3 for r in recs)


def test_metrics_flush_every_batches_writes(tmp_path):
    p = tmp_path / "m.jsonl"
    log = MetricsLogger(str(p), flush_every=3)
    log.log(1, {"v": 1.0})
    log.log(2, {"v": 2.0})
    # Block-buffered + no flush yet: nothing has reached the file.
    assert p.read_text() == ""
    log.log(3, {"v": 3.0})                       # 3rd record -> flush
    assert len(p.read_text().splitlines()) == 3
    log.log(4, {"v": 4.0})
    log.close()                                  # close flushes the tail
    assert len(p.read_text().splitlines()) == 4
    log.close()                                  # idempotent


def test_metrics_flush_every_validated(tmp_path):
    import pytest

    with pytest.raises(ValueError, match="flush_every"):
        MetricsLogger(str(tmp_path / "m.jsonl"), flush_every=0)


def test_metrics_and_audit_context_managers(tmp_path):
    with MetricsLogger(str(tmp_path / "m.jsonl")) as log:
        log.log(1, {"v": 1.0})
    assert log._f.closed
    with SchedulerAudit(str(tmp_path / "a.jsonl")) as audit:
        pass
    assert audit._f.closed


def test_audit_records_estimate_degraded_and_scheduler(tmp_path):
    from types import SimpleNamespace

    p = tmp_path / "audit.jsonl"
    audit = SchedulerAudit(str(p), scheduler="bods")
    audit.on_round(SimpleNamespace(
        job=1, round_idx=4, t_start=0.0, t_end=9.5, round_time=9.5,
        cost=3.25, est_cost=np.float64(3.0), fairness=1.5, degraded=True,
        loss=0.4, accuracy=0.75, device_ids=np.array([2, 5]),
        dropped=np.array([7])))
    audit.close()
    (rec,) = replay(str(p))
    assert rec["scheduler"] == "bods"
    assert rec["est_cost"] == 3.0 and isinstance(rec["est_cost"], float)
    assert rec["degraded"] is True
    assert rec["devices"] == [2, 5] and rec["dropped"] == [7]
