"""Data-partitioner tests (the paper's §5 IID / non-IID setups)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; suite must collect without it
from hypothesis import given, settings, strategies as st

from repro.data.synthetic import make_classification_dataset
from repro.fl.partition import device_label_histogram, iid_partition, noniid_partition


def test_noniid_two_classes_per_device():
    _, y = make_classification_dataset(8000, (4, 4, 1), 10, seed=0)
    part = noniid_partition(y, 100, classes_per_device=2, parts_per_class=20, seed=1)
    hist = device_label_histogram(y, part, 10)
    classes_per_dev = (hist > 0).sum(axis=1)
    assert np.all(classes_per_dev <= 2)
    assert np.all(classes_per_dev >= 1)


def test_noniid_covers_all_classes_globally():
    _, y = make_classification_dataset(8000, (4, 4, 1), 10, seed=0)
    part = noniid_partition(y, 100, seed=1)
    hist = device_label_histogram(y, part, 10)
    assert np.all(hist.sum(axis=0) > 0)


def test_iid_devices_see_most_classes():
    _, y = make_classification_dataset(8000, (4, 4, 1), 10, seed=0)
    part = iid_partition(y, 50, 200, seed=1)
    hist = device_label_histogram(y, part, 10)
    assert ((hist > 0).sum(axis=1) >= 8).mean() > 0.9


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31), num_devices=st.integers(5, 60))
def test_partition_indices_in_range(seed, num_devices):
    _, y = make_classification_dataset(4000, (2, 2, 1), 10, seed=0)
    part = noniid_partition(y, num_devices, seed=seed)
    assert part.min() >= 0 and part.max() < 4000
    assert part.shape[0] == num_devices


def test_noniid_starved_class_raises_informatively():
    """Too few samples per class for the requested split must fail early
    with the sizing math, not produce width-0 shards (or divide by zero
    downstream)."""
    _, y = make_classification_dataset(60, (2, 2, 1), 10, seed=0)
    # ~6 samples/class split 20 ways: some chunks are inevitably empty
    with pytest.raises(ValueError, match="parts_per_class"):
        noniid_partition(y, 10, parts_per_class=20, seed=1)
    # the same data partitions fine when the split is feasible
    part = noniid_partition(y, 10, parts_per_class=2, seed=1)
    assert part.shape[1] > 0


def test_train_eval_share_prototypes():
    x1, y1 = make_classification_dataset(100, (4, 4, 1), 10, noise=0.0, seed=0)
    x2, y2 = make_classification_dataset(100, (4, 4, 1), 10, noise=0.0, seed=99)
    # zero-noise samples of the same class must be identical across splits
    c = y1[0]
    j = np.flatnonzero(y2 == c)
    assert j.size > 0
    np.testing.assert_allclose(x1[0], x2[j[0]])
