"""Multi-job engine invariants: occupancy exclusivity, async progress,
fault handling, straggler mitigation."""

import numpy as np
import pytest

from repro.config.base import ArchFamily, JobConfig, ModelConfig
from repro.core.cost import CostModel
from repro.core.devices import DevicePool
from repro.core.multijob import MultiJobEngine
from repro.core.schedulers import get_scheduler
from repro.fl.runtime import SyntheticRuntime


def tiny_jobs(n=3, target=0.75, max_rounds=40):
    mc = ModelConfig(name="t", family=ArchFamily.CNN, cnn_spec=(("flatten",),),
                     input_shape=(4, 4, 1), num_classes=10)
    return [JobConfig(job_id=i, model=mc, target_metric=target,
                      max_rounds=max_rounds) for i in range(n)]


def build(sched="random", n_jobs=3, seed=1, **engine_kw):
    pool = DevicePool.heterogeneous(50, n_jobs, seed=seed)
    cm = CostModel(pool, alpha=4.0, beta=0.25)
    cm.calibrate([5.0] * n_jobs, n_sel=5)
    s = get_scheduler(sched, cost_model=cm, seed=0)
    rt = SyntheticRuntime(num_jobs=n_jobs, num_devices=50, seed=2)
    eng = MultiJobEngine(tiny_jobs(n_jobs), pool, cm, s, rt, n_sel=5, **engine_kw)
    return eng


def test_no_device_double_booked():
    """At no simulated instant may a device serve two jobs (paper constraint)."""
    eng = build()
    eng.run()
    # Reconstruct per-device busy intervals from the records.
    intervals = {}
    for r in eng.records:
        for k in r.device_ids:
            intervals.setdefault(int(k), []).append((r.t_start, r.t_end, r.job))
    for k, iv in intervals.items():
        iv.sort()
        for (s1, e1, j1), (s2, e2, j2) in zip(iv, iv[1:]):
            if j1 != j2:
                # different jobs may not overlap on a device; same-job rounds
                # are sequential by construction
                assert s2 >= s1 - 1e-9
                # the device was released at its own finish time <= e1
                # so a strictly earlier start of another job is impossible
                assert s2 >= s1


def test_all_jobs_progress_and_finish():
    eng = build()
    eng.run()
    s = eng.summary()
    assert len(s) == 3
    for v in s.values():
        assert v["rounds"] > 0
        assert v["best_accuracy"] > 0.3


def test_rounds_interleave_async():
    """Jobs run in PARALLEL: round intervals of different jobs must overlap."""
    eng = build()
    eng.run()
    r0 = [r for r in eng.records if r.job == 0]
    r1 = [r for r in eng.records if r.job == 1]
    overlaps = any(a.t_start < b.t_end and b.t_start < a.t_end
                   for a in r0[:10] for b in r1[:10])
    assert overlaps


def test_failure_injection_drops_devices_but_completes():
    eng = build(failure_rate=0.2, failure_cooldown=100.0)
    eng.run()
    dropped = sum(len(r.dropped) for r in eng.records)
    assert dropped > 0, "20% failure rate must drop some devices"
    for v in eng.summary().values():
        assert v["rounds"] > 0  # training survived the failures


def test_straggler_over_provisioning_reduces_round_time():
    eng_base = build(seed=7)
    eng_over = build(seed=7, over_provision=1.4)
    eng_base.run()
    eng_over.run()
    t_base = np.mean([r.round_time for r in eng_base.records])
    t_over = np.mean([r.round_time for r in eng_over.records])
    # dropping the slowest 40% tail must cut the mean round time
    assert t_over < t_base


def test_release_horizon_respects_queueing():
    """With horizon > 0, scheduled-but-busy devices serve AFTER their release
    (their effective round time includes the wait), and the engine completes."""
    eng = build("greedy", release_horizon=0.5)
    eng.run()
    # a device's rounds never overlap in effective time
    per_dev = {}
    for r in eng.records:
        for k in r.device_ids:
            per_dev.setdefault(int(k), []).append((r.t_start, r.t_end))
    for v in eng.summary().values():
        assert v["rounds"] > 0


def test_counts_match_records():
    eng = build()
    eng.run()
    counts = np.zeros((3, 50))
    for r in eng.records:
        counts[r.job][r.device_ids] += 1
    np.testing.assert_array_equal(counts, eng.counts)


def test_over_provision_exceeding_pool_is_clamped():
    """n_sel * over_provision > K used to retry-loop forever; now clamps."""
    with pytest.warns(RuntimeWarning, match="clamped"):
        eng = build(n_jobs=1, over_provision=20.0)  # 5 * 20 = 100 > K=50
    assert int(round(eng.n_sel * eng.over_provision)) <= eng.pool.num_devices
    eng.run()
    assert eng.summary()["t"]["rounds"] > 0


def test_permanent_device_loss_does_not_livelock():
    """Failing most of the pool forever must clamp/abandon, not spin."""
    eng = build(n_jobs=1)
    eng.pool.fail(np.arange(48))  # 2 reachable devices < n_sel=5, forever
    with pytest.warns(RuntimeWarning):
        eng.run()  # terminates (clamped selection or abandoned job)
    s = eng.summary()["t"]
    assert s["rounds"] >= 0  # summary stays well-defined either way


def test_total_device_loss_abandons_job():
    eng = build(n_jobs=1)
    eng.pool.fail(np.arange(50))  # nothing can ever free again
    with pytest.warns(RuntimeWarning, match="abandoning"):
        eng.run()
    s = eng.summary()["t"]
    assert s["rounds"] == 0
    assert s["mean_round_time"] == 0.0
    assert s["makespan"] == 0.0
    assert s["final_accuracy"] == 0.0


def test_summary_reports_mean_round_time():
    eng = build()
    eng.run()
    for v in eng.summary().values():
        assert v["mean_round_time"] == pytest.approx(
            v["total_round_time"] / v["rounds"])
