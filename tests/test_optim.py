"""Optimizer tests: convergence on a quadratic, state shapes, clipping, schedules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    adafactor,
    adamw,
    clip_by_global_norm,
    cosine_schedule,
    make_optimizer,
    momentum,
    sgd,
    warmup_cosine,
)
from repro.config.base import OptimizerConfig


def quad_loss(p):
    return sum(jnp.sum((leaf - 3.0) ** 2) for leaf in jax.tree_util.tree_leaves(p))


PARAMS = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}


@pytest.mark.parametrize("opt", [
    sgd(0.1), momentum(0.05, 0.9), adamw(0.3), adafactor(0.5)])
def test_optimizers_converge_on_quadratic(opt):
    init, update = opt
    p = PARAMS
    st = init(p)
    for _ in range(200):
        g = jax.grad(quad_loss)(p)
        up, st = update(g, st, p)
        p = jax.tree_util.tree_map(lambda a, b: a + b, p, up)
    assert quad_loss(p) < 0.1 * quad_loss(PARAMS)


def test_adafactor_state_is_factored():
    init, _ = adafactor(0.1)
    st = init({"w": jnp.zeros((64, 32))})
    row, col = st.inner["w"]
    assert row.shape == (64,)
    assert col.shape == (32,)


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert norm == pytest.approx(np.sqrt(1000.0), rel=1e-5)
    cn = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
    assert float(cn) == pytest.approx(1.0, rel=1e-4)


def test_clip_noop_below_threshold():
    g = {"a": jnp.full((4,), 0.01)}
    clipped, _ = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]), np.asarray(g["a"]))


def test_make_optimizer_resolves_all():
    for name in ("sgd", "momentum", "adam", "adamw", "adafactor"):
        init, update = make_optimizer(OptimizerConfig(name=name, lr=0.1))
        st = init(PARAMS)
        up, _ = update(PARAMS, st, PARAMS)
        assert jax.tree_util.tree_structure(up) == jax.tree_util.tree_structure(PARAMS)


def test_schedules_shape():
    cos = cosine_schedule(100)
    assert float(cos(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(cos(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)
    wc = warmup_cosine(10, 110)
    assert float(wc(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(wc(jnp.asarray(10))) == pytest.approx(1.0)
