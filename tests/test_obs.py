"""Observability layer tests: span tracer, Perfetto schema, event bus,
the spec's ``obs`` axis, the recompile counter, and the report CLI."""

import dataclasses
import json

import numpy as np
import pytest

from repro.experiment.spec import ExperimentSpec, JobSpec, PoolSpec
from repro.monitoring import EventBus, ObsSession, ObsSpec, Tracer
from repro.monitoring import report as rpt
from repro.monitoring import trace as trace_mod
from repro.monitoring.__main__ import main as monitoring_cli


# ---- span tracer ----


def test_disabled_span_is_shared_noop_and_records_nothing():
    t = Tracer()
    s = t.span("a", job=1)
    assert s is t.span("b")          # the shared singleton, zero allocation
    with s:
        pass
    t.counter("c", 1.0)
    t.instant("i")
    assert t.num_events == 0


def test_spans_nest_and_record_complete_events():
    t = Tracer(enabled=True)
    with t.span("outer", job=3):
        with t.span("inner"):
            pass
    evs = t.events()
    assert [e["name"] for e in evs] == ["inner", "outer"]  # exit order
    inner, outer = evs
    for e in evs:
        assert e["ph"] == "X" and e["dur"] >= 0 and "ts" in e
    # proper nesting: inner's [ts, ts+dur] sits inside outer's
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    assert outer["args"] == {"job": 3}


def test_global_tracer_enable_disable():
    trace_mod.clear()
    assert not trace_mod.enabled()
    with trace_mod.span("off"):
        pass
    assert trace_mod.get_tracer().num_events == 0
    trace_mod.enable()
    try:
        with trace_mod.span("on"):
            pass
        trace_mod.counter("jit_recompiles", 2)
    finally:
        trace_mod.disable()
    evs = trace_mod.get_tracer().events()
    assert [e["name"] for e in evs] == ["on", "jit_recompiles"]
    assert rpt.recompile_count(evs) == 2
    trace_mod.clear()


def test_perfetto_schema_roundtrip(tmp_path):
    t = Tracer(enabled=True)
    with t.span("phase", k=1):
        pass
    t.counter("ctr", 5.0)
    t.instant("mark", why="x")
    p = tmp_path / "sub" / "trace.json"   # save creates parent dirs
    t.save(str(p), process_name="proc")
    d = json.load(open(p))
    assert set(d) == {"traceEvents", "displayTimeUnit"}
    evs = d["traceEvents"]
    assert evs[0]["ph"] == "M" and evs[0]["args"]["name"] == "proc"
    by_ph = {e["ph"]: e for e in evs}
    assert by_ph["X"]["name"] == "phase" and by_ph["X"]["args"] == {"k": 1}
    assert by_ph["C"]["args"]["ctr"] == 5.0
    assert by_ph["i"]["s"] == "t"
    # load_trace accepts both the object form and a bare event array
    assert rpt.load_trace(str(p)) == evs
    bare = tmp_path / "bare.json"
    bare.write_text(json.dumps(evs))
    assert rpt.load_trace(str(bare)) == evs


# ---- event bus ----


def test_bus_fan_out_and_error_isolation():
    bus = EventBus()
    seen_a, seen_b = [], []
    bus.subscribe("round", seen_a.append)
    bus.subscribe("round", lambda _: 1 / 0)   # must not break the fan-out
    bus.subscribe("round", seen_b.append)
    with pytest.warns(RuntimeWarning, match="round"):
        assert bus.publish("round", "r0") == 2
    assert bus.publish("round", "r1") == 2    # warns once per sink
    assert seen_a == ["r0", "r1"] == seen_b
    assert bus.errors == 2
    assert bus.publish("other", "x") == 0     # no sinks: no-op


def test_bus_unsubscribe():
    bus = EventBus()
    seen = []
    bus.subscribe("t", seen.append)
    assert bus.unsubscribe("t", seen.append)
    assert not bus.unsubscribe("t", seen.append)
    assert bus.publish("t", 1) == 0 and seen == []


# ---- the obs spec axis ----


def _tiny_spec(**obs):
    return ExperimentSpec(
        jobs=(JobSpec(name="j0", max_rounds=6, target_metric=2.0),),
        pool=PoolSpec(num_devices=12), scheduler="greedy", n_sel=3,
        obs=ObsSpec(**obs))


def test_obsspec_json_roundtrip_and_replace_merge(tmp_path):
    spec = _tiny_spec(trace_path="t.json", flush_every=4)
    back = ExperimentSpec.from_dict(json.loads(spec.to_json()))
    assert back == spec and back.obs.flush_every == 4
    # dict-merge replace (the CLI's --set obs.metrics_path=... path)
    merged = spec.replace(obs={"metrics_path": "m.jsonl"})
    assert merged.obs.trace_path == "t.json"      # preserved
    assert merged.obs.metrics_path == "m.jsonl"   # merged in
    assert merged.obs.active
    # specs without an obs block (pre-axis JSONs) load with the default
    d = spec.to_dict()
    del d["obs"]
    assert ExperimentSpec.from_dict(d).obs == ObsSpec()


def test_obsspec_active():
    assert not ObsSpec().active
    assert ObsSpec(enabled=True).active
    assert ObsSpec(metrics_path="m.jsonl").active


def test_obs_run_emits_trace_metrics_audit(tmp_path):
    tp, mp, ap = (str(tmp_path / n) for n in ("t.json", "m.jsonl", "a.jsonl"))
    spec = _tiny_spec(trace_path=tp, metrics_path=mp, audit_path=ap)
    res = spec.run()
    assert not trace_mod.enabled()       # session released the tracer
    evs = rpt.load_trace(tp)
    stats = rpt.phase_stats(evs)
    for phase in rpt.ENGINE_PHASES + ("engine_run",):
        assert phase in stats, phase
    assert rpt.coverage(stats) >= 0.9
    metrics = rpt.load_metrics(mp)
    assert len(metrics) == len(res.records)
    assert {m["job"] for m in metrics} == {0}
    audit = [json.loads(l) for l in open(ap)]
    assert len(audit) == len(res.records)
    assert all(a["scheduler"] == "greedy" for a in audit)


def test_obs_disabled_run_is_bitwise_identical(tmp_path):
    plain = _tiny_spec().run()
    traced = _tiny_spec(trace_path=str(tmp_path / "t.json"),
                        metrics_path=str(tmp_path / "m.jsonl")).run()
    assert len(plain.records) == len(traced.records)
    for a, b in zip(plain.records, traced.records):
        da, db = dataclasses.asdict(a), dataclasses.asdict(b)
        for k, va in da.items():
            if isinstance(va, np.ndarray):
                assert np.array_equal(va, db[k]), k
            else:
                assert va == db[k] or (va is None and db[k] is None), k


def test_engine_bus_topics(tmp_path):
    spec = _tiny_spec(enabled=True)
    ex = spec.build()
    eng = ex.engine
    assert eng.events is not None and eng.obs is not None
    begun, rounds, done = [], [], []
    eng.events.subscribe("round_begin", begun.append)
    eng.events.subscribe("round", rounds.append)
    eng.events.subscribe("job_done", done.append)
    ex.run()
    assert len(begun) == len(rounds) > 0
    assert [d["job"] for d in done] == [0]
    assert all(b["est_cost"] is not None or True for b in begun)
    assert all(r.job == 0 for r in rounds)   # RoundRecord payloads


# ---- recompile counter ----


def test_runtime_recompile_counter_matches_jit_probe():
    from repro.config.base import JobConfig
    from repro.configs.paper_models import lenet5
    from repro.data.synthetic import make_classification_dataset
    from repro.fl.partition import noniid_partition
    from repro.fl.runtime import FusedMultiRuntime, _fused_group_round

    cfg = dataclasses.replace(
        lenet5(), name="tiny-obs", input_shape=(8, 8, 1),
        cnn_spec=(("flatten",), ("fc", 8)))
    x, y = make_classification_dataset(600, cfg.input_shape, cfg.num_classes,
                                       noise=1.0, seed=0)
    ex, ey = make_classification_dataset(60, cfg.input_shape, cfg.num_classes,
                                         noise=1.0, seed=1)
    part = noniid_partition(y, 12, seed=0)
    job = JobConfig(job_id=0, model=cfg, target_metric=2.0,
                    local_epochs=1, batch_size=4, lr=0.05)
    fused = FusedMultiRuntime([job], [(x, y, part, ex, ey)], seed=0,
                              buckets=(4, 8, 12))
    assert fused.recompiles == 0
    before = _fused_group_round._cache_size()
    rng = np.random.default_rng(5)
    for r in range(10):
        n = int(rng.integers(1, 13))
        fused.run_round(0, rng.choice(12, n, replace=False), r)
    assert fused.recompiles == _fused_group_round._cache_size() - before > 0


# ---- report CLI ----


def _fake_trace(tmp_path, name="trace.json", p50_scale=1.0):
    evs = [{"name": "engine_run", "ph": "X", "ts": 0.0, "dur": 4000.0,
            "pid": 1, "tid": 1, "args": {}}]
    for i in range(4):
        for phase in rpt.ENGINE_PHASES:
            evs.append({"name": phase, "ph": "X", "ts": i * 1000.0,
                        "dur": 190.0 * p50_scale, "pid": 1, "tid": 1,
                        "args": {"job": 0}})
    evs.append({"name": "jit_recompiles", "ph": "C", "ts": 500.0, "pid": 1,
                "tid": 1, "args": {"jit_recompiles": 3}})
    p = tmp_path / name
    p.write_text(json.dumps({"traceEvents": evs}))
    return str(p)


def test_report_cli_smoke(tmp_path, capsys):
    p = _fake_trace(tmp_path)
    out_json = tmp_path / "report.json"
    assert monitoring_cli(["report", p, "--json", str(out_json)]) == 0
    out = capsys.readouterr().out
    assert "engine_run" in out and "recompiles=3" in out
    assert "coverage" in out
    rep = json.load(open(out_json))
    assert rep["recompiles"] == 3
    assert rep["coverage"] == pytest.approx(0.95)
    # empty trace -> exit 1
    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"traceEvents": []}))
    assert monitoring_cli(["report", str(empty)]) == 1


def test_report_cli_diff_and_check_bench(tmp_path, capsys):
    a = _fake_trace(tmp_path, "a.json")
    b = _fake_trace(tmp_path, "b.json", p50_scale=2.0)
    assert monitoring_cli(["report", a, "--diff", b]) == 0
    assert "ratio" in capsys.readouterr().out

    stats_a = rpt.phase_stats(rpt.load_trace(a))
    bench = tmp_path / "BENCH_obs.json"
    bench.write_text(json.dumps({"phases": stats_a, "gate": {"failures": []}}))
    # a vs its own baseline: clean
    assert monitoring_cli(["report", a, "--check-bench", str(bench)]) == 0
    # b is 2x slower than the baseline: regression at 50% tolerance
    assert monitoring_cli(["report", b, "--check-bench", str(bench)]) == 1
    assert "REGRESSIONS" in capsys.readouterr().out
    # recorded gate failures surface even when phases compare clean
    bench.write_text(json.dumps(
        {"phases": stats_a, "gate": {"failures": ["boom"]}}))
    assert monitoring_cli(["report", a, "--check-bench", str(bench)]) == 1


def test_check_bench_skips_engine_run_root(tmp_path):
    base = {"engine_run": {"p50_ms": 1.0}}
    stats = rpt.phase_stats(rpt.load_trace(_fake_trace(tmp_path)))
    assert rpt.check_bench(stats, [], tolerance=0.5) == []
    bench = tmp_path / "BENCH_x.json"
    bench.write_text(json.dumps({"phases": base}))
    # 4000ms vs 1ms baseline — ignored: the root scales with workload size
    assert rpt.check_bench(stats, [str(bench)], tolerance=0.5) == []
