"""Online scheduler-service tests: traffic generation, admission control,
incremental-vs-full rescoring parity, dynamic engine job sets, pool churn
invalidation, and scheduler warm hand-off across retire/readmit."""

import json

import numpy as np
import pytest

from repro.config.base import ArchFamily, JobConfig, ModelConfig
from repro.core.cost import CostModel
from repro.core.devices import DevicePool
from repro.core.multijob import MultiJobEngine
from repro.core.schedulers import get_scheduler
from repro.core.schedulers.base import SchedulingContext
from repro.experiment.presets import get_preset
from repro.experiment.spec import ArrivalsSpec, ExperimentSpec
from repro.fl.runtime import SyntheticRuntime
from repro.serve import (SchedulerService, load_trace, poisson_trace,
                         save_trace, trace_from_spec)
from repro.serve.metrics import LatencyStats, jain_fairness


def small_spec(**kw):
    """A CI-sized online spec (fast scheduler, short horizon)."""
    kw = {"scheduler": "greedy", "num_devices": 30, "horizon": 6_000.0,
          "interarrival": 600.0, "max_concurrent": 2, **kw}
    return get_preset("online-smoke", **kw)


# ---- traffic -------------------------------------------------------------

def test_trace_deterministic_in_seed():
    arr = ArrivalsSpec(seed=7, horizon=10_000.0, interarrival=500.0,
                       mean_lifetime=2_000.0, readmit_prob=0.5,
                       churn_interarrival=3_000.0)
    t1 = poisson_trace(arr, num_templates=2, num_devices=40)
    t2 = poisson_trace(arr, num_templates=2, num_devices=40)
    assert [e.to_dict() for e in t1] == [e.to_dict() for e in t2]
    t3 = poisson_trace(ArrivalsSpec(**{**arr.__dict__, "seed": 8}), 2, 40)
    assert [e.to_dict() for e in t1] != [e.to_dict() for e in t3]


def test_trace_sorted_and_well_formed():
    arr = ArrivalsSpec(seed=0, horizon=20_000.0, interarrival=800.0,
                       mean_lifetime=2_500.0, readmit_prob=0.5,
                       churn_interarrival=4_000.0, churn_fraction=0.05)
    trace = poisson_trace(arr, num_templates=3, num_devices=60)
    assert trace, "horizon/interarrival must produce events"
    times = [e.t for e in trace]
    assert times == sorted(times)
    arrives = [e for e in trace if e.kind == "arrive"]
    assert all(e.tenant and e.template in (0, 1, 2) for e in arrives)
    # Every depart names a tenant that arrived earlier.
    seen = set()
    for e in trace:
        if e.kind == "arrive":
            seen.add(e.tenant)
        elif e.kind == "depart":
            assert e.tenant in seen
    # Churn comes in out/in pairs over the same device set.
    outs = [tuple(e.devices) for e in trace if e.kind == "churn_out"]
    ins = [tuple(e.devices) for e in trace if e.kind == "churn_in"]
    assert sorted(outs) == sorted(ins) and len(outs) > 0


def test_trace_json_roundtrip(tmp_path):
    arr = ArrivalsSpec(seed=1, horizon=8_000.0, interarrival=700.0,
                       mean_lifetime=2_000.0, churn_interarrival=3_000.0,
                       drift=1.5)
    trace = poisson_trace(arr, num_templates=2, num_devices=30)
    path = str(tmp_path / "trace.json")
    save_trace(trace, path)
    back = load_trace(path)
    assert [e.to_dict() for e in back] == [e.to_dict() for e in trace]
    # trace mode replays the file verbatim
    arr2 = ArrivalsSpec(mode="trace", trace_path=path)
    replay = trace_from_spec(arr2, 2, 30)
    assert [e.to_dict() for e in replay] == [e.to_dict() for e in trace]


# ---- spec axis -----------------------------------------------------------

def test_spec_arrivals_axis_roundtrip():
    spec = small_spec()
    assert spec.arrivals is not None
    d = spec.to_dict()
    back = ExperimentSpec.from_dict(d)
    assert back.arrivals == spec.arrivals
    # nested replace merges into the existing ArrivalsSpec
    spec2 = spec.replace(arrivals={"horizon": 123.0})
    assert spec2.arrivals.horizon == 123.0
    assert spec2.arrivals.interarrival == spec.arrivals.interarrival


# ---- metrics -------------------------------------------------------------

def test_latency_stats_and_jain():
    ls = LatencyStats()
    for v in [0.01, 0.02, 0.03, 0.04]:
        ls.add(v)
    assert ls.count == 4
    assert 0.01 <= ls.p50 <= 0.04 and ls.p99 <= 0.04 + 1e-9
    assert jain_fairness([1.0, 1.0, 1.0]) == pytest.approx(1.0)
    assert jain_fairness([1.0, 0.0, 0.0]) == pytest.approx(1.0 / 3.0)
    assert jain_fairness([]) == 1.0


# ---- the service end to end ---------------------------------------------

def test_service_end_to_end_sustains_traffic():
    spec = small_spec()
    svc = SchedulerService(spec)
    report = svc.run()
    assert report.arrivals > 0 and report.rounds_completed > 0
    assert svc.metrics.decisions == sum(
        t.admissions for t in svc.metrics.tenants.values())
    # every admitted tenant's rounds were attributed (even tenants whose
    # in-flight round finished after retirement)
    admitted = [t for t in svc.metrics.tenants.values() if t.admissions]
    assert sum(t.rounds for t in admitted) == report.rounds_completed
    # parked catalogue templates never execute and never appear in summary
    summ = svc.engine.summary()
    live = [js for js in svc.engine.jobs if not js.parked]
    assert len(summ) == len(live) and len(live) > 0
    assert not any(r.job < len(svc.templates) for r in svc.engine.records)
    d = report.to_dict()
    assert json.loads(report.to_json())["arrivals"] == d["arrivals"]


def test_service_respects_admission_budget():
    spec = small_spec(interarrival=300.0)  # oversubscribed on purpose
    svc = SchedulerService(spec)
    peak = {"live": 0}
    orig = svc._admit

    def counting_admit(tenant, template, now):
        orig(tenant, template, now)
        peak["live"] = max(peak["live"], len(svc._live))

    svc._admit = counting_admit
    report = svc.run()
    assert peak["live"] <= spec.arrivals.max_concurrent
    assert report.rejections > 0 and report.queue_depth_max > 0


def test_service_readmission_uses_saved_state():
    spec = small_spec(scheduler="bods", interarrival=500.0)
    svc = SchedulerService(spec)
    report = svc.run()
    assert report.readmissions > 0
    # a readmitted tenant got a FRESH job id; ids are never reused
    jobs = list(svc._job_tenant)
    assert len(jobs) == len(set(jobs))


def test_incremental_and_full_rescoring_execute_identically():
    spec = small_spec(scheduler="bods", horizon=4_000.0)
    probe = SchedulerService(spec)
    trace = trace_from_spec(spec.arrivals, len(probe.templates),
                            probe.engine.pool.num_devices)
    runs = {}
    for mode in ("incremental", "full"):
        svc = SchedulerService(spec, rescore_mode=mode)
        svc.run(trace)
        runs[mode] = [(r.job, r.round_idx, r.cost, tuple(r.device_ids))
                      for r in svc.engine.records]
    assert runs["incremental"] == runs["full"]


def test_service_requires_arrivals_axis():
    spec = small_spec().replace(arrivals=None)
    with pytest.raises(ValueError, match="arrivals"):
        SchedulerService(spec)
    with pytest.raises(ValueError, match="rescore_mode"):
        SchedulerService(small_spec(), rescore_mode="bogus")


# ---- engine dynamic job set ---------------------------------------------

def _tiny_engine(n_jobs=2, sched="greedy", max_rounds=8):
    mc = ModelConfig(name="t", family=ArchFamily.CNN, cnn_spec=(("flatten",),),
                     input_shape=(4, 4, 1), num_classes=10)
    jobs = [JobConfig(job_id=i, model=mc, target_metric=0.99,
                      max_rounds=max_rounds) for i in range(n_jobs)]
    pool = DevicePool.heterogeneous(30, n_jobs, seed=3)
    cm = CostModel(pool, alpha=4.0, beta=0.25)
    cm.calibrate([5.0] * n_jobs, n_sel=4)
    s = get_scheduler(sched, cost_model=cm, seed=0)
    rt = SyntheticRuntime(num_jobs=n_jobs, num_devices=30, seed=2)
    return MultiJobEngine(jobs, pool, cm, s, rt, n_sel=4)


def test_engine_add_job_mid_run():
    eng = _tiny_engine()
    for j in range(2):
        eng._launch(j, 0.0)
    eng.advance_until(eng._heap[0][0])  # complete the first round
    assert eng.clock > 0.0
    mc = eng.jobs[0].config.model
    cfg = JobConfig(job_id=2, model=mc, target_metric=0.99, max_rounds=4)
    job = eng.add_job(cfg, now=eng.clock)
    assert job == 2
    assert eng.pool.num_jobs == 3 and eng.counts.shape[0] == 3
    eng.run()  # drains everything
    summ = {k: v for k, v in eng.summary().items()}
    s0, s2 = summ["t"], summ["t#2"]  # keyed by model name (+#job on clash)
    assert s2["rounds"] >= 1
    assert s2["admitted_at"] > 0.0
    # unequal lifetimes: late job still summarized correctly
    assert s0["rounds"] == 8 and s2["rounds"] <= 4


def test_engine_retire_job_mid_run():
    eng = _tiny_engine(max_rounds=50)
    for j in range(2):
        eng._launch(j, 0.0)
    eng.advance_until(eng.clock + 1.0)
    assert eng.retire_job(1, now=eng.clock)
    assert not eng.retire_job(1, now=eng.clock)  # already retired
    eng.run()
    summ = eng.summary()
    s0, s1 = summ["t"], summ["t#1"]
    assert s1["retired"] and not s0["retired"]
    # the retired job's in-flight round completed but no new one launched
    assert s1["rounds"] < s0["rounds"]
    assert all(r.job != 1 or r.t_start <= eng.jobs[1].retired_at
               for r in eng.records)


def test_engine_done_callback_fires():
    eng = _tiny_engine(max_rounds=3)
    done = []
    eng.on_job_done = lambda job, now: done.append(job)
    eng.run()
    assert sorted(done) == [0, 1]


# ---- pool churn + cache invalidation (the stale-cache regression) --------

def test_pool_set_capabilities_invalidates_time_cache():
    pool = DevicePool.heterogeneous(20, 2, seed=0)
    before = pool.expected_times(0, 5.0).copy()
    v0 = pool.version
    # RAW writes bypass invalidation — this is the documented hazard the
    # mutator API exists to close: the memo keeps serving stale times.
    pool.a = pool.a.copy()
    pool.a[:5] *= 10.0
    np.testing.assert_array_equal(pool.expected_times(0, 5.0), before)
    # The mutator refreshes the memo and bumps the version.
    pool.set_capabilities(np.arange(5), a=pool.a[:5])
    after = pool.expected_times(0, 5.0)
    assert pool.version > v0
    assert (after[:5] > before[:5]).all()
    np.testing.assert_allclose(after[5:], before[5:])


def test_pool_depart_rejoin_roundtrip():
    pool = DevicePool.heterogeneous(20, 2, seed=1)
    base = pool.expected_times(0, 5.0).copy()
    pool.depart([3, 7])
    # membership churn rides on occupancy: departed devices are busy forever
    assert np.isinf(pool.busy_until[[3, 7]]).all()
    pool.rejoin([3, 7])
    assert np.isfinite(pool.busy_until[[3, 7]]).all()
    np.testing.assert_allclose(pool.expected_times(0, 5.0), base)
    # drifted rejoin changes the rejoined device's time model only
    pool.depart([3])
    pool.rejoin([3], a=pool.a[[3]] * 2.0)
    t2 = pool.expected_times(0, 5.0)
    assert t2[3] > base[3]
    np.testing.assert_allclose(np.delete(t2, 3), np.delete(base, 3))


def test_pool_add_job_grows_data_columns():
    pool = DevicePool.heterogeneous(15, 2, seed=2)
    col = pool.data_sizes[:, 1].copy()
    j = pool.add_job(col * 2.0)
    assert j == 2 and pool.num_jobs == 3
    np.testing.assert_allclose(
        pool.expected_times(2, 5.0), 2.0 * pool.expected_times(1, 5.0))


# ---- scheduler warm hand-off (retire -> readmit under a new job id) ------

LEARNERS = {
    "bods": {"num_candidates": 64, "init_points": 4},
    "rlds": {"pretrain_rounds": 0},
    "dnn": {"num_candidates": 64},
}


@pytest.mark.parametrize("name", sorted(LEARNERS))
def test_warm_handoff_identical_next_decision(name):
    """Transplanting a retired job's per-job state under a NEW job id (the
    service's readmission path) must reproduce the exact next decision the
    uninterrupted scheduler would have made."""
    pool = DevicePool.heterogeneous(24, 2, seed=5)
    cm = CostModel(pool, alpha=4.0, beta=0.25)
    cm.calibrate([5.0, 5.0], n_sel=4)
    sched = get_scheduler(name, cost_model=cm, seed=0, **LEARNERS[name])

    def ctx(job, r, counts):
        return SchedulingContext(
            job=job, round_idx=r, tau=5.0, n_sel=4,
            available=np.ones(24, dtype=bool), counts=counts.copy(),
            expected_times=pool.expected_times(job, 5.0))

    counts = np.zeros((2, 24))
    for r in range(3):  # give the learners per-job history
        for j in (0, 1):
            c = ctx(j, r, counts[j])
            plan = sched.schedule(c)
            sched.observe(c, plan, float(sched.last_estimated_cost or 1.0))
            counts[j] += plan

    snap = sched.snapshot()
    plan_uninterrupted = sched.schedule(ctx(1, 3, counts[1]))

    # Retire job 1, readmit as job 2: fresh pool column (same data), grown
    # scheduler state, per-job slice transplanted, rng pinned via restore.
    sched.restore(snap)
    saved = sched.job_state_dict(1)
    pool.add_job(pool.data_sizes[:, 1].copy())
    sched.ensure_jobs(3)
    sched.load_job_state(2, saved)
    plan_readmitted = sched.schedule(ctx(2, 3, counts[1]))

    np.testing.assert_array_equal(plan_uninterrupted, plan_readmitted)


def test_snapshot_restore_pins_rng():
    pool = DevicePool.heterogeneous(24, 2, seed=5)
    cm = CostModel(pool, alpha=4.0, beta=0.25)
    cm.calibrate([5.0, 5.0], n_sel=4)
    sched = get_scheduler("random", cost_model=cm, seed=0)
    c = SchedulingContext(job=0, round_idx=0, tau=5.0, n_sel=4,
                          available=np.ones(24, dtype=bool),
                          counts=np.zeros(24),
                          expected_times=pool.expected_times(0, 5.0))
    snap = sched.snapshot()
    p1 = sched.schedule(c)
    p2 = sched.schedule(c)
    sched.restore(snap)
    np.testing.assert_array_equal(sched.schedule(c), p1)
    np.testing.assert_array_equal(sched.schedule(c), p2)


# ---- CLI -----------------------------------------------------------------

def test_cli_smoke(tmp_path, capsys):
    from repro.serve.__main__ import main

    out = tmp_path / "report.json"
    trace = tmp_path / "trace.json"
    main(["--preset", "online-smoke", "--arg", "horizon=3000",
          "--arg", "num_devices=30", "--arg", "scheduler=greedy",
          "--save-trace", str(trace), "--out", str(out)])
    rep = json.loads(out.read_text())
    assert rep["rounds_completed"] > 0
    assert trace.exists()
    assert "latency" in capsys.readouterr().out
