"""Federated-substrate tests: FedAvg, compressed aggregation, local training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import JobConfig
from repro.configs.paper_models import lenet5, cnn_b
from repro.data.synthetic import make_classification_dataset
from repro.fl.aggregation import (fedavg, fedavg_compressed,
                                  fedavg_compressed_loop)
from repro.fl.partition import iid_partition
from repro.fl.runtime import FLJobRuntime, _local_train_one
from repro.models.cnn_zoo import cnn_init, cnn_loss_and_accuracy


def test_fedavg_is_weighted_mean():
    stacked = {"w": jnp.asarray([[1.0, 2.0], [3.0, 4.0], [5.0, 6.0]])}
    weights = jnp.asarray([1.0, 1.0, 2.0])
    out = fedavg(stacked, weights)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               [(1 + 3 + 2 * 5) / 4, (2 + 4 + 2 * 6) / 4])


def test_fedavg_compressed_full_ratio_equals_fedavg():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(0, 1, (6, 3)))}
    stacked = {"w": jnp.stack([g["w"] + i for i in range(3)])}
    weights = jnp.asarray([1.0, 2.0, 1.0])
    exact = fedavg(stacked, weights)
    comp = fedavg_compressed(g, stacked, weights, ratio=1.0)
    np.testing.assert_allclose(np.asarray(exact["w"]), np.asarray(comp["w"]),
                               atol=1e-6)


def _random_pytree_stack(rng, n_dev):
    """Multi-leaf pytree with a leading device axis, shaped like CNN params."""
    g = [{"w": jnp.asarray(rng.normal(0, 1, (5, 5, 1, 4))),
          "b": jnp.asarray(rng.normal(0, 1, (4,)))},
         {"w": jnp.asarray(rng.normal(0, 1, (36, 10))),
          "b": jnp.asarray(rng.normal(0, 1, (10,)))}]
    stacked = jax.tree_util.tree_map(
        lambda leaf: jnp.stack([leaf + 0.1 * rng.normal(0, 1, leaf.shape)
                                for _ in range(n_dev)]), g)
    return g, stacked


@pytest.mark.parametrize("ratio", [0.1, 0.33, 1.0])
def test_fedavg_compressed_matches_loop(ratio):
    """The vectorized scatter-add path must reproduce the historical
    per-device Python loop it replaced."""
    rng = np.random.default_rng(3)
    g, stacked = _random_pytree_stack(rng, n_dev=5)
    weights = jnp.asarray(rng.uniform(0.5, 2.0, 5))
    old = fedavg_compressed_loop(g, stacked, weights, ratio)
    new = fedavg_compressed(g, stacked, weights, ratio)
    for a, b in zip(jax.tree_util.tree_leaves(old),
                    jax.tree_util.tree_leaves(new)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


def test_fedavg_compressed_pallas_interpret_matches_ref():
    rng = np.random.default_rng(4)
    g, stacked = _random_pytree_stack(rng, n_dev=3)
    weights = jnp.asarray([1.0, 2.0, 0.5])
    a = fedavg_compressed(g, stacked, weights, 0.25, impl="ref")
    b = fedavg_compressed(g, stacked, weights, 0.25, impl="interpret")
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-5, rtol=1e-5)


def test_local_training_reduces_local_loss():
    cfg = cnn_b()
    x, y = make_classification_dataset(128, cfg.input_shape, cfg.num_classes,
                                       noise=1.0, seed=0)
    x, y = jnp.asarray(x), jnp.asarray(y)
    params = cnn_init(cfg, seed=0)
    l0, _ = cnn_loss_and_accuracy(params, cfg, x, y)
    p1 = _local_train_one(params, cfg, x, y, 3, 32, 0.05)
    l1, _ = cnn_loss_and_accuracy(p1, cfg, x, y)
    assert float(l1) < float(l0)


def test_local_train_width0_shard_is_identity():
    """A device holding zero samples must return its params unchanged
    instead of crashing on a zero-row gather/reshape."""
    cfg = cnn_b()
    params = cnn_init(cfg, seed=0)
    x = jnp.zeros((0,) + cfg.input_shape, jnp.float32)
    y = jnp.zeros((0,), jnp.int32)
    out = _local_train_one(params, cfg, x, y, 3, 32, 0.05)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fl_runtime_round_improves_accuracy_iid():
    cfg = lenet5()
    x, y = make_classification_dataset(4000, cfg.input_shape, cfg.num_classes,
                                       noise=1.0, seed=0)
    ex, ey = make_classification_dataset(500, cfg.input_shape, cfg.num_classes,
                                         noise=1.0, seed=99)
    part = iid_partition(y, 30, 128, seed=1)
    job = JobConfig(job_id=0, model=cfg, target_metric=0.9,
                    local_epochs=2, batch_size=32, lr=0.03)
    rt = FLJobRuntime(job, x, y, part, ex, ey)
    m0 = rt.run_round(0, np.arange(8), 0)
    m1 = rt.run_round(0, np.arange(8, 16), 1)
    m2 = rt.run_round(0, np.arange(16, 24), 2)
    assert m2["accuracy"] > max(0.3, m0["accuracy"] * 0.9)
