"""Checkpoint tests: roundtrip, atomicity, GC, pipeline-state restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint


@pytest.fixture
def tree():
    return {"layer": {"w": jnp.arange(12.0).reshape(3, 4),
                      "b": jnp.ones((4,), jnp.bfloat16)},
            "opt": (jnp.zeros(3), jnp.asarray(7, jnp.int32))}


def test_roundtrip(tmp_path, tree):
    save_checkpoint(str(tmp_path), 5, tree, extra={"cursor": 42})
    step, restored, extra = load_checkpoint(str(tmp_path), tree)
    assert step == 5 and extra == {"cursor": 42}
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32)), tree, restored)
    # dtypes preserved
    assert restored["layer"]["b"].dtype == jnp.bfloat16


def test_partial_saves_invisible(tmp_path, tree):
    save_checkpoint(str(tmp_path), 1, tree)
    # fake a crashed save: step dir without the commit marker
    os.makedirs(tmp_path / "step_0000000009")
    with open(tmp_path / "step_0000000009" / "manifest.json", "w") as f:
        f.write("{}")
    step, _, _ = load_checkpoint(str(tmp_path), tree)
    assert step == 1  # the uncommitted step 9 is ignored


def test_manager_keeps_last_n(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_0000000003", "step_0000000004"]
    assert mgr.latest_step() == 4


def test_manager_gc_partial_on_init(tmp_path, tree):
    save_checkpoint(str(tmp_path), 1, tree)
    os.makedirs(tmp_path / ".tmp_step_9_abc")
    CheckpointManager(str(tmp_path))
    assert not any(n.startswith(".tmp_") for n in os.listdir(tmp_path))


def test_restore_missing_raises(tmp_path, tree):
    with pytest.raises(FileNotFoundError):
        load_checkpoint(str(tmp_path / "nope"), tree)


def test_manager_keep3_gc_under_repeated_saves(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    for s in range(1, 9):
        mgr.save(s, tree)
        steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                       if n.startswith("step_"))
        assert steps == list(range(max(1, s - 2), s + 1))
    assert mgr.latest_step() == 8


def _corrupt(tmp_path, step, what="arrays"):
    d = tmp_path / f"step_{step:010d}"
    if what == "arrays":
        with open(d / "arrays.npz", "wb") as f:
            f.write(b"not a zipfile")      # torn npz
    elif what == "manifest":
        with open(d / "manifest.json", "w") as f:
            f.write('{"step": ')           # truncated JSON
    else:
        os.remove(d / "arrays.npz")        # file lost entirely


@pytest.mark.parametrize("what", ["arrays", "manifest", "missing"])
def test_restore_falls_back_past_corrupt_latest(tmp_path, tree, what):
    save_checkpoint(str(tmp_path), 1, tree, extra={"cursor": 1})
    save_checkpoint(str(tmp_path), 2, tree, extra={"cursor": 2})
    _corrupt(tmp_path, 2, what)
    with pytest.warns(UserWarning, match="unreadable"):
        step, restored, extra = load_checkpoint(str(tmp_path), tree)
    assert step == 1 and extra == {"cursor": 1}
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32)),
        tree, restored)


def test_restore_explicit_corrupt_step_still_raises(tmp_path, tree):
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, tree)
    _corrupt(tmp_path, 2, "arrays")
    with pytest.raises(Exception):
        load_checkpoint(str(tmp_path), tree, step=2)


def test_restore_all_corrupt_raises(tmp_path, tree):
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, tree)
    _corrupt(tmp_path, 1, "manifest")
    _corrupt(tmp_path, 2, "arrays")
    with pytest.warns(UserWarning), pytest.raises(FileNotFoundError,
                                                  match="unreadable"):
        load_checkpoint(str(tmp_path), tree)
