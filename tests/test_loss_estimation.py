"""Loss-curve fitting (paper Formula 13) tests."""

import numpy as np
import pytest

from repro.core.loss_estimation import fit_loss_curve, rounds_to_target


def test_fit_recovers_synthetic_curve():
    b0, b1, b2 = 0.05, 0.4, 0.3
    r = np.arange(1, 60)
    loss = 1.0 / (b0 * r + b1) + b2
    fb0, fb1, fb2 = fit_loss_curve(r, loss)
    est = 1.0 / (fb0 * r + fb1) + fb2
    np.testing.assert_allclose(est, loss, rtol=0.08)


def test_rounds_to_target_monotone_in_target():
    b0, b1, b2 = 0.05, 0.4, 0.3
    r_easy = rounds_to_target(b0, b1, b2, target_loss=1.0)
    r_hard = rounds_to_target(b0, b1, b2, target_loss=0.5)
    assert r_hard > r_easy


def test_rounds_to_target_includes_safety_margin():
    b0, b1, b2 = 0.05, 0.4, 0.0
    target = 0.5
    rc = (1.0 / target - b1) / b0
    assert rounds_to_target(b0, b1, b2, target) == pytest.approx(
        np.ceil(1.3 * rc), abs=1)


def test_unreachable_target_caps_at_max():
    assert rounds_to_target(0.05, 0.4, 0.3, target_loss=0.2,
                            max_rounds=500) == 500
