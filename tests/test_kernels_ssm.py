"""Chunked linear-scan kernel vs sequential-oracle sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ssm_scan import linear_scan

CASES = [
    (2, 128, 2, 16, 32, 32),
    (1, 256, 4, 32, 64, 64),
    (2, 64, 1, 8, 8, 16),
    (1, 128, 3, 16, 48, 128),   # single chunk == whole sequence
]


@pytest.mark.parametrize("B,S,H,Dk,Dv,chunk", CASES)
def test_scan_matches_oracle(B, S, H, Dk, Dv, chunk):
    rng = np.random.default_rng(hash((B, S, H, Dk)) % 2**31)
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, Dk)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 0.5, (B, S, H, Dk)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, H, Dv)), jnp.float32)
    a = jnp.asarray(rng.uniform(0.6, 1.0, (B, S, H)), jnp.float32)
    y, (Sf, nf) = linear_scan(q, k, v, a, chunk=chunk, interpret=True)
    ye, (Se, ne) = ref.linear_scan(q, k, v, a)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(Sf), np.asarray(Se), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(nf), np.asarray(ne), atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("B,S,H,Dk,Dv,chunk", [
    (2, 128, 2, 16, 32, 32), (1, 256, 3, 8, 24, 128), (2, 64, 1, 8, 8, 64)])
def test_chunked_jnp_matches_oracle(B, S, H, Dk, Dv, chunk):
    """linear_scan_chunked (the data-plane default) vs the sequential oracle."""
    rng = np.random.default_rng(hash((B, S, chunk)) % 2**31)
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, Dk)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 0.5, (B, S, H, Dk)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, H, Dv)), jnp.float32)
    a = jnp.asarray(rng.uniform(0.5, 1.0, (B, S, H)), jnp.float32)
    y, (Sf, nf) = ref.linear_scan_chunked(q, k, v, a, chunk=chunk)
    ye, (Se, ne) = ref.linear_scan(q, k, v, a)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(Sf), np.asarray(Se), atol=3e-4, rtol=3e-4)


def test_scan_strong_decay_stability():
    """Near-zero decays underflow naive cumprod ratios; log-space must hold."""
    rng = np.random.default_rng(5)
    B, S, H, Dk, Dv = 1, 128, 2, 8, 16
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, Dk)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 0.5, (B, S, H, Dk)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, H, Dv)), jnp.float32)
    a = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    y, _ = linear_scan(q, k, v, a, chunk=32, interpret=True)
    ye, _ = ref.linear_scan(q, k, v, a)
    assert bool(jnp.isfinite(y).all())
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=2e-4, rtol=2e-4)


def test_decode_step_continues_prefill():
    """linear_scan final state + one linear_scan_step == oracle over S+1."""
    rng = np.random.default_rng(9)
    B, S, H, Dk, Dv = 2, 64, 2, 8, 16
    mk = lambda *s: jnp.asarray(rng.normal(0, 0.5, s), jnp.float32)
    q, k = mk(B, S + 1, H, Dk), mk(B, S + 1, H, Dk)
    v = mk(B, S + 1, H, Dv)
    a = jnp.asarray(rng.uniform(0.6, 1.0, (B, S + 1, H)), jnp.float32)
    y_all, _ = ref.linear_scan(q, k, v, a)
    _, state = linear_scan(q[:, :S], k[:, :S], v[:, :S], a[:, :S],
                           chunk=32, interpret=True)
    y_step, _ = ref.linear_scan_step(q[:, S], k[:, S], v[:, S], a[:, S], state)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_all[:, S]),
                               atol=2e-4, rtol=2e-4)
