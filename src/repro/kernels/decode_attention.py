"""Decode attention Pallas kernel: one new token vs a long KV cache.

Decode is memory-bound: the whole KV cache streams through VMEM once per
step. Grid (B, KV, T/BK), KV-block axis innermost; the G = H/KV queries that
share a kv-head ride together as a (G, D) tile so the cache is read ONCE per
kv-head (the GQA bandwidth win — a per-q-head layout would read it G times).
Online softmax in f32 scratch, masked by the per-sequence ``length``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale: float, block_k: int, n_k: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                       # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)                    # (BK, D)
    v = v_ref[0, :, 0].astype(jnp.float32)                    # (BK, D)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale           # (G, BK)

    t_pos = ti * block_k + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 1)
    valid = t_pos < len_ref[0]
    logits = jnp.where(valid, logits, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
    p = jnp.exp(logits - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(ti == n_k - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     length: jnp.ndarray, block_k: int = 512,
                     interpret: bool = False) -> jnp.ndarray:
    """q: (B,H,D); caches: (B,T,KV,D); length: (B,) -> (B,H,D)."""
    B, H, D = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    bk = min(block_k, T)
    while T % bk:
        bk //= 2
    grid = (B, KV, T // bk)
    scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, KV, G, D)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_k=bk, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, t: (b,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda b, h, t: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, t: (b, t, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, t: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(length.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(B, H, D)
