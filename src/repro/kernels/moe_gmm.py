"""Grouped expert matmul (MoE) Pallas kernel.

xg: (E, C, din) bucketed tokens; wg: (E, din, dout) expert weights
-> (E, C, dout). Grid (E, C/BC, dout/BD, din/BK): the din axis is the
innermost (sequential) grid dim, accumulating partial products into an f32
VMEM scratch tile and flushing on the last k-step — the canonical TPU MXU
tiling (every tile dim a multiple of 128 where shapes allow).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0], w_ref[0], preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _tile(n: int, target: int) -> int:
    t = min(target, n)
    while n % t:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("block_c", "block_d", "block_k",
                                             "interpret"))
def moe_gmm(xg: jnp.ndarray, wg: jnp.ndarray, block_c: int = 128,
            block_d: int = 256, block_k: int = 512,
            interpret: bool = False) -> jnp.ndarray:
    E, C, din = xg.shape
    dout = wg.shape[-1]
    bc = _tile(C, block_c)
    bd = _tile(dout, block_d)
    bk = _tile(din, block_k)
    grid = (E, C // bc, dout // bd, din // bk)
    return pl.pallas_call(
        functools.partial(_gmm_kernel, n_k=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bk), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bk, bd), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bd), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, dout), xg.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bd), jnp.float32)],
        interpret=interpret,
    )(xg, wg)
