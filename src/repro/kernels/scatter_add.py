"""Tiled Pallas kernel for weighted scatter-add (compressed FedAvg).

The server-side decompression of top-k sparsified device deltas is, per
parameter leaf, ``out[idx[i, j]] += w[i] * vals[i, j]`` over all devices i
and their k kept entries j — a weighted scatter-add into a flat (size,)
accumulator. The historical implementation materialized one DENSE leaf per
device (n x size floats) and summed them in a Python loop; this kernel never
builds the dense per-device tensors at all.

TPU has no efficient arbitrary scatter, so the kernel inverts the access
pattern the same way kernels/sched_score.py does: the OUTPUT axis is tiled
(BLOCK_S lanes per program) and the (n*k,) value/index stream is tiled along
the accumulation grid dimension. Each program builds a one-hot hit matrix
``idx_tile == out_position`` and folds the weighted values with a single
(1, BK) x (BK, BS) MXU matmul — contributions land in registers, the (n*k,
size) one-hot never exists in memory either. Padding positions are -1 and
can never match a non-negative output lane.

Off-TPU callers go through ``repro.kernels.ops.scatter_add`` which falls
back to the jnp oracle in kernels/ref.py (identical semantics, tested to
1e-5 against this kernel in interpret mode).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SUBLANES = 8  # f32 min tile height; row 0 carries the result


def _scatter_kernel(idx_ref, wv_ref, out_ref, *, block_s: int):
    s_idx = pl.program_id(0)
    k_idx = pl.program_id(1)
    bk = idx_ref.shape[1]

    idx = idx_ref[...].reshape(bk, 1)                  # (BK, 1) int32
    wv = wv_ref[...].astype(jnp.float32)               # (1, BK)
    base = s_idx * block_s
    cols = base + jax.lax.broadcasted_iota(jnp.int32, (bk, block_s), 1)
    onehot = (idx == cols).astype(jnp.float32)         # (BK, BS)
    contrib = jnp.dot(wv, onehot,
                      preferred_element_type=jnp.float32)  # (1, BS)

    row = jax.lax.broadcasted_iota(jnp.int32, out_ref.shape, 0)

    @pl.when(k_idx == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.where(row == 0, contrib, 0.0)


@functools.partial(jax.jit,
                   static_argnames=("size", "block_s", "block_k", "interpret"))
def scatter_add(vals: jnp.ndarray, idx: jnp.ndarray, weights: jnp.ndarray,
                size: int, block_s: int = 256, block_k: int = 512,
                interpret: bool = False) -> jnp.ndarray:
    """(n, k) vals, (n, k) int32 idx, (n,) weights -> (size,) f32.

    out[p] = sum_{i,j: idx[i,j] == p} weights[i] * vals[i,j]. Negative
    indices are padding (never accumulated).
    """
    n, k = vals.shape
    wv = (vals.astype(jnp.float32)
          * weights.astype(jnp.float32)[:, None]).reshape(1, n * k)
    flat_idx = idx.astype(jnp.int32).reshape(1, n * k)

    bs = min(block_s, max(128, size))
    bk = min(block_k, max(128, n * k))
    pad_s = (-size) % bs
    pad_k = (-(n * k)) % bk
    if pad_k:
        wv = jnp.pad(wv, ((0, 0), (0, pad_k)))
        flat_idx = jnp.pad(flat_idx, ((0, 0), (0, pad_k)),
                           constant_values=-1)
    s_pad = size + pad_s
    grid = (s_pad // bs, (n * k + pad_k) // bk)
    out = pl.pallas_call(
        functools.partial(_scatter_kernel, block_s=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bk), lambda i, j: (0, j)),
            pl.BlockSpec((1, bk), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((SUBLANES, bs), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((SUBLANES, s_pad), jnp.float32),
        interpret=interpret,
    )(flat_idx, wv)
    return out[0, :size]
