"""Pure-jnp reference oracles for every kernel in this package.

These are the semantics contract: each Pallas kernel must match its oracle to
tolerance across the shape/dtype sweeps in tests/test_kernels_*.py. They are
also the implementation used by the CPU dry-run (TPU Pallas does not lower on
the CPU backend), so the roofline terms in EXPERIMENTS.md §Roofline reflect
this HLO unless noted.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---- attention (training / prefill) ----

def attention_dense(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: Optional[int] = None) -> jnp.ndarray:
    """Simple-but-exact GQA attention oracle (materializes S x S logits).

    Used as the semantics contract in tests; the data-plane default is the
    q-chunked ``attention`` below (identical math, bounded memory).
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, D)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    idx = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= idx[:, None] >= idx[None, :]
    if window is not None:
        mask &= idx[:, None] - idx[None, :] < window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v)
    return out.reshape(B, S, H, D)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, window: Optional[int] = None,
              q_chunk: int = 512, max_chunks: int = 16) -> jnp.ndarray:
    """GQA attention, q-CHUNKED (exact — softmax is per q-row so chunking the
    q axis changes nothing numerically). q: (B,S,H,D); k,v: (B,S,KV,D).

    §Perf H6/H7 (measured on the dry-run HLO):
    - k/v are REPEATED to the full H heads before the contraction so the
      logits tensor carries a clean H axis -> shards over the model mesh axis
      (the (KV, G) factorization defeated GSPMD for every arch with
      KV < mesh_model, replicating the S x S logits 16x).
    - q chunks skip fully-masked kv spans: causal drops the upper triangle
      (~2x), sliding-window drops everything beyond the band (S/window x).
    - peak logits memory drops S/q_chunk-fold vs the dense oracle.
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    qc = min(q_chunk, S)
    while S % qc:
        qc //= 2
    qc = max(qc, S // max_chunks if S % max_chunks == 0 else qc)
    nc = S // qc
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))

    outs = []
    for i in range(nc):
        q_lo = i * qc
        # kv span for this chunk: causal upper bound, window lower bound
        k_hi = (i + 1) * qc if causal else S
        k_lo = max(0, q_lo - (window - 1)) if window is not None else 0
        # align to qc for static, cache-friendly slices
        k_lo = (k_lo // qc) * qc
        ks = k[:, k_lo:k_hi]
        vs = v[:, k_lo:k_hi]
        qi = q[:, q_lo:q_lo + qc]
        logits = jnp.einsum("bqhd,bkhd->bhqk", qi, ks).astype(jnp.float32) * scale
        qpos = q_lo + jnp.arange(qc)
        kpos = k_lo + jnp.arange(k_hi - k_lo)
        mask = jnp.ones((qc, k_hi - k_lo), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        outs.append(jnp.einsum("bhqk,bkhd->bqhd", p, vs))
    return jnp.concatenate(outs, axis=1)


# ---- decode attention (one new token vs a KV cache) ----

def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     length: jnp.ndarray) -> jnp.ndarray:
    """q: (B,H,D); caches: (B,T,KV,D); length: (B,) valid cache prefix.
    Returns (B,H,D)."""
    B, H, D = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    logits = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache).astype(jnp.float32) * scale
    valid = jnp.arange(T)[None, :] < length[:, None]  # (B,T)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v_cache)
    return out.reshape(B, H, D)


# ---- MoE grouped matmul ----

def moe_gmm(xg: jnp.ndarray, wg: jnp.ndarray) -> jnp.ndarray:
    """Grouped expert matmul. xg: (E,C,din); wg: (E,din,dout) -> (E,C,dout)."""
    return jnp.einsum("ecd,edf->ecf", xg, wg)


# ---- gated linear recurrence (SSM / mLSTM shared primitive) ----

def linear_scan(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                decay: jnp.ndarray,
                init_state: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Gated linear attention scan (shared by Mamba2-SSD and xLSTM-mLSTM).

        S_t = decay_t * S_{t-1} + k_t ⊗ v_t        (per head; S: (Dk, Dv))
        n_t = decay_t * n_{t-1} + k_t
        y_t = (q_t · S_t) / max(|q_t · n_t|, 1)

    q,k: (B,S,H,Dk); v: (B,S,H,Dv); decay: (B,S,H) in (0,1].
    Returns y: (B,S,H,Dv) and final (S, n) state for decode continuation.
    """
    B, S, H, Dk = q.shape
    Dv = v.shape[-1]
    if init_state is None:
        S0 = jnp.zeros((B, H, Dk, Dv), jnp.float32)
        n0 = jnp.zeros((B, H, Dk), jnp.float32)
    else:
        S0, n0 = init_state

    def step(carry, xs):
        St, nt = carry
        qt, kt, vt, dt = xs  # (B,H,Dk),(B,H,Dk),(B,H,Dv),(B,H)
        St = dt[..., None, None] * St + kt[..., :, None].astype(jnp.float32) * vt[..., None, :].astype(jnp.float32)
        nt = dt[..., None] * nt + kt.astype(jnp.float32)
        num = jnp.einsum("bhk,bhkv->bhv", qt.astype(jnp.float32), St)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qt.astype(jnp.float32), nt)), 1.0)
        y = num / den[..., None]
        return (St, nt), y

    xs = (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0),
          jnp.moveaxis(v, 1, 0), jnp.moveaxis(decay, 1, 0))
    (Sf, nf), ys = jax.lax.scan(step, (S0, n0), xs)
    return jnp.moveaxis(ys, 0, 1).astype(v.dtype), (Sf, nf)


def linear_scan_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        decay: jnp.ndarray, chunk: int = 128,
                        ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Chunked pure-jnp form of ``linear_scan`` — same math as the Pallas
    kernel: O(S/Lc) state round-trips instead of O(S), intra-chunk work as
    dense matmuls. This is the DEFAULT data-plane path (§Perf H1: the
    per-timestep scan was 10-30x memory-bound on hymba/xlstm); the sequential
    ``linear_scan`` remains the test oracle."""
    B, S, H, Dk = q.shape
    Dv = v.shape[-1]
    Lc = min(chunk, S)
    while S % Lc:
        Lc //= 2
    nC = S // Lc

    def resh(x):
        return x.reshape(B, nC, Lc, *x.shape[2:]).astype(jnp.float32)

    qc, kc, vc = resh(q), resh(k), resh(v)                   # (B,nC,Lc,H,·)
    ac = resh(decay)                                         # (B,nC,Lc,H)
    la = jnp.cumsum(jnp.log(jnp.maximum(ac, 1e-37)), axis=2)
    A = jnp.exp(la)                                          # (B,nC,Lc,H)
    ratio = jnp.exp(la[:, :, :, None, :] - la[:, :, None, :, :])  # (B,nC,t,i,H)
    mask = (jnp.arange(Lc)[:, None] >= jnp.arange(Lc)[None, :])[None, None, :, :, None]
    W = jnp.where(mask, ratio, 0.0)
    qk = jnp.einsum("bcthd,bcihd->bctih", qc, kc)            # (B,nC,t,i,H)
    Wqk = W * qk
    y_intra = jnp.einsum("bctih,bcihv->bcthv", Wqk, vc)
    den_intra = Wqk.sum(axis=3)                              # (B,nC,t,H)
    # decayed keys for the carry: (A_L / A_i) k_i
    wL = jnp.exp(la[:, :, -1:, :] - la)                      # (B,nC,Lc,H)
    kd = kc * wL[..., None]
    S_chunk = jnp.einsum("bcihk,bcihv->bchkv", kd, vc)       # (B,nC,H,Dk,Dv)
    n_chunk = kd.sum(axis=2)                                 # (B,nC,H,Dk)
    AL = A[:, :, -1, :]                                      # (B,nC,H)

    def carry_step(carry, xs):
        S_in, n_in = carry                                   # (B,H,Dk,Dv), (B,H,Dk)
        S_c, n_c, AL_c = xs
        S_out = AL_c[..., None, None] * S_in + S_c
        n_out = AL_c[..., None] * n_in + n_c
        return (S_out, n_out), (S_in, n_in)

    S0 = jnp.zeros((B, H, Dk, Dv), jnp.float32)
    n0 = jnp.zeros((B, H, Dk), jnp.float32)
    xs = (jnp.moveaxis(S_chunk, 1, 0), jnp.moveaxis(n_chunk, 1, 0),
          jnp.moveaxis(AL, 1, 0))
    (S_f, n_f), (S_ins, n_ins) = jax.lax.scan(carry_step, (S0, n0), xs)
    S_ins = jnp.moveaxis(S_ins, 0, 1)                        # (B,nC,H,Dk,Dv)
    n_ins = jnp.moveaxis(n_ins, 0, 1)                        # (B,nC,H,Dk)

    y_cross = A[..., None] * jnp.einsum("bcthk,bchkv->bcthv", qc, S_ins)
    den_cross = A * jnp.einsum("bcthk,bchk->bcth", qc, n_ins)
    y = y_intra + y_cross
    den = jnp.maximum(jnp.abs(den_intra + den_cross), 1.0)
    y = (y / den[..., None]).reshape(B, S, H, Dv).astype(v.dtype)
    return y, (S_f, n_f)


def linear_scan_step(q, k, v, decay, state):
    """Single decode step of linear_scan. q,k: (B,H,Dk); v: (B,H,Dv); decay: (B,H)."""
    St, nt = state
    St = decay[..., None, None] * St + k[..., :, None].astype(jnp.float32) * v[..., None, :].astype(jnp.float32)
    nt = decay[..., None] * nt + k.astype(jnp.float32)
    num = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), St)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q.astype(jnp.float32), nt)), 1.0)
    return (num / den[..., None]).astype(v.dtype), (St, nt)


# ---- fused RMSNorm ----

def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---- weighted scatter-add (compressed FedAvg aggregation) ----

def scatter_add(vals: jnp.ndarray, idx: jnp.ndarray, weights: jnp.ndarray,
                size: int) -> jnp.ndarray:
    """Oracle for kernels/scatter_add.py: weighted sparse accumulation.

    ``vals``: (n, k) per-row sparse values; ``idx``: (n, k) int positions into
    a flat output of ``size``; ``weights``: (n,) per-row weights. Returns
    (size,) f32 with out[p] = sum over all (i, j) with idx[i, j] == p of
    weights[i] * vals[i, j]. Rows may repeat positions; negative positions
    are treated as padding and dropped (jnp ``.add`` with mode='drop').
    """
    wv = vals.astype(jnp.float32) * weights.astype(jnp.float32)[:, None]
    flat_idx = idx.reshape(-1)
    # mode="drop" alone does not help with negatives (jnp wraps them first):
    # route padding rows to an extra slot past the end and slice it off.
    flat_idx = jnp.where(flat_idx < 0, size, flat_idx)
    out = jnp.zeros((size + 1,), jnp.float32).at[flat_idx].add(
        wv.reshape(-1), mode="drop")
    return out[:size]


# ---- scheduler plan-scoring stats (fleet-scale scoring core) ----

def sched_plan_stats(times: jnp.ndarray, weights: jnp.ndarray,
                     plans: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels/sched_score.py: (P, 3) [masked max time,
    selected count, selected weight sum] per candidate plan."""
    sel = plans != 0
    tmax = jnp.max(jnp.where(sel, times[None, :].astype(jnp.float32), NEG_INF),
                   axis=1)
    n = jnp.sum(jnp.where(sel, 1.0, 0.0), axis=1)
    ws = jnp.sum(jnp.where(sel, weights[None, :].astype(jnp.float32), 0.0),
                 axis=1)
    return jnp.stack([tmax, n, ws], axis=1)
