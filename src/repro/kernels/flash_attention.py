"""Flash attention (fwd) Pallas TPU kernel with GQA, causal and
sliding-window masking.

Online-softmax tiling (Dao et al., adapted to the TPU grid model): grid
(B, H, S/BQ, S/BK) with the KV-block axis INNERMOST — TPU executes the grid
sequentially minor-to-major, so the running max m, normalizer l, and f32
output accumulator live in VMEM scratch across the KV sweep and flush once
per Q tile. GQA is pure indexing: the k/v BlockSpec index_map sends q-head h
to kv-head h // (H // KV) — no head replication in HBM.

VMEM budget per step: q (BQ, D) + k,v (BK, D) + acc (BQ, D) f32 + logits
(BQ, BK) f32 ≈ 0.6 MB at BQ=BK=512, D=128 — far under the ~16 MB/core VMEM,
leaving room for the double-buffered pipeline.

Backward falls back to the jnp reference via custom_vjp: training still
differentiates, the paper's contribution is not a bwd kernel, and §Perf
tracks the fwd path (prefill/serving) where this kernel lands.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref as _ref

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, n_k: int,
                  causal: bool, window: Optional[int]):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                      # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)                      # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)                      # (BK, D)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale          # (BQ, BK)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(logits, dtype=jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
    p = jnp.exp(logits - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(ki == n_k - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _flash_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    bq = min(block_q, S)
    bk = min(block_k, S)
    while S % bq:
        bq //= 2
    while S % bk:
        bk //= 2
    grid = (B, H, S // bq, S // bk)
    scale = 1.0 / (D ** 0.5)

    # (B,S,H,D) -> (B,H,S,D) layout for clean (S, D) tiles
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, block_q=bq, block_k=bk,
                          n_k=grid[3], causal=causal, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # normalizer l
            pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, block_q, block_k, interpret):
    return _flash_fwd(q, k, v, causal, window, block_q, block_k, interpret)


def _flash_vjp_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    return _flash(q, k, v, causal, window, block_q, block_k, interpret), (q, k, v)


def _flash_vjp_bwd(causal, window, block_q, block_k, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: _ref.attention(q_, k_, v_, causal=causal,
                                                       window=window), q, k, v)
    return vjp(g)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention(q, k, v, causal: bool = True, window: Optional[int] = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False):
    """q: (B,S,H,D); k,v: (B,S,KV,D) -> (B,S,H,D)."""
    return _flash(q, k, v, causal, window, block_q, block_k, interpret)
