"""Tiled Pallas kernel for the fleet-scale plan-scoring reduction.

Scores P candidate scheduling plans over K devices in one pass (the inner
loop of every scheduler in this repo — Formula 2 = alpha * masked-max round
time + beta * fairness-variance increment). The (P, K) problem is tiled
(BLOCK_P, BLOCK_K); the kernel accumulates three sufficient statistics per
plan across the K grid dimension:

  col 0:  max_{k in V} t_k          (Formula 3, running max)
  col 1:  |V| = sum_k v_k           (selected count)
  col 2:  sum_{k in V} (2 c_k + 1)  (fairness increment numerator)

because the Formula-5 variance terms reduce exactly:

  sum(s)  = sum(c) + |V|                      with s = c + v, v in {0,1}
  sum(s²) = sum(c²) + sum_{k in V} (2 c_k + 1)

so Var(s) (and the delta form Var(s) - Var(c)) are closed-form in the three
per-plan accumulators plus two scalars of ``counts`` — no (P, K) float
intermediate ever exists. The cheap (P,)-sized cost combine runs in plain
jnp after the kernel (see ``repro.core.scoring``).

Plans stream through as int8 tiles (the natural layout for 100k-device
pools: a (4096, 100k) candidate set is 0.4 GB as int8, 1.6 GB as f32).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
STATS_LANES = 128  # output lane width (TPU tile); cols 0..2 carry the stats


def _score_kernel(times_ref, w_ref, plans_ref, stats_ref):
    k_idx = pl.program_id(1)
    p = plans_ref[...] != 0                       # (BP, BK) bool
    t = times_ref[...].astype(jnp.float32)        # (1, BK)
    w = w_ref[...].astype(jnp.float32)            # (1, BK)

    tile_max = jnp.max(jnp.where(p, t, NEG_INF), axis=1)   # (BP,)
    tile_n = jnp.sum(jnp.where(p, 1.0, 0.0), axis=1)
    tile_w = jnp.sum(jnp.where(p, w, 0.0), axis=1)

    col = jax.lax.broadcasted_iota(jnp.int32, stats_ref.shape, 1)
    new = jnp.where(col == 0, tile_max[:, None],
                    jnp.where(col == 1, tile_n[:, None],
                              jnp.where(col == 2, tile_w[:, None], 0.0)))

    @pl.when(k_idx == 0)
    def _():
        stats_ref[...] = jnp.where(col == 0, NEG_INF, 0.0)

    old = stats_ref[...]
    stats_ref[...] = jnp.where(col == 0, jnp.maximum(old, new), old + new)


@functools.partial(jax.jit,
                   static_argnames=("block_p", "block_k", "interpret"))
def plan_stats(times: jnp.ndarray, weights: jnp.ndarray, plans: jnp.ndarray,
               block_p: int = 256, block_k: int = 512,
               interpret: bool = False) -> jnp.ndarray:
    """(K,) times, (K,) weights, (P, K) int8/bool plans -> (P, 3) f32 stats.

    stats[:, 0] = masked max time (NEG_INF for empty plans)
    stats[:, 1] = selected count
    stats[:, 2] = sum of weights over selected
    """
    P, K = plans.shape
    bp = min(block_p, max(8, P))
    bk = min(block_k, max(128, K))
    pad_p = (-P) % bp
    pad_k = (-K) % bk
    plans8 = plans.astype(jnp.int8)
    if pad_p or pad_k:
        plans8 = jnp.pad(plans8, ((0, pad_p), (0, pad_k)))
    t2 = times.astype(jnp.float32).reshape(1, K)
    w2 = weights.astype(jnp.float32).reshape(1, K)
    if pad_k:
        t2 = jnp.pad(t2, ((0, 0), (0, pad_k)))
        w2 = jnp.pad(w2, ((0, 0), (0, pad_k)))
    grid = (plans8.shape[0] // bp, plans8.shape[1] // bk)
    out = pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bk), lambda i, k: (0, k)),
            pl.BlockSpec((1, bk), lambda i, k: (0, k)),
            pl.BlockSpec((bp, bk), lambda i, k: (i, k)),
        ],
        out_specs=pl.BlockSpec((bp, STATS_LANES), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((plans8.shape[0], STATS_LANES),
                                       jnp.float32),
        interpret=interpret,
    )(t2, w2, plans8)
    return out[:P, :3]
