"""Kernel dispatch: jit-ready wrappers selecting Pallas / interpret / ref.

``set_default_impl`` flips the whole model zoo between the pure-jnp reference
path (CPU tests + dry-run) and the Pallas TPU kernels. Individual calls can
override via ``impl=``. ``interpret`` runs the Pallas kernel body in Python
on CPU — the validation mode used by tests/test_kernels_*.py.
"""

from __future__ import annotations

import threading
from typing import Optional

import jax.numpy as jnp

from repro.kernels import ref as _ref

_state = threading.local()
VALID = ("ref", "pallas", "interpret")


def set_default_impl(impl: str) -> None:
    assert impl in VALID, impl
    _state.impl = impl


def get_default_impl() -> str:
    return getattr(_state, "impl", "ref")


def _resolve(impl: Optional[str]) -> str:
    return impl if impl is not None else get_default_impl()


def attention(q, k, v, causal: bool = True, window: Optional[int] = None,
              impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.attention(q, k, v, causal=causal, window=window)
    from repro.kernels import flash_attention as fa
    return fa.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=(impl == "interpret"))


def decode_attention(q, k_cache, v_cache, length, impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.decode_attention(q, k_cache, v_cache, length)
    from repro.kernels import decode_attention as da
    return da.decode_attention(q, k_cache, v_cache, length,
                               interpret=(impl == "interpret"))


def moe_gmm(xg, wg, impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.moe_gmm(xg, wg)
    from repro.kernels import moe_gmm as gmm
    return gmm.moe_gmm(xg, wg, interpret=(impl == "interpret"))


def linear_scan(q, k, v, decay, init_state=None, impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "ref":
        # Chunked form by default (§Perf H1): identical math, O(S/Lc) state
        # round-trips. The sequential oracle stays in ref.linear_scan.
        if init_state is None:
            return _ref.linear_scan_chunked(q, k, v, decay)
        return _ref.linear_scan(q, k, v, decay, init_state)
    from repro.kernels import ssm_scan as ss
    return ss.linear_scan(q, k, v, decay, init_state,
                          interpret=(impl == "interpret"))


def linear_scan_step(q, k, v, decay, state):
    # Decode steps are O(1) work: the ref path is already optimal (no kernel).
    return _ref.linear_scan_step(q, k, v, decay, state)


def rmsnorm(x, scale, eps: float = 1e-6, impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.rmsnorm(x, scale, eps)
    from repro.kernels import rmsnorm as rn
    return rn.rmsnorm(x, scale, eps, interpret=(impl == "interpret"))


def scatter_add(vals, idx, weights, size: int, impl: Optional[str] = None):
    """Weighted sparse accumulation (compressed-FedAvg server decompression).

    (n, k) vals/idx + (n,) weights -> (size,) f32; see kernels/ref.py for the
    exact semantics (negative idx = padding).
    """
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.scatter_add(vals, idx, weights, size)
    from repro.kernels import scatter_add as sa
    return sa.scatter_add(vals, idx, weights, size,
                          interpret=(impl == "interpret"))


def sched_plan_stats(times, weights, plans, impl: Optional[str] = None):
    """Per-plan scoring stats for the scheduler core (see core/scoring.py)."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.sched_plan_stats(times, weights, plans)
    from repro.kernels import sched_score as ss
    return ss.plan_stats(times, weights, plans,
                         interpret=(impl == "interpret"))
