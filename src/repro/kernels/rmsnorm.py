"""Fused RMSNorm Pallas kernel.

One pass over HBM: each grid step loads a (BLOCK_ROWS, d) tile into VMEM,
computes the row mean-square in f32, scales, and writes the tile back —
vs 3 HBM round-trips for the unfused (square, mean, mul) graph.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # (BR, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool = False) -> jnp.ndarray:
    """x: (..., d); scale: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    br = min(block_rows, rows)
    # pad rows to a multiple of br
    pad = (-rows) % br
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    grid = (xf.shape[0] // br,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
