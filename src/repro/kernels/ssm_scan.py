"""Chunked gated-linear-recurrence Pallas kernel (Mamba2-SSD / mLSTM).

Recurrence (per head):  S_t = a_t S_{t-1} + k_t v_t^T,  n_t = a_t n_{t-1} + k_t,
                        y_t = (q_t . S_t) / max(|q_t . n_t|, 1).

The TPU adaptation of Mamba's sequential CUDA scan (DESIGN.md §3): split the
sequence into chunks of length Lc. Within a chunk everything is dense matmul
(MXU): with cumulative decays A_t = prod_{i<=t} a_i (computed in log space,
ratios are <= 1 so exp never overflows),

    y_t   = A_t (q_t . S_in) + sum_{i<=t} (A_t/A_i)(q_t . k_i) v_i
    den_t = A_t (q_t . n_in) + sum_{i<=t} (A_t/A_i)(q_t . k_i)
    S_out = A_L S_in + sum_i (A_L/A_i) k_i v_i^T      (same for n_out)

Grid (B, H, S/Lc), chunk axis innermost and sequential; the (Dk, Dv) state
and (Dk,) normalizer carry across chunks in f32 VMEM scratch. Wall-clock is
O(S·Dk·Dv / MXU) instead of O(S) serial steps.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(q_ref, k_ref, v_ref, a_ref, y_ref, S_ref, n_ref, *,
                 chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        S_ref[...] = jnp.zeros_like(S_ref)
        n_ref[...] = jnp.zeros_like(n_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (Lc, Dk)
    k = k_ref[0, 0].astype(jnp.float32)          # (Lc, Dk)
    v = v_ref[0, 0].astype(jnp.float32)          # (Lc, Dv)
    a = a_ref[0, 0].astype(jnp.float32)          # (Lc,)

    la = jnp.cumsum(jnp.log(jnp.maximum(a, 1e-37)))          # (Lc,)
    A = jnp.exp(la)                                          # A_t
    # intra-chunk decay ratios W_ti = A_t / A_i for i <= t, else 0
    ratio = jnp.exp(la[:, None] - la[None, :])
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    W = jnp.where(mask, ratio, 0.0)

    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Lc, Lc)
    Wqk = W * qk

    S_in = S_ref[...]                                        # (Dk, Dv)
    n_in = n_ref[...][:, 0]                                  # (Dk,)

    y = (jnp.dot(Wqk, v, preferred_element_type=jnp.float32)
         + A[:, None] * jnp.dot(q, S_in, preferred_element_type=jnp.float32))
    den = Wqk.sum(axis=1) + A * (q @ n_in)
    den = jnp.maximum(jnp.abs(den), 1.0)
    y_ref[0, 0] = (y / den[:, None]).astype(y_ref.dtype)

    # carry updates: decay-weighted keys kd_i = (A_L / A_i) k_i
    AL = A[-1]
    kd = k * jnp.exp(la[-1] - la)[:, None]
    S_ref[...] = AL * S_in + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    n_ref[...] = (AL * n_in + kd.sum(axis=0))[:, None]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def linear_scan(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                decay: jnp.ndarray,
                init_state=None, chunk: int = 128,
                interpret: bool = False):
    """q,k: (B,S,H,Dk); v: (B,S,H,Dv); decay: (B,S,H) in (0,1].
    Returns (y: (B,S,H,Dv), (S_final, n_final)).

    NOTE: the kernel path starts from a zero state (init_state must be None —
    prefill); decode continuation uses ops.linear_scan_step. Final states are
    recomputed cheaply from the last chunk via the reference when needed.
    """
    assert init_state is None, "kernel path is prefill-only (zero init state)"
    B, S, H, Dk = q.shape
    Dv = v.shape[-1]
    Lc = min(chunk, S)
    while S % Lc:
        Lc //= 2
    grid = (B, H, S // Lc)

    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    at = decay.transpose(0, 2, 1)

    y = pl.pallas_call(
        functools.partial(_scan_kernel, chunk=Lc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Lc, Dk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Lc, Dk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Lc, Dv), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Lc), lambda b, h, c: (b, h, c)),
        ],
        out_specs=pl.BlockSpec((1, 1, Lc, Dv), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, Dv), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((Dk, Dv), jnp.float32),
            pltpu.VMEM((Dk, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, at)
    y = y.transpose(0, 2, 1, 3)

    # Final state (needed only at the prefill->decode hand-off): one cheap
    # recurrence over the last chunk equivalent — use the reference formulas
    # on decayed sums. For the kernel API we return analytic final states.
    la_full = jnp.cumsum(jnp.log(jnp.maximum(decay.astype(jnp.float32), 1e-37)), axis=1)
    w_last = jnp.exp(la_full[:, -1:, :] - la_full)            # (B,S,H)
    kd = k.astype(jnp.float32) * w_last[..., None]
    S_f = jnp.einsum("bshk,bshv->bhkv", kd, v.astype(jnp.float32))
    n_f = jnp.einsum("bshk->bhk", kd)
    return y, (S_f, n_f)
