"""The online multi-tenant scheduler service.

``SchedulerService`` wraps a built ``MultiJobEngine`` in an event loop that
interleaves EXTERNAL traffic (job arrivals/departures, device churn — a
``repro.serve.traffic`` trace) with the engine's INTERNAL round events
(``engine.advance_until``). The spec's job list becomes a catalogue of
tenant templates: template jobs are parked (never run), and every arrival
instantiates a fresh engine job from its template.

Admission control: at most ``arrivals.max_concurrent`` live jobs; excess
arrivals queue and are admitted least-served-first when a slot frees (a job
finishes or its tenant departs) — Jain-fairness-aware admission.

Per-arrival plan rescoring (the admission decision's cost estimate for
every live job under the post-arrival world state) runs in one of two modes:

- ``incremental`` — rescore each live job's CURRENT plan through the
  batched scoring core, reusing the pool's SoA caches and skipping jobs
  whose world is unchanged (``pool.version`` + round index as the cache
  key). Churn invalidates exactly the affected entries.
- ``full``        — re-run a cold scheduler's complete plan SEARCH for
  every live job (the ablation baseline the incremental path is benched
  against; ``benchmarks/bench_serve.py`` gates the speedup).

Both modes are ADVISORY: executed plans always come from the live
scheduler inside the engine, so the realized trajectory is identical across
modes — the bench compares decision latency at equal outcomes.

Warm hand-off: a departing tenant's per-job scheduler state
(``job_state_dict`` — BODS observation ring, RLDS baseline) is saved and
reloaded under the new job id if the tenant is readmitted, BEFORE its first
decision (``add_job(launch=False)`` + ``launch_job``).
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Set

import numpy as np

from repro.core.multijob import MultiJobEngine, RoundRecord
from repro.experiment.spec import ExperimentSpec
from repro.monitoring.trace import instant, span
from repro.serve.metrics import ServiceMetrics, ServiceReport
from repro.serve.traffic import TrafficEvent, trace_from_spec

RESCORE_MODES = ("incremental", "full")


class SimulatedCrash(RuntimeError):
    """In-process stand-in for ``kill -9`` (the ``crash_after`` test hook):
    raised AFTER the Nth traffic event is applied, past any checkpoint for
    that boundary — state on disk is whatever the last atomic save
    committed, exactly like a hard kill."""


class SchedulerService:
    def __init__(self, spec: ExperimentSpec,
                 rescore_mode: str = "incremental",
                 verbose: bool = False,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0,
                 crash_after: Optional[int] = None):
        """``checkpoint_dir``/``checkpoint_every``: atomically persist the
        FULL service state every N traffic events (``repro.serve.
        persistence``); ``resume()`` restarts bit-identically from the
        newest committed step. ``crash_after``: raise ``SimulatedCrash``
        after the Nth event (chaos tests)."""
        if spec.arrivals is None:
            raise ValueError("SchedulerService needs spec.arrivals "
                             "(the online traffic axis)")
        if rescore_mode not in RESCORE_MODES:
            raise ValueError(f"rescore_mode {rescore_mode!r} not in "
                             f"{RESCORE_MODES}")
        self.spec = spec
        self.rescore_mode = rescore_mode
        self.verbose = verbose
        self.checkpoint_every = int(checkpoint_every)
        self.checkpoint_dir = checkpoint_dir
        self._ckpt_manager = None
        if checkpoint_dir is not None:
            from repro.checkpoint import CheckpointManager

            self._ckpt_manager = CheckpointManager(checkpoint_dir)
        self.crash_after = crash_after
        self.trace: Optional[List[TrafficEvent]] = None
        self._next_event = 0   # resume cursor: traffic events already applied

        self.engine: MultiJobEngine = spec.build().engine
        eng = self.engine
        # The catalogue: template configs + their data-size columns. Park
        # the template jobs — they exist so build()/calibration see a valid
        # job mix, but only arrival-instantiated jobs ever run.
        self.templates = [js.config for js in eng.jobs]
        self.template_data = [eng.pool.data_sizes[:, i].copy()
                              for i in range(len(self.templates))]
        for js in eng.jobs:
            js.parked = True
            js.done = True
        eng.on_job_done = self._on_job_done

        self.metrics = ServiceMetrics()
        self._live: Set[int] = set()            # admitted, not finished
        self._tenant_job: Dict[str, int] = {}   # live tenant -> job id
        # Job ids are never reused, so job -> tenant is PERMANENT — a
        # retired tenant's in-flight round still finishes (and must still
        # be attributed) after its slot is released.
        self._job_tenant: Dict[int, str] = {}
        self._tenant_template: Dict[str, int] = {}
        self._tenant_saved: Dict[str, dict] = {}  # retired -> per-job state
        self._queue: List[str] = []             # tenants waiting for a slot
        # Incremental rescoring memo: job -> ((pool.version, round_idx), cost)
        self._rescore_cache: Dict[int, tuple] = {}
        # Advisory mean rescore cost per admission (the bench's parity data).
        self.rescore_costs: List[float] = []
        self._cold = (self._make_cold_scheduler()
                      if rescore_mode == "full" else None)
        self.last_report: Optional[ServiceReport] = None

    # ---- crash-consistent persistence ----

    @classmethod
    def resume(cls, checkpoint_dir: str, verbose: bool = False,
               crash_after: Optional[int] = None) -> "SchedulerService":
        """Rebuild a service from the newest committed checkpoint and
        position it at the saved event boundary; a subsequent ``run()``
        continues the SAME trajectory bit-for-bit."""
        from repro.serve.persistence import (read_manifest_extra,
                                             restore_service)

        extra = read_manifest_extra(checkpoint_dir)
        svc = cls(ExperimentSpec.from_dict(extra["spec"]),
                  rescore_mode=extra["rescore_mode"], verbose=verbose,
                  checkpoint_dir=checkpoint_dir,
                  checkpoint_every=int(extra["checkpoint_every"]),
                  crash_after=crash_after)
        restore_service(svc, checkpoint_dir)
        return svc

    # ---- construction helpers ----

    def _make_cold_scheduler(self):
        """A second scheduler instance for the ``full`` ablation: same
        registry entry and knobs, own seed/rng (so its advisory searches
        never perturb the live scheduler's decision stream), and no
        pre-training (RLDS) — it re-searches from the current world state,
        which is the point."""
        from repro.experiment.registry import SCHEDULERS

        spec = self.spec
        kwargs = {"cost_model": self.engine.cost_model,
                  "seed": spec.scheduler_seed + 10_000,
                  **spec._candidate_kwargs(),
                  **dict(spec.scheduler_kwargs)}
        if "pretrain_rounds" in spec._scheduler_params():
            kwargs["pretrain_rounds"] = 0
        return SCHEDULERS.create(spec.scheduler, **kwargs)

    # ---- engine callbacks ----

    def _on_round(self, rec: RoundRecord) -> None:
        self.metrics.rounds_completed += 1
        tenant = self._job_tenant.get(rec.job)
        if tenant is None:
            return
        ts = self.metrics.tenants[tenant]
        ts.rounds += 1
        ts.total_cost += rec.cost
        ts.total_round_time += rec.round_time
        ts.last_fairness = rec.fairness
        ts.best_accuracy = max(ts.best_accuracy, rec.accuracy)

    def _on_job_done(self, job: int, now: float) -> None:
        """Engine signal: a job finished naturally (target/max_rounds) —
        free its admission slot and drain the queue."""
        self._release(job, now)

    # ---- admission control ----

    def _release(self, job: int, now: float) -> None:
        tenant = self._job_tenant.get(job)
        if tenant is not None and self._tenant_job.get(tenant) == job:
            self._tenant_job.pop(tenant)
        self._live.discard(job)
        self._rescore_cache.pop(job, None)
        self._drain_queue(now)

    def _drain_queue(self, now: float) -> None:
        while self._queue and len(self._live) < self.spec.arrivals.max_concurrent:
            # Least-served first: the tenant with the fewest rounds across
            # ALL its admissions gets the freed slot.
            self._queue.sort(key=lambda t: self.metrics.tenants[t].rounds)
            tenant = self._queue.pop(0)
            queued_at = self.metrics.tenants[tenant].queued_at
            if queued_at is not None:
                wait = float(now - queued_at)
                instant("queue_wait", tenant=tenant, wait_s=wait)
                if self.engine.events is not None:
                    self.engine.events.publish("serve.queue_wait", dict(
                        tenant=tenant, t=now, wait_s=wait))
            self.metrics.tenants[tenant].queued_at = None
            self._admit(tenant, self._tenant_template[tenant], now)

    def _admit(self, tenant: str, template: int, now: float) -> None:
        t0 = time.perf_counter()
        self._rescore(now)
        eng = self.engine
        job = eng.add_job(self.templates[template],
                          data_sizes=self.template_data[template],
                          now=now, launch=False)
        saved = self._tenant_saved.pop(tenant, None)
        if saved is not None:
            # Warm hand-off: the tenant's history lands under its NEW job
            # id before the first decision is made.
            eng.scheduler.load_job_state(job, saved)
            self.metrics.readmissions += 1
        eng.launch_job(job, now)
        self.metrics.decision_latency.add(time.perf_counter() - t0)
        self.metrics.decisions += 1
        self._live.add(job)
        self._tenant_job[tenant] = job
        self._job_tenant[job] = tenant
        self.metrics.tenants[tenant].admissions += 1
        if eng.events is not None:
            eng.events.publish("serve.admit", dict(
                tenant=tenant, job=job, template=template, t=now,
                live=len(self._live), warm=saved is not None))
        if self.verbose:
            print(f"[t={now:9.1f}s] admit  {tenant} -> job{job} "
                  f"(template {template}, live={len(self._live)})")

    # ---- incremental plan rescoring ----

    def _rescore(self, now: float) -> Dict[int, float]:
        """Advisory cost estimate of every live job's plan under the
        current world state — the admission decision's inputs."""
        eng = self.engine
        costs: Dict[int, float] = {}
        with span("rescore", mode=self.rescore_mode, live=len(self._live)):
            for job in sorted(self._live):
                if eng.jobs[job].done:
                    continue
                if self.rescore_mode == "incremental":
                    key = (eng.pool.version, eng.jobs[job].round_idx)
                    cached = self._rescore_cache.get(job)
                    if cached is not None and cached[0] == key:
                        costs[job] = cached[1]
                        continue
                    # Score the job's CURRENT plan under the post-churn time
                    # model — wait-free (its own devices are mid-round busy;
                    # full-search also plans over wait-free devices, so this
                    # is the comparable quantity). ``pool.expected_times`` is
                    # the per-(job, tau) memo that churn invalidation
                    # refreshes: unchanged world -> pure cache lookups end to
                    # end.
                    cm = eng.cost_model
                    tau = eng.jobs[job].config.local_epochs
                    times = eng.pool.expected_times(job, tau)
                    f = eng._in_flight.get(job)
                    if f is not None:
                        plan = f["plan"]
                    else:
                        # Between rounds (waiting on a retry): cheapest-n
                        # closed-form stand-in.
                        plan = np.zeros(eng.pool.num_devices, dtype=bool)
                        plan[np.argsort(times)[: eng.n_sel]] = True
                    c = float(cm.total_cost_batch(
                        job=job, tau=tau, counts=eng.counts[job],
                        plans=plan[None], other_costs=0.0, times=times)[0])
                    self._rescore_cache[job] = (key, c)
                    costs[job] = c
                else:
                    self._cold.ensure_jobs(len(eng.jobs))
                    ctx = eng._make_ctx(job, now)
                    self._cold.schedule(ctx)
                    est = self._cold.last_estimated_cost
                    costs[job] = float(est) if est is not None else 0.0
        self.rescore_costs.append(
            float(np.mean(list(costs.values()))) if costs else 0.0)
        return costs

    # ---- traffic handling ----

    def _handle(self, ev: TrafficEvent) -> None:
        now = ev.t
        eng = self.engine
        if ev.kind == "arrive":
            self.metrics.arrivals += 1
            template = (ev.template if ev.template is not None
                        else self._tenant_template.get(ev.tenant, 0))
            self._tenant_template[ev.tenant] = template
            self.metrics.tenant(ev.tenant, template)
            if ev.tenant in self._tenant_job or ev.tenant in self._queue:
                return  # duplicate arrival of a live/queued tenant
            if len(self._live) < self.spec.arrivals.max_concurrent:
                self._admit(ev.tenant, template, now)
            else:
                self.metrics.rejections += 1
                self.metrics.tenants[ev.tenant].queued_at = now
                self._queue.append(ev.tenant)
                if self.verbose:
                    print(f"[t={now:9.1f}s] queue  {ev.tenant} "
                          f"(depth={len(self._queue)})")
        elif ev.kind == "depart":
            self.metrics.departures += 1
            if ev.tenant in self._queue:
                self._queue.remove(ev.tenant)
                return
            job = self._tenant_job.get(ev.tenant)
            if job is None:
                return  # already finished (slot released via on_job_done)
            self._tenant_saved[ev.tenant] = eng.scheduler.job_state_dict(job)
            eng.retire_job(job, now=now)
            if eng.events is not None:
                eng.events.publish("serve.depart", dict(
                    tenant=ev.tenant, job=job, t=now))
            if self.verbose:
                print(f"[t={now:9.1f}s] retire {ev.tenant} (job{job})")
            self._release(job, now)
        elif ev.kind == "churn_out":
            self.metrics.churn_events += 1
            eng.pool.depart(ev.devices)
            if eng.events is not None:
                eng.events.publish("serve.churn", dict(
                    kind="out", t=now, n=len(ev.devices)))
        elif ev.kind == "churn_in":
            self.metrics.churn_events += 1
            if ev.drift != 1.0:
                ids = np.asarray(ev.devices)
                eng.pool.rejoin(ids, a=eng.pool.a[ids] * ev.drift)
            else:
                eng.pool.rejoin(ev.devices)
            if eng.events is not None:
                eng.events.publish("serve.churn", dict(
                    kind="in", t=now, n=len(ev.devices), drift=ev.drift))

    # ---- the event loop ----

    def run(self, trace: Optional[List[TrafficEvent]] = None
            ) -> ServiceReport:
        """Sustain the traffic stream end-to-end: for each traffic event,
        advance the engine's internal heap up to the event's timestamp,
        apply the event, then drain the remaining rounds. Returns the
        service report; per-job engine summaries stay on
        ``self.engine.summary()``."""
        arr = self.spec.arrivals
        eng = self.engine
        if trace is None:
            # A resumed service replays ITS OWN saved trace (regenerating
            # would fork the trajectory if the spec's seed axis changed).
            trace = self.trace if self.trace is not None else trace_from_spec(
                arr, len(self.templates), eng.pool.num_devices)
        self.trace = trace
        t0 = time.perf_counter()
        try:
            for i in range(self._next_event, len(trace)):
                ev = trace[i]
                with span("serve_advance", until=ev.t):
                    eng.advance_until(ev.t, on_round=self._on_round)
                with span("handle_event", kind=ev.kind):
                    self._handle(ev)
                self.metrics.events_processed += 1
                self.metrics.sample_queue_depth(len(self._queue))
                self._next_event = i + 1
                if (self._ckpt_manager is not None
                        and self.checkpoint_every > 0
                        and self._next_event % self.checkpoint_every == 0):
                    from repro.serve.persistence import save_service_checkpoint

                    with span("checkpoint_write", step=self._next_event):
                        save_service_checkpoint(self, self._next_event)
                    if eng.events is not None:
                        eng.events.publish("serve.checkpoint", dict(
                            step=self._next_event, t=ev.t))
                if (self.crash_after is not None
                        and self._next_event >= self.crash_after):
                    raise SimulatedCrash(
                        f"crash_after={self.crash_after}: simulated hard "
                        f"kill after event {self._next_event}")
            # Drain: live jobs run to completion; finishing jobs release
            # slots, which admits queued tenants mid-drain (on_job_done
            # fires inside advance_until, so late admissions still execute).
            with span("serve_advance", until=float("inf")):
                eng.advance_until(np.inf, on_round=self._on_round)
        finally:
            # The spec's obs axis hung a session on the engine at build();
            # the service owns the run, so it finalizes (trace write + sink
            # close) even on a simulated crash.
            if eng.obs is not None:
                eng.obs.close()
        self.last_report = self.metrics.report(
            sim_horizon=arr.horizon, wall_s=time.perf_counter() - t0)
        return self.last_report
