"""The online multi-tenant scheduler service.

``SchedulerService`` wraps a built ``MultiJobEngine`` in an event loop that
interleaves EXTERNAL traffic (job arrivals/departures, device churn — a
``repro.serve.traffic`` trace) with the engine's INTERNAL round events
(``engine.advance_until``). The spec's job list becomes a catalogue of
tenant templates: template jobs are parked (never run), and every arrival
instantiates a fresh engine job from its template.

Admission control: at most ``arrivals.max_concurrent`` live jobs; excess
arrivals queue and are admitted least-served-first when a slot frees (a job
finishes or its tenant departs) — Jain-fairness-aware admission.

Per-arrival plan rescoring (the admission decision's cost estimate for
every live job under the post-arrival world state) runs in one of two modes:

- ``incremental`` — rescore each live job's CURRENT plan through the
  batched scoring core, reusing the pool's SoA caches and skipping jobs
  whose world is unchanged (``pool.version`` + round index as the cache
  key). Churn invalidates exactly the affected entries.
- ``full``        — re-run a cold scheduler's complete plan SEARCH for
  every live job (the ablation baseline the incremental path is benched
  against; ``benchmarks/bench_serve.py`` gates the speedup).

Both modes are ADVISORY: executed plans always come from the live
scheduler inside the engine, so the realized trajectory is identical across
modes — the bench compares decision latency at equal outcomes.

Warm hand-off: a departing tenant's per-job scheduler state
(``job_state_dict`` — BODS observation ring, RLDS baseline) is saved and
reloaded under the new job id if the tenant is readmitted, BEFORE its first
decision (``add_job(launch=False)`` + ``launch_job``).
"""

from __future__ import annotations

import time
import warnings
from typing import Dict, List, Optional, Set

import numpy as np

from repro.core.multijob import MultiJobEngine, RoundRecord
from repro.experiment.spec import ExperimentSpec
from repro.monitoring.trace import instant, span
from repro.serve.metrics import ServiceMetrics, ServiceReport
from repro.serve.resilience import RoundWatchdog
from repro.serve.traffic import TrafficEvent, trace_from_spec

RESCORE_MODES = ("incremental", "full")


class SimulatedCrash(RuntimeError):
    """In-process stand-in for ``kill -9`` (the ``crash_after`` test hook):
    raised AFTER the Nth traffic event is applied, past any checkpoint for
    that boundary — state on disk is whatever the last atomic save
    committed, exactly like a hard kill."""


class SchedulerService:
    def __init__(self, spec: ExperimentSpec,
                 rescore_mode: str = "incremental",
                 verbose: bool = False,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0,
                 crash_after: Optional[int] = None):
        """``checkpoint_dir``/``checkpoint_every``: atomically persist the
        FULL service state every N traffic events (``repro.serve.
        persistence``); ``resume()`` restarts bit-identically from the
        newest committed step. ``crash_after``: raise ``SimulatedCrash``
        after the Nth event (chaos tests)."""
        if spec.arrivals is None:
            raise ValueError("SchedulerService needs spec.arrivals "
                             "(the online traffic axis)")
        if rescore_mode not in RESCORE_MODES:
            raise ValueError(f"rescore_mode {rescore_mode!r} not in "
                             f"{RESCORE_MODES}")
        self.spec = spec
        self.rescore_mode = rescore_mode
        self.verbose = verbose
        self.checkpoint_every = int(checkpoint_every)
        self.checkpoint_dir = checkpoint_dir
        self._ckpt_manager = None
        if checkpoint_dir is not None:
            from repro.checkpoint import CheckpointManager

            self._ckpt_manager = CheckpointManager(checkpoint_dir)
        self.crash_after = crash_after
        self.trace: Optional[List[TrafficEvent]] = None
        self._next_event = 0   # resume cursor: traffic events already applied

        # SLO resilience axis: backpressure thresholds, the watchdog, and
        # (inside the engine) the decision governor + breakers.
        self._slo = spec.effective_slo()
        self._watchdog = (RoundWatchdog(self._slo.watchdog_rounds)
                          if self._slo is not None
                          and self._slo.watchdog_rounds > 0 else None)
        self._draining = False   # post-trace drain forces deferred admits

        self.engine: MultiJobEngine = self._fresh_engine()
        eng = self.engine
        # The catalogue: template configs + their data-size columns.
        self.templates = [js.config for js in eng.jobs]
        self.template_data = [eng.pool.data_sizes[:, i].copy()
                              for i in range(len(self.templates))]

        self.metrics = ServiceMetrics()
        self._live: Set[int] = set()            # admitted, not finished
        self._tenant_job: Dict[str, int] = {}   # live tenant -> job id
        # Job ids are never reused, so job -> tenant is PERMANENT — a
        # retired tenant's in-flight round still finishes (and must still
        # be attributed) after its slot is released.
        self._job_tenant: Dict[int, str] = {}
        self._tenant_template: Dict[str, int] = {}
        self._tenant_saved: Dict[str, dict] = {}  # retired -> per-job state
        self._queue: List[str] = []             # tenants waiting for a slot
        # Incremental rescoring memo: job -> ((pool.version, round_idx), cost)
        self._rescore_cache: Dict[int, tuple] = {}
        # Advisory mean rescore cost per admission (the bench's parity data).
        self.rescore_costs: List[float] = []
        self._cold = (self._make_cold_scheduler()
                      if rescore_mode == "full" else None)
        self.last_report: Optional[ServiceReport] = None

    # ---- crash-consistent persistence ----

    @classmethod
    def resume(cls, checkpoint_dir: str, verbose: bool = False,
               crash_after: Optional[int] = None) -> "SchedulerService":
        """Rebuild a service from the newest committed checkpoint and
        position it at the saved event boundary; a subsequent ``run()``
        continues the SAME trajectory bit-for-bit."""
        from repro.serve.persistence import (read_manifest_extra,
                                             restore_service)

        extra = read_manifest_extra(checkpoint_dir)
        svc = cls(ExperimentSpec.from_dict(extra["spec"]),
                  rescore_mode=extra["rescore_mode"], verbose=verbose,
                  checkpoint_dir=checkpoint_dir,
                  checkpoint_every=int(extra["checkpoint_every"]),
                  crash_after=crash_after)
        restore_service(svc, checkpoint_dir)
        return svc

    # ---- construction helpers ----

    def _fresh_engine(self) -> MultiJobEngine:
        """Build the construction-time engine skeleton (also the watchdog-
        recovery rebuild path): template jobs parked — they exist so
        build()/calibration see a valid job mix, but only
        arrival-instantiated jobs ever run — and the done-callback wired."""
        eng = self.spec.build().engine
        for js in eng.jobs:
            js.parked = True
            js.done = True
        eng.on_job_done = self._on_job_done
        return eng

    def _make_cold_scheduler(self):
        """A second scheduler instance for the ``full`` ablation: same
        registry entry and knobs, own seed/rng (so its advisory searches
        never perturb the live scheduler's decision stream), and no
        pre-training (RLDS) — it re-searches from the current world state,
        which is the point."""
        from repro.experiment.registry import SCHEDULERS

        spec = self.spec
        kwargs = {"cost_model": self.engine.cost_model,
                  "seed": spec.scheduler_seed + 10_000,
                  **spec._candidate_kwargs(),
                  **dict(spec.scheduler_kwargs)}
        if "pretrain_rounds" in spec._scheduler_params():
            kwargs["pretrain_rounds"] = 0
        return SCHEDULERS.create(spec.scheduler, **kwargs)

    # ---- engine callbacks ----

    def _on_round(self, rec: RoundRecord) -> None:
        self.metrics.rounds_completed += 1
        if rec.rung is not None and rec.rung != "full":
            self.metrics.degraded_rounds += 1
        tenant = self._job_tenant.get(rec.job)
        gov = self.engine.governor
        if gov is not None and gov.breakers is not None:
            # Simulated-time breaker feedback: the round's end instant.
            for ch in gov.note_round(rec, tenant, rec.t_end):
                if ch["state"] == "open":
                    self.metrics.breaker_trips += 1
                if self.engine.events is not None:
                    self.engine.events.publish("serve.breaker", ch)
        if tenant is None:
            return
        ts = self.metrics.tenants[tenant]
        ts.rounds += 1
        ts.total_cost += rec.cost
        ts.total_round_time += rec.round_time
        ts.last_fairness = rec.fairness
        ts.best_accuracy = max(ts.best_accuracy, rec.accuracy)

    def _on_job_done(self, job: int, now: float) -> None:
        """Engine signal: a job finished naturally (target/max_rounds) —
        free its admission slot and drain the queue."""
        self._release(job, now)

    # ---- admission control ----

    def _sync_queue_depth(self) -> None:
        """Mirror the admission queue into the governor (its deterministic
        queue-pressure input for the degradation ladder)."""
        gov = self.engine.governor
        if gov is not None:
            gov.queue_depth = len(self._queue)

    def _latency_pressure(self) -> bool:
        """Is the rolling p99 decision latency over the SLO deadline? (The
        wall-clock admission-backpressure signal; False without a
        deadline.)"""
        slo = self._slo
        if slo is None or slo.decision_deadline_ms is None:
            return False
        gov = self.engine.governor
        return gov is not None and gov.rolling_p99() > slo.decision_deadline_ms

    def _shed(self, tenant: str, now: float, reason: str) -> None:
        self.metrics.shed_arrivals += 1
        if self.engine.events is not None:
            self.engine.events.publish("serve.shed", dict(
                tenant=tenant, t=now, reason=reason, action="shed",
                queue_depth=len(self._queue)))
        if self.verbose:
            print(f"[t={now:9.1f}s] shed   {tenant} ({reason})")

    def _release(self, job: int, now: float) -> None:
        tenant = self._job_tenant.get(job)
        if tenant is not None and self._tenant_job.get(tenant) == job:
            self._tenant_job.pop(tenant)
        self._live.discard(job)
        self._rescore_cache.pop(job, None)
        self._drain_queue(now)

    def _drain_queue(self, now: float, force: bool = False) -> None:
        force = force or self._draining
        while self._queue and len(self._live) < self.spec.arrivals.max_concurrent:
            if not force and self._latency_pressure():
                # Overload: keep deferring even though a slot is free; the
                # post-trace drain (and any later release once the window
                # cools) picks the queue back up.
                break
            # Least-served first: the tenant with the fewest rounds across
            # ALL its admissions gets the freed slot.
            self._queue.sort(key=lambda t: self.metrics.tenants[t].rounds)
            tenant = self._queue.pop(0)
            queued_at = self.metrics.tenants[tenant].queued_at
            if queued_at is not None:
                wait = float(now - queued_at)
                instant("queue_wait", tenant=tenant, wait_s=wait)
                if self.engine.events is not None:
                    self.engine.events.publish("serve.queue_wait", dict(
                        tenant=tenant, t=now, wait_s=wait))
            self.metrics.tenants[tenant].queued_at = None
            self._sync_queue_depth()
            self._admit(tenant, self._tenant_template[tenant], now)
        self._sync_queue_depth()

    def _admit(self, tenant: str, template: int, now: float) -> None:
        t0 = time.perf_counter()
        self._rescore(now)
        eng = self.engine
        job = eng.add_job(self.templates[template],
                          data_sizes=self.template_data[template],
                          now=now, launch=False)
        saved = self._tenant_saved.pop(tenant, None)
        if saved is not None:
            # Warm hand-off: the tenant's history lands under its NEW job
            # id before the first decision is made.
            eng.scheduler.load_job_state(job, saved)
            self.metrics.readmissions += 1
        eng.launch_job(job, now)
        self.metrics.decision_latency.add(time.perf_counter() - t0)
        self.metrics.decisions += 1
        self._live.add(job)
        self._tenant_job[tenant] = job
        self._job_tenant[job] = tenant
        self.metrics.tenants[tenant].admissions += 1
        if eng.events is not None:
            eng.events.publish("serve.admit", dict(
                tenant=tenant, job=job, template=template, t=now,
                live=len(self._live), warm=saved is not None))
        if self.verbose:
            print(f"[t={now:9.1f}s] admit  {tenant} -> job{job} "
                  f"(template {template}, live={len(self._live)})")

    # ---- incremental plan rescoring ----

    def _rescore(self, now: float) -> Dict[int, float]:
        """Advisory cost estimate of every live job's plan under the
        current world state — the admission decision's inputs."""
        eng = self.engine
        costs: Dict[int, float] = {}
        with span("rescore", mode=self.rescore_mode, live=len(self._live)):
            for job in sorted(self._live):
                if eng.jobs[job].done:
                    continue
                if self.rescore_mode == "incremental":
                    key = (eng.pool.version, eng.jobs[job].round_idx)
                    cached = self._rescore_cache.get(job)
                    if cached is not None and cached[0] == key:
                        costs[job] = cached[1]
                        continue
                    # Score the job's CURRENT plan under the post-churn time
                    # model — wait-free (its own devices are mid-round busy;
                    # full-search also plans over wait-free devices, so this
                    # is the comparable quantity). ``pool.expected_times`` is
                    # the per-(job, tau) memo that churn invalidation
                    # refreshes: unchanged world -> pure cache lookups end to
                    # end.
                    cm = eng.cost_model
                    tau = eng.jobs[job].config.local_epochs
                    times = eng.pool.expected_times(job, tau)
                    f = eng._in_flight.get(job)
                    if f is not None:
                        plan = f["plan"]
                    else:
                        # Between rounds (waiting on a retry): cheapest-n
                        # closed-form stand-in.
                        plan = np.zeros(eng.pool.num_devices, dtype=bool)
                        plan[np.argsort(times)[: eng.n_sel]] = True
                    c = float(cm.total_cost_batch(
                        job=job, tau=tau, counts=eng.counts[job],
                        plans=plan[None], other_costs=0.0, times=times)[0])
                    self._rescore_cache[job] = (key, c)
                    costs[job] = c
                else:
                    self._cold.ensure_jobs(len(eng.jobs))
                    ctx = eng._make_ctx(job, now)
                    self._cold.schedule(ctx)
                    est = self._cold.last_estimated_cost
                    costs[job] = float(est) if est is not None else 0.0
        self.rescore_costs.append(
            float(np.mean(list(costs.values()))) if costs else 0.0)
        return costs

    # ---- traffic handling ----

    def _handle(self, ev: TrafficEvent) -> None:
        now = ev.t
        eng = self.engine
        if ev.kind == "arrive":
            self.metrics.arrivals += 1
            template = (ev.template if ev.template is not None
                        else self._tenant_template.get(ev.tenant, 0))
            self._tenant_template[ev.tenant] = template
            self.metrics.tenant(ev.tenant, template)
            if ev.tenant in self._tenant_job or ev.tenant in self._queue:
                return  # duplicate arrival of a live/queued tenant
            slo = self._slo
            gov = eng.governor
            # Circuit breaker: an open tenant breaker sheds the arrival
            # outright (allow() also grants the half-open probe admission).
            if (gov is not None and gov.breakers is not None
                    and not gov.breakers.tenant(ev.tenant).allow(now)):
                self._shed(ev.tenant, now, "breaker_open")
                return
            # Queue-depth bound: beyond it the arrival is shed, not queued.
            if (slo is not None and slo.max_queue_depth is not None
                    and len(self._queue) >= slo.max_queue_depth):
                self._shed(ev.tenant, now, "queue_full")
                return
            if len(self._live) < self.spec.arrivals.max_concurrent:
                if self._latency_pressure():
                    # Rolling p99 over the deadline: the decision path is
                    # overloaded, so don't add work even though a slot is
                    # free — defer (queue) or shed per policy.
                    if slo.shed_policy == "shed":
                        self._shed(ev.tenant, now, "latency")
                        return
                    self.metrics.deferrals += 1
                    self.metrics.tenants[ev.tenant].queued_at = now
                    self._queue.append(ev.tenant)
                    self._sync_queue_depth()
                    if eng.events is not None:
                        eng.events.publish("serve.shed", dict(
                            tenant=ev.tenant, t=now, reason="latency",
                            action="defer", queue_depth=len(self._queue)))
                    if self.verbose:
                        print(f"[t={now:9.1f}s] defer  {ev.tenant} "
                              f"(depth={len(self._queue)})")
                    return
                self._admit(ev.tenant, template, now)
            else:
                self.metrics.rejections += 1
                self.metrics.tenants[ev.tenant].queued_at = now
                self._queue.append(ev.tenant)
                self._sync_queue_depth()
                if self.verbose:
                    print(f"[t={now:9.1f}s] queue  {ev.tenant} "
                          f"(depth={len(self._queue)})")
        elif ev.kind == "depart":
            self.metrics.departures += 1
            if ev.tenant in self._queue:
                self._queue.remove(ev.tenant)
                self._sync_queue_depth()
                return
            job = self._tenant_job.get(ev.tenant)
            if job is None:
                return  # already finished (slot released via on_job_done)
            self._tenant_saved[ev.tenant] = eng.scheduler.job_state_dict(job)
            eng.retire_job(job, now=now)
            if eng.events is not None:
                eng.events.publish("serve.depart", dict(
                    tenant=ev.tenant, job=job, t=now))
            if self.verbose:
                print(f"[t={now:9.1f}s] retire {ev.tenant} (job{job})")
            self._release(job, now)
        elif ev.kind == "churn_out":
            self.metrics.churn_events += 1
            eng.pool.depart(ev.devices)
            if eng.events is not None:
                eng.events.publish("serve.churn", dict(
                    kind="out", t=now, n=len(ev.devices)))
        elif ev.kind == "churn_in":
            self.metrics.churn_events += 1
            if ev.drift != 1.0:
                ids = np.asarray(ev.devices)
                eng.pool.rejoin(ids, a=eng.pool.a[ids] * ev.drift)
            else:
                eng.pool.rejoin(ev.devices)
            if eng.events is not None:
                eng.events.publish("serve.churn", dict(
                    kind="in", t=now, n=len(ev.devices), drift=ev.drift))

    # ---- the event loop ----

    def run(self, trace: Optional[List[TrafficEvent]] = None
            ) -> ServiceReport:
        """Sustain the traffic stream end-to-end: for each traffic event,
        advance the engine's internal heap up to the event's timestamp,
        apply the event, then drain the remaining rounds. Returns the
        service report; per-job engine summaries stay on
        ``self.engine.summary()``."""
        arr = self.spec.arrivals
        if trace is None:
            # A resumed service replays ITS OWN saved trace (regenerating
            # would fork the trajectory if the spec's seed axis changed).
            trace = self.trace if self.trace is not None else trace_from_spec(
                arr, len(self.templates), self.engine.pool.num_devices)
        self.trace = trace
        t0 = time.perf_counter()
        try:
            # While-loop over the resume cursor (not a range): watchdog
            # recovery rewinds ``_next_event`` and swaps ``self.engine``
            # mid-run, so both are re-read every iteration.
            while self._next_event < len(self.trace):
                eng = self.engine
                i = self._next_event
                ev = self.trace[i]
                with span("serve_advance", until=ev.t):
                    eng.advance_until(ev.t, on_round=self._on_round)
                with span("handle_event", kind=ev.kind):
                    self._handle(ev)
                self.metrics.events_processed += 1
                self.metrics.sample_queue_depth(len(self._queue))
                self._next_event = i + 1
                if (self._ckpt_manager is not None
                        and self.checkpoint_every > 0
                        and self._next_event % self.checkpoint_every == 0):
                    from repro.serve.persistence import save_service_checkpoint

                    with span("checkpoint_write", step=self._next_event):
                        save_service_checkpoint(self, self._next_event)
                    if eng.events is not None:
                        eng.events.publish("serve.checkpoint", dict(
                            step=self._next_event, t=ev.t))
                if (self.crash_after is not None
                        and self._next_event >= self.crash_after):
                    raise SimulatedCrash(
                        f"crash_after={self.crash_after}: simulated hard "
                        f"kill after event {self._next_event}")
                if self._watchdog is not None:
                    self._watchdog_tick(ev.t)
            # Drain: live jobs run to completion; finishing jobs release
            # slots, which admits queued tenants mid-drain (on_job_done
            # fires inside advance_until, so late admissions still execute).
            # ``_draining`` lifts the p99 deferral hold first.
            self._draining = True
            self._drain_queue(self.engine.clock, force=True)
            with span("serve_advance", until=float("inf")):
                self.engine.advance_until(np.inf, on_round=self._on_round)
        finally:
            # The spec's obs axis hung a session on the engine at build();
            # the service owns the run, so it finalizes (trace write + sink
            # close) even on a simulated crash.
            if self.engine.obs is not None:
                self.engine.obs.close()
        self.last_report = self.metrics.report(
            sim_horizon=arr.horizon, wall_s=time.perf_counter() - t0,
            resilience=self.resilience_summary())
        return self.last_report

    # ---- watchdog recovery ----

    def _watchdog_tick(self, now: float) -> None:
        wedged = self._watchdog.check(self.engine)
        if not wedged:
            return
        eng = self.engine
        if eng.events is not None:
            eng.events.publish("serve.stall", dict(
                jobs=list(wedged), t=now,
                recoveries=self.metrics.recoveries))
        can_restore = (self._ckpt_manager is not None
                       and self.metrics.recoveries < self._slo.max_recoveries)
        if can_restore:
            from repro.checkpoint import committed_steps

            can_restore = bool(committed_steps(self.checkpoint_dir))
        if can_restore:
            self._recover(now, wedged)
        else:
            # No committed snapshot (or recovery budget exhausted): best
            # effort — push the wedged jobs back into the event loop.
            warnings.warn(
                f"watchdog: jobs {wedged} stalled with no usable checkpoint "
                "(or max_recoveries reached); re-launching them in place",
                RuntimeWarning)
            for j in wedged:
                eng._launch(j, max(eng.clock, now))
            self._watchdog.reset()

    def _recover(self, now: float, wedged: List[int]) -> None:
        """Rebuild the engine skeleton and restore the newest committed
        checkpoint IN PLACE, rewinding the traffic cursor to the saved
        boundary — the run loop then replays forward deterministically."""
        from repro.serve.persistence import restore_service

        warnings.warn(
            f"watchdog: jobs {wedged} stalled for "
            f"{self._slo.watchdog_rounds} checks; restoring from the newest "
            f"checkpoint in {self.checkpoint_dir}", RuntimeWarning)
        if self.engine.obs is not None:
            self.engine.obs.close()
        self.engine = self._fresh_engine()
        if self.rescore_mode == "full":
            self._cold = self._make_cold_scheduler()
        # Reset the dynamic maps to construction state so restore_service
        # re-adds the arrival-instantiated jobs onto a clean skeleton.
        self._live = set()
        self._queue = []
        self._tenant_job = {}
        self._job_tenant = {}
        self._tenant_template = {}
        self._tenant_saved = {}
        self._rescore_cache = {}
        step = restore_service(self, self.checkpoint_dir)
        self.metrics.recoveries += 1
        self._watchdog.reset()
        self._sync_queue_depth()
        if self.engine.events is not None:
            self.engine.events.publish("serve.recovered", dict(
                t=now, step=step, jobs=list(wedged),
                recoveries=self.metrics.recoveries))
        if self.verbose:
            print(f"[t={now:9.1f}s] recovered from checkpoint step {step} "
                  f"(stalled jobs {wedged})")

    # ---- resilience reporting ----

    def resilience_summary(self) -> Optional[dict]:
        """Degradation/shed/breaker/recovery accounting for the report
        (None when the SLO axis is off)."""
        gov = self.engine.governor
        if gov is None and self._slo is None:
            return None
        out = gov.summary() if gov is not None else {}
        out.update(
            shed_arrivals=self.metrics.shed_arrivals,
            deferrals=self.metrics.deferrals,
            recoveries=self.metrics.recoveries,
            breaker_trips=self.metrics.breaker_trips,
            degraded_rounds=self.metrics.degraded_rounds)
        return out
