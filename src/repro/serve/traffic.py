"""Traffic generation for the online scheduler service.

A trace is a time-sorted list of ``TrafficEvent``s — the EXTERNAL world the
service reacts to (what the engine's own event heap is to the internal
world). Three kinds:

- ``arrive``     — tenant submits a job built from catalogue template
                   ``template``; if the tenant departed earlier, this is a
                   READMISSION and the scheduler's per-job state follows it.
- ``depart``     — tenant voluntarily retires its job (mid-run churn, as
                   opposed to finishing by target/max_rounds).
- ``churn_out``  — ``devices`` leave the fleet.
- ``churn_in``   — those devices rejoin, capabilities drifted by ``drift``
                   (multiplier on the per-sample cost floor ``a``).

Traces are JSON-serializable (``save_trace``/``load_trace``) so a generated
stream can be replayed bit-identically across service configurations — the
incremental-vs-full rescoring benchmark depends on this.
"""

from __future__ import annotations

import dataclasses
import json
from typing import List, Optional, Sequence

import numpy as np

from repro.experiment.spec import ArrivalsSpec

EVENT_KINDS = ("arrive", "depart", "churn_out", "churn_in")


@dataclasses.dataclass
class TrafficEvent:
    t: float                              # simulated seconds
    kind: str                             # one of EVENT_KINDS
    tenant: Optional[str] = None          # arrive/depart
    template: Optional[int] = None        # arrive: index into spec.jobs
    devices: Optional[List[int]] = None   # churn_out/churn_in
    drift: float = 1.0                    # churn_in: multiplier on ``a``

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {self.kind!r}")

    def to_dict(self) -> dict:
        d = {"t": self.t, "kind": self.kind}
        if self.tenant is not None:
            d["tenant"] = self.tenant
        if self.template is not None:
            d["template"] = self.template
        if self.devices is not None:
            d["devices"] = [int(k) for k in self.devices]
        if self.drift != 1.0:
            d["drift"] = self.drift
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TrafficEvent":
        return cls(t=float(d["t"]), kind=d["kind"], tenant=d.get("tenant"),
                   template=d.get("template"), devices=d.get("devices"),
                   drift=float(d.get("drift", 1.0)))


def save_trace(events: Sequence[TrafficEvent], path: str) -> None:
    with open(path, "w") as f:
        json.dump([e.to_dict() for e in events], f, indent=2)
        f.write("\n")


def load_trace(path: str) -> List[TrafficEvent]:
    with open(path) as f:
        return [TrafficEvent.from_dict(d) for d in json.load(f)]


def poisson_trace(arrivals: ArrivalsSpec, num_templates: int,
                  num_devices: int) -> List[TrafficEvent]:
    """Seeded synthetic stream: Poisson job arrivals (exponential
    interarrivals), optional exponential tenant lifetimes with probabilistic
    readmission, and periodic device-churn out/in pairs. Deterministic in
    ``arrivals.seed`` — equal specs yield equal traces."""
    rng = np.random.default_rng(arrivals.seed)
    events: List[TrafficEvent] = []

    t, n = 0.0, 0
    while True:
        t += float(rng.exponential(arrivals.interarrival))
        if t >= arrivals.horizon:
            break
        tenant = f"tenant-{n:03d}"
        n += 1
        template = int(rng.integers(num_templates))
        events.append(TrafficEvent(t=t, kind="arrive", tenant=tenant,
                                   template=template))
        if arrivals.mean_lifetime is not None:
            t_dep = t + float(rng.exponential(arrivals.mean_lifetime))
            if t_dep < arrivals.horizon:
                events.append(TrafficEvent(t=t_dep, kind="depart",
                                           tenant=tenant))
                if rng.random() < arrivals.readmit_prob:
                    t_re = t_dep + float(
                        rng.exponential(arrivals.interarrival))
                    if t_re < arrivals.horizon:
                        # Same tenant, same template: the service hands the
                        # scheduler's per-job state across the gap.
                        events.append(TrafficEvent(
                            t=t_re, kind="arrive", tenant=tenant,
                            template=template))

    if arrivals.churn_interarrival is not None:
        n_out = max(1, int(round(arrivals.churn_fraction * num_devices)))
        t = 0.0
        while True:
            t += float(rng.exponential(arrivals.churn_interarrival))
            if t >= arrivals.horizon:
                break
            devs = rng.choice(num_devices, size=n_out, replace=False)
            devs = [int(k) for k in devs]
            events.append(TrafficEvent(t=t, kind="churn_out", devices=devs))
            events.append(TrafficEvent(t=t + arrivals.rejoin_after,
                                       kind="churn_in", devices=devs,
                                       drift=arrivals.drift))

    events.sort(key=lambda e: (e.t, EVENT_KINDS.index(e.kind)))
    return events


def trace_from_spec(arrivals: ArrivalsSpec, num_templates: int,
                    num_devices: int) -> List[TrafficEvent]:
    """Dispatch on ``arrivals.mode``: generate (poisson) or replay (trace)."""
    if arrivals.mode == "poisson":
        return poisson_trace(arrivals, num_templates, num_devices)
    if arrivals.mode == "trace":
        if not arrivals.trace_path:
            raise ValueError('arrivals.mode="trace" needs trace_path')
        return load_trace(arrivals.trace_path)
    raise ValueError(f"unknown arrivals mode {arrivals.mode!r}")
