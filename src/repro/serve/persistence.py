"""Crash-consistent scheduler-service checkpoints.

The service's full state splits the same way the engine's does (see
``MultiJobEngine.state_arrays``/``state_meta``): an ARRAY half persisted as
an atomic ``repro.checkpoint`` pytree (fairness counts, in-flight round
arrays, fault-quarantine strikes, pool coefficients/occupancy, scheduler
learned state, runtime convergence state, retired tenants' warm hand-off
slices) and a JSON half riding in the manifest's ``extra`` (the spec, the
traffic trace, the engine's event heap and clock, every RNG's bit-generator
state, round records, service maps, metrics counters).

Resume contract: ``restore_service`` rebuilds the construction-time
skeleton from the spec (templates parked, dynamic jobs re-added from their
templates in id order — every per-job row then has the saved shape), loads
the newest COMMITTED step, and overwrites all mutable state. Because the
fault schedule, traffic trace, and every RNG are replayed/restored exactly,
a service killed mid-run (``kill -9`` included — saves are atomic
tmp+rename) resumes BIT-IDENTICALLY: same rounds, same plans, same metrics.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

from repro.checkpoint import committed_steps, load_checkpoint
from repro.experiment.spec import _record_from_dict, _record_to_dict
from repro.serve.traffic import TrafficEvent

_INFLIGHT_DTYPES = dict(
    plan=bool, survivors=int, counted=int, failed=int, dropped=int,
    corrupt=int, ctx_available=bool, ctx_counts=np.float64,
    ctx_times=np.float64)


def _runtime_state(runtime) -> dict:
    sd = getattr(runtime, "state_dict", None)
    return sd() if sd is not None else {}


def service_state(service) -> Tuple[dict, dict]:
    """(tree, extra): the array pytree and its JSON sidecar."""
    eng = service.engine
    tree = {
        "engine": eng.state_arrays(),
        "pool": eng.pool.state_dict(),
        "scheduler": eng.scheduler.state_dict(),
        "runtime": _runtime_state(eng.runtime),
        "cold": (service._cold.state_dict()
                 if service._cold is not None else {}),
        "tenant_saved": {t: dict(s)
                         for t, s in sorted(service._tenant_saved.items())},
    }
    rt_rng = getattr(eng.runtime, "rng", None)
    extra = {
        "spec": service.spec.to_dict(),
        "rescore_mode": service.rescore_mode,
        "checkpoint_every": service.checkpoint_every,
        "next_event": service._next_event,
        "trace": [ev.to_dict() for ev in service.trace],
        "engine_meta": eng.state_meta(),
        "pool_rng": eng.pool.rng.bit_generator.state,
        "sched_rng": eng.scheduler.rng.bit_generator.state,
        "runtime_rng": (rt_rng.bit_generator.state
                        if rt_rng is not None else None),
        "cold_rng": (service._cold.rng.bit_generator.state
                     if service._cold is not None else None),
        "records": [_record_to_dict(r) for r in eng.records],
        "metrics": service.metrics.to_state(),
        "live": sorted(service._live),
        "queue": list(service._queue),
        "tenant_job": dict(service._tenant_job),
        "job_tenant": {str(j): t for j, t in service._job_tenant.items()},
        "tenant_template": dict(service._tenant_template),
        "rescore_costs": list(service.rescore_costs),
        "num_templates": len(service.templates),
        # Stateless schedulers save EMPTY per-tenant slices (no array
        # leaves), so the tenant list must ride here for the like-tree.
        "tenant_saved_keys": sorted(service._tenant_saved),
    }
    # SLO resilience state (``repro.serve.resilience``): last-good plans,
    # rung/shed counters, breaker board, watchdog stall counts — all JSON.
    # Wall-clock latency windows are deliberately NOT persisted (they are
    # not replayable); they re-fill after resume.
    resilience = {}
    if eng.governor is not None:
        resilience["governor"] = eng.governor.state_dict()
    wd = getattr(service, "_watchdog", None)
    if wd is not None:
        resilience["watchdog"] = wd.state_dict()
    if resilience:
        extra["resilience"] = resilience
    return tree, extra


def save_service_checkpoint(service, event_idx: int) -> str:
    """Atomically persist the service at an event boundary (step =
    number of traffic events already applied)."""
    tree, extra = service_state(service)
    if service._ckpt_manager is None:
        raise ValueError("service has no checkpoint_dir")
    return service._ckpt_manager.save(event_idx, tree, extra)


def read_manifest_extra(directory: str, step: Optional[int] = None) -> dict:
    """The JSON half of the newest (or given) committed step — enough to
    rebuild the construction-time skeleton before touching any arrays."""
    import json
    import os

    from repro.checkpoint import step_path

    steps = committed_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints in {directory}")
    step = steps[-1] if step is None else step
    with open(os.path.join(step_path(directory, step), "manifest.json")) as f:
        return json.load(f)["extra"]


def _like_tree(service, extra: dict) -> dict:
    """A structural twin of the saved tree built from the REBUILT skeleton
    (leaf shapes are irrelevant — ``load_checkpoint`` takes shapes from the
    stored arrays and only dtypes/structure from ``like``)."""
    eng = service.engine
    like_engine = eng.state_arrays()   # fresh skeleton: inflight is empty
    like_engine["inflight"] = {
        key: {k: np.zeros(0, dt) for k, dt in _INFLIGHT_DTYPES.items()}
        for key in extra["engine_meta"]["inflight"]}
    sched = eng.scheduler
    like = {
        "engine": like_engine,
        "pool": eng.pool.state_dict(),
        "scheduler": sched.state_dict(),
        "runtime": _runtime_state(eng.runtime),
        "cold": (service._cold.state_dict()
                 if service._cold is not None else {}),
        # Any job's slice has the per-job structure (shapes don't matter).
        "tenant_saved": {t: dict(sched.job_state_dict(0))
                         for t in extra["tenant_saved_keys"]},
    }
    return jax.tree_util.tree_map(np.asarray, like)


def restore_service(service, directory: str,
                    step: Optional[int] = None) -> int:
    """Load the newest (or given) committed step into an already-constructed
    service whose skeleton matches (same spec, dynamic jobs re-added).
    Returns the restored step (= events already applied)."""
    extra = read_manifest_extra(directory, step)
    eng = service.engine

    # Re-add the dynamic (arrival-instantiated) jobs in id order so every
    # per-job row — pool column, counts, scheduler ring, runtime row —
    # exists with the saved shape before any array lands.
    n_templates = int(extra["num_templates"])
    n_jobs = len(extra["engine_meta"]["jobs"])
    job_tenant = {int(j): t for j, t in extra["job_tenant"].items()}
    for j in range(n_templates, n_jobs):
        template = int(extra["tenant_template"][job_tenant[j]])
        jid = eng.add_job(service.templates[template],
                          data_sizes=service.template_data[template],
                          launch=False)
        assert jid == j, (jid, j)

    step, tree, _ = load_checkpoint(directory, _like_tree(service, extra),
                                    step=step)

    eng.pool.load_state_dict(tree["pool"])
    eng.pool.rng.bit_generator.state = extra["pool_rng"]
    eng.load_state(tree["engine"], extra["engine_meta"])
    eng.scheduler.load_state_dict(tree["scheduler"])
    eng.scheduler.rng.bit_generator.state = extra["sched_rng"]
    if tree["runtime"]:
        eng.runtime.load_state_dict(tree["runtime"])
    if extra["runtime_rng"] is not None:
        eng.runtime.rng.bit_generator.state = extra["runtime_rng"]
    if service._cold is not None:
        if tree["cold"]:
            service._cold.load_state_dict(tree["cold"])
        if extra["cold_rng"] is not None:
            service._cold.rng.bit_generator.state = extra["cold_rng"]
    eng.records = [_record_from_dict(d) for d in extra["records"]]

    service.metrics.load_state(extra["metrics"])
    service._live = set(int(j) for j in extra["live"])
    service._queue = list(extra["queue"])
    service._tenant_job = {t: int(j)
                           for t, j in extra["tenant_job"].items()}
    service._job_tenant = job_tenant
    service._tenant_template = {t: int(v) for t, v
                                in extra["tenant_template"].items()}
    service._tenant_saved = {t: dict(tree["tenant_saved"].get(t, {}))
                             for t in extra["tenant_saved_keys"]}
    service.rescore_costs = list(extra["rescore_costs"])
    service._rescore_cache = {}   # memo of pure functions: rebuilt on miss
    service.trace = [TrafficEvent.from_dict(d) for d in extra["trace"]]
    service._next_event = int(extra["next_event"])

    # SLO resilience state (.get: pre-SLO checkpoints lack the key).
    resilience = extra.get("resilience") or {}
    if eng.governor is not None and resilience.get("governor") is not None:
        eng.governor.load_state_dict(resilience["governor"])
    wd = getattr(service, "_watchdog", None)
    if wd is not None and resilience.get("watchdog") is not None:
        wd.load_state_dict(resilience["watchdog"])
    sync = getattr(service, "_sync_queue_depth", None)
    if sync is not None:
        sync()

    # Re-announce in-flight cohorts to batching runtimes (the pre-crash
    # announcement died with the process; SyntheticRuntime has no hook).
    begin = getattr(eng.runtime, "begin_round", None)
    if begin is not None:
        for job, f in eng._in_flight.items():
            begin(job, f["survivors"], eng.jobs[job].round_idx)
    return step
