"""Service-side observability: decision latency, throughput, queue depth,
per-tenant accounting.

Decision latency here is WALL-CLOCK time of the scheduler-facing work the
service performs per traffic event (admission rescoring, plan search) — the
quantity an online deployment must bound — while everything else in the
simulator runs on simulated seconds. ``LatencyStats`` keeps raw samples (the
streams are short: one per traffic event) and reports p50/p99.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class LatencyStats:
    """Raw wall-clock samples (seconds) with percentile summaries."""

    samples: List[float] = dataclasses.field(default_factory=list)

    def add(self, seconds: float) -> None:
        self.samples.append(float(seconds))

    @property
    def count(self) -> int:
        return len(self.samples)

    def percentile(self, q: float) -> float:
        if not self.samples:
            return 0.0
        return float(np.percentile(np.asarray(self.samples), q))

    @property
    def p50(self) -> float:
        return self.percentile(50.0)

    @property
    def p99(self) -> float:
        return self.percentile(99.0)

    @property
    def mean(self) -> float:
        return float(np.mean(self.samples)) if self.samples else 0.0

    @property
    def total(self) -> float:
        return float(np.sum(self.samples)) if self.samples else 0.0

    def to_dict(self) -> dict:
        return {"count": self.count, "p50_s": self.p50, "p99_s": self.p99,
                "mean_s": self.mean, "total_s": self.total}


def jain_fairness(values: np.ndarray) -> float:
    """Jain's index over per-tenant service shares: 1 = perfectly even,
    1/n = one tenant got everything. Empty/zero input -> 1.0."""
    v = np.asarray(values, dtype=np.float64)
    if v.size == 0:
        return 1.0
    s = float(v.sum())
    if s <= 0.0:
        return 1.0
    return float(s * s / (v.size * float((v * v).sum())))


@dataclasses.dataclass
class TenantStats:
    """Per-tenant service accounting (accumulated over all of the tenant's
    jobs, including across a retire/readmit cycle)."""

    tenant: str
    template: int
    rounds: int = 0
    total_cost: float = 0.0
    total_round_time: float = 0.0
    last_fairness: float = 0.0
    best_accuracy: float = 0.0
    admissions: int = 0
    queued_at: Optional[float] = None   # transient: waiting for a slot

    def to_dict(self) -> dict:
        return {"tenant": self.tenant, "template": self.template,
                "rounds": self.rounds, "total_cost": self.total_cost,
                "total_round_time": self.total_round_time,
                "mean_cost": (self.total_cost / self.rounds
                              if self.rounds else 0.0),
                "best_accuracy": self.best_accuracy,
                "admissions": self.admissions}


@dataclasses.dataclass
class ServiceMetrics:
    """Mutable accumulator the service writes into as it runs."""

    decision_latency: LatencyStats = dataclasses.field(
        default_factory=LatencyStats)
    tenants: Dict[str, TenantStats] = dataclasses.field(default_factory=dict)
    queue_depth_samples: List[int] = dataclasses.field(default_factory=list)
    events_processed: int = 0
    arrivals: int = 0
    departures: int = 0
    readmissions: int = 0
    rejections: int = 0        # queued because the budget was full
    churn_events: int = 0
    rounds_completed: int = 0
    decisions: int = 0         # admission rescoring passes
    # SLO resilience accounting (the ``slo`` axis; all 0 without it).
    shed_arrivals: int = 0     # dropped: breaker open / queue full / latency
    deferrals: int = 0         # queued despite a free slot (p99 pressure)
    recoveries: int = 0        # watchdog checkpoint restores
    breaker_trips: int = 0     # breaker open transitions observed
    degraded_rounds: int = 0   # rounds whose plan came from a non-full rung

    def tenant(self, name: str, template: int) -> TenantStats:
        ts = self.tenants.get(name)
        if ts is None:
            ts = self.tenants[name] = TenantStats(tenant=name,
                                                  template=template)
        return ts

    def sample_queue_depth(self, depth: int) -> None:
        self.queue_depth_samples.append(int(depth))

    # ---- persistence (crash-consistent service resume) ----

    _COUNTERS = ("events_processed", "arrivals", "departures", "readmissions",
                 "rejections", "churn_events", "rounds_completed", "decisions",
                 "shed_arrivals", "deferrals", "recoveries", "breaker_trips",
                 "degraded_rounds")

    def to_state(self) -> dict:
        """Full mutable state as a JSON-serializable dict (raw latency and
        queue-depth samples included, so a resumed run's report percentiles
        match an uninterrupted one's — modulo wall-clock latency noise)."""
        return {
            **{k: getattr(self, k) for k in self._COUNTERS},
            "latency_samples": list(self.decision_latency.samples),
            "queue_depth_samples": list(self.queue_depth_samples),
            "tenants": [dataclasses.asdict(t) for t in self.tenants.values()],
        }

    def load_state(self, state: dict) -> None:
        # .get: checkpoints written before the SLO axis lack its counters.
        for k in self._COUNTERS:
            setattr(self, k, int(state.get(k, 0)))
        self.decision_latency = LatencyStats(
            samples=[float(s) for s in state["latency_samples"]])
        self.queue_depth_samples = [int(s)
                                    for s in state["queue_depth_samples"]]
        self.tenants = {d["tenant"]: TenantStats(**d)
                        for d in state["tenants"]}

    def report(self, sim_horizon: float, wall_s: float,
               resilience: Optional[dict] = None) -> "ServiceReport":
        rounds = np.asarray(
            [t.rounds for t in self.tenants.values()], dtype=np.float64)
        return ServiceReport(
            resilience=resilience,
            decision_latency=self.decision_latency.to_dict(),
            decisions_per_sec=(self.decisions / wall_s if wall_s > 0 else 0.0),
            rounds_per_sec=(self.rounds_completed / wall_s
                            if wall_s > 0 else 0.0),
            queue_depth_max=(max(self.queue_depth_samples)
                             if self.queue_depth_samples else 0),
            queue_depth_mean=(float(np.mean(self.queue_depth_samples))
                              if self.queue_depth_samples else 0.0),
            tenant_fairness=jain_fairness(rounds),
            tenants={k: t.to_dict() for k, t in self.tenants.items()},
            events_processed=self.events_processed,
            arrivals=self.arrivals, departures=self.departures,
            readmissions=self.readmissions, rejections=self.rejections,
            churn_events=self.churn_events,
            rounds_completed=self.rounds_completed,
            sim_horizon=sim_horizon, wall_s=wall_s)


@dataclasses.dataclass
class ServiceReport:
    """Immutable end-of-run summary (JSON-serializable)."""

    decision_latency: dict
    decisions_per_sec: float
    rounds_per_sec: float
    queue_depth_max: int
    queue_depth_mean: float
    tenant_fairness: float          # Jain index over per-tenant round counts
    tenants: Dict[str, dict]
    events_processed: int
    arrivals: int
    departures: int
    readmissions: int
    rejections: int
    churn_events: int
    rounds_completed: int
    sim_horizon: float
    wall_s: float
    # SLO resilience summary (``DecisionGovernor.summary`` + the service's
    # shed/defer/recovery counters); None when the axis is off.
    resilience: Optional[dict] = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")
