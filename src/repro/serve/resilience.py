"""SLO-driven serve resilience: the degradation ladder, circuit breakers,
and the stalled-round watchdog (the ``slo`` spec axis' runtime).

``attach_resilience(engine, slo)`` hangs a ``DecisionGovernor`` on a built
``MultiJobEngine`` and configures its bounded-retry knobs. From then on
every scheduling decision flows through ``DecisionGovernor.decide``, which
picks a rung of the degradation ladder

    full         — the live scheduler's complete plan search (rung 0)
    incremental  — repair the job's cached last-good plan for current
                   availability, score it against a greedy candidate
                   through the batched scoring core, keep the cheaper
    greedy       — fastest-n_sel closed form (one argpartition)
    last_good    — the repaired cached plan, unscored (floor latency)

under two independent pressures:

- **queue pressure** (deterministic): the service mirrors its admission
  queue depth into ``governor.queue_depth``; depth in the upper half of
  ``max_queue_depth`` degrades one rung, beyond it two. Pure function of
  simulated state — crash/resume replays it bit-identically.
- **latency pressure** (wall clock): when ``decision_deadline_ms`` is set,
  each rung's recent worst-case latency (a bounded window) must fit within
  the safety-scaled budget; the best rung that fits wins, and every
  ``rung_probe_every`` forced degradations the next-better rung gets one
  probe decision so recoveries are discovered.

The governor caches each job's chosen plan (by device index) as its
last-good plan after every decision, so rungs 1/3 always have a repair
base after the first round; without one they fall through to greedy.

``CircuitBreaker``/``BreakerBoard`` implement closed -> open -> half-open
breakers on SIMULATED time: per-tenant (opened by consecutive degraded or
fault-heavy rounds; open sheds that tenant's arrivals) and per-fault-domain
(opened by consecutive rounds where the domain's scheduled members mostly
failed; open masks the domain's devices out of ``ctx.available`` whenever
enough devices remain). Board state is JSON and rides in the service
checkpoint, so breakers survive ``kill -9`` resume.

``RoundWatchdog`` checks the engine's liveness invariant — every launched,
unfinished job must own an in-flight round or a pending heap event — and
reports jobs that stay wedged for N consecutive checks; the service
responds by restoring from the newest committed checkpoint.

Determinism: wall-clock latency samples are deliberately NOT persisted
(they are not replayable); everything else — last-good plans, rung/shed
counters, breaker and watchdog state — is.
"""

from __future__ import annotations

import time
import warnings
from collections import deque
from typing import Dict, List, Optional

import numpy as np

RUNGS = ("full", "incremental", "greedy", "last_good")


# ---------------------------------------------------------------------------
# circuit breakers
# ---------------------------------------------------------------------------

class CircuitBreaker:
    """Consecutive-failure breaker on simulated time.

    closed -> (threshold consecutive failures) -> open -> (cooldown elapses)
    -> half-open, where ``allow`` grants exactly one probe; the probe's
    outcome (``record``) either closes the breaker or re-opens it for
    another cooldown. A probe whose outcome never arrives (e.g. a masked
    domain that no plan happened to exercise) re-arms after a further
    cooldown so the breaker cannot wedge half-open.
    """

    def __init__(self, threshold: int, cooldown: float):
        self.threshold = int(threshold)
        self.cooldown = float(cooldown)
        self.state = "closed"
        self.failures = 0          # consecutive, while closed
        self.opened_at: Optional[float] = None
        self.probing = False
        self.probe_at: Optional[float] = None
        self.trips = 0

    def allow(self, now: float) -> bool:
        """May the guarded party participate at simulated instant ``now``?
        (Transitions open -> half-open and arms the single probe.)"""
        if self.state == "closed":
            return True
        if self.state == "open":
            if now - self.opened_at >= self.cooldown:
                self.state = "half_open"
                self.probing = True
                self.probe_at = now
                return True
            return False
        # half-open: one probe outstanding; re-arm if its outcome never came.
        if self.probing and now - self.probe_at >= self.cooldown:
            self.probe_at = now
            return True
        if not self.probing:
            self.probing = True
            self.probe_at = now
            return True
        return False

    def record(self, ok: bool, now: float) -> Optional[str]:
        """Feed one outcome; returns the new state iff it changed."""
        if self.state == "half_open":
            self.probing = False
            if ok:
                self.state = "closed"
                self.failures = 0
                return "closed"
            self.state = "open"
            self.opened_at = now
            self.trips += 1
            return "open"
        if ok:
            self.failures = 0
            return None
        self.failures += 1
        if self.state == "closed" and self.failures >= self.threshold:
            self.state = "open"
            self.opened_at = now
            self.trips += 1
            return "open"
        return None

    def state_dict(self) -> dict:
        return dict(state=self.state, failures=self.failures,
                    opened_at=self.opened_at, probing=self.probing,
                    probe_at=self.probe_at, trips=self.trips)

    def load_state_dict(self, d: dict) -> None:
        self.state = str(d["state"])
        self.failures = int(d["failures"])
        self.opened_at = d["opened_at"]
        self.probing = bool(d["probing"])
        self.probe_at = d["probe_at"]
        self.trips = int(d["trips"])


class BreakerBoard:
    """Per-tenant and per-fault-domain breaker registries (lazy-created)."""

    def __init__(self, threshold: int, cooldown: float):
        self.threshold = int(threshold)
        self.cooldown = float(cooldown)
        self.tenants: Dict[str, CircuitBreaker] = {}
        self.domains: Dict[int, CircuitBreaker] = {}

    def tenant(self, name: str) -> CircuitBreaker:
        br = self.tenants.get(name)
        if br is None:
            br = self.tenants[name] = CircuitBreaker(self.threshold,
                                                     self.cooldown)
        return br

    def domain(self, d: int) -> CircuitBreaker:
        br = self.domains.get(d)
        if br is None:
            br = self.domains[d] = CircuitBreaker(self.threshold,
                                                  self.cooldown)
        return br

    @property
    def trips(self) -> int:
        return (sum(b.trips for b in self.tenants.values())
                + sum(b.trips for b in self.domains.values()))

    def open_counts(self) -> dict:
        return dict(
            tenants_open=sum(1 for b in self.tenants.values()
                             if b.state != "closed"),
            domains_open=sum(1 for b in self.domains.values()
                             if b.state != "closed"),
            trips=self.trips)

    def state_dict(self) -> dict:
        return {
            "tenants": {t: b.state_dict()
                        for t, b in sorted(self.tenants.items())},
            "domains": {str(d): b.state_dict()
                        for d, b in sorted(self.domains.items())},
        }

    def load_state_dict(self, d: dict) -> None:
        self.tenants = {}
        for t, bd in d["tenants"].items():
            self.tenant(t).load_state_dict(bd)
        self.domains = {}
        for k, bd in d["domains"].items():
            self.domain(int(k)).load_state_dict(bd)


# ---------------------------------------------------------------------------
# the decision governor (degradation ladder)
# ---------------------------------------------------------------------------

class DecisionGovernor:
    """Wraps ``scheduler.schedule`` in the SLO's latency budget.

    ``decide`` returns ``(plan, rung, decision_ms, est_cost)`` where
    ``decision_ms`` is None unless a wall-clock deadline is active (so
    records stay replayable in the deterministic modes) and ``est_cost``
    is the rung's own Formula-2 estimate of its chosen plan (None for the
    unscored last-good rung).
    """

    def __init__(self, slo, cost_model, clock=time.perf_counter):
        self.slo = slo
        self.cost_model = cost_model
        self.clock = clock  # injectable for deterministic tests
        self.engine = None  # set by attach_resilience (event publishing)
        self.fault_domain: Optional[np.ndarray] = None  # (K,) device->domain
        self.breakers: Optional[BreakerBoard] = (
            BreakerBoard(slo.breaker_threshold, slo.breaker_cooldown)
            if slo.breaker_threshold > 0 else None)
        # Queue pressure input, mirrored by the service from its admission
        # queue; stays 0 for offline (non-serve) engines.
        self.queue_depth = 0
        self._last_good: Dict[int, np.ndarray] = {}   # job -> (n_sel,) idx
        # Rolling worst-case latency estimate per rung (ms), plus full
        # sample lists for the report's rung-level p50/p99.
        self._lat = {r: deque(maxlen=slo.latency_window) for r in RUNGS}
        # Chronological window across ALL rungs — the admission-control
        # rolling-p99 input.
        self.recent_ms = deque(maxlen=slo.latency_window)
        self.rung_samples: Dict[str, List[float]] = {r: [] for r in RUNGS}
        self.rung_counts: Dict[str, int] = {r: 0 for r in RUNGS}
        self.deadline_misses = 0
        self._forced = 0          # latency-forced degradations (probe clock)
        # Bench hook: keep (ctx, chosen idx, rung, est) per decision.
        self.keep_decisions = False
        self.decision_log: List[dict] = []

    # ---- rung selection ----

    def _queue_rung(self) -> int:
        q = self.slo.max_queue_depth
        if q is None or q <= 0:
            return 0
        if self.queue_depth <= q // 2:
            return 0
        if self.queue_depth <= q:
            return 1
        return 2

    def _latency_rung(self) -> int:
        ddl = self.slo.decision_deadline_ms
        if ddl is None:
            return 0
        budget = ddl * self.slo.deadline_safety
        for i, r in enumerate(RUNGS):
            est = max(self._lat[r]) if self._lat[r] else 0.0
            if est <= budget:
                if i > 0:
                    self._forced += 1
                    if self._forced % self.slo.rung_probe_every == 0:
                        return i - 1   # periodic probe of the better rung
                return i
        return len(RUNGS) - 1

    # ---- domain-breaker availability masking ----

    def _mask_domains(self, ctx, now: float) -> None:
        if self.breakers is None or self.fault_domain is None:
            return
        blocked = [d for d, br in sorted(self.breakers.domains.items())
                   if not br.allow(now)]
        if not blocked:
            return
        keep = ctx.available & ~np.isin(self.fault_domain, blocked)
        # Never starve the decision: masking must leave a full cohort.
        if int(np.count_nonzero(keep)) >= ctx.n_sel:
            ctx.available = keep
            ctx._avail_idx = None  # invalidate the context's id cache

    # ---- rung executors ----

    def _greedy_idx(self, ctx) -> np.ndarray:
        avail = ctx.available_indices()
        if avail.size <= ctx.n_sel:
            return avail.copy()
        t_av = ctx.expected_times[avail]
        cut = np.argpartition(t_av, ctx.n_sel - 1)[: ctx.n_sel]
        return np.sort(avail[cut])

    def _repair(self, cached: np.ndarray, ctx) -> np.ndarray:
        """Fit a cached plan to the current world: drop unavailable
        members, trim to n_sel keeping the fastest, fill shortfalls with
        the fastest available non-members."""
        keep = cached[ctx.available[cached]]
        if keep.size > ctx.n_sel:
            order = np.argsort(ctx.expected_times[keep], kind="stable")
            keep = keep[order[: ctx.n_sel]]
        elif keep.size < ctx.n_sel:
            avail = ctx.available_indices()
            extra = np.setdiff1d(avail, keep, assume_unique=False)
            need = min(ctx.n_sel - keep.size, extra.size)
            if need > 0:
                order = np.argsort(ctx.expected_times[extra], kind="stable")
                keep = np.concatenate([keep, extra[order[:need]]])
        return np.sort(keep)

    def _execute(self, rung: int, scheduler, ctx):
        """Run one rung; returns (idx, est_cost, plan_or_None)."""
        if rung == 0:
            plan = scheduler.schedule(ctx)
            est = scheduler.last_estimated_cost
            return np.flatnonzero(plan), (
                None if est is None else float(est)), plan
        if rung == 1:
            cand = np.stack([self._repair(self._last_good[ctx.job], ctx),
                             self._greedy_idx(ctx)])
            costs = np.asarray(self.cost_model.cost_indices(
                ctx.expected_times, ctx.counts, cand))
            best = int(np.argmin(costs))
            return cand[best], float(costs[best]), None
        if rung == 2:
            idx = self._greedy_idx(ctx)
            cost = self.cost_model.cost_indices(
                ctx.expected_times, ctx.counts, idx[None])
            return idx, float(np.asarray(cost)[0]), None
        return self._repair(self._last_good[ctx.job], ctx), None, None

    # ---- the decision ----

    def decide(self, scheduler, ctx, now: float):
        self._mask_domains(ctx, now)
        rung = max(self._queue_rung(), self._latency_rung())
        # The repair rungs need a cached base; before the job's first
        # decision they fall through to greedy (still bounded latency).
        if rung in (1, 3) and ctx.job not in self._last_good:
            rung = 2
        t0 = self.clock()
        idx, est, plan = self._execute(rung, scheduler, ctx)
        ms = (self.clock() - t0) * 1e3
        if plan is None:
            plan = np.zeros(ctx.available.shape[0], dtype=bool)
            plan[idx] = True
        name = RUNGS[rung]
        self._lat[name].append(ms)
        self.recent_ms.append(ms)
        self.rung_samples[name].append(ms)
        self.rung_counts[name] += 1
        ddl = self.slo.decision_deadline_ms
        if ddl is not None and ms > ddl:
            self.deadline_misses += 1
        self._last_good[ctx.job] = idx
        if self.keep_decisions:
            self.decision_log.append(dict(
                job=ctx.job, round_idx=ctx.round_idx, rung=name,
                ms=ms, est=est, idx=idx.copy(), ctx=ctx))
        if rung > 0 and self.engine is not None \
                and self.engine.events is not None:
            self.engine.events.publish("serve.degrade", dict(
                job=ctx.job, round_idx=ctx.round_idx, rung=name, t=now,
                decision_ms=(ms if ddl is not None else None),
                queue_depth=self.queue_depth))
        return plan, name, (ms if ddl is not None else None), est

    def rolling_p99(self) -> float:
        """p99 (ms) over the chronological recent-decision window — the
        service's admission-backpressure signal."""
        if not self.recent_ms:
            return 0.0
        return float(np.percentile(np.asarray(self.recent_ms), 99.0))

    # ---- breaker feedback (called by the service per finished round) ----

    def note_round(self, rec, tenant: Optional[str], now: float) -> List[dict]:
        """Feed one finished round's outcome to the breakers; returns the
        state transitions (for event publishing)."""
        if self.breakers is None:
            return []
        changes: List[dict] = []
        failed = np.asarray(rec.failed_ids, dtype=int)
        scheduled = len(rec.device_ids) + len(rec.dropped)
        frac = failed.size / max(scheduled, 1)
        if tenant is not None:
            bad = bool(rec.degraded) or frac >= self.slo.breaker_failure_frac
            tr = self.breakers.tenant(tenant).record(not bad, now)
            if tr is not None:
                changes.append(dict(kind="tenant", key=tenant, state=tr,
                                    t=now))
        if self.fault_domain is not None and scheduled > 0:
            part = np.concatenate([np.asarray(rec.device_ids, dtype=int),
                                   np.asarray(rec.dropped, dtype=int)])
            part_dom = self.fault_domain[part]
            fail_dom = self.fault_domain[failed] if failed.size else \
                np.array([], dtype=int)
            for d in np.unique(part_dom):
                n_part = int(np.count_nonzero(part_dom == d))
                n_fail = int(np.count_nonzero(fail_dom == d))
                bad = n_fail / n_part >= self.slo.breaker_failure_frac
                dr = self.breakers.domain(int(d)).record(not bad, now)
                if dr is not None:
                    changes.append(dict(kind="domain", key=int(d), state=dr,
                                        t=now))
        return changes

    # ---- persistence (wall-clock samples intentionally excluded) ----

    def state_dict(self) -> dict:
        return {
            "last_good": {str(j): idx.tolist()
                          for j, idx in sorted(self._last_good.items())},
            "rung_counts": dict(self.rung_counts),
            "deadline_misses": self.deadline_misses,
            "forced": self._forced,
            "breakers": (self.breakers.state_dict()
                         if self.breakers is not None else None),
        }

    def load_state_dict(self, d: dict) -> None:
        self._last_good = {int(j): np.asarray(v, dtype=int)
                           for j, v in d["last_good"].items()}
        self.rung_counts = {r: int(d["rung_counts"].get(r, 0))
                            for r in RUNGS}
        self.deadline_misses = int(d["deadline_misses"])
        self._forced = int(d["forced"])
        if self.breakers is not None and d.get("breakers") is not None:
            self.breakers.load_state_dict(d["breakers"])

    # ---- reporting ----

    def summary(self) -> dict:
        def pct(xs, q):
            return float(np.percentile(np.asarray(xs), q)) if xs else 0.0
        out = dict(
            rung_counts=dict(self.rung_counts),
            rung_latency_ms={r: dict(count=len(s), p50=pct(s, 50),
                                     p99=pct(s, 99))
                             for r, s in self.rung_samples.items() if s},
            deadline_misses=self.deadline_misses,
            degraded_decisions=sum(v for r, v in self.rung_counts.items()
                                   if r != "full"),
            decisions=sum(self.rung_counts.values()),
        )
        if self.breakers is not None:
            out["breakers"] = self.breakers.open_counts()
        return out


# ---------------------------------------------------------------------------
# stalled-round watchdog
# ---------------------------------------------------------------------------

class RoundWatchdog:
    """Liveness invariant: every launched, unfinished, unparked job owns an
    in-flight round or a pending heap event. ``check`` counts consecutive
    violations per job and reports the jobs at/over the threshold."""

    def __init__(self, threshold: int):
        self.threshold = int(threshold)
        self._stalls: Dict[int, int] = {}

    def check(self, engine) -> List[int]:
        pending = {j for (_, _, _, j) in engine._heap}
        wedged: List[int] = []
        for j, js in enumerate(engine.jobs):
            live = js.launched and not js.done and not js.parked
            if not live or j in engine._in_flight or j in pending:
                self._stalls.pop(j, None)
                continue
            c = self._stalls.get(j, 0) + 1
            self._stalls[j] = c
            if c >= self.threshold:
                wedged.append(j)
        return wedged

    def reset(self) -> None:
        self._stalls = {}

    def state_dict(self) -> dict:
        return {str(j): c for j, c in sorted(self._stalls.items())}

    def load_state_dict(self, d: dict) -> None:
        self._stalls = {int(j): int(c) for j, c in d.items()}


# ---------------------------------------------------------------------------
# wiring
# ---------------------------------------------------------------------------

def attach_resilience(engine, slo) -> Optional[DecisionGovernor]:
    """Configure a built engine for the SLO: hang a ``DecisionGovernor``
    (when any decision-path knob is active) and set the bounded-retry
    knobs. Called by ``ExperimentSpec.build`` when ``effective_slo()`` is
    non-None; an inert spec never reaches here."""
    engine.max_launch_retries = slo.max_launch_retries
    engine.retry_backoff = slo.retry_backoff
    engine.retry_base_delay = slo.retry_base_delay
    engine.max_agg_retries = slo.max_agg_retries
    needs_governor = (slo.decision_deadline_ms is not None
                      or slo.max_queue_depth is not None
                      or slo.breaker_threshold > 0)
    if not needs_governor:
        return None
    gov = DecisionGovernor(slo, engine.cost_model)
    gov.engine = engine
    if engine.fault_engine is not None:
        gov.fault_domain = engine.fault_engine.domain
    if slo.breaker_threshold > 0 and engine.fault_engine is None:
        warnings.warn("slo.breaker_threshold set without a faults axis: "
                      "domain breakers are inactive (no fault domains)",
                      RuntimeWarning)
    engine.governor = gov
    return gov
