"""Scheduler-service CLI.

  python -m repro.serve --preset online-smoke
  python -m repro.serve --preset online-smoke --rescore full --out report.json
  python -m repro.serve --spec spec.json --save-trace trace.json
  python -m repro.serve --preset online-smoke --trace trace.json --verbose
  python -m repro.serve --preset fault-injection \\
      --checkpoint-dir ckpt/ --checkpoint-every 5
  python -m repro.serve --resume ckpt/ --out report.json

``--preset``/``--arg``/``--set`` follow the experiment CLI's conventions
(``--arg k=v`` feeds the preset factory, ``--set k=v`` overrides spec
fields, including nested dicts: ``--set 'arrivals={"horizon": 40000}'``).
``--save-trace`` writes the generated traffic stream as JSON;
``--trace`` replays one (bit-identical traffic across service configs —
how the incremental-vs-full benchmark holds traffic fixed).

Crash consistency: ``--checkpoint-dir``/``--checkpoint-every N`` atomically
persist the FULL service state every N traffic events; ``--resume DIR``
restarts from the newest committed step (the spec rides in the checkpoint,
so no ``--preset``/``--spec`` is needed) and continues BIT-IDENTICALLY.
``--crash-after N`` hard-kills the process (``os._exit(137)``, no cleanup —
the ``kill -9`` equivalent) after the Nth event; ``--records-out`` dumps
the engine's per-round records for trajectory comparison.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.experiment.cli import _parse_kv
from repro.experiment.presets import get_preset
from repro.experiment.spec import ExperimentSpec, _record_to_dict
from repro.serve.service import RESCORE_MODES, SchedulerService
from repro.serve.traffic import load_trace, save_trace, trace_from_spec


def _print_report(service: SchedulerService) -> None:
    r = service.last_report
    lat = r.decision_latency
    print(f"\n[{service.spec.name}] scheduler={service.spec.scheduler} "
          f"rescore={service.rescore_mode}")
    print(f"  traffic: {r.arrivals} arrivals, {r.departures} departures, "
          f"{r.readmissions} readmissions, {r.churn_events} churn events, "
          f"{r.rejections} queued")
    print(f"  rounds:  {r.rounds_completed} completed "
          f"({r.rounds_per_sec:.1f}/s wall), tenant fairness "
          f"(Jain) {r.tenant_fairness:.3f}")
    print(f"  latency: p50={lat['p50_s'] * 1e3:.2f}ms "
          f"p99={lat['p99_s'] * 1e3:.2f}ms over {lat['count']} decisions; "
          f"queue depth max={r.queue_depth_max}")
    res = r.resilience
    if res:
        rungs = res.get("rung_counts", {})
        hist = " ".join(f"{k}={v}" for k, v in rungs.items() if v)
        print(f"  slo:     rungs[{hist or 'none'}] "
              f"shed={res['shed_arrivals']} deferred={res['deferrals']} "
              f"breaker_trips={res['breaker_trips']} "
              f"recoveries={res['recoveries']} "
              f"deadline_misses={res.get('deadline_misses', 0)}")
        for rung, st in sorted(res.get("rung_latency_ms", {}).items()):
            print(f"    rung {rung:12s} n={st['count']:4d} "
                  f"p50={st['p50']:.2f}ms p99={st['p99']:.2f}ms")
    for name, t in sorted(service.metrics.tenants.items()):
        print(f"    {name:12s} rounds={t.rounds:4d} "
              f"admissions={t.admissions} "
              f"mean_cost={t.total_cost / t.rounds if t.rounds else 0.0:.3f} "
              f"best_acc={t.best_accuracy:.3f}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    src = ap.add_mutually_exclusive_group()
    src.add_argument("--preset", help="preset name (e.g. online-smoke)")
    src.add_argument("--spec", help="path to an ExperimentSpec JSON file")
    src.add_argument("--resume", metavar="DIR",
                     help="resume from the newest committed checkpoint in "
                          "DIR (the spec rides in the checkpoint)")
    ap.add_argument("--arg", action="append", metavar="K=V",
                    help="preset factory argument")
    ap.add_argument("--set", action="append", metavar="K=V",
                    help="override a spec field (nested dicts merge)")
    ap.add_argument("--rescore", choices=RESCORE_MODES,
                    default="incremental")
    ap.add_argument("--trace", help="replay this traffic trace JSON")
    ap.add_argument("--save-trace", help="write the traffic trace here")
    ap.add_argument("--out", help="write the ServiceReport JSON here")
    ap.add_argument("--checkpoint-dir",
                    help="atomically checkpoint the service state here")
    ap.add_argument("--checkpoint-every", type=int, default=5,
                    metavar="N", help="checkpoint every N traffic events "
                    "(default 5; needs --checkpoint-dir)")
    ap.add_argument("--crash-after", type=int, metavar="N",
                    help="hard-kill the process (os._exit 137) after the "
                         "Nth traffic event — chaos testing")
    ap.add_argument("--records-out",
                    help="dump the engine's per-round records JSON here "
                         "(trajectory comparison across crash/resume)")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    if not (args.preset or args.spec or args.resume):
        ap.error("one of --preset, --spec, --resume is required")

    if args.resume:
        if args.set or args.arg or args.trace:
            ap.error("--resume replays the checkpointed spec and trace; "
                     "--set/--arg/--trace cannot be combined with it")
        service = SchedulerService.resume(args.resume, verbose=args.verbose)
        trace = None   # run() continues the restored trace
    else:
        if args.preset:
            spec = get_preset(args.preset, **_parse_kv(args.arg))
        else:
            spec = ExperimentSpec.load(args.spec)
        if args.set:
            spec = spec.replace(**_parse_kv(args.set))
        if spec.arrivals is None:
            raise SystemExit("spec has no arrivals axis — use an online "
                             "preset or --set 'arrivals={...}'")
        service = SchedulerService(spec, rescore_mode=args.rescore,
                                   verbose=args.verbose,
                                   checkpoint_dir=args.checkpoint_dir,
                                   checkpoint_every=args.checkpoint_every)
        trace = (load_trace(args.trace) if args.trace
                 else trace_from_spec(spec.arrivals, len(service.templates),
                                      service.engine.pool.num_devices))
        if args.save_trace:
            save_trace(trace, args.save_trace)
            print(f"trace -> {args.save_trace} ({len(trace)} events)")

    if args.crash_after is not None:
        # The hard-kill path: run until the Nth event boundary, then exit
        # WITHOUT cleanup (no atexit, no flush) — indistinguishable from
        # kill -9 as far as the checkpoint directory is concerned.
        import os

        from repro.serve.service import SimulatedCrash

        service.crash_after = args.crash_after
        try:
            service.run(trace)
        except SimulatedCrash:
            os._exit(137)
        raise SystemExit(
            f"--crash-after {args.crash_after}: trace ended after "
            f"{service._next_event} events without reaching the crash point")

    report = service.run(trace)
    _print_report(service)
    if args.out:
        report.save(args.out)
        print(f"report -> {args.out}")
    if args.records_out:
        with open(args.records_out, "w") as f:
            json.dump([_record_to_dict(r) for r in service.engine.records],
                      f, indent=2)
            f.write("\n")
        print(f"records -> {args.records_out}")


if __name__ == "__main__":
    main(sys.argv[1:])
