"""Online multi-tenant scheduler service (``python -m repro.serve``).

The classic experiment pipeline runs a CLOSED job set: every job exists at
t=0 and the engine drains the heap. Production multi-job FL is open-world —
tenants submit jobs while others are mid-flight, devices leave and rejoin
the fleet with drifted capabilities, and the scheduler must re-plan
incrementally instead of re-searching from scratch on every change.

- ``repro.serve.traffic``  — arrival/departure/churn event streams
  (seeded Poisson generation, JSON trace replay).
- ``repro.serve.service``  — the event loop: admission control under a
  concurrent-job budget, mid-run ``add_job``/``retire_job`` on the engine,
  incremental plan rescoring, scheduler warm hand-off across
  retire/readmit cycles.
- ``repro.serve.metrics``  — decision-latency percentiles, throughput,
  queue depth, per-tenant cost/fairness accounting.
- ``repro.serve.resilience`` — the SLO axis' runtime: the decision
  governor's degradation ladder (full -> incremental -> greedy ->
  last-good), per-tenant/per-fault-domain circuit breakers, and the
  stalled-round watchdog (``--set slo.decision_deadline_ms=...``).
"""

from repro.serve.metrics import LatencyStats, ServiceMetrics, ServiceReport
from repro.serve.resilience import (RUNGS, BreakerBoard, CircuitBreaker,
                                    DecisionGovernor, RoundWatchdog,
                                    attach_resilience)
from repro.serve.service import SchedulerService
from repro.serve.traffic import (TrafficEvent, load_trace, poisson_trace,
                                 save_trace, trace_from_spec)

__all__ = [
    "RUNGS", "BreakerBoard", "CircuitBreaker", "DecisionGovernor",
    "LatencyStats", "RoundWatchdog", "SchedulerService", "ServiceMetrics",
    "ServiceReport", "TrafficEvent", "attach_resilience", "load_trace",
    "poisson_trace", "save_trace", "trace_from_spec",
]
