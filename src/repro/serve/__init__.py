"""Online multi-tenant scheduler service (``python -m repro.serve``).

The classic experiment pipeline runs a CLOSED job set: every job exists at
t=0 and the engine drains the heap. Production multi-job FL is open-world —
tenants submit jobs while others are mid-flight, devices leave and rejoin
the fleet with drifted capabilities, and the scheduler must re-plan
incrementally instead of re-searching from scratch on every change.

- ``repro.serve.traffic``  — arrival/departure/churn event streams
  (seeded Poisson generation, JSON trace replay).
- ``repro.serve.service``  — the event loop: admission control under a
  concurrent-job budget, mid-run ``add_job``/``retire_job`` on the engine,
  incremental plan rescoring, scheduler warm hand-off across
  retire/readmit cycles.
- ``repro.serve.metrics``  — decision-latency percentiles, throughput,
  queue depth, per-tenant cost/fairness accounting.
"""

from repro.serve.metrics import LatencyStats, ServiceMetrics, ServiceReport
from repro.serve.service import SchedulerService
from repro.serve.traffic import (TrafficEvent, load_trace, poisson_trace,
                                 save_trace, trace_from_spec)

__all__ = [
    "LatencyStats", "SchedulerService", "ServiceMetrics", "ServiceReport",
    "TrafficEvent", "load_trace", "poisson_trace", "save_trace",
    "trace_from_spec",
]
