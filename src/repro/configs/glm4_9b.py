"""GLM4-9B [hf:THUDM/glm-4-9b; dense GQA kv=2, RoPE]."""

from repro.config.base import ArchFamily, AttentionKind, ModelConfig
from repro.config.registry import register_arch


@register_arch("glm4-9b")
def glm4_9b() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b",
        family=ArchFamily.DENSE,
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        vocab_size=151552,
        mlp_kind="swiglu",
        rope_theta=10000.0,
        attention=AttentionKind.FULL,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b-smoke",
        family=ArchFamily.DENSE,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=176,
        vocab_size=256,
        attention=AttentionKind.FULL,
        remat=False,
    )
