"""Qwen3-1.7B [hf:Qwen/Qwen3-8B family; dense GQA with qk_norm]."""

from repro.config.base import ArchFamily, AttentionKind, ModelConfig
from repro.config.registry import register_arch


@register_arch("qwen3-1.7b")
def qwen3_1p7b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b",
        family=ArchFamily.DENSE,
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=6144,
        vocab_size=151936,
        qk_norm=True,
        mlp_kind="swiglu",
        rope_theta=1_000_000.0,
        attention=AttentionKind.FULL,
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    """Small same-family config for CPU smoke tests."""
    return ModelConfig(
        name="qwen3-1.7b-smoke",
        family=ArchFamily.DENSE,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        qk_norm=True,
        mlp_kind="swiglu",
        attention=AttentionKind.FULL,
        tie_embeddings=True,
        remat=False,
    )
