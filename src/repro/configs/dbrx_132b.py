"""DBRX-132B [hf:databricks/dbrx-base; MoE 16 experts top-4, fine-grained]."""

from repro.config.base import ArchFamily, AttentionKind, ModelConfig
from repro.config.registry import register_arch


@register_arch("dbrx-132b")
def dbrx_132b() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b",
        family=ArchFamily.MOE,
        num_layers=40,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=10752,
        vocab_size=100352,
        mlp_kind="swiglu",
        rope_theta=500_000.0,
        attention=AttentionKind.FULL,
        num_experts=16,
        experts_per_token=4,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b-smoke",
        family=ArchFamily.MOE,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        attention=AttentionKind.FULL,
        num_experts=4,
        experts_per_token=2,
        remat=False,
    )
