"""xLSTM-350M [arXiv:2405.04517; sLSTM + mLSTM blocks, attention-free].

Blocks alternate mLSTM / sLSTM (scan over pairs keeps the HLO compact).
d_ff=0 per the assigned table: blocks carry their own up/down projections
(expand factor 2) instead of a separate FFN. Recurrent state -> O(1) decode,
long_500k runs.
"""

from repro.config.base import ArchFamily, AttentionKind, ModelConfig
from repro.config.registry import register_arch


@register_arch("xlstm-350m")
def xlstm_350m() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m",
        family=ArchFamily.SSM,
        num_layers=24,
        d_model=1024,
        num_heads=4,
        num_kv_heads=4,
        head_dim=256,
        d_ff=0,
        vocab_size=50304,
        attention=AttentionKind.NONE,
        ssm_state=0,
        ssm_expand=2,
        slstm_every=2,
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m-smoke",
        family=ArchFamily.SSM,
        num_layers=4,
        d_model=64,
        num_heads=2,
        num_kv_heads=2,
        head_dim=32,
        d_ff=0,
        vocab_size=256,
        attention=AttentionKind.NONE,
        ssm_expand=2,
        slstm_every=2,
        remat=False,
    )
