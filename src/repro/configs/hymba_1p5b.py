"""Hymba-1.5B [arXiv:2411.13676; hybrid parallel attention+Mamba heads].

Every block runs attention heads and SSM (Mamba-style selective-scan) heads
in PARALLEL on the same input and fuses their outputs (mean), per the paper.
Attention is sliding-window (global layers omitted for uniform scan blocks),
making the arch sub-quadratic -> long_500k runs.
"""

from repro.config.base import ArchFamily, AttentionKind, ModelConfig
from repro.config.registry import register_arch


@register_arch("hymba-1.5b")
def hymba_1p5b() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family=ArchFamily.HYBRID,
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        mlp_kind="swiglu",
        attention=AttentionKind.SLIDING,
        sliding_window=1024,
        ssm_state=16,
        ssm_expand=2,
        hybrid_parallel=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b-smoke",
        family=ArchFamily.HYBRID,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attention=AttentionKind.SLIDING,
        sliding_window=32,
        ssm_state=8,
        ssm_expand=2,
        hybrid_parallel=True,
        remat=False,
    )
