"""Qwen3-8B [hf:Qwen/Qwen3-8B; dense GQA with qk_norm]."""

from repro.config.base import ArchFamily, AttentionKind, ModelConfig
from repro.config.registry import register_arch


@register_arch("qwen3-8b")
def qwen3_8b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b",
        family=ArchFamily.DENSE,
        num_layers=36,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=12288,
        vocab_size=151936,
        qk_norm=True,
        mlp_kind="swiglu",
        rope_theta=1_000_000.0,
        attention=AttentionKind.FULL,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b-smoke",
        family=ArchFamily.DENSE,
        num_layers=2,
        d_model=96,
        num_heads=6,
        num_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=256,
        qk_norm=True,
        attention=AttentionKind.FULL,
        remat=False,
    )
