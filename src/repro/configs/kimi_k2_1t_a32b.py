"""Kimi K2 1T-A32B [arXiv:2501.kimi2 paper table; MoE 384 experts top-8].

~1.04T total / ~31B active parameters with the assigned table values
(61L, d_model 7168, per-expert d_ff 2048, GQA kv=8, vocab 163840).
Memory policy for this job defaults to bf16 params + Adafactor (see
launch/steps.py); the dense-everything fp32+Adam variant exceeds a single
v5e pod's HBM — quantified in EXPERIMENTS.md §Dry-run.
"""

from repro.config.base import ArchFamily, AttentionKind, ModelConfig
from repro.config.registry import register_arch


@register_arch("kimi-k2-1t-a32b")
def kimi_k2() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family=ArchFamily.MOE,
        num_layers=61,
        d_model=7168,
        num_heads=64,
        num_kv_heads=8,
        head_dim=112,
        d_ff=2048,
        vocab_size=163840,
        mlp_kind="swiglu",
        rope_theta=50_000.0,
        attention=AttentionKind.FULL,
        num_experts=384,
        experts_per_token=8,
        param_dtype="bfloat16",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-smoke",
        family=ArchFamily.MOE,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=32,
        vocab_size=256,
        attention=AttentionKind.FULL,
        num_experts=8,
        experts_per_token=2,
        remat=False,
    )
