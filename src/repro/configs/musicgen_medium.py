"""MusicGen-medium [arXiv:2306.05284; decoder-only over EnCodec tokens].

The modality frontend (EnCodec) is a STUB: ``input_specs()`` provides
precomputed frame embeddings of shape (batch, seq, d_model); the backbone is
a plain decoder-only transformer (MHA, GELU MLP) with a 2048-way codebook head.
"""

from repro.config.base import ArchFamily, AttentionKind, ModelConfig
from repro.config.registry import register_arch


@register_arch("musicgen-medium")
def musicgen_medium() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family=ArchFamily.AUDIO,
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        vocab_size=2048,
        mlp_kind="gelu",
        attention=AttentionKind.FULL,
        frontend_tokens=0,   # audio frames ARE the sequence (no prefix tokens)
        frontend_dim=1536,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-smoke",
        family=ArchFamily.AUDIO,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        mlp_kind="gelu",
        attention=AttentionKind.FULL,
        frontend_dim=64,
        remat=False,
    )
