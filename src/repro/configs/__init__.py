"""Assigned architecture configs (one module per arch) + the paper's FL model groups.

Importing this package registers every arch with the registry, enabling
``repro.config.get_arch("<id>")`` and ``--arch <id>`` on all CLIs.
"""

from repro.configs import (  # noqa: F401
    dbrx_132b,
    deepseek_67b,
    glm4_9b,
    hymba_1p5b,
    kimi_k2_1t_a32b,
    musicgen_medium,
    paligemma_3b,
    paper_models,
    qwen3_1p7b,
    qwen3_8b,
    xlstm_350m,
)

ASSIGNED_ARCHS = (
    "qwen3-1.7b",
    "qwen3-8b",
    "deepseek-67b",
    "glm4-9b",
    "musicgen-medium",
    "dbrx-132b",
    "kimi-k2-1t-a32b",
    "hymba-1.5b",
    "xlstm-350m",
    "paligemma-3b",
)
