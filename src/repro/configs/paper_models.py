"""The paper's FL model zoo (Tables 3 & 4) as ``ModelConfig`` CNN specs.

Group A: VGG16 (CIFAR-like 32x32x3), CNN-A (EMNIST-letters-like 28x28, 26 cls),
LeNet-5 (EMNIST-digits-like 28x28, 10 cls).
Group B: ResNet-18-thin (CIFAR-like; paper reports 598K params so the widths
are CIFAR-thin), CNN-B (Fashion-like 28x28), AlexNet-mini (MNIST-like 28x28;
paper reports 3,275K params).

BatchNorm is replaced by GroupNorm (stateless) — standard practice in FL where
per-device running statistics are ill-defined under non-IID data; noted in
DESIGN.md. Dropout in CNN-B is omitted (inference-time identical).

CNN layer-spec mini-language (see models/cnn_zoo.py):
  ("conv",  out_c, k)        conv k×k stride 1 SAME + ReLU
  ("convp", out_c, k)        conv + ReLU + 2×2 maxpool
  ("gn",)                    GroupNorm over channels
  ("res",  out_c, stride)    basic residual block (2× conv3×3)
  ("flatten",)
  ("fc", width)              dense + ReLU
Final classifier to ``num_classes`` is implicit.
"""

from __future__ import annotations

from repro.config.base import ArchFamily, JobConfig, ModelConfig
from repro.config.registry import register_arch


def _cnn(name, spec, input_shape, num_classes) -> ModelConfig:
    return ModelConfig(
        name=name,
        family=ArchFamily.CNN,
        cnn_spec=tuple(spec),
        input_shape=tuple(input_shape),
        num_classes=num_classes,
    )


@register_arch("paper-vgg16")
def vgg16() -> ModelConfig:
    spec = [
        ("conv", 64, 3), ("convp", 64, 3),
        ("conv", 128, 3), ("convp", 128, 3),
        ("conv", 256, 3), ("conv", 256, 3), ("convp", 256, 3),
        ("conv", 512, 3), ("conv", 512, 3), ("convp", 512, 3),
        ("conv", 512, 3), ("conv", 512, 3), ("convp", 512, 3),
        ("flatten",), ("fc", 4096), ("fc", 4096),
    ]
    return _cnn("paper-vgg16", spec, (32, 32, 3), 10)


@register_arch("paper-cnn-a-iid")
def cnn_a_iid() -> ModelConfig:
    spec = [
        ("convp", 32, 3), ("gn",),
        ("convp", 64, 3), ("gn",),
        ("flatten",), ("fc", 1568), ("fc", 784),
    ]
    return _cnn("paper-cnn-a-iid", spec, (28, 28, 1), 26)


@register_arch("paper-cnn-a-noniid")
def cnn_a_noniid() -> ModelConfig:
    spec = [
        ("convp", 32, 3), ("convp", 64, 3), ("conv", 64, 3),
        ("flatten",), ("fc", 64),
    ]
    return _cnn("paper-cnn-a-noniid", spec, (28, 28, 1), 26)


@register_arch("paper-lenet5")
def lenet5() -> ModelConfig:
    spec = [("convp", 6, 5), ("convp", 16, 5), ("flatten",), ("fc", 120), ("fc", 84)]
    return _cnn("paper-lenet5", spec, (28, 28, 1), 10)


@register_arch("paper-resnet18")
def resnet18() -> ModelConfig:
    # CIFAR-thin ResNet-18 (paper Table 4: 598K params).
    spec = [
        ("conv", 16, 3),
        ("res", 16, 1), ("res", 16, 1),
        ("res", 32, 2), ("res", 32, 1),
        ("res", 64, 2), ("res", 64, 1),
        ("res", 128, 2), ("res", 128, 1),
        ("flatten",),
    ]
    return _cnn("paper-resnet18", spec, (32, 32, 3), 10)


@register_arch("paper-cnn-b")
def cnn_b() -> ModelConfig:
    spec = [("conv", 64, 2), ("conv", 32, 2), ("flatten",)]
    return _cnn("paper-cnn-b", spec, (28, 28, 1), 10)


@register_arch("paper-alexnet")
def alexnet() -> ModelConfig:
    # MNIST-scale AlexNet (paper Table 4: 3,275K params).
    spec = [
        ("convp", 32, 3), ("convp", 64, 3), ("conv", 128, 3),
        ("flatten",), ("fc", 512),
    ]
    return _cnn("paper-alexnet", spec, (28, 28, 1), 10)


# ---- the paper's job groups (3 jobs each, run in parallel) ----

def group_a(non_iid: bool = True):
    """VGG16 + CNN-A + LeNet5, targets from Table 1 (scaled to synthetic data)."""
    cnn_a = cnn_a_noniid() if non_iid else cnn_a_iid()
    return [
        JobConfig(job_id=0, model=vgg16(), target_metric=0.55 if non_iid else 0.60,
                  local_epochs=5, batch_size=30, lr=0.05),
        JobConfig(job_id=1, model=cnn_a, target_metric=0.80 if non_iid else 0.93,
                  local_epochs=5, batch_size=10, lr=0.05),
        JobConfig(job_id=2, model=lenet5(), target_metric=0.984 if non_iid else 0.993,
                  local_epochs=5, batch_size=64, lr=0.05),
    ]


def group_b(non_iid: bool = True):
    """ResNet18 + CNN-B + AlexNet, targets from Table 2 (scaled to synthetic data)."""
    return [
        JobConfig(job_id=0, model=resnet18(), target_metric=0.45 if non_iid else 0.74,
                  local_epochs=5, batch_size=30, lr=0.05),
        JobConfig(job_id=1, model=cnn_b(), target_metric=0.73 if non_iid else 0.865,
                  local_epochs=5, batch_size=10, lr=0.05),
        JobConfig(job_id=2, model=alexnet(), target_metric=0.978 if non_iid else 0.9933,
                  local_epochs=5, batch_size=64, lr=0.05),
    ]
