"""PaliGemma-3B [arXiv:2407.07726; SigLIP + Gemma-2B backbone].

The SigLIP vision tower is a STUB: ``input_specs()`` provides 256 precomputed
patch embeddings (batch, 256, d_model) prepended to the token sequence.
Backbone = Gemma-2B: 18L, d_model 2048, 8 heads with head_dim 256, MQA (kv=1),
GeGLU d_ff 16384, vocab 257216. kv=1 means the KV tensor cannot shard on the
16-way model axis -> replicated KV (see launch/sharding.py).
"""

from repro.config.base import ArchFamily, AttentionKind, ModelConfig
from repro.config.registry import register_arch


@register_arch("paligemma-3b")
def paligemma_3b() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b",
        family=ArchFamily.VLM,
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=257216,
        mlp_kind="geglu",
        attention=AttentionKind.FULL,
        frontend_tokens=256,
        frontend_dim=2048,
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b-smoke",
        family=ArchFamily.VLM,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=192,
        vocab_size=256,
        mlp_kind="geglu",
        attention=AttentionKind.FULL,
        frontend_tokens=16,
        frontend_dim=64,
        tie_embeddings=True,
        remat=False,
    )
