"""DeepSeek-67B [arXiv:2401.02954; llama-arch dense GQA]."""

from repro.config.base import ArchFamily, AttentionKind, ModelConfig
from repro.config.registry import register_arch


@register_arch("deepseek-67b")
def deepseek_67b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b",
        family=ArchFamily.DENSE,
        num_layers=95,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab_size=102400,
        mlp_kind="swiglu",
        rope_theta=10000.0,
        attention=AttentionKind.FULL,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b-smoke",
        family=ArchFamily.DENSE,
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=160,
        vocab_size=256,
        attention=AttentionKind.FULL,
        remat=False,
    )
