"""Logical-axis sharding (MaxText-style) with divisibility-aware resolution.

Models annotate tensors with LOGICAL axis names ("embed", "mlp", "heads",
"experts", "batch", ...). A rule table maps logical axes to mesh axes; the
resolver drops any mapping whose mesh-axis size does not divide the tensor
dimension (e.g. paligemma's kv=1 head on a 16-way model axis, musicgen's 24
heads, hymba's 32001 vocab) — GSPMD correctness never depends on the rules,
only efficiency does.

``axis_rules(...)`` installs a rule table in a context; ``shard(x, *axes)``
applies a with_sharding_constraint when a mesh is active, else no-ops, so the
same model code runs single-device tests and 512-chip dry-runs unchanged.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default rule table: single-pod ("data", "model") and multi-pod
# ("pod", "data", "model") meshes share it — "pod" only ever carries batch.
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    # activations
    "batch": ("pod", "data"),
    "seq": (),               # sequence usually replicated; SP overrides per-config
    "act_embed": (),
    "act_heads": ("model",),
    "act_mlp": ("model",),
    "act_exp": ("model",),
    "act_vocab": ("model",),
    # params
    "vocab": ("model",),
    "embed": ("data",),      # FSDP / ZeRO-3: weight d_model dim over data axis
    "mlp": ("model",),       # tensor parallel: d_ff over model axis
    "heads": ("model",),
    "kv_heads": ("model",),
    "qkv": ("model",),       # flattened (heads*head_dim) projections
    "experts": ("model",),   # expert parallelism
    "mlp_zero": ("data",),   # ZeRO storage of expert w_down's d_ff dim
    "inner": ("model",),     # SSM inner/expanded dim
    "layers": (),            # stacked-scan layer axis: never sharded
    "state": (),
    # KV cache
    "cache_batch": ("data",),
    "cache_seq": (),
    "cache_heads": ("model",),
}

_local = threading.local()

# Logical axes where uneven (padded) sharding beats replication.
UNEVEN_OK = {"act_heads"}


def current_rules() -> Dict[str, Tuple[str, ...]]:
    return getattr(_local, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def axis_rules(rules: Optional[Dict[str, Tuple[str, ...]]] = None, **overrides):
    base = dict(rules if rules is not None else DEFAULT_RULES)
    base.update(overrides)
    prev = getattr(_local, "rules", None)
    _local.rules = base
    try:
        yield base
    finally:
        if prev is None:
            del _local.rules
        else:
            _local.rules = prev


def _active_mesh() -> Optional[Mesh]:
    mesh = jax.sharding.get_abstract_mesh() if hasattr(jax.sharding, "get_abstract_mesh") else None
    try:
        from jax._src import mesh as mesh_lib
        env_mesh = mesh_lib.thread_resources.env.physical_mesh
        if env_mesh is not None and not env_mesh.empty:
            return env_mesh
    except Exception:
        pass
    return None


def resolve_spec(shape: Sequence[int], logical_axes: Sequence[Optional[str]],
                 mesh: Mesh) -> P:
    """logical axes -> PartitionSpec, dropping non-divisible mappings."""
    rules = current_rules()
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used = set()
    parts = []
    for dim, name in zip(shape, logical_axes):
        if name is None:
            parts.append(None)
            continue
        mesh_axes = [a for a in rules.get(name, ()) if a in axis_sizes and a not in used]
        total = int(np.prod([axis_sizes[a] for a in mesh_axes])) if mesh_axes else 1
        # Activations tolerate UNEVEN sharding (GSPMD pads): e.g. hymba's 25
        # heads on a 16-way axis — replication would redundantly compute the
        # full attention on every model shard (§Perf H7).
        if name in UNEVEN_OK and mesh_axes and dim >= total:
            used.update(mesh_axes)
            parts.append(tuple(mesh_axes) if len(mesh_axes) > 1 else mesh_axes[0])
            continue
        if mesh_axes and dim % total == 0:
            used.update(mesh_axes)
            parts.append(tuple(mesh_axes) if len(mesh_axes) > 1 else mesh_axes[0])
        else:
            # try progressively shorter prefixes (e.g. batch too small for pod*data)
            ok = None
            for cut in range(len(mesh_axes) - 1, 0, -1):
                sub = mesh_axes[:cut]
                tot = int(np.prod([axis_sizes[a] for a in sub]))
                if dim % tot == 0:
                    ok = sub
                    break
            if ok:
                used.update(ok)
                parts.append(tuple(ok) if len(ok) > 1 else ok[0])
            else:
                parts.append(None)
    return P(*parts)


def shard(x, *logical_axes):
    """Apply a sharding constraint from logical axes (no-op without a mesh)."""
    mesh = _active_mesh()
    if mesh is None:
        return x
    spec = resolve_spec(x.shape, logical_axes, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, shape: Sequence[int],
                   logical_axes: Sequence[Optional[str]]) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(shape, logical_axes, mesh))


def tree_shardings(mesh: Mesh, tree_sds, tree_axes):
    """Map a ShapeDtypeStruct tree + matching logical-axes tree to
    NamedShardings. The SDS tree is primary: its leaves bound the traversal,
    so the axes tuples (which LOOK like containers) are taken whole."""
    return jax.tree_util.tree_map(
        lambda s, ax: named_sharding(mesh, s.shape,
                                     ax if ax is not None else (None,) * len(s.shape)),
        tree_sds, tree_axes)
