import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be run as a module (``python -m repro.launch.dryrun``): the XLA flag
above executes before any jax import so 512 host devices exist for
``jax.make_mesh``. Never set that flag globally — tests and benches see 1
device.

Per cell it jit-lowers the step with explicit in/out shardings resolved from
the logical-axis rules, compiles, and records memory_analysis(),
cost_analysis() and the collective-bytes breakdown parsed from the HLO —
everything §Roofline consumes. Results accumulate in a JSON file so the
(slow, single-CPU) compiles are resumable.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh single|multi|both] [--out f.json]
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SHAPES, get_arch, shape_applicable
from repro.config.base import ArchFamily, ModelConfig, OptimizerConfig, ShapeConfig, TrainConfig
from repro.configs import ASSIGNED_ARCHS
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import collective_bytes_from_hlo, roofline_terms
from repro.launch.sharding import axis_rules, current_rules, tree_shardings
from repro.launch.steps import (
    batch_axes,
    input_specs,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    opt_state_axes,
)
from repro.models.transformer import lm_init

DEFAULT_OUT = "dryrun_results.json"


def _shapes_tree(tree):
    return jax.tree_util.tree_map(lambda s: tuple(s.shape), tree)


def _train_cfg(cfg: ModelConfig, shape: ShapeConfig,
               microbatches: Optional[int] = None) -> TrainConfig:
    # Big models need grad accumulation to bound live activations; the 1T MoE
    # runs Adafactor (factored second moments) per DESIGN.md §4. Microbatch
    # counts are the memory/collective trade: every microbatch re-gathers the
    # FSDP weights (§Perf H5) — use the fewest that fit HBM.
    if microbatches is None:
        big = cfg.param_count() > 3e10
        microbatches = 8 if big else (2 if cfg.param_count() > 5e9 else 1)
    opt_name = "adafactor" if cfg.param_count() > 3e11 else "adamw"
    return TrainConfig(optimizer=OptimizerConfig(name=opt_name),
                       microbatches=microbatches)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               microbatches: Optional[int] = None) -> Dict[str, Any]:
    """Lower + compile one cell; return the §Dry-run / §Roofline record."""
    cfg = get_arch(arch)
    if cfg.family == ArchFamily.CNN:
        raise SystemExit(f"{arch} is a federated-plane CNN config; the dry-run "
                         "covers the assigned LM architectures")
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        return {"status": "skipped",
                "reason": "long_500k requires sub-quadratic attention (DESIGN.md §5)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()

    from repro.models.layers import abstract_init
    with abstract_init():
        params_shapes, params_axes = lm_init(cfg, 0)

    with mesh:
        p_shard = tree_shardings(mesh, params_shapes, params_axes)
        specs = input_specs(cfg, shape)
        b_axes = batch_axes(cfg, shape)
        b_shard = tree_shardings(mesh, specs, b_axes)

        # Donation mirrors production: params/opt-state update in place for
        # train; the KV/recurrent cache updates in place for decode (without
        # it every step would copy the multi-GB cache — visible in the
        # memory roofline term).
        if shape.mode == "train":
            tc = _train_cfg(cfg, shape, microbatches)
            step, opt_init = make_train_step(cfg, tc)
            opt_shapes = jax.eval_shape(opt_init, params_shapes)
            o_axes = opt_state_axes(cfg, params_axes, tc.optimizer)
            o_shard = _opt_shardings(mesh, opt_shapes, o_axes, p_shard)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_shapes, opt_shapes, specs)
        elif shape.mode == "prefill":
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard),
                             out_shardings=None)
            lowered = jitted.lower(params_shapes, specs)
        else:  # decode
            step = make_serve_step(cfg)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, b_shard["state"],
                                           b_shard["tokens"], b_shard["length"]),
                             out_shardings=(None, b_shard["state"]),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_shapes, specs["state"],
                                   specs["tokens"], specs["length"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll = collective_bytes_from_hlo(compiled.as_text())
        n_dev = mesh.devices.size

    # Whole-program cost_analysis undercounts scan bodies (counted once, not
    # × trip count) — use per-component analysis for the roofline terms.
    tc = _train_cfg(cfg, shape, microbatches) if shape.mode == "train" else None
    comp = component_cost_analysis(cfg, shape, mesh, tc)

    rec = {
        "status": "ok",
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "num_devices": int(n_dev),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_total": comp["flops"],
        "bytes_total": comp["bytes"],
        "collective_bytes": {"total": comp["coll"], "wholeprog": coll},
        "wholeprog_flops": float(cost.get("flops", 0.0)),
        "wholeprog_bytes": float(cost.get("bytes accessed", 0.0)),
        "memory": _mem_dict(mem),
        "params": _actual_params(params_shapes),
        "active_params": _actual_active_params(cfg, params_shapes),
        "tokens": shape.tokens if shape.mode != "decode" else shape.global_batch,
        "mode": shape.mode,
    }
    rec["roofline"] = roofline_terms(rec)
    return rec


def component_cost_analysis(cfg: ModelConfig, shape: ShapeConfig, mesh,
                            tc: Optional[TrainConfig]) -> Dict[str, float]:
    """Whole-step FLOPs/bytes/collective-bytes via per-component analysis.

    XLA's cost_analysis counts a while/scan BODY exactly once regardless of
    trip count (verified on this backend), so whole-program numbers undercount
    layer-scanned models by ~L×. We therefore cost the scan body (one block)
    separately and scale: step = M_microbatches × (L × block + embed/head)
    [+ optimizer once, train only]. Remat is accounted exactly: a remat'd
    block executes fwd (forward scan) + fwd+bwd (backward scan).
    """
    import functools as ft
    from repro.models.layers import abstract_init
    from repro.models import transformer as T

    with abstract_init():
        params_shapes, params_axes = lm_init(cfg, 0)
    blocks_sds = params_shapes["blocks"]
    block_sds = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), blocks_sds)
    block_axes = jax.tree_util.tree_map(lambda s, ax: tuple(ax[1:]),
                                        blocks_sds, params_axes["blocks"])
    L = block_sds and jax.tree_util.tree_leaves(blocks_sds)[0].shape[0]
    M = tc.microbatches if (tc and shape.mode == "train") else 1
    B = shape.global_batch // M
    S = shape.seq_len
    act_dt = jnp.dtype(cfg.dtype)

    def analyzed(fn, in_shardings, *sds, donate=()):
        lowered = jax.jit(fn, in_shardings=in_shardings,
                          donate_argnums=donate).lower(*sds)
        comp = lowered.compile()
        c = comp.cost_analysis()
        coll = collective_bytes_from_hlo(comp.as_text())
        return {"flops": float(c.get("flops", 0.0)),
                "bytes": float(c.get("bytes accessed", 0.0)),
                "coll": float(coll["total"])}

    with mesh:
        b_shard = tree_shardings(mesh, block_sds, block_axes)
        from repro.launch.sharding import named_sharding
        x_sds = jax.ShapeDtypeStruct((B, S, cfg.d_model), act_dt)
        x_sh = named_sharding(mesh, x_sds.shape, ("batch", None, None))
        pos_sds = jax.ShapeDtypeStruct((B, S), jnp.int32)
        pos_sh = named_sharding(mesh, pos_sds.shape, ("batch", None))

        if shape.mode in ("train", "prefill"):
            def blk_fwd(bp, x, pos):
                return T._block_apply(cfg, bp, x, pos)
            fwd = analyzed(blk_fwd, (b_shard, x_sh, pos_sh), block_sds, x_sds, pos_sds)

            if shape.mode == "train":
                def blk_grad(bp, x, pos):
                    def f(bp_, x_):
                        y = T._block_apply(cfg, bp_, x_, pos)
                        return jnp.sum(y.astype(jnp.float32) ** 2)
                    return jax.grad(f, argnums=(0, 1))(bp, x)
                grd = analyzed(blk_grad, (b_shard, x_sh, pos_sh), block_sds, x_sds, pos_sds)
                per_block = {k: (fwd[k] + grd[k]) if cfg.remat else grd[k]
                             for k in ("flops", "bytes", "coll")}
            else:
                per_block = fwd

            # embed + head (+ loss & their grads for train), once per microbatch
            specs = input_specs(cfg, ShapeConfig(shape.name, S, B, shape.mode))
            eh_axes = batch_axes(cfg, ShapeConfig(shape.name, S, B, shape.mode))
            eh_shard = tree_shardings(mesh, specs, eh_axes)
            emb_parts = {k: params_shapes[k] for k in ("embed", "head", "final_norm")}
            emb_axes = {k: params_axes[k] for k in ("embed", "head", "final_norm")}
            emb_shard = tree_shardings(mesh, emb_parts, emb_axes)

            def eh_fn(pp, batch):
                dt = act_dt
                if cfg.family == ArchFamily.AUDIO:
                    x = batch["frontend"].astype(dt)
                elif cfg.family == ArchFamily.VLM:
                    te = T.embed_apply(cfg, pp["embed"], batch["tokens"])
                    x = jnp.concatenate([batch["frontend"].astype(dt), te], axis=1)
                else:
                    x = T.embed_apply(cfg, pp["embed"], batch["tokens"])
                x = T.rmsnorm(pp["final_norm"], x, cfg.norm_eps)
                if shape.mode == "train":
                    labels = batch["labels"]
                    logits = T.unembed_apply(cfg, pp["embed"], pp["head"], x[:, :-1])
                    return T.cross_entropy(logits[:, -(labels.shape[1] - 1):],
                                           labels[:, 1:]).mean()
                return T.unembed_apply(cfg, pp["embed"], pp["head"], x)

            if shape.mode == "train":
                def eh_grad(pp, batch):
                    return jax.grad(eh_fn)(pp, batch)
                eh = analyzed(eh_grad, (emb_shard, eh_shard), emb_parts, specs)
            else:
                eh = analyzed(eh_fn, (emb_shard, eh_shard), emb_parts, specs)

            total = {k: M * (L * per_block[k] + eh[k]) for k in ("flops", "bytes", "coll")}

            if shape.mode == "train":
                opt_init_, opt_update_ = __import__("repro.optim", fromlist=["make_optimizer"]
                                                    ).make_optimizer(tc.optimizer)
                opt_shapes = jax.eval_shape(opt_init_, params_shapes)
                o_axes = opt_state_axes(cfg, params_axes, tc.optimizer)
                o_shard = _opt_shardings(mesh, opt_shapes, o_axes, None)
                p_shard = tree_shardings(mesh, params_shapes, params_axes)

                def opt_fn(g, st, p):
                    up, st2 = opt_update_(g, st, p)
                    p2 = jax.tree_util.tree_map(
                        lambda pp, uu: (pp.astype(jnp.float32)
                                        + uu.astype(jnp.float32)).astype(pp.dtype), p, up)
                    return p2, st2
                opt = analyzed(opt_fn, (p_shard, o_shard, p_shard),
                               params_shapes, opt_shapes, params_shapes,
                               donate=(1, 2))
                total = {k: total[k] + opt[k] for k in total}
            return total

        # decode: one block-decode × L + embed/head fwd
        state_sds = jax.eval_shape(lambda: T.init_decode_state(cfg, B, S))
        layer_state = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), state_sds)
        st_axes_full = T.decode_state_axes(cfg)
        layer_state_axes = jax.tree_util.tree_map(lambda s, ax: tuple(ax[1:]),
                                                  state_sds, st_axes_full)
        st_shard = tree_shardings(mesh, layer_state, layer_state_axes)
        x1 = jax.ShapeDtypeStruct((B, 1, cfg.d_model), act_dt)
        x1_sh = named_sharding(mesh, x1.shape, ("cache_batch", None, None))
        len_sds = jax.ShapeDtypeStruct((B,), jnp.int32)
        len_sh = named_sharding(mesh, (B,), ("cache_batch",))

        def blk_dec(bp, x, st, ln):
            return T._block_decode(cfg, bp, x, st, ln)
        dec = analyzed(blk_dec, (b_shard, x1_sh, st_shard, len_sh),
                       block_sds, x1, layer_state, len_sds, donate=(2,))

        emb_parts = {k: params_shapes[k] for k in ("embed", "head", "final_norm")}
        emb_axes = {k: params_axes[k] for k in ("embed", "head", "final_norm")}
        emb_shard = tree_shardings(mesh, emb_parts, emb_axes)
        tok_sds = (jax.ShapeDtypeStruct((B, cfg.d_model), act_dt)
                   if cfg.family == ArchFamily.AUDIO
                   else jax.ShapeDtypeStruct((B,), jnp.int32))
        tok_sh = named_sharding(mesh, tok_sds.shape,
                                ("cache_batch", None) if cfg.family == ArchFamily.AUDIO
                                else ("cache_batch",))

        def eh_dec(pp, tok):
            if cfg.family == ArchFamily.AUDIO:
                x = tok.astype(act_dt)[:, None, :]
            else:
                x = T.embed_apply(cfg, pp["embed"], tok[:, None])
            x = T.rmsnorm(pp["final_norm"], x, cfg.norm_eps)
            return T.unembed_apply(cfg, pp["embed"], pp["head"], x)
        eh = analyzed(eh_dec, (emb_shard, tok_sh), emb_parts, tok_sds)

        return {k: L * dec[k] + eh[k] for k in ("flops", "bytes", "coll")}


def _actual_params(params_shapes) -> int:
    return int(sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params_shapes)))


def _actual_active_params(cfg: ModelConfig, params_shapes) -> int:
    """Total params minus the unactivated expert fraction (per token)."""
    total = _actual_params(params_shapes)
    if not cfg.is_moe:
        return total
    blocks = params_shapes["blocks"]
    moe = blocks.get("moe", {})
    expert_params = sum(int(np.prod(moe[k].shape))
                        for k in ("w_gate", "w_up", "w_down") if k in moe)
    inactive = expert_params * (cfg.num_experts - cfg.experts_per_token) / cfg.num_experts
    return int(total - inactive)


def _mem_dict(mem) -> Dict[str, float]:
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes", "alias_size_in_bytes"):
        try:
            out[k] = float(getattr(mem, k))
        except Exception:
            pass
    return out


def _opt_shardings(mesh, opt_shapes, o_axes, p_shard):
    from repro.launch.sharding import named_sharding
    is_ax = lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)

    def resolve(shapes, axes):
        return jax.tree_util.tree_map(
            lambda s, a: named_sharding(mesh, s.shape, a if a is not None else
                                        (None,) * len(s.shape)),
            shapes, axes, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    # OptState(step, inner): map manually to tolerate structural differences
    step_sh = named_sharding(mesh, (), ())
    inner = jax.tree_util.tree_map(
        lambda s, a: named_sharding(mesh, s.shape, a if a is not None else (None,) * len(s.shape)),
        opt_shapes.inner, o_axes["inner"], is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    import repro.optim.optimizers as O
    return O.OptState(step_sh, inner)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--microbatches", type=int, default=None)
    args = ap.parse_args()

    results: Dict[str, Any] = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    archs = list(ASSIGNED_ARCHS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = f"{arch}|{shape}|{'multi' if mp else 'single'}"
                if results.get(key, {}).get("status") == "ok":
                    print(f"[skip cached] {key}")
                    continue
                print(f"[lower+compile] {key} ...", flush=True)
                try:
                    rec = lower_cell(arch, shape, mp, args.microbatches)
                except Exception as e:
                    rec = {"status": "error", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                results[key] = rec
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"  ok: compile={rec['compile_s']}s "
                          f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
                          f"collective={r['collective_s']:.4f}s dominant={r['dominant']}")
                else:
                    print(f"  {rec['status']}: {rec.get('reason', rec.get('error'))}")

    n_ok = sum(1 for v in results.values() if v.get("status") == "ok")
    n_skip = sum(1 for v in results.values() if v.get("status") == "skipped")
    n_err = sum(1 for v in results.values() if v.get("status") == "error")
    print(f"\n=== dry-run summary: {n_ok} ok, {n_skip} skipped, {n_err} errors ===")


if __name__ == "__main__":
    main()
