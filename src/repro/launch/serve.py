"""Serving driver: continuous-batched decode against a KV/recurrent cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --requests 16 --max-new 32

Implements the production decode loop: a request pool with per-slot lengths,
one fused ``serve_step`` per token across the whole batch (decode-time
continuous batching — finished slots are immediately re-filled from the
queue), greedy sampling.
"""

from __future__ import annotations

import argparse
import importlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ArchFamily
from repro.launch.steps import make_serve_step
from repro.launch.train import REDUCED_MODULES
from repro.config import get_arch
from repro.models.transformer import init_decode_state, lm_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4, help="batch slots")
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()

    cfg = (importlib.import_module(REDUCED_MODULES[args.arch]).reduced()
           if args.reduced else get_arch(args.arch))
    if cfg.family == ArchFamily.AUDIO:
        raise SystemExit("audio decode demo: use examples/serve_batched.py")

    params, _ = lm_init(cfg, seed=0)
    serve_step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    B = args.slots
    state = init_decode_state(cfg, B, args.cache_len)
    rng = np.random.default_rng(0)

    # request queue: each request = a prompt token + how many tokens to emit
    queue = [(int(rng.integers(0, cfg.vocab_size)), args.max_new)
             for _ in range(args.requests)]
    slot_tok = jnp.zeros((B,), jnp.int32)
    slot_left = np.zeros(B, np.int64)
    lengths = jnp.zeros((B,), jnp.int32)
    completed = 0
    steps = 0
    t0 = time.time()

    while completed < args.requests:
        # fill free slots (continuous batching)
        for b in range(B):
            if slot_left[b] == 0 and queue:
                tok, n = queue.pop()
                slot_tok = slot_tok.at[b].set(tok)
                slot_left[b] = n
                lengths = lengths.at[b].set(0)
        logits, state = serve_step(params, state, slot_tok, lengths)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        lengths = lengths + (slot_left > 0)
        slot_tok = jnp.where(jnp.asarray(slot_left > 0), next_tok, slot_tok)
        steps += 1
        for b in range(B):
            if slot_left[b] > 0:
                slot_left[b] -= 1
                if slot_left[b] == 0:
                    completed += 1

    dt = time.time() - t0
    total_tokens = args.requests * args.max_new
    print(f"served {args.requests} requests / {total_tokens} tokens in "
          f"{steps} fused steps, {dt:.2f}s ({total_tokens / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
