"""Sharded step builders + input specs for every (arch × shape) cell.

``make_train_step``: microbatched (grad-accumulation scan), remat'd,
grad-clipped train step with the configured optimizer.
``make_serve_step``: one-token decode against a KV/recurrent cache.
``make_prefill_step``: full-sequence forward (serving prefill).

``input_specs`` returns ShapeDtypeStructs for every model input of a cell —
weak-type-correct, shardable, no device allocation — the dry-run contract.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ArchFamily, ModelConfig, OptimizerConfig, ShapeConfig, TrainConfig
from repro.models.transformer import (
    init_decode_state,
    lm_apply,
    lm_decode_step,
    lm_init,
    lm_loss,
)
from repro.optim import clip_by_global_norm, make_optimizer

PyTree = Any


# ---------------- input specs (ShapeDtypeStruct stand-ins) ----------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Model inputs for one cell. train/prefill: the batch dict;
    decode: {"state": ..., "tokens": ..., "length": ...}."""
    B, S = shape.global_batch, shape.seq_len
    f32 = jnp.dtype(cfg.dtype)
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct

    if shape.mode in ("train", "prefill"):
        batch: Dict[str, Any] = {}
        if cfg.family == ArchFamily.AUDIO:
            batch["frontend"] = sd((B, S, cfg.d_model), f32)
            if shape.mode == "train":
                batch["labels"] = sd((B, S), i32)
        elif cfg.family == ArchFamily.VLM:
            F = cfg.frontend_tokens
            batch["frontend"] = sd((B, F, cfg.d_model), f32)
            batch["tokens"] = sd((B, S - F), i32)
            if shape.mode == "train":
                batch["labels"] = sd((B, S - F), i32)
        else:
            batch["tokens"] = sd((B, S), i32)
            if shape.mode == "train":
                batch["labels"] = sd((B, S), i32)
        return batch

    # decode: one new token against a cache of S
    state = jax.eval_shape(lambda: init_decode_state(cfg, B, S))
    if cfg.family == ArchFamily.AUDIO:
        tokens = sd((B, cfg.d_model), f32)
    else:
        tokens = sd((B,), i32)
    return {"state": state, "tokens": tokens, "length": sd((B,), i32)}


def batch_axes(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Logical axes matching input_specs (for in_shardings)."""
    if shape.mode in ("train", "prefill"):
        axes: Dict[str, Any] = {}
        if cfg.family == ArchFamily.AUDIO:
            axes["frontend"] = ("batch", "seq", None)
            if shape.mode == "train":
                axes["labels"] = ("batch", "seq")
        elif cfg.family == ArchFamily.VLM:
            axes["frontend"] = ("batch", "seq", None)
            axes["tokens"] = ("batch", "seq")
            if shape.mode == "train":
                axes["labels"] = ("batch", "seq")
        else:
            axes["tokens"] = ("batch", "seq")
            if shape.mode == "train":
                axes["labels"] = ("batch", "seq")
        return axes
    from repro.models.transformer import decode_state_axes
    if cfg.family == ArchFamily.AUDIO:
        tok_ax = ("cache_batch", None)
    else:
        tok_ax = ("cache_batch",)
    return {"state": decode_state_axes(cfg), "tokens": tok_ax,
            "length": ("cache_batch",)}


# ---------------- optimizer state axes ----------------

def opt_state_axes(cfg: ModelConfig, params_axes: PyTree, opt: OptimizerConfig):
    """Logical axes for the optimizer state pytree (mirrors params)."""
    is_ax = lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)
    if opt.name in ("adam", "adamw"):
        inner = (jax.tree_util.tree_map(lambda a: a, params_axes, is_leaf=is_ax),
                 jax.tree_util.tree_map(lambda a: a, params_axes, is_leaf=is_ax))
    elif opt.name == "momentum":
        inner = jax.tree_util.tree_map(lambda a: a, params_axes, is_leaf=is_ax)
    elif opt.name == "adafactor":
        def factored(a):
            # row acc drops last dim; col acc drops second-to-last
            if len(a) >= 2:
                return (a[:-1], a[:-2] + a[-1:])
            return (a, None)
        inner = jax.tree_util.tree_map(factored, params_axes, is_leaf=is_ax)
    else:  # sgd
        inner = ()
    return {"step": (), "inner": inner}


# ---------------- train step ----------------

def make_train_step(cfg: ModelConfig, train_cfg: TrainConfig):
    opt_init, opt_update = make_optimizer(train_cfg.optimizer)

    def loss_fn(params, mb):
        return lm_loss(cfg, params, mb)

    def train_step(params, opt_state, batch):
        M = train_cfg.microbatches
        if M > 1:
            def split(x):
                return x.reshape((M, x.shape[0] // M) + x.shape[1:])
            mbs = jax.tree_util.tree_map(split, batch)

            def acc_body(carry, mb):
                loss_acc, grad_acc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                grad_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), grad_acc, g)
                return (loss_acc + loss, grad_acc), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = jax.lax.scan(acc_body, (0.0, zeros), mbs)
            loss = loss_sum / M
            grads = jax.tree_util.tree_map(lambda g: g / M, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        grads, gnorm = clip_by_global_norm(grads, train_cfg.optimizer.grad_clip)
        updates, opt_state = opt_update(grads, opt_state, params)
        params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
            params, updates)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step, opt_init


# ---------------- serving steps ----------------

def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return lm_apply(cfg, params, tokens=batch.get("tokens"),
                        frontend=batch.get("frontend"))
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, state, tokens, length):
        return lm_decode_step(cfg, params, state, tokens, length)
    return serve_step


# ---------------- host-side batch synthesis (real runs, not dry-run) ----------------

def synth_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
    rng = np.random.default_rng(seed)
    specs = input_specs(cfg, shape)

    def materialize(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jnp.asarray(rng.integers(0, max(cfg.vocab_size, 2),
                                            s.shape), s.dtype)
        return jnp.asarray(rng.normal(0, 1, s.shape), s.dtype)

    return jax.tree_util.tree_map(materialize, specs)
