"""End-to-end training driver: real steps on the host mesh.

Usage (CPU-scale smoke of the production path):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --steps 50 \
      --reduced --batch 8 --seq 128

``--reduced`` swaps in the per-arch smoke config (same family, small dims) so
a few hundred real steps finish on this container; the full configs are
exercised via the dry-run. The driver wires together: config registry, data
pipeline, sharded train step, checkpointing, and the elastic runtime —
identical code paths to the production launch.
"""

from __future__ import annotations

import argparse
import importlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SHAPES, get_arch
from repro.config.base import ArchFamily, OptimizerConfig, ShapeConfig, TrainConfig
from repro.data.synthetic import make_lm_tokens
from repro.launch.elastic import ElasticConfig, run_elastic
from repro.launch.steps import make_train_step
from repro.models.transformer import lm_init

REDUCED_MODULES = {
    "qwen3-1.7b": "repro.configs.qwen3_1p7b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "glm4-9b": "repro.configs.glm4_9b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "hymba-1.5b": "repro.configs.hymba_1p5b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "paligemma-3b": "repro.configs.paligemma_3b",
}


class TokenBatcher:
    """Restartable LM batch stream over a synthetic token corpus."""

    def __init__(self, cfg, batch: int, seq: int, seed: int = 0):
        self.cfg = cfg
        self.batch, self.seq = batch, seq
        vocab = max(cfg.vocab_size, 2)
        self.tokens = make_lm_tokens(200_000, vocab, seed=seed)
        self.cursor = 0
        self.rng_seed = seed

    def state(self):
        return {"cursor": self.cursor}

    def restore(self, st):
        self.cursor = st["cursor"]

    def __iter__(self):
        return self

    def __next__(self):
        n = self.batch * self.seq
        if self.cursor + n + 1 > len(self.tokens):
            self.cursor = 0
        chunk = self.tokens[self.cursor: self.cursor + n]
        self.cursor += n
        toks = jnp.asarray(chunk.reshape(self.batch, self.seq), jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        if self.cfg.family == ArchFamily.AUDIO:
            rng = np.random.default_rng(self.cursor)
            batch = {"frontend": jnp.asarray(
                rng.normal(0, 1, (self.batch, self.seq, self.cfg.d_model)),
                jnp.float32), "labels": toks}
        elif self.cfg.family == ArchFamily.VLM:
            rng = np.random.default_rng(self.cursor)
            batch["frontend"] = jnp.asarray(
                rng.normal(0, 1, (self.batch, self.cfg.frontend_tokens,
                                  self.cfg.d_model)), jnp.float32)
        return batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="use the per-arch smoke config (CPU-scale)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--save-every", type=int, default=25)
    args = ap.parse_args()

    if args.reduced:
        cfg = importlib.import_module(REDUCED_MODULES[args.arch]).reduced()
    else:
        cfg = get_arch(args.arch)

    tc = TrainConfig(optimizer=OptimizerConfig(name="adamw", lr=args.lr),
                     microbatches=1)
    step, opt_init = make_train_step(cfg, tc)
    step = jax.jit(step, donate_argnums=(0, 1))

    def make_state():
        params, _ = lm_init(cfg, seed=0)
        return (params, opt_init(params))

    batches = TokenBatcher(cfg, args.batch, args.seq)

    t0 = time.time()
    losses = []

    def on_step(i, m):
        losses.append(m["loss"])
        if i % 10 == 0 or i == 1:
            print(f"step {i:4d}  loss {m['loss']:.4f}  gnorm {m['grad_norm']:.3f} "
                  f"({time.time() - t0:.1f}s)")

    def step_fn(state, batch):
        params, opt_state = state
        params, opt_state, metrics = step(params, opt_state, batch)
        return (params, opt_state), metrics

    out = run_elastic(make_state=make_state, step_fn=step_fn,
                      batch_iter=batches, num_steps=args.steps,
                      config=ElasticConfig(save_every=args.save_every,
                                           checkpoint_dir=args.ckpt_dir),
                      on_step=on_step)
    print(f"done: {args.steps} steps, first loss {losses[0]:.4f} -> "
          f"last {losses[-1]:.4f}, restarts={out['restarts']}")


if __name__ == "__main__":
    main()
