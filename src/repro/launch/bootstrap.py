"""Host-platform bootstrap: size the jax CPU "fleet" BEFORE jax imports.

The fleet-sharding layer (``repro.core.shard``) partitions the K axis over
``jax.device_count()`` devices. On CPU that count is 1 unless the process
was started with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` —
and XLA reads the flag at backend initialization, so setting it after
``import jax`` (or after anything that imports jax) is a silent no-op.
Same story for tcmalloc: ``LD_PRELOAD`` only takes effect at process start.
Hence this module's contract: import it and call ``ensure_host_devices``
FIRST, before any jax import anywhere in the process; when the environment
is missing it re-execs the interpreter once with the right env and the
marker ``REPRO_LAUNCH_BOOTSTRAPPED=1`` (so a misconfigured child can never
re-exec forever).

Typical use, first lines of a benchmark / experiment entry point::

    from repro.launch.bootstrap import ensure_host_devices
    ensure_host_devices(8)      # may os.execv() and not return
    import jax                  # now sees 8 CPU devices

or purely declarative (print the env for a shell wrapper)::

    python -m repro.launch.bootstrap --shards 8
"""

from __future__ import annotations

import os
import sys
from typing import Dict, Optional

# Re-exec guard: present in the child environment so a host that cannot
# satisfy the request fails loudly instead of exec-looping.
_MARKER = "REPRO_LAUNCH_BOOTSTRAPPED"

_DEVICE_FLAG = "--xla_force_host_platform_device_count"

# Common tcmalloc locations (Debian/Ubuntu multiarch, RHEL, conda).
_TCMALLOC_CANDIDATES = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/aarch64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib64/libtcmalloc.so.4",
    "/usr/lib/libtcmalloc.so.4",
)


def find_tcmalloc() -> Optional[str]:
    """Path of a preloadable tcmalloc, or None. glibc malloc serializes
    the multi-hundred-MB host buffer churn of a many-device CPU platform;
    tcmalloc's thread caches remove that contention (the HomebrewNLP CPU
    recipe). Optional — sharding works without it, just slower."""
    if os.environ.get("REPRO_NO_TCMALLOC"):
        return None
    for cand in _TCMALLOC_CANDIDATES:
        if os.path.exists(cand):
            return cand
    return None


def host_platform_env(num_shards: int,
                      tcmalloc: bool = True) -> Dict[str, str]:
    """The env vars a process needs for an ``num_shards``-device host
    platform: ``XLA_FLAGS`` with the device-count flag folded into any
    existing flags, plus ``LD_PRELOAD`` of tcmalloc when available."""
    n = int(num_shards)
    if n < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    flags = [f for f in os.environ.get("XLA_FLAGS", "").split()
             if not f.startswith(f"{_DEVICE_FLAG}=")]
    flags.append(f"{_DEVICE_FLAG}={n}")
    env = {"XLA_FLAGS": " ".join(flags)}
    if tcmalloc:
        lib = find_tcmalloc()
        if lib is not None:
            pre = os.environ.get("LD_PRELOAD", "")
            if lib not in pre.split(":"):
                env["LD_PRELOAD"] = f"{pre}:{lib}".strip(":")
    return env


def _current_device_flag() -> Optional[int]:
    for f in os.environ.get("XLA_FLAGS", "").split():
        if f.startswith(f"{_DEVICE_FLAG}="):
            try:
                return int(f.split("=", 1)[1])
            except ValueError:
                return None
    return None


def ensure_host_devices(num_shards: int, tcmalloc: bool = True) -> bool:
    """Make sure this process runs with >= ``num_shards`` host devices.

    Returns True when the environment already satisfies the request (also
    covers real multi-device backends, and num_shards <= 1). Otherwise
    re-execs the CURRENT interpreter with ``host_platform_env`` applied —
    the call does not return in that case. Must run before jax is
    imported; if jax is already in ``sys.modules`` with too few devices,
    raises RuntimeError instead of silently mis-sharding.
    """
    n = int(num_shards)
    if n <= 1:
        return True
    flag = _current_device_flag()
    if flag is not None and flag >= n:
        return True
    if "jax" in sys.modules:
        import jax

        if jax.device_count() >= n:
            return True
        raise RuntimeError(
            f"need {n} devices but jax initialized with "
            f"{jax.device_count()}; call ensure_host_devices() before "
            "importing jax (or launch with "
            f"XLA_FLAGS={_DEVICE_FLAG}={n})")
    if os.environ.get(_MARKER):
        raise RuntimeError(
            f"bootstrap re-exec did not produce {n} host devices "
            f"(XLA_FLAGS={os.environ.get('XLA_FLAGS', '')!r})")
    env = dict(os.environ)
    env.update(host_platform_env(n, tcmalloc=tcmalloc))
    env[_MARKER] = "1"
    sys.stdout.flush()
    sys.stderr.flush()
    os.execve(sys.executable, [sys.executable] + sys.argv, env)
    raise AssertionError("unreachable: execve returned")  # pragma: no cover


def main(argv=None) -> None:
    """Print ``export`` lines for a shell wrapper (no jax import here)."""
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.bootstrap",
        description="print the env needed for an N-device host platform")
    ap.add_argument("--shards", type=int, required=True)
    ap.add_argument("--no-tcmalloc", action="store_true")
    args = ap.parse_args(argv)
    for k, v in host_platform_env(args.shards,
                                  tcmalloc=not args.no_tcmalloc).items():
        print(f"export {k}={v!r}")


if __name__ == "__main__":
    main(sys.argv[1:])
