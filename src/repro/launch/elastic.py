"""Elastic / fault-tolerant training runtime.

Production contract (designed for 1000+ nodes, exercised here at host scale):

- **Checkpoint cadence + atomic commits** (checkpoint/): a crash at any
  instant loses at most ``save_every`` steps; partial saves are GC'd.
- **Elastic restore**: params/opt-state are saved UNSHARDED and re-device_put
  against whatever mesh exists at restart — scaling from 256 to 512 chips (or
  down to whatever survives a failure) needs no checkpoint surgery. The
  data-pipeline cursor rides in the checkpoint ``extra`` so the batch stream
  resumes exactly.
- **Failure detection loop**: ``run_elastic`` wraps the step loop; a step
  raising (device loss manifests as XlaRuntimeError on real fleets — injected
  here via ``FailureInjector``) triggers: re-mesh over surviving devices,
  restore latest checkpoint, resume. Straggler mitigation at the FL plane
  lives in core/multijob.py (over-provisioning + deadline drop).
- **Cross-pod gradient strategy**: the pod axis only carries batch, so a pod
  loss degrades to the single-pod mesh with the SAME logical rules — resolve_
  spec simply stops mapping "pod".
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


class FailureInjector:
    """Deterministic fault injection for tests/examples: raises at given steps."""

    def __init__(self, fail_at_steps=(), exc=RuntimeError):
        self.fail_at = set(fail_at_steps)
        self.exc = exc
        self.injected = []

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.injected.append(step)
            raise self.exc(f"injected device failure at step {step}")


@dataclasses.dataclass
class ElasticConfig:
    save_every: int = 20
    max_restarts: int = 3
    checkpoint_dir: str = "/tmp/repro_ckpt"


def run_elastic(
    *,
    make_state: Callable[[], Any],           # () -> (params, opt_state)
    step_fn: Callable[[Any, Any], Any],      # (state, batch) -> (state, metrics)
    batch_iter,                               # restartable iterator with .state()/.restore()
    num_steps: int,
    config: ElasticConfig,
    injector: Optional[FailureInjector] = None,
    on_step: Optional[Callable[[int, Dict], None]] = None,
) -> Dict[str, Any]:
    """Run ``num_steps`` with checkpoint/restart fault tolerance.

    Returns {'state': final_state, 'restarts': n, 'steps_replayed': n}.
    """
    mgr = CheckpointManager(config.checkpoint_dir, keep=2)
    restarts = 0
    replayed = 0

    init_pipeline = batch_iter.state()  # for recovery before any checkpoint
    state = make_state()
    step = 0
    latest = mgr.latest_step()
    if latest is not None:
        step, state, extra = mgr.restore_latest(state)
        if "pipeline" in extra:
            batch_iter.restore(extra["pipeline"])

    while step < num_steps:
        try:
            if injector is not None:
                injector.maybe_fail(step)
            batch = next(batch_iter)
            state, metrics = step_fn(state, batch)
            step += 1
            if on_step is not None:
                m = {k: float(v) for k, v in metrics.items()}
                on_step(step, m)
            if step % config.save_every == 0 or step == num_steps:
                mgr.save(step, state, extra={"pipeline": batch_iter.state()})
        except StopIteration:
            break
        except Exception as e:  # noqa: BLE001 — any fault triggers recovery
            restarts += 1
            if restarts > config.max_restarts:
                raise RuntimeError(f"exceeded max_restarts={config.max_restarts}") from e
            latest = mgr.latest_step()
            if latest is None:
                state = make_state()
                batch_iter.restore(init_pipeline)
                replayed += step
                step = 0
            else:
                prev_step, state, extra = mgr.restore_latest(make_state())
                if "pipeline" in extra:
                    batch_iter.restore(extra["pipeline"])
                replayed += step - prev_step
                step = prev_step
    return {"state": state, "restarts": restarts, "steps_replayed": replayed}
