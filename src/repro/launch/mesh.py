"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Single-pod: (16, 16) = ("data", "model") — one v5e pod of
256 chips. Multi-pod: (2, 16, 16) = ("pod", "data", "model") — 512 chips;
the "pod" axis only ever carries batch (pure DP across pods: the slowest
links are crossed by exactly one gradient all-reduce per step).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.config.base import MeshConfig

SINGLE_POD = MeshConfig(shape=(16, 16), axes=("data", "model"))
MULTI_POD = MeshConfig(shape=(2, 16, 16), axes=("pod", "data", "model"))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(config: MeshConfig) -> jax.sharding.Mesh:
    return jax.make_mesh(config.shape, config.axes)


def make_host_mesh(model_axis: Optional[int] = None) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    model = model_axis or 1
    return jax.make_mesh((n // model, model), ("data", "model"))
