"""Roofline analysis from the compiled dry-run artifact.

Three terms per (arch × shape × mesh), in SECONDS:

    compute_s    = HLO_FLOPs / (chips × 197e12)          [bf16 MXU peak]
    memory_s     = HLO_bytes / (chips × 819e9)           [HBM bandwidth]
    collective_s = collective_bytes / (chips × 50e9)     [ICI per link]

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis() (whole-program
totals; per-chip = total / chips since GSPMD splits evenly).
collective_bytes is NOT in cost_analysis: we parse the post-SPMD HLO text
and sum SHARD-LOCAL operand bytes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops, weighted by the ring
traffic factor each collective actually puts on a link.

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) sanity-checks the compiled
FLOPs (remat & dead compute inflate the ratio HLO/MODEL above ~1.33 for a
remat'd train step: fwd+bwd+recompute ≈ 8·N·D).
"""

from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12        # TPU v5e bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"^\s*(?:[%\w.\-]+)\s*=\s*\(?([\w\[\],\s{}]+?)\)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.MULTILINE)

# Ring-algorithm traffic each op puts on a single link, as a multiple of the
# shard-local payload bytes (n = group size; approximated for large n):
#   all-gather: receives (n-1)/n of the FULL output  ~= output_bytes
#   all-reduce: 2(n-1)/n of payload                  ~= 2x
#   reduce-scatter: (n-1)/n of payload               ~= 1x
#   all-to-all: (n-1)/n of payload                   ~= 1x
#   collective-permute: 1x
_TRAFFIC_FACTOR = {
    "all-gather": 1.0,      # applied to the (full) result shape
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Sum link-traffic bytes per collective kind from post-SPMD HLO."""
    out: Dict[str, float] = {k: 0.0 for k in _TRAFFIC_FACTOR}
    count: Dict[str, int] = {k: 0 for k in _TRAFFIC_FACTOR}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        result_shapes, kind = m.group(1), m.group(2)
        b = _shape_bytes(result_shapes)
        out[kind] += b * _TRAFFIC_FACTOR[kind]
        count[kind] += 1
    out["total"] = sum(v for k, v in out.items() if k in _TRAFFIC_FACTOR)
    out["counts"] = count  # type: ignore
    return out


def roofline_terms(rec: Dict) -> Dict:
    """rec: a dry-run record. flops_total / bytes_total / collective_bytes are
    PER-DEVICE quantities: cost_analysis and the HLO text both describe the
    post-SPMD per-partition program (verified: per-device flops × chips ≈
    8·N·D for a remat'd train step). The brief's chips-denominator formulas
    are therefore applied with cluster_total = per_device × chips, i.e. the
    chips cancel: term_s = per_device_quantity / per_chip_rate."""
    chips = rec["num_devices"]
    compute_s = rec["flops_total"] / PEAK_FLOPS
    memory_s = rec["bytes_total"] / HBM_BW
    coll_bytes = rec["collective_bytes"]["total"]
    collective_s = coll_bytes / ICI_BW

    n = rec["active_params"]
    d = rec["tokens"]
    factor = 6.0 if rec["mode"] == "train" else 2.0
    model_flops = factor * n * d              # cluster-total useful FLOPs
    model_flops_pd = model_flops / chips      # per-device share
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "model_flops": model_flops,
        "hlo_flops_per_device": rec["flops_total"],
        "useful_flops_ratio": (model_flops_pd / rec["flops_total"]
                               if rec["flops_total"] else 0.0),
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["dominant"] = dom.replace("_s", "")
    bound = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    ideal_s = model_flops_pd / PEAK_FLOPS
    terms["roofline_fraction"] = ideal_s / bound if bound > 0 else 0.0
    return terms
