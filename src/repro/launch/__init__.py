"""Distributed launch layer: meshes, sharding rules, step builders, dry-run.

``repro.launch.bootstrap`` sizes the host platform (XLA_FLAGS device count,
tcmalloc preload) and must be imported/called BEFORE jax — this package
``__init__`` therefore stays import-free.
"""
