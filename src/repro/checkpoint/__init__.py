"""Checkpointing: atomic pytree save/restore with elastic re-sharding."""

from repro.checkpoint.checkpoint import (
    CheckpointManager,
    committed_steps,
    load_checkpoint,
    save_checkpoint,
    step_path,
)

__all__ = ["CheckpointManager", "committed_steps", "load_checkpoint",
           "save_checkpoint", "step_path"]
