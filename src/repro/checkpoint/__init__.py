"""Checkpointing: atomic pytree save/restore with elastic re-sharding."""

from repro.checkpoint.checkpoint import (
    CheckpointManager,
    load_checkpoint,
    save_checkpoint,
)

__all__ = ["CheckpointManager", "load_checkpoint", "save_checkpoint"]
