"""Atomic, manifest-driven pytree checkpoints (pure numpy .npz container).

Layout:  <dir>/step_<N>/
            manifest.json   — tree structure, leaf dtypes/shapes, metadata
            arrays.npz      — flat leaf arrays keyed "leaf_<i>"
            .complete       — commit marker (written LAST -> atomic restore)

Fault-tolerance contract:
- ``save`` writes into a temp dir then os.rename's it into place; a crash
  mid-save never corrupts the latest checkpoint.
- ``restore`` picks the newest COMMITTED step; partial saves are ignored and
  garbage-collected.
- Elastic restore: leaves are stored unsharded (host gathers); on resume the
  caller re-device_puts against the CURRENT mesh's shardings, so the job can
  restart on a different device count (EXPERIMENTS.md §Dry-run demonstrates
  restore across 256- and 512-chip meshes).
- The data-pipeline cursor and scheduler states ride in ``extra`` so a
  restart resumes the exact batch stream.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import warnings
import zipfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

PyTree = Any
_MARKER = ".complete"

# numpy's savez cannot serialize ml_dtypes (bfloat16 etc.) — store them as
# same-width integer views and restore from the manifest dtype.
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
           "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _encode(a: np.ndarray) -> np.ndarray:
    name = a.dtype.name
    if name in _EXOTIC:
        return a.view(_EXOTIC[name][1])
    return a


def _decode(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC:
        return a.view(_EXOTIC[dtype_name][0])
    return a


def _flatten_with_paths(tree: PyTree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out, treedef


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    final = step_path(directory, step)
    tmp = tempfile.mkdtemp(prefix=f".tmp_step_{step}_", dir=directory)
    try:
        flat, _ = _flatten_with_paths(tree)
        raw = [np.asarray(jax.device_get(v)) for _, v in flat]
        arrays = {f"leaf_{i}": _encode(a) for i, a in enumerate(raw)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "keys": [k for k, _ in flat],
            "dtypes": [a.dtype.name for a in raw],
            "shapes": [list(a.shape) for a in raw],
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, _MARKER), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def step_path(directory: str, step: int) -> str:
    """Canonical on-disk location of one step — the single definition of
    the layout (consumers like ``repro.gym.zoo`` must not re-derive it)."""
    return os.path.join(directory, f"step_{step:010d}")


def committed_steps(directory: str) -> List[int]:
    """Steps with a commit marker (fully written), ascending. Public so
    layered stores (the policy zoo) share one notion of 'committed'."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
                os.path.join(directory, name, _MARKER)):
            steps.append(int(name.split("_")[1]))
    return sorted(steps)


_committed_steps = committed_steps


# Failure modes a damaged-on-disk step presents as: missing/short files
# (OSError, EOFError), garbled JSON, an npz whose zip directory is torn
# (zipfile.BadZipFile or ValueError from numpy), or a manifest missing keys.
_CORRUPT_ERRORS = (OSError, ValueError, KeyError, json.JSONDecodeError,
                   zipfile.BadZipFile, EOFError)


def _load_step(path: str, like: PyTree, shardings: Optional[PyTree]
               ) -> Tuple[PyTree, Dict[str, Any]]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves = [_decode(data[f"leaf_{i}"], manifest["dtypes"][i])
              for i in range(len(manifest["keys"]))]
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    assert len(flat_like) == len(leaves), "checkpoint/model structure mismatch"
    if shardings is not None:
        flat_sh = treedef.flatten_up_to(shardings)
        leaves = [jax.device_put(l.astype(fl.dtype), s)
                  for l, fl, s in zip(leaves, flat_like, flat_sh)]
    else:
        leaves = [np.asarray(l, dtype=fl.dtype) for l, fl in zip(leaves, flat_like)]
    return treedef.unflatten(leaves), manifest["extra"]


def load_checkpoint(directory: str, like: PyTree, step: Optional[int] = None,
                    shardings: Optional[PyTree] = None
                    ) -> Tuple[int, PyTree, Dict[str, Any]]:
    """Restore the newest (or given) committed step into the structure of
    ``like``. If ``shardings`` is given, leaves are device_put against it
    (elastic re-shard onto the current mesh).

    When ``step`` is None and the newest committed step is unreadable
    (torn write that still managed to land a marker, disk bit-rot), older
    committed steps are tried newest-first — losing one save interval
    beats refusing to resume. An explicitly requested ``step`` still
    raises: the caller asked for THAT state, not a neighbor's.
    """
    steps = _committed_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints in {directory}")
    if step is not None:
        tree, extra = _load_step(step_path(directory, step), like, shardings)
        return step, tree, extra
    last_err: Optional[BaseException] = None
    for s in reversed(steps):
        try:
            tree, extra = _load_step(step_path(directory, s), like, shardings)
        except _CORRUPT_ERRORS as e:
            warnings.warn(
                f"checkpoint step {s} in {directory} is unreadable "
                f"({type(e).__name__}: {e}); falling back to the previous "
                f"committed step", stacklevel=2)
            last_err = e
            continue
        return s, tree, extra
    raise FileNotFoundError(
        f"all {len(steps)} committed checkpoints in {directory} are "
        f"unreadable (last error: {last_err!r})")


class CheckpointManager:
    """Keep-last-N manager with crash-safe GC of partial saves."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._gc_partial()

    def _gc_partial(self) -> None:
        for name in os.listdir(self.directory):
            p = os.path.join(self.directory, name)
            if name.startswith(".tmp_") or (
                    name.startswith("step_") and not os.path.exists(os.path.join(p, _MARKER))):
                shutil.rmtree(p, ignore_errors=True)

    def save(self, step: int, tree: PyTree, extra: Optional[Dict] = None) -> str:
        path = save_checkpoint(self.directory, step, tree, extra)
        for s in _committed_steps(self.directory)[: -self.keep]:
            shutil.rmtree(step_path(self.directory, s),
                          ignore_errors=True)
        return path

    def restore_latest(self, like: PyTree, shardings=None):
        return load_checkpoint(self.directory, like, shardings=shardings)

    def latest_step(self) -> Optional[int]:
        steps = _committed_steps(self.directory)
        return steps[-1] if steps else None
