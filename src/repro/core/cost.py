"""The paper's cost model.

Formula 2:  Cost_m^r(V) = alpha * T_m^r(V) + beta * F_m^r(V)
Formula 3:  T_m^r(V)    = max_{k in V} t_m^k
Formula 5:  F_m^r(V)    = Var_k(s_{k,m}^r)   (population variance over ALL K devices)
Formula 8:  TotalCost   = sum_m Cost_m^r  (other jobs' in-flight plans are context)

Costs are evaluated two ways:
- ``estimate``: expected times (used by schedulers to search plans);
- ``realize``:  sampled times from Formula 4 (used by the engine to advance
  the simulated clock — the number the paper reports).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.devices import DevicePool


@dataclasses.dataclass
class CostModel:
    pool: DevicePool
    alpha: float = 1.0
    beta: float = 1.0
    # Normalizers keep the two terms commensurate (paper: alpha/beta tuned
    # empirically; we normalize by running scales so alpha=beta=1 is sane).
    time_scale: float = 1.0
    fairness_scale: float = 1.0
    # Scheduling uses the per-round fairness INCREMENT var(s+v) - var(s):
    # identical argmin to the paper's absolute var(s+v) (the subtrahend is
    # constant w.r.t. the candidate), but scale-stationary over rounds — the
    # absolute variance grows ~linearly with r, which would drown the time
    # term and break GP stationarity for BODS / reward stationarity for RLDS.
    # Records still report the paper's absolute Formula-5 value.
    delta_fairness: bool = True

    # ---- Formula 5 ----

    def fairness(self, counts: np.ndarray, plan: Optional[np.ndarray] = None) -> float:
        """Variance of scheduling frequency if ``plan`` were applied on top of counts.

        ``counts``: (K,) cumulative times device k has been scheduled to the job.
        ``plan``:   optional (K,) bool/0-1 — the candidate round plan.
        """
        s = counts if plan is None else counts + plan
        return float(np.var(s))

    def fairness_batch(self, counts: np.ndarray, plans: np.ndarray) -> np.ndarray:
        """(P,) fairness for P candidate plans (P, K)."""
        s = counts[None, :] + plans
        f = np.var(s, axis=1)
        if self.delta_fairness:
            f = f - np.var(counts)
        return f

    # ---- Formula 3 ----

    def round_time(self, times: np.ndarray, plan: np.ndarray) -> float:
        """max over selected devices; empty plan -> 0."""
        sel = times[plan.astype(bool)]
        return float(sel.max()) if sel.size else 0.0

    def round_time_batch(self, times: np.ndarray, plans: np.ndarray) -> np.ndarray:
        masked = np.where(plans.astype(bool), times[None, :], -np.inf)
        out = masked.max(axis=1)
        return np.where(np.isfinite(out), out, 0.0)

    # ---- Formula 2 ----

    def cost(self, times: np.ndarray, counts: np.ndarray, plan: np.ndarray) -> float:
        t = self.round_time(times, plan) / self.time_scale
        f = self.fairness(counts, plan)
        if self.delta_fairness:
            f -= self.fairness(counts)
        return self.alpha * t + self.beta * f / self.fairness_scale

    def cost_batch(self, times: np.ndarray, counts: np.ndarray, plans: np.ndarray) -> np.ndarray:
        t = self.round_time_batch(times, plans) / self.time_scale
        f = self.fairness_batch(counts, plans) / self.fairness_scale
        return self.alpha * t + self.beta * f

    # ---- Formula 8 (TotalCost): current job's candidate + other jobs' fixed plans ----

    def total_cost_batch(
        self,
        job: int,
        tau: float,
        counts: np.ndarray,           # (K,) frequency counts of the current job
        plans: np.ndarray,            # (P, K) candidates for the current job
        other_costs: float = 0.0,     # sum of Cost_m' for jobs m' != m (constants)
        times: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        if times is None:
            times = self.pool.expected_times(job, tau)
        return self.cost_batch(times, counts, plans) + other_costs

    def calibrate(self, taus: Sequence[float], n_sel: int) -> None:
        """Set time/fairness normalizers from the pool so alpha,beta are unitless.

        time_scale ~ median expected round time over jobs; fairness_scale ~ the
        variance increment a single maximally-unfair round would add.
        """
        med = []
        for m, tau in enumerate(taus):
            t = self.pool.expected_times(m, tau)
            med.append(np.median(np.sort(t)[:n_sel]))
        self.time_scale = float(np.median(med)) or 1.0
        # Fairness increment scale: adding one round moves var(s) by O(n_sel/K)
        # around its mean drift — normalize so a typical increment is O(1).
        k = self.pool.num_devices
        p = n_sel / k
        self.fairness_scale = max(p * (1 - p), 1e-6)
