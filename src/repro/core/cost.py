"""The paper's cost model.

Formula 2:  Cost_m^r(V) = alpha * T_m^r(V) + beta * F_m^r(V)
Formula 3:  T_m^r(V)    = max_{k in V} t_m^k
Formula 5:  F_m^r(V)    = Var_k(s_{k,m}^r)   (population variance over ALL K devices)
Formula 8:  TotalCost   = sum_m Cost_m^r  (other jobs' in-flight plans are context)

Costs are evaluated two ways:
- ``estimate``: expected times (used by schedulers to search plans);
- ``realize``:  sampled times from Formula 4 (used by the engine to advance
  the simulated clock — the number the paper reports).

All batched evaluation routes through ``repro.core.scoring`` — one jitted
scoring path (numpy / jax / pallas by ``scoring_backend``) under every
scheduler; the scalar helpers stay plain numpy.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core import scoring
from repro.core.devices import DevicePool


@dataclasses.dataclass
class CostModel:
    pool: DevicePool
    alpha: float = 1.0
    beta: float = 1.0
    # Normalizers keep the two terms commensurate (paper: alpha/beta tuned
    # empirically; we normalize by running scales so alpha=beta=1 is sane).
    time_scale: float = 1.0
    fairness_scale: float = 1.0
    # Scheduling uses the per-round fairness INCREMENT var(s+v) - var(s):
    # identical argmin to the paper's absolute var(s+v) (the subtrahend is
    # constant w.r.t. the candidate), but scale-stationary over rounds — the
    # absolute variance grows ~linearly with r, which would drown the time
    # term and break GP stationarity for BODS / reward stationarity for RLDS.
    # Records still report the paper's absolute Formula-5 value.
    delta_fairness: bool = True
    # Batched-scoring backend: "numpy" | "jax" | "pallas" | "auto" (auto
    # picks numpy for small P*K, the jitted jax path at fleet scale).
    scoring_backend: str = "auto"
    # Fleet-axis shards for the scoring core and fused searchers (see
    # repro.core.shard): 1 = single lane; >1 partitions the K axis of
    # cost_batch/cost_indices and the parallel axes of SA/GA/BODS across
    # host platform devices. Plumbed from FleetSpec.num_shards.
    num_shards: int = 1

    # ---- Formula 5 ----

    def fairness(self, counts: np.ndarray, plan: Optional[np.ndarray] = None) -> float:
        """Variance of scheduling frequency if ``plan`` were applied on top of counts.

        ``counts``: (K,) cumulative times device k has been scheduled to the job.
        ``plan``:   optional (K,) bool/0-1 — the candidate round plan.
        """
        s = counts if plan is None else counts + plan
        return float(np.var(s))

    def fairness_batch(self, counts: np.ndarray, plans: np.ndarray) -> np.ndarray:
        """(P,) fairness for P candidate plans (P, K)."""
        return scoring.fairness_batch(counts, plans,
                                      delta_fairness=self.delta_fairness,
                                      backend=self.scoring_backend)

    # ---- Formula 3 ----

    def round_time(self, times: np.ndarray, plan: np.ndarray) -> float:
        """max over selected devices; empty plan -> 0."""
        sel = times[plan.astype(bool)]
        return float(sel.max()) if sel.size else 0.0

    def round_time_batch(self, times: np.ndarray, plans: np.ndarray) -> np.ndarray:
        return scoring.round_time_batch(times, plans,
                                        backend=self.scoring_backend)

    # ---- Formula 2 ----

    def cost(self, times: np.ndarray, counts: np.ndarray, plan: np.ndarray) -> float:
        t = self.round_time(times, plan) / self.time_scale
        f = self.fairness(counts, plan)
        if self.delta_fairness:
            f -= self.fairness(counts)
        return self.alpha * t + self.beta * f / self.fairness_scale

    def cost_batch(self, times: np.ndarray, counts: np.ndarray,
                   plans: np.ndarray, backend: Optional[str] = None) -> np.ndarray:
        """(P,) Formula-2 costs via the batched scoring core (one fused
        masked-max + variance reduction, never two passes)."""
        return scoring.score_plans(
            times, counts, plans, alpha=self.alpha, beta=self.beta,
            time_scale=self.time_scale, fairness_scale=self.fairness_scale,
            delta_fairness=self.delta_fairness,
            backend=backend if backend is not None else self.scoring_backend,
            num_shards=self.num_shards)

    def cost_indices(self, times: np.ndarray, counts: np.ndarray,
                     idx: np.ndarray, backend: Optional[str] = None) -> np.ndarray:
        """(P,) Formula-2 costs for plans in INDEX form ((P, n_sel) device
        ids) — the fleet fast path: P*n_sel gathered elements instead of a
        P*K dense sweep."""
        return scoring.score_plan_indices(
            times, counts, idx, alpha=self.alpha, beta=self.beta,
            time_scale=self.time_scale, fairness_scale=self.fairness_scale,
            delta_fairness=self.delta_fairness,
            backend=backend if backend is not None else self.scoring_backend,
            num_shards=self.num_shards)

    # ---- Formula 8 (TotalCost): current job's candidate + other jobs' fixed plans ----

    def total_cost_batch(
        self,
        job: int,
        tau: float,
        counts: np.ndarray,           # (K,) frequency counts of the current job
        plans: np.ndarray,            # (P, K) candidates for the current job
        other_costs: float = 0.0,     # sum of Cost_m' for jobs m' != m (constants)
        times: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        if times is None:
            times = self.pool.expected_times(job, tau)
        return self.cost_batch(times, counts, plans) + other_costs

    def calibrate(self, taus: Sequence[float], n_sel: int) -> None:
        """Set time/fairness normalizers from the pool so alpha,beta are unitless.

        time_scale ~ median expected round time over jobs; fairness_scale ~ the
        variance increment a single maximally-unfair round would add.
        """
        t = self.pool.expected_times_all(taus)                 # (M, K) fused
        ksel = min(n_sel, t.shape[1])
        fastest = np.partition(t, ksel - 1, axis=1)[:, :ksel]  # smallest per job
        self.time_scale = float(np.median(np.median(fastest, axis=1))) or 1.0
        # Fairness increment scale: adding one round moves var(s) by O(n_sel/K)
        # around its mean drift — normalize so a typical increment is O(1).
        k = self.pool.num_devices
        p = n_sel / k
        self.fairness_scale = max(p * (1 - p), 1e-6)
