"""Fleet-axis sharding: million-device plan scoring across host devices.

The scoring core (``repro.core.scoring``) and the fused searchers
(``repro.core.search``) are single-lane jit programs; they top out around
K = 1e5 devices because every reduction walks the whole fleet axis on one
device. This module shards the FLEET (K) axis across the host platform's
devices (``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — see
``repro.launch.bootstrap``) with ``shard_map``:

- **Scoring** (``plan_stats_sharded``) — each shard reduces its K/N block
  of the fleet to the per-plan sufficient statistics of Formula 2
  (masked-max round time, selected count, sum of fairness weights); the
  cross-shard combine is an O(N * P) max/sum over those partials, finished
  on the host in float64 by ``scoring._score_from_stats`` — the same
  combine the Pallas kernel path uses. Works on both plan forms: dense
  (P, K) membership and (P, n_sel) index rows (each shard owns the ids in
  ``[lo, lo + K/N)`` and masks the rest of the gather).
- **Plan repair / candidate generation** (``repair_plans_sharded``,
  ``random_plan_indices_sharded``, ``gumbel_topk_indices_sharded``) —
  shard-local priority top-k over the shard's block (noise drawn in-graph
  per shard), then a cross-shard top-k MERGE selects the global ``n_sel``:
  the global top-k of a row is always contained in the union of its
  per-shard top-k's.

Every sharded program has two executors with identical shard-local math:

- ``shard_map`` — the real thing, one program per mesh device (requires
  ``num_shards <= jax.device_count()``);
- ``emulate``  — the same blocked computation as a ``vmap`` over a
  reshaped leading shard axis on ONE device.

``executor="auto"`` picks ``shard_map`` when the process has enough
devices and falls back to emulation otherwise, so ``num_shards=8``
produces the same numbers on a laptop (serially) and on an
8-device host platform (in parallel). Tests exploit this: emulated
parity runs in-process anywhere; a subprocess test with forced host
devices pins shard_map-vs-emulated agreement.
"""

from __future__ import annotations

import functools
import logging
from typing import Optional, Tuple

import numpy as np

logger = logging.getLogger(__name__)

VALID_EXECUTORS = ("auto", "shard_map", "emulate")


def resolve_num_shards(num_shards, fleet_size: Optional[int] = None) -> int:
    """Normalize the ``num_shards`` knob to a concrete shard count.

    ``None``/``1`` -> 1 (single lane, no jax import); ``0`` or ``"auto"``
    -> ``jax.device_count()`` (the host-platform device pool the launch
    bootstrap sized). ``fleet_size`` caps the count so no shard is ever
    empty.
    """
    if num_shards is None:
        return 1
    if num_shards == "auto" or num_shards == 0:
        import jax

        n = int(jax.device_count())
    else:
        n = int(num_shards)
    if n < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards!r}")
    if fleet_size is not None:
        n = min(n, max(int(fleet_size), 1))
    return n


def shard_capacity() -> int:
    """Shard counts up to this run under the real ``shard_map`` executor."""
    import jax

    return int(jax.device_count())


def _resolve_executor(executor: str, num_shards: int) -> str:
    if executor not in VALID_EXECUTORS:
        raise ValueError(f"executor {executor!r} not in {VALID_EXECUTORS}")
    if executor != "auto":
        return executor
    try:
        return "shard_map" if num_shards <= shard_capacity() else "emulate"
    except Exception:  # pragma: no cover - no jax runtime
        return "emulate"


def shard_sizes(K: int, num_shards: int) -> Tuple[int, int]:
    """(per-shard block size Kb, padded fleet size Kb * num_shards)."""
    Kb = -(-int(K) // int(num_shards))
    return Kb, Kb * int(num_shards)


@functools.lru_cache(maxsize=None)
def fleet_mesh(num_shards: int):
    """The 1-axis ``("fleet",)`` mesh over the first ``num_shards`` devices
    (cached — mesh identity matters for jit cache hits)."""
    import jax
    from jax.sharding import Mesh

    devs = jax.devices()
    if num_shards > len(devs):
        raise ValueError(
            f"num_shards={num_shards} exceeds jax.device_count()="
            f"{len(devs)}; launch with repro.launch.bootstrap or use the "
            "emulate executor")
    return Mesh(np.asarray(devs[:num_shards]), ("fleet",))


# ---- shard-local sufficient statistics (Formula 2) ----------------------
#
# Shared bodies: the SAME function runs per mesh device under shard_map and
# per reshaped block under vmap emulation, so the two executors produce
# identical float32 partials. The combine (max/sum over the N partials)
# happens on the host in float64 either way.


def _partial_stats_dense(times_b, w_b, plans_b):
    """One shard's block: (Kb,) times, (Kb,) fairness weights, (P, Kb)
    membership -> (P, 3) [masked-max t, n selected, wsum]."""
    import jax.numpy as jnp

    sel = plans_b != 0
    t = jnp.max(jnp.where(sel, times_b[None, :], -jnp.inf), axis=1)
    n = jnp.sum(sel, axis=1).astype(jnp.float32)
    wsum = jnp.sum(jnp.where(sel, w_b[None, :], 0.0), axis=1)
    return jnp.stack([t, n, wsum], axis=1)


def _partial_stats_index(times_b, w_b, idx, lo):
    """Index-form twin: (P, n_sel) GLOBAL device ids against the shard's
    ``[lo, lo + Kb)`` block — out-of-block ids are masked, in-block ids
    gather through the clipped relative offset."""
    import jax.numpy as jnp

    Kb = times_b.shape[0]
    rel = idx - lo
    own = (rel >= 0) & (rel < Kb)
    relc = jnp.clip(rel, 0, Kb - 1)
    t = jnp.max(jnp.where(own, times_b[relc], -jnp.inf), axis=1)
    n = jnp.sum(own, axis=1).astype(jnp.float32)
    wsum = jnp.sum(jnp.where(own, w_b[relc], 0.0), axis=1)
    return jnp.stack([t, n, wsum], axis=1)


@functools.lru_cache(maxsize=None)
def _stats_fn(num_shards: int, form: str, executor: str):
    import jax
    import jax.numpy as jnp

    N = num_shards
    if executor == "shard_map":
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        mesh = fleet_mesh(N)
        if form == "dense":
            def body(times_b, w_b, plans_b):
                return _partial_stats_dense(times_b, w_b, plans_b)[None]

            fn = shard_map(body, mesh=mesh,
                           in_specs=(P("fleet"), P("fleet"), P(None, "fleet")),
                           out_specs=P("fleet", None, None))
        else:
            def body(times_b, w_b, idx):
                lo = jax.lax.axis_index("fleet") * times_b.shape[0]
                return _partial_stats_index(times_b, w_b, idx, lo)[None]

            fn = shard_map(body, mesh=mesh,
                           in_specs=(P("fleet"), P("fleet"), P(None, None)),
                           out_specs=P("fleet", None, None))
        return jax.jit(fn)

    if form == "dense":
        def run(times, w, plans):
            Kb = times.shape[0] // N
            tb = times.reshape(N, Kb)
            wb = w.reshape(N, Kb)
            pb = plans.reshape(plans.shape[0], N, Kb).transpose(1, 0, 2)
            return jax.vmap(_partial_stats_dense)(tb, wb, pb)
    else:
        def run(times, w, idx):
            Kb = times.shape[0] // N
            tb = times.reshape(N, Kb)
            wb = w.reshape(N, Kb)
            lo = (jnp.arange(N, dtype=idx.dtype) * Kb)
            return jax.vmap(_partial_stats_index,
                            in_axes=(0, 0, None, 0))(tb, wb, idx, lo)
    return jax.jit(run)


def plan_stats_sharded(times: np.ndarray, counts_c: np.ndarray, plans,
                       form: str, num_shards: int,
                       executor: str = "auto") -> np.ndarray:
    """Sharded Formula-2 sufficient statistics: (P, 3) [t_max, n, wsum].

    ``counts_c`` must be mean-centered (the scoring core's convention);
    ``plans`` is (P, K) membership when ``form == "dense"``, (P, n_sel)
    global device ids when ``form == "index"``. Feed the result to
    ``scoring._score_from_stats`` — exactly the Pallas kernel contract.
    """
    import jax.numpy as jnp

    N = int(num_shards)
    ex = _resolve_executor(executor, N)
    times = np.asarray(times)
    K = times.shape[0]
    Kb, Kpad = shard_sizes(K, N)
    t32 = np.asarray(times, np.float32)
    w32 = (2.0 * np.asarray(counts_c, np.float64) + 1.0).astype(np.float32)
    if Kpad != K:
        t32 = np.pad(t32, (0, Kpad - K))
        w32 = np.pad(w32, (0, Kpad - K))
    if form == "dense":
        p = np.asarray(plans)
        p8 = p if p.dtype == np.int8 else p.astype(np.int8)
        if Kpad != K:  # padded devices are never selected
            p8 = np.pad(p8, ((0, 0), (0, Kpad - K)))
        parts = _stats_fn(N, "dense", ex)(
            jnp.asarray(t32), jnp.asarray(w32), jnp.asarray(p8))
    elif form == "index":
        idx = np.asarray(plans)
        i32 = idx if idx.dtype == np.int32 else idx.astype(np.int32)
        parts = _stats_fn(N, "index", ex)(
            jnp.asarray(t32), jnp.asarray(w32), jnp.asarray(i32))
    else:
        raise ValueError(f"form {form!r} not in ('dense', 'index')")
    parts = np.asarray(parts, np.float64)          # (N, P, 3)
    return np.stack([parts[:, :, 0].max(axis=0),   # round time: max of maxes
                     parts[:, :, 1].sum(axis=0),   # n selected: sum
                     parts[:, :, 2].sum(axis=0)],  # wsum: sum
                    axis=1)


# ---- shard-local top-k with cross-shard merge ---------------------------
#
# The repair / candidate-generation primitives are all one shape: build a
# (P, K) priority-key matrix (valid selections outrank noise outranks
# occupied), take each row's top n_sel. Sharded, each shard draws ITS
# block's noise in-graph (key folded with the shard id), takes a local
# top-k, and the merge takes the top n_sel of the N stacked local winners
# — correct because a row's global top-k is contained in the union of its
# per-shard top-k's. Note the noise REALIZATION depends on the shard
# count (each block has its own fold_in stream): results are valid draws
# from the same distribution at any N, but not bit-identical across N.

_MODES = ("repair", "random", "gumbel")


@functools.lru_cache(maxsize=None)
def _noisy_topk_fn(num_shards: int, n_sel: int, executor: str, mode: str,
                   rows: int = 0):
    """``mode="random"`` takes no (P, K) operand at all: the key matrix is
    drawn in-graph per shard at the static ``rows`` count, so a
    million-device candidate draw never materializes a (P, K) host array
    (the single-lane ``plans.random_plan_indices`` allocates the full
    matrix). ``repair``/``gumbel`` carry one (P, K) operand (membership /
    logits) split across shards."""
    import jax
    import jax.numpy as jnp

    N = num_shards

    def local_keys(seed, sid, avail_b, mat_b):
        k = jax.random.fold_in(jax.random.key(seed, impl="rbg"), sid)
        if mode == "repair":
            keys = ((mat_b & avail_b[None, :])
                    + jax.random.uniform(k, mat_b.shape))
        elif mode == "random":
            keys = jax.random.uniform(k, (rows, avail_b.shape[0]))
        else:  # gumbel
            keys = mat_b + jax.random.gumbel(k, mat_b.shape)
        return jnp.where(avail_b[None, :], keys, -jnp.inf)

    def local_topk(keys_b, lo):
        Kb = keys_b.shape[1]
        m = min(n_sel, Kb)
        v, i = jax.lax.top_k(keys_b, m)
        gi = (i + lo).astype(jnp.int32)
        if m < n_sel:
            v = jnp.pad(v, ((0, 0), (0, n_sel - m)),
                        constant_values=-np.inf)
            gi = jnp.pad(gi, ((0, 0), (0, n_sel - m)))
        return v, gi

    def body(seed, sid, lo, avail_b, mat_b):
        keys = local_keys(seed, sid, avail_b, mat_b)
        v, gi = local_topk(keys, lo)
        return v, gi

    if executor == "shard_map":
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        mesh = fleet_mesh(N)

        def sm_body(seed, avail_b, mat_b):
            sid = jax.lax.axis_index("fleet")
            lo = sid * avail_b.shape[0]
            v, gi = body(seed, sid, lo, avail_b, mat_b)
            return v[None], gi[None]

        mat_spec = P() if mode == "random" else P(None, "fleet")
        inner = shard_map(
            sm_body, mesh=mesh,
            in_specs=(P(), P("fleet"), mat_spec),
            out_specs=(P("fleet", None, None), P("fleet", None, None)))
    else:
        def inner(seed, avail, mat):
            Kb = avail.shape[0] // N
            ab = avail.reshape(N, Kb)
            sids = jnp.arange(N, dtype=jnp.int32)
            los = sids * Kb
            if mode == "random":
                mb, mat_ax = mat, None
            else:
                mb = mat.reshape(mat.shape[0], N, Kb).transpose(1, 0, 2)
                mat_ax = 0
            return jax.vmap(body, in_axes=(None, 0, 0, 0, mat_ax))(
                seed, sids, los, ab, mb)

    def run(seed, avail, mat):
        v, gi = inner(seed, avail, mat)                # (N, P, n_sel) x2
        P_ = v.shape[1]
        vm = v.transpose(1, 0, 2).reshape(P_, N * n_sel)
        gm = gi.transpose(1, 0, 2).reshape(P_, N * n_sel)
        _, pick = jax.lax.top_k(vm, n_sel)
        return jnp.take_along_axis(gm, pick, axis=1)

    return jax.jit(run)


def _topk_call(mode: str, seed: int, avail: np.ndarray, n_sel: int,
               num_shards: int, executor: str, mat=None,
               rows: Optional[int] = None) -> np.ndarray:
    import jax.numpy as jnp

    N = int(num_shards)
    ex = _resolve_executor(executor, N)
    avail = np.asarray(avail, dtype=bool)
    K = avail.shape[0]
    if int(avail.sum()) < n_sel:
        raise ValueError(
            f"need {n_sel} available devices, have {int(avail.sum())}")
    Kb, Kpad = shard_sizes(K, N)
    a = np.pad(avail, (0, Kpad - K)) if Kpad != K else avail
    seed32 = jnp.uint32(seed & 0xFFFFFFFF)
    if mode == "random":
        fn = _noisy_topk_fn(N, int(n_sel), ex, mode, rows=int(rows))
        out = fn(seed32, jnp.asarray(a), None)
    else:
        mat = np.asarray(mat, dtype=bool if mode == "repair" else np.float32)
        if Kpad != K:
            mat = np.pad(mat, ((0, 0), (0, Kpad - K)))
        fn = _noisy_topk_fn(N, int(n_sel), ex, mode)
        out = fn(seed32, jnp.asarray(a), jnp.asarray(mat))
    return np.asarray(out)


def repair_plans_sharded(rng: np.random.Generator, plans: np.ndarray,
                         available: np.ndarray, n_sel: int, num_shards: int,
                         executor: str = "auto") -> np.ndarray:
    """Fleet-sharded twin of ``plans.repair_plans``: (P, K) candidates ->
    (P, n_sel) repaired GLOBAL indices via shard-local priority top-k +
    cross-shard merge. Valid selections (selected & available) always
    outrank noise, so already-valid plans pass through unchanged (as a
    set); occupied devices are dropped, random available devices top up."""
    seed = int(rng.integers(0, 2**31 - 1))
    return _topk_call("repair", seed, available, int(n_sel), num_shards,
                      executor, mat=np.atleast_2d(plans))


def random_plan_indices_sharded(rng: np.random.Generator,
                                available: np.ndarray, n_sel: int,
                                count: int, num_shards: int,
                                executor: str = "auto") -> np.ndarray:
    """Fleet-sharded twin of ``plans.random_plan_indices``: uniform
    n_sel-subsets of the available set, (count, n_sel) global ids, with the
    (count, K) key draw split across shards (never materialized on the
    host — the single-lane host version allocates the full matrix)."""
    if count == 0 or n_sel == 0:
        return np.zeros((count, n_sel), dtype=np.int32)
    seed = int(rng.integers(0, 2**31 - 1))
    return _topk_call("random", seed, available, int(n_sel), num_shards,
                      executor, rows=int(count))


def gumbel_topk_indices_sharded(rng: np.random.Generator,
                                logits: np.ndarray, available: np.ndarray,
                                n_sel: int, num_shards: int,
                                executor: str = "auto") -> np.ndarray:
    """Fleet-sharded twin of ``plans.gumbel_topk_plans`` returning INDEX
    form: per-row Plackett-Luce draws over the available set, each shard
    drawing its own block's Gumbel noise in-graph."""
    seed = int(rng.integers(0, 2**31 - 1))
    return _topk_call("gumbel", seed, available, int(n_sel), num_shards,
                      executor, mat=np.atleast_2d(logits))
