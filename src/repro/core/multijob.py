"""Event-driven multi-job FL engine (the paper's Fig. 1 process).

M jobs run in PARALLEL and asynchronously share the K-device pool: at any
simulated instant a device belongs to at most one job. Each job round:

  (1)-(2) the scheduler picks V_m^r from the currently-available devices,
  (3)-(5) the scheduled devices run local training (their realized times are
          sampled from the shifted-exponential model; the slowest defines the
          round time, Formula 3),
  (6)     the server aggregates (FedAvg) — executed by the pluggable
          ``JobRuntime`` which performs REAL training on partitioned data,
          exactly like the paper's GPU-simulated testbed (times simulated,
          accuracy real).

The engine keeps a completion-time heap; when a round finishes, the realized
cost feeds back to the scheduler (BODS observation point / RLDS reward) and
the next round of that job is scheduled at the release instant. Devices are
released individually when THEIR local work ends (a fast device that
finished uploading can immediately join another job).

Fault tolerance: the ``faults`` axis (``repro.faults.FaultSpec``) injects a
replayable per-round fault schedule — transient dropouts with escalating
quarantine (exponential backoff, reset on success), permanent crashes,
straggler slowdown multipliers, correlated fault-domain outages, and
corrupted uploads. Dropped devices are excluded from aggregation (FedAvg
over survivors) and the engine proceeds, which is exactly how a production
FL server must behave. ``round_deadline`` adds FedCS-style partial
aggregation: survivors slower than the deadline are cut from the cohort.
The legacy ``failure_rate``/``failure_cooldown`` kwargs remain as a
deprecated alias (uniform dropouts, fixed cooldown). Straggler mitigation:
optional ``over_provision`` factor schedules extra devices and the round
completes when n_sel have finished (deadline on the straggler tail).
"""

from __future__ import annotations

import dataclasses
import heapq
import warnings
from typing import Callable, Dict, List, Optional, Protocol, Sequence

import numpy as np

from repro.config.base import JobConfig
from repro.core.cost import CostModel
from repro.core.devices import DevicePool
from repro.core.schedulers.base import SchedulerBase, SchedulingContext
from repro.faults import FaultEngine, FaultSpec
from repro.monitoring.trace import span

_EMPTY_IDS = np.array([], dtype=int)


class JobRuntime(Protocol):
    """Executes the real training for one round of one job.

    The engine resolves the ROUND'S REALIZED participation at launch time
    (over-provisioned stragglers cut, failed devices dropped) and hands the
    runtime the surviving cohort twice: once through the optional
    ``begin_round`` hook at launch (so batching runtimes can overlap/fuse
    training of concurrently in-flight jobs), and once through ``run_round``
    at the simulated finish instant, which must return the metrics."""

    def run_round(self, job_id: int, device_ids: np.ndarray, round_idx: int
                  ) -> Dict[str, float]:
        """Train the scheduled devices locally + aggregate. ``device_ids`` is
        the realized survivor cohort (the engine's weight mask: exactly these
        devices aggregate). Returns metrics with at least
        {'loss': float, 'accuracy': float}."""

    # Optional: ``begin_round(job_id, device_ids, round_idx)`` — same
    # realized cohort, announced when the round LAUNCHES. Runtimes that
    # batch cross-job execution (``repro.fl.runtime.FusedMultiRuntime``)
    # queue work here and flush every pending job in one dispatch at the
    # first ``run_round`` demand.


@dataclasses.dataclass
class RoundRecord:
    job: int
    round_idx: int
    t_start: float
    t_end: float
    round_time: float
    cost: float
    fairness: float
    loss: float
    accuracy: float
    device_ids: np.ndarray
    dropped: np.ndarray
    # Scheduler's estimated Formula-2 cost of the plan at schedule time (None
    # for schedulers that don't estimate); cost - est_cost is the realized
    # residual the learned schedulers (BODS GP, DNN) model.
    est_cost: Optional[float] = None
    # Degraded round: every scheduled device failed (or missed the deadline)
    # and the engine fell back to aggregating the single fastest reporter.
    degraded: bool = False
    # Devices whose uploads were drawn corrupted this round (rejected by a
    # robust runtime, or oracle-discarded by the engine otherwise).
    corrupt_ids: np.ndarray = dataclasses.field(
        default_factory=lambda: np.array([], dtype=int))
    # Fault-failed devices this round (subset of ``dropped``; the breaker
    # board keys tenant/domain health on these).
    failed_ids: np.ndarray = dataclasses.field(
        default_factory=lambda: np.array([], dtype=int))
    # SLO axis: which degradation-ladder rung produced the plan (None when
    # no governor is attached) and the measured decision latency in ms
    # (recorded ONLY when a wall-clock deadline is active — it is not
    # replayable, so the deterministic modes keep records bit-identical).
    rung: Optional[str] = None
    decision_ms: Optional[float] = None


@dataclasses.dataclass
class JobState:
    config: JobConfig
    round_idx: int = 0
    done: bool = False
    reached_target_at: Optional[float] = None
    total_round_time: float = 0.0  # Σ_r T_m^r (Formula 6 numerator)
    # Online-service lifecycle (dynamic job sets): when the job was admitted
    # to the engine, and whether/when it was retired EARLY (tenant departure
    # — distinct from finishing by target/max_rounds).
    admitted_at: float = 0.0
    retired: bool = False
    retired_at: Optional[float] = None
    # Set once the job enters the event loop (in flight or retry pending);
    # run() skips launched jobs so mixing manual launches / dynamic
    # admission with a later run() never double-books a job's events.
    launched: bool = False
    # Catalogue rows: the scheduler service builds the engine from a spec
    # whose jobs are tenant TEMPLATES, never run directly; parked jobs are
    # skipped by run()/summary().
    parked: bool = False


class MultiJobEngine:
    def __init__(
        self,
        jobs: Sequence[JobConfig],
        pool: DevicePool,
        cost_model: CostModel,
        scheduler: SchedulerBase,
        runtime: JobRuntime,
        n_sel: Optional[int] = None,
        failure_rate: float = 0.0,
        failure_cooldown: float = 60.0,
        over_provision: float = 1.0,
        release_horizon: float = 0.0,
        rng: Optional[np.random.Generator] = None,
        faults: Optional[FaultSpec] = None,
    ):
        """``release_horizon``: the paper's appendix notes BODS/RLDS "consider
        the probability to release the devices in V_o". With horizon h > 0, a
        device freeing within h*time_scale is schedulable NOW; its remaining
        busy time is added to its expected/realized round time (so a nearly-
        free fast device can beat a free slow one). h = 0 is paper-faithful
        strict availability.

        ``faults``: the fault model (``repro.faults.FaultSpec``, or a live
        ``FaultEngine``). The legacy ``failure_rate``/``failure_cooldown``
        kwargs are a deprecated alias: when ``faults`` is None and
        ``failure_rate > 0`` they map onto a uniform-dropout FaultSpec with
        a fixed cooldown (``FaultSpec.from_legacy``)."""
        self.jobs = [JobState(config=j) for j in jobs]
        self.pool = pool
        self.cost_model = cost_model
        self.scheduler = scheduler
        self.runtime = runtime
        self.n_sel = n_sel or max(1, int(round(0.1 * pool.num_devices)))
        self.failure_rate = failure_rate
        self.failure_cooldown = failure_cooldown
        if faults is None and failure_rate > 0.0:
            faults = FaultSpec.from_legacy(failure_rate, failure_cooldown)
        if isinstance(faults, FaultSpec):
            faults = (None if faults.inert
                      else FaultEngine(faults, pool.num_devices))
        self.fault_engine: Optional[FaultEngine] = faults
        self.over_provision = over_provision
        # Validate up front: an over-provisioned selection larger than the
        # pool can NEVER be satisfied — the engine would re-enqueue "retry"
        # events forever. Clamp (with a warning) instead of livelocking.
        K = pool.num_devices
        requested = int(round(self.n_sel * self.over_provision))
        if requested > K:
            self.n_sel = min(self.n_sel, K)
            self.over_provision = K / self.n_sel
            warnings.warn(
                f"n_sel*over_provision = {requested} exceeds the pool size "
                f"{K}; clamped to n_sel={self.n_sel}, "
                f"over_provision={self.over_provision:.3f}", RuntimeWarning)
        self.release_horizon = release_horizon
        self.rng = rng or np.random.default_rng(12345)
        self.counts = np.zeros((len(jobs), pool.num_devices))  # S_m (Formula 16)
        self.records: List[RoundRecord] = []
        self.clock = 0.0  # latest processed simulated instant
        # Optional hook for online drivers (the scheduler service): called as
        # ``on_job_done(job, now)`` when a job completes (target reached,
        # max_rounds, or abandoned) — the admission-slot release signal.
        self.on_job_done: Optional[Callable[[int, float], None]] = None
        # Observability (the spec's ``obs`` axis): ``events`` is an optional
        # ``repro.monitoring.bus.EventBus`` the engine publishes
        # ``round_begin`` / ``round`` / ``job_done`` to; ``obs`` is the
        # owning ``ObsSession`` (closed by the run driver). Both None by
        # default — the untraced path is unchanged.
        self.events = None
        self.obs = None
        # SLO resilience (``repro.serve.resilience.attach_resilience``):
        # ``governor`` routes scheduling decisions through the degradation
        # ladder; the retry knobs bound the historical retry-forever /
        # fail-fast paths. Defaults keep legacy behavior bit-identically.
        self.governor = None
        self.max_launch_retries: Optional[int] = None
        self.retry_backoff = 2.0
        self.retry_base_delay = 1.0
        self.max_agg_retries = 0
        self._retry_counts: Dict[int, int] = {}
        self._heap: list = []
        self._seq = 0
        self._in_flight: Dict[int, dict] = {}
        self._clamp_warned: set = set()
        # Preallocated per-round scratch (fleet pools: no 100k-sized fresh
        # allocations inside the hot scheduling loop).
        self._times_buf = np.empty(K, dtype=np.float64)
        self._wait_buf = np.empty(K, dtype=np.float64)
        self._busy_buf = np.empty(K, dtype=np.float64)
        self._mask_buf = np.empty(K, dtype=bool)

    # ---- context assembly (Formula 8: other jobs' in-flight costs are context) ----

    def _other_costs(self, job: int) -> float:
        return float(sum(f["cost"] for m, f in self._in_flight.items() if m != job))

    def _wait_times(self, now: float) -> np.ndarray:
        return np.maximum(self.pool.busy_until - now, 0.0)

    def _make_ctx(self, job: int, now: float) -> SchedulingContext:
        js = self.jobs[job]
        wait = self._wait_times(now)
        horizon = self.release_horizon * self.cost_model.time_scale
        return SchedulingContext(
            job=job,
            round_idx=js.round_idx,
            tau=js.config.local_epochs,
            n_sel=int(round(self.n_sel * self.over_provision)),
            available=wait <= horizon + 1e-12,
            counts=self.counts[job].copy(),
            # Queueing-aware expected time: remaining busy time is part of the
            # cost of picking a soon-to-free device.
            expected_times=(self.pool.expected_times(job, js.config.local_epochs)
                            + wait),
            other_costs=self._other_costs(job),
        )

    # ---- schedule one round of one job at simulated time ``now`` ----

    def _launch(self, job: int, now: float) -> None:
        js = self.jobs[job]
        if js.done:
            # Retired (or parked) while a retry event was pending: the
            # stale event must not resurrect the job.
            return
        js.launched = True
        with span("ctx_build", job=job, round=js.round_idx):
            ctx = self._make_ctx(job, now)
            # Populate the context's per-round available-id cache here: the
            # availability-independent derived arrays (float32 time mirror,
            # available-id list) are computed at most once per _make_ctx and
            # reused by greedy/FedCS and the fused searchers instead of being
            # recomputed per candidate batch.
            avail = int(ctx.available_indices().size)
        if avail < ctx.n_sel:
            # Distinguish a transient shortage (devices will free soon) from
            # a PERMANENT one (devices failed forever / selection larger than
            # the reachable pool) — re-enqueueing a retry for the latter
            # would livelock the event loop.
            reachable = int(np.count_nonzero(np.isfinite(self.pool.busy_until)))
            if reachable == 0:
                warnings.warn(f"job {job}: no device can ever become "
                              "available again; abandoning remaining rounds",
                              RuntimeWarning)
                js.done = True
                return
            if reachable < ctx.n_sel:
                if job not in self._clamp_warned:
                    self._clamp_warned.add(job)
                    warnings.warn(
                        f"job {job}: selection {ctx.n_sel} permanently "
                        f"exceeds the {reachable} reachable device(s); "
                        "clamping", RuntimeWarning)
                ctx.n_sel = reachable
            if avail < ctx.n_sel:
                tries = self._retry_counts.get(job, 0)
                if (self.max_launch_retries is not None
                        and tries >= self.max_launch_retries and avail >= 1):
                    # Retry budget exhausted with SOME devices reachable:
                    # launch a clamped cohort now instead of waiting for a
                    # full one (bounded-retry SLO semantics).
                    ctx.n_sel = avail
                else:
                    # Transient: wait for the next FINITE release event —
                    # with a bounded budget, exponential simulated-time
                    # backoff widens each successive wait.
                    b = self.pool.busy_until
                    pending = b[(b > now) & np.isfinite(b)]
                    nxt = float(pending.min()) if pending.size else now + 1.0
                    if self.max_launch_retries is not None:
                        self._retry_counts[job] = tries + 1
                        nxt = max(nxt, now + self.retry_base_delay
                                  * self.retry_backoff ** tries)
                    heapq.heappush(self._heap, (nxt, self._seq, "retry", job))
                    self._seq += 1
                    return
        self._retry_counts.pop(job, None)
        with span("schedule", job=job, round=js.round_idx):
            if self.governor is not None:
                plan, rung, decision_ms, gov_est = self.governor.decide(
                    self.scheduler, ctx, now)
            else:
                plan = self.scheduler.schedule(ctx)
                rung = decision_ms = None
                gov_est = getattr(self.scheduler, "last_estimated_cost", None)
        dispatch_span = span("dispatch", job=job, round=js.round_idx)
        dispatch_span.__enter__()
        fe = self.fault_engine
        # Realized time includes any remaining busy time (release_horizon > 0).
        # Preallocated buffers: valid until this launch returns (nothing
        # below stores a view of them).
        times = self.pool.sample_times_into(
            job, js.config.local_epochs, self._times_buf)
        if fe is not None:
            # Straggler slowdown multiplies COMPUTE time, not queueing wait.
            slow = fe.straggler_multipliers(job, js.round_idx)
            if slow is not None:
                times *= slow
        np.subtract(self.pool.busy_until, now, out=self._wait_buf)
        np.maximum(self._wait_buf, 0.0, out=self._wait_buf)
        times += self._wait_buf
        sel_ids = np.flatnonzero(plan)

        # Straggler mitigation: with over-provisioning the round ends when the
        # n_sel fastest of the scheduled set are done; the tail is dropped.
        sel_times = times[sel_ids]
        if len(sel_ids) > self.n_sel:
            keep = sel_ids[np.argsort(sel_times)[: self.n_sel]]
            dropped_straggler = np.setdiff1d(sel_ids, keep)
        else:
            keep, dropped_straggler = sel_ids, _EMPTY_IDS

        # Fault injection: replayable keyed draws (transient dropouts,
        # permanent crashes, correlated domain outages).
        degraded = False
        if fe is not None:
            transient_m, crash_m, domain_m = fe.failure_masks(job, js.round_idx)
            fail_mask = (transient_m | crash_m | domain_m)[keep]
        else:
            fail_mask = np.zeros(len(keep), dtype=bool)
        failed = keep[fail_mask]
        survivors = keep[~fail_mask]
        if survivors.size == 0 and keep.size:
            # Pathological: everyone failed. Keep the FASTEST reporter (its
            # partial upload is the best single-device aggregate available)
            # and mark the round degraded so summary() can surface it.
            fastest = keep[np.argmin(times[keep])]
            survivors = np.array([fastest])
            failed = keep[keep != fastest]
            degraded = True

        # FedCS-style deadline: partial aggregation over on-time survivors.
        # Late survivors still finish their local work (their devices stay
        # busy until their own end time) but are cut from the cohort; they
        # are NOT failures, so no quarantine strikes.
        deadline_dropped = _EMPTY_IDS
        if fe is not None and fe.spec.round_deadline is not None:
            on_time = survivors[times[survivors] <= fe.spec.round_deadline]
            if on_time.size == 0:
                on_time = survivors[[np.argmin(times[survivors])]]
                degraded = True
            deadline_dropped = np.setdiff1d(survivors, on_time)
            survivors = on_time

        round_time = float(times[survivors].max())
        t_end = now + round_time
        # Devices are busy until THEIR OWN finish time (then free for other jobs).
        per_dev_busy = self._busy_buf  # only masked entries are read by occupy
        per_dev_busy[sel_ids] = now + times[sel_ids]
        if fe is not None:
            # Transient failures escalate (exponential-backoff quarantine,
            # reset on success); domain outages park for the outage duration;
            # crashes are permanent.
            transient_ids = failed[transient_m[failed]]
            domain_ids = failed[domain_m[failed] & ~crash_m[failed]]
            crash_ids = failed[crash_m[failed]]
            per_dev_busy[transient_ids] = (
                t_end + fe.quarantine_durations(transient_ids))
            per_dev_busy[domain_ids] = t_end + fe.spec.domain_outage_duration
            per_dev_busy[crash_ids] = np.inf
            fe.record_success(survivors)
        elif failed.size:
            per_dev_busy[failed] = t_end + self.failure_cooldown
        busy_mask = self._mask_buf
        busy_mask[:] = False
        busy_mask[sel_ids] = True
        self.pool.occupy(busy_mask, per_dev_busy)

        # Corrupted uploads: a robust runtime injects + rejects them inside
        # its own aggregation (``handles_corruption``); otherwise the engine
        # oracle-discards them from the aggregation cohort. Either way they
        # are excluded from the fairness counts (their update never landed).
        corrupt_ids = (fe.corrupt_mask(job, js.round_idx, survivors)
                       if fe is not None else None)
        if corrupt_ids is not None and corrupt_ids.any():
            corrupt_ids = survivors[corrupt_ids]
            counted = np.setdiff1d(survivors, corrupt_ids)
            if not getattr(self.runtime, "handles_corruption", False):
                if counted.size == 0:
                    # Every on-time update is corrupt and nothing can screen
                    # them: aggregate the fastest anyway (degraded round).
                    counted = survivors[[np.argmin(times[survivors])]]
                    degraded = True
                survivors = counted
        else:
            corrupt_ids = _EMPTY_IDS
            counted = survivors

        cm = self.cost_model
        fairness = cm.fairness(self.counts[job], plan)  # paper Formula 5 (absolute, recorded)
        dfair = fairness - cm.fairness(self.counts[job]) if cm.delta_fairness else fairness
        # Realized cost (scheduler feedback): realized straggler time + fairness.
        cost = float(cm.alpha * round_time / cm.time_scale
                     + cm.beta * dfair / cm.fairness_scale)

        # Announce the realized cohort to batching runtimes at LAUNCH time:
        # training is a pure function of (params, survivors), so a fused
        # runtime can execute it any time before the finish event and batch
        # every concurrently in-flight job into one dispatch.
        begin = getattr(self.runtime, "begin_round", None)
        if begin is not None:
            begin(job, survivors, js.round_idx)

        self._in_flight[job] = dict(
            plan=plan, survivors=survivors, counted=counted, failed=failed,
            dropped=np.concatenate(
                [dropped_straggler, failed, deadline_dropped]),
            corrupt=corrupt_ids, degraded=degraded,
            t_start=now, cost=cost, fairness=fairness, round_time=round_time,
            est_cost=gov_est, rung=rung, decision_ms=decision_ms,
            ctx=ctx,
        )
        heapq.heappush(self._heap, (float(t_end), self._seq, "finish", job))
        self._seq += 1
        # Close the dispatch span opened after the scheduling decision (the
        # span is bookkeeping only: an exception above just drops the event).
        dispatch_span.__exit__()
        if self.events is not None:
            self.events.publish("round_begin", dict(
                job=job, round_idx=js.round_idx, t_start=now,
                n_scheduled=int(sel_ids.size), n_survivors=int(survivors.size),
                est_cost=self._in_flight[job]["est_cost"]))

    # ---- round completion ----

    def _finish(self, job: int, now: float) -> bool:
        js = self.jobs[job]
        f = self._in_flight.pop(job)
        with span("aggregate", job=job, round=js.round_idx):
            # Bounded aggregation retries (SLO axis): 0 keeps the historical
            # fail-fast raise; N retries the dispatch, then records a
            # degraded round carrying the job's previous metrics forward.
            tries = 0
            while True:
                try:
                    metrics = self.runtime.run_round(
                        job, f["survivors"], js.round_idx)
                    break
                except Exception as e:
                    if self.max_agg_retries <= 0:
                        raise
                    if tries >= self.max_agg_retries:
                        prev = next((r for r in reversed(self.records)
                                     if r.job == job), None)
                        metrics = {
                            "loss": prev.loss if prev is not None else 0.0,
                            "accuracy": (prev.accuracy
                                         if prev is not None else 0.0)}
                        f["degraded"] = True
                        warnings.warn(
                            f"job {job} round {js.round_idx}: aggregation "
                            f"failed after {tries} retries ({e!r}); "
                            "recording a degraded round", RuntimeWarning)
                        if self.events is not None:
                            self.events.publish("serve.agg_failed", dict(
                                job=job, round_idx=js.round_idx, t=now,
                                retries=tries, error=repr(e)))
                        break
                    tries += 1
        with span("record", job=job, round=js.round_idx):
            self.counts[job][f["counted"]] += 1.0  # Formula 16

            self.records.append(RoundRecord(
                job=job, round_idx=js.round_idx, t_start=f["t_start"],
                t_end=now, round_time=f["round_time"], cost=f["cost"],
                fairness=f["fairness"],
                loss=metrics["loss"], accuracy=metrics["accuracy"],
                device_ids=f["survivors"], dropped=f["dropped"],
                est_cost=f["est_cost"], degraded=f["degraded"],
                corrupt_ids=f["corrupt"], failed_ids=f["failed"],
                rung=f.get("rung"), decision_ms=f.get("decision_ms")))

            self.scheduler.observe(f["ctx"], f["plan"], f["cost"])
            js.total_round_time += f["round_time"]
            js.round_idx += 1

            reached = metrics["accuracy"] >= js.config.target_metric
            if reached and js.reached_target_at is None:
                js.reached_target_at = now
            if reached or js.round_idx >= js.config.max_rounds:
                js.done = True
            # Sink fan-out counts as recording: the metrics/audit JSONL
            # writes happen inside the subscribed sinks.
            if self.events is not None:
                self.events.publish("round", self.records[-1])
        return js.done

    # ---- dynamic job set (online multi-tenant service) ----

    def add_job(self, config: JobConfig,
                data_sizes: Optional[np.ndarray] = None,
                now: Optional[float] = None,
                launch: bool = True,
                runtime_kwargs: Optional[dict] = None) -> int:
        """Admit a NEW job mid-run: grow the pool's data-size columns, the
        fairness-count matrix, the scheduler's per-job state, and the
        runtime's per-job rows, then (if ``now`` is given and ``launch``)
        launch its first round at that simulated instant. ``launch=False``
        defers the first round so the caller can load warm scheduler state
        (a readmitted tenant) before any decision is made.

        ``data_sizes``: the tenant's (K,) per-device data profile; None
        draws a fresh column from the pool's existing range. The runtime
        must expose ``add_job(job_id, config, **runtime_kwargs)`` —
        ``SyntheticRuntime`` does; training runtimes with preallocated
        device-resident datasets do not (yet) support dynamic admission.
        """
        job_id = len(self.jobs)
        config = dataclasses.replace(config, job_id=job_id)
        if self.pool.num_jobs <= job_id:
            self.pool.add_job(data_sizes)
        elif data_sizes is not None:
            self.pool.set_job_data(job_id, data_sizes)
        self.counts = np.concatenate(
            [self.counts, np.zeros((1, self.pool.num_devices))])
        self.jobs.append(JobState(
            config=config,
            admitted_at=float(now) if now is not None else self.clock))
        self.scheduler.ensure_jobs(len(self.jobs))
        add = getattr(self.runtime, "add_job", None)
        if add is None:
            raise TypeError(
                f"runtime {type(self.runtime).__name__} does not support "
                "dynamic job admission (no add_job hook)")
        add(job_id, config, **(runtime_kwargs or {}))
        if now is not None and launch:
            self._launch(job_id, float(now))
        return job_id

    def launch_job(self, job: int, now: float) -> None:
        """Launch the first round of a job admitted with ``launch=False``."""
        self._launch(job, float(now))

    def retire_job(self, job: int, now: Optional[float] = None) -> bool:
        """Retire a job EARLY (tenant departure). An in-flight round runs to
        its finish event (its devices are already committed and its metrics
        still count); nothing is launched afterwards — pending retry events
        die against the ``done`` guard. Returns False if the job had already
        finished."""
        js = self.jobs[job]
        if js.done:
            return False
        js.done = True
        js.retired = True
        js.retired_at = float(now) if now is not None else self.clock
        return True

    # ---- main loop ----

    def advance_until(self, until: float, verbose: bool = False,
                      on_round: Optional[Callable[[RoundRecord], None]] = None
                      ) -> int:
        """Process every queued engine event with timestamp <= ``until``
        (the bounded event loop online drivers interleave with external
        traffic events); returns the number of completed rounds."""
        finished = 0
        while self._heap and self._heap[0][0] <= until:
            now, _, kind, job = heapq.heappop(self._heap)
            self.clock = max(self.clock, now)
            if kind == "retry":
                self._launch(job, now)
                continue
            done = self._finish(job, now)
            finished += 1
            if on_round is not None:
                on_round(self.records[-1])
            if verbose:
                r = self.records[-1]
                print(f"[t={now:9.1f}s] job{job} r{r.round_idx} "
                      f"acc={r.accuracy:.4f} loss={r.loss:.4f} T={r.round_time:.1f}s")
            if not done:
                self._launch(job, now)
            else:
                if self.events is not None:
                    self.events.publish("job_done", dict(
                        job=job, t=now, rounds=self.jobs[job].round_idx,
                        retired=self.jobs[job].retired))
                if self.on_job_done is not None:
                    self.on_job_done(job, now)
        return finished

    def run(self, verbose: bool = False,
            on_round: Optional[Callable[[RoundRecord], None]] = None) -> List[RoundRecord]:
        with span("engine_run", jobs=len(self.jobs)):
            for m in range(len(self.jobs)):
                if not self.jobs[m].done and not self.jobs[m].launched:
                    self._launch(m, 0.0)
            self.advance_until(np.inf, verbose=verbose, on_round=on_round)
        return self.records

    # ---- summary (paper Tables 1/2/5 quantities) ----

    def summary(self) -> Dict[str, dict]:
        out = {}
        for m, js in enumerate(self.jobs):
            if js.parked:
                continue  # tenant templates, never executed
            recs = [r for r in self.records if r.job == m]
            key = js.config.model.name
            if key in out:
                key = f"{key}#{m}"
            # All fields must be well-defined for jobs with ZERO completed
            # rounds (abandoned before first finish, or clamped away) — and
            # lifetimes are UNEQUAL under dynamic admission, so every
            # per-job quantity derives from that job's own records only.
            out[key] = dict(
                rounds=js.round_idx,
                final_accuracy=recs[-1].accuracy if recs else 0.0,
                best_accuracy=max((r.accuracy for r in recs), default=0.0),
                time_to_target=js.reached_target_at,
                total_round_time=js.total_round_time,
                mean_round_time=(js.total_round_time / js.round_idx
                                 if js.round_idx else 0.0),
                makespan=recs[-1].t_end if recs else 0.0,
                admitted_at=js.admitted_at,
                retired=js.retired,
                degraded_rounds=sum(1 for r in recs if r.degraded),
                corrupt_updates=sum(len(r.corrupt_ids) for r in recs),
            )
        return out

    # ---- crash-consistent persistence (the serve resume path) ----
    #
    # The engine's state splits into an ARRAY half (a checkpointable pytree:
    # fairness counts, in-flight round arrays, fault strikes) and a JSON
    # half (clock, event heap, per-job lifecycle, RNG states, in-flight
    # scalars). ``repro.serve.persistence`` stores the former through
    # ``repro.checkpoint`` and the latter in the manifest's ``extra``.

    def state_arrays(self) -> dict:
        inflight = {}
        for j, f in sorted(self._in_flight.items()):
            ctx = f["ctx"]
            inflight[str(j)] = dict(
                plan=f["plan"], survivors=f["survivors"],
                counted=f["counted"], failed=f["failed"],
                dropped=f["dropped"], corrupt=f["corrupt"],
                ctx_available=ctx.available, ctx_counts=ctx.counts,
                ctx_times=ctx.expected_times)
        out = {"counts": self.counts, "inflight": inflight}
        if self.fault_engine is not None:
            out["faults"] = self.fault_engine.state_dict()
        return out

    def state_meta(self) -> dict:
        """JSON-serializable half (scalars, heap, RNG states)."""
        inflight = {}
        for j, f in sorted(self._in_flight.items()):
            ctx = f["ctx"]
            inflight[str(j)] = dict(
                t_start=f["t_start"], cost=f["cost"],
                fairness=f["fairness"], round_time=f["round_time"],
                est_cost=(None if f["est_cost"] is None
                          else float(f["est_cost"])),
                degraded=bool(f["degraded"]),
                rung=f.get("rung"),
                decision_ms=(None if f.get("decision_ms") is None
                             else float(f["decision_ms"])),
                ctx_round_idx=int(ctx.round_idx), ctx_tau=float(ctx.tau),
                ctx_n_sel=int(ctx.n_sel),
                ctx_other_costs=float(ctx.other_costs))
        return dict(
            clock=self.clock, seq=self._seq,
            retry_counts={str(j): int(c)
                          for j, c in sorted(self._retry_counts.items())},
            heap=[[float(t), int(s), k, int(j)] for t, s, k, j in self._heap],
            clamp_warned=sorted(self._clamp_warned),
            n_sel=self.n_sel, over_provision=self.over_provision,
            rng=self.rng.bit_generator.state,
            jobs=[dict(round_idx=js.round_idx, done=js.done,
                       reached_target_at=js.reached_target_at,
                       total_round_time=js.total_round_time,
                       admitted_at=js.admitted_at, retired=js.retired,
                       retired_at=js.retired_at, launched=js.launched,
                       parked=js.parked) for js in self.jobs],
            inflight=inflight)

    def load_state(self, arrays: dict, meta: dict) -> None:
        """Restore ``state_arrays``/``state_meta`` (jobs must already be
        re-added so every per-job row exists)."""
        self.counts = np.asarray(arrays["counts"], dtype=np.float64).copy()
        if self.fault_engine is not None and "faults" in arrays:
            self.fault_engine.load_state_dict(arrays["faults"])
        self.clock = float(meta["clock"])
        self._seq = int(meta["seq"])
        self._heap = [(float(t), int(s), str(k), int(j))
                      for t, s, k, j in meta["heap"]]
        heapq.heapify(self._heap)
        self._clamp_warned = set(meta["clamp_warned"])
        self._retry_counts = {int(j): int(c) for j, c
                              in meta.get("retry_counts", {}).items()}
        self.n_sel = int(meta["n_sel"])
        self.over_provision = float(meta["over_provision"])
        self.rng.bit_generator.state = meta["rng"]
        if len(meta["jobs"]) != len(self.jobs):
            raise ValueError(
                f"checkpoint has {len(meta['jobs'])} jobs, engine has "
                f"{len(self.jobs)} — re-add admitted jobs before load_state")
        for js, jm in zip(self.jobs, meta["jobs"]):
            js.round_idx = int(jm["round_idx"])
            js.done = bool(jm["done"])
            js.reached_target_at = jm["reached_target_at"]
            js.total_round_time = float(jm["total_round_time"])
            js.admitted_at = float(jm["admitted_at"])
            js.retired = bool(jm["retired"])
            js.retired_at = jm["retired_at"]
            js.launched = bool(jm["launched"])
            js.parked = bool(jm["parked"])
        self._in_flight = {}
        for key, fa in arrays["inflight"].items():
            fm = meta["inflight"][key]
            job = int(key)
            ctx = SchedulingContext(
                job=job, round_idx=int(fm["ctx_round_idx"]),
                tau=float(fm["ctx_tau"]), n_sel=int(fm["ctx_n_sel"]),
                available=np.asarray(fa["ctx_available"], dtype=bool),
                counts=np.asarray(fa["ctx_counts"], dtype=np.float64),
                expected_times=np.asarray(fa["ctx_times"], dtype=np.float64),
                other_costs=float(fm["ctx_other_costs"]))
            self._in_flight[job] = dict(
                plan=np.asarray(fa["plan"], dtype=bool),
                survivors=np.asarray(fa["survivors"], dtype=int),
                counted=np.asarray(fa["counted"], dtype=int),
                failed=np.asarray(fa["failed"], dtype=int),
                dropped=np.asarray(fa["dropped"], dtype=int),
                corrupt=np.asarray(fa["corrupt"], dtype=int),
                degraded=bool(fm["degraded"]),
                t_start=float(fm["t_start"]), cost=float(fm["cost"]),
                fairness=float(fm["fairness"]),
                round_time=float(fm["round_time"]),
                est_cost=fm["est_cost"], rung=fm.get("rung"),
                decision_ms=fm.get("decision_ms"), ctx=ctx)
