"""Heterogeneous device pool with the paper's shifted-exponential time model.

Formula 4:  P[t_m^k < t] = 1 - exp(-(mu_k / (tau_m D_k^m)) * (t - tau_m a_k D_k^m))
i.e. t_m^k = tau_m * a_k * D_k^m  +  Exp(scale = tau_m * D_k^m / mu_k)

- ``a_k``  — deterministic per-sample cost floor (inverse max capability)
- ``mu_k`` — fluctuation rate (larger mu -> less jitter)
- ``D_k^m`` — local dataset size of job m on device k
- ``tau_m`` — local epochs of job m

Expected time:  E[t_m^k] = tau_m * D_k^m * (a_k + 1/mu_k).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class DevicePool:
    """K devices, their capabilities, per-job data sizes, and occupancy."""

    a: np.ndarray          # (K,) capability floor, seconds per (epoch * sample)
    mu: np.ndarray         # (K,) fluctuation rate
    data_sizes: np.ndarray  # (K, M) samples of job m on device k
    rng: np.random.Generator

    # Occupancy: device k is busy until time busy_until[k] (simulated seconds).
    busy_until: np.ndarray = None  # (K,)

    def __post_init__(self):
        if self.busy_until is None:
            self.busy_until = np.zeros(self.num_devices, dtype=np.float64)

    # ---- constructors ----

    @classmethod
    def heterogeneous(
        cls,
        num_devices: int,
        num_jobs: int,
        seed: int = 0,
        a_range=(2e-4, 2e-3),
        mu_range=(1.0, 10.0),
        data_range=(200, 600),
    ) -> "DevicePool":
        """Log-uniform capabilities — a 10x speed spread as in edge fleets."""
        rng = np.random.default_rng(seed)
        a = np.exp(rng.uniform(np.log(a_range[0]), np.log(a_range[1]), num_devices))
        mu = rng.uniform(*mu_range, num_devices)
        d = rng.integers(data_range[0], data_range[1], size=(num_devices, num_jobs))
        return cls(a=a, mu=mu, data_sizes=d.astype(np.float64), rng=rng)

    @property
    def num_devices(self) -> int:
        return int(self.a.shape[0])

    @property
    def num_jobs(self) -> int:
        return int(self.data_sizes.shape[1])

    # ---- time model (Formula 4) ----

    def expected_times(self, job: int, tau: float) -> np.ndarray:
        """(K,) expected round time per device for job ``job``."""
        d = self.data_sizes[:, job]
        return tau * d * (self.a + 1.0 / self.mu)

    def sample_times(self, job: int, tau: float, size: Optional[int] = None) -> np.ndarray:
        """Sample realized times for all K devices (one round)."""
        d = self.data_sizes[:, job]
        shift = tau * self.a * d
        scale = tau * d / self.mu
        shape = (self.num_devices,) if size is None else (size, self.num_devices)
        return shift + self.rng.exponential(1.0, size=shape) * scale

    # ---- occupancy ----

    def available_mask(self, now: float) -> np.ndarray:
        """(K,) bool — devices free at simulated time ``now``."""
        return self.busy_until <= now + 1e-12

    def occupy(self, mask: np.ndarray, until: np.ndarray | float) -> None:
        """Mark masked devices busy until ``until`` (scalar or per-device)."""
        until = np.asarray(until, dtype=np.float64)
        if until.ndim == 0:
            until = np.full(self.num_devices, float(until))
        self.busy_until = np.where(mask, np.maximum(self.busy_until, until), self.busy_until)

    def fail(self, device_ids, until: float = np.inf) -> None:
        """Fault injection: device(s) drop out until ``until`` (default forever)."""
        mask = np.zeros(self.num_devices, dtype=bool)
        mask[np.asarray(device_ids)] = True
        self.occupy(mask, until)

    def recover(self, device_ids) -> None:
        self.busy_until[np.asarray(device_ids)] = 0.0
