"""Heterogeneous device pool with the paper's shifted-exponential time model.

Formula 4:  P[t_m^k < t] = 1 - exp(-(mu_k / (tau_m D_k^m)) * (t - tau_m a_k D_k^m))
i.e. t_m^k = tau_m * a_k * D_k^m  +  Exp(scale = tau_m * D_k^m / mu_k)

- ``a_k``  — deterministic per-sample cost floor (inverse max capability)
- ``mu_k`` — fluctuation rate (larger mu -> less jitter)
- ``D_k^m`` — local dataset size of job m on device k
- ``tau_m`` — local epochs of job m
- Expected time:  E[t_m^k] = tau_m * D_k^m * (a_k + 1/mu_k).

Fleet-scale fast path: the per-job time-model coefficients are materialized
ONCE as a structure-of-arrays (``_base``/``_shift``/``_scale``, (M, K), plus
float32 mirrors for the scoring core) so a 100k-device pool constructs and
schedules without per-round Python loops or repeated elementwise rebuilds —
``expected_times`` is a cached lookup, ``sample_times_into`` draws a round
into a caller-owned buffer with zero fresh allocation, and the ``*_all``
variants produce all M jobs fused in one vectorized call.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


def _bf16_dtype() -> np.dtype:
    """bfloat16 if the runtime ships it (``ml_dtypes`` comes with jax),
    else float16 — either way a 2-byte compact mirror."""
    try:
        import ml_dtypes

        return np.dtype(ml_dtypes.bfloat16)
    except ImportError:  # pragma: no cover - ml_dtypes ships with jax
        return np.dtype(np.float16)


@dataclasses.dataclass
class DevicePool:
    """K devices, their capabilities, per-job data sizes, and occupancy."""

    a: np.ndarray          # (K,) capability floor, seconds per (epoch * sample)
    mu: np.ndarray         # (K,) fluctuation rate
    data_sizes: np.ndarray  # (K, M) samples of job m on device k
    rng: np.random.Generator

    # Occupancy: device k is busy until time busy_until[k] (simulated seconds).
    busy_until: np.ndarray = None  # (K,)

    # Pool-level dtype for every time-valued hot-path buffer (busy_until,
    # the SoA coefficient arrays, the sampling scratch buffer). float64 by
    # default; a million-device pool drops to float32 to halve its resident
    # footprint — the scoring core consumes the float32/bf16 mirrors either
    # way, so plan costs are unchanged.
    time_dtype: np.dtype = np.float64

    def __post_init__(self):
        self.time_dtype = np.dtype(self.time_dtype)
        if self.busy_until is None:
            self.busy_until = np.zeros(self.num_devices, dtype=self.time_dtype)
        else:
            self.busy_until = np.asarray(self.busy_until, dtype=self.time_dtype)
        self._soa_src = None  # SoA caches build lazily (data_sizes may be rescaled)
        self._version = 0     # bumped on every invalidation (churn detection)

    # ---- constructors ----

    @classmethod
    def heterogeneous(
        cls,
        num_devices: int,
        num_jobs: int,
        seed: int = 0,
        a_range=(2e-4, 2e-3),
        mu_range=(1.0, 10.0),
        data_range=(200, 600),
        time_dtype=np.float64,
    ) -> "DevicePool":
        """Log-uniform capabilities — a 10x speed spread as in edge fleets."""
        rng = np.random.default_rng(seed)
        a = np.exp(rng.uniform(np.log(a_range[0]), np.log(a_range[1]), num_devices))
        mu = rng.uniform(*mu_range, num_devices)
        d = rng.integers(data_range[0], data_range[1], size=(num_devices, num_jobs))
        return cls(a=a, mu=mu, data_sizes=d.astype(np.float64), rng=rng,
                   time_dtype=time_dtype)

    @property
    def num_devices(self) -> int:
        return int(self.a.shape[0])

    @property
    def num_jobs(self) -> int:
        return int(self.data_sizes.shape[1])

    # ---- structure-of-arrays fast path ----

    def invalidate(self) -> None:
        """Drop the SoA caches (``_base``/``_shift``/``_scale`` and the
        per-(job, tau) ``_exp_cache``/``_shift_cache`` memo tables). Needed
        after IN-PLACE mutation of ``a``/``mu``/``data_sizes`` (replacing
        ``data_sizes`` wholesale is detected automatically). The churn
        mutators below (``set_capabilities``/``add_job``/``rejoin``) call
        this themselves — use them instead of raw attribute writes and the
        caches can never go stale."""
        self._soa_src = None
        self._version += 1

    @property
    def version(self) -> int:
        """Monotone cache-generation counter: bumped every time the time
        model mutates (coefficient churn, job admission). Consumers holding
        derived arrays (scheduler services, plan caches) compare versions
        instead of re-deriving per round."""
        return self._version

    # ---- churn mutators (the invalidation hooks) ----

    def set_capabilities(self, device_ids, a=None, mu=None) -> None:
        """Mutate per-device capability coefficients in place and drop every
        derived cache. This is the supported way to model capability churn
        (thermal throttling, a rejoining device on a different network):
        writing ``pool.a[...]`` directly leaves ``_exp_cache`` serving the
        pre-churn time model."""
        ids = np.asarray(device_ids)
        if a is not None:
            self.a[ids] = a
        if mu is not None:
            self.mu[ids] = mu
        self.invalidate()

    def add_job(self, data_sizes: Optional[np.ndarray] = None) -> int:
        """Append one job column to ``data_sizes`` (dynamic job admission);
        returns the new job index. ``data_sizes`` defaults to a fresh draw
        from the range of the existing columns."""
        K = self.num_devices
        if data_sizes is None:
            if self.num_jobs == 0:
                raise ValueError("add_job on a 0-job pool needs explicit "
                                 "data_sizes (no range to draw from)")
            lo, hi = float(self.data_sizes.min()), float(self.data_sizes.max())
            data_sizes = self.rng.uniform(lo, hi, K)
        col = np.asarray(data_sizes, dtype=np.float64).reshape(K, 1)
        self.data_sizes = np.concatenate([self.data_sizes, col], axis=1)
        self.invalidate()  # new array is auto-detected; bump version anyway
        return self.num_jobs - 1

    def set_job_data(self, job: int, data_sizes: np.ndarray) -> None:
        """Overwrite one job's data-size column (and invalidate)."""
        self.data_sizes[:, job] = np.asarray(data_sizes, dtype=np.float64)
        self.invalidate()

    def depart(self, device_ids) -> None:
        """Membership churn: device(s) leave the fleet until ``rejoin``
        (identical occupancy semantics to a permanent fault)."""
        self.fail(device_ids, until=np.inf)

    def rejoin(self, device_ids, a=None, mu=None) -> None:
        """Departed device(s) return, optionally with drifted capability
        coefficients (cache invalidation included)."""
        if a is not None or mu is not None:
            self.set_capabilities(device_ids, a=a, mu=mu)
        self.recover(device_ids)

    def _ensure_soa(self) -> None:
        """(Re)build the per-job coefficient arrays; invalidates automatically
        when ``data_sizes`` is replaced (e.g. PoolSpec job_weights rescaling)."""
        if self._soa_src is self.data_sizes:
            return
        d = self.data_sizes.T                         # (M, K)
        dt = self.time_dtype
        self._base = np.ascontiguousarray(
            (d * (self.a + 1.0 / self.mu)).astype(dt, copy=False))  # E[t]/tau
        self._shift = np.ascontiguousarray(
            (d * self.a).astype(dt, copy=False))                    # floor/tau
        self._scale = np.ascontiguousarray(
            (d / self.mu).astype(dt, copy=False))                   # Exp scale/tau
        self._base32 = self._base.astype(np.float32)  # scoring-core mirror
        self._base_bf16 = None                        # lazy 2-byte mirror
        self._exp_cache = {}                          # (job, tau) -> (K,) E[t]
        self._shift_cache = {}                        # (job, tau) -> (K,) tau*shift
        self._ebuf = np.empty(self.num_devices, dtype=dt)
        self._soa_src = self.data_sizes

    # ---- time model (Formula 4) ----

    def expected_times(self, job: int, tau: float) -> np.ndarray:
        """(K,) expected round time per device for job ``job`` (cached —
        treat as read-only)."""
        self._ensure_soa()
        key = (int(job), float(tau))
        out = self._exp_cache.get(key)
        if out is None:
            out = tau * self._base[job]
            self._exp_cache[key] = out
        return out

    def expected_times32(self, job: int, tau: float) -> np.ndarray:
        """float32 expected times for the jitted scoring backends."""
        self._ensure_soa()
        return np.float32(tau) * self._base32[job]

    def expected_times_bf16(self, job: int, tau: float) -> np.ndarray:
        """Expected times computed from the 2-byte (bf16) coefficient
        mirror, upcast to float32 for arithmetic. Quarter the float64
        coefficients' footprint at ~0.4% relative error (bf16 keeps
        float32's exponent range, 8 mantissa bits) — the memory-bound
        choice for million-device fleets. Built lazily; rebuilt with the
        SoA on churn."""
        self._ensure_soa()
        if self._base_bf16 is None:
            self._base_bf16 = self._base32.astype(_bf16_dtype())
        return np.float32(tau) * self._base_bf16[job].astype(np.float32)

    def expected_times_all(self, taus: Sequence[float]) -> np.ndarray:
        """(M, K) expected times for every job fused in one call."""
        self._ensure_soa()
        return np.asarray(taus, dtype=self.time_dtype)[:, None] * self._base

    def sample_times(self, job: int, tau: float, size: Optional[int] = None) -> np.ndarray:
        """Sample realized times for all K devices (one round)."""
        self._ensure_soa()
        if size is not None:
            e = self.rng.exponential(1.0, size=(size, self.num_devices))
            return tau * self._shift[job] + e * (tau * self._scale[job])
        out = np.empty(self.num_devices, dtype=self.time_dtype)
        return self.sample_times_into(job, tau, out)

    def sample_times_into(self, job: int, tau: float, out: np.ndarray) -> np.ndarray:
        """Allocation-free round sampling into a caller-owned (K,) buffer."""
        self._ensure_soa()
        key = (int(job), float(tau))
        shift = self._shift_cache.get(key)
        if shift is None:
            shift = tau * self._shift[job]
            self._shift_cache[key] = shift
        self.rng.standard_exponential(out=self._ebuf, dtype=self._ebuf.dtype)
        np.multiply(self._ebuf, self._scale[job], out=out)
        out *= tau
        out += shift
        return out

    def sample_times_all(self, taus: Sequence[float]) -> np.ndarray:
        """(M, K) one realized round for every job, one fused RNG draw."""
        self._ensure_soa()
        t = np.asarray(taus, dtype=self.time_dtype)[:, None]
        e = self.rng.standard_exponential((self.num_jobs, self.num_devices),
                                          dtype=self.time_dtype)
        return t * self._shift + e * (t * self._scale)

    # ---- occupancy ----

    def available_mask(self, now: float) -> np.ndarray:
        """(K,) bool — devices free at simulated time ``now``."""
        return self.busy_until <= now + 1e-12

    def occupy(self, mask: np.ndarray, until: np.ndarray | float) -> None:
        """Mark masked devices busy until ``until`` (scalar or per-device)."""
        until = np.asarray(until, dtype=self.time_dtype)
        if until.ndim == 0:
            until = np.full(self.num_devices, until, dtype=self.time_dtype)
        self.busy_until = np.where(mask, np.maximum(self.busy_until, until), self.busy_until)

    def fail(self, device_ids, until: float = np.inf) -> None:
        """Fault injection: device(s) drop out until ``until`` (default forever)."""
        mask = np.zeros(self.num_devices, dtype=bool)
        mask[np.asarray(device_ids)] = True
        self.occupy(mask, until)

    def recover(self, device_ids) -> None:
        self.busy_until[np.asarray(device_ids)] = 0.0

    # ---- persistence (crash-consistent service checkpoints) ----

    def state_dict(self) -> dict:
        """Array state for checkpointing. ``rng`` state is NOT included —
        PCG64 state holds 128-bit integers that don't fit numpy arrays, so
        it rides in the manifest's JSON half (``rng.bit_generator.state``)."""
        return {
            "a": self.a.copy(),
            "mu": self.mu.copy(),
            "data_sizes": self.data_sizes.copy(),
            "busy_until": self.busy_until.copy(),
        }

    def load_state_dict(self, state: dict) -> None:
        """Restore array state (shapes must match — re-add job columns via
        ``add_job`` first when resuming a run with dynamic admission)."""
        if np.shape(state["data_sizes"]) != self.data_sizes.shape:
            raise ValueError(
                f"checkpoint data_sizes {np.shape(state['data_sizes'])} vs "
                f"pool {self.data_sizes.shape} — re-add jobs before loading")
        self.a = np.asarray(state["a"], dtype=np.float64).copy()
        self.mu = np.asarray(state["mu"], dtype=np.float64).copy()
        self.data_sizes = np.asarray(state["data_sizes"],
                                     dtype=np.float64).copy()
        self.busy_until = np.asarray(state["busy_until"],
                                     dtype=self.time_dtype).copy()
        self.invalidate()
