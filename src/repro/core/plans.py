"""Scheduling-plan representation and invariants.

A plan is a boolean vector over the K devices with exactly ``n_sel`` True
entries, all of which must be available (not occupied by another job).
These invariants are property-tested in tests/test_schedulers.py.
"""

from __future__ import annotations

import numpy as np


def empty_plan(num_devices: int) -> np.ndarray:
    return np.zeros(num_devices, dtype=bool)


def plan_from_indices(num_devices: int, idx) -> np.ndarray:
    p = empty_plan(num_devices)
    p[np.asarray(idx, dtype=int)] = True
    return p


def random_plan_indices(
    rng: np.random.Generator, available: np.ndarray, n_sel: int, count: int
) -> np.ndarray:
    """(count, n_sel) int32 device ids — uniform sampling without replacement.

    Fully vectorized: one (count, |avail|) key draw + batched argpartition,
    instead of ``count`` sequential ``rng.choice`` calls — the difference
    between milliseconds and minutes when proposing 4096 candidates over a
    100k-device fleet. This INDEX form is also the scoring core's fast
    path (``scoring.score_plan_indices`` never touches a (P, K) dense
    array); ``random_plans`` is the same draw scattered to dense bool.
    """
    avail_idx = np.flatnonzero(available)
    if avail_idx.size < n_sel:
        raise ValueError(f"need {n_sel} available devices, have {avail_idx.size}")
    if n_sel == 0 or count == 0:
        return np.zeros((count, n_sel), dtype=np.int32)
    keys = rng.random((count, avail_idx.size))
    sel = np.argpartition(keys, n_sel - 1, axis=1)[:, :n_sel]
    return avail_idx[sel].astype(np.int32)


def indices_to_plans(idx: np.ndarray, num_devices: int,
                     dtype=bool) -> np.ndarray:
    """(count, n_sel) device ids -> (count, K) dense plans.

    ``dtype=np.int8`` produces the scoring core's compact mirror directly
    (0/1 bytes): ``scoring.score_plans`` converts bool plans to int8 before
    the jitted reduction anyway, so int8-from-the-start skips one (P, K)
    materialization on the hot path.
    """
    idx = np.asarray(idx)
    plans = np.zeros((idx.shape[0], num_devices), dtype=dtype)
    if idx.size:
        rows = np.repeat(np.arange(idx.shape[0]), idx.shape[1])
        plans[rows, idx.ravel()] = True
    return plans


def random_plans(
    rng: np.random.Generator, available: np.ndarray, n_sel: int, count: int,
    dtype=bool
) -> np.ndarray:
    """(count, K) random valid plans drawn from the available set."""
    idx = random_plan_indices(rng, available, n_sel, count)
    return indices_to_plans(idx, available.shape[0], dtype=dtype)


def gumbel_topk_plans(
    rng: np.random.Generator, logits: np.ndarray, available: np.ndarray,
    n_sel: int
) -> np.ndarray:
    """(count, K) plans via batched Gumbel top-k over per-plan logits.

    ``logits``: (count, K) (or (K,), broadcast) — a Plackett-Luce draw
    without replacement per row, restricted to the available set. This is
    the shared candidate-proposal primitive (BODS structured candidates,
    RLDS policy converter) in one vectorized pass.
    """
    logits = np.atleast_2d(np.asarray(logits, dtype=np.float64))
    count, K = logits.shape
    g = logits + rng.gumbel(size=(count, K))
    g = np.where(available[None, :], g, -np.inf)
    plans = np.zeros((count, K), dtype=bool)
    if n_sel == 0 or count == 0:
        return plans
    sel = np.argpartition(-g, n_sel - 1, axis=1)[:, :n_sel]
    np.put_along_axis(plans, sel, True, axis=1)
    return plans


def validate_plan(plan: np.ndarray, available: np.ndarray, n_sel: int) -> None:
    assert plan.dtype == bool and plan.ndim == 1
    assert int(plan.sum()) == n_sel, (int(plan.sum()), n_sel)
    assert not np.any(plan & ~available), "plan uses occupied device(s)"


def repair_plan(
    rng: np.random.Generator, plan: np.ndarray, available: np.ndarray, n_sel: int
) -> np.ndarray:
    """Force a candidate onto the feasible set: drop occupied, fix cardinality."""
    p = plan & available
    n = int(p.sum())
    if n > n_sel:  # drop random extras
        on = np.flatnonzero(p)
        off = rng.choice(on, size=n - n_sel, replace=False)
        p[off] = False
    elif n < n_sel:  # top up from available complement
        free = np.flatnonzero(available & ~p)
        add = rng.choice(free, size=n_sel - n, replace=False)
        p[add] = True
    return p


def repair_plans(
    rng: np.random.Generator, plans: np.ndarray, available: np.ndarray,
    n_sel: int
) -> np.ndarray:
    """Vectorized ``repair_plan``: a whole (P, K) population in one pass.

    Same semantics per row — occupied devices dropped, valid selections kept
    (random extras dropped when over ``n_sel``, random available top-ups when
    under), idempotent on already-valid plans — via one priority top-k
    instead of P Python loops: key = 1[selected & available] + U(0, 1),
    masked to -inf off the available set; the ``n_sel`` largest keys are the
    repaired selection. This is the same top-k machinery the fused searchers
    (``repro.core.search``) and the gym's Gumbel-top-k plan primitive run
    in-graph. Like ``repair_plan``, raises when the available set cannot
    host ``n_sel`` devices (the jax twin, which cannot raise under jit,
    returns under-full masked plans instead).
    """
    plans = np.atleast_2d(np.asarray(plans, dtype=bool))
    P, K = plans.shape
    if n_sel == 0 or P == 0:
        return np.zeros((P, K), dtype=bool)
    n_avail = int(np.count_nonzero(available))
    if n_avail < n_sel:
        raise ValueError(f"need {n_sel} available devices, have {n_avail}")
    keys = (plans & available[None, :]) + rng.random((P, K))
    keys = np.where(available[None, :], keys, -np.inf)
    sel = np.argpartition(-keys, n_sel - 1, axis=1)[:, :n_sel]
    out = np.zeros((P, K), dtype=bool)
    np.put_along_axis(out, sel, True, axis=1)
    return out
