"""Scheduling-plan representation and invariants.

A plan is a boolean vector over the K devices with exactly ``n_sel`` True
entries, all of which must be available (not occupied by another job).
These invariants are property-tested in tests/test_schedulers.py.
"""

from __future__ import annotations

import numpy as np


def empty_plan(num_devices: int) -> np.ndarray:
    return np.zeros(num_devices, dtype=bool)


def plan_from_indices(num_devices: int, idx) -> np.ndarray:
    p = empty_plan(num_devices)
    p[np.asarray(idx, dtype=int)] = True
    return p


def random_plans(
    rng: np.random.Generator, available: np.ndarray, n_sel: int, count: int
) -> np.ndarray:
    """(count, K) random valid plans drawn from the available set."""
    avail_idx = np.flatnonzero(available)
    if avail_idx.size < n_sel:
        raise ValueError(f"need {n_sel} available devices, have {avail_idx.size}")
    plans = np.zeros((count, available.shape[0]), dtype=bool)
    for i in range(count):
        sel = rng.choice(avail_idx, size=n_sel, replace=False)
        plans[i, sel] = True
    return plans


def validate_plan(plan: np.ndarray, available: np.ndarray, n_sel: int) -> None:
    assert plan.dtype == bool and plan.ndim == 1
    assert int(plan.sum()) == n_sel, (int(plan.sum()), n_sel)
    assert not np.any(plan & ~available), "plan uses occupied device(s)"


def repair_plan(
    rng: np.random.Generator, plan: np.ndarray, available: np.ndarray, n_sel: int
) -> np.ndarray:
    """Force a candidate onto the feasible set: drop occupied, fix cardinality."""
    p = plan & available
    n = int(p.sum())
    if n > n_sel:  # drop random extras
        on = np.flatnonzero(p)
        off = rng.choice(on, size=n - n_sel, replace=False)
        p[off] = False
    elif n < n_sel:  # top up from available complement
        free = np.flatnonzero(available & ~p)
        add = rng.choice(free, size=n_sel - n, replace=False)
        p[add] = True
    return p
