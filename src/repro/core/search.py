"""Fused on-device scheduler search: the plan-SEARCH loops, jitted.

PR 2 made plan *evaluation* fast (one batched scoring call under every
scheduler); this module makes the *search* around it fast. The host
searchers step one proposal at a time through Python — SA performs
``steps`` sequential cost calls per decision, the GA repairs and mutates
children in per-individual loops — so at fleet scale (K = 1e4+) scheduler
decision latency dominates round time. Here the full loops run as jitted
``lax.scan`` programs:

- ``sa_search``   — C parallel simulated-annealing chains stepped under one
  ``lax.scan``: plans carried in INDEX form ((C, n_sel) device ids — the
  scoring core's fleet fast path, so each step is n_sel gathers instead of
  a K-wide sweep), swap/accept noise PRE-DRAWN on the host (the scan body
  contains zero PRNG), masked one-selected-for-one-free swaps, geometric
  cooling, running per-chain best, best-of-chains result. One jitted call
  per decision instead of ``steps`` host round-trips.
- ``ga_search``   — generations under ``lax.scan`` with vmapped tournament
  selection, slot-wise uniform crossover on the index form (each slot
  flips a coin to adopt the other parent's device at that slot, gated so
  only devices absent from this parent are adopted — children are
  duplicate-free and exactly ``n_sel``-sized by construction, so no
  repair/sort step runs mid-loop), swap mutation, elitism.
- ``bods_acquire`` — the full BODS acquisition (candidate generation:
  random + structured Gumbel-top-k over availability logits in-graph,
  plus host-prepared local-search mutants of the best observed plan run
  through the vectorized in-graph repair; featurization phi(V);
  Matern-5/2 GP posterior + Expected Improvement; argmax) in ONE jitted
  call per decision. ``ei_scores_jobs`` vmaps the GP posterior over the
  job axis so all M jobs' candidate sets score in one call.

Conventions shared with ``repro.core.scoring``: times/counts are float32
on device, counts are mean-centered in float64 on the host first (variance
is shift-invariant; centering keeps f32 cancellation-free), a plan is a
(K,) bool row with exactly ``n_sel`` True entries inside ``available`` —
equivalently an (n_sel,) row of distinct available device ids. Every
jitted builder is keyed on its STATIC shape knobs via ``lru_cache`` (the
per-experiment set is tiny: one compile per (steps, chains, n_sel)).

Both fused population inits seed one slot with the greedy plan (the
``n_sel`` fastest available devices — a standard memetic warm start): at a
matched evaluation budget the fused searchers then dominate the host path
on chosen-plan cost, which ``benchmarks/bench_sched.py`` gates on.
"""

from __future__ import annotations

import functools
import logging
from typing import Optional, Tuple

import numpy as np

from repro.core.plans import plan_from_indices
from repro.monitoring.trace import span

logger = logging.getLogger(__name__)


def _usable_search_shards(num_shards, rows: int, pairs: bool = False) -> int:
    """Shard count a fused searcher can actually use for ``rows`` parallel
    units (SA chains / GA population / BODS candidates): falls back to the
    single lane when the process lacks devices, when ``rows`` does not
    split evenly, or (``pairs``) when the per-shard block would break the
    GA's consecutive-pair crossover. Falling back changes NOTHING but the
    partitioning — the single-lane program is the num_shards=1 special
    case of the same math."""
    n = int(num_shards or 1)
    if n <= 1:
        return 1
    reason = None
    try:
        from repro.core import shard

        if n > shard.shard_capacity():
            reason = (f"num_shards={n} exceeds jax.device_count(); "
                      "launch via repro.launch.bootstrap to size the "
                      "host platform")
    except Exception:  # pragma: no cover - no jax runtime
        reason = "no jax runtime"
    if reason is None and rows % n:
        reason = f"{rows} search rows do not split across {n} shards"
    if reason is None and pairs and (rows // n) % 2:
        reason = (f"per-shard block {rows // n} is odd (pair crossover "
                  "needs even blocks)")
    if reason is not None:
        logger.debug("fused search falling back to single lane: %s", reason)
        return 1
    return n

# ---- traced building blocks ---------------------------------------------


def _fairness_from_stats(counts_c, n, wsum, delta_fairness: bool):
    """Formula-5 fairness from the centered sufficient statistics — the
    ONE copy of the variance expansion inside this module (shared by the
    dense/index cost paths and the BODS featurization; semantics identical
    to ``scoring._jax_score_fn``). ``n``: (P,) selected counts; ``wsum``:
    (P,) sums of 2*counts_c+1 over the selection."""
    import jax.numpy as jnp

    K = float(counts_c.shape[-1])
    c1 = jnp.sum(counts_c)
    if delta_fairness:
        return wsum / K - (2.0 * c1 * n + n * n) / (K * K)
    c2 = jnp.sum(counts_c * counts_c)
    return (c2 + wsum) / K - ((c1 + n) / K) ** 2


def _dense_stats(times, counts_c, plans):
    """(P, K) bool plans -> (round time t, n selected, wsum) — the masked
    max + fairness sufficient statistics, one pass."""
    import jax.numpy as jnp

    masked = jnp.where(plans, times, -jnp.inf)
    t = jnp.max(masked, axis=-1)
    t = jnp.where(jnp.isfinite(t), t, 0.0)
    w = 2.0 * counts_c + 1.0
    n = jnp.sum(plans, axis=-1).astype(jnp.float32)
    wsum = jnp.sum(jnp.where(plans, w, 0.0), axis=-1)
    return t, n, wsum


def plan_costs(times, counts_c, plans, alpha, beta, ts, fs,
               delta_fairness: bool):
    """(P, K) bool plans -> (P,) Formula-2 costs. Traced (safe under
    jit/vmap/scan); semantics identical to ``scoring._jax_score_fn``.
    ``counts_c`` must be mean-centered."""
    t, n, wsum = _dense_stats(times, counts_c, plans)
    f = _fairness_from_stats(counts_c, n, wsum, delta_fairness)
    return alpha * t / ts + beta * f / fs


def plan_costs_idx(times, counts_c, idx, alpha, beta, ts, fs,
                   delta_fairness: bool):
    """(P, n_sel) device-id plans -> (P,) Formula-2 costs (the index fast
    path: n_sel gathers per plan, never a K-wide sweep). Rows must hold
    distinct ids. Semantics identical to ``scoring._jax_score_idx_fn``."""
    import jax.numpy as jnp

    n = float(idx.shape[-1])
    t = jnp.max(times[idx], axis=-1)
    w = 2.0 * counts_c + 1.0
    wsum = jnp.sum(w[idx], axis=-1)
    f = _fairness_from_stats(counts_c, n, wsum, delta_fairness)
    return alpha * t / ts + beta * f / fs


def _gumbel_plans(key, logits, avail, n_sel: int):
    """(P, K) logits -> (P, K) bool plans: Gumbel top-k over the available
    set (the in-graph twin of ``plans.gumbel_topk_plans``)."""
    import jax
    import jax.numpy as jnp

    g = jnp.where(avail[None, :], logits + jax.random.gumbel(key, logits.shape),
                  -jnp.inf)
    _, idx = jax.lax.top_k(g, n_sel)
    plans = jnp.zeros(logits.shape, bool)
    plans = plans.at[jnp.arange(logits.shape[0])[:, None], idx].set(True)
    return plans & avail[None, :]


def repair_plans_jax(key, plans, avail, n_sel: int):
    """In-graph vectorized repair — jax twin of ``plans.repair_plans``.

    Priority top-k: valid selections keep rank over everything else (key
    1 + noise vs noise), occupied devices are masked out, noise tie-breaks
    pick the random extras to drop / random available devices to add.
    Idempotent on valid plans. Precondition: ``avail.sum() >= n_sel``.
    """
    import jax
    import jax.numpy as jnp

    keys = (plans & avail[None, :]) + jax.random.uniform(key, plans.shape)
    keys = jnp.where(avail[None, :], keys, -jnp.inf)
    _, idx = jax.lax.top_k(keys, n_sel)
    out = jnp.zeros(plans.shape, bool)
    out = out.at[jnp.arange(plans.shape[0])[:, None], idx].set(True)
    return out & avail[None, :]


def _swap_into(idx, pos, cand):
    """Propose ``idx[row, pos[row]] = cand[row]`` per row, masked where
    ``cand`` already sits in the row (a swap must introduce a NEW device).
    Returns (proposal, moved_mask)."""
    import jax.numpy as jnp

    collision = jnp.any(idx == cand[:, None], axis=-1)
    rows = jnp.arange(idx.shape[0])
    nxt = idx.at[rows, pos].set(cand)
    moved = ~collision
    return jnp.where(moved[:, None], nxt, idx), moved


def _greedy_indices(times: np.ndarray, avail_idx: np.ndarray,
                    n_sel: int) -> np.ndarray:
    """Host helper: ids of the n_sel fastest available devices."""
    t_av = times[avail_idx]
    cut = np.argpartition(t_av, n_sel - 1)[:n_sel]
    return avail_idx[cut].astype(np.int32)


def _init_indices(rng: np.random.Generator, avail_idx: np.ndarray,
                  n_sel: int, rows: int) -> np.ndarray:
    """``rows`` random n_sel-subsets of the available set: strided windows
    of ONE permutation at random offsets — O(A + rows * n_sel) instead of
    ``random_plan_indices``'s O(rows * A) per-row key draw (8 ms vs 0.1 ms
    at A = 8000, rows = 32). Uniform marginals, distinct-within-row; rows
    are windows of the same permutation, which for a population INIT is
    diversity-preserving (near-disjoint coverage of the pool)."""
    A = avail_idx.size
    perm = rng.permutation(A)
    offs = rng.integers(0, A, rows)
    pos = (offs[:, None] + np.arange(n_sel)[None, :]) % A
    return avail_idx[perm[pos]].astype(np.int32)


def _swap_noise(rng: np.random.Generator, avail_idx: np.ndarray,
                steps: int, rows: int, n_sel: int):
    """Pre-drawn swap/accept noise for ``steps`` scan iterations: the slot
    to vacate, the available device to propose (collisions with the current
    selection mask the move on-device), and the Metropolis uniform."""
    pos = rng.integers(0, n_sel, (steps, rows)).astype(np.int32)
    cand = avail_idx[rng.integers(0, avail_idx.size, (steps, rows))]
    u = rng.random((steps, rows)).astype(np.float32)
    return pos, cand.astype(np.int32), u


def _center(counts: np.ndarray) -> np.ndarray:
    counts = np.asarray(counts, dtype=np.float64)
    return (counts - float(counts.mean())).astype(np.float32)


def _check_avail(avail_idx: np.ndarray, n_sel: int) -> None:
    if avail_idx.size < n_sel:
        raise ValueError(
            f"need {n_sel} available devices, have {avail_idx.size}")


# ---- (a) batched multi-chain simulated annealing -------------------------


@functools.lru_cache(maxsize=None)
def _sa_fn(steps: int, chains: int, n_sel: int, delta_fairness: bool,
           num_shards: int = 1):
    import jax
    import jax.numpy as jnp

    def chains_run(init_idx, times, counts_c, pos, cand, accept_u,
                   alpha, beta, ts, fs, t0, cooling):
        # Anneal a block of chains; per-chain bests are returned so the
        # cross-chain argmin can run OUTSIDE the (possibly sharded) body.
        # Chains never interact mid-anneal, so partitioning this body over
        # the chain axis is bitwise-identical to the single lane.
        costs = plan_costs_idx(times, counts_c, init_idx, alpha, beta, ts,
                               fs, delta_fairness)

        def body(carry, xs):
            idx, costs, best_i, best_c, temp = carry
            pos_t, cand_t, u = xs
            nxt, moved = _swap_into(idx, pos_t, cand_t)
            nxt_cost = plan_costs_idx(times, counts_c, nxt, alpha, beta,
                                      ts, fs, delta_fairness)
            dc = nxt_cost - costs
            # Clamped Metropolis exponent: pathological cost spikes (huge
            # |dc| / tiny temp) stay finite instead of overflowing exp.
            acc_p = jnp.exp(jnp.clip(-dc / jnp.maximum(temp, 1e-9),
                                     -60.0, 0.0))
            accept = moved & ((dc < 0.0) | (u < acc_p))
            idx = jnp.where(accept[:, None], nxt, idx)
            costs = jnp.where(accept, nxt_cost, costs)
            better = costs < best_c
            best_i = jnp.where(better[:, None], idx, best_i)
            best_c = jnp.where(better, costs, best_c)
            # Cooling advances even on masked (collision / no-free-device)
            # steps, so the schedule stays consistent across chains and
            # with the host path's skip semantics.
            return (idx, costs, best_i, best_c, temp * cooling), None

        carry0 = (init_idx, costs, init_idx, costs, t0)
        (_, _, best_i, best_c, _), _ = jax.lax.scan(
            body, carry0, (pos, cand, accept_u))
        return best_i, best_c

    if num_shards > 1:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.core.shard import fleet_mesh

        chains_run = shard_map(
            chains_run, mesh=fleet_mesh(num_shards),
            in_specs=(P("fleet", None), P(None), P(None),
                      P(None, "fleet"), P(None, "fleet"), P(None, "fleet"),
                      P(), P(), P(), P(), P(), P()),
            out_specs=(P("fleet", None), P("fleet")),
            check_rep=False)

    def run(*args):
        best_i, best_c = chains_run(*args)
        ci = jnp.argmin(best_c)
        return best_i[ci], best_c[ci]

    return jax.jit(run)


def sa_search(rng: np.random.Generator, times: np.ndarray, counts: np.ndarray,
              available: np.ndarray, n_sel: int, *, alpha: float, beta: float,
              time_scale: float, fairness_scale: float, delta_fairness: bool,
              steps: int, chains: int, t0: float, cooling: float,
              greedy_seed: bool = True,
              avail_idx: Optional[np.ndarray] = None,
              num_shards: int = 1) -> np.ndarray:
    """One fused multi-chain SA decision -> (K,) bool plan.

    ``chains`` plans anneal in parallel for ``steps`` scan iterations
    (``chains * steps`` cost evaluations in ONE jitted call); the best plan
    any chain ever visited is returned. All randomness is pre-drawn from
    ``rng`` on the host, so decisions are reproducible under the
    scheduler's seed and the scan body is PRNG-free. With ``num_shards``
    > 1 the chain axis partitions across host platform devices
    (bitwise-identical result: chains are independent and the noise is
    host-drawn once, regardless of shard count).
    """
    import jax.numpy as jnp

    avail = np.asarray(available, dtype=bool)
    if avail_idx is None:
        avail_idx = np.flatnonzero(avail)
    _check_avail(avail_idx, n_sel)
    init = _init_indices(rng, avail_idx, n_sel, chains)
    if greedy_seed:
        init[0] = _greedy_indices(np.asarray(times), avail_idx, n_sel)
    pos, cand, u = _swap_noise(rng, avail_idx, steps, chains, n_sel)
    fn = _sa_fn(int(steps), int(chains), int(n_sel), bool(delta_fairness),
                _usable_search_shards(num_shards, chains))
    with span("sa_search", chains=int(chains), steps=int(steps)):
        best_idx, _ = fn(jnp.asarray(init), jnp.asarray(times, jnp.float32),
                         jnp.asarray(_center(counts)), jnp.asarray(pos),
                         jnp.asarray(cand), jnp.asarray(u),
                         jnp.float32(alpha), jnp.float32(beta),
                         jnp.float32(time_scale), jnp.float32(fairness_scale),
                         jnp.float32(t0), jnp.float32(cooling))
        plan = plan_from_indices(avail.shape[0], np.asarray(best_idx))
    return plan


# ---- (b) fused genetic algorithm -----------------------------------------


def _ga_children_block(pop, cost, ta, tb, cu_l, mu_l, mpos_l, mcand_l,
                       off, rows, n_sel: int, mutation_rate):
    """Rows ``[off, off + rows)`` of the next GA generation, computed from
    the FULL (P, S) population and (P,) costs but only the LOCAL slices of
    the crossover/mutation noise. The single lane calls this with
    ``off=0, rows=P``; the sharded executor calls it per shard with an even
    block so consecutive parent pairs never straddle shards — either way
    the math below is the same ops in the same order.

    Tournament selection (size 2) runs on the full index arrays (O(P*S),
    cheap) and the block is carved out of the parents; the expensive
    O(rows * S^2) membership matrices only ever see the local block.

    Slot-wise uniform crossover between consecutive parent pairs:
    slot j of a child takes the OTHER parent's j-th device iff
    the coin says swap and that device is a single (absent from
    this parent) — entries adopted from the other parent are
    then distinct from every kept entry, so children stay
    duplicate-free and exactly n_sel-sized with no repair/sort
    step (``lax.top_k`` costs ~1 ms/call on CPU and would
    dominate the loop). Unlike the host GA's bitwise crossover
    + repair, a shared device CAN be dropped when its slot swaps
    to a single — a deliberate trade for the sort-free form; the
    parity gate measures the outcome, not the operator. The two
    children use complementary coins, mirroring the host GA's
    shared crossover mask.
    """
    import jax
    import jax.numpy as jnp

    parents = jnp.where((cost[ta] <= cost[tb])[:, None], pop[ta], pop[tb])
    par_l = jax.lax.dynamic_slice_in_dim(parents, off, rows, 0)
    pairs = rows // 2
    p0, p1 = par_l[0:2 * pairs:2], par_l[1:2 * pairs:2]
    m0 = jnp.any(p0[:, :, None] == p1[:, None, :], axis=-1)
    m1 = jnp.any(p1[:, :, None] == p0[:, None, :], axis=-1)
    swap = cu_l < 0.5
    c0 = jnp.where(swap & ~m1, p1, p0)
    c1 = jnp.where(~swap & ~m0, p0, p1)
    children = jnp.stack([c0, c1], axis=1).reshape(2 * pairs, n_sel)
    if rows != 2 * pairs:  # odd block: last parent passes through
        children = jnp.concatenate([children, par_l[-1:]])
    # Mutation: swap one selected device for one free device.
    swapped, moved = _swap_into(children, mpos_l, mcand_l)
    apply = (mu_l < mutation_rate) & moved
    return jnp.where(apply[:, None], swapped, children)


@functools.lru_cache(maxsize=None)
def _ga_fn(population: int, generations: int, n_sel: int,
           delta_fairness: bool, num_shards: int = 1):
    import jax
    import jax.numpy as jnp

    P = population
    half = P // 2
    S = n_sel
    N = num_shards
    Pb = P // N  # rows this shard owns (P itself when unsharded)

    def run_single(init_idx, times, counts_c, tourn_a, tourn_b, cross_u,
                   mut_u, mut_pos, mut_cand, alpha, beta, ts, fs,
                   mutation_rate):
        def body(carry, xs):
            pop, best_i, best_c = carry
            ta, tb, cu, mu, mpos, mcand = xs
            cost = plan_costs_idx(times, counts_c, pop, alpha, beta, ts,
                                  fs, delta_fairness)
            i = jnp.argmin(cost)
            better = cost[i] < best_c
            best_i = jnp.where(better, pop[i], best_i)
            best_c = jnp.where(better, cost[i], best_c)
            children = _ga_children_block(pop, cost, ta, tb, cu, mu, mpos,
                                          mcand, 0, P, S, mutation_rate)
            # Elitism: the best plan seen so far survives in slot 0.
            children = children.at[0].set(best_i)
            return (children, best_i, best_c), None

        carry0 = (init_idx, init_idx[0], jnp.float32(jnp.inf))
        (pop, best_i, best_c), _ = jax.lax.scan(
            body, carry0,
            (tourn_a, tourn_b, cross_u, mut_u, mut_pos, mut_cand))
        cost = plan_costs_idx(times, counts_c, pop, alpha, beta, ts, fs,
                              delta_fairness)
        i = jnp.argmin(cost)
        better = cost[i] < best_c
        return (jnp.where(better, pop[i], best_i),
                jnp.where(better, cost[i], best_c))

    if N == 1:
        return jax.jit(run_single)

    # Data-parallel sharded executor: each shard scores and breeds its own
    # Pb-row block, with one tiled ``all_gather`` of (population, cost) per
    # generation so tournament selection and elitism see the GLOBAL state —
    # the recombination trajectory is exactly the single lane's. Noise
    # arrays stay replicated; each shard slices its rows (pair noise at
    # off/2 since crossover coins are drawn per PAIR).
    def run_shard(init_idx, times, counts_c, tourn_a, tourn_b, cross_u,
                  mut_u, mut_pos, mut_cand, alpha, beta, ts, fs,
                  mutation_rate):
        sid = jax.lax.axis_index("fleet")
        off = sid * Pb

        def body(carry, xs):
            pop_l, best_i, best_c = carry
            ta, tb, cu, mu, mpos, mcand = xs
            cost_l = plan_costs_idx(times, counts_c, pop_l, alpha, beta,
                                    ts, fs, delta_fairness)
            pop = jax.lax.all_gather(pop_l, "fleet", tiled=True)
            cost = jax.lax.all_gather(cost_l, "fleet", tiled=True)
            i = jnp.argmin(cost)
            better = cost[i] < best_c
            best_i = jnp.where(better, pop[i], best_i)
            best_c = jnp.where(better, cost[i], best_c)
            cu_l = jax.lax.dynamic_slice_in_dim(cu, sid * (Pb // 2),
                                                Pb // 2, 0)
            mu_l = jax.lax.dynamic_slice_in_dim(mu, off, Pb, 0)
            mpos_l = jax.lax.dynamic_slice_in_dim(mpos, off, Pb, 0)
            mcand_l = jax.lax.dynamic_slice_in_dim(mcand, off, Pb, 0)
            children = _ga_children_block(pop, cost, ta, tb, cu_l, mu_l,
                                          mpos_l, mcand_l, off, Pb, S,
                                          mutation_rate)
            # Elitism lives in GLOBAL slot 0, i.e. shard 0's local slot 0.
            children = children.at[0].set(
                jnp.where(sid == 0, best_i, children[0]))
            return (children, best_i, best_c), None

        carry0 = (init_idx, init_idx[0], jnp.float32(jnp.inf))
        (pop_l, best_i, best_c), _ = jax.lax.scan(
            body, carry0,
            (tourn_a, tourn_b, cross_u, mut_u, mut_pos, mut_cand))
        cost_l = plan_costs_idx(times, counts_c, pop_l, alpha, beta, ts,
                                fs, delta_fairness)
        pop = jax.lax.all_gather(pop_l, "fleet", tiled=True)
        cost = jax.lax.all_gather(cost_l, "fleet", tiled=True)
        i = jnp.argmin(cost)
        better = cost[i] < best_c
        return (jnp.where(better, pop[i], best_i)[None],
                jnp.where(better, cost[i], best_c)[None])

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as Psp

    from repro.core.shard import fleet_mesh

    rep = Psp()
    sharded = shard_map(
        run_shard, mesh=fleet_mesh(N),
        in_specs=(Psp("fleet", None), rep, rep, rep, rep, rep,
                  rep, rep, rep, rep, rep, rep, rep, rep),
        out_specs=(Psp("fleet", None), Psp("fleet")),
        check_rep=False)

    def run(*args):
        best_i, best_c = sharded(*args)
        # Every shard returns the same global best; row 0 is canonical.
        return best_i[0], best_c[0]

    return jax.jit(run)


def ga_search(rng: np.random.Generator, times: np.ndarray, counts: np.ndarray,
              available: np.ndarray, n_sel: int, *, alpha: float, beta: float,
              time_scale: float, fairness_scale: float, delta_fairness: bool,
              population: int, generations: int, mutation_rate: float,
              greedy_seed: bool = True,
              avail_idx: Optional[np.ndarray] = None,
              num_shards: int = 1) -> np.ndarray:
    """One fused GA decision -> (K,) bool plan (all generations in ONE
    jitted ``lax.scan`` call; index-form population, pre-drawn noise).
    With ``num_shards`` > 1 the population breeds data-parallel across
    host platform devices (same trajectory: per-generation all_gather
    keeps selection/elitism global, noise is host-drawn once)."""
    import jax.numpy as jnp

    avail = np.asarray(available, dtype=bool)
    if avail_idx is None:
        avail_idx = np.flatnonzero(avail)
    _check_avail(avail_idx, n_sel)
    P, G = population, generations
    init = _init_indices(rng, avail_idx, n_sel, P)
    if greedy_seed:
        init[0] = _greedy_indices(np.asarray(times), avail_idx, n_sel)
    tourn = rng.integers(0, P, (2, G, P)).astype(np.int32)
    half = P // 2
    cross_u = rng.random((G, half, n_sel)).astype(np.float32)
    mut_u = rng.random((G, P)).astype(np.float32)
    mut_pos, mut_cand, _ = _swap_noise(rng, avail_idx, G, P, n_sel)
    fn = _ga_fn(int(P), int(G), int(n_sel), bool(delta_fairness),
                _usable_search_shards(num_shards, P, pairs=True))
    with span("ga_search", population=int(P), generations=int(G)):
        best_idx, _ = fn(jnp.asarray(init), jnp.asarray(times, jnp.float32),
                         jnp.asarray(_center(counts)), jnp.asarray(tourn[0]),
                         jnp.asarray(tourn[1]), jnp.asarray(cross_u),
                         jnp.asarray(mut_u),
                         jnp.asarray(mut_pos), jnp.asarray(mut_cand),
                         jnp.float32(alpha), jnp.float32(beta),
                         jnp.float32(time_scale), jnp.float32(fairness_scale),
                         jnp.float32(mutation_rate))
        plan = plan_from_indices(avail.shape[0], np.asarray(best_idx))
    return plan


# ---- (c) batched BODS acquisition ----------------------------------------


def _matern52(sq):
    import jax.numpy as jnp

    r = jnp.sqrt(jnp.maximum(sq, 1e-12))
    return (1.0 + jnp.sqrt(5.0) * r + 5.0 * sq / 3.0) * jnp.exp(-jnp.sqrt(5.0) * r)


def gp_fit(F, resid, valid, noise):
    """Masked Matern-5/2 GP fit over the observation ring: returns the
    Cholesky factor, the dual weights ``K_nn^-1 (resid * m)``, and the
    float mask ``m``. Split out of ``ei_scores`` so the sharded BODS
    acquisition can fit ONCE per shard and score only its local candidate
    block against it."""
    import jax
    import jax.numpy as jnp

    m = valid.astype(jnp.float32)
    mm = m[:, None] * m[None, :]
    d_nn = jnp.sum((F[:, None, :] - F[None, :, :]) ** 2, -1)
    K_nn = _matern52(d_nn) * mm + (1.0 - mm) * jnp.eye(F.shape[0])
    K_nn = K_nn + (noise + 1e-6) * jnp.eye(F.shape[0])
    chol = jnp.linalg.cholesky(K_nn)
    w = jax.scipy.linalg.cho_solve((chol, True), resid * m)
    return chol, w, m


def gp_posterior(chol, w, m, F, cand_feats, cand_est):
    """Posterior (mean, stddev) of a candidate block under a ``gp_fit``
    model; the prior mean enters through ``cand_est``."""
    import jax
    import jax.numpy as jnp

    d_nc = jnp.sum((F[:, None, :] - cand_feats[None, :, :]) ** 2, -1)
    K_nc = _matern52(d_nc) * m[:, None]
    mu_c = cand_est + K_nc.T @ w              # posterior mean, candidates
    v = jax.scipy.linalg.solve_triangular(chol, K_nc, lower=True)
    var = jnp.maximum(1.0 - jnp.sum(v * v, axis=0), 1e-9)
    return mu_c, jnp.sqrt(var)


def ei_from_posterior(mu_c, sigma, best):
    """Expected Improvement of each candidate against incumbent ``best``
    (a plugin incumbent: pass ``jnp.min(mu_c)`` — or, sharded, the pmin
    over every shard's ``mu_c`` so all shards improve against the same
    global incumbent)."""
    import jax

    z = (best - mu_c) / sigma
    cdf = jax.scipy.stats.norm.cdf(z)
    pdf = jax.scipy.stats.norm.pdf(z)
    return (best - mu_c) * cdf + sigma * pdf


def ei_scores(F, resid, valid, cand_feats, cand_est, noise):
    """Expected Improvement under the masked Matern-5/2 GP posterior.

    Traced core shared by the host BODS scheduler (which jits it directly),
    the fused acquisition below (which inlines it into one decision graph),
    and ``ei_scores_jobs`` (which vmaps it over the job axis). Composed
    from ``gp_fit`` / ``gp_posterior`` / ``ei_from_posterior`` above (the
    sharded acquisition uses the pieces directly). See
    ``schedulers/bods.py`` for the modelling rationale (residual GP over a
    low-dimensional feature map, plugin incumbent within the round; the
    prior mean enters through ``cand_est``, so the observations' own
    estimates never appear here).

    F: (L, d) observed features; resid: (L,) realized-estimated residuals
    (normalized); valid: (L,) ring mask; cand_feats: (P, d);
    cand_est: (P,) estimated candidate costs (same normalization as
    ``resid``). Returns (P,) EI (higher = better).
    """
    import jax.numpy as jnp

    chol, w, m = gp_fit(F, resid, valid, noise)
    mu_c, sigma = gp_posterior(chol, w, m, F, cand_feats, cand_est)
    # WITHIN-ROUND plugin incumbent (see bods.py): the best posterior-mean
    # candidate of THIS round; EI arbitrates exploitation vs exploration
    # among the current feasible set.
    return ei_from_posterior(mu_c, sigma, jnp.min(mu_c))


@functools.lru_cache(maxsize=None)
def _ei_scores_jobs_fn():
    import jax

    return jax.jit(jax.vmap(ei_scores, in_axes=(0, 0, 0, 0, 0, None)))


def ei_scores_jobs(F, resid, valid, cand_feats, cand_est, noise):
    """EI for ALL M jobs in one call: every argument except ``noise`` gains
    a leading (M,) axis (each job's observation ring + candidate set); the
    Matern-GP posterior is vmapped over jobs instead of looped in Python.
    Returns (M, P) EI scores."""
    import jax.numpy as jnp

    return _ei_scores_jobs_fn()(
        jnp.asarray(F), jnp.asarray(resid),
        jnp.asarray(valid), jnp.asarray(cand_feats), jnp.asarray(cand_est),
        jnp.asarray(noise, jnp.float32))


def _norm01_traced(x, mask):
    """Traced twin of ``bods._norm01``: [0, 1]-normalize by the spread over
    ``mask``; a flat (or empty) reference set yields all-zeros, never NaN."""
    import jax.numpy as jnp

    lo = jnp.min(jnp.where(mask, x, jnp.inf))
    hi = jnp.max(jnp.where(mask, x, -jnp.inf))
    spread = hi - lo
    ok = jnp.isfinite(spread) & (spread >= 1e-9)
    safe = jnp.where(ok, spread, 1.0)
    return jnp.where(ok, jnp.clip((x - lo) / safe, 0.0, 1.0), 0.0)


def featurize_plans(times, counts_c, counts_zero, mu, plans, ts, fs,
                    n_sel: int, delta_fairness: bool):
    """Traced phi(V): (P, K) plans -> (P, 6) features, formula-for-formula
    the host ``BODSScheduler._featurize`` (est round time, fairness
    increment, mean selected time, capability-jitter exposure, novelty,
    occupancy — all O(1)-normalized). Also returns the normalized time and
    fairness terms so the caller can assemble Formula-2 estimates without
    a second pass."""
    import jax.numpy as jnp

    K = plans.shape[1]
    sel_t = jnp.where(plans, times, 0.0)
    t, n, wsum = _dense_stats(times, counts_c, plans)
    est_time = t / ts
    dfair = _fairness_from_stats(counts_c, n, wsum, delta_fairness) / fs
    nn = jnp.maximum(n, 1.0)
    mean_t = jnp.sum(sel_t, axis=1) / nn / ts
    jitter = jnp.max(
        jnp.where(plans, times / jnp.maximum(mu, 1e-9), 0.0), axis=1) / ts
    novelty = jnp.sum(plans & counts_zero[None, :], axis=1) / max(n_sel, 1)
    occupancy = n / float(K)
    feats = jnp.stack([est_time, dfair, mean_t, jitter, novelty, occupancy],
                      axis=1).astype(jnp.float32)
    return feats, est_time, dfair


@functools.lru_cache(maxsize=None)
def _bods_fn(num_candidates: int, n_mut: int, n_sel: int,
             delta_fairness: bool, local_search: bool, num_shards: int = 1):
    import jax
    import jax.numpy as jnp

    P = num_candidates
    n_rand = P // 4
    N = num_shards
    Pb = P // N  # candidates this shard owns (P itself when unsharded)

    def gen_candidates(seed, ids, times, counts_c, avail, mutants, use_base):
        """(B,) global candidate ids -> (B, K) bool plans. PRNG is PER
        CANDIDATE (``fold_in`` of the decision seed by candidate id), so the
        candidate set is a pure function of (seed, id) — invariant to how
        the candidate axis is partitioned across shards. Threefry keys on
        purpose: the fast ``rbg`` impl draws DIFFERENT bits for the same
        key under different vmap batch sizes, which would make the
        candidate set depend on the shard count. Layout matches the host
        path: ids [0, n_rand) uniform Gumbel top-k, the rest structured
        (availability-logit) Gumbel top-k, and when local search is armed
        ids [0, n_mut) become repaired mutants of the incumbent."""
        K = times.shape[0]
        t_norm = _norm01_traced(times, avail)
        c_norm = _norm01_traced(counts_c, jnp.ones(K, bool))
        base_key = jax.random.key(seed)

        def one(cid):
            k = jax.random.fold_in(base_key, cid)
            kg, kw1, kw2, kr = jax.random.split(k, 4)
            w_time = jax.random.uniform(kw1, (), minval=0.0, maxval=6.0)
            w_fair = jax.random.uniform(kw2, (), minval=0.0, maxval=4.0)
            logits = jnp.where(cid >= n_rand,
                               -w_time * t_norm - w_fair * c_norm, 0.0)
            g = jnp.where(avail, logits + jax.random.gumbel(kg, (K,)),
                          -jnp.inf)
            _, ti = jax.lax.top_k(g, n_sel)
            plan = jnp.zeros((K,), bool).at[ti].set(True) & avail
            if local_search:
                # Row-wise twin of ``repair_plans_jax`` on this candidate's
                # mutant of the best observed plan.
                mut = mutants[jnp.minimum(cid, n_mut - 1)]
                rk = jnp.where(avail, (mut & avail) +
                               jax.random.uniform(kr, (K,)), -jnp.inf)
                _, ri = jax.lax.top_k(rk, n_sel)
                rplan = jnp.zeros((K,), bool).at[ri].set(True) & avail
                plan = jnp.where(use_base & (cid < n_mut), rplan, plan)
            return plan

        return jax.vmap(one)(ids)

    def block(seed, ids, times, counts_c, counts_zero, avail, mu, mutants,
              use_base, F, resid, valid, inv_sd, alpha, beta, ts, fs,
              noise):
        """One candidate block end-to-end: generation, featurization, GP
        posterior. Returns (plans, est cost, posterior mean, stddev)."""
        cands = gen_candidates(seed, ids, times, counts_c, avail, mutants,
                               use_base)
        feats, est_time, dfair = featurize_plans(
            times, counts_c, counts_zero, mu, cands, ts, fs, n_sel,
            delta_fairness)
        cand_est = alpha * est_time + beta * dfair
        chol, w, m = gp_fit(F, resid, valid, noise)
        mu_c, sigma = gp_posterior(chol, w, m, F, feats, cand_est * inv_sd)
        return cands, cand_est, mu_c, sigma

    if N == 1:
        def run(seed, times, counts_c, counts_zero, avail, mu, mutants,
                use_base, F, resid, valid, inv_sd, alpha, beta, ts, fs,
                noise):
            ids = jnp.arange(P, dtype=jnp.int32)
            cands, cand_est, mu_c, sigma = block(
                seed, ids, times, counts_c, counts_zero, avail, mu, mutants,
                use_base, F, resid, valid, inv_sd, alpha, beta, ts, fs,
                noise)
            ei = ei_from_posterior(mu_c, sigma, jnp.min(mu_c))
            choice = jnp.argmax(ei)
            return cands[choice], cand_est[choice]

        return jax.jit(run)

    # Candidate-axis sharding: each shard generates/featurizes/scores its
    # own Pb candidates (the per-candidate PRNG keeps the candidate SET
    # identical to the single lane), the plugin incumbent is the pmin of
    # posterior means across shards, and each shard emits its local EI
    # winner for a tiny host-side final argmax.
    def run_shard(seed, times, counts_c, counts_zero, avail, mu, mutants,
                  use_base, F, resid, valid, inv_sd, alpha, beta, ts, fs,
                  noise):
        sid = jax.lax.axis_index("fleet")
        ids = sid * Pb + jnp.arange(Pb, dtype=jnp.int32)
        cands, cand_est, mu_c, sigma = block(
            seed, ids, times, counts_c, counts_zero, avail, mu, mutants,
            use_base, F, resid, valid, inv_sd, alpha, beta, ts, fs, noise)
        best = jax.lax.pmin(jnp.min(mu_c), "fleet")
        ei = ei_from_posterior(mu_c, sigma, best)
        c = jnp.argmax(ei)
        return cands[c][None], cand_est[c][None], ei[c][None], ids[c][None]

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as Psp

    from repro.core.shard import fleet_mesh

    rep = Psp()
    sharded = shard_map(
        run_shard, mesh=fleet_mesh(N), in_specs=(rep,) * 17,
        out_specs=(Psp("fleet", None), Psp("fleet"), Psp("fleet"),
                   Psp("fleet")),
        check_rep=False)

    def run(*args):
        plans, ests, eis, gids = sharded(*args)
        # Max EI wins; ties break to the LOWEST global candidate id,
        # matching the single lane's first-argmax semantics.
        order = jnp.where(eis == jnp.max(eis), gids,
                          jnp.iinfo(jnp.int32).max)
        wi = jnp.argmin(order)
        return plans[wi], ests[wi]

    return jax.jit(run)


def _mutate_plan_host(rng: np.random.Generator, base: np.ndarray,
                      n_mut: int) -> np.ndarray:
    """Host twin of the BODS local-search proposal: n_mut copies of
    ``base``, each with 1-3 selected-for-unselected swaps (identical to the
    host scheduler's mutation loop; availability is restored in-graph by
    the vectorized repair)."""
    K = base.shape[0]
    mutants = np.broadcast_to(base, (n_mut, K)).copy()
    for i in range(n_mut):
        flips = rng.integers(1, 4)
        on, off = np.flatnonzero(mutants[i]), np.flatnonzero(~mutants[i])
        for _ in range(flips):
            if on.size and off.size:
                mutants[i][rng.choice(on)] = False
                mutants[i][rng.choice(off)] = True
    return mutants


def bods_acquire(rng: np.random.Generator, times: np.ndarray,
                 counts: np.ndarray, available: np.ndarray, mu: np.ndarray,
                 n_sel: int, *, F: np.ndarray, y: np.ndarray,
                 est: np.ndarray, valid: np.ndarray,
                 base_plan: Optional[np.ndarray], alpha: float, beta: float,
                 time_scale: float, fairness_scale: float,
                 delta_fairness: bool, num_candidates: int, n_mut: int,
                 local_search: bool, gp_noise: float,
                 avail_idx: Optional[np.ndarray] = None,
                 num_shards: int = 1) -> Tuple[np.ndarray, float]:
    """One fused BODS decision: (chosen (K,) bool plan, its estimated cost).

    Candidate generation, featurization, GP posterior and EI argmax run in
    one jitted call; only the observation-ring slicing, the residual
    normalization and the tiny local-search mutant loop stay on the host.
    The in-graph Gumbel draws use PER-CANDIDATE threefry keys folded from
    one decision seed (the (P, K) noise block is the one unavoidable
    K-wide draw in this module), so with ``num_shards`` > 1 the candidate
    axis partitions across host platform devices without changing the
    candidate set.
    """
    import jax.numpy as jnp

    avail = np.asarray(available, dtype=bool)
    if avail_idx is None:
        avail_idx = np.flatnonzero(avail)
    _check_avail(avail_idx, n_sel)
    sd = float(y[valid > 0].std()) + 1e-6 if valid.sum() else 1.0
    use_base = base_plan is not None and local_search
    if use_base:
        mutants = _mutate_plan_host(rng, np.asarray(base_plan, dtype=bool),
                                    n_mut)
    else:
        mutants = np.zeros((n_mut, avail.shape[0]), dtype=bool)
    fn = _bods_fn(int(num_candidates), int(n_mut), int(n_sel),
                  bool(delta_fairness), bool(local_search),
                  _usable_search_shards(num_shards, num_candidates))
    seed = jnp.uint32(int(rng.integers(0, 2**31 - 1)))
    with span("bods_acquire", candidates=int(num_candidates),
              mutants=int(n_mut)):
        plan, cand_est = fn(
            seed, jnp.asarray(times, jnp.float32),
            jnp.asarray(_center(counts)),
            jnp.asarray(np.asarray(counts) == 0), jnp.asarray(avail),
            jnp.asarray(mu, jnp.float32), jnp.asarray(mutants),
            jnp.asarray(bool(use_base)), jnp.asarray(F),
            jnp.asarray((y - est) / sd * valid, jnp.float32),
            jnp.asarray(valid, jnp.float32), jnp.float32(1.0 / sd),
            jnp.float32(alpha), jnp.float32(beta), jnp.float32(time_scale),
            jnp.float32(fairness_scale), jnp.float32(gp_noise))
        out = np.asarray(plan), float(cand_est)
    return out
