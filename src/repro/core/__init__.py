"""The paper's primary contribution: multi-job FL device scheduling.

- ``devices``  — heterogeneous device pool with shifted-exponential time model (Formula 4)
- ``cost``     — time + data-fairness cost model (Formulas 2, 3, 5, 8)
- ``plans``    — scheduling-plan representation and invariants
- ``scoring``  — batched plan scoring (numpy/jax/pallas, one path under all)
- ``search``   — fused on-device search loops (jitted multi-chain SA, GA,
  BODS acquisition) behind the schedulers' ``search_backend`` knob
- ``schedulers`` — BODS (GP+EI), RLDS (LSTM+REINFORCE), Random, FedCS, Greedy,
  Genetic, SimulatedAnnealing
- ``multijob`` — event-driven parallel multi-job engine (Fig. 1 process)
- ``loss_estimation`` — round-budget estimation (Formula 13)
"""

from repro.core.cost import CostModel
from repro.core.devices import DevicePool
from repro.core.multijob import MultiJobEngine, RoundRecord
from repro.core.schedulers import get_scheduler, list_schedulers

__all__ = [
    "CostModel",
    "DevicePool",
    "MultiJobEngine",
    "RoundRecord",
    "get_scheduler",
    "list_schedulers",
]
