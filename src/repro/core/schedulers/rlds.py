"""RLDS — Reinforcement Learning-based Device Scheduling (paper Algorithm 2).

Architecture (paper Fig. 2): an LSTM over the device sequence followed by a
fully-connected head emits a per-device scheduling probability; an ε-greedy
policy converter turns probabilities into a plan of exactly n_sel devices.
Training is REINFORCE (paper Formula 12) with an EMA baseline b_m per job:

    θ' = θ + η/N Σ_n Σ_k ∇ log P(S_k | S_{k-1:1}; θ) (R_n - b_m)

with reward R = -TotalCost. The policy is shared across jobs ("learns the
sharing relationship of devices among diverse jobs"); per-device features:
[a_k, μ_k, E[t_k] (job-specific), fairness count s_{k,m}, availability,
D_k^m]. Pre-training (paper Algorithm 3) runs LAZILY at the first
``schedule()`` call against the estimated cost model with N plans per
synthetic round — or not at all when a gym-trained policy is warm-started
via ``load_state_dict`` / the ExperimentSpec ``policy`` axis (the scalable
replacement: ``repro.gym.train`` runs batched REINFORCE over vectorized
environments instead of this sequential Python loop).

All policy math is jitted JAX; the LSTM is a lax.scan over the K devices.
All randomness that feeds JAX code is threaded through explicit
``jax.random`` keys (``init_policy``); the numpy Generator is only used for
the host-side ε-greedy/plan-repair sampling.
"""

from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plans import gumbel_topk_plans, repair_plan
from repro.core.schedulers.base import SchedulerBase, SchedulingContext
from repro.experiment.registry import register_scheduler
from repro.optim import adamw

NUM_FEATURES = 6
HIDDEN = 64


def policy_optimizer(lr: float):
    """The RLDS policy optimizer — ONE definition shared by the live
    scheduler, the gym trainer, and the policy zoo's warm-start wrapper,
    so saved optimizer moments always match the online settings. (Named
    distinctly from ``repro.optim.make_optimizer``, which takes an
    ``OptimizerConfig``.)"""
    return adamw(lr, 0.9, 0.999, 1e-8, 0.0)


def init_policy(key: jax.Array) -> Dict[str, jnp.ndarray]:
    """Glorot-init policy params from an explicit ``jax.random`` key.

    The one PRNG entry point shared by the constructor (which derives its
    key from ``seed``) and the gym trainer's fully key-threaded path.
    """
    ks = jax.random.split(key, 3)

    def glorot(k, shape):
        fan = sum(shape)
        return jax.random.normal(k, shape, jnp.float32) * np.sqrt(2.0 / fan)

    return {
        "wi": glorot(ks[0], (NUM_FEATURES, 4 * HIDDEN)),   # input -> gates
        "wh": glorot(ks[1], (HIDDEN, 4 * HIDDEN)),          # hidden -> gates
        "b": jnp.zeros((4 * HIDDEN,), jnp.float32),
        "w_out": glorot(ks[2], (HIDDEN, 1)),
        "b_out": jnp.zeros((1,), jnp.float32),
    }


def _policy_logits(params, feats):
    """feats: (K, F) -> logits (K,). LSTM scan over the device sequence.

    The input projection has no recurrent dependency, so it is hoisted out
    of the scan as one (K, F) @ (F, 4H) matmul — inside the scan only the
    hidden-to-gates matvec remains (matters for gym rollout throughput,
    where this scan is the inner loop of E*T vectorized policy calls).
    """
    xw = feats @ params["wi"] + params["b"]      # (K, 4H), scan-invariant

    def cell(carry, xw_t):
        h, c = carry
        gates = xw_t + h @ params["wh"]
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    h0 = jnp.zeros((HIDDEN,), jnp.float32)
    (_, _), hs = jax.lax.scan(cell, (h0, h0), xw)
    return (hs @ params["w_out"] + params["b_out"])[:, 0]


def _logprob(params, feats, plan, available):
    """Paper Formula 12: Σ_{k ∈ V} log P(S_k | S_{k-1:1}; θ) — the sum runs over
    the SELECTED devices only (not the Bernoulli complement: with n_sel << K the
    ~K unselected terms would swamp the selected ones and collapse the policy)."""
    logits = _policy_logits(params, feats)
    logp = jax.nn.log_sigmoid(logits)
    return jnp.sum(jnp.where(plan > 0, logp, 0.0) * available)


@jax.jit
def _reinforce_grads(params, feats_batch, plans_batch, avail_batch, advantages):
    """Mean REINFORCE gradient over N (plan, advantage) samples.

    A small logit L2 keeps the policy away from saturation: REINFORCE's
    per-plan gradient magnitude Σ_k (1 - p_k) correlates with the plan's
    exploration content (and hence its reward), which otherwise drifts all
    logits downward until the sigmoid saturates.
    """

    def loss(p):
        lps = jax.vmap(lambda f, pl, av: _logprob(p, f, pl, av))(
            feats_batch, plans_batch, avail_batch)
        logits = jax.vmap(lambda f: _policy_logits(p, f))(feats_batch)
        return -jnp.mean(lps * advantages) + 1e-2 * jnp.mean(jnp.square(logits))

    return jax.grad(loss)(params)


@jax.jit
def _probs(params, feats):
    return jax.nn.sigmoid(_policy_logits(params, feats))


@register_scheduler("rlds")
class RLDSScheduler(SchedulerBase):
    name = "rlds"

    def __init__(self, cost_model, seed: int = 0, lr: float = 1e-2,
                 epsilon: float = 0.1, gamma: float = 0.1,
                 pretrain_rounds: int = 300, pretrain_plans: int = 8,
                 search_backend: str = "fused"):
        # search_backend accepted (and ignored) for a uniform scheduler
        # constructor contract: RLDS's policy sampling is already jitted.
        super().__init__(cost_model, seed, search_backend=search_backend)
        self.epsilon = epsilon
        self.gamma = gamma  # EMA factor for the baseline b_m (paper Line 7)
        self.params = init_policy(jax.random.PRNGKey(seed))
        self._opt_init, self._opt_update = policy_optimizer(lr)
        self.opt_state = self._opt_init(self.params)
        # Baselines b_m start unset; the first observed reward initializes them
        # (a zero init against rewards ≈ -cost << 0 yields huge early advantages).
        self.baselines = np.full(cost_model.pool.num_jobs, np.nan)
        self._adv_scale = 1.0  # running |advantage| normalizer
        # Pre-training is LAZY: construction is O(1); the Algorithm-3 loop
        # runs at the first schedule() unless a warm start arrives first
        # (load_state_dict) or pretrain_rounds == 0.
        self._pretrain_cfg = (pretrain_rounds, pretrain_plans)
        self._pretrained = pretrain_rounds <= 0

    # ---- persistence (policy zoo) ----

    def state_dict(self) -> Dict:
        """Full learner state as a checkpointable pytree (bit-exact restore
        via ``repro.gym.zoo.PolicyZoo``)."""
        return {
            "params": self.params,
            "opt": self.opt_state,
            "baselines": np.asarray(self.baselines, np.float64),
            "adv_scale": np.asarray(self._adv_scale, np.float64),
            "pretrained": np.asarray(self._pretrained),
        }

    def load_state_dict(self, tree: Dict) -> None:
        """Warm-start from a saved/gym-trained state. The pretrained flag
        rides in the state: a trained snapshot skips the lazy Algorithm-3
        loop entirely, while a snapshot taken BEFORE any training (fresh
        constructor state) still pre-trains at first schedule()."""
        params = jax.tree_util.tree_map(jnp.asarray, tree["params"])
        saved = jax.tree_util.tree_map(lambda p: p.shape, params)
        own = jax.tree_util.tree_map(lambda p: p.shape, self.params)
        if saved != own:
            raise ValueError(
                f"RLDS policy shapes {saved} do not match this build's "
                f"{own} (NUM_FEATURES/HIDDEN mismatch)")
        self.params = params
        self.opt_state = tree["opt"]
        baselines = np.asarray(tree["baselines"], np.float64)
        # Policies are portable across job mixes: a baseline vector saved
        # for a different M resets to unset (first reward re-initializes).
        M = self.cost_model.pool.num_jobs
        self.baselines = baselines if baselines.shape == (M,) else np.full(M, np.nan)
        self._adv_scale = float(np.asarray(tree["adv_scale"]))
        self._pretrained = bool(np.asarray(tree["pretrained"]))

    # ---- dynamic job set (scheduler service) ----

    def ensure_jobs(self, num_jobs: int) -> None:
        """Grow the per-job baseline vector (policy params are shared across
        jobs, so a newly admitted job only needs a fresh unset baseline)."""
        if num_jobs > self.baselines.shape[0]:
            pad = np.full(num_jobs - self.baselines.shape[0], np.nan)
            self.baselines = np.concatenate([self.baselines, pad])

    def job_state_dict(self, job: int) -> dict:
        return {"baseline": float(self.baselines[job])}

    def load_job_state(self, job: int, tree: dict) -> None:
        self.baselines[job] = float(tree["baseline"])

    # ---- features ----

    def _features(self, ctx: SchedulingContext) -> np.ndarray:
        pool = self.cost_model.pool
        t = ctx.expected_times
        f = np.stack([
            pool.a / pool.a.max(),
            pool.mu / pool.mu.max(),
            t / (t.max() + 1e-12),
            ctx.counts / (ctx.counts.max() + 1.0),
            ctx.available.astype(np.float64),
            pool.data_sizes[:, ctx.job] / pool.data_sizes.max(),
        ], axis=1)
        return f.astype(np.float32)

    # ---- policy converter (ε-greedy) ----

    def _convert(self, probs: np.ndarray, ctx: SchedulingContext,
                 explore: bool) -> np.ndarray:
        """ε-greedy policy converter (paper Fig. 2).

        explore=True samples the plan from the policy itself via Gumbel top-k
        over the logits (Plackett-Luce without replacement) — proper on-policy
        visitation that cannot lock onto a sticky top-k set — then applies the
        ε-greedy random swap on top. explore=False is the deterministic top-k.
        """
        K = ctx.available.shape[0]
        logits = np.log(np.clip(probs, 1e-9, 1 - 1e-9)) - np.log(
            np.clip(1 - probs, 1e-9, 1.0))
        if explore:
            # Shared vectorized Gumbel top-k primitive (plans.py).
            plan = gumbel_topk_plans(self.rng, logits, ctx.available,
                                     ctx.n_sel)[0]
        else:
            score = np.where(ctx.available, logits, -np.inf)
            plan = np.zeros(K, dtype=bool)
            plan[np.argsort(-score, kind="stable")[: ctx.n_sel]] = True
        if explore:
            free = np.flatnonzero(ctx.available & ~plan)
            on = np.flatnonzero(plan)
            for k in on:
                if free.size and self.rng.random() < self.epsilon:
                    swap = self.rng.choice(free)
                    plan[k] = False
                    plan[swap] = True
                    free = np.flatnonzero(ctx.available & ~plan)
        return repair_plan(self.rng, plan, ctx.available, ctx.n_sel)

    # ---- Algorithm 2 ----

    def schedule(self, ctx: SchedulingContext) -> np.ndarray:
        if not self._pretrained:
            # Flag set only after _pretrain RETURNS: an exception mid-loop
            # (caller catches and retries) must not skip pre-training.
            self._pretrain(*self._pretrain_cfg)
            self._pretrained = True
        feats = self._features(ctx)
        probs = np.asarray(_probs(self.params, jnp.asarray(feats)))
        # Annealed ε-greedy: exploration is front-loaded; late-round random
        # swaps only slow convergence once the policy has settled.
        eps_now = self.epsilon / (1.0 + ctx.round_idx / 50.0)
        old_eps, self.epsilon = self.epsilon, eps_now
        plan = self._convert(probs, ctx, explore=True)
        self.epsilon = old_eps
        self._last_feats = feats
        return self._score_plan(ctx, plan)

    def observe(self, ctx: SchedulingContext, plan: np.ndarray, realized_cost: float) -> None:
        reward = -realized_cost
        if np.isnan(self.baselines[ctx.job]):
            self.baselines[ctx.job] = reward
        adv = self._norm_adv(reward - self.baselines[ctx.job])
        self._update(
            feats=self._last_feats[None],
            plans=plan[None].astype(np.float32),
            avail=ctx.available[None].astype(np.float32),
            advantages=np.array([adv], np.float32),
        )
        self.baselines[ctx.job] = (
            (1 - self.gamma) * self.baselines[ctx.job] + self.gamma * reward)

    def _norm_adv(self, adv):
        """Running-scale advantage normalization (variance control, Formula 12's
        b_m does the centering; this bounds the magnitude)."""
        a = np.asarray(adv, np.float64)
        self._adv_scale = 0.95 * self._adv_scale + 0.05 * float(np.mean(np.abs(a)) + 1e-8)
        return a / max(self._adv_scale, 1e-6)

    def _update(self, feats, plans, avail, advantages):
        grads = _reinforce_grads(
            self.params, jnp.asarray(feats), jnp.asarray(plans),
            jnp.asarray(avail), jnp.asarray(advantages))
        updates, self.opt_state = self._opt_update(grads, self.opt_state, self.params)
        self.params = jax.tree_util.tree_map(lambda p, u: p + u, self.params, updates)

    # ---- Algorithm 3: pre-training against the estimated cost model ----

    def _pretrain(self, rounds: int, n_plans: int) -> None:
        pool = self.cost_model.pool
        K, M = pool.num_devices, pool.num_jobs
        counts = np.zeros((M, K))
        n_sel = max(1, K // 10)
        for r in range(rounds):
            m = r % M
            tau = 5.0
            ctx = SchedulingContext(
                job=m, round_idx=r, tau=tau, n_sel=n_sel,
                available=np.ones(K, dtype=bool), counts=counts[m],
                expected_times=pool.expected_times(m, tau))
            feats = self._features(ctx)
            probs = np.asarray(_probs(self.params, jnp.asarray(feats)))
            plans = np.stack([self._convert(probs, ctx, explore=True)
                              for _ in range(n_plans)])
            costs = self._own_cost_of(ctx, plans)
            rewards = -costs
            if np.isnan(self.baselines[m]):
                self.baselines[m] = float(rewards.mean())
            # Batch standardization (on top of the EMA baseline): kills the
            # reward/gradient-magnitude correlation that collapses the policy.
            adv = rewards - rewards.mean()
            adv = adv / (adv.std() + 1e-8)
            self._update(
                feats=np.repeat(feats[None], n_plans, 0),
                plans=plans.astype(np.float32),
                avail=np.repeat(ctx.available[None].astype(np.float32), n_plans, 0),
                advantages=adv.astype(np.float32),
            )
            self.baselines[m] = ((1 - self.gamma) * self.baselines[m]
                                 + self.gamma * float(rewards.mean()))
            best = plans[int(np.argmin(costs))]
            counts[m] += best
