"""BODS — Bayesian Optimization-based Device Scheduling (paper Algorithm 1).

A Gaussian Process with a Matérn-5/2 kernel models the REALIZED TotalCost of
scheduling plans; each round candidates are sampled from the available set,
scored with Expected Improvement (paper Formula 15) against the best observed
cost, and the argmax is scheduled. ``observe()`` feeds the realized cost back
as a new observation point (Algorithm 1 lines 5-7).

Two engineering choices on top of the paper's sketch (both standard BO
practice; the GP/EI machinery is unchanged):

1. **Plan featurization.** The kernel acts on a low-dimensional feature map
   φ(V) = [estimated round time, fairness increment, mean/max expected time
   of selected, capability-jitter exposure, novelty] rather than the raw
   100-bit indicator vector. A stationary kernel on raw bits cannot express
   the "max over selected devices" structure of Formula 3, so its sample
   efficiency is hopeless in C(K, n_sel) space; on φ the GP learns the
   realized-vs-estimated correction (e.g. the straggler tail of
   max-of-exponentials) within tens of observations. φ uses exactly the
   information the scheduler already holds (the paper's cost ingredients).
2. **Stratified candidate sampling** (Gumbel top-k with random time/fairness
   bias weights) so the proposal distribution actually contains low-cost
   plans; EI still arbitrates.

The GP observation buffer is FIXED-SIZE (ring, MAX_OBS) with a validity mask
so the jitted posterior never recompiles as observations accumulate: masked
slots contribute identity Gram rows and zero cross-covariance — exact no-ops
in the posterior algebra.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import search
from repro.core.plans import gumbel_topk_plans, random_plans, repair_plans
from repro.core.schedulers.base import SchedulerBase, SchedulingContext
from repro.experiment.registry import register_scheduler

MAX_OBS = 256
NUM_FEATURES = 6


def _norm01(x: np.ndarray, mask: np.ndarray = None) -> np.ndarray:
    """[0, 1]-normalize ``x`` by the spread over ``mask`` (or all of x).

    Degenerate pools are the hazard: with one free device or identical
    available devices ``ptp`` is ~0 and a naive ``(x - min) / ptp`` blows up
    into inf/NaN logits. A flat reference set carries no signal, so the
    normalized feature is defined as all-zeros there.
    """
    ref = x[mask] if mask is not None else x
    if ref.size == 0:
        return np.zeros(x.shape, dtype=np.float64)
    lo = float(ref.min())
    spread = float(np.ptp(ref))
    if not np.isfinite(spread) or spread < 1e-9:
        return np.zeros(x.shape, dtype=np.float64)
    return np.clip((x - lo) / spread, 0.0, 1.0)


# Expected Improvement under the masked GP posterior in feature space.
#
# The GP prior mean is the scheduler's ESTIMATED cost (the cost model); the
# GP itself models the realized-estimated residual. Predicted candidate
# cost = cand_est + mu_resid(cand); the incumbent is the PLUGIN best (min
# posterior mean over observed plans), which is robust to the noise-biased
# min-of-observations. The traced core lives in ``repro.core.search``
# (shared verbatim by the fused one-call acquisition and the vmapped
# all-jobs form ``search.ei_scores_jobs``); this is its host-path jit.
_ei_scores = jax.jit(search.ei_scores)


@register_scheduler("bods")
class BODSScheduler(SchedulerBase):
    name = "bods"

    def __init__(self, cost_model, seed: int = 0, num_candidates: int = 256,
                 init_points: int = 16, local_search: bool = True,
                 gp_noise: float = 0.25, search_backend: str = "fused"):
        super().__init__(cost_model, seed, search_backend=search_backend)
        self.num_candidates = num_candidates
        self.init_points = init_points
        self.local_search = local_search
        self.gp_noise = gp_noise
        M = cost_model.pool.num_jobs
        K = cost_model.pool.num_devices
        self._F = np.zeros((M, MAX_OBS, NUM_FEATURES), dtype=np.float32)
        self._plans = np.zeros((M, MAX_OBS, K), dtype=bool)
        self._y = np.zeros((M, MAX_OBS), dtype=np.float32)      # realized cost
        self._est = np.zeros((M, MAX_OBS), dtype=np.float32)    # estimated cost (prior mean)
        self._valid = np.zeros((M, MAX_OBS), dtype=np.float32)
        self._head = np.zeros(M, dtype=int)
        self._initialized = np.zeros(M, dtype=bool)

    # ---- persistence (policy zoo) ----

    def state_dict(self):
        """The GP observation rings as a checkpointable pytree (the policy
        zoo saves/loads them bit-exactly; a restored BODS resumes with its
        full observation history instead of re-bootstrapping)."""
        return {"F": self._F, "plans": self._plans, "y": self._y,
                "est": self._est, "valid": self._valid, "head": self._head,
                "initialized": self._initialized}

    def load_state_dict(self, tree) -> None:
        F = np.asarray(tree["F"], np.float32)
        plans = np.asarray(tree["plans"], bool)
        # The plans ring carries K, the F ring carries M — both must match
        # (a ring saved on a different pool would broadcast-crash later).
        if F.shape != self._F.shape or plans.shape != self._plans.shape:
            raise ValueError(
                f"BODS observation ring shapes {F.shape}/{plans.shape} do "
                f"not match this pool/job mix "
                f"{self._F.shape}/{self._plans.shape}; BODS state is "
                "pool-specific")
        self._F = F
        self._plans = plans
        self._y = np.asarray(tree["y"], np.float32)
        self._est = np.asarray(tree["est"], np.float32)
        self._valid = np.asarray(tree["valid"], np.float32)
        self._head = np.asarray(tree["head"], int)
        self._initialized = np.asarray(tree["initialized"], bool)

    # ---- dynamic job set (scheduler service) ----

    def ensure_jobs(self, num_jobs: int) -> None:
        """Grow the per-job observation rings to ``num_jobs`` rows (newly
        admitted jobs start with an empty, uninitialized ring)."""
        M = self._F.shape[0]
        if num_jobs <= M:
            return
        n = num_jobs - M

        def grow(arr):
            pad = np.zeros((n,) + arr.shape[1:], dtype=arr.dtype)
            return np.concatenate([arr, pad], axis=0)

        self._F = grow(self._F)
        self._plans = grow(self._plans)
        self._y = grow(self._y)
        self._est = grow(self._est)
        self._valid = grow(self._valid)
        self._head = np.concatenate([self._head, np.zeros(n, dtype=int)])
        self._initialized = np.concatenate(
            [self._initialized, np.zeros(n, dtype=bool)])

    def job_state_dict(self, job: int) -> dict:
        """One job's GP observation ring — a retiring tenant's history."""
        return {"F": self._F[job].copy(), "plans": self._plans[job].copy(),
                "y": self._y[job].copy(), "est": self._est[job].copy(),
                "valid": self._valid[job].copy(),
                "head": int(self._head[job]),
                "initialized": bool(self._initialized[job])}

    def load_job_state(self, job: int, tree: dict) -> None:
        """Restore a tenant's ring under its NEW job id (warm hand-off: a
        readmitted tenant resumes with its observation history instead of
        re-bootstrapping ``init_points`` fresh cost evaluations)."""
        plans = np.asarray(tree["plans"], bool)
        if plans.shape != self._plans.shape[1:]:
            raise ValueError(
                f"BODS per-job ring shape {plans.shape} does not match "
                f"this pool's {self._plans.shape[1:]}")
        self._F[job] = np.asarray(tree["F"], np.float32)
        self._plans[job] = plans
        self._y[job] = np.asarray(tree["y"], np.float32)
        self._est[job] = np.asarray(tree["est"], np.float32)
        self._valid[job] = np.asarray(tree["valid"], np.float32)
        self._head[job] = int(tree["head"])
        self._initialized[job] = bool(tree["initialized"])

    # ---- plan featurization φ(V) ----

    def _featurize(self, ctx: SchedulingContext, plans: np.ndarray) -> np.ndarray:
        """(P, K) plans -> (P, d) features, all O(1)-normalized."""
        cm = self.cost_model
        t = ctx.expected_times
        est_time = cm.round_time_batch(t, plans) / cm.time_scale
        dfair = cm.fairness_batch(ctx.counts, plans) / cm.fairness_scale
        sel_t = np.where(plans, t[None, :], 0.0)
        n = np.maximum(plans.sum(1), 1)
        mean_t = sel_t.sum(1) / n / cm.time_scale
        mu = cm.pool.mu
        jitter = np.where(plans, (t / np.maximum(mu, 1e-9))[None, :], 0.0).max(1) / cm.time_scale
        novelty = np.where(plans, (ctx.counts == 0)[None, :], False).sum(1) / np.maximum(ctx.n_sel, 1)
        occupancy = plans.sum(1) / plans.shape[1]
        return np.stack([est_time, dfair, mean_t, jitter, novelty, occupancy],
                        axis=1).astype(np.float32)

    # ---- Algorithm 1, Line 1: random initial observations (estimated costs) ----

    def _bootstrap(self, ctx: SchedulingContext) -> None:
        plans = random_plans(self.rng, ctx.available, ctx.n_sel, self.init_points)
        costs = self._own_cost_of(ctx, plans)
        feats = self._featurize(ctx, plans)
        for p, f, c in zip(plans, feats, costs):
            self._push(ctx.job, p, f, float(c), float(c))
        self._initialized[ctx.job] = True

    def _push(self, job: int, plan: np.ndarray, feat: np.ndarray,
              cost: float, est: float) -> None:
        h = self._head[job] % MAX_OBS
        self._plans[job, h] = plan
        self._F[job, h] = feat
        self._y[job, h] = cost
        self._est[job, h] = est
        self._valid[job, h] = 1.0
        self._head[job] += 1

    # ---- candidate generation ----

    def _structured_candidates(self, ctx: SchedulingContext, count: int) -> np.ndarray:
        """Gumbel top-k draws with random time/fairness bias weights.

        Normalization is degenerate-safe (``_norm01``): a pool where all
        available devices are identical, or only one is free, yields flat
        zero logits (pure-random proposals) instead of NaN.
        """
        t_norm = _norm01(ctx.expected_times, ctx.available)
        c_norm = _norm01(ctx.counts)
        w_time = self.rng.uniform(0.0, 6.0, count)
        w_fair = self.rng.uniform(0.0, 4.0, count)
        logits = -w_time[:, None] * t_norm[None, :] - w_fair[:, None] * c_norm[None, :]
        return gumbel_topk_plans(self.rng, logits, ctx.available, ctx.n_sel)

    # ---- Algorithm 1, Lines 3-4: candidates + EI argmax ----

    def schedule(self, ctx: SchedulingContext) -> np.ndarray:
        if not self._initialized[ctx.job]:
            self._bootstrap(ctx)
        if self.search_backend == "fused":
            return self._schedule_fused(ctx)
        n_rand = self.num_candidates // 4
        cands = np.concatenate([
            random_plans(self.rng, ctx.available, ctx.n_sel, n_rand),
            self._structured_candidates(ctx, self.num_candidates - n_rand),
        ])
        if self.local_search and self._head[ctx.job] > 0:
            # Mutations of the best observed plan, repaired onto the
            # feasible set — the same proposal the fused path ships to
            # device (search._mutate_plan_host + the vectorized repair).
            j = ctx.job
            best_i = int(np.argmin(np.where(self._valid[j] > 0, self._y[j], np.inf)))
            n_mut = min(32, self.num_candidates // 4)
            mutants = search._mutate_plan_host(
                self.rng, self._plans[j, best_i], n_mut)
            cands[:n_mut] = repair_plans(self.rng, mutants, ctx.available,
                                         ctx.n_sel)

        y = self._y[ctx.job]
        est = self._est[ctx.job]
        valid = self._valid[ctx.job]
        sd = y[valid > 0].std() + 1e-6 if valid.sum() else 1.0
        cand_feats = self._featurize(ctx, cands)
        cand_est = self._own_cost_of(ctx, cands).astype(np.float32)
        ei = np.asarray(_ei_scores(
            jnp.asarray(self._F[ctx.job]),
            jnp.asarray((y - est) / sd * valid),      # residual (normalized)
            jnp.asarray(valid),
            jnp.asarray(cand_feats),
            jnp.asarray(cand_est / sd),
            jnp.asarray(self.gp_noise, jnp.float32)))
        choice = int(np.argmax(ei))
        self.last_estimated_cost = float(cand_est[choice])
        return cands[choice]

    # ---- fused acquisition: the whole of Lines 3-4 in one jitted call ----

    def _schedule_fused(self, ctx: SchedulingContext) -> np.ndarray:
        """Candidate generation + featurization + GP/EI + argmax on-device
        (``search.bods_acquire``); only the ring slicing stays host-side.
        Same acquisition math as the host path — candidates come from the
        same random/structured/local-search proposal mix, features from the
        same phi(V) formulas — with device-resident search replacing the
        ~six host passes over the (P, K) candidate block."""
        j = ctx.job
        base_plan = None
        if self.local_search and self._head[j] > 0:
            best_i = int(np.argmin(np.where(self._valid[j] > 0,
                                            self._y[j], np.inf)))
            base_plan = self._plans[j, best_i]
        cm = self.cost_model
        plan, est = search.bods_acquire(
            self.rng, ctx.times32(), ctx.counts, ctx.available,
            cm.pool.mu, ctx.n_sel,
            F=self._F[j], y=self._y[j], est=self._est[j],
            valid=self._valid[j], base_plan=base_plan,
            alpha=cm.alpha, beta=cm.beta, time_scale=cm.time_scale,
            fairness_scale=cm.fairness_scale,
            delta_fairness=cm.delta_fairness,
            num_candidates=self.num_candidates,
            n_mut=min(32, self.num_candidates // 4),
            local_search=self.local_search, gp_noise=self.gp_noise,
            avail_idx=ctx.available_indices(),
            num_shards=cm.num_shards)
        self.last_estimated_cost = float(est)
        return plan

    # ---- Algorithm 1, Lines 6-7: realized cost becomes an observation ----

    def observe(self, ctx: SchedulingContext, plan: np.ndarray, realized_cost: float) -> None:
        feat = self._featurize(ctx, plan[None])[0]
        est = float(self._own_cost_of(ctx, plan[None])[0])
        self._push(ctx.job, plan, feat, realized_cost, est)
