"""Scheduler interface (Formula 9): pick V_m^r ⊂ K \\ V_o minimizing TotalCost."""

from __future__ import annotations

import abc
import dataclasses
from typing import Optional

import numpy as np

from repro.core.cost import CostModel


@dataclasses.dataclass
class SchedulingContext:
    """Everything a scheduler may look at when planning one round of one job."""

    job: int                    # index m of the job being scheduled
    round_idx: int              # r
    tau: float                  # local epochs tau_m
    n_sel: int                  # |V_m^r| = C_m * |K|
    available: np.ndarray       # (K,) bool — K \ V_o at this instant
    counts: np.ndarray          # (K,) s_{k,m}: cumulative scheduling frequency of job m
    expected_times: np.ndarray  # (K,) E[t_m^k] from the pool's time model
    other_costs: float = 0.0    # sum of other jobs' in-flight round costs (Formula 8)
    # Observed realized cost of the previous round of this job (schedulers that
    # learn online — BODS, RLDS — consume this as feedback).
    last_plan: Optional[np.ndarray] = None
    last_cost: Optional[float] = None
    # Per-round derived-array caches, computed at most ONCE per context (the
    # engine builds one context per launch): the float32 expected-time mirror
    # every jitted search/scoring path consumes, and the available-device id
    # list the closed-form schedulers (greedy/FedCS) and the engine share.
    # Lazy so host-only paths never pay for them; init=False so no
    # constructor (or dataclasses.replace) can smuggle in a stale cache.
    _times32: Optional[np.ndarray] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    _avail_idx: Optional[np.ndarray] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    def times32(self) -> np.ndarray:
        """float32 mirror of ``expected_times`` (cached per round)."""
        if self._times32 is None:
            self._times32 = self.expected_times.astype(np.float32)
        return self._times32

    def available_indices(self) -> np.ndarray:
        """``np.flatnonzero(available)`` (cached per round)."""
        if self._avail_idx is None:
            self._avail_idx = np.flatnonzero(self.available)
        return self._avail_idx


class SchedulerBase(abc.ABC):
    """Stateful per-experiment scheduler. One instance schedules ALL jobs.

    ALL batched plan evaluation flows through ``repro.core.scoring`` (via
    ``cost_model.cost_batch``): the searchers (BODS/RLDS/genetic/SA/DNN)
    score their candidate sets there, and the closed-form baselines
    (greedy/FedCS/random) score their chosen plan there via
    ``_score_plan`` — one jitted scoring path under every scheduler.
    """

    name: str = "base"

    #: Which plan-search implementation ``schedule`` runs: ``"fused"`` (the
    #: default) uses the jitted on-device loops in ``repro.core.search``;
    #: ``"host"`` keeps the historical sequential numpy path. Schedulers
    #: without a search loop (random/greedy/FedCS/DNN/RLDS) accept and
    #: ignore the knob — their one code path serves both settings.
    SEARCH_BACKENDS = ("host", "fused")

    def __init__(self, cost_model: CostModel, seed: int = 0,
                 search_backend: str = "fused"):
        if search_backend not in self.SEARCH_BACKENDS:
            raise ValueError(f"search_backend {search_backend!r} not in "
                             f"{self.SEARCH_BACKENDS}")
        self.cost_model = cost_model
        self.rng = np.random.default_rng(seed)
        self.search_backend = search_backend
        # Estimated Formula-2 cost of the most recently returned plan.
        self.last_estimated_cost: Optional[float] = None

    @abc.abstractmethod
    def schedule(self, ctx: SchedulingContext) -> np.ndarray:
        """Return a (K,) bool plan with exactly ctx.n_sel devices, all available."""

    def observe(self, ctx: SchedulingContext, plan: np.ndarray, realized_cost: float) -> None:
        """Feedback after the round really ran (default: no-op)."""

    # ---- persistence / warm hand-off -------------------------------------
    #
    # Every scheduler participates in the policy-zoo and scheduler-service
    # persistence protocols. The closed-form schedulers (random/greedy/
    # FedCS/SA/genetic) have no learned state, so the defaults are empty;
    # the learners (BODS/RLDS/DNN) override with their rings/params.

    def state_dict(self) -> dict:
        """Learned state as a checkpointable pytree (default: stateless)."""
        return {}

    def load_state_dict(self, tree: dict) -> None:
        """Restore learned state (default: no-op)."""

    def snapshot(self) -> dict:
        """FULL in-memory snapshot: ``state_dict`` plus the host PRNG state.
        Unlike the zoo-persisted ``state_dict`` (portable, array-only), a
        snapshot pins the numpy Generator too, so ``restore`` reproduces the
        next decision bit-for-bit — the scheduler-service warm hand-off
        across a retire/readmit cycle."""
        return {"state": self.state_dict(),
                "rng": self.rng.bit_generator.state}

    def restore(self, snap: dict) -> None:
        self.load_state_dict(snap["state"])
        self.rng.bit_generator.state = snap["rng"]

    # ---- dynamic job set -------------------------------------------------

    def ensure_jobs(self, num_jobs: int) -> None:
        """Grow per-job state to ``num_jobs`` rows (dynamic job admission —
        the engine calls this from ``add_job``). Default: no per-job state."""

    def job_state_dict(self, job: int) -> dict:
        """Per-job learned state (a retiring tenant's slice), for warm
        hand-off when the tenant is readmitted under a NEW job id. Default:
        nothing job-specific."""
        return {}

    def load_job_state(self, job: int, tree: dict) -> None:
        """Restore one job's slice saved by ``job_state_dict`` (default:
        no-op)."""

    # Shared helper: batch-estimate candidate TotalCosts under the context.
    def _cost_of(self, ctx: SchedulingContext, plans: np.ndarray) -> np.ndarray:
        return self.cost_model.total_cost_batch(
            job=ctx.job,
            tau=ctx.tau,
            counts=ctx.counts,
            plans=plans,
            other_costs=ctx.other_costs,
            times=ctx.expected_times,
        )

    # Own-job estimated cost (no cross-job constant): comparable to the
    # engine's realized-cost feedback, so learned schedulers can form
    # realized-estimated residuals that are stationary across rounds.
    def _own_cost_of(self, ctx: SchedulingContext, plans: np.ndarray) -> np.ndarray:
        return self.cost_model.total_cost_batch(
            job=ctx.job,
            tau=ctx.tau,
            counts=ctx.counts,
            plans=plans,
            other_costs=0.0,
            times=ctx.expected_times,
        )

    # Closed-form schedulers (greedy/FedCS/random) call this on their chosen
    # plan so even non-searching baselines flow through the scoring core.
    # Uses the INDEX fast path (n_sel gathers, not a K-wide dense pass) and
    # feeds the engine's RoundRecord.est_cost — the estimated-vs-realized
    # residual is exactly the quantity the learned schedulers model.
    def _score_plan(self, ctx: SchedulingContext, plan: np.ndarray) -> np.ndarray:
        idx = np.flatnonzero(plan)[None, :]
        self.last_estimated_cost = float(self.cost_model.cost_indices(
            ctx.expected_times, ctx.counts, idx)[0])
        return plan
