"""Device schedulers for multi-job FL.

Paper methods: BODS (Bayesian optimization), RLDS (reinforcement learning).
Paper baselines: Random, FedCS, Greedy, Genetic (+ appendix: SimulatedAnnealing).

Schedulers self-register into ``repro.experiment.registry.SCHEDULERS`` via
``@register_scheduler("<name>")`` at class definition; importing this package
loads every built-in. ``get_scheduler``/``list_schedulers`` remain the
convenience front end over that registry.
"""

from repro.core.schedulers.base import SchedulerBase, SchedulingContext
from repro.core.schedulers.random_sched import RandomScheduler
from repro.core.schedulers.greedy import GreedyScheduler
from repro.core.schedulers.fedcs import FedCSScheduler
from repro.core.schedulers.genetic import GeneticScheduler
from repro.core.schedulers.simulated_annealing import SimulatedAnnealingScheduler
from repro.core.schedulers.bods import BODSScheduler
from repro.core.schedulers.dnn import DNNScheduler
from repro.core.schedulers.rlds import RLDSScheduler
from repro.experiment.registry import SCHEDULERS


def get_scheduler(name: str, **kwargs) -> SchedulerBase:
    return SCHEDULERS.create(name, **kwargs)


def list_schedulers():
    return SCHEDULERS.names()


__all__ = [
    "SchedulerBase",
    "SchedulingContext",
    "SCHEDULERS",
    "get_scheduler",
    "list_schedulers",
    "RandomScheduler",
    "GreedyScheduler",
    "FedCSScheduler",
    "GeneticScheduler",
    "SimulatedAnnealingScheduler",
    "BODSScheduler",
    "RLDSScheduler",
]
