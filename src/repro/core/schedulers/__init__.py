"""Device schedulers for multi-job FL.

Paper methods: BODS (Bayesian optimization), RLDS (reinforcement learning).
Paper baselines: Random, FedCS, Greedy, Genetic (+ appendix: SimulatedAnnealing).
"""

from typing import Dict, Type

from repro.core.schedulers.base import SchedulerBase, SchedulingContext
from repro.core.schedulers.random_sched import RandomScheduler
from repro.core.schedulers.greedy import GreedyScheduler
from repro.core.schedulers.fedcs import FedCSScheduler
from repro.core.schedulers.genetic import GeneticScheduler
from repro.core.schedulers.simulated_annealing import SimulatedAnnealingScheduler
from repro.core.schedulers.bods import BODSScheduler
from repro.core.schedulers.dnn import DNNScheduler
from repro.core.schedulers.rlds import RLDSScheduler

_SCHEDULERS: Dict[str, Type[SchedulerBase]] = {
    "random": RandomScheduler,
    "greedy": GreedyScheduler,
    "fedcs": FedCSScheduler,
    "genetic": GeneticScheduler,
    "sa": SimulatedAnnealingScheduler,
    "dnn": DNNScheduler,
    "bods": BODSScheduler,
    "rlds": RLDSScheduler,
}


def get_scheduler(name: str, **kwargs) -> SchedulerBase:
    if name not in _SCHEDULERS:
        raise KeyError(f"unknown scheduler {name!r}; known: {sorted(_SCHEDULERS)}")
    return _SCHEDULERS[name](**kwargs)


def list_schedulers():
    return sorted(_SCHEDULERS)


__all__ = [
    "SchedulerBase",
    "SchedulingContext",
    "get_scheduler",
    "list_schedulers",
    "RandomScheduler",
    "GreedyScheduler",
    "FedCSScheduler",
    "GeneticScheduler",
    "SimulatedAnnealingScheduler",
    "BODSScheduler",
    "RLDSScheduler",
]
