"""FedCS (Nishio & Yonetani 2019) adapted to multi-job FL.

FedCS greedily accepts clients under a round deadline, visiting them in a
RANDOM order (which is where its partial fairness comes from), and keeps the
plan within the deadline budget. If fewer than n_sel fit the deadline, the
deadline is relaxed; if more fit, the first n_sel accepted are kept.
"""

from __future__ import annotations

import numpy as np

from repro.core.plans import plan_from_indices
from repro.core.schedulers.base import SchedulerBase, SchedulingContext
from repro.experiment.registry import register_scheduler


@register_scheduler("fedcs")
class FedCSScheduler(SchedulerBase):
    name = "fedcs"

    def __init__(self, cost_model, seed: int = 0,
                 deadline_quantile: float = 0.6,
                 search_backend: str = "fused"):
        super().__init__(cost_model, seed, search_backend=search_backend)
        self.deadline_quantile = deadline_quantile

    def schedule(self, ctx: SchedulingContext) -> np.ndarray:
        avail = ctx.available_indices()  # cached per round (shared w/ engine)
        times = ctx.expected_times
        deadline = np.quantile(times[avail], self.deadline_quantile)
        order = self.rng.permutation(avail)
        fits = times[order] <= deadline
        chosen = order[fits][: ctx.n_sel]
        if chosen.size < ctx.n_sel:  # relax: admit the fastest remaining
            rest = order[~fits]
            rest = rest[np.argsort(times[rest], kind="stable")]
            chosen = np.concatenate([chosen, rest[: ctx.n_sel - chosen.size]])
        plan = plan_from_indices(ctx.available.shape[0], chosen)
        return self._score_plan(ctx, plan)
