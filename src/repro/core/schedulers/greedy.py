"""Greedy scheduling (Shi, Zhou, Niu 2020): fastest available devices first.

The paper observes this maximizes per-round speed but starves slow devices'
data (poor fairness) -> accuracy collapse on non-IID. Kept faithful.
"""

from __future__ import annotations

import numpy as np

from repro.core.plans import plan_from_indices
from repro.core.schedulers.base import SchedulerBase, SchedulingContext
from repro.experiment.registry import register_scheduler


@register_scheduler("greedy")
class GreedyScheduler(SchedulerBase):
    name = "greedy"

    def schedule(self, ctx: SchedulingContext) -> np.ndarray:
        times = np.where(ctx.available, ctx.expected_times, np.inf)
        idx = np.argsort(times, kind="stable")[: ctx.n_sel]
        return plan_from_indices(ctx.available.shape[0], idx)
