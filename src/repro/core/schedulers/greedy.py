"""Greedy scheduling (Shi, Zhou, Niu 2020): fastest available devices first.

The paper observes this maximizes per-round speed but starves slow devices'
data (poor fairness) -> accuracy collapse on non-IID. Kept faithful.
"""

from __future__ import annotations

import numpy as np

from repro.core.plans import plan_from_indices
from repro.core.schedulers.base import SchedulerBase, SchedulingContext
from repro.experiment.registry import register_scheduler


@register_scheduler("greedy")
class GreedyScheduler(SchedulerBase):
    name = "greedy"

    def schedule(self, ctx: SchedulingContext) -> np.ndarray:
        times = np.where(ctx.available, ctx.expected_times, np.inf)
        # argpartition: the paper's top-n_sel-fastest rule is selection, not
        # a full sort — O(K) instead of O(K log K) on 100k-device fleets.
        cut = np.argpartition(times, ctx.n_sel - 1)[: ctx.n_sel]
        idx = cut[np.argsort(times[cut], kind="stable")]
        plan = plan_from_indices(ctx.available.shape[0], idx)
        return self._score_plan(ctx, plan)
