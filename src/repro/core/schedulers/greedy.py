"""Greedy scheduling (Shi, Zhou, Niu 2020): fastest available devices first.

The paper observes this maximizes per-round speed but starves slow devices'
data (poor fairness) -> accuracy collapse on non-IID. Kept faithful.
"""

from __future__ import annotations

import numpy as np

from repro.core.plans import plan_from_indices
from repro.core.schedulers.base import SchedulerBase, SchedulingContext
from repro.experiment.registry import register_scheduler


@register_scheduler("greedy")
class GreedyScheduler(SchedulerBase):
    name = "greedy"

    def schedule(self, ctx: SchedulingContext) -> np.ndarray:
        # The context's cached available-id list (shared with the engine and
        # FedCS this round) replaces a K-wide masked copy: the selection
        # runs over the |avail|-sized gather of the pool's cached
        # expected-time row.
        avail = ctx.available_indices()
        t_av = ctx.expected_times[avail]
        # argpartition: the paper's top-n_sel-fastest rule is selection, not
        # a full sort — O(K) instead of O(K log K) on 100k-device fleets.
        cut = np.argpartition(t_av, ctx.n_sel - 1)[: ctx.n_sel]
        idx = avail[cut[np.argsort(t_av[cut], kind="stable")]]
        plan = plan_from_indices(ctx.available.shape[0], idx)
        return self._score_plan(ctx, plan)
