"""Random scheduling — FedAvg's device selection (McMahan et al. 2017b)."""

from __future__ import annotations

import numpy as np

from repro.core.plans import random_plans
from repro.core.schedulers.base import SchedulerBase, SchedulingContext
from repro.experiment.registry import register_scheduler


@register_scheduler("random")
class RandomScheduler(SchedulerBase):
    name = "random"

    def schedule(self, ctx: SchedulingContext) -> np.ndarray:
        plan = random_plans(self.rng, ctx.available, ctx.n_sel, 1)[0]
        return self._score_plan(ctx, plan)
