"""Genetic-algorithm scheduling (Barika et al. 2019) on the plan bit-vectors.

Population of valid plans; tournament selection; uniform crossover + repair
(cardinality and availability restored); mutation swaps a selected device for
a free one. Fitness = -TotalCost (estimated).

Two search backends (``search_backend``):

- ``fused`` (default): all generations under one jitted ``lax.scan``
  (``repro.core.search.ga_search``) with vmapped tournament selection, the
  vectorized population repair, and the greedy plan seeding individual 0.
- ``host``: the historical per-individual numpy loops, kept as the
  behavioural reference (``benchmarks/bench_sched.py`` gates fused
  against it).
"""

from __future__ import annotations

import numpy as np

from repro.core import search
from repro.core.plans import random_plans, repair_plan
from repro.core.schedulers.base import SchedulerBase, SchedulingContext
from repro.experiment.registry import register_scheduler


@register_scheduler("genetic")
class GeneticScheduler(SchedulerBase):
    name = "genetic"

    def __init__(self, cost_model, seed: int = 0, population: int = 32,
                 generations: int = 12, mutation_rate: float = 0.2,
                 search_backend: str = "fused"):
        super().__init__(cost_model, seed, search_backend=search_backend)
        self.population = population
        self.generations = generations
        self.mutation_rate = mutation_rate

    def schedule(self, ctx: SchedulingContext) -> np.ndarray:
        if self.search_backend == "fused":
            cm = self.cost_model
            plan = search.ga_search(
                self.rng, ctx.times32(), ctx.counts, ctx.available,
                ctx.n_sel, alpha=cm.alpha, beta=cm.beta,
                time_scale=cm.time_scale, fairness_scale=cm.fairness_scale,
                delta_fairness=cm.delta_fairness,
                population=self.population, generations=self.generations,
                mutation_rate=self.mutation_rate,
                avail_idx=ctx.available_indices(),
                num_shards=cm.num_shards)
            return self._score_plan(ctx, plan)
        pop = random_plans(self.rng, ctx.available, ctx.n_sel, self.population)
        for _ in range(self.generations):
            cost = self._cost_of(ctx, pop)
            pop = self._next_generation(ctx, pop, cost)
        cost = self._cost_of(ctx, pop)
        return self._score_plan(ctx, pop[int(np.argmin(cost))])

    def _next_generation(self, ctx, pop, cost):
        P = pop.shape[0]
        # Tournament selection (size 2).
        a, b = self.rng.integers(0, P, (2, P))
        parents = np.where((cost[a] <= cost[b])[:, None], pop[a], pop[b])
        # Uniform crossover between consecutive parents, then repair.
        children = parents.copy()
        for i in range(0, P - 1, 2):
            mask = self.rng.random(pop.shape[1]) < 0.5
            c0 = np.where(mask, parents[i], parents[i + 1])
            c1 = np.where(mask, parents[i + 1], parents[i])
            children[i] = repair_plan(self.rng, c0, ctx.available, ctx.n_sel)
            children[i + 1] = repair_plan(self.rng, c1, ctx.available, ctx.n_sel)
        # Mutation: swap one in-plan device for one free device.
        for i in range(P):
            if self.rng.random() < self.mutation_rate:
                on = np.flatnonzero(children[i])
                off = np.flatnonzero(ctx.available & ~children[i])
                if on.size and off.size:
                    children[i][self.rng.choice(on)] = False
                    children[i][self.rng.choice(off)] = True
        # Elitism: keep the best parent.
        best = int(np.argmin(cost))
        children[0] = pop[best]
        return children
