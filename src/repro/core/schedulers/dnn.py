"""DNN-based scheduling baseline (paper appendix: Zang et al. 2019).

A small MLP regresses realized cost from plan features; each round the
scheduler picks the argmin predicted cost among sampled candidates
(exploitation) with epsilon-greedy random exploration. The paper reports this
class of method underperforms BODS/RLDS (up to 90.5% slower, 26.3% lower
accuracy) — included to reproduce that comparison.

Pure JAX: the MLP trains online by SGD on (features, realized cost) pairs
from a fixed-size ring buffer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plans import random_plans
from repro.core.schedulers.base import SchedulerBase, SchedulingContext
from repro.experiment.registry import register_scheduler
from repro.core.schedulers.bods import NUM_FEATURES

BUF = 256
HIDDEN = 32


def _init_mlp(rng: np.random.Generator):
    def g(shape):
        return jnp.asarray(rng.normal(0, np.sqrt(2.0 / sum(shape)), shape), jnp.float32)

    return {"w1": g((NUM_FEATURES, HIDDEN)), "b1": jnp.zeros((HIDDEN,)),
            "w2": g((HIDDEN, HIDDEN)), "b2": jnp.zeros((HIDDEN,)),
            "w3": g((HIDDEN, 1)), "b3": jnp.zeros((1,))}


@jax.jit
def _mlp(params, f):
    h = jax.nn.relu(f @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    return (h @ params["w3"] + params["b3"])[..., 0]


@jax.jit
def _sgd_step(params, feats, targets, valid, lr):
    def loss(p):
        pred = _mlp(p, feats)
        return jnp.sum(jnp.square(pred - targets) * valid) / jnp.maximum(valid.sum(), 1.0)

    g = jax.grad(loss)(params)
    return jax.tree_util.tree_map(lambda p_, g_: p_ - lr * g_, params, g)


@register_scheduler("dnn")
class DNNScheduler(SchedulerBase):
    name = "dnn"

    def __init__(self, cost_model, seed: int = 0, num_candidates: int = 256,
                 epsilon: float = 0.1, lr: float = 1e-2, train_steps: int = 4,
                 search_backend: str = "fused"):
        # search_backend accepted (and ignored) for a uniform scheduler
        # constructor contract: DNN has no fused search loop — its one
        # candidate-scoring path serves both settings.
        super().__init__(cost_model, seed, search_backend=search_backend)
        self.num_candidates = num_candidates
        self.epsilon = epsilon
        self.lr = lr
        self.train_steps = train_steps
        self.params = _init_mlp(self.rng)
        self._F = np.zeros((BUF, NUM_FEATURES), np.float32)
        self._y = np.zeros(BUF, np.float32)
        self._valid = np.zeros(BUF, np.float32)
        self._head = 0

    # ---- persistence (policy zoo) ----

    def state_dict(self):
        return {"params": self.params, "F": self._F, "y": self._y,
                "valid": self._valid, "head": np.asarray(self._head, np.int64)}

    def load_state_dict(self, tree) -> None:
        F = np.asarray(tree["F"], np.float32)
        if F.shape != self._F.shape:
            raise ValueError(
                f"DNN replay-ring shape {F.shape} does not match this "
                f"scheduler's {self._F.shape} (BUF/feature-count mismatch)")
        self.params = jax.tree_util.tree_map(jnp.asarray, tree["params"])
        self._F = F
        self._y = np.asarray(tree["y"], np.float32)
        self._valid = np.asarray(tree["valid"], np.float32)
        self._head = int(np.asarray(tree["head"]))

    def _featurize(self, ctx, plans):
        from repro.core.schedulers.bods import BODSScheduler
        return BODSScheduler._featurize(self, ctx, plans)  # shared feature map

    def schedule(self, ctx: SchedulingContext) -> np.ndarray:
        cands = random_plans(self.rng, ctx.available, ctx.n_sel, self.num_candidates)
        if self.rng.random() < self.epsilon or self._valid.sum() < 8:
            return self._score_plan(ctx, cands[self.rng.integers(0, len(cands))])
        feats = self._featurize(ctx, cands)
        pred = np.asarray(_mlp(self.params, jnp.asarray(feats)))
        return self._score_plan(ctx, cands[int(np.argmin(pred))])

    def observe(self, ctx: SchedulingContext, plan: np.ndarray, realized_cost: float) -> None:
        f = self._featurize(ctx, plan[None])[0]
        i = self._head % BUF
        self._F[i] = f
        self._y[i] = realized_cost
        self._valid[i] = 1.0
        self._head += 1
        for _ in range(self.train_steps):
            self.params = _sgd_step(self.params, jnp.asarray(self._F),
                                    jnp.asarray(self._y), jnp.asarray(self._valid),
                                    jnp.asarray(self.lr, jnp.float32))
