"""Simulated annealing baseline (paper appendix comparison).

Neighborhood move: swap one selected device with one free device. Geometric
cooling. Fitness = estimated TotalCost.
"""

from __future__ import annotations

import numpy as np

from repro.core.plans import random_plans
from repro.core.schedulers.base import SchedulerBase, SchedulingContext
from repro.experiment.registry import register_scheduler


@register_scheduler("sa")
class SimulatedAnnealingScheduler(SchedulerBase):
    name = "sa"

    def __init__(self, cost_model, seed: int = 0, steps: int = 200,
                 t0: float = 1.0, cooling: float = 0.97):
        super().__init__(cost_model, seed)
        self.steps = steps
        self.t0 = t0
        self.cooling = cooling

    def schedule(self, ctx: SchedulingContext) -> np.ndarray:
        cur = random_plans(self.rng, ctx.available, ctx.n_sel, 1)[0]
        cur_cost = float(self._cost_of(ctx, cur[None])[0])
        best, best_cost = cur.copy(), cur_cost
        temp = self.t0
        for _ in range(self.steps):
            nxt = cur.copy()
            on = np.flatnonzero(nxt)
            off = np.flatnonzero(ctx.available & ~nxt)
            if not off.size:
                break
            nxt[self.rng.choice(on)] = False
            nxt[self.rng.choice(off)] = True
            nxt_cost = float(self._cost_of(ctx, nxt[None])[0])
            if nxt_cost < cur_cost or self.rng.random() < np.exp(-(nxt_cost - cur_cost) / max(temp, 1e-9)):
                cur, cur_cost = nxt, nxt_cost
                if cur_cost < best_cost:
                    best, best_cost = cur.copy(), cur_cost
            temp *= self.cooling
        return self._score_plan(ctx, best)
