"""Simulated annealing baseline (paper appendix comparison).

Neighborhood move: swap one selected device with one free device. Geometric
cooling. Fitness = estimated TotalCost.

Two search backends (``search_backend``):

- ``fused`` (default): ``chains`` parallel SA chains stepped under one
  jitted ``lax.scan`` (``repro.core.search.sa_search``) — one device call
  per decision instead of ``steps`` sequential host round-trips, with the
  greedy plan seeding chain 0 (memetic warm start). NOTE on budgets:
  ``steps`` counts PER-CHAIN scan iterations, so the fused default spends
  ``chains * steps`` cost evaluations per decision — deliberately ~8x the
  host budget, because batched evaluations are nearly free on-device (the
  point of fusing). For an apples-to-apples comparison against ``host``,
  divide ``steps`` by ``chains`` and raise ``cooling`` to the
  ``chains``-th power so each short chain spans the same temperature
  range — exactly what ``benchmarks/bench_sched.py`` does for its
  matched-budget parity gate.
- ``host``: the historical sequential numpy loop, kept as the behavioural
  reference (``benchmarks/bench_sched.py`` gates fused against it).
"""

from __future__ import annotations

import numpy as np

from repro.core import search
from repro.core.plans import random_plans
from repro.core.schedulers.base import SchedulerBase, SchedulingContext
from repro.experiment.registry import register_scheduler


@register_scheduler("sa")
class SimulatedAnnealingScheduler(SchedulerBase):
    name = "sa"

    def __init__(self, cost_model, seed: int = 0, steps: int = 200,
                 t0: float = 1.0, cooling: float = 0.97, chains: int = 8,
                 search_backend: str = "fused"):
        super().__init__(cost_model, seed, search_backend=search_backend)
        self.steps = steps
        self.t0 = t0
        self.cooling = cooling
        self.chains = chains

    def schedule(self, ctx: SchedulingContext) -> np.ndarray:
        if self.search_backend == "fused":
            cm = self.cost_model
            plan = search.sa_search(
                self.rng, ctx.times32(), ctx.counts, ctx.available,
                ctx.n_sel, alpha=cm.alpha, beta=cm.beta,
                time_scale=cm.time_scale, fairness_scale=cm.fairness_scale,
                delta_fairness=cm.delta_fairness, steps=self.steps,
                chains=self.chains, t0=self.t0, cooling=self.cooling,
                avail_idx=ctx.available_indices(),
                num_shards=cm.num_shards)
            return self._score_plan(ctx, plan)
        return self._schedule_host(ctx)

    def _schedule_host(self, ctx: SchedulingContext) -> np.ndarray:
        cur = random_plans(self.rng, ctx.available, ctx.n_sel, 1)[0]
        cur_cost = float(self._cost_of(ctx, cur[None])[0])
        best, best_cost = cur.copy(), cur_cost
        temp = self.t0
        # The free pool (available & ~plan) has CONSTANT size across swap
        # moves (every move trades one selected for one free device), so a
        # swapless schedule is detectable up front — no mid-loop break that
        # would leave the cooling schedule half-applied.
        if not np.any(ctx.available & ~cur):
            return self._score_plan(ctx, best)
        for _ in range(self.steps):
            nxt = cur.copy()
            on = np.flatnonzero(nxt)
            off = np.flatnonzero(ctx.available & ~nxt)
            nxt[self.rng.choice(on)] = False
            nxt[self.rng.choice(off)] = True
            nxt_cost = float(self._cost_of(ctx, nxt[None])[0])
            # Clamped Metropolis exponent: a pathological cost spike must
            # not overflow exp (RuntimeWarning) — past ±60 the accept
            # probability is saturated anyway.
            dc = nxt_cost - cur_cost
            accept_p = np.exp(np.clip(-dc / max(temp, 1e-9), -60.0, 0.0))
            if dc < 0 or self.rng.random() < accept_p:
                cur, cur_cost = nxt, nxt_cost
                if cur_cost < best_cost:
                    best, best_cost = cur.copy(), cur_cost
            temp *= self.cooling
        return self._score_plan(ctx, best)
