"""Batched plan-scoring core: one scoring path under every scheduler.

Every scheduler in this repo (BODS Alg. 1, RLDS, greedy/genetic/SA/FedCS/
random/DNN) reduces to the same inner loop — score P candidate plans over K
devices with Formula 2:

    cost(V) = alpha * max_{k in V} t_k / time_scale
            + beta  * [Var(c + v) (- Var(c))] / fairness_scale

``score_plans`` is that loop, batched, with three interchangeable backends:

- ``numpy``  — the seed implementation, bit-identical to the historical
  ``CostModel.cost_batch`` (small pools, zero dispatch overhead);
- ``jax``    — a jitted fused reduction (single pass over the (P, K) tile
  stream, no materialized float intermediates; ~10-100x numpy on 10k+
  device pools even on CPU);
- ``pallas`` — the tiled TPU kernel in ``repro.kernels.sched_score``
  (sufficient-statistics reduction; falls back to the jax reference with a
  logged warning off-TPU).

``backend="auto"`` (the default) picks numpy below a per-FORM element
threshold (``AUTO_NUMPY_MAX_DENSE`` / ``AUTO_NUMPY_MAX_INDEX``) and jax
above — the same size/backend dispatch the model kernels in
``repro/kernels/ops.py`` use, but calibrated separately for the dense
(P, K) sweep and the (P, n_sel) gather fast path (the index form's numpy
gather stays ahead of jit dispatch for ~4x more elements). The
process-wide default can be flipped with ``set_default_backend`` (the
experiment layer wires ``ExperimentSpec.fleet.scoring_backend`` through
``CostModel``).
"""

from __future__ import annotations

import functools
import logging
import threading
from typing import Optional, Tuple

import numpy as np

logger = logging.getLogger(__name__)

VALID_BACKENDS = ("auto", "numpy", "jax", "pallas")

# Below these many elements the numpy path wins: jit dispatch + host->device
# transfer costs more than the whole reduction. Calibrated per FORM from
# BENCH_fleet.json (CPU): dense numpy/jax cross over between P*K = 2.6e5
# (K=1e3, P=256: a tie) and 4.1e5 (jax clearly ahead); the index-form numpy
# gather is still ahead at P*n_sel = 4.1e5 (K=1e4, P=4096) and loses by
# 4.1e6 (K=1e5), so its threshold sits a factor of 4 higher.
AUTO_NUMPY_MAX_DENSE = 1 << 18
AUTO_NUMPY_MAX_INDEX = 1 << 20
# Back-compat alias (pre-calibration single threshold == the dense one).
AUTO_NUMPY_MAX = AUTO_NUMPY_MAX_DENSE
# Sharded fleets dispatch on PER-SHARD size, against a much smaller floor:
# a fleet someone bothered to shard should stay on the jax path (that is
# the whole point of sharding), so numpy only wins when the per-shard
# problem is genuinely tiny (below jit dispatch overhead). Comparing the
# per-shard count against the single-lane caps would do the opposite —
# make the numpy fallback MORE likely as shards are added.
MIN_SHARD_ELEMENTS = 1 << 12

_state = threading.local()
_warned_pallas_fallback = False


def set_default_backend(backend: str) -> None:
    if backend not in VALID_BACKENDS:
        raise ValueError(f"backend {backend!r} not in {VALID_BACKENDS}")
    _state.backend = backend


def get_default_backend() -> str:
    return getattr(_state, "backend", "auto")


def resolve_backend(backend: Optional[str], num_elements: int,
                    form: str = "dense", num_shards: int = 1) -> str:
    """Concrete backend for an ``num_elements``-sized scoring problem.

    ``form`` is ``dense`` (a (P, K) sweep) or ``index`` (a (P, n_sel)
    gather): the auto dispatch uses a separate measured crossover per form.
    With ``num_shards > 1`` the auto dispatch is SHARD-AWARE: it compares
    the per-shard element count against ``MIN_SHARD_ELEMENTS`` instead of
    the single-lane caps, so sharded fleets stay on the jax path.
    """
    b = backend if backend is not None else get_default_backend()
    if b not in VALID_BACKENDS:
        raise ValueError(f"backend {b!r} not in {VALID_BACKENDS}")
    if b == "auto":
        if num_shards and num_shards > 1:
            return ("numpy" if num_elements // num_shards <= MIN_SHARD_ELEMENTS
                    else "jax")
        cap = AUTO_NUMPY_MAX_INDEX if form == "index" else AUTO_NUMPY_MAX_DENSE
        return "numpy" if num_elements <= cap else "jax"
    if b == "pallas" and not _pallas_available():
        global _warned_pallas_fallback
        if not _warned_pallas_fallback:
            logger.warning(
                "scoring backend 'pallas' requested but the default JAX "
                "backend is %s (TPU required) — falling back to the jitted "
                "jax reference", _jax_backend_name())
            _warned_pallas_fallback = True
        return "jax"
    return b


def _jax_backend_name() -> str:
    import jax

    return jax.default_backend()


def _pallas_available() -> bool:
    try:
        return _jax_backend_name() == "tpu"
    except Exception:  # pragma: no cover - no jax runtime at all
        return False


# ---- jitted jax reference ----------------------------------------------

@functools.lru_cache(maxsize=None)
def _jax_score_fn(delta_fairness: bool):
    import jax
    import jax.numpy as jnp

    @jax.jit
    def score(times, counts, plans, alpha, beta, ts, fs):
        K = float(times.shape[0])  # float: K*K overflows int32 at K=100k
        sel = plans != 0
        masked = jnp.where(sel, times[None, :], -jnp.inf)
        t = jnp.max(masked, axis=1)
        t = jnp.where(jnp.isfinite(t), t, 0.0)
        # Fairness via sufficient statistics (v in {0,1}):
        #   sum(s) = sum(c) + n,  sum(s^2) = sum(c^2) + sum_{sel} (2c + 1)
        w = 2.0 * counts + 1.0
        n = jnp.sum(jnp.where(sel, 1.0, 0.0), axis=1)
        wsum = jnp.sum(jnp.where(sel, w[None, :], 0.0), axis=1)
        c1 = jnp.sum(counts)
        if delta_fairness:
            # Var(c+v) - Var(c), expanded: cancellation-free at any scale.
            f = wsum / K - (2.0 * c1 * n + n * n) / (K * K)
        else:
            c2 = jnp.sum(counts * counts)
            f = (c2 + wsum) / K - ((c1 + n) / K) ** 2
        return alpha * t / ts + beta * f / fs

    return score


@functools.lru_cache(maxsize=None)
def _jax_score_idx_fn(delta_fairness: bool):
    import jax
    import jax.numpy as jnp

    @jax.jit
    def score(times, counts_c, idx, alpha, beta, ts, fs):
        K = float(counts_c.shape[0])  # float: K*K overflows int32 at K=100k
        n = jnp.float32(idx.shape[1])
        t = jnp.max(times[idx], axis=1)
        w = 2.0 * counts_c + 1.0
        wsum = jnp.sum(w[idx], axis=1)
        c1 = jnp.sum(counts_c)
        if delta_fairness:
            f = wsum / K - (2.0 * c1 * n + n * n) / (K * K)
        else:
            c2 = jnp.sum(counts_c * counts_c)
            f = (c2 + wsum) / K - ((c1 + n) / K) ** 2
        return alpha * t / ts + beta * f / fs

    return score


@functools.lru_cache(maxsize=None)
def _jax_fairness_fn(delta_fairness: bool):
    import jax
    import jax.numpy as jnp

    @jax.jit
    def fairness(counts_c, plans):
        K = float(counts_c.shape[0])
        sel = plans != 0
        w = 2.0 * counts_c + 1.0
        n = jnp.sum(jnp.where(sel, 1.0, 0.0), axis=1)
        wsum = jnp.sum(jnp.where(sel, w[None, :], 0.0), axis=1)
        c1 = jnp.sum(counts_c)
        if delta_fairness:
            return wsum / K - (2.0 * c1 * n + n * n) / (K * K)
        c2 = jnp.sum(counts_c * counts_c)
        return (c2 + wsum) / K - ((c1 + n) / K) ** 2

    return fairness


@functools.lru_cache(maxsize=None)
def _jax_round_time_fn():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def round_time(times, plans):
        masked = jnp.where(plans != 0, times[None, :], -jnp.inf)
        t = jnp.max(masked, axis=1)
        return jnp.where(jnp.isfinite(t), t, 0.0)

    return round_time


# ---- traced-callable accessors (the gym's in-graph scoring path) --------
#
# The public API below is host-facing (numpy in/out). The scheduler gym
# (``repro.gym``) evaluates Formula 2/3 INSIDE its own jit/vmap/scan graphs,
# so it needs the underlying jitted callables directly: jax.Array in,
# jax.Array out, safe to call from traced code (an inner jit is inlined).
# Conventions match the wrappers: ``counts_c`` is mean-centered float32
# (variance is shift-invariant; centering keeps f32 cancellation-free),
# plans are (P, K) with nonzero = selected.

def jax_fairness_fn(delta_fairness: bool = False):
    """(counts_c, plans) -> (P,) Formula-5 fairness (or its increment)."""
    return _jax_fairness_fn(bool(delta_fairness))


def jax_round_time_fn():
    """(times, plans) -> (P,) Formula-3 round time (masked max, empty -> 0)."""
    return _jax_round_time_fn()


# ---- numpy reference (the seed semantics, bit-for-bit) ------------------

def _score_numpy(times, counts, plans, alpha, beta, ts, fs, delta_fairness):
    sel = plans.astype(bool)
    masked = np.where(sel, times[None, :], -np.inf)
    t = masked.max(axis=1)
    t = np.where(np.isfinite(t), t, 0.0) / ts
    f = np.var(counts[None, :] + plans, axis=1)
    if delta_fairness:
        f = f - np.var(counts)
    return alpha * t + beta * f / fs


def _score_from_stats(stats, counts, alpha, beta, ts, fs, delta_fairness):
    """(P, 3) kernel stats -> (P,) costs (cheap host-side combine)."""
    t_max = stats[:, 0].astype(np.float64)
    n = stats[:, 1].astype(np.float64)
    wsum = stats[:, 2].astype(np.float64)
    K = counts.shape[0]
    t = np.where(t_max > -1e29, t_max, 0.0) / ts
    c1 = float(np.sum(counts))
    if delta_fairness:
        f = wsum / K - (2.0 * c1 * n + n * n) / (K * K)
    else:
        c2 = float(np.sum(np.square(counts, dtype=np.float64)))
        f = (c2 + wsum) / K - ((c1 + n) / K) ** 2
    return alpha * t + beta * f / fs


# ---- public API ---------------------------------------------------------

def score_plans(times: np.ndarray, counts: np.ndarray, plans: np.ndarray,
                alpha: float = 1.0, beta: float = 1.0,
                time_scale: float = 1.0, fairness_scale: float = 1.0,
                delta_fairness: bool = True,
                backend: Optional[str] = None,
                num_shards: int = 1) -> np.ndarray:
    """Score P candidate plans: (K,) times, (K,) counts, (P, K) plans -> (P,).

    The one batched inner loop under every scheduler (Formula 2 over a
    candidate set). ``backend`` is ``numpy | jax | pallas | auto`` (None ->
    the process default, normally ``auto``). ``num_shards > 1`` shards the
    fleet (K) axis across host platform devices (``repro.core.shard``) —
    shard-local sufficient-statistics reductions with a cheap cross-shard
    combine; an explicit ``pallas`` backend is single-device, so it also
    routes to the sharded jax path when shards are requested.
    """
    times = np.asarray(times)
    counts = np.asarray(counts)
    plans = np.asarray(plans)
    if plans.ndim == 1:
        plans = plans[None, :]
    P, K = plans.shape
    b = resolve_backend(backend, P * K, num_shards=num_shards)
    if b == "numpy":
        return _score_numpy(times, counts, plans, alpha, beta,
                            time_scale, fairness_scale, delta_fairness)
    # Variance is shift-invariant: center counts once in f64 so the f32
    # backends never cancel two large sums (exact parity at fleet scale,
    # where cumulative counts grow without bound).
    counts_c = counts.astype(np.float64) - float(np.mean(counts))
    if num_shards and num_shards > 1:
        from repro.core import shard

        stats = shard.plan_stats_sharded(times, counts_c, plans, "dense",
                                         num_shards)
        return _score_from_stats(stats, counts_c, alpha, beta,
                                 time_scale, fairness_scale, delta_fairness)
    if b == "jax":
        import jax.numpy as jnp

        fn = _jax_score_fn(bool(delta_fairness))
        # int8 plan mirrors (plans.indices_to_plans(..., dtype=np.int8))
        # pass through without another (P, K) materialization.
        p8 = plans if plans.dtype == np.int8 else plans.astype(np.int8)
        out = fn(jnp.asarray(times, jnp.float32),
                 jnp.asarray(counts_c, jnp.float32),
                 jnp.asarray(p8),
                 jnp.float32(alpha), jnp.float32(beta),
                 jnp.float32(time_scale), jnp.float32(fairness_scale))
        return np.asarray(out, dtype=np.float64)
    # pallas (resolve_backend already verified TPU availability)
    stats = plan_stats_pallas(times, counts_c, plans)
    return _score_from_stats(stats, counts_c, alpha, beta,
                             time_scale, fairness_scale, delta_fairness)


def score_plan_indices(times: np.ndarray, counts: np.ndarray,
                       idx: np.ndarray, alpha: float = 1.0, beta: float = 1.0,
                       time_scale: float = 1.0, fairness_scale: float = 1.0,
                       delta_fairness: bool = True,
                       backend: Optional[str] = None,
                       num_shards: int = 1) -> np.ndarray:
    """Score P candidate plans given in INDEX form: (P, n_sel) device ids.

    The fleet fast path: the vectorized candidate generators
    (``plans.random_plan_indices``, Gumbel top-k) produce exactly this shape
    before any dense scatter, and scoring it is P*n_sel gathered elements
    instead of a P*K dense sweep — the difference between ~2 and ~2000 ms
    at K=100k, P=4096. Semantically identical to ``score_plans`` on the
    scattered dense plans (each row selects its n_sel ids exactly once).
    ``num_shards > 1`` shards the fleet axis: each shard owns a K/N block
    of devices and masks the gather to the ids it owns.
    """
    times = np.asarray(times)
    counts = np.asarray(counts)
    idx = np.asarray(idx)
    if idx.ndim == 1:
        idx = idx[None, :]
    P, S = idx.shape
    K = counts.shape[0]
    if S == 0:
        if delta_fairness:
            return np.zeros(P, dtype=np.float64)
        return np.full(P, beta * float(np.var(counts)) / fairness_scale)
    b = resolve_backend(backend, P * S, form="index", num_shards=num_shards)
    if b == "numpy":
        t = times[idx].max(axis=1) / time_scale
        w = 2.0 * counts + 1.0
        wsum = w[idx].sum(axis=1)
        c1 = float(np.sum(counts))
        if delta_fairness:
            f = wsum / K - (2.0 * c1 * S + S * S) / (K * K)
        else:
            c2 = float(np.sum(np.square(counts, dtype=np.float64)))
            f = (c2 + wsum) / K - ((c1 + S) / K) ** 2
        return alpha * t + beta * f / fairness_scale
    # jax (pallas has no index-form kernel; the gather path is already tiny)
    import jax.numpy as jnp

    counts_c = counts.astype(np.float64) - float(np.mean(counts))
    if num_shards and num_shards > 1:
        from repro.core import shard

        stats = shard.plan_stats_sharded(times, counts_c, idx, "index",
                                         num_shards)
        return _score_from_stats(stats, counts_c, alpha, beta,
                                 time_scale, fairness_scale, delta_fairness)
    fn = _jax_score_idx_fn(bool(delta_fairness))
    out = fn(jnp.asarray(times, jnp.float32),
             jnp.asarray(counts_c, jnp.float32),
             jnp.asarray(idx.astype(np.int32)),
             jnp.float32(alpha), jnp.float32(beta),
             jnp.float32(time_scale), jnp.float32(fairness_scale))
    return np.asarray(out, dtype=np.float64)


def plan_stats_pallas(times: np.ndarray, counts: np.ndarray,
                      plans: np.ndarray, interpret: bool = False) -> np.ndarray:
    """Run the tiled Pallas reduction; (P, 3) [max_t, n_sel, sum(2c+1)]."""
    import jax.numpy as jnp

    from repro.kernels.sched_score import plan_stats

    w = 2.0 * np.asarray(counts, np.float32) + 1.0
    out = plan_stats(jnp.asarray(times, jnp.float32), jnp.asarray(w),
                     jnp.asarray(np.asarray(plans).astype(np.int8)),
                     interpret=interpret)
    return np.asarray(out)


def score_plans_pallas_interpret(times, counts, plans, alpha=1.0, beta=1.0,
                                 time_scale=1.0, fairness_scale=1.0,
                                 delta_fairness=True) -> np.ndarray:
    """Interpreter-mode Pallas scoring — the CPU validation path used by
    tests/test_scoring.py (TPU Pallas does not lower on the CPU backend)."""
    plans = np.asarray(plans)
    if plans.ndim == 1:
        plans = plans[None, :]
    stats = plan_stats_pallas(times, counts, plans, interpret=True)
    return _score_from_stats(stats, np.asarray(counts), alpha, beta,
                             time_scale, fairness_scale, delta_fairness)


def round_time_batch(times: np.ndarray, plans: np.ndarray,
                     backend: Optional[str] = None) -> np.ndarray:
    """(P,) Formula-3 round time (masked max; empty plan -> 0)."""
    times = np.asarray(times)
    plans = np.asarray(plans)
    if plans.ndim == 1:
        plans = plans[None, :]
    b = resolve_backend(backend, plans.size)
    if b == "numpy":
        masked = np.where(plans.astype(bool), times[None, :], -np.inf)
        out = masked.max(axis=1)
        return np.where(np.isfinite(out), out, 0.0)
    import jax.numpy as jnp

    fn = _jax_round_time_fn()
    out = fn(jnp.asarray(times, jnp.float32),
             jnp.asarray(plans.astype(np.int8)))
    return np.asarray(out, dtype=np.float64)


def fairness_batch(counts: np.ndarray, plans: np.ndarray,
                   delta_fairness: bool = False,
                   backend: Optional[str] = None) -> np.ndarray:
    """(P,) Formula-5 fairness (variance of counts + plan; optionally the
    per-round increment Var(c+v) - Var(c))."""
    counts = np.asarray(counts)
    plans = np.asarray(plans)
    if plans.ndim == 1:
        plans = plans[None, :]
    b = resolve_backend(backend, plans.size)
    if b == "numpy":
        f = np.var(counts[None, :] + plans, axis=1)
        if delta_fairness:
            f = f - np.var(counts)
        return f
    # Dedicated sum-only reduction (no wasted masked-max pass).
    import jax.numpy as jnp

    counts_c = counts.astype(np.float64) - float(np.mean(counts))
    fn = _jax_fairness_fn(bool(delta_fairness))
    out = fn(jnp.asarray(counts_c, jnp.float32),
             jnp.asarray(plans.astype(np.int8)))
    return np.asarray(out, dtype=np.float64)
