"""Round-budget estimation (paper Appendix, Formula 13).

Loss_m(r) = 1 / (b0*r + b1) + b2, fitted to the observed (round, loss) history
by least squares on the linearized form, then R_m = (1+0.3) * R_m^c where
R_m^c solves Loss(R) = l_m.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


def fit_loss_curve(rounds: Sequence[int], losses: Sequence[float]) -> Tuple[float, float, float]:
    """Fit (b0, b1, b2) of Loss(r) = 1/(b0 r + b1) + b2.

    b2 is estimated as a fraction of the running minimum (the asymptote must sit
    strictly below every observation for the linearization to be defined), then
    1/(loss - b2) = b0 r + b1 is fit by linear least squares.
    """
    r = np.asarray(rounds, dtype=np.float64)
    l = np.asarray(losses, dtype=np.float64)
    if r.size < 2:
        raise ValueError("need >= 2 observations")
    A = np.stack([r, np.ones_like(r)], axis=1)
    best = None
    # The asymptote b2 must sit below every observation; grid-search the
    # fraction of the running minimum and keep the best reconstruction.
    for frac in (0.0, 0.25, 0.5, 0.7, 0.85, 0.95, 0.99):
        b2 = float(l.min()) * frac
        y = 1.0 / np.maximum(l - b2, 1e-9)
        (b0, b1), *_ = np.linalg.lstsq(A, y, rcond=None)
        b0, b1 = max(b0, 1e-9), max(b1, 1e-9)
        resid = float(np.mean((1.0 / (b0 * r + b1) + b2 - l) ** 2))
        if best is None or resid < best[0]:
            best = (resid, b0, b1, b2)
    _, b0, b1, b2 = best
    return float(b0), float(b1), float(b2)


def rounds_to_target(b0: float, b1: float, b2: float, target_loss: float,
                     safety: float = 0.3, max_rounds: int = 100000) -> int:
    """R_m = ceil((1 + safety) * R_m^c) with R_m^c solving Loss(R)=target."""
    if target_loss <= b2:
        return max_rounds
    rc = (1.0 / (target_loss - b2) - b1) / b0
    rc = max(rc, 1.0)
    return int(min(np.ceil((1.0 + safety) * rc), max_rounds))
