"""Decoder-only LM backbone covering all assigned architecture families.

Layer stacking: homogeneous blocks are weight-STACKED (leading dim = number
of repeats) and iterated with ``lax.scan`` — one compiled block body
regardless of depth (MaxText pattern; keeps the 95-layer deepseek-67b HLO
compact enough to compile for 512 devices on this container's single CPU).
xLSTM alternates mLSTM/sLSTM -> the scanned unit is a PAIR.

Families:
  dense / audio / vlm : x += attn(norm(x)); x += mlp(norm(x))
  moe                 : x += attn(norm(x)); x += moe(norm(x))
  hybrid (hymba)      : h = norm(x); x += mean(attn(h), ssd(h)); x += mlp(norm(x))
  ssm (xlstm)         : x += mlstm(norm(x)); x += slstm(norm(x))   [pair]

Modality frontends are STUBS per the assignment: VLM prepends precomputed
patch embeddings; AUDIO feeds precomputed frame embeddings directly.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ArchFamily, AttentionKind, ModelConfig
from repro.launch.sharding import shard
from repro.models import ssm as ssm_mod
from repro.models.attention import (
    attention_apply,
    attention_decode,
    attention_init,
    init_kv_cache,
    kv_cache_axes,
)
from repro.models.layers import (
    embed_apply,
    embed_init,
    head_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    unembed_apply,
)
from repro.models.moe import moe_apply, moe_init


# ---------------- block init / apply ----------------

def _block_init(cfg: ModelConfig, rng: np.random.Generator):
    fam = cfg.family
    if fam in (ArchFamily.DENSE, ArchFamily.AUDIO, ArchFamily.VLM):
        pa, aa = attention_init(cfg, rng)
        pm, am = mlp_init(cfg, rng)
        n1, an1 = rmsnorm_init(cfg, cfg.d_model)
        n2, an2 = rmsnorm_init(cfg, cfg.d_model)
        return ({"attn": pa, "mlp": pm, "norm1": n1, "norm2": n2},
                {"attn": aa, "mlp": am, "norm1": an1, "norm2": an2})
    if fam == ArchFamily.MOE:
        pa, aa = attention_init(cfg, rng)
        pm, am = moe_init(cfg, rng)
        n1, an1 = rmsnorm_init(cfg, cfg.d_model)
        n2, an2 = rmsnorm_init(cfg, cfg.d_model)
        return ({"attn": pa, "moe": pm, "norm1": n1, "norm2": n2},
                {"attn": aa, "moe": am, "norm1": an1, "norm2": an2})
    if fam == ArchFamily.HYBRID:
        pa, aa = attention_init(cfg, rng)
        ps, as_ = ssm_mod.ssd_init(cfg, rng)
        pm, am = mlp_init(cfg, rng)
        n1, an1 = rmsnorm_init(cfg, cfg.d_model)
        n2, an2 = rmsnorm_init(cfg, cfg.d_model)
        return ({"attn": pa, "ssd": ps, "mlp": pm, "norm1": n1, "norm2": n2},
                {"attn": aa, "ssd": as_, "mlp": am, "norm1": an1, "norm2": an2})
    if fam == ArchFamily.SSM:  # xLSTM pair
        pm, am = ssm_mod.mlstm_init(cfg, rng)
        ps, as_ = ssm_mod.slstm_init(cfg, rng)
        n1, an1 = rmsnorm_init(cfg, cfg.d_model)
        n2, an2 = rmsnorm_init(cfg, cfg.d_model)
        return ({"mlstm": pm, "slstm": ps, "norm1": n1, "norm2": n2},
                {"mlstm": am, "slstm": as_, "norm1": an1, "norm2": an2})
    raise ValueError(fam)


def _block_apply(cfg: ModelConfig, p, x, positions):
    fam = cfg.family
    x = shard(x, "batch", None, "act_embed")
    if fam in (ArchFamily.DENSE, ArchFamily.AUDIO, ArchFamily.VLM):
        x = x + attention_apply(cfg, p["attn"], rmsnorm(p["norm1"], x, cfg.norm_eps), positions)
        x = x + mlp_apply(cfg, p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps))
        return x
    if fam == ArchFamily.MOE:
        x = x + attention_apply(cfg, p["attn"], rmsnorm(p["norm1"], x, cfg.norm_eps), positions)
        x = x + moe_apply(cfg, p["moe"], rmsnorm(p["norm2"], x, cfg.norm_eps))
        return x
    if fam == ArchFamily.HYBRID:
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        x = x + 0.5 * (attention_apply(cfg, p["attn"], h, positions)
                       + ssm_mod.ssd_apply(cfg, p["ssd"], h))
        x = x + mlp_apply(cfg, p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps))
        return x
    if fam == ArchFamily.SSM:
        x = x + ssm_mod.mlstm_apply(cfg, p["mlstm"], rmsnorm(p["norm1"], x, cfg.norm_eps))
        x = x + ssm_mod.slstm_apply(cfg, p["slstm"], rmsnorm(p["norm2"], x, cfg.norm_eps))
        return x
    raise ValueError(fam)


def _num_scan_blocks(cfg: ModelConfig) -> int:
    if cfg.family == ArchFamily.SSM:
        assert cfg.num_layers % 2 == 0, "xLSTM pairs need even num_layers"
        return cfg.num_layers // 2
    return cfg.num_layers


# ---------------- whole-model init ----------------

def lm_init(cfg: ModelConfig, seed: int = 0):
    """Returns (params, logical_axes) — matching pytrees."""
    rng = np.random.default_rng(seed)
    pe, ae = embed_init(cfg, rng)
    ph, ah = head_init(cfg, rng)
    pn, an = rmsnorm_init(cfg, cfg.d_model)

    n_blocks = _num_scan_blocks(cfg)
    from repro.models.layers import is_abstract
    if is_abstract():
        bp, block_as = _block_init(cfg, rng)
        stacked = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((n_blocks,) + tuple(s.shape), s.dtype), bp)
    else:
        block_ps, block_as = [], None
        for _ in range(n_blocks):
            bp, ba = _block_init(cfg, rng)
            block_ps.append(bp)
            block_as = ba
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *block_ps)
    stacked_axes = jax.tree_util.tree_map(
        lambda ax: ("layers",) + ax, block_as,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x))

    params = {"embed": pe, "head": ph, "final_norm": pn, "blocks": stacked}
    axes = {"embed": ae, "head": ah, "final_norm": an, "blocks": stacked_axes}
    return params, axes


def lm_param_shapes(cfg: ModelConfig):
    """ShapeDtypeStructs of lm_init output WITHOUT allocating (for dry-run)."""
    params, axes = jax.eval_shape(lambda: lm_init(cfg, 0)[0]), None
    return params


# ---------------- forward (train / prefill) ----------------

def lm_apply(cfg: ModelConfig, params, tokens: Optional[jnp.ndarray] = None,
             frontend: Optional[jnp.ndarray] = None,
             drop_last_logit: bool = False) -> jnp.ndarray:
    """Returns logits (B, S_total, vocab).

    dense/moe/ssm/hybrid: ``tokens`` (B,S).
    audio (musicgen): ``frontend`` (B,S,d) frame embeddings; no tokens.
    vlm (paligemma): ``frontend`` (B,F,d) patch embeddings + ``tokens`` (B,S_text).
    """
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == ArchFamily.AUDIO:
        x = frontend.astype(dt)
    elif cfg.family == ArchFamily.VLM:
        te = embed_apply(cfg, params["embed"], tokens)
        x = jnp.concatenate([frontend.astype(dt), te], axis=1)
    else:
        x = embed_apply(cfg, params["embed"], tokens)
    B, S, _ = x.shape
    x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)
    x = shard(x, "batch", None, "act_embed")
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    block_fn = functools.partial(_block_apply, cfg)
    if cfg.remat:
        block_fn = jax.checkpoint(block_fn, static_argnums=())

    def body(carry, bp):
        return block_fn(bp, carry, positions), None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if drop_last_logit:
        # Slice BEFORE the unembed: slicing the (B,S,vocab) logits instead
        # put an unconstrained pad on the backward path of the biggest tensor
        # in the program — the partitioner replicated it over the data axis
        # (2x 20 GB collectives on qwen3-8b, §Perf H4c).
        x = x[:, :-1]
    logits = unembed_apply(cfg, params["embed"], params["head"], x)
    return logits


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Vocab-sharding-friendly CE: logsumexp + masked-sum label logit.

    take_along_axis / log_softmax over a vocab-SHARDED axis makes the SPMD
    partitioner all-gather the full logits (20 GB/microbatch on qwen3-8b) and
    all-reduce full-vocab cotangents in backward (§Perf H4b). logsumexp and
    the one-hot contraction reduce per-shard first — the only cross-shard
    traffic is (B, S)-shaped.
    """
    lg = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
    label_logit = jnp.sum(
        jnp.where(vocab_iota == targets[..., None].astype(jnp.int32), lg, 0.0),
        axis=-1)
    return lse - label_logit  # (B, S) nll


def lm_loss(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Next-token cross-entropy. batch: {tokens?, frontend?, labels, loss_mask?}."""
    logits = lm_apply(cfg, params,
                      tokens=batch.get("tokens"), frontend=batch.get("frontend"),
                      drop_last_logit=True)
    labels = batch["labels"]
    S_lab = labels.shape[1] - 1
    logits = logits[:, -S_lab:, :]          # align (frontend prefix carries no labels)
    nll = cross_entropy(logits, labels[:, 1:])
    mask = batch.get("loss_mask")
    if mask is not None:
        mask = mask[:, 1:].astype(jnp.float32)
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


# ---------------- decode (serving) ----------------

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Stacked per-layer decode state + its logical axes."""
    dt = jnp.dtype(cfg.dtype)
    n = _num_scan_blocks(cfg)

    def stack(tree):
        return jax.tree_util.tree_map(lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy(), tree)

    fam = cfg.family
    if fam in (ArchFamily.DENSE, ArchFamily.AUDIO, ArchFamily.VLM, ArchFamily.MOE):
        return {"kv": stack(init_kv_cache(cfg, batch, max_len, dt))}
    if fam == ArchFamily.HYBRID:
        return {"kv": stack(init_kv_cache(cfg, batch, max_len, dt)),
                "ssd": stack(ssm_mod.ssd_decode_state(cfg, batch))}
    if fam == ArchFamily.SSM:
        return {"mlstm": stack(ssm_mod.mlstm_decode_state(cfg, batch)),
                "slstm": stack(ssm_mod.slstm_decode_state(cfg, batch, dt))}
    raise ValueError(fam)


def decode_state_axes(cfg: ModelConfig) -> Dict[str, Any]:
    fam = cfg.family
    kv_ax = jax.tree_util.tree_map(lambda ax: ("layers",) + ax, kv_cache_axes(cfg),
                                   is_leaf=lambda x: isinstance(x, tuple) and all(
                                       isinstance(e, (str, type(None))) for e in x))
    if fam in (ArchFamily.DENSE, ArchFamily.AUDIO, ArchFamily.VLM, ArchFamily.MOE):
        return {"kv": kv_ax}
    if fam == ArchFamily.HYBRID:
        return {"kv": kv_ax,
                "ssd": (("layers", "cache_batch", "cache_heads", None, None),
                        ("layers", "cache_batch", "cache_heads", None))}
    if fam == ArchFamily.SSM:
        return {"mlstm": (("layers", "cache_batch", "cache_heads", None, None),
                          ("layers", "cache_batch", "cache_heads", None)),
                "slstm": (("layers", "cache_batch", "inner"),
                          ("layers", "cache_batch", "inner"),
                          ("layers", "cache_batch", "inner"))}
    raise ValueError(fam)


def _block_decode(cfg: ModelConfig, p, x, state, length):
    fam = cfg.family
    if fam in (ArchFamily.DENSE, ArchFamily.AUDIO, ArchFamily.VLM):
        y, kv = attention_decode(cfg, p["attn"], rmsnorm(p["norm1"], x, cfg.norm_eps),
                                 state["kv"], length)
        x = x + y
        x = x + mlp_apply(cfg, p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps))
        return x, {"kv": kv}
    if fam == ArchFamily.MOE:
        y, kv = attention_decode(cfg, p["attn"], rmsnorm(p["norm1"], x, cfg.norm_eps),
                                 state["kv"], length)
        x = x + y
        x = x + moe_apply(cfg, p["moe"], rmsnorm(p["norm2"], x, cfg.norm_eps))
        return x, {"kv": kv}
    if fam == ArchFamily.HYBRID:
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        ya, kv = attention_decode(cfg, p["attn"], h, state["kv"], length)
        ys, sstate = ssm_mod.ssd_decode(cfg, p["ssd"], h, state["ssd"])
        x = x + 0.5 * (ya + ys)
        x = x + mlp_apply(cfg, p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps))
        return x, {"kv": kv, "ssd": sstate}
    if fam == ArchFamily.SSM:
        y, ms = ssm_mod.mlstm_decode(cfg, p["mlstm"], rmsnorm(p["norm1"], x, cfg.norm_eps),
                                     state["mlstm"])
        x = x + y
        y, ss = ssm_mod.slstm_decode(cfg, p["slstm"], rmsnorm(p["norm2"], x, cfg.norm_eps),
                                     state["slstm"])
        x = x + y
        return x, {"mlstm": ms, "slstm": ss}
    raise ValueError(fam)


def lm_decode_step(cfg: ModelConfig, params, state, tokens: jnp.ndarray,
                   length: jnp.ndarray) -> Tuple[jnp.ndarray, Any]:
    """One decode step. tokens: (B,) int32 (or (B,d) frame embedding for audio);
    length: (B,) current sequence lengths. Returns (logits (B,vocab), new state)."""
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == ArchFamily.AUDIO and tokens.ndim == 2:
        x = tokens.astype(dt)[:, None, :]
    else:
        x = embed_apply(cfg, params["embed"], tokens[:, None])
    x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)

    def body(carry, xs):
        bp, st = xs
        y, new_st = _block_decode(cfg, bp, carry, st, length)
        return y, new_st

    x, new_state = jax.lax.scan(body, x, (params["blocks"], state))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed_apply(cfg, params["embed"], params["head"], x)
    return logits[:, 0], new_state
