"""SSM / recurrent blocks: Mamba2-style SSD heads (Hymba hybrid) and
xLSTM mLSTM / sLSTM blocks.

All sequence mixing funnels through the shared gated-linear-recurrence
primitive ``kernels/ops.linear_scan`` (S_t = a_t S_{t-1} + k_t v_t^T), which
is exactly the TPU-friendly chunked-scan form (the Pallas kernel tiles it);
decode is the O(1) ``linear_scan_step``. This is the documented hardware
adaptation of Mamba's CUDA selective scan (DESIGN.md §3): scalar-per-head
decay (Mamba2/SSD) instead of Mamba1's per-channel gating, because the
outer-product state update maps onto the MXU.

sLSTM (xLSTM) is inherently sequential scalar recurrence; it keeps a
lax.scan over time (O(1) state, tiny math — never a bottleneck).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig
from repro.kernels import ops
from repro.launch.sharding import shard
from repro.models.layers import normal, zeros, _pdtype


# ---------- Mamba2-style SSD heads (used by Hymba's parallel SSM branch) ----------

def ssd_init(cfg: ModelConfig, rng: np.random.Generator):
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    H = max(cfg.num_heads, 1)
    dk = cfg.ssm_state or 16
    s = 1.0 / np.sqrt(d)
    pd = _pdtype(cfg)
    p = {
        "w_in": normal(rng, (d, inner), s, pd),         # value path
        "w_qk": normal(rng, (d, 2 * H * dk), s, pd),     # B,C projections (k,q)
        "w_dt": normal(rng, (d, H), s, pd),              # per-head decay control
        "a_log": zeros((H,), pd),                        # state decay base
        "w_out": normal(rng, (inner, d), 1.0 / np.sqrt(inner), pd),
    }
    a = {
        "w_in": ("embed", "inner"),
        "w_qk": ("embed", "qkv"),
        "w_dt": ("embed", None),
        "a_log": (None,),
        "w_out": ("inner", "embed"),
    }
    return p, a


def ssd_apply(cfg: ModelConfig, p, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B,S,d) -> (B,S,d)."""
    B, S, d = x.shape
    H = max(cfg.num_heads, 1)
    dk = cfg.ssm_state or 16
    inner = cfg.ssm_expand * d
    dv = inner // H
    dt_ = x.dtype
    v = (x @ p["w_in"].astype(dt_)).reshape(B, S, H, dv)
    qk = (x @ p["w_qk"].astype(dt_)).reshape(B, S, H, 2 * dk)
    k, q = qk[..., :dk], qk[..., dk:]
    # decay in (0,1): exp(-softplus(dt) * exp(a_log))
    dt_ctrl = jax.nn.softplus((x @ p["w_dt"].astype(dt_)).astype(jnp.float32))
    decay = jnp.exp(-dt_ctrl * jnp.exp(p["a_log"].astype(jnp.float32))[None, None, :])
    y, _ = ops.linear_scan(q, k, v, decay)
    y = y.reshape(B, S, inner)
    y = shard(y, "batch", None, "act_mlp")
    return y @ p["w_out"].astype(dt_)


def ssd_decode_state(cfg: ModelConfig, batch: int):
    H = max(cfg.num_heads, 1)
    dk = cfg.ssm_state or 16
    dv = cfg.ssm_expand * cfg.d_model // H
    return (jnp.zeros((batch, H, dk, dv), jnp.float32),
            jnp.zeros((batch, H, dk), jnp.float32))


def ssd_decode(cfg: ModelConfig, p, x, state):
    """x: (B,1,d) -> (B,1,d), new state."""
    B = x.shape[0]
    H = max(cfg.num_heads, 1)
    dk = cfg.ssm_state or 16
    inner = cfg.ssm_expand * cfg.d_model
    dv = inner // H
    dt_ = x.dtype
    xt = x[:, 0]
    v = (xt @ p["w_in"].astype(dt_)).reshape(B, H, dv)
    qk = (xt @ p["w_qk"].astype(dt_)).reshape(B, H, 2 * dk)
    k, q = qk[..., :dk], qk[..., dk:]
    dt_ctrl = jax.nn.softplus((xt @ p["w_dt"].astype(dt_)).astype(jnp.float32))
    decay = jnp.exp(-dt_ctrl * jnp.exp(p["a_log"].astype(jnp.float32))[None, :])
    y, state = ops.linear_scan_step(q, k, v, decay, state)
    return (y.reshape(B, 1, inner) @ p["w_out"].astype(dt_)), state


# ---------- xLSTM: mLSTM block ----------

def mlstm_init(cfg: ModelConfig, rng: np.random.Generator):
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    H = cfg.num_heads
    dh = inner // H
    s = 1.0 / np.sqrt(d)
    pd = _pdtype(cfg)
    p = {
        "w_up": normal(rng, (d, 2 * inner), s, pd),      # u (value path), z (output gate)
        "w_qk": normal(rng, (d, 2 * H * dh), s, pd),
        "w_if": normal(rng, (d, 2 * H), s, pd),          # input & forget gates
        "w_down": normal(rng, (inner, d), 1.0 / np.sqrt(inner), pd),
    }
    a = {
        "w_up": ("embed", "inner"),
        "w_qk": ("embed", "qkv"),
        "w_if": ("embed", None),
        "w_down": ("inner", "embed"),
    }
    return p, a


def _mlstm_qkvg(cfg, p, x):
    B = x.shape[0]
    S = x.shape[1] if x.ndim == 3 else 1
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    H = cfg.num_heads
    dh = inner // H
    dt_ = x.dtype
    x2 = x.reshape(B, S, d)
    uz = x2 @ p["w_up"].astype(dt_)
    u, z = uz[..., :inner], uz[..., inner:]
    v = u.reshape(B, S, H, dh)
    qk = (x2 @ p["w_qk"].astype(dt_)).reshape(B, S, H, 2 * dh)
    q, k = qk[..., :dh], qk[..., dh:]
    k = k / jnp.sqrt(jnp.asarray(dh, dt_))
    gates = (x2 @ p["w_if"].astype(dt_)).astype(jnp.float32)
    i_gate = jnp.exp(jnp.minimum(gates[..., :H], 8.0))   # exponential input gate
    f_gate = jax.nn.sigmoid(gates[..., H:] + 1.0)        # forget/decay
    return q, k, v, z, i_gate, f_gate


def mlstm_apply(cfg: ModelConfig, p, x: jnp.ndarray) -> jnp.ndarray:
    B, S, d = x.shape
    inner = cfg.ssm_expand * d
    q, k, v, z, i_gate, f_gate = _mlstm_qkvg(cfg, p, x)
    y, _ = ops.linear_scan(q, k * i_gate[..., None].astype(k.dtype), v, f_gate)
    y = y.reshape(B, S, inner) * jax.nn.silu(z)
    y = shard(y, "batch", None, "act_mlp")
    return y @ p["w_down"].astype(x.dtype)


def mlstm_decode_state(cfg: ModelConfig, batch: int):
    inner = cfg.ssm_expand * cfg.d_model
    H = cfg.num_heads
    dh = inner // H
    return (jnp.zeros((batch, H, dh, dh), jnp.float32),
            jnp.zeros((batch, H, dh), jnp.float32))


def mlstm_decode(cfg: ModelConfig, p, x, state):
    B = x.shape[0]
    inner = cfg.ssm_expand * cfg.d_model
    q, k, v, z, i_gate, f_gate = _mlstm_qkvg(cfg, p, x)
    y, state = ops.linear_scan_step(
        q[:, 0], (k * i_gate[..., None].astype(k.dtype))[:, 0], v[:, 0], f_gate[:, 0], state)
    y = y.reshape(B, 1, inner) * jax.nn.silu(z)
    return y @ p["w_down"].astype(x.dtype), state


# ---------- xLSTM: sLSTM block (scalar recurrence, sequential) ----------

def slstm_init(cfg: ModelConfig, rng: np.random.Generator):
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    H = cfg.num_heads
    dh = inner // H
    s = 1.0 / np.sqrt(d)
    pd = _pdtype(cfg)
    p = {
        "w_x": normal(rng, (d, 4 * inner), s, pd),       # z, i, f, o pre-activations
        # BLOCK-DIAGONAL recurrence (xLSTM paper): each head recurs only on
        # itself -> (H, dh, 4*dh) instead of a dense (inner, 4*inner).
        "r_h": normal(rng, (H, dh, 4 * dh), 1.0 / np.sqrt(dh), pd),
        "w_down": normal(rng, (inner, d), 1.0 / np.sqrt(inner), pd),
    }
    a = {"w_x": ("embed", "inner"), "r_h": ("heads", None, None),
         "w_down": ("inner", "embed")}
    return p, a


def _slstm_cell(p, carry, xt, inner):
    """One sLSTM step with exponential gating + normalizer state.
    xt: (B, 4*inner) input pre-activations; h: (B, inner)."""
    h, c, n = carry
    H, dh = p["r_h"].shape[0], p["r_h"].shape[1]
    B = h.shape[0]
    hh = h.reshape(B, H, dh)
    rec = jnp.einsum("bhd,hdf->bhf", hh.astype(jnp.float32),
                     p["r_h"].astype(jnp.float32))       # (B,H,4*dh)
    z, i, f, o = jnp.split(rec, 4, axis=-1)
    xz, xi, xf, xo = [t.reshape(B, H, dh) for t in
                      jnp.split(xt.astype(jnp.float32), 4, axis=-1)]
    i = jnp.exp(jnp.minimum(xi + i, 8.0))
    f = jax.nn.sigmoid(xf + f + 1.0)
    c = f * c.reshape(B, H, dh) + i * jnp.tanh(xz + z)
    n = f * n.reshape(B, H, dh) + i
    h_new = jax.nn.sigmoid(xo + o) * (c / jnp.maximum(n, 1.0))
    return (h_new.reshape(B, inner).astype(xt.dtype),
            c.reshape(B, inner), n.reshape(B, inner))


def slstm_apply(cfg: ModelConfig, p, x: jnp.ndarray) -> jnp.ndarray:
    B, S, d = x.shape
    inner = cfg.ssm_expand * d
    dt_ = x.dtype
    xs = (x @ p["w_x"].astype(dt_))                      # (B,S,4*inner)
    h0 = jnp.zeros((B, inner), dt_)
    c0 = jnp.zeros((B, inner), jnp.float32)
    n0 = jnp.zeros((B, inner), jnp.float32)

    def step(carry, xt):
        carry = _slstm_cell(p, carry, xt, inner)
        return carry, carry[0]

    _, hs = jax.lax.scan(step, (h0, c0, n0), jnp.moveaxis(xs, 1, 0))
    y = jnp.moveaxis(hs, 0, 1)                            # (B,S,inner)
    y = shard(y, "batch", None, "act_mlp")
    return y @ p["w_down"].astype(dt_)


def slstm_decode_state(cfg: ModelConfig, batch: int, dtype):
    inner = cfg.ssm_expand * cfg.d_model
    return (jnp.zeros((batch, inner), dtype),
            jnp.zeros((batch, inner), jnp.float32),
            jnp.zeros((batch, inner), jnp.float32))


def slstm_decode(cfg: ModelConfig, p, x, state):
    B = x.shape[0]
    inner = cfg.ssm_expand * cfg.d_model
    xt = (x[:, 0] @ p["w_x"].astype(x.dtype))
    state = _slstm_cell(p, state, xt, inner)
    y = state[0][:, None, :]
    return y @ p["w_down"].astype(x.dtype), state
