"""GQA attention block: init, train/prefill apply, and KV-cache decode.

Routes the inner product through kernels/ops.py so the same module runs the
pure-jnp oracle (CPU, dry-run) or the Pallas flash kernels (TPU).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import AttentionKind, ModelConfig
from repro.kernels import ops
from repro.launch.sharding import shard
from repro.models.layers import normal, ones, rope, use_param, _pdtype


def attention_init(cfg: ModelConfig, rng: np.random.Generator):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s = 1.0 / np.sqrt(d)
    pd = _pdtype(cfg)
    p = {
        "wq": normal(rng, (d, h * hd), s, pd),
        "wk": normal(rng, (d, kv * hd), s, pd),
        "wv": normal(rng, (d, kv * hd), s, pd),
        "wo": normal(rng, (h * hd, d), 1.0 / np.sqrt(h * hd), pd),
    }
    # GQA (kv < h): kv projections are small — REPLICATE their columns over
    # the model axis and compute k/v redundantly per shard. Column-sharding
    # them forced an all-gather of the (B,S,kv*hd) activations every block
    # (fwd + recompute + bwd transpose), ~8% of step collective traffic on
    # qwen3-8b (§Perf H8). MHA (kv == h) keeps the sharded projection.
    kv_ax = ("embed", "qkv") if kv == h else ("embed", None)
    a = {"wq": ("embed", "qkv"), "wk": kv_ax, "wv": kv_ax,
         "wo": ("qkv", "embed")}
    if cfg.qk_norm:
        p["q_norm"] = ones((hd,), pd)
        p["k_norm"] = ones((hd,), pd)
        a["q_norm"] = (None,)
        a["k_norm"] = (None,)
    return p, a


def _qk_norm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def _project_qkv(cfg: ModelConfig, p, x, positions):
    B, S, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = x.dtype
    q = (x @ use_param(p["wq"], dt, "embed", "qkv")).reshape(B, S, h, hd)
    k = (x @ use_param(p["wk"], dt, "embed", "qkv")).reshape(B, S, kv, hd)
    v = (x @ use_param(p["wv"], dt, "embed", "qkv")).reshape(B, S, kv, hd)
    if cfg.qk_norm:
        q = _qk_norm(q, p["q_norm"])
        k = _qk_norm(k, p["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if S > 1:
        # Prefill/train: shard the head axis (uneven allowed). Decode writes
        # k/v into the cache whose layout is fixed by cache_heads — pinning
        # the 1-token projections differently forced a full-cache reshard
        # every step (caught on musicgen decode_32k).
        q = shard(q, "batch", None, "act_heads", None)
        k = shard(k, "batch", None, "act_heads", None)
        v = shard(v, "batch", None, "act_heads", None)
    return q, k, v


def attention_apply(cfg: ModelConfig, p, x, positions) -> jnp.ndarray:
    """Causal self-attention over the full sequence (train / prefill)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions)
    window = cfg.sliding_window if cfg.attention == AttentionKind.SLIDING else None
    out = ops.attention(q, k, v, causal=True, window=window)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    out = shard(out, "batch", None, "act_mlp")
    return out @ use_param(p["wo"], x.dtype, "qkv", "embed")


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    if cfg.attention == AttentionKind.SLIDING:
        max_len = min(max_len, cfg.sliding_window)
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, kv, hd), dtype),
    }


def kv_cache_axes(cfg: ModelConfig) -> Dict:
    return {"k": ("cache_batch", "cache_seq", "cache_heads", None),
            "v": ("cache_batch", "cache_seq", "cache_heads", None)}


def attention_decode(cfg: ModelConfig, p, x, cache: Dict, length: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode. x: (B,1,d); cache k/v: (B,T,kv,hd); length: (B,).

    Sliding-window archs use a ring buffer of size ``sliding_window`` (the
    cache position is length % window); full attention writes at ``length``.
    """
    B = x.shape[0]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    positions = length[:, None]  # (B,1) absolute position of the new token
    q, k, v = _project_qkv(cfg, p, x, positions)
    T = cache["k"].shape[1]
    slot = (length % T) if cfg.attention == AttentionKind.SLIDING else length
    bidx = jnp.arange(B)
    new_k = cache["k"].at[bidx, slot].set(k[:, 0])
    new_v = cache["v"].at[bidx, slot].set(v[:, 0])
    eff_len = jnp.minimum(length + 1, T)
    out = ops.decode_attention(q[:, 0], new_k, new_v, eff_len)
    out = out.reshape(B, 1, h * hd)
    y = out @ use_param(p["wo"], x.dtype, "qkv", "embed")
    return y, {"k": new_k, "v": new_v}
