"""Model zoo: composable LM backbone (dense/MoE/SSM/hybrid/VLM/audio) +
the paper's CNN classifiers, all pure JAX.

Submodules import lazily so the FL plane (cnn_zoo) never pays LM import cost.
"""

from repro.models.cnn_zoo import cnn_apply, cnn_init, cnn_loss_and_accuracy

__all__ = [
    "cnn_apply",
    "cnn_init",
    "cnn_loss_and_accuracy",
]
