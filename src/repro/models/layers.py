"""Shared transformer layers: RMSNorm, RoPE, MLP variants, embeddings.

Params are plain dicts; every tensor has a parallel "logical axes" tuple used
by launch/sharding.py. Initializers take a numpy Generator so model building
is deterministic and host-side (no device traffic at init).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig

_abstract = threading.local()


@contextlib.contextmanager
def abstract_init():
    """Inside this context every initializer returns ShapeDtypeStructs —
    zero host allocation. The dry-run builds trillion-parameter models with
    it; the logical-axes trees are identical either way."""
    _abstract.on = True
    try:
        yield
    finally:
        _abstract.on = False


def is_abstract() -> bool:
    return getattr(_abstract, "on", False)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def normal(rng: np.random.Generator, shape, scale, dtype):
    if is_abstract():
        return jax.ShapeDtypeStruct(shape, dtype)
    return jnp.asarray(rng.normal(0.0, scale, shape), dtype=dtype)


def ones(shape, dtype):
    if is_abstract():
        return jax.ShapeDtypeStruct(tuple(shape), dtype)
    return jnp.ones(shape, dtype)


def zeros(shape, dtype):
    if is_abstract():
        return jax.ShapeDtypeStruct(tuple(shape), dtype)
    return jnp.zeros(shape, dtype)


def use_param(w, dtype, *logical_axes):
    """Cast a (possibly f32-stored) weight to the compute dtype and RE-PIN its
    sharding. Without the constraint after the cast, the SPMD partitioner is
    free to all-gather the f32 original and cast afterwards — which it did
    (§Perf H2): pinning forces FSDP/TP weight collectives to move bf16.
    """
    from repro.launch.sharding import shard

    y = w.astype(dtype)
    if logical_axes:
        y = shard(y, *logical_axes)
    return y


# ---- RMSNorm ----

def rmsnorm_init(cfg: ModelConfig, dim: int):
    return {"scale": ones((dim,), _pdtype(cfg))}, {"scale": ("embed",)}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# ---- RoPE ----

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D) rotated by positions (..., S).

    Angles are computed in f32 (position precision matters at 500k ctx) but
    cos/sin are CAST TO x.dtype before the rotation: keeping the multiply in
    f32 promoted the whole k tensor to f32 ahead of its GQA all-gather —
    doubling that collective (§Perf H3, measured in the deepseek-67b HLO).
    """
    d = x.shape[-1]
    half = d // 2
    freq = (1.0 / theta) ** (jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---- MLP (SwiGLU / GeGLU / GELU) ----

def mlp_init(cfg: ModelConfig, rng: np.random.Generator):
    d, f = cfg.d_model, cfg.d_ff
    s_in = 1.0 / np.sqrt(d)
    s_out = 1.0 / np.sqrt(f)
    pd = _pdtype(cfg)
    if cfg.mlp_kind in ("swiglu", "geglu"):
        p = {
            "w_gate": normal(rng, (d, f), s_in, pd),
            "w_up": normal(rng, (d, f), s_in, pd),
            "w_down": normal(rng, (f, d), s_out, pd),
        }
        a = {
            "w_gate": ("embed", "mlp"),
            "w_up": ("embed", "mlp"),
            "w_down": ("mlp", "embed"),
        }
    else:  # plain gelu
        p = {
            "w_up": normal(rng, (d, f), s_in, pd),
            "w_down": normal(rng, (f, d), s_out, pd),
        }
        a = {"w_up": ("embed", "mlp"), "w_down": ("mlp", "embed")}
    return p, a


def mlp_apply(cfg: ModelConfig, p, x):
    from repro.launch.sharding import shard

    dt = x.dtype
    if cfg.mlp_kind in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_kind == "swiglu" else jax.nn.gelu
        h = act(x @ use_param(p["w_gate"], dt, "embed", "mlp")) * (
            x @ use_param(p["w_up"], dt, "embed", "mlp"))
    else:
        h = jax.nn.gelu(x @ use_param(p["w_up"], dt, "embed", "mlp"))
    h = shard(h, "batch", None, "act_mlp")
    return h @ use_param(p["w_down"], dt, "mlp", "embed")


# ---- Embeddings ----

def embed_init(cfg: ModelConfig, rng: np.random.Generator):
    # N(0, 1/sqrt(d)): with the sqrt(d) input multiplier this gives unit-scale
    # activations, and tied-unembedding logits stay O(|x|).
    p = {"embedding": normal(rng, (cfg.vocab_size, cfg.d_model),
                             1.0 / np.sqrt(cfg.d_model), _pdtype(cfg))}
    a = {"embedding": ("vocab", "embed")}
    return p, a


def embed_apply(cfg: ModelConfig, p, tokens):
    return p["embedding"].astype(_dtype(cfg))[tokens]


def unembed_apply(cfg: ModelConfig, emb_p, head_p, x):
    from repro.launch.sharding import shard

    if cfg.tie_embeddings:
        w = emb_p["embedding"].astype(x.dtype).T
    else:
        w = head_p["w"].astype(x.dtype)
    # Pin (batch, seq, vocab-shard): left free, the partitioner replicated
    # the ~20 GB logits across the data axis to simplify the loss reduction
    # (§Perf H4c — two f32 all-gathers + one all-reduce of the full logits).
    return shard(x @ w, "batch", None, "act_vocab")


def head_init(cfg: ModelConfig, rng: np.random.Generator):
    if cfg.tie_embeddings:
        return {}, {}
    p = {"w": normal(rng, (cfg.d_model, cfg.vocab_size), 1.0 / np.sqrt(cfg.d_model), _pdtype(cfg))}
    # vocab-only sharding (§Perf H4): sharding the d_model (contraction) dim
    # over "data" made every logits matmul emit PARTIAL sums -> an all-reduce
    # of the full (B,S,vocab/16) f32 logits (9.7 GB/microbatch on qwen3-8b).
    # Vocab-sharded weights keep logits local; the weight is replicated over
    # "data" (~150 MB/device for the largest vocab) — a >20x collective win.
    a = {"w": (None, "vocab")}
    return p, a
