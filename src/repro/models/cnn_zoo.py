"""The paper's CNN model zoo in pure JAX (NHWC, lax conv).

Built from the ``cnn_spec`` mini-language in configs/paper_models.py.
Params are a flat list of per-layer dicts so they vmap/aggregate trivially
(FedAvg = weighted tree-mean over a stacked leading axis).
"""

from __future__ import annotations

import threading
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig


def _conv_init(rng, k, c_in, c_out):
    fan_in = k * k * c_in
    w = rng.normal(0, np.sqrt(2.0 / fan_in), (k, k, c_in, c_out))
    return {"w": jnp.asarray(w, jnp.float32), "b": jnp.zeros((c_out,), jnp.float32)}


def _fc_init(rng, c_in, c_out):
    w = rng.normal(0, np.sqrt(2.0 / c_in), (c_in, c_out))
    return {"w": jnp.asarray(w, jnp.float32), "b": jnp.zeros((c_out,), jnp.float32)}


def cnn_init(cfg: ModelConfig, seed: int = 0) -> List[Dict]:
    rng = np.random.default_rng(seed)
    params: List[Dict] = []
    c = cfg.input_shape[-1]
    spatial = cfg.input_shape[0]
    for layer in cfg.cnn_spec:
        kind = layer[0]
        if kind in ("conv", "convp"):
            _, out_c, k = layer
            params.append(_conv_init(rng, k, c, out_c))
            c = out_c
            if kind == "convp":
                spatial //= 2
        elif kind == "gn":
            params.append({"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))})
        elif kind == "res":
            _, out_c, stride = layer
            blk = {
                "conv1": _conv_init(rng, 3, c, out_c),
                "conv2": _conv_init(rng, 3, out_c, out_c),
            }
            if stride != 1 or c != out_c:
                blk["proj"] = _conv_init(rng, 1, c, out_c)
            params.append(blk)
            c = out_c
            spatial //= stride
        elif kind == "flatten":
            params.append({})
            c = c * spatial * spatial
        elif kind == "fc":
            _, width = layer
            params.append(_fc_init(rng, c, width))
            c = width
        else:
            raise ValueError(kind)
    params.append(_fc_init(rng, c, cfg.num_classes))  # classifier head
    return params


_conv_state = threading.local()
CONV_IMPLS = ("gemm", "lax")


def set_conv_impl(impl: str) -> None:
    """Select the conv/pool lowering: ``gemm`` (default — im2col + matmul
    conv and reshape-max pool, the fast path on CPU) or ``lax``
    (``conv_general_dilated`` + ``reduce_window``, the historical lowering,
    kept as the semantics reference and the faithful pre-refactor benchmark
    baseline). Forward math is identical either way (see
    tests/test_models_smoke.py); max-pool GRADIENTS may route ties
    differently (both valid subgradients — see ``_maxpool2``).

    Flipping the impl clears the jit caches: the flag is resolved at trace
    time, so stale compiled executables would otherwise keep the old conv."""
    assert impl in CONV_IMPLS, impl
    if impl != get_conv_impl():
        jax.clear_caches()
    _conv_state.impl = impl


def get_conv_impl() -> str:
    return getattr(_conv_state, "impl", "gemm")


def _conv_lax(x, p, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _conv(x, p, stride=1):
    """SAME conv as im2col + GEMM.

    Identical math to ``lax.conv_general_dilated`` (same padding layout),
    but routed through a dense matmul: XLA:CPU lowers small-kernel NHWC
    convs through a naive path (~1 GFLOP/s measured on the FL training
    loop) while its GEMM hits the fast vectorized kernels — 10x+ on the
    per-round hot path, forward and backward (the adjoint is GEMMs too).
    """
    if get_conv_impl() == "lax":
        return _conv_lax(x, p, stride)
    w = p["w"]
    k = w.shape[0]
    n, h, wd, c = x.shape
    ho = -(-h // stride)
    wo = -(-wd // stride)
    pad_h = max((ho - 1) * stride + k - h, 0)
    pad_w = max((wo - 1) * stride + k - wd, 0)
    xp = jnp.pad(x, ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
                     (pad_w // 2, pad_w - pad_w // 2), (0, 0)))
    cols = [xp[:, i:i + (ho - 1) * stride + 1:stride,
               j:j + (wo - 1) * stride + 1:stride, :]
            for i in range(k) for j in range(k)]
    patches = jnp.concatenate(cols, axis=-1)          # (N, Ho, Wo, k*k*C)
    y = patches.reshape(n * ho * wo, k * k * c) @ w.reshape(k * k * c, -1)
    return y.reshape(n, ho, wo, -1) + p["b"]


def _maxpool2(x):
    """2x2/2 VALID max-pool. Forward is identical under both lowerings
    (ragged edge dropped); gradients differ only in TIE-BREAKING at equal
    window maxima (common: ReLU zeros) — both are valid subgradients. The
    default reshape-max form's gradient is invariant to extra vmap lanes;
    ``select_and_scatter`` (the ``lax`` path's backward) broke
    fused-vs-unfused bitwise parity because its tie choice differed between
    the batched and unbatched lowerings."""
    if get_conv_impl() == "lax":
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    n, h, w, c = x.shape
    x = x[:, : h // 2 * 2, : w // 2 * 2, :]
    x = x.reshape(n, h // 2, 2, w // 2, 2, c)
    return x.max(axis=(2, 4))


def _groupnorm(x, p, groups=8):
    n, h, w, c = x.shape
    g = min(groups, c)
    xg = x.reshape(n, h, w, g, c // g)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + 1e-5)
    return xg.reshape(n, h, w, c) * p["scale"] + p["bias"]


def cnn_apply(params: List[Dict], cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """x: (N, H, W, C) -> logits (N, num_classes)."""
    i = 0
    for layer in cfg.cnn_spec:
        kind = layer[0]
        p = params[i]
        if kind == "conv":
            x = jax.nn.relu(_conv(x, p))
        elif kind == "convp":
            x = _maxpool2(jax.nn.relu(_conv(x, p)))
        elif kind == "gn":
            x = _groupnorm(x, p)
        elif kind == "res":
            _, out_c, stride = layer
            h = jax.nn.relu(_conv(x, p["conv1"], stride))
            h = _conv(h, p["conv2"])
            sc = _conv(x, p["proj"], stride) if "proj" in p else x
            x = jax.nn.relu(h + sc)
        elif kind == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif kind == "fc":
            x = jax.nn.relu(x @ p["w"] + p["b"])
        i += 1
    head = params[-1]
    return x @ head["w"] + head["b"]


def cnn_loss_and_accuracy(params, cfg: ModelConfig, x, y) -> Tuple[jnp.ndarray, jnp.ndarray]:
    logits = cnn_apply(params, cfg, x)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
    acc = (logits.argmax(-1) == y).mean()
    return loss, acc
