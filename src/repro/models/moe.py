"""Mixture-of-Experts block: top-k router + LOCAL expert dispatch (shard_map).

Communication-minimal EP layout (the naive global sort/scatter version
produced 2x f32[T*k, d] all-reduces per layer — 51 GB/device/block on dbrx —
because GSPMD cannot shard a global argsort/scatter-add; caught in the
dry-run and redesigned):

- Experts are sharded over the "model" mesh axis and REPLICATED over "data"
  (no FSDP on expert weights: ZeRO-gathering them per layer would dwarf the
  activation traffic).
- Tokens stay batch-sharded over ("pod","data"). Under shard_map, every
  (data i, model j) device routes ITS tokens, buckets only the experts OWNED
  by model-shard j (capacity C_loc = ceil(T_local*k/E * cf), overflow drops),
  runs the local grouped matmul (kernels/ops.moe_gmm -> Pallas on TPU), and
  combines into a PARTIAL (T_local, d) output.
- One psum over "model" completes the combine — identical collective volume
  to a dense TP MLP's output all-reduce.

Without an active mesh (unit tests) the same local function runs with all
experts local — bitwise-identical math.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config.base import ModelConfig
from repro.kernels import ops
from repro.launch.sharding import _active_mesh, current_rules
from repro.models.layers import normal, _pdtype

CAPACITY_FACTOR = 1.25


def moe_init(cfg: ModelConfig, rng: np.random.Generator):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    pd = _pdtype(cfg)
    p = {
        "router": normal(rng, (d, E), s_in, pd),
        "w_gate": normal(rng, (E, d, f), s_in, pd),
        "w_up": normal(rng, (E, d, f), s_in, pd),
        "w_down": normal(rng, (E, f, d), s_out, pd),
    }
    a = {
        "router": (None, None),              # small; replicated
        # STORAGE: experts over "model" (EP) AND the contraction dim over
        # "data" (ZeRO-3) — EP-only storage replicated each expert across the
        # 16 data shards (kimi-k2: 129.7 GB/device, 8x over HBM; caught by
        # memory_analysis). The shard_map all-gathers the local experts'
        # weights per layer (compute stays EP-local).
        "w_gate": ("experts", "embed", None),
        "w_up": ("experts", "embed", None),
        "w_down": ("experts", "mlp_zero", None),
    }
    return p, a


def moe_apply(cfg: ModelConfig, p, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B,S,d) -> (B,S,d)."""
    mesh = _active_mesh()
    E = cfg.num_experts
    rules = current_rules()
    model_axes = tuple(a for a in rules.get("experts", ())
                       if mesh is not None and a in mesh.axis_names)
    n_model = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                           for a in model_axes])) if (mesh and model_axes) else 1

    if mesh is None or n_model == 1 or E % n_model != 0:
        return _moe_local(cfg, p, x, 0, E).astype(x.dtype)

    batch_axes = tuple(a for a in rules.get("batch", ()) if a in mesh.axis_names)
    E_local = E // n_model
    maxis = model_axes[0]
    bspec = (tuple(batch_axes) if len(batch_axes) > 1
             else (batch_axes[0] if batch_axes else None))
    # ZeRO storage axis for the expert weights' contraction dims (d for
    # gate/up, f for down): present and divisible -> gather inside.
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    zaxis = "data" if ("data" in mesh.axis_names
                       and cfg.d_model % axis_sizes["data"] == 0
                       and cfg.d_ff % axis_sizes["data"] == 0) else None

    def shard_fn(router, wg, wu, wd, xl):
        # model-axis rank of this shard -> which experts it owns.
        j = jax.lax.axis_index(maxis)
        if zaxis is not None:
            # ZeRO-3: weights stored contraction-dim-sharded over data;
            # gather the LOCAL experts' full weights for this layer's gmm.
            wg = jax.lax.all_gather(wg, zaxis, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, zaxis, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, zaxis, axis=1, tiled=True)
        p_local = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        partial = _moe_local(cfg, p_local, xl, j * E_local, E_local)
        return jax.lax.psum(partial, maxis)

    zspec = zaxis  # None -> replicated storage (small-expert fallback)
    y = jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(None, None), P(maxis, zspec, None), P(maxis, zspec, None),
                  P(maxis, zspec, None), P(bspec, None, None)),
        out_specs=P(bspec, None, None), check_vma=False)(
        p["router"], p["w_gate"], p["w_up"], p["w_down"], x)
    return y.astype(x.dtype)


def _moe_local(cfg, p, x, e_start, E_local: int):
    """Route + bucket + grouped-matmul for the E_local experts owned locally.

    x: (B_l, S, d) local tokens (full d); e_start may be a traced scalar
    (lax.axis_index under shard_map) or a static int (no-mesh path).
    Returns the PARTIAL output (B_l, S, d) of the local experts only.
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    C = int(math.ceil(T * k / E * CAPACITY_FACTOR))
    dt = x.dtype
    xf = x.reshape(T, d)

    logits = (xf @ p["router"].astype(dt)).astype(jnp.float32)
    weights, ids = jax.lax.top_k(jax.nn.softmax(logits, -1), k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    flat_e = ids.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    flat_w = weights.reshape(-1)

    hit = (flat_e >= e_start) & (flat_e < e_start + E_local)
    e_rel = jnp.where(hit, flat_e - e_start, E_local)
    order = jnp.argsort(e_rel, stable=True)
    e_sorted = e_rel[order]
    tok_sorted = flat_tok[order]
    w_sorted = jnp.where(hit[order], flat_w[order], 0.0)

    counts = jnp.bincount(e_sorted, length=E_local + 1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - starts[e_sorted]

    xg = jnp.zeros((E_local, C, d), dt).at[e_sorted, pos].set(
        xf[tok_sorted], mode="drop")

    h = jax.nn.silu(ops.moe_gmm(xg, p["w_gate"].astype(dt))) * ops.moe_gmm(
        xg, p["w_up"].astype(dt))
    yg = ops.moe_gmm(h, p["w_down"].astype(dt))

    ok = (e_sorted < E_local) & (pos < C)
    w_eff = jnp.where(ok, w_sorted, 0.0)
    yf = jnp.zeros((T, d), jnp.float32).at[tok_sorted].add(
        (yg[jnp.minimum(e_sorted, E_local - 1), jnp.minimum(pos, C - 1)]
         * w_eff[:, None].astype(dt)).astype(jnp.float32))
    return yf.reshape(B, S, d)
