"""Configuration system: typed configs for models, shapes, meshes, training, FL."""

from repro.config.base import (
    ArchFamily,
    AttentionKind,
    FLConfig,
    JobConfig,
    MeshConfig,
    ModelConfig,
    OptimizerConfig,
    ShapeConfig,
    TrainConfig,
)
from repro.config.shapes import SHAPES, shape_applicable
from repro.config.registry import get_arch, list_archs, register_arch

__all__ = [
    "ArchFamily",
    "AttentionKind",
    "FLConfig",
    "JobConfig",
    "MeshConfig",
    "ModelConfig",
    "OptimizerConfig",
    "ShapeConfig",
    "TrainConfig",
    "SHAPES",
    "shape_applicable",
    "get_arch",
    "list_archs",
    "register_arch",
]
