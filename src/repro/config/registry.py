"""Architecture registry: ``--arch <id>`` resolution.

Each module in ``repro/configs/`` registers its ModelConfig at import time;
``get_arch`` lazily imports the package so CLI users just pass the id.
"""

from __future__ import annotations

import importlib
from typing import Callable, Dict, List

from repro.config.base import ModelConfig

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register_arch(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def _ensure_loaded() -> None:
    importlib.import_module("repro.configs")


def get_arch(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)
