"""The assigned input-shape set (identical for all 10 LM archs)."""

from __future__ import annotations

from repro.config.base import ModelConfig, ShapeConfig

SHAPES = {
    "train_4k": ShapeConfig("train_4k", seq_len=4096, global_batch=256, mode="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, mode="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32768, global_batch=128, mode="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524288, global_batch=1, mode="decode"),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k needs sub-quadratic attention (see DESIGN.md §5 shape skips)."""
    if shape.name == "long_500k":
        return model.subquadratic
    return True
