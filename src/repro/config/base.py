"""Typed configuration dataclasses.

One ``ModelConfig`` describes every architecture family in the zoo
(dense / MoE / SSM / hybrid / VLM / audio decoder-only LM backbones, plus the
paper's CNN classifiers used by the federated plane). Field semantics follow
public configs; see ``repro/configs/<arch>.py`` for the assigned instances.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional, Sequence, Tuple


class ArchFamily(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    SSM = "ssm"
    HYBRID = "hybrid"
    VLM = "vlm"
    AUDIO = "audio"
    CNN = "cnn"  # paper-plane classifiers


class AttentionKind(str, enum.Enum):
    FULL = "full"          # full causal attention (quadratic)
    SLIDING = "sliding"    # sliding-window attention (sub-quadratic)
    NONE = "none"          # attention-free (pure SSM/recurrent)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (decoder-only LM backbone unless family=CNN)."""

    name: str
    family: ArchFamily = ArchFamily.DENSE

    # Transformer backbone.
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0  # 0 -> d_model // num_heads
    qk_norm: bool = False
    mlp_kind: str = "swiglu"  # swiglu | geglu | gelu
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # Attention behaviour.
    attention: AttentionKind = AttentionKind.FULL
    sliding_window: int = 4096  # used when attention == SLIDING

    # MoE.
    num_experts: int = 0
    experts_per_token: int = 0
    moe_dense_first_n: int = 0   # leading dense layers before MoE blocks (e.g. kimi)
    num_shared_experts: int = 0

    # SSM / recurrent.
    ssm_state: int = 0           # per-head SSM state width
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    slstm_every: int = 0         # xLSTM: every n-th block is sLSTM (0 = none)

    # Hybrid (parallel attention + SSM heads, Hymba-style).
    hybrid_parallel: bool = False

    # Modality frontend stubs (precomputed embeddings provided by input_specs).
    frontend_tokens: int = 0     # number of prepended frontend embedding positions
    frontend_dim: int = 0        # embedding dim of the frontend stub (== d_model)

    # CNN-family (paper plane) description: sequence of layer specs.
    cnn_spec: Tuple = ()
    input_shape: Tuple[int, ...] = ()
    num_classes: int = 0

    # Numerics / memory policy.
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"     # parameter storage dtype
    remat: bool = True               # checkpoint at block boundaries

    def __post_init__(self):
        if self.family != ArchFamily.CNN:
            assert self.d_model > 0 and self.num_layers > 0, self.name
            if self.num_heads:
                hd = self.head_dim or self.d_model // self.num_heads
                object.__setattr__(self, "head_dim", hd)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_recurrent(self) -> bool:
        return self.family in (ArchFamily.SSM, ArchFamily.HYBRID)

    @property
    def subquadratic(self) -> bool:
        """True if the arch supports O(1)-state or windowed decode at 500k ctx."""
        return self.attention in (AttentionKind.SLIDING, AttentionKind.NONE) or self.is_recurrent

    # ---- parameter counting (used for roofline MODEL_FLOPS = 6·N·D) ----

    def param_count(self) -> int:
        if self.family == ArchFamily.CNN:
            return _cnn_param_count(self)
        d, h, kv, hd, f = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim, self.d_ff
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d  # q, k+v, o
        if self.qk_norm:
            attn += 2 * hd
        per_layer = attn + 2 * d  # two norms
        if self.is_moe:
            moe_layers = self.num_layers - self.moe_dense_first_n
            dense_layers = self.moe_dense_first_n
            expert_ff = 3 * d * f  # gate/up/down (SwiGLU)
            per_moe = attn + 2 * d + self.num_experts * expert_ff + d * self.num_experts
            per_moe += self.num_shared_experts * expert_ff
            dense_f = f if dense_layers else 0
            per_dense = attn + 2 * d + 3 * d * (dense_f or f)
            body = moe_layers * per_moe + dense_layers * per_dense
        elif self.family == ArchFamily.SSM:
            # xLSTM-style: mLSTM block ~ qkv proj + gates; approx via expand factor
            inner = self.ssm_expand * d
            per_layer = 2 * d + 3 * d * inner + inner * d + 4 * inner
            body = self.num_layers * per_layer
        elif self.family == ArchFamily.HYBRID:
            inner = self.ssm_expand * d
            ssm = 2 * d * inner + inner * (self.ssm_state * 2 + 1) + inner * d
            per_layer = attn + ssm + 2 * d + 3 * d * f
            body = self.num_layers * per_layer
        else:
            mlp_mats = 2 if self.mlp_kind == "gelu" else 3
            per_layer += mlp_mats * d * f
            body = self.num_layers * per_layer
        emb = self.vocab_size * d
        out = 0 if self.tie_embeddings else self.vocab_size * d
        return body + emb + out + d  # final norm

    def active_param_count(self) -> int:
        """Activated params per token (MoE: routed top-k + shared only)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        expert_ff = 3 * d * f
        total = self.param_count()
        inactive = (self.num_layers - self.moe_dense_first_n) * (
            (self.num_experts - self.experts_per_token) * expert_ff
        )
        return total - inactive


def _cnn_param_count(cfg: ModelConfig) -> int:
    """Parameter count for the CNN zoo, derived from the spec tuples."""
    n = 0
    c = cfg.input_shape[-1]
    spatial = cfg.input_shape[0]
    for layer in cfg.cnn_spec:
        kind = layer[0]
        if kind == "conv":
            _, out_c, k = layer
            n += k * k * c * out_c + out_c
            c = out_c
        elif kind == "convp":
            _, out_c, k = layer
            n += k * k * c * out_c + out_c
            c = out_c
            spatial //= 2
        elif kind == "gn":
            n += 2 * c
        elif kind == "res":
            _, out_c, stride = layer
            n += 9 * c * out_c + out_c + 9 * out_c * out_c + out_c
            if stride != 1 or c != out_c:
                n += c * out_c + out_c  # 1x1 projection shortcut
            c = out_c
            spatial //= stride
        elif kind == "pool":
            spatial //= layer[1]
        elif kind == "flatten":
            c = c * spatial * spatial
        elif kind == "fc":
            _, width = layer
            n += c * width + width
            c = width
    n += c * cfg.num_classes + cfg.num_classes
    return n


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """An input-shape cell: (seq_len, global_batch, mode)."""

    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        return math.prod(self.shape)


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"  # sgd | momentum | adam | adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    momentum: float = 0.9
    grad_clip: float = 1.0
    # Gradient compression (FL / cross-pod): 0 disables.
    topk_compress_ratio: float = 0.0
    error_feedback: bool = True


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    microbatches: int = 1            # gradient accumulation steps
    remat_policy: str = "block"      # none | block | full
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class FLConfig:
    """Federated plane configuration (the paper's experimental setting)."""

    num_devices: int = 100
    devices_per_round_ratio: float = 0.1   # C_m — paper samples 10% of devices
    local_epochs: int = 5                  # τ_m
    batch_size: int = 32
    # Cost weights (Formula 2). The paper sets these empirically ("increase
    # alpha for fast convergence, increase beta for high accuracy"); these
    # defaults are tuned on the synthetic-runtime sweep in EXPERIMENTS.md.
    alpha: float = 4.0                     # time-cost weight
    beta: float = 0.25                     # fairness-cost weight
    non_iid: bool = True
    classes_per_device: int = 2            # paper's non-IID split
    parts_per_class: int = 20
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class JobConfig:
    """One FL job: a model trained to a target metric."""

    job_id: int
    model: ModelConfig
    target_metric: float            # target accuracy (paper uses accuracy in place of loss)
    max_rounds: int = 200           # R_m
    local_epochs: int = 5           # τ_m
    batch_size: int = 32
    lr: float = 0.05
