"""``FaultEngine``: the replayable per-round fault schedule.

The engine realizes a ``FaultSpec`` as concrete per-round fault draws.
Every draw comes from a COUNTER-KEYED generator —
``np.random.default_rng([seed, purpose, job, round_idx])`` over the full
device axis — so the schedule is a pure function of (spec, job, round):

- order-independent: jobs launching in a different interleaving (service
  resume, engine refactors) see identical faults;
- multi-reader: the training runtime recomputes the exact corrupt mask
  the engine drew, with no plumbing between them;
- resume-safe: a restored run replays the same faults without having to
  persist any stream position.

The only MUTABLE state is the strike counter behind escalating
quarantine (a fold over realized failures) and it round-trips through
``state_dict``/``load_state_dict`` for checkpointing.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.faults.spec import FaultSpec

# Draw purposes (the second RNG key word). Distinct per fault class so the
# classes are independent of each other at equal (job, round).
_SALT_DOMAIN_ASSIGN = 0
_SALT_DROPOUT = 1
_SALT_CRASH = 2
_SALT_STRAGGLER = 3
_SALT_DOMAIN_OUTAGE = 4
_SALT_CORRUPT = 5


class FaultEngine:
    """Realizes a ``FaultSpec`` for a ``num_devices``-sized fleet."""

    def __init__(self, spec: FaultSpec, num_devices: int):
        self.spec = spec
        self.num_devices = int(num_devices)
        # Escalating-quarantine strike counts (consecutive transient
        # failures per device; reset on a completed round).
        self.strikes = np.zeros(self.num_devices, dtype=np.int64)
        if spec.num_domains > 0:
            rng = np.random.default_rng([int(spec.seed), _SALT_DOMAIN_ASSIGN])
            self.domain = rng.integers(spec.num_domains,
                                       size=self.num_devices)
        else:
            self.domain = None

    # ---- keyed draws (stateless, replayable) ----

    def _uniform(self, salt: int, job: int, round_idx: int,
                 n: int) -> np.ndarray:
        rng = np.random.default_rng(
            [int(self.spec.seed), int(salt), int(job), int(round_idx)])
        return rng.random(n)

    def straggler_multipliers(self, job: int, round_idx: int) -> np.ndarray:
        """(K,) multiplicative slowdown on realized compute times (1.0 for
        unaffected devices); None when the spec has no stragglers."""
        sp = self.spec
        if sp.straggler_rate <= 0.0:
            return None
        slow = self._uniform(_SALT_STRAGGLER, job, round_idx,
                             self.num_devices) < sp.straggler_rate
        return np.where(slow, sp.straggler_slowdown, 1.0)

    def failure_masks(self, job: int, round_idx: int
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(transient (K,), crash (K,), domain_out (K,)) bool masks for one
        round. ``domain_out`` marks correlated (whole-domain) outages —
        disjoint from ``transient`` so the engine can apply the outage
        duration instead of backoff escalation."""
        sp, K = self.spec, self.num_devices
        transient = (self._uniform(_SALT_DROPOUT, job, round_idx, K)
                     < sp.dropout_rate if sp.dropout_rate > 0.0
                     else np.zeros(K, dtype=bool))
        crash = (self._uniform(_SALT_CRASH, job, round_idx, K)
                 < sp.crash_rate if sp.crash_rate > 0.0
                 else np.zeros(K, dtype=bool))
        if self.domain is not None and sp.domain_outage_rate > 0.0:
            out = self._uniform(_SALT_DOMAIN_OUTAGE, job, round_idx,
                                sp.num_domains) < sp.domain_outage_rate
            domain_out = out[self.domain]
        else:
            domain_out = np.zeros(K, dtype=bool)
        transient &= ~domain_out  # outage semantics win for domain members
        return transient, crash, domain_out

    def corrupt_mask(self, job: int, round_idx: int,
                     device_ids: np.ndarray) -> np.ndarray:
        """(len(ids),) bool — which of these devices upload a corrupted
        model this round. Keyed over the FULL device axis, so the engine
        and the runtime agree regardless of which subset each asks about."""
        ids = np.asarray(device_ids)
        if self.spec.corrupt_rate <= 0.0 or ids.size == 0:
            return np.zeros(ids.shape, dtype=bool)
        u = self._uniform(_SALT_CORRUPT, job, round_idx, self.num_devices)
        return u[ids] < self.spec.corrupt_rate

    # ---- escalating quarantine (the stateful fold) ----

    def quarantine_durations(self, device_ids: np.ndarray) -> np.ndarray:
        """Register transient failures and return each device's quarantine:
        ``cooldown * backoff**(strikes-1)`` capped at ``max_cooldown``."""
        ids = np.asarray(device_ids)
        if ids.size == 0:
            return np.zeros(0)
        self.strikes[ids] += 1
        d = self.spec.cooldown * self.spec.backoff ** (
            self.strikes[ids] - 1.0)
        return np.minimum(d, self.spec.max_cooldown)

    def record_success(self, device_ids: np.ndarray) -> None:
        """A completed round resets the strike counter (readmission)."""
        ids = np.asarray(device_ids)
        if ids.size:
            self.strikes[ids] = 0

    # ---- persistence ----

    def state_dict(self) -> dict:
        return {"strikes": self.strikes.copy()}

    def load_state_dict(self, tree: dict) -> None:
        self.strikes = np.asarray(tree["strikes"], dtype=np.int64).copy()
