"""Deterministic, replayable fault injection (the resilience layer).

``FaultSpec`` declares WHAT goes wrong (crash/dropout/straggler/domain/
corruption rates, quarantine backoff, round deadline); ``FaultEngine``
realizes it as counter-keyed per-round draws any layer can replay
independently. See ``repro.faults.spec`` for the taxonomy.
"""

from repro.faults.engine import FaultEngine
from repro.faults.spec import CORRUPT_MODES, FaultSpec

__all__ = ["FaultSpec", "FaultEngine", "CORRUPT_MODES"]
