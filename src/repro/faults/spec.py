"""``FaultSpec``: the declarative fault model of one experiment.

One frozen, JSON-round-trippable axis describes everything that can go
wrong with a device mid-round:

- **Transient dropouts** (``dropout_rate``): the device fails this round,
  is excluded from aggregation, and is quarantined with EXPONENTIAL
  BACKOFF — ``cooldown * backoff**(strikes-1)`` seconds, capped at
  ``max_cooldown``; a successfully completed round resets the strike
  counter (readmission).
- **Crash faults** (``crash_rate``): the device is gone for good
  (``busy_until = inf`` — same semantics as fleet departure).
- **Straggler slowdowns** (``straggler_rate``/``straggler_slowdown``): a
  slowed device's realized compute time is multiplied — the tail the
  engine's over-provisioning cut and ``round_deadline`` both absorb.
- **Correlated fault domains** (``num_domains``/``domain_outage_rate``):
  devices are statically binned into racks/regions; a domain outage drops
  every scheduled device in the domain at once and parks them for
  ``domain_outage_duration`` seconds (no backoff escalation — the rack
  came back, the devices did nothing wrong).
- **Corrupted updates** (``corrupt_rate``/``corrupt_mode``): the device
  finishes on time but uploads garbage — all-NaN parameters
  (``"nan"``) or a delta blown up by ``corrupt_scale`` (``"scale"``).
  Robust runtimes (``TrainSpec.robust``) inject and reject these inside
  the fused round; otherwise the engine oracle-discards them before
  aggregation.
- **Deadline rounds** (``round_deadline``): FedCS-style partial
  aggregation — survivors slower than the deadline are cut and the round
  aggregates the on-time cohort only.

Every draw is keyed on ``(seed, purpose, job, round_idx)`` — NOT on a
shared stateful stream — so the schedule is replayable: any layer
(engine, runtime, a resumed service) independently recomputes the exact
same faults for a given round, in any order, any number of times.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

CORRUPT_MODES = ("nan", "scale")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Declarative fault model (see module docstring for semantics)."""

    seed: int = 0
    # Transient dropouts + escalating quarantine.
    dropout_rate: float = 0.0
    cooldown: float = 60.0
    backoff: float = 2.0
    max_cooldown: float = 3600.0
    # Permanent crashes.
    crash_rate: float = 0.0
    # Straggler slowdown multipliers.
    straggler_rate: float = 0.0
    straggler_slowdown: float = 3.0
    # Correlated fault domains (racks/regions). 0 domains = uncorrelated.
    num_domains: int = 0
    domain_outage_rate: float = 0.0
    domain_outage_duration: float = 500.0
    # Corrupted / NaN model updates.
    corrupt_rate: float = 0.0
    corrupt_mode: str = "nan"
    corrupt_scale: float = 100.0
    # FedCS-style per-round deadline (simulated seconds); None = no deadline.
    round_deadline: Optional[float] = None

    def __post_init__(self):
        if self.corrupt_mode not in CORRUPT_MODES:
            raise ValueError(f"corrupt_mode {self.corrupt_mode!r} not in "
                             f"{CORRUPT_MODES}")
        if self.backoff < 1.0:
            raise ValueError("backoff must be >= 1 (quarantines never shrink "
                             "with repeated failures)")
        for name in ("dropout_rate", "crash_rate", "straggler_rate",
                     "domain_outage_rate", "corrupt_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be a probability, got {v}")

    @property
    def inert(self) -> bool:
        """True when this spec injects nothing (the engine skips the fault
        path entirely)."""
        return (self.dropout_rate == 0.0 and self.crash_rate == 0.0
                and self.straggler_rate == 0.0
                and (self.num_domains == 0 or self.domain_outage_rate == 0.0)
                and self.corrupt_rate == 0.0
                and self.round_deadline is None)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FaultSpec":
        return cls(**d)

    @classmethod
    def from_legacy(cls, failure_rate: float, failure_cooldown: float = 60.0,
                    seed: int = 0) -> "FaultSpec":
        """Map the deprecated ``failure_rate``/``failure_cooldown`` engine
        kwargs onto the axis: uniform transient dropouts with a FIXED
        quarantine (``backoff=1``), matching the historical semantics."""
        return cls(seed=seed, dropout_rate=float(failure_rate),
                   cooldown=float(failure_cooldown), backoff=1.0,
                   max_cooldown=float(failure_cooldown))
