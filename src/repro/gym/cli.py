"""Gym CLI: train, evaluate, and list learned scheduler policies.

  python -m repro.gym train --name rlds-full --curriculum full \\
      --num-devices 64,256 --iters 80 --zoo policies
  python -m repro.gym eval --name rlds-full --curriculum default
  python -m repro.gym list

``train`` runs batched REINFORCE over the chosen curriculum (one stage per
pool size), reports trained-vs-untrained mean cost on held-out scenarios,
and saves the policy to the zoo. The saved name plugs straight into the
experiment CLI::

  python -m repro.experiment.cli preset quickstart \\
      --arg scheduler=rlds --set policy=rlds-full --run
"""

from __future__ import annotations

import argparse
import json
import sys

import jax

from repro.gym.scenarios import CURRICULA
from repro.gym.train import (TrainConfig, default_stages, evaluate,
                             train_rlds)
from repro.gym.zoo import DEFAULT_ZOO_DIR, PolicyZoo, save_rlds_params


def _stages(args):
    sizes = tuple(int(k) for k in str(args.num_devices).split(","))
    return default_stages(args.curriculum, num_devices=sizes,
                          num_jobs=args.num_jobs,
                          n_sel_frac=args.n_sel_frac), sizes


def cmd_train(args) -> None:
    from repro.core.schedulers.rlds import init_policy

    stages, sizes = _stages(args)
    tcfg = TrainConfig(num_envs=args.envs, rollout_len=args.rollout,
                       iters=args.iters, lr=args.lr,
                       minibatches=args.minibatches)
    print(f"training {args.name!r}: curriculum={args.curriculum} "
          f"K={sizes} E={tcfg.num_envs} T={tcfg.rollout_len} "
          f"iters={tcfg.iters}")
    params, logs = train_rlds(stages, tcfg, seed=args.seed)
    for log in logs[:: max(1, len(logs) // 10)]:
        print(f"  iter {log['iter']:4d} stage {log['stage']} "
              f"mean_cost={log['mean_cost']:.4f} "
              f"({log['wall_s'] * 1e3:.0f} ms)")

    # Held-out comparison vs a fresh (untrained) policy on paired scenarios.
    cfg, scen = stages[0]
    untrained = init_policy(jax.random.PRNGKey(args.seed + 1))
    ev_t = evaluate(cfg, scen, params, seed=args.seed + 2)
    ev_u = evaluate(cfg, scen, untrained, seed=args.seed + 2)
    print(f"eval (K={cfg.num_devices}): trained mean_cost="
          f"{ev_t['mean_cost']:.4f}  untrained={ev_u['mean_cost']:.4f}")

    zoo = PolicyZoo(args.zoo)
    meta = {"curriculum": args.curriculum, "num_devices": list(sizes),
            "num_jobs": args.num_jobs, "iters": tcfg.iters,
            "seed": args.seed, "eval_trained_cost": ev_t["mean_cost"],
            "eval_untrained_cost": ev_u["mean_cost"]}
    path = save_rlds_params(zoo, args.name, params, num_jobs=args.num_jobs,
                            lr=args.lr, meta=meta)
    print(f"saved -> {path}\nuse it: python -m repro.experiment.cli preset "
          f"quickstart --arg scheduler=rlds --set policy={args.name} "
          f"--set policy_dir={args.zoo} --run")


def cmd_eval(args) -> None:
    from repro.core.schedulers.rlds import RLDSScheduler
    from repro.core.cost import CostModel
    from repro.core.devices import DevicePool

    stages, _ = _stages(args)
    cfg, scen = stages[0]
    zoo = PolicyZoo(args.zoo)
    # Materialize via a scratch scheduler so the restore path is the same
    # one the experiment layer uses.
    pool = DevicePool.heterogeneous(cfg.num_devices, cfg.num_jobs, seed=0)
    sched = RLDSScheduler(CostModel(pool), seed=0, pretrain_rounds=0)
    meta = zoo.load_into(args.name, sched)
    ev = evaluate(cfg, scen, sched.params, seed=args.seed)
    print(json.dumps({"name": args.name, "meta": meta, "eval": ev}, indent=2))


def cmd_list(args) -> None:
    zoo = PolicyZoo(args.zoo)
    names = zoo.names()
    if not names:
        print(f"(no policies in {args.zoo!r})")
    for name in names:
        info = zoo.info(name)
        print(f"{name:24s} kind={info.get('kind', '?'):5s} "
              f"meta={json.dumps(info.get('meta', {}))}")


def _common(p) -> None:
    p.add_argument("--zoo", default=DEFAULT_ZOO_DIR,
                   help="policy zoo root directory")
    p.add_argument("--curriculum", default="default",
                   choices=sorted(CURRICULA))
    p.add_argument("--num-devices", default="64",
                   help="comma-separated pool sizes (one stage each)")
    p.add_argument("--num-jobs", type=int, default=3)
    p.add_argument("--n-sel-frac", type=float, default=0.1)
    p.add_argument("--seed", type=int, default=0)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.gym", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_tr = sub.add_parser("train", help="train an RLDS policy in the gym")
    p_tr.add_argument("--name", required=True, help="policy zoo entry name")
    p_tr.add_argument("--envs", type=int, default=32)
    p_tr.add_argument("--rollout", type=int, default=32)
    p_tr.add_argument("--iters", type=int, default=80)
    p_tr.add_argument("--lr", type=float, default=1e-2)
    p_tr.add_argument("--minibatches", type=int, default=4)
    _common(p_tr)
    p_tr.set_defaults(fn=cmd_train)

    p_ev = sub.add_parser("eval", help="evaluate a saved policy in the gym")
    p_ev.add_argument("--name", required=True)
    _common(p_ev)
    p_ev.set_defaults(fn=cmd_eval)

    p_ls = sub.add_parser("list", help="list zoo policies")
    p_ls.add_argument("--zoo", default=DEFAULT_ZOO_DIR)
    p_ls.set_defaults(fn=cmd_list)

    args = ap.parse_args(argv)
    args.fn(args)


if __name__ == "__main__":
    main(sys.argv[1:])
