"""Scheduler gym: vectorized pure-JAX training environments, the REINFORCE
trainer that replaces RLDS constructor pre-training, and the policy zoo.

    from repro.gym import EnvConfig, train_rlds, default_stages, PolicyZoo

    params, logs = train_rlds(default_stages("full", num_devices=(64, 256)))
    zoo = PolicyZoo("policies")
    save_rlds_params(zoo, "rlds-full", params, num_jobs=3)
    # then: ExperimentSpec(..., scheduler="rlds", policy="rlds-full")

Shell entry point: ``python -m repro.gym train|eval|list``.
"""

from repro.gym.env import (
    EnvConfig,
    EnvState,
    StepOut,
    Transition,
    batch_reset,
    batch_rollout,
    config_from_cost_model,
    greedy_plan,
    policy_rollout,
    reset,
    sample_plan,
    state_from_pool,
    step,
)
from repro.gym.scenarios import CURRICULA, ScenarioSpec
from repro.gym.train import (
    TrainConfig,
    default_stages,
    evaluate,
    train_rlds,
)
from repro.gym.zoo import DEFAULT_ZOO_DIR, PolicyZoo, save_rlds_params

__all__ = [
    "CURRICULA",
    "DEFAULT_ZOO_DIR",
    "EnvConfig",
    "EnvState",
    "PolicyZoo",
    "ScenarioSpec",
    "StepOut",
    "TrainConfig",
    "Transition",
    "batch_reset",
    "batch_rollout",
    "config_from_cost_model",
    "default_stages",
    "evaluate",
    "greedy_plan",
    "policy_rollout",
    "reset",
    "sample_plan",
    "save_rlds_params",
    "state_from_pool",
    "step",
    "train_rlds",
]
