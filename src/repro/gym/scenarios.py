"""Scenario randomization and curricula for the scheduler gym.

A ``ScenarioSpec`` is a static (hashable) description of the DISTRIBUTION a
gym environment draws its episode from: capability heterogeneity, device
fluctuation, data-size spread, job mix (local epochs), and failure rate.
``sample_scenario`` draws one concrete scenario per reset — under ``vmap``
every parallel environment gets an independent draw, so a single training
batch spans the whole curriculum.

Pool-SIZE diversity is the one axis that cannot vary inside a batch (array
shapes are static under jit); the trainer handles it by cycling through
curriculum STAGES with different ``EnvConfig.num_devices`` (see
``repro.gym.train.default_stages``).

The named ``CURRICULA`` map to the ROADMAP's scenario axes: the default
paper-like regime, extreme heterogeneity, flaky fleets, mixed job
complexity, and the all-of-the-above "full" curriculum.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """Per-episode scenario distribution (static under jit).

    ``a_lo`` anchors the fastest device class; each episode draws a
    heterogeneity SPREAD in decades from ``hetero_decades`` and scatters
    device capabilities log-uniformly across it — so one batch contains
    both near-homogeneous and 100x-spread fleets. ``tau_range`` draws
    per-job local epochs (the job mix); ``failure_range`` draws the
    episode's device drop probability.
    """

    a_lo: float = 2e-4
    hetero_decades: Tuple[float, float] = (0.7, 1.3)
    mu_range: Tuple[float, float] = (1.0, 10.0)
    data_range: Tuple[float, float] = (200.0, 600.0)
    tau_range: Tuple[int, int] = (5, 5)
    failure_range: Tuple[float, float] = (0.0, 0.0)
    # Online-traffic axis (mirrors the repro.serve service's dynamic job
    # sets): each job arrives at a step drawn from ``arrival_window`` and
    # stays for a lifetime drawn from ``lifetime`` (both in global env
    # steps); (0, 0) means every job is live for the whole episode. Job 0
    # is always anchored live so an episode never goes fully idle. Inactive
    # jobs are plan-masked in rollouts — an empty plan is a zero-cost,
    # zero-gradient no-op round.
    arrival_window: Tuple[float, float] = (0.0, 0.0)
    lifetime: Tuple[float, float] = (0.0, 0.0)
    # Fault axes beyond uniform dropouts (mirroring repro.faults.FaultSpec):
    # per-episode straggler rate (devices whose compute time is multiplied
    # by ``straggler_slowdown``) and correlated fault domains — devices are
    # scattered over ``num_domains`` groups and a whole group drops together
    # with per-round probability drawn from ``domain_outage_range``.
    straggler_range: Tuple[float, float] = (0.0, 0.0)
    straggler_slowdown: float = 3.0
    num_domains: int = 0
    domain_outage_range: Tuple[float, float] = (0.0, 0.0)


CURRICULA: Dict[str, ScenarioSpec] = {
    # Paper-like regime: the DevicePool.heterogeneous defaults (10x spread).
    "default": ScenarioSpec(),
    # Edge fleets with up to ~300x capability spread.
    "hetero": ScenarioSpec(hetero_decades=(1.0, 2.5)),
    # Unreliable fleets: up to 30% of a cohort drops every round.
    "flaky": ScenarioSpec(failure_range=(0.0, 0.3)),
    # Mixed job complexity: per-job local epochs drawn from [1, 10].
    "mixed-jobs": ScenarioSpec(tau_range=(1, 10)),
    # Everything at once — the hardest training distribution.
    "full": ScenarioSpec(hetero_decades=(0.7, 2.5), tau_range=(1, 10),
                         failure_range=(0.0, 0.3)),
    # Online traffic: jobs arrive mid-episode and depart after a finite
    # lifetime (the repro.serve regime) — policies must stay robust to the
    # fairness-count and occupancy shifts of a changing job mix.
    "arrivals": ScenarioSpec(arrival_window=(0.0, 24.0),
                             lifetime=(8.0, 48.0)),
    # Rich fault regime matching the engine's faults axis: uniform dropouts
    # PLUS stragglers and correlated fault-domain outages — policies must
    # learn that a slow or outage-prone cohort is a cost, not just a risk.
    "faults": ScenarioSpec(failure_range=(0.0, 0.2),
                           straggler_range=(0.0, 0.3),
                           num_domains=8,
                           domain_outage_range=(0.0, 0.05)),
}


class ScenarioDraw(NamedTuple):
    """One concrete scenario (the output of ``sample_scenario``)."""

    a: jax.Array
    mu: jax.Array
    data: jax.Array
    taus: jax.Array
    failure_rate: jax.Array
    job_start: jax.Array
    job_end: jax.Array
    straggler_rate: jax.Array   # ()
    domain: jax.Array           # (K,) int32 fault-domain assignment
    domain_rate: jax.Array      # () per-round whole-domain outage prob


def sample_scenario(key: jax.Array, scen: ScenarioSpec, num_devices: int,
                    num_jobs: int) -> ScenarioDraw:
    """Draw one scenario as a ``ScenarioDraw`` of jnp arrays."""
    (k_spread, k_a, k_mu, k_d, k_tau, k_f, k_s, k_l, k_str,
     k_dom, k_dr) = jax.random.split(key, 11)
    spread = jax.random.uniform(
        k_spread, (), minval=scen.hetero_decades[0],
        maxval=scen.hetero_decades[1])
    # Log-uniform capabilities over the episode's spread (in decades).
    a = scen.a_lo * 10.0 ** (jax.random.uniform(k_a, (num_devices,)) * spread)
    mu = jax.random.uniform(k_mu, (num_devices,), minval=scen.mu_range[0],
                            maxval=scen.mu_range[1])
    data = jax.random.uniform(k_d, (num_devices, num_jobs),
                              minval=scen.data_range[0],
                              maxval=scen.data_range[1])
    taus = jax.random.randint(k_tau, (num_jobs,), scen.tau_range[0],
                              scen.tau_range[1] + 1).astype(jnp.float32)
    failure_rate = jax.random.uniform(k_f, (), minval=scen.failure_range[0],
                                      maxval=scen.failure_range[1])
    # Job activity windows (ScenarioSpec is static, so the no-traffic
    # default compiles the windows away entirely). Job 0 anchors: always
    # live from step 0 for the whole episode.
    if scen.arrival_window == (0.0, 0.0):
        job_start = jnp.zeros((num_jobs,), jnp.float32)
    else:
        job_start = jax.random.uniform(
            k_s, (num_jobs,), minval=scen.arrival_window[0],
            maxval=scen.arrival_window[1]).astype(jnp.float32)
        job_start = job_start.at[0].set(0.0)
    if scen.lifetime == (0.0, 0.0):
        job_end = jnp.full((num_jobs,), jnp.inf, jnp.float32)
    else:
        life = jax.random.uniform(k_l, (num_jobs,), minval=scen.lifetime[0],
                                  maxval=scen.lifetime[1])
        job_end = (job_start + life).astype(jnp.float32).at[0].set(jnp.inf)
    straggler_rate = jax.random.uniform(
        k_str, (), minval=scen.straggler_range[0],
        maxval=scen.straggler_range[1])
    if scen.num_domains > 0:
        domain = jax.random.randint(k_dom, (num_devices,), 0,
                                    scen.num_domains)
        domain_rate = jax.random.uniform(
            k_dr, (), minval=scen.domain_outage_range[0],
            maxval=scen.domain_outage_range[1])
    else:
        domain = jnp.zeros((num_devices,), jnp.int32)
        domain_rate = jnp.zeros((), jnp.float32)
    return ScenarioDraw(
        a.astype(jnp.float32), mu.astype(jnp.float32),
        data.astype(jnp.float32), taus, failure_rate.astype(jnp.float32),
        job_start, job_end, straggler_rate.astype(jnp.float32),
        domain.astype(jnp.int32), domain_rate.astype(jnp.float32))
