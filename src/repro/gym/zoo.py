"""Policy zoo: durable, named storage for trained scheduler state.

Gym-trained RLDS policies, online-trained DNN regressors, and BODS GP
observation rings all persist through ``repro.checkpoint`` (atomic,
manifest-driven .npz pytrees), keyed by a policy NAME under one root
directory::

    policies/<name>/step_0000000000/{manifest.json, arrays.npz, .complete}

The manifest's ``extra`` block records the policy KIND (the scheduler
registry name) and free-form metadata (curriculum, training iters, eval
costs), so ``load_into`` can refuse kind mismatches before touching any
scheduler state. Restores are bit-exact (tested in tests/test_gym.py).

Schedulers participate by exposing ``state_dict() -> pytree`` and
``load_state_dict(pytree)`` (RLDS, DNN, BODS do); the experiment layer
wires the ``ExperimentSpec.policy`` axis through ``load_into`` so a spec
names its warm start declaratively.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint import (committed_steps, load_checkpoint,
                              save_checkpoint, step_path)

DEFAULT_ZOO_DIR = "policies"


class PolicyZoo:
    """Name -> checkpointed scheduler-state pytree, with kind/meta tags."""

    def __init__(self, root: str = DEFAULT_ZOO_DIR):
        self.root = root

    def _dir(self, name: str) -> str:
        return os.path.join(self.root, name)

    # ---- write ----

    def save(self, name: str, kind: str, tree: Any,
             meta: Optional[Dict] = None) -> str:
        """Persist a scheduler state pytree under ``name``; returns the
        committed checkpoint path."""
        return save_checkpoint(self._dir(name), 0, tree,
                               extra={"kind": kind, "meta": meta or {}})

    def save_scheduler(self, name: str, scheduler,
                       meta: Optional[Dict] = None) -> str:
        """Snapshot a live scheduler (anything with ``state_dict``)."""
        return self.save(name, scheduler.name, scheduler.state_dict(), meta)

    # ---- read ----

    def load(self, name: str, like: Any) -> Tuple[Any, str, Dict]:
        """Restore ``name`` into the structure of ``like``; returns
        (tree, kind, meta)."""
        try:
            _, tree, extra = load_checkpoint(self._dir(name), like)
        except FileNotFoundError:
            raise FileNotFoundError(
                f"no policy {name!r} in zoo {self.root!r}; "
                f"known: {self.names()}") from None
        return tree, extra.get("kind", "?"), extra.get("meta", {})

    def load_into(self, name: str, scheduler) -> Dict:
        """Load ``name`` into a live scheduler; returns the policy meta.

        The scheduler must expose ``state_dict``/``load_state_dict`` and its
        registry name must match the saved policy kind.
        """
        # SchedulerBase gives every scheduler an EMPTY state_dict default
        # (service warm hand-off protocol); only learners override it with
        # real state, so an empty tree means there is nothing to load into.
        if not scheduler.state_dict():
            raise TypeError(
                f"scheduler {scheduler.name!r} has an empty state_dict; "
                "only learned schedulers (rlds, dnn, bods) can load zoo "
                "policies")
        # info() raises the known-names FileNotFoundError for missing
        # entries and reads the kind from the manifest BEFORE any arrays
        # materialize, so a mismatched tree structure can't mask the error.
        kind = self.info(name).get("kind", "?")
        if kind != scheduler.name:
            raise ValueError(
                f"policy {name!r} is kind {kind!r}, scheduler is "
                f"{scheduler.name!r}")
        tree, _, meta = self.load(name, like=scheduler.state_dict())
        scheduler.load_state_dict(tree)
        return meta

    def info(self, name: str) -> Dict:
        """Kind + meta of the newest committed step, without materializing
        the arrays. Layout questions (which step, what counts as committed)
        are answered by ``repro.checkpoint`` — the zoo never re-derives the
        on-disk format."""
        steps = committed_steps(self._dir(name))
        if not steps:
            raise FileNotFoundError(
                f"no policy {name!r} in zoo {self.root!r}; "
                f"known: {self.names()}")
        path = os.path.join(step_path(self._dir(name), steps[-1]),
                            "manifest.json")
        with open(path) as f:
            return json.load(f).get("extra", {})

    def names(self) -> List[str]:
        if not os.path.isdir(self.root):
            return []
        return [name for name in sorted(os.listdir(self.root))
                if committed_steps(self._dir(name))]


def save_rlds_params(zoo: PolicyZoo, name: str, params, num_jobs: int,
                     lr: float = 1e-2, meta: Optional[Dict] = None) -> str:
    """Wrap bare gym-trained policy params into a full RLDS scheduler state
    (fresh AdamW moments, unset baselines) and save it.

    The live scheduler's optimizer state is shape-determined by the params,
    so a fresh init is the correct warm start — online fine-tuning resumes
    from step 0 with the trained weights. ``pretrained`` is True: the gym
    training IS the pre-training, so the lazy Algorithm-3 loop is skipped.
    """
    from repro.core.schedulers.rlds import policy_optimizer

    opt_init, _ = policy_optimizer(lr)
    tree = {"params": params, "opt": opt_init(params),
            "baselines": np.full(num_jobs, np.nan),
            "adv_scale": np.asarray(1.0, np.float64),
            "pretrained": np.asarray(True)}
    return zoo.save(name, "rlds", tree, meta=meta)
