"""``python -m repro.gym`` -> the gym CLI."""

import sys

from repro.gym.cli import main

main(sys.argv[1:])
