"""Vectorized pure-JAX multi-job scheduling environment (the scheduler gym).

The live ``MultiJobEngine`` is an event-driven Python loop — correct, but
useless for training learned schedulers at scale: RLDS pre-training needs
millions of scheduling decisions over DIVERSE scenarios, and a Python
round loop delivers thousands. This module is the trainable mirror of the
engine: the whole environment state lives in jnp arrays, ``reset``/``step``
are pure functions, rollouts are a ``lax.scan`` over rounds, and E parallel
environments with independently randomized scenarios run under one ``vmap``.

Semantics mirror ``repro.core.multijob.MultiJobEngine`` (parity-tested in
tests/test_gym.py):

- **Time model** — Formula 4 shifted-exponential realized times, identical
  coefficients to ``DevicePool`` (``t = tau*D*a + Exp(tau*D/mu)``); like the
  pool's SoA fast path, the per-job shift/scale products are materialized
  ONCE at reset so the per-step work is one fused multiply-add.
- **Occupancy** — each scheduled device is busy until ITS OWN finish time;
  a job launches its next round at ``max(own release instant, instant at
  which n_sel devices are free)`` — exactly the engine's retry-until-release
  behaviour, computed in closed form via a top-k over ``busy_until``.
- **Faults** — each scheduled device drops with ``failure_rate``; survivors
  define the round time, failed devices are quarantined for
  ``failure_cooldown`` and excluded from the fairness-count update, and the
  engine's keep-one guard applies when everyone fails.
- **Cost** — Formula 2/3 evaluated through the SAME jitted reductions the
  scoring core uses everywhere else (``repro.core.scoring.jax_*_fn``):
  realized straggler max + fairness-variance increment, normalized by the
  calibrated time/fairness scales.

Jobs are scheduled round-robin (the engine interleaves by completion
events; round-robin is the synchronous projection of that order and keeps
the scan shape static). Per-device policy features mirror
``RLDSScheduler._features`` field for field, so a gym-trained policy drops
into the live scheduler unchanged.

All randomness is explicit ``jax.random`` key splitting carried in the
state — no numpy Generators anywhere in the rollout path. Rollouts
pre-draw the whole trajectory's noise in three bulk calls (exponential
jitter, fault uniforms, Gumbel exploration) instead of 3T scan-interleaved
threefry dispatches — on CPU this alone is worth ~2x env throughput.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import scoring
from repro.gym.scenarios import ScenarioSpec, sample_scenario


class EnvConfig(NamedTuple):
    """Static (hashable) environment shape/coefficients — safe as a jit
    static argument; everything per-scenario lives in ``EnvState``."""

    num_devices: int = 64
    num_jobs: int = 3
    n_sel: int = 6
    alpha: float = 4.0
    beta: float = 0.25
    # Cost fairness form, mirroring CostModel.delta_fairness: True uses the
    # per-round increment Var(c+v) - Var(c), False the absolute Formula-5
    # variance (the engine honors the same flag in its realized cost).
    delta_fairness: bool = True
    failure_cooldown: float = 60.0


class Scenario(NamedTuple):
    """Per-episode coefficients, drawn at reset and fixed until the next.

    Beyond the raw Formula-4 parameters, the scenario carries the
    derived arrays every step would otherwise recompute (mirroring
    ``DevicePool``'s structure-of-arrays fast path): per-job realized-time
    ``shift``/``scale``, per-job expected times ``exp_base``, and the
    max-normalized static policy features.
    """

    a: jax.Array               # (K,) capability floor
    mu: jax.Array              # (K,) fluctuation rate
    data: jax.Array            # (K, M) per-job data sizes
    taus: jax.Array            # (M,) local epochs (job mix)
    failure_rate: jax.Array    # () per-device drop probability
    time_scale: jax.Array      # () calibrated Formula-2 normalizers
    fairness_scale: jax.Array  # ()
    shift: jax.Array           # (M, K) tau*D*a   (realized-time floor)
    scale: jax.Array           # (M, K) tau*D/mu  (exponential scale)
    exp_base: jax.Array        # (M, K) expected times tau*D*(a + 1/mu)
    a_norm: jax.Array          # (K,) a / max(a)        (policy features)
    mu_norm: jax.Array         # (K,) mu / max(mu)
    data_norm: jax.Array       # (K, M) D / max(D)
    # Online-traffic windows (global env steps): job m is live while
    # job_start[m] <= t < job_end[m]; the closed-job-set default is
    # start=0 / end=inf for every job.
    job_start: jax.Array       # (M,)
    job_end: jax.Array         # (M,)
    # Rich fault axes (inert at the zero defaults): per-round straggler
    # slowdowns and correlated fault-domain outages, mirroring the live
    # engine's ``repro.faults`` schedule.
    straggler_rate: jax.Array      # () per-device slowdown probability
    straggler_slowdown: jax.Array  # () compute-time multiplier
    domain: jax.Array              # (K,) int32 fault-domain assignment
    domain_rate: jax.Array         # () per-round whole-domain outage prob


class EnvState(NamedTuple):
    """One environment: scenario + dynamic clocks/counters."""

    scen: Scenario
    busy_until: jax.Array      # (K,) occupancy clocks
    counts: jax.Array          # (M, K) fairness counters s_{k,m}
    round_idx: jax.Array       # (M,) per-job round indices
    job_clock: jax.Array       # (M,) per-job release instants
    job: jax.Array             # () job scheduled at the next step
    t: jax.Array               # () global step counter
    key: jax.Array             # PRNG key (explicit jax.random threading)


class StepOut(NamedTuple):
    """Per-step outcome (the quantities the engine records per round)."""

    cost: jax.Array        # realized Formula-2 cost (delta fairness)
    round_time: jax.Array  # realized Formula-3 straggler max
    fairness: jax.Array    # absolute Formula-5 variance (recorded form)
    dfair: jax.Array       # fairness increment used in the cost
    reward: jax.Array      # -cost (the RLDS reward)
    job: jax.Array         # job index that was scheduled
    now: jax.Array         # launch instant


class Transition(NamedTuple):
    """What a policy rollout collects per step (REINFORCE ingredients)."""

    feats: jax.Array      # (K, F) policy features
    plan: jax.Array       # (K,) bool
    available: jax.Array  # (K,) bool
    reward: jax.Array
    cost: jax.Array
    round_time: jax.Array
    job: jax.Array


# ---- reset ---------------------------------------------------------------

def calibrate_scales(cfg: EnvConfig, exp_base: jax.Array):
    """Mirror ``CostModel.calibrate``: time_scale = median over jobs of the
    median of the n_sel smallest expected times; fairness_scale = p(1-p)."""
    fastest = jnp.sort(exp_base, axis=1)[:, : cfg.n_sel]
    time_scale = jnp.maximum(jnp.median(jnp.median(fastest, axis=1)), 1e-9)
    p = cfg.n_sel / cfg.num_devices
    fairness_scale = jnp.asarray(max(p * (1.0 - p), 1e-6), jnp.float32)
    return time_scale.astype(jnp.float32), fairness_scale


def make_scenario(cfg: Optional[EnvConfig], a, mu, data, taus, failure_rate,
                  time_scale=None, fairness_scale=None,
                  job_start=None, job_end=None,
                  straggler_rate=0.0, straggler_slowdown=3.0,
                  domain=None, domain_rate=0.0) -> Scenario:
    """Materialize the derived per-job arrays (SoA fast path) and calibrate
    the cost normalizers (unless given, e.g. from a live CostModel — then
    ``cfg`` may be None). The fault axes default to inert (no stragglers,
    no fault domains) so legacy callers are untouched."""
    f32 = jnp.float32
    a = jnp.asarray(a, f32)
    mu = jnp.asarray(mu, f32)
    data = jnp.asarray(data, f32)
    taus = jnp.asarray(taus, f32)
    d_t = data.T                                    # (M, K)
    shift = taus[:, None] * d_t * a[None, :]
    scale = taus[:, None] * d_t / mu[None, :]
    exp_base = shift + scale                        # tau*D*(a + 1/mu)
    if time_scale is None or fairness_scale is None:
        time_scale, fairness_scale = calibrate_scales(cfg, exp_base)
    M = d_t.shape[0]
    K = d_t.shape[1]
    if job_start is None:
        job_start = jnp.zeros((M,), f32)
    if job_end is None:
        job_end = jnp.full((M,), jnp.inf, f32)
    if domain is None:
        domain = jnp.zeros((K,), jnp.int32)
    return Scenario(
        a=a, mu=mu, data=data, taus=taus,
        failure_rate=jnp.asarray(failure_rate, f32),
        time_scale=jnp.asarray(time_scale, f32),
        fairness_scale=jnp.asarray(fairness_scale, f32),
        shift=shift, scale=scale, exp_base=exp_base,
        a_norm=a / jnp.max(a), mu_norm=mu / jnp.max(mu),
        data_norm=data / jnp.max(data),
        job_start=jnp.asarray(job_start, f32),
        job_end=jnp.asarray(job_end, f32),
        straggler_rate=jnp.asarray(straggler_rate, f32),
        straggler_slowdown=jnp.asarray(straggler_slowdown, f32),
        domain=jnp.asarray(domain, jnp.int32),
        domain_rate=jnp.asarray(domain_rate, f32))


def _zero_dynamics(cfg: EnvConfig, scen: Scenario, key: jax.Array) -> EnvState:
    K, M = cfg.num_devices, cfg.num_jobs
    return EnvState(
        scen=scen,
        busy_until=jnp.zeros(K, jnp.float32),
        counts=jnp.zeros((M, K), jnp.float32),
        round_idx=jnp.zeros(M, jnp.int32),
        job_clock=jnp.zeros(M, jnp.float32),
        job=jnp.zeros((), jnp.int32),
        t=jnp.zeros((), jnp.int32),
        key=key)


def reset(cfg: EnvConfig, scen_spec: ScenarioSpec, key: jax.Array) -> EnvState:
    """Draw a fresh randomized scenario and zero the dynamic state."""
    k_scen, k_env = jax.random.split(key)
    d = sample_scenario(k_scen, scen_spec, cfg.num_devices, cfg.num_jobs)
    scen = make_scenario(cfg, d.a, d.mu, d.data, d.taus, d.failure_rate,
                         job_start=d.job_start, job_end=d.job_end,
                         straggler_rate=d.straggler_rate,
                         straggler_slowdown=scen_spec.straggler_slowdown,
                         domain=d.domain, domain_rate=d.domain_rate)
    return _zero_dynamics(cfg, scen, k_env)


def batch_reset(cfg: EnvConfig, scen_spec: ScenarioSpec, key: jax.Array,
                num_envs: int) -> EnvState:
    """(E,)-batched reset: E independent scenarios under one vmap."""
    return jax.vmap(lambda k: reset(cfg, scen_spec, k))(
        jax.random.split(key, num_envs))


def state_from_pool(pool, cost_model, taus: Sequence[float],
                    failure_rate: float = 0.0,
                    key: Optional[jax.Array] = None) -> EnvState:
    """EnvState mirroring a CONCRETE ``DevicePool`` + calibrated
    ``CostModel`` — the bridge for engine-parity tests and for training a
    policy against the exact scenario an ``ExperimentSpec`` will run."""
    K, M = pool.num_devices, pool.num_jobs
    assert len(taus) == M, (len(taus), M)
    scen = make_scenario(None, pool.a, pool.mu, pool.data_sizes, taus,
                         failure_rate, time_scale=cost_model.time_scale,
                         fairness_scale=cost_model.fairness_scale)
    return _zero_dynamics(config_from_cost_model(cost_model, n_sel=1), scen,
                          jax.random.PRNGKey(0) if key is None else key)


def config_from_cost_model(cost_model, n_sel: int,
                           failure_cooldown: float = 60.0) -> EnvConfig:
    """EnvConfig matching a live CostModel's pool and coefficients; pass
    the engine's ``failure_cooldown`` so quarantine dynamics match too."""
    return EnvConfig(num_devices=cost_model.pool.num_devices,
                     num_jobs=cost_model.pool.num_jobs, n_sel=n_sel,
                     alpha=float(cost_model.alpha),
                     beta=float(cost_model.beta),
                     delta_fairness=bool(cost_model.delta_fairness),
                     failure_cooldown=float(failure_cooldown))


# ---- step ----------------------------------------------------------------

def release_instant(cfg: EnvConfig, state: EnvState) -> jax.Array:
    """Engine retry semantics in closed form: the job launches at
    ``max(its own release instant, the instant n_sel devices are free)``
    (the n_sel-th smallest occupancy clock)."""
    neg_busy, _ = jax.lax.top_k(-state.busy_until, cfg.n_sel)
    kth_free = -neg_busy[cfg.n_sel - 1]
    return jnp.maximum(state.job_clock[state.job], kth_free)


def available_mask(state: EnvState, now: jax.Array) -> jax.Array:
    return state.busy_until <= now + 1e-6


def job_active(state: EnvState) -> jax.Array:
    """() bool — is the job up for scheduling live at the current step?
    (Online-traffic windows; always True under the closed-set default.)
    Rollouts AND this into the plan: an inactive job's round is an empty
    plan, which ``_apply_round`` treats as a zero-cost, zero-time no-op
    (and an empty plan has zero REINFORCE log-prob, so inactive rounds
    contribute no gradient)."""
    t = state.t.astype(jnp.float32)
    return ((state.scen.job_start[state.job] <= t)
            & (t < state.scen.job_end[state.job]))


def _apply_round(cfg: EnvConfig, state: EnvState, plan: jax.Array,
                 exp_noise: jax.Array, fail_u: jax.Array,
                 straggler_u: Optional[jax.Array] = None,
                 domain_u: Optional[jax.Array] = None
                 ) -> Tuple[EnvState, StepOut]:
    """Deterministic round transition given the stochastic draws.

    ``exp_noise``: (K,) unit-exponential draws (Formula 4's jitter);
    ``fail_u``: (K,) uniforms for the fault coin-flips. Exposed separately
    so rollouts can pre-draw whole trajectories in bulk and so the
    engine-parity test can inject the exact draws the live
    ``DevicePool``/engine consumed.

    The rich-fault draws are optional (None compiles them away entirely):
    ``straggler_u`` (K,) uniforms gating the per-device slowdown
    multiplier; ``domain_u`` (K,) uniforms read PER FAULT DOMAIN —
    ``domain_u[scen.domain]`` correlates the outage coin-flip across every
    device in a domain, mirroring ``repro.faults.FaultEngine``.
    """
    scen = state.scen
    job = state.job
    now = release_instant(cfg, state)

    # Formula 4 realized times from the precomputed per-job shift/scale
    # (selected devices are available => no wait term).
    times = scen.shift[job] + exp_noise * scen.scale[job]
    if straggler_u is not None:
        times = times * jnp.where(straggler_u < scen.straggler_rate,
                                  scen.straggler_slowdown, 1.0)

    sel = plan
    fail = sel & (fail_u < scen.failure_rate)
    if domain_u is not None:
        # One uniform per domain, indexed per device: the whole domain
        # shares a coin-flip, so outages are correlated.
        fail = fail | (sel & (domain_u[scen.domain] < scen.domain_rate))
    survivors = sel & ~fail
    # Engine guard: if every selected device failed, keep the first one.
    first_sel = jax.nn.one_hot(jnp.argmax(sel), cfg.num_devices,
                               dtype=bool) & sel
    survivors = jnp.where(survivors.any(), survivors, first_sel)
    fail = sel & ~survivors

    # Formula 3 via the scoring core's jitted masked-max reduction.
    round_time = scoring.jax_round_time_fn()(times, survivors[None])[0]
    t_end = now + round_time

    busy = jnp.where(sel, now + times, state.busy_until)
    busy = jnp.where(fail, t_end + cfg.failure_cooldown, busy)  # quarantine

    # Formula 2/5 via the scoring core. Counts are mean-centered (f32-safe
    # variance); the absolute Formula-5 value recorded by the engine is the
    # increment plus Var(c) = E[c_centered^2]. The cost term uses the
    # increment or the absolute form per cfg.delta_fairness, exactly as the
    # engine's realized cost does.
    counts_j = state.counts[job]
    counts_c = counts_j - jnp.mean(counts_j)
    dfair = scoring.jax_fairness_fn(True)(counts_c, plan[None])[0]
    fairness = dfair + jnp.mean(jnp.square(counts_c))
    cost_fair = dfair if cfg.delta_fairness else fairness
    cost = (cfg.alpha * round_time / scen.time_scale
            + cfg.beta * cost_fair / scen.fairness_scale)

    new_state = state._replace(
        busy_until=busy,
        counts=state.counts.at[job].add(survivors.astype(jnp.float32)),
        round_idx=state.round_idx.at[job].add(1),
        job_clock=state.job_clock.at[job].set(t_end),
        job=(job + 1) % cfg.num_jobs,
        t=state.t + 1)
    out = StepOut(cost=cost, round_time=round_time, fairness=fairness,
                  dfair=dfair, reward=-cost, job=job, now=now)
    return new_state, out


def step(cfg: EnvConfig, state: EnvState, plan: jax.Array
         ) -> Tuple[EnvState, StepOut]:
    """One scheduling round of the round-robin job under ``plan`` ((K,)
    bool, exactly n_sel available devices)."""
    key, k_t, k_f, k_s, k_d = jax.random.split(state.key, 5)
    exp_noise = jax.random.exponential(k_t, (cfg.num_devices,))
    fail_u = jax.random.uniform(k_f, (cfg.num_devices,))
    # (K,) uniforms cover any domain count <= K; domain_u[scen.domain]
    # reads one shared coin-flip per fault domain.
    straggler_u = jax.random.uniform(k_s, (cfg.num_devices,))
    domain_u = jax.random.uniform(k_d, (cfg.num_devices,))
    return _apply_round(cfg, state._replace(key=key), plan, exp_noise,
                        fail_u, straggler_u, domain_u)


# ---- policy plumbing (mirrors RLDSScheduler) -----------------------------

def device_features(cfg: EnvConfig, state: EnvState, now: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """(K, F) per-device policy features + availability mask.

    Field-for-field mirror of ``RLDSScheduler._features`` (keep in sync):
    [a, mu, E[t]+wait (job-specific), fairness count, availability, D^m].
    The scenario-constant normalizations are precomputed at reset.
    """
    scen = state.scen
    job = state.job
    wait = jnp.maximum(state.busy_until - now, 0.0)
    available = available_mask(state, now)
    exp_t = scen.exp_base[job] + wait
    counts = state.counts[job]
    feats = jnp.stack([
        scen.a_norm,
        scen.mu_norm,
        exp_t / (jnp.max(exp_t) + 1e-12),
        counts / (jnp.max(counts) + 1.0),
        available.astype(jnp.float32),
        scen.data_norm[:, job],
    ], axis=1)
    return feats, available


def plan_from_gumbel(logits: jax.Array, gumbel: jax.Array,
                     available: jax.Array, n_sel: int) -> jax.Array:
    """Gumbel top-k plan from pre-drawn Gumbel noise (Plackett-Luce without
    replacement over the available set).

    Precondition: ``available.sum() >= n_sel`` (``release_instant``
    guarantees it inside rollouts). top_k cannot check this under jit, so
    the result is post-masked with ``available``: a violating caller gets a
    SMALLER plan (caught by ``validate_plan``), never a plan that schedules
    busy devices.
    """
    g = jnp.where(available, logits + gumbel, -jnp.inf)
    _, idx = jax.lax.top_k(g, n_sel)
    return jnp.zeros(logits.shape[0], bool).at[idx].set(True) & available


def sample_plan(key: jax.Array, logits: jax.Array, available: jax.Array,
                n_sel: int) -> jax.Array:
    """On-policy Gumbel top-k plan — the policy-converter sampling RLDS
    uses, minus the host-side ε-swap (Gumbel noise already provides proper
    visitation)."""
    return plan_from_gumbel(logits, jax.random.gumbel(key, logits.shape),
                            available, n_sel)


def greedy_plan(logits: jax.Array, available: jax.Array, n_sel: int
                ) -> jax.Array:
    """Deterministic top-k (the explore=False policy converter). Same
    ``available.sum() >= n_sel`` precondition and post-mask as
    ``plan_from_gumbel``."""
    g = jnp.where(available, logits, -jnp.inf)
    _, idx = jax.lax.top_k(g, n_sel)
    return jnp.zeros(logits.shape[0], bool).at[idx].set(True) & available


def policy_rollout(cfg: EnvConfig, params, state: EnvState, num_steps: int,
                   deterministic: bool = False
                   ) -> Tuple[EnvState, Transition]:
    """``lax.scan`` of the RLDS policy over ``num_steps`` rounds.

    Returns the final state and a (num_steps,)-stacked ``Transition`` — the
    REINFORCE ingredients (features/plan/availability for the log-prob,
    reward for the advantage). All trajectory noise is pre-drawn in three
    bulk ``jax.random`` calls.
    """
    from repro.core.schedulers.rlds import _policy_logits

    K = cfg.num_devices
    key, k_e, k_f, k_g, k_s, k_d = jax.random.split(state.key, 6)
    state = state._replace(key=key)
    exp_noise = jax.random.exponential(k_e, (num_steps, K))
    fail_u = jax.random.uniform(k_f, (num_steps, K))
    gumbel = (jnp.zeros((num_steps, K)) if deterministic
              else jax.random.gumbel(k_g, (num_steps, K)))
    straggler_u = jax.random.uniform(k_s, (num_steps, K))
    domain_u = jax.random.uniform(k_d, (num_steps, K))

    def one(st, xs):
        noise, fu, g, su, du = xs
        now = release_instant(cfg, st)
        feats, available = device_features(cfg, st, now)
        logits = _policy_logits(params, feats)
        plan = plan_from_gumbel(logits, g, available, cfg.n_sel)
        plan = plan & job_active(st)
        st, out = _apply_round(cfg, st, plan, noise, fu, su, du)
        return st, Transition(feats=feats, plan=plan, available=available,
                              reward=out.reward, cost=out.cost,
                              round_time=out.round_time, job=out.job)

    return jax.lax.scan(one, state,
                        (exp_noise, fail_u, gumbel, straggler_u, domain_u))


def batch_rollout(cfg: EnvConfig, params, states: EnvState, num_steps: int,
                  deterministic: bool = False
                  ) -> Tuple[EnvState, Transition]:
    """vmap of ``policy_rollout`` over E environments: transitions come back
    (E, num_steps, ...)."""
    return jax.vmap(
        lambda s: policy_rollout(cfg, params, s, num_steps, deterministic)
    )(states)


def random_rollout(cfg: EnvConfig, state: EnvState, num_steps: int
                   ) -> Tuple[EnvState, StepOut]:
    """Uniform-random-plan rollout (no policy): the env-only throughput
    workload and the random-scheduler baseline. Identical environment
    machinery to ``policy_rollout`` minus the policy network."""
    K = cfg.num_devices
    key, k_e, k_f, k_g, k_s, k_d = jax.random.split(state.key, 6)
    state = state._replace(key=key)
    noise = (jax.random.exponential(k_e, (num_steps, K)),
             jax.random.uniform(k_f, (num_steps, K)),
             jax.random.gumbel(k_g, (num_steps, K)),
             jax.random.uniform(k_s, (num_steps, K)),
             jax.random.uniform(k_d, (num_steps, K)))

    def one(st, xs):
        e, fu, g, su, du = xs
        now = release_instant(cfg, st)
        available = available_mask(st, now)
        plan = plan_from_gumbel(jnp.zeros(K), g, available, cfg.n_sel)
        plan = plan & job_active(st)
        return _apply_round(cfg, st, plan, e, fu, su, du)

    return jax.lax.scan(one, state, noise)


def batch_random_rollout(cfg: EnvConfig, states: EnvState, num_steps: int
                         ) -> Tuple[EnvState, StepOut]:
    return jax.vmap(lambda s: random_rollout(cfg, s, num_steps))(states)
