"""Batched REINFORCE over the scheduler gym (the scalable Algorithm 3).

Replaces RLDS's sequential constructor pre-training loop: instead of 300
Python rounds against one fixed pool, the trainer runs E vectorized
environments with independently randomized scenarios, collects E*T
scheduling decisions per jitted iteration, and updates the policy with the
same REINFORCE gradient the live scheduler uses (``rlds._reinforce_grads``
— one gradient path, offline and online):

    rollout (vmap + lax.scan)  ->  EMA-baseline advantages (per job,
    batch-standardized)        ->  shuffled minibatched AdamW updates.

Curriculum stages with different pool sizes cycle in the outer Python loop
(shapes are static under jit, so K cannot vary inside a batch); everything
else — heterogeneity, failure rate, job mix — varies per environment inside
a single batch via ``ScenarioSpec`` sampling.

The trained params drop directly into ``RLDSScheduler`` (same policy
network, same feature map) through the policy zoo + the ExperimentSpec
``policy`` axis.
"""

from __future__ import annotations

import functools
import time
from typing import Dict, List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedulers.rlds import (_reinforce_grads, init_policy,
                                        policy_optimizer)
from repro.gym.env import EnvConfig, batch_reset, batch_rollout
from repro.gym.scenarios import CURRICULA, ScenarioSpec


class TrainConfig(NamedTuple):
    """Trainer knobs (static under jit)."""

    num_envs: int = 32       # E parallel environments per iteration
    rollout_len: int = 32    # T rounds per environment per iteration
    iters: int = 80          # total jitted iterations (across all stages)
    lr: float = 1e-2
    gamma: float = 0.1       # EMA factor for the per-job baselines b_m
    minibatches: int = 4     # gradient steps per iteration


Stage = Tuple[EnvConfig, ScenarioSpec]


def default_stages(curriculum: str = "default",
                   num_devices: Sequence[int] = (64,), num_jobs: int = 3,
                   n_sel_frac: float = 0.1, alpha: float = 4.0,
                   beta: float = 0.25) -> List[Stage]:
    """Curriculum stages: one (EnvConfig, ScenarioSpec) per pool size."""
    scen = CURRICULA[curriculum]
    return [(EnvConfig(num_devices=int(K), num_jobs=num_jobs,
                       n_sel=max(1, int(round(n_sel_frac * K))),
                       alpha=alpha, beta=beta), scen)
            for K in num_devices]


def _make_train_iter(cfg: EnvConfig, scen: ScenarioSpec, tcfg: TrainConfig,
                     opt_update):
    """One fully-jitted training iteration for a fixed stage."""
    E, T, M = tcfg.num_envs, tcfg.rollout_len, cfg.num_jobs
    B = E * T
    nb = max(1, min(tcfg.minibatches, B))
    mb = B // nb

    @jax.jit
    def train_iter(params, opt_state, baselines, key):
        k_reset, k_perm = jax.random.split(key)
        states = batch_reset(cfg, scen, k_reset, E)
        _, tr = batch_rollout(cfg, params, states, T)

        # Per-job EMA baselines (paper Line 7), batch-standardized advantages
        # (kills the reward/gradient-magnitude correlation, as in _pretrain).
        rewards = tr.reward                                    # (E, T)
        onehot = jax.nn.one_hot(tr.job, M)                     # (E, T, M)
        per_job_n = jnp.maximum(onehot.sum((0, 1)), 1.0)
        per_job_mean = jnp.einsum("et,etm->m", rewards, onehot) / per_job_n
        baselines = jnp.where(jnp.isnan(baselines), per_job_mean, baselines)
        adv = rewards - baselines[tr.job]
        adv = (adv - adv.mean()) / (adv.std() + 1e-8)
        new_baselines = (1 - tcfg.gamma) * baselines + tcfg.gamma * per_job_mean

        # Shuffled minibatched updates over the flattened batch.
        feats = tr.feats.reshape(B, cfg.num_devices, -1)
        plans = tr.plan.reshape(B, -1).astype(jnp.float32)
        avail = tr.available.reshape(B, -1).astype(jnp.float32)
        advf = adv.reshape(B)
        idx = jax.random.permutation(k_perm, B)[: nb * mb].reshape(nb, mb)

        def mb_step(carry, i):
            p, s = carry
            grads = _reinforce_grads(p, feats[i], plans[i], avail[i], advf[i])
            updates, s = opt_update(grads, s, p)
            p = jax.tree_util.tree_map(lambda a, u: a + u, p, updates)
            return (p, s), None

        (params, opt_state), _ = jax.lax.scan(
            mb_step, (params, opt_state), idx)
        log = {"mean_cost": tr.cost.mean(), "mean_reward": rewards.mean(),
               "mean_round_time": tr.round_time.mean()}
        return params, opt_state, new_baselines, log

    return train_iter


def train_rlds(stages: Sequence[Stage], tcfg: TrainConfig = TrainConfig(),
               seed: int = 0, params=None
               ) -> Tuple[Dict, List[Dict[str, float]]]:
    """Train an RLDS policy over curriculum ``stages`` (cycled round-robin).

    Returns (trained params, per-iteration logs). ``params=None`` starts
    from a fresh ``init_policy`` draw; passing existing params fine-tunes.
    """
    key = jax.random.PRNGKey(seed)
    if params is None:
        key, k_init = jax.random.split(key)
        params = init_policy(k_init)
    opt_init, opt_update = policy_optimizer(tcfg.lr)
    opt_state = opt_init(params)

    iters = [_make_train_iter(cfg, scen, tcfg, opt_update)
             for cfg, scen in stages]
    # Baselines are per (stage-M); costs are scale-calibrated so one EMA
    # vector per job count is meaningful across scenarios.
    baselines = {i: jnp.full((cfg.num_jobs,), jnp.nan)
                 for i, (cfg, _) in enumerate(stages)}

    logs: List[Dict[str, float]] = []
    for it in range(tcfg.iters):
        si = it % len(stages)
        key, k_it = jax.random.split(key)
        t0 = time.perf_counter()
        params, opt_state, baselines[si], log = iters[si](
            params, opt_state, baselines[si], k_it)
        logs.append({"iter": it, "stage": si,
                     **{k: float(v) for k, v in log.items()},
                     "wall_s": time.perf_counter() - t0})
    return params, logs


def evaluate(cfg: EnvConfig, scen: ScenarioSpec, params, seed: int = 0,
             episodes: int = 32, steps: int = 32,
             deterministic: bool = True) -> Dict[str, float]:
    """Mean per-round cost/round-time of a policy over fresh scenarios.

    Deterministic (greedy top-k) by default so trained-vs-untrained
    comparisons at the same seed are paired on identical scenario draws.
    """
    eval_fn = functools.partial(_eval_jit, cfg, scen, episodes, steps,
                                deterministic)
    costs, rts = eval_fn(params, jax.random.PRNGKey(seed))
    return {"mean_cost": float(np.mean(costs)),
            "mean_round_time": float(np.mean(rts)),
            "episodes": episodes, "steps": steps}


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4))
def _eval_jit(cfg, scen, episodes, steps, deterministic, params, key):
    states = batch_reset(cfg, scen, key, episodes)
    _, tr = batch_rollout(cfg, params, states, steps, deterministic)
    return tr.cost, tr.round_time
