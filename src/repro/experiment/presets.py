"""Named experiment presets: the scenarios the repo ships ready-to-run.

Each preset is a factory returning an ``ExperimentSpec`` — list them with
``list_presets()``, build one with ``get_preset(name, **factory_kwargs)``,
or from the shell::

    python -m repro.experiment.cli preset paper-group-a --run
    python -m repro.experiment.cli preset quickstart --out spec.json

Presets cover the paper's benchmark groups (Tables 1-2), the real-training
two-job testbed, and the beyond-paper fault-injection regime. The group
tables are the single source of truth — ``benchmarks/common.py`` builds its
specs from here.
"""

from __future__ import annotations

from typing import Callable, Dict, List

from repro.experiment.registry import Registry
from repro.experiment.slo import SLOSpec
from repro.experiment.spec import (ArrivalsSpec, ExperimentSpec, FleetSpec,
                                   JobSpec, PoolSpec)
from repro.faults import FaultSpec

PRESETS = Registry("preset")
register_preset = PRESETS.register


def get_preset(name: str, **kwargs) -> ExperimentSpec:
    return PRESETS.create(name, **kwargs)


def list_presets() -> List[str]:
    return PRESETS.names()


# Paper groups in scheduler-benchmark form: per-job complexity is encoded as
# (target_noniid, target_iid, convergence rate b0). Complexity ordering
# follows the paper: LeNet < CNN < VGG; AlexNet < CNN-B < ResNet. Non-IID
# targets sit ABOVE greedy's starvation ceiling (~0.73-0.76) and safely below
# the fair schedulers' ceiling so the paper's accuracy separation is the
# thing being measured, not seed luck at the asymptote.
PAPER_GROUPS: Dict[str, List[tuple]] = {
    "A": [("vgg16", 0.54, 0.54, 0.06), ("cnn-a", 0.78, 0.79, 0.12),
          ("lenet5", 0.79, 0.84, 0.20)],
    "B": [("resnet18", 0.58, 0.59, 0.08), ("cnn-b", 0.72, 0.72, 0.12),
          ("alexnet", 0.78, 0.84, 0.18)],
}


def paper_group(group: str, scheduler: str = "bods", non_iid: bool = True,
                seed: int = 1, num_devices: int = 100, n_sel: int = 10,
                max_rounds: int = 150) -> ExperimentSpec:
    """Paper Tables 1-2 scheduler-plane benchmark (synthetic convergence)."""
    jobs = tuple(
        JobSpec(name=name, target_metric=t_noniid if non_iid else t_iid,
                max_rounds=max_rounds, local_epochs=5, convergence_rate=rate)
        for name, t_noniid, t_iid, rate in PAPER_GROUPS[group])
    return ExperimentSpec(
        name=f"paper-group-{group.lower()}-{scheduler}",
        jobs=jobs, pool=PoolSpec(num_devices=num_devices, seed=seed),
        scheduler=scheduler, runtime="synthetic",
        runtime_kwargs={"seed": 2}, non_iid=non_iid, n_sel=n_sel)


@register_preset("paper-group-a")
def paper_group_a(**kwargs) -> ExperimentSpec:
    return paper_group("A", **kwargs)


@register_preset("paper-group-b")
def paper_group_b(**kwargs) -> ExperimentSpec:
    return paper_group("B", **kwargs)


@register_preset("quickstart")
def quickstart(scheduler: str = "bods", n_jobs: int = 3, target: float = 0.8,
               num_devices: int = 100, max_rounds: int = 150,
               seed: int = 1) -> ExperimentSpec:
    """3 identical synthetic jobs over 100 heterogeneous devices — the
    paper's core loop in under a minute."""
    return ExperimentSpec(
        name=f"quickstart-{scheduler}",
        jobs=tuple(JobSpec(name="clf", target_metric=target,
                           max_rounds=max_rounds) for _ in range(n_jobs)),
        pool=PoolSpec(num_devices=num_devices, seed=seed),
        scheduler=scheduler, runtime="synthetic",
        runtime_kwargs={"seed": 2}, n_sel=max(1, num_devices // 10))


@register_preset("real-fl-two-job")
def real_fl_two_job(scheduler: str = "bods", rounds: int = 15,
                    num_devices: int = 40, seed: int = 5,
                    lenet_target: float = 0.90,
                    cnn_target: float = 0.80) -> ExperimentSpec:
    """The paper's testbed in miniature: LeNet-5 + CNN-B, REAL vmap'd local
    SGD + FedAvg on non-IID synthetic shards, times simulated."""
    jobs = (
        JobSpec(name="paper-lenet5", model="paper-lenet5",
                target_metric=lenet_target, max_rounds=rounds,
                local_epochs=3, batch_size=32, lr=0.02),
        JobSpec(name="paper-cnn-b", model="paper-cnn-b",
                target_metric=cnn_target, max_rounds=rounds,
                local_epochs=3, batch_size=32, lr=0.02),
    )
    return ExperimentSpec(
        name=f"real-fl-two-job-{scheduler}",
        jobs=jobs, pool=PoolSpec(num_devices=num_devices, seed=seed),
        scheduler=scheduler, runtime="real_fl", non_iid=True, n_sel=5)


@register_preset("fleet-scale")
def fleet_scale(scheduler: str = "bods", num_devices: int = 10_000,
                n_sel: int = None, candidates: int = 512,
                scoring_backend: str = "jax",
                search_backend: str = "fused", n_jobs: int = 2,
                max_rounds: int = 5, seed: int = 1) -> ExperimentSpec:
    """Beyond-paper scale regime: a cross-device fleet of 10k-100k devices
    (cf. Liu et al., arXiv:2211.13430) scheduled through the batched
    jit-compiled scoring core. The ``fleet`` axis carries pool size,
    candidate count, and scoring backend; everything else stays the
    quickstart scheduler-plane setup."""
    n_sel = n_sel or max(1, num_devices // 100)
    return ExperimentSpec(
        name=f"fleet-scale-{scheduler}-K{num_devices}",
        jobs=tuple(JobSpec(name="clf", target_metric=0.95,
                           max_rounds=max_rounds) for _ in range(n_jobs)),
        pool=PoolSpec(seed=seed),
        fleet=FleetSpec(num_devices=num_devices, n_sel=n_sel,
                        candidates=candidates,
                        scoring_backend=scoring_backend,
                        search_backend=search_backend),
        scheduler=scheduler, runtime="synthetic",
        runtime_kwargs={"seed": 2})


@register_preset("rlds-warmstart")
def rlds_warmstart(policy: str = "rlds-default",
                   policy_dir: str = "policies", n_jobs: int = 3,
                   num_devices: int = 100, max_rounds: int = 150,
                   seed: int = 1) -> ExperimentSpec:
    """Quickstart scenario driven by a gym-trained RLDS policy loaded from
    the policy zoo (train one first: ``python -m repro.gym train --name
    rlds-default``). Construction skips the legacy 300-round constructor
    pre-training entirely — the warm start replaces it."""
    spec = quickstart(scheduler="rlds", n_jobs=n_jobs,
                      num_devices=num_devices, max_rounds=max_rounds,
                      seed=seed)
    return spec.replace(name=f"rlds-warmstart-{policy}", policy=policy,
                        policy_dir=policy_dir)


@register_preset("online-smoke")
def online_smoke(scheduler: str = "bods", num_devices: int = 60,
                 horizon: float = 20_000.0, interarrival: float = 900.0,
                 max_concurrent: int = 3, seed: int = 1) -> ExperimentSpec:
    """Online multi-tenant scheduler service in the small: a 2-template
    tenant catalogue served under Poisson arrivals with tenant departures,
    probabilistic readmission (the warm hand-off path), and device churn
    with capability drift — ``python -m repro.serve --preset online-smoke``.
    Jobs are short (max_rounds) so arrivals genuinely interleave with
    completions inside the horizon."""
    jobs = (
        JobSpec(name="small", target_metric=0.95, max_rounds=12,
                local_epochs=3, convergence_rate=0.20),
        JobSpec(name="large", target_metric=0.95, max_rounds=20,
                local_epochs=5, convergence_rate=0.10),
    )
    return ExperimentSpec(
        name=f"online-smoke-{scheduler}",
        jobs=jobs, pool=PoolSpec(num_devices=num_devices, seed=seed),
        scheduler=scheduler, runtime="synthetic",
        runtime_kwargs={"seed": 2}, n_sel=max(1, num_devices // 10),
        arrivals=ArrivalsSpec(
            seed=seed, horizon=horizon, interarrival=interarrival,
            mean_lifetime=2_500.0, readmit_prob=0.5,
            max_concurrent=max_concurrent,
            churn_interarrival=4_000.0, churn_fraction=0.05,
            rejoin_after=2_000.0, drift=1.3))


@register_preset("slo-overload")
def slo_overload(scheduler: str = "bods", num_devices: int = 40,
                 horizon: float = 12_000.0, interarrival: float = 350.0,
                 max_concurrent: int = 2, max_queue_depth: int = 3,
                 breaker_threshold: int = 2,
                 watchdog_rounds: int = 5, seed: int = 3) -> ExperimentSpec:
    """Overload + chaos regime for the SLO axis: the online-smoke tenant
    catalogue arriving ~3x faster than the service can drain it, over a
    faulty fleet (dropouts, crashes, a domain outage schedule, corrupted
    uploads), with the full resilience stack armed — queue-depth
    degradation ladder, admission shedding, per-tenant/per-domain circuit
    breakers, bounded launch/aggregation retries, and the stalled-round
    watchdog. Deliberately leaves ``slo.decision_deadline_ms`` unset so the
    trajectory (including fired rungs) is bit-identical across crash/resume
    — the overload-chaos CI arm depends on that."""
    spec = online_smoke(scheduler=scheduler, num_devices=num_devices,
                        horizon=horizon, interarrival=interarrival,
                        max_concurrent=max_concurrent, seed=seed)
    return spec.replace(
        name=f"slo-overload-{scheduler}",
        faults=FaultSpec(
            seed=seed, dropout_rate=0.12, crash_rate=0.002,
            straggler_rate=0.10, straggler_slowdown=3.0,
            num_domains=4, domain_outage_rate=0.03, corrupt_rate=0.05),
        slo=SLOSpec(
            max_queue_depth=max_queue_depth, shed_policy="defer",
            breaker_threshold=breaker_threshold, breaker_cooldown=2_000.0,
            watchdog_rounds=watchdog_rounds,
            max_launch_retries=3, max_agg_retries=1))


@register_preset("fault-injection")
def fault_injection(scheduler: str = "bods", dropout_rate: float = 0.15,
                    crash_rate: float = 0.003,
                    straggler_rate: float = 0.10,
                    straggler_slowdown: float = 3.0,
                    num_domains: int = 8,
                    domain_outage_rate: float = 0.02,
                    corrupt_rate: float = 0.05,
                    round_deadline: float = None,
                    over_provision: float = 1.2,
                    num_devices: int = 100, seed: int = 1) -> ExperimentSpec:
    """Beyond-paper robustness regime, now the full ``faults`` axis
    (``repro.faults.FaultSpec``): transient dropouts with escalating
    quarantine, rare permanent crashes, straggler slowdowns, correlated
    fault-domain outages, and corrupted (NaN) uploads — all from a seeded
    replayable schedule. Over-provisioning absorbs the straggler/failure
    tail; an optional FedCS-style ``round_deadline`` adds partial
    aggregation. The legacy ``failure_rate`` spec field remains a
    deprecated alias for plain uniform dropouts."""
    spec = quickstart(scheduler=scheduler, num_devices=num_devices, seed=seed)
    return spec.replace(
        name=f"fault-injection-{scheduler}",
        over_provision=over_provision,
        faults=FaultSpec(
            seed=seed, dropout_rate=dropout_rate, crash_rate=crash_rate,
            straggler_rate=straggler_rate,
            straggler_slowdown=straggler_slowdown,
            num_domains=num_domains, domain_outage_rate=domain_outage_rate,
            corrupt_rate=corrupt_rate, round_deadline=round_deadline))
