"""``python -m repro.experiment`` — alias for ``python -m repro.experiment.cli``."""

from repro.experiment.cli import main

if __name__ == "__main__":
    main()
