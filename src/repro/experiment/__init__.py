"""Declarative experiment API: one ``ExperimentSpec -> run()`` entrypoint.

Every multi-job FL scenario in this repo — paper table reproductions,
real-training testbeds, fault-injection studies, cluster-scale scheduling —
is a single frozen, JSON-serializable ``ExperimentSpec``:

    from repro.experiment import ExperimentSpec, JobSpec

    spec = ExperimentSpec(jobs=(JobSpec(name="lenet5", target_metric=0.8),),
                          scheduler="bods")
    result = spec.run()          # -> ExperimentResult (summary + records)
    spec2 = ExperimentSpec.from_dict(result.to_dict()["spec"])  # replayable

Components resolve through decorator registries (``@register_scheduler``,
``@register_runtime``), named presets live in ``repro.experiment.presets``,
and ``python -m repro.experiment.cli run spec.json`` runs a spec from disk.

Attribute access is lazy (PEP 562) so that ``repro.core.schedulers`` can
import ``repro.experiment.registry`` at class-definition time without
triggering the heavier spec/runtime imports (and without an import cycle).
"""

from __future__ import annotations

_EXPORTS = {
    "Registry": "repro.experiment.registry",
    "SCHEDULERS": "repro.experiment.registry",
    "RUNTIMES": "repro.experiment.registry",
    "register_scheduler": "repro.experiment.registry",
    "register_runtime": "repro.experiment.registry",
    "JobSpec": "repro.experiment.spec",
    "PoolSpec": "repro.experiment.spec",
    "CostSpec": "repro.experiment.spec",
    "FleetSpec": "repro.experiment.spec",
    "TrainSpec": "repro.experiment.spec",
    "ExperimentSpec": "repro.experiment.spec",
    "Experiment": "repro.experiment.spec",
    "ExperimentResult": "repro.experiment.spec",
    "get_preset": "repro.experiment.presets",
    "list_presets": "repro.experiment.presets",
    "register_preset": "repro.experiment.presets",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    if name in _EXPORTS:
        import importlib

        module = importlib.import_module(_EXPORTS[name])
        return getattr(module, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return __all__
