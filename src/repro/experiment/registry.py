"""Decorator-based component registries for the experiment layer.

Two axes are pluggable today — schedulers and runtimes — and both use the
same ``Registry``: a component module decorates its class/factory at import
time, and ``ExperimentSpec.build`` resolves names lazily. This replaces the
hand-maintained ``_SCHEDULERS`` dict that used to live in
``repro/core/schedulers/__init__.py`` and opens the runtime axis the same
way (``synthetic`` vs ``real_fl``; future: async fleets, trace replay).

This module is intentionally dependency-free (stdlib only) so the scheduler
modules in ``repro.core`` can import it without a cycle: registration flows
core -> here, resolution flows experiment.spec -> here -> (lazy import of
the providing package).
"""

from __future__ import annotations

import importlib
from typing import Callable, Dict, List, Optional


class Registry:
    """Name -> factory mapping with decorator registration.

    ``ensure``: dotted module whose import triggers registration of the
    built-in components (mirrors ``repro.config.registry``'s lazy loading).
    """

    def __init__(self, kind: str, ensure: Optional[str] = None):
        self.kind = kind
        self._ensure = ensure
        self._factories: Dict[str, Callable] = {}

    def register(self, name: str) -> Callable:
        def deco(factory: Callable) -> Callable:
            if name in self._factories and self._factories[name] is not factory:
                raise ValueError(
                    f"duplicate {self.kind} registration {name!r} "
                    f"({self._factories[name]!r} vs {factory!r})")
            self._factories[name] = factory
            return factory

        return deco

    def _load_builtins(self) -> None:
        if self._ensure is not None:
            importlib.import_module(self._ensure)

    def get(self, name: str) -> Callable:
        self._load_builtins()
        if name not in self._factories:
            raise KeyError(
                f"unknown {self.kind} {name!r}; known: {self.names()}")
        return self._factories[name]

    def create(self, name: str, *args, **kwargs):
        return self.get(name)(*args, **kwargs)

    def names(self) -> List[str]:
        self._load_builtins()
        return sorted(self._factories)

    def __contains__(self, name: str) -> bool:
        self._load_builtins()
        return name in self._factories


SCHEDULERS = Registry("scheduler", ensure="repro.core.schedulers")
RUNTIMES = Registry("runtime", ensure="repro.experiment.runtimes")

register_scheduler = SCHEDULERS.register
register_runtime = RUNTIMES.register
