"""``ExperimentSpec``: the one declarative front door to the MJ-FL system.

A spec is a frozen, JSON-round-trippable description of a complete multi-job
federated-learning experiment: the jobs, the device pool, the cost-model
coefficients, the scheduler (by registry name) and its search backend
(``search_backend``: fused on-device search loops vs the host reference),
the runtime (``synthetic`` closed-form convergence or ``real_fl`` actual
JAX training), the training
execution knobs (``TrainSpec``: fused engine, cohort buckets, eval cadence),
the fault/straggler/queueing knobs of the engine, and the ``policy`` axis
(a policy-zoo entry name that warm-starts the scheduler — e.g. a gym-trained
RLDS policy from ``repro.gym``). ``spec.build()`` wires the
``DevicePool -> CostModel -> calibrate -> scheduler -> runtime ->
MultiJobEngine`` chain that every example/benchmark/test used to assemble by
hand; ``spec.run()`` executes it and returns an ``ExperimentResult`` whose
``to_dict()`` embeds the spec, so any saved result is a replayable spec.

All randomness is seeded from the spec (pool seed, scheduler seed, runtime
seed, engine seed), so equal specs reproduce results bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.config.base import ArchFamily, JobConfig, ModelConfig
from repro.core.cost import CostModel
from repro.core.devices import DevicePool
from repro.core.multijob import MultiJobEngine, RoundRecord
from repro.experiment.registry import RUNTIMES, SCHEDULERS
from repro.experiment.slo import SLOSpec
from repro.faults import FaultSpec
from repro.monitoring.session import ObsSession, ObsSpec

STUB_MODEL = "stub"


def _resolve_model(job: "JobSpec") -> ModelConfig:
    """Resolve a JobSpec's model id to a ModelConfig named after the job.

    ``stub`` is the scheduler-plane placeholder (a flatten-only classifier —
    never trained by the synthetic runtime, but it gives the engine a valid
    config and the summary a stable key). Any other id resolves through the
    arch registry (``paper-lenet5``, ``qwen3-8b``, ...).
    """
    if job.model == STUB_MODEL:
        return ModelConfig(name=job.name, family=ArchFamily.CNN,
                           cnn_spec=(("flatten",),), input_shape=(4, 4, 1),
                           num_classes=10)
    from repro.config.registry import get_arch

    cfg = get_arch(job.model)
    return dataclasses.replace(cfg, name=job.name)


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One FL job, declaratively: what to train, to which target, how fast
    it converges under the synthetic runtime."""

    name: str
    model: str = STUB_MODEL         # arch-registry id, or "stub"
    target_metric: float = 0.8
    max_rounds: int = 150
    local_epochs: int = 5
    batch_size: int = 32
    lr: float = 0.05
    # Synthetic-runtime convergence rate b0 (Formula 13); None -> runtime
    # default. Encodes job complexity ordering (LeNet > CNN > VGG).
    convergence_rate: Optional[float] = None

    def to_job_config(self, job_id: int) -> JobConfig:
        return JobConfig(job_id=job_id, model=_resolve_model(self),
                         target_metric=self.target_metric,
                         max_rounds=self.max_rounds,
                         local_epochs=self.local_epochs,
                         batch_size=self.batch_size, lr=self.lr)


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    """The heterogeneous device pool (Formula 4 shifted-exponential model)."""

    num_devices: int = 100
    seed: int = 0
    a_range: Tuple[float, float] = (2e-4, 2e-3)
    mu_range: Tuple[float, float] = (1.0, 10.0)
    data_range: Tuple[int, int] = (200, 600)
    # Optional per-job multiplier on data sizes (cluster scheduling folds
    # per-arch step cost into slice-seconds this way). Length must equal the
    # number of jobs.
    job_weights: Optional[Tuple[float, ...]] = None

    def build(self, num_jobs: int) -> DevicePool:
        pool = DevicePool.heterogeneous(
            self.num_devices, num_jobs, seed=self.seed,
            a_range=tuple(self.a_range), mu_range=tuple(self.mu_range),
            data_range=tuple(self.data_range))
        if self.job_weights is not None:
            w = np.asarray(self.job_weights, dtype=np.float64)
            if w.shape != (num_jobs,):
                raise ValueError(
                    f"job_weights has shape {w.shape}, expected ({num_jobs},)")
            pool.data_sizes = pool.data_sizes * w[None, :]
        return pool


@dataclasses.dataclass(frozen=True)
class CostSpec:
    """Formula 2 coefficients; ``calibrate`` normalizes the two terms from
    the pool so alpha/beta are unitless (the repo-wide default)."""

    alpha: float = 4.0
    beta: float = 0.25
    delta_fairness: bool = True
    calibrate: bool = True

    def build(self, pool: DevicePool, taus: List[float], n_sel: int,
              scoring_backend: str = "auto",
              num_shards: int = 1) -> CostModel:
        cm = CostModel(pool, alpha=self.alpha, beta=self.beta,
                       delta_fairness=self.delta_fairness,
                       scoring_backend=scoring_backend,
                       num_shards=num_shards)
        if self.calibrate:
            cm.calibrate(taus, n_sel=n_sel)
        return cm


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Fleet-scale axis: pool size, candidate count, and backends.

    ``num_devices``/``n_sel`` override the pool/engine sizing when set
    (so one preset sweeps K without re-deriving the rest of the spec);
    ``candidates`` overrides the candidate-set size of searching schedulers
    (BODS/DNN ``num_candidates``, genetic ``population``); ``scoring_backend``
    selects the plan-scoring path: ``numpy | jax | pallas | auto``;
    ``search_backend`` selects the plan-SEARCH path of the searching
    schedulers (SA/genetic/BODS): ``fused`` (jitted on-device loops,
    ``repro.core.search``) or ``host`` (the sequential numpy reference);
    ``num_shards`` shards the fleet (K) axis of scoring and the parallel
    axes of the fused searchers across host platform devices
    (``repro.core.shard``): None/1 = single lane, ``"auto"``/0 = one shard
    per jax device (size the host platform first — see
    ``repro.launch.bootstrap``).
    """

    num_devices: Optional[int] = None
    n_sel: Optional[int] = None
    candidates: Optional[int] = None
    scoring_backend: str = "auto"
    search_backend: str = "fused"
    num_shards: Optional[Any] = None  # None | int | "auto" | 0 (= auto)


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    """Training-runtime execution knobs (the fused FL engine).

    ``fused`` selects the recompile-free ``FusedMultiRuntime`` (bucketed
    cohorts, device-resident data, cross-job batched dispatch) for the
    ``real_fl`` runtime; False keeps the historical per-job unfused path.
    ``buckets`` overrides the power-of-two cohort buckets (None -> derived
    from the pool size); ``eval_every`` evaluates held-out metrics every
    k-th round per job — skipped rounds report the last evaluated metrics,
    so target detection lags by < k rounds when k > 1. ``buckets`` and
    ``eval_every`` apply to the fused runtime only (the unfused baseline
    has no buckets and evaluates every round; setting them with
    ``fused=False`` warns).

    ``robust`` turns on robust aggregation inside the fused jitted round:
    per-device updates that are non-finite or whose delta norm exceeds
    ``reject_mult`` x the cohort's masked median are rejected (zero FedAvg
    weight) — and the runtime injects the ``faults`` axis's corrupted
    uploads itself, so screening is part of the measured round (no oracle).
    """

    fused: bool = True
    buckets: Optional[Tuple[int, ...]] = None
    eval_every: int = 1
    robust: bool = False
    reject_mult: float = 4.0


@dataclasses.dataclass(frozen=True)
class ArrivalsSpec:
    """Online traffic axis (the ``repro.serve`` scheduler service): dynamic
    job arrivals/departures and device churn over a simulated horizon.

    With this axis set, ``spec.jobs`` becomes a catalogue of tenant TEMPLATES
    — the service instantiates a fresh job per arrival (template chosen by
    the trace) instead of running the catalogue directly. ``mode="poisson"``
    generates a seeded synthetic trace; ``mode="trace"`` replays the JSON
    trace at ``trace_path`` (``repro.serve.traffic.save_trace``).
    """

    mode: str = "poisson"               # "poisson" | "trace"
    seed: int = 0
    horizon: float = 20000.0            # simulated seconds of traffic
    interarrival: float = 1500.0        # mean seconds between job arrivals
    # Mean tenant lifetime before voluntary departure; None -> tenants run
    # to completion (target/max_rounds) and only the engine retires them.
    mean_lifetime: Optional[float] = None
    # A departing tenant returns later with this probability — the warm
    # hand-off path (scheduler per-job state follows the tenant).
    readmit_prob: float = 0.0
    max_concurrent: int = 4             # admission-control budget (live jobs)
    # Device churn: mean seconds between churn events (None -> no churn),
    # the fleet fraction departing per event, how long until they rejoin,
    # and the multiplicative capability drift (on ``a``) applied on rejoin.
    churn_interarrival: Optional[float] = None
    churn_fraction: float = 0.02
    rejoin_after: float = 2000.0
    drift: float = 1.0
    trace_path: Optional[str] = None    # mode="trace" input


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """A complete multi-job FL experiment. ``build()`` -> ``Experiment``,
    ``run()`` -> ``ExperimentResult``; ``to_dict``/``from_dict`` round-trip
    through JSON."""

    jobs: Tuple[JobSpec, ...]
    pool: PoolSpec = PoolSpec()
    cost: CostSpec = CostSpec()
    fleet: FleetSpec = FleetSpec()
    # Convenience aliases for fleet.scoring_backend / fleet.search_backend
    # (they win when set), so ``ExperimentSpec(..., scoring_backend="jax")``
    # and ``--set search_backend=host`` work without nesting.
    scoring_backend: Optional[str] = None
    search_backend: Optional[str] = None
    scheduler: str = "random"
    scheduler_seed: int = 0
    scheduler_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    runtime: str = "synthetic"
    runtime_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    train: TrainSpec = TrainSpec()
    # Observability axis (``repro.monitoring``): ``--set obs.trace_path=
    # trace.json`` makes any run emit a Perfetto trace; ``obs.metrics_path``
    # a per-round metrics JSONL; ``obs.audit_path`` the scheduler audit log.
    obs: ObsSpec = ObsSpec()
    # Policy axis: name of a policy-zoo entry (``repro.gym.zoo``) to load
    # into the scheduler after construction — e.g. a gym-trained RLDS
    # policy, a saved BODS observation ring. A loaded policy ALWAYS
    # replaces RLDS's lazy Algorithm-3 pre-training (``load_state_dict``
    # marks the policy pre-trained).
    policy: Optional[str] = None
    policy_dir: str = "policies"
    # Online traffic axis: set -> ``spec.jobs`` is a tenant-template
    # catalogue served by ``repro.serve.SchedulerService`` (dynamic
    # arrivals/departures/churn); None -> classic closed job set.
    arrivals: Optional[ArrivalsSpec] = None
    non_iid: bool = True            # data distribution (both runtime kinds)
    n_sel: Optional[int] = None     # devices per round; None -> 10% of pool
    # Fault model (``repro.faults.FaultSpec``): crash/dropout/straggler/
    # domain/corruption rates, quarantine backoff, round deadline. None with
    # ``failure_rate > 0`` maps the deprecated alias below onto the axis
    # (``effective_faults``).
    faults: Optional[FaultSpec] = None
    # Serve-resilience axis (``repro.experiment.slo.SLOSpec``): decision
    # deadlines + degradation ladder, admission backpressure, circuit
    # breakers, bounded retries, and the stalled-round watchdog. None or an
    # inert spec leaves trajectories bit-identical to the legacy paths.
    slo: Optional[SLOSpec] = None
    # DEPRECATED alias (uniform transient dropouts, fixed cooldown) — kept
    # for old spec JSONs; subsumed by the ``faults`` axis, which wins when
    # both are set.
    failure_rate: float = 0.0
    failure_cooldown: float = 60.0
    # Engine knobs: straggler over-provisioning cut, queueing-aware release
    # horizon.
    over_provision: float = 1.0
    release_horizon: float = 0.0
    engine_seed: int = 12345
    name: str = "experiment"

    def __post_init__(self):
        object.__setattr__(self, "jobs", tuple(self.jobs))
        if not self.jobs:
            raise ValueError("ExperimentSpec needs at least one job")

    # ---- construction ----

    def effective_num_devices(self) -> int:
        return self.fleet.num_devices or self.pool.num_devices

    def effective_n_sel(self) -> int:
        n = self.fleet.n_sel or self.n_sel
        return n or max(1, int(round(0.1 * self.effective_num_devices())))

    def effective_scoring_backend(self) -> str:
        return self.scoring_backend or self.fleet.scoring_backend

    def effective_search_backend(self) -> str:
        return self.search_backend or self.fleet.search_backend

    def effective_faults(self) -> Optional[FaultSpec]:
        """The resolved fault model: the ``faults`` axis when set, else the
        deprecated ``failure_rate``/``failure_cooldown`` alias mapped onto
        it (fixed-cooldown uniform dropouts), else None."""
        if self.faults is not None:
            return self.faults
        if self.failure_rate > 0.0:
            return FaultSpec.from_legacy(self.failure_rate,
                                         self.failure_cooldown,
                                         seed=self.engine_seed)
        return None

    def effective_slo(self) -> Optional[SLOSpec]:
        """The resolved resilience axis: the ``slo`` spec when set and NOT
        inert (an inert spec must change nothing — the bit-identity
        contract), else None."""
        if self.slo is not None and not self.slo.inert:
            return self.slo
        return None

    def effective_num_shards(self) -> int:
        """Resolved fleet-axis shard count (``fleet.num_shards``: None -> 1,
        "auto"/0 -> one shard per jax device, capped at the fleet size)."""
        from repro.core import shard

        return shard.resolve_num_shards(self.fleet.num_shards,
                                        fleet_size=self.effective_num_devices())

    def _scheduler_params(self):
        import inspect

        factory = SCHEDULERS.get(self.scheduler)
        fn = factory.__init__ if inspect.isclass(factory) else factory
        return inspect.signature(fn).parameters

    def _candidate_kwargs(self) -> Dict[str, Any]:
        """Map fleet.candidates / the search-backend axis onto the
        scheduler's own knobs, where it has them."""
        params = self._scheduler_params()
        out: Dict[str, Any] = {}
        if "search_backend" in params:
            out["search_backend"] = self.effective_search_backend()
        if self.fleet.candidates is not None:
            for knob in ("num_candidates", "population"):
                if knob in params:
                    out[knob] = int(self.fleet.candidates)
                    break
        return out

    def build(self) -> "Experiment":
        jobs = [js.to_job_config(i) for i, js in enumerate(self.jobs)]
        pool_spec = self.pool
        if self.fleet.num_devices is not None:
            pool_spec = dataclasses.replace(
                pool_spec, num_devices=self.fleet.num_devices)
        pool = pool_spec.build(len(jobs))
        n_sel = self.effective_n_sel()
        cost_model = self.cost.build(
            pool, [float(j.local_epochs) for j in jobs], n_sel,
            scoring_backend=self.effective_scoring_backend(),
            num_shards=self.effective_num_shards())
        # scheduler_kwargs may override the default seed/cost_model wiring
        sched_kwargs = {
            "cost_model": cost_model, "seed": self.scheduler_seed,
            **self._candidate_kwargs(),
            **dict(self.scheduler_kwargs)}
        if self.policy and self.scheduler == "rlds":
            # The warm start replaces the lazy Algorithm-3 pre-training
            # (load_state_dict marks the policy pre-trained regardless);
            # zeroing the knob just keeps the constructor contract obvious.
            sched_kwargs.setdefault("pretrain_rounds", 0)
        scheduler = SCHEDULERS.create(self.scheduler, **sched_kwargs)
        if self.policy:
            from repro.gym.zoo import PolicyZoo

            PolicyZoo(self.policy_dir).load_into(self.policy, scheduler)
        runtime = RUNTIMES.get(self.runtime)(
            self, jobs, pool, **dict(self.runtime_kwargs))
        engine = MultiJobEngine(
            jobs, pool, cost_model, scheduler, runtime,
            n_sel=n_sel,
            faults=self.effective_faults(),
            over_provision=self.over_provision,
            release_horizon=self.release_horizon,
            rng=np.random.default_rng(self.engine_seed))
        slo = self.effective_slo()
        if slo is not None:
            # Lazy import: repro.serve imports this module at package level.
            from repro.serve.resilience import attach_resilience

            attach_resilience(engine, slo)
        if self.obs.active:
            ObsSession(self.obs, scheduler=self.scheduler,
                       process_name=self.name).attach(engine)
        return Experiment(spec=self, engine=engine)

    def run(self, verbose: bool = False,
            on_round: Optional[Callable[[RoundRecord], None]] = None
            ) -> "ExperimentResult":
        return self.build().run(verbose=verbose, on_round=on_round)

    # ---- serialization ----

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        d = dict(d)
        d["jobs"] = tuple(JobSpec(**j) for j in d["jobs"])
        pool = dict(d.get("pool", {}))
        for key in ("a_range", "mu_range", "data_range", "job_weights"):
            if pool.get(key) is not None:
                pool[key] = tuple(pool[key])
        d["pool"] = PoolSpec(**pool)
        d["cost"] = CostSpec(**d.get("cost", {}))
        d["fleet"] = FleetSpec(**d.get("fleet", {}))
        train = dict(d.get("train", {}))
        if train.get("buckets") is not None:
            train["buckets"] = tuple(train["buckets"])
        d["train"] = TrainSpec(**train)
        d["obs"] = ObsSpec(**d.get("obs", {}))
        if d.get("arrivals") is not None:
            d["arrivals"] = ArrivalsSpec(**d["arrivals"])
        if d.get("faults") is not None:
            d["faults"] = FaultSpec(**d["faults"])
        if d.get("slo") is not None:
            d["slo"] = SLOSpec(**d["slo"])
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))

    @classmethod
    def load(cls, path: str) -> "ExperimentSpec":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    _NESTED_TUPLE_FIELDS = ("a_range", "mu_range", "data_range",
                            "job_weights", "buckets")

    def replace(self, **changes) -> "ExperimentSpec":
        """``dataclasses.replace`` that also accepts dicts for the nested
        axes (``pool``/``cost``/``fleet``/``train``), merged over the current
        values — so ``spec.replace(train={"eval_every": 2})`` and the CLI's
        ``--set train={...}`` work without rebuilding the whole sub-spec."""
        _optional = {"arrivals": ArrivalsSpec, "faults": FaultSpec,
                     "slo": SLOSpec}
        for key in ("pool", "cost", "fleet", "train", "obs", "arrivals",
                    "faults", "slo"):
            v = changes.get(key)
            if isinstance(v, dict):
                v = {k: (tuple(val) if k in self._NESTED_TUPLE_FIELDS
                         and val is not None else val)
                     for k, val in v.items()}
                cur = getattr(self, key)
                changes[key] = (dataclasses.replace(cur, **v)
                                if cur is not None else _optional[key](**v))
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass
class Experiment:
    """A built (but not yet run) experiment: the spec plus the live engine.

    The engine is exposed for instrumentation (``engine.counts``,
    ``engine.records``, monitoring hooks) — scenario wiring itself should
    stay in the spec."""

    spec: ExperimentSpec
    engine: MultiJobEngine

    def run(self, verbose: bool = False,
            on_round: Optional[Callable[[RoundRecord], None]] = None
            ) -> "ExperimentResult":
        t0 = time.time()
        try:
            self.engine.run(verbose=verbose, on_round=on_round)
        finally:
            # Finalize the obs axis (trace write + sink close) even when a
            # run dies mid-flight — partial traces are still loadable.
            if self.engine.obs is not None:
                self.engine.obs.close()
        return ExperimentResult(
            spec=self.spec, summary=self.engine.summary(),
            records=list(self.engine.records), wall_s=time.time() - t0)


def _record_to_dict(r: RoundRecord) -> dict:
    d = dataclasses.asdict(r)
    d["device_ids"] = np.asarray(r.device_ids).astype(int).tolist()
    d["dropped"] = np.asarray(r.dropped).astype(int).tolist()
    d["corrupt_ids"] = np.asarray(r.corrupt_ids).astype(int).tolist()
    d["failed_ids"] = np.asarray(r.failed_ids).astype(int).tolist()
    d["degraded"] = bool(r.degraded)
    return d


def _record_from_dict(d: dict) -> RoundRecord:
    d = dict(d)
    d["device_ids"] = np.asarray(d["device_ids"], dtype=int)
    d["dropped"] = np.asarray(d["dropped"], dtype=int)
    d["corrupt_ids"] = np.asarray(d.get("corrupt_ids", []), dtype=int)
    d["failed_ids"] = np.asarray(d.get("failed_ids", []), dtype=int)
    d.setdefault("rung", None)
    d.setdefault("decision_ms", None)
    return RoundRecord(**d)


@dataclasses.dataclass
class ExperimentResult:
    """What a run produced: per-job summary (paper Tables 1/2/5 quantities),
    the full round trace, and the spec that generated it."""

    spec: ExperimentSpec
    summary: Dict[str, dict]
    records: List[RoundRecord]
    wall_s: float = 0.0

    def to_dict(self) -> dict:
        return dict(spec=self.spec.to_dict(), summary=self.summary,
                    records=[_record_to_dict(r) for r in self.records],
                    wall_s=self.wall_s)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentResult":
        return cls(spec=ExperimentSpec.from_dict(d["spec"]),
                   summary=d["summary"],
                   records=[_record_from_dict(r) for r in d["records"]],
                   wall_s=d.get("wall_s", 0.0))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "ExperimentResult":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    @property
    def makespan(self) -> float:
        return max(v["makespan"] for v in self.summary.values())
