"""Experiment CLI: run a spec file, materialize a preset, list components.

  python -m repro.experiment.cli run spec.json [--verbose] [--out result.json]
  python -m repro.experiment.cli preset paper-group-a --run [--arg scheduler=rlds]
  python -m repro.experiment.cli preset quickstart --out spec.json
  python -m repro.experiment.cli list

``preset --arg k=v`` feeds the preset factory (values parsed as JSON, bare
strings allowed); ``--set k=v`` overrides ExperimentSpec fields on the
materialized spec — top-level, or nested via a dotted key (``--set
fleet.num_shards=4`` shards the fleet axis across host platform devices;
launch under ``repro.launch.bootstrap`` / ``XLA_FLAGS`` so the devices
exist). Other axes: the policy axis (``--set policy=<name>`` loads a
gym-trained scheduler policy from the zoo; train one with
``python -m repro.gym train``) and the search-backend axis
(``--set search_backend=host|fused`` flips the SA/genetic/BODS plan search
between the jitted on-device loops and the sequential numpy reference;
see ``benchmarks/bench_sched.py``) and the observability axis (``--set
obs.trace_path=trace.json`` emits a Perfetto trace of the run, ``--set
obs.metrics_path=m.jsonl`` / ``obs.audit_path=a.jsonl`` the round-metrics
and scheduler-audit logs; inspect with ``python -m repro.monitoring
report``). A saved result's ``spec`` block is
itself a valid input to ``run`` — benchmark outputs are replayable.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict

from repro.experiment.presets import get_preset, list_presets
from repro.experiment.registry import RUNTIMES, SCHEDULERS
from repro.experiment.spec import ExperimentResult, ExperimentSpec


def _parse_kv(pairs) -> Dict:
    """``k=v`` pairs -> dict (values parsed as JSON, bare strings allowed).

    Dotted keys address nested spec axes: ``fleet.num_shards=4`` becomes
    ``{"fleet": {"num_shards": 4}}``, which ``ExperimentSpec.replace``
    merges over the current sub-spec. Dotted pairs for the same axis
    accumulate into one merge dict."""
    out = {}
    for pair in pairs or []:
        if "=" not in pair:
            raise SystemExit(f"expected key=value, got {pair!r}")
        k, v = pair.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass  # bare string
        if "." in k:
            root, sub = k.split(".", 1)
            node = out.setdefault(root, {})
            if not isinstance(node, dict):
                raise SystemExit(
                    f"--set {k}: {root!r} already set to a non-dict value")
            node[sub] = v
        else:
            if isinstance(v, dict) and isinstance(out.get(k), dict):
                out[k].update(v)
            else:
                out[k] = v
    return out


def _print_summary(result: ExperimentResult) -> None:
    print(f"\n[{result.spec.name}] scheduler={result.spec.scheduler} "
          f"runtime={result.spec.runtime} rounds={len(result.records)} "
          f"wall={result.wall_s:.1f}s")
    for name, v in result.summary.items():
        t2t = ("-" if v["time_to_target"] is None
               else f"{v['time_to_target'] / 60:.1f}m")
        print(f"  {name:20s} rounds={v['rounds']:4d} "
              f"best_acc={v['best_accuracy']:.3f} t2t={t2t} "
              f"makespan={v['makespan'] / 60:.1f}m")


def _run_spec(spec: ExperimentSpec, args) -> None:
    result = spec.run(verbose=args.verbose)
    _print_summary(result)
    if args.out:
        result.save(args.out)
        print(f"result -> {args.out} (replay: python -m repro.experiment.cli "
              f"run {args.out})")


def cmd_run(args) -> None:
    with open(args.spec) as f:
        d = json.load(f)
    # Accept either a bare spec or a saved ExperimentResult (replay).
    spec = ExperimentSpec.from_dict(d.get("spec", d))
    if args.set:
        spec = spec.replace(**_parse_kv(args.set))
    _run_spec(spec, args)


def cmd_preset(args) -> None:
    spec = get_preset(args.name, **_parse_kv(args.arg))
    if args.set:
        spec = spec.replace(**_parse_kv(args.set))
    wrote_spec = bool(args.out)
    if wrote_spec:
        spec.save(args.out)
        print(f"spec -> {args.out}")
        args.out = None  # --out holds the spec; don't overwrite with a result
    if args.run or not wrote_spec:
        _run_spec(spec, args)


def cmd_list(args) -> None:
    print("schedulers:", ", ".join(SCHEDULERS.names()))
    print("runtimes:  ", ", ".join(RUNTIMES.names()))
    print("presets:   ", ", ".join(list_presets()))
    # Trained policies usable via the spec's `policy` axis (repro.gym.zoo).
    from repro.gym.zoo import DEFAULT_ZOO_DIR, PolicyZoo

    names = PolicyZoo(DEFAULT_ZOO_DIR).names()
    if names:
        print("policies:  ", ", ".join(names),
              f"(--set policy=<name>, zoo dir {DEFAULT_ZOO_DIR!r})")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="python -m repro.experiment.cli",
                                 description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_run = sub.add_parser("run", help="run an ExperimentSpec JSON file")
    p_run.add_argument("spec", help="path to spec.json (or a saved result)")
    p_run.add_argument("--set", action="append", metavar="K=V",
                       help="override a top-level spec field")
    p_run.add_argument("--out", help="write the ExperimentResult JSON here")
    p_run.add_argument("--verbose", action="store_true")
    p_run.set_defaults(fn=cmd_run)

    p_pre = sub.add_parser("preset", help="materialize (and optionally run) "
                                          "a named preset")
    p_pre.add_argument("name", help="preset name (see `list`)")
    p_pre.add_argument("--arg", action="append", metavar="K=V",
                       help="preset factory argument")
    p_pre.add_argument("--set", action="append", metavar="K=V",
                       help="override a top-level spec field")
    p_pre.add_argument("--out", help="write the spec JSON here (skips the "
                                     "run unless --run)")
    p_pre.add_argument("--run", action="store_true")
    p_pre.add_argument("--verbose", action="store_true")
    p_pre.set_defaults(fn=cmd_preset)

    p_ls = sub.add_parser("list", help="list registered schedulers / "
                                       "runtimes / presets")
    p_ls.set_defaults(fn=cmd_list)

    args = ap.parse_args(argv)
    args.fn(args)


if __name__ == "__main__":
    main(sys.argv[1:])
