"""``SLOSpec``: the serve-plane service-level-objective axis.

One frozen, JSON-round-trippable axis describes how the online scheduler
service must DEGRADE under pressure instead of stalling (the graceful-
degradation contract of ``repro.serve.resilience``):

- **Decision deadline** (``decision_deadline_ms``): a wall-clock latency
  budget on every scheduling decision. The decision governor picks the
  highest-quality rung of the degradation ladder — full search ->
  incremental rescore of the cached plan -> greedy fallback ->
  last-known-good plan — whose recent latency fits the budget, and records
  which rung fired in the round record.
- **Overload control** (``max_queue_depth``): deterministic queue-depth
  backpressure. Arrivals beyond the depth bound are SHED; a deep (but not
  full) queue degrades the decision ladder one rung at a time, and a
  rolling-p99 breach of the deadline defers (or sheds, ``shed_policy``)
  admissions even when a slot is free.
- **Circuit breakers** (``breaker_threshold``): per-tenant and
  per-fault-domain breakers open after N consecutive fault-quarantined
  ("bad") rounds, stay open for ``breaker_cooldown`` simulated seconds,
  then half-open for a single probe. Open tenant breakers shed that
  tenant's arrivals; open domain breakers mask the domain's devices out
  of scheduling. Breaker state is checkpointed (kill -9 safe).
- **Bounded retries** (``max_launch_retries``/``max_agg_retries``): the
  engine's transient-shortage relaunch path retries at most N times with
  exponential simulated-time backoff (``retry_base_delay * retry_backoff
  ** tries``) before launching a clamped cohort; aggregation failures are
  retried at most ``max_agg_retries`` times before the round is recorded
  degraded with carried-forward metrics. ``None``/0 keeps the historical
  retry-forever / fail-fast semantics bit-identically.
- **Watchdog** (``watchdog_rounds``): the service checks the engine's
  liveness invariant at every traffic-event boundary; a job stalled for N
  consecutive checks triggers an in-place restore from the newest
  committed ``repro.checkpoint`` snapshot (at most ``max_recoveries``
  times per run).

Determinism contract: an INERT spec (the default — every knob off) must
leave executed trajectories bit-identical to ``slo=None``; with only the
deterministic knobs set (no ``decision_deadline_ms``), rung choices depend
only on simulated state, so crash/resume stays bit-identical too.
Wall-clock-driven degradation (the deadline) is intrinsically
non-replayable, which is why ``decision_ms`` rides in round records only
when the deadline is set.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

SHED_POLICIES = ("defer", "shed")


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Declarative serve-resilience objectives (see module docstring)."""

    # Wall-clock latency budget per scheduling decision; None -> no budget.
    decision_deadline_ms: Optional[float] = None
    # A rung is eligible while its recent latency fits within
    # deadline * deadline_safety (headroom against noise spikes).
    deadline_safety: float = 0.8
    # Rolling window: per-rung latency samples and the admission p99.
    latency_window: int = 32
    # Every N latency-forced degradations, re-probe the next-better rung.
    rung_probe_every: int = 16
    # Admission backpressure: queue depth bound (None -> unbounded) and the
    # response to a rolling-p99 deadline breach ("defer" queues the arrival
    # even when a slot is free; "shed" drops it).
    max_queue_depth: Optional[int] = None
    shed_policy: str = "defer"
    # Event-bus watchdog: consecutive stalled liveness checks before a
    # checkpoint restore fires; 0 -> watchdog off.
    watchdog_rounds: int = 0
    max_recoveries: int = 3
    # Circuit breakers: N consecutive bad rounds opens (0 -> breakers off);
    # cooldown is SIMULATED seconds open before the half-open probe; a round
    # is "bad" for a tenant when it degraded or >= breaker_failure_frac of
    # its cohort was fault-quarantined.
    breaker_threshold: int = 0
    breaker_cooldown: float = 2000.0
    breaker_failure_frac: float = 0.5
    # Bounded launch retries (transient device shortage): None keeps the
    # legacy wait-for-next-release forever; N bounds it with exponential
    # simulated-time backoff, then launches whatever is available.
    max_launch_retries: Optional[int] = None
    retry_backoff: float = 2.0
    retry_base_delay: float = 1.0
    # Bounded aggregation/dispatch retries (runtime.run_round raising):
    # 0 keeps fail-fast; N retries then records a degraded round.
    max_agg_retries: int = 0

    def __post_init__(self):
        if self.shed_policy not in SHED_POLICIES:
            raise ValueError(f"shed_policy {self.shed_policy!r} not in "
                             f"{SHED_POLICIES}")
        if self.decision_deadline_ms is not None \
                and self.decision_deadline_ms <= 0:
            raise ValueError("decision_deadline_ms must be positive")
        if not 0.0 < self.deadline_safety <= 1.0:
            raise ValueError("deadline_safety must be in (0, 1]")
        if self.latency_window < 1:
            raise ValueError("latency_window must be >= 1")
        if self.rung_probe_every < 1:
            raise ValueError("rung_probe_every must be >= 1")
        if self.retry_backoff < 1.0:
            raise ValueError("retry_backoff must be >= 1 (retry delays "
                             "never shrink)")
        if not 0.0 < self.breaker_failure_frac <= 1.0:
            raise ValueError("breaker_failure_frac must be in (0, 1]")
        for name in ("watchdog_rounds", "max_recoveries", "breaker_threshold",
                     "max_agg_retries"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0")

    @property
    def inert(self) -> bool:
        """True when this spec changes nothing (the engine/service skip the
        resilience path entirely — the bit-identity contract)."""
        return (self.decision_deadline_ms is None
                and self.max_queue_depth is None
                and self.watchdog_rounds == 0
                and self.breaker_threshold == 0
                and self.max_launch_retries is None
                and self.max_agg_retries == 0)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "SLOSpec":
        return cls(**d)
