"""Built-in runtime factories for the experiment registry.

A runtime factory turns ``(spec, jobs, pool, **runtime_kwargs)`` into an
object implementing the engine's ``JobRuntime`` protocol. Two kinds ship:

- ``synthetic`` — the closed-form convergence model (scheduler-plane studies,
  fast tests). Per-job ``convergence_rate`` from the spec's jobs becomes the
  runtime's per-job ``b0`` array.
- ``real_fl`` — the paper's testbed: REAL vmap'd local SGD + FedAvg on
  synthetic prototype data partitioned IID or non-IID (§5). By default this
  is the fused, recompile-free ``FusedMultiRuntime`` (bucketed cohort
  shapes, device-resident data, cross-job batched dispatch); the spec's
  ``train`` axis (``TrainSpec``) selects the unfused per-job
  ``FLJobRuntime`` baseline and carries the bucket/eval_every knobs.

Registering a new kind is one decorator: ``@register_runtime("my_kind")``.
"""

from __future__ import annotations

import warnings
from typing import List

import numpy as np

from repro.config.base import JobConfig
from repro.core.devices import DevicePool
from repro.experiment.registry import register_runtime
from repro.fl.runtime import (DEFAULT_B0, FLJobRuntime, FusedMultiRuntime,
                              MultiRuntime, SyntheticRuntime)


@register_runtime("synthetic")
def synthetic_runtime(spec, jobs: List[JobConfig], pool: DevicePool, *,
                      seed: int = 0, num_classes: int = 10,
                      classes_per_device: int = None, **kwargs):
    if classes_per_device is None:
        classes_per_device = 2 if spec.non_iid else num_classes
    rates = [js.convergence_rate for js in spec.jobs]
    if any(r is not None for r in rates) and "b0" not in kwargs:
        kwargs["b0"] = np.array(
            [DEFAULT_B0 if r is None else float(r) for r in rates])
    return SyntheticRuntime(num_jobs=len(jobs), num_devices=pool.num_devices,
                            num_classes=num_classes,
                            classes_per_device=classes_per_device,
                            seed=seed, **kwargs)


@register_runtime("real_fl")
def real_fl_runtime(spec, jobs: List[JobConfig], pool: DevicePool, *,
                    samples_per_job: int = 8000, eval_samples: int = 800,
                    noise: float = 1.2, data_seed: int = 0,
                    init_seed: int = 0, classes_per_device: int = 2,
                    parts_per_class: int = 20):
    from repro.data.synthetic import make_classification_dataset
    from repro.fl.partition import iid_partition, noniid_partition

    datasets = []
    for jid, job in enumerate(jobs):
        cfg = job.model
        x, y = make_classification_dataset(
            samples_per_job, cfg.input_shape, cfg.num_classes, noise=noise,
            seed=data_seed + jid)
        ex, ey = make_classification_dataset(
            eval_samples, cfg.input_shape, cfg.num_classes, noise=noise,
            seed=data_seed + 100 + jid)
        if spec.non_iid:
            part = noniid_partition(y, pool.num_devices,
                                    classes_per_device=classes_per_device,
                                    parts_per_class=parts_per_class,
                                    seed=data_seed + jid)
        else:
            part = iid_partition(y, pool.num_devices,
                                 samples_per_device=samples_per_job
                                 // pool.num_devices,
                                 seed=data_seed + jid)
        datasets.append((x, y, part, ex, ey))

    train = spec.train
    if train.fused:
        buckets = train.buckets
        if buckets is None:
            # Align buckets with the engine's operating points: the steady
            # cohort (n_sel) and the over-provisioned selection pad to
            # themselves, so the common case trains with ZERO padded lanes
            # and the power-of-two ladder only absorbs failure jitter.
            from repro.fl.runtime import default_buckets

            K = pool.num_devices
            n_hot = spec.effective_n_sel()
            sched = min(K, max(n_hot, int(round(n_hot * spec.over_provision))))
            buckets = tuple(sorted(set(default_buckets(K)) | {n_hot, sched}))
        # One fused runtime over all jobs: the per-job init seeds match the
        # unfused path (seed=init_seed + job_id) so fused/unfused runs are
        # comparable round-for-round at equal specs.
        fault_engine = None
        if train.robust:
            # The runtime re-draws corrupt masks from the SAME keyed
            # schedule as the engine — a second FaultEngine over the same
            # spec replays identically, so no state is shared.
            fspec = spec.effective_faults()
            if fspec is not None and not fspec.inert:
                from repro.faults import FaultEngine

                fault_engine = FaultEngine(fspec, pool.num_devices)
        return FusedMultiRuntime(jobs, datasets, seed=init_seed,
                                 buckets=buckets,
                                 eval_every=train.eval_every,
                                 robust=train.robust,
                                 reject_mult=train.reject_mult,
                                 fault_engine=fault_engine)
    if train.robust:
        warnings.warn(
            "TrainSpec.robust requires the fused runtime; the unfused "
            "baseline aggregates without fault screening", RuntimeWarning)
    if train.buckets is not None or train.eval_every != 1:
        warnings.warn(
            "TrainSpec.buckets/eval_every only apply to the fused runtime; "
            "the unfused baseline has no cohort buckets and evaluates every "
            "round", RuntimeWarning)
    return MultiRuntime([
        FLJobRuntime(job, *ds, seed=init_seed + jid)
        for jid, (job, ds) in enumerate(zip(jobs, datasets))])
