"""Synthetic datasets with real learnable structure.

``make_classification_dataset`` builds class-prototype image data: each class
c has a fixed random prototype P_c; a sample is x = P_c + sigma * noise with
label c. Models must learn the prototypes -> accuracy is a real function of
training, capacity, and (critically for the paper) WHICH devices' label
shards participated — the property the fairness term exploits.

``make_lm_tokens`` builds an order-2 Markov token stream with a Zipfian
marginal so LM training steps have non-trivial learnable signal.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


def make_classification_dataset(
    num_samples: int,
    input_shape: Tuple[int, ...],
    num_classes: int,
    noise: float = 1.0,
    seed: int = 0,
    proto_seed: int = 1234,
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (x: (N, *input_shape) float32, y: (N,) int32).

    ``proto_seed`` fixes the class prototypes INDEPENDENTLY of the sampling
    seed, so train and eval splits drawn with different ``seed`` values share
    the same underlying task.
    """
    rng_p = np.random.default_rng(proto_seed)
    protos = rng_p.normal(0.0, 1.0, size=(num_classes, *input_shape)).astype(np.float32)
    rng = np.random.default_rng(seed)
    y = rng.integers(0, num_classes, size=num_samples).astype(np.int32)
    x = protos[y] + noise * rng.normal(0.0, 1.0, size=(num_samples, *input_shape)).astype(np.float32)
    return x.astype(np.float32), y


def make_lm_tokens(num_tokens: int, vocab_size: int, seed: int = 0,
                   zipf_a: float = 1.2) -> np.ndarray:
    """Order-2 Markov chain over a Zipfian vocabulary. (num_tokens,) int32."""
    rng = np.random.default_rng(seed)
    # Sparse transition structure: each (prev token bucket) prefers 8 successors.
    buckets = 256
    succ = rng.integers(0, vocab_size, size=(buckets, 8))
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    zipf_p = ranks ** (-zipf_a)
    zipf_p /= zipf_p.sum()
    out = np.empty(num_tokens, dtype=np.int32)
    tok = int(rng.integers(0, vocab_size))
    for i in range(num_tokens):
        if rng.random() < 0.7:
            tok = int(succ[tok % buckets, rng.integers(0, 8)])
        else:
            tok = int(rng.choice(vocab_size, p=zipf_p))
        out[i] = tok
    return out
