"""Batching / host-local data feeding for the distributed plane.

``Batcher`` is a deterministic, restartable batch iterator (epoch + cursor are
part of its state so checkpoints can resume the pipeline exactly).
``host_local_batches`` yields the per-host slice of a global batch for
multi-host pjit feeding (device_put against the host-local sharding).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class Batcher:
    x: np.ndarray
    y: np.ndarray
    batch_size: int
    seed: int = 0
    epoch: int = 0
    cursor: int = 0

    def __post_init__(self):
        self._order = self._perm(self.epoch)

    def _perm(self, epoch: int) -> np.ndarray:
        return np.random.default_rng(self.seed + epoch).permutation(len(self.x))

    def state(self) -> Dict[str, int]:
        return {"epoch": self.epoch, "cursor": self.cursor, "seed": self.seed}

    def restore(self, state: Dict[str, int]) -> None:
        self.epoch, self.cursor, self.seed = state["epoch"], state["cursor"], state["seed"]
        self._order = self._perm(self.epoch)

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        return self

    def __next__(self) -> Tuple[np.ndarray, np.ndarray]:
        if self.cursor + self.batch_size > len(self.x):
            self.epoch += 1
            self.cursor = 0
            self._order = self._perm(self.epoch)
        idx = self._order[self.cursor: self.cursor + self.batch_size]
        self.cursor += self.batch_size
        return self.x[idx], self.y[idx]


def host_local_batches(global_batch: np.ndarray, host_id: int, num_hosts: int) -> np.ndarray:
    """Slice the per-host shard of a global batch along axis 0."""
    per_host = global_batch.shape[0] // num_hosts
    return global_batch[host_id * per_host: (host_id + 1) * per_host]
