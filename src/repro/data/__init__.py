"""Data pipelines: synthetic class-prototype image sets (offline container —
no CIFAR/MNIST downloads), LM token streams, and sharding-aware batching."""

from repro.data.synthetic import (
    make_classification_dataset,
    make_lm_tokens,
)
from repro.data.pipeline import Batcher, host_local_batches

__all__ = [
    "make_classification_dataset",
    "make_lm_tokens",
    "Batcher",
    "host_local_batches",
]
