"""Data partitioners — exactly the paper's §5 setups.

IID: each device uniformly samples a fixed number of examples.
Non-IID: "the training set is classified by category, and the samples of each
category are divided into 20 parts. Each device randomly selects two
categories and then selects one part from each category."

Both return an (num_devices, samples_per_device) int index matrix into the
global arrays — fixed width so device datasets stack/vmap with static shapes.
"""

from __future__ import annotations

import numpy as np


def iid_partition(labels: np.ndarray, num_devices: int, samples_per_device: int,
                  seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    n = len(labels)
    return rng.integers(0, n, size=(num_devices, samples_per_device)).astype(np.int64)


def noniid_partition(labels: np.ndarray, num_devices: int,
                     classes_per_device: int = 2, parts_per_class: int = 20,
                     seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    # Split each class into `parts_per_class` equal parts.
    parts = {}
    min_part = np.inf
    for c in classes:
        idx = rng.permutation(np.flatnonzero(labels == c))
        chunks = np.array_split(idx, parts_per_class)
        parts[c] = chunks
        min_part = min(min_part, min(len(ch) for ch in chunks))
    width = int(min_part) * classes_per_device
    if width == 0:
        # Some class split into `parts_per_class` chunks came out empty —
        # every device shard would be width 0 (a zero-row gather the local
        # train step can only skip). Fail early with the actual sizing math
        # instead of a downstream ZeroDivisionError.
        counts = {int(c): int(np.count_nonzero(labels == c)) for c in classes}
        starved = min(counts, key=counts.get)
        raise ValueError(
            f"noniid_partition: {len(labels)} samples over {len(classes)} "
            f"classes split {parts_per_class} ways leaves class {starved} "
            f"(n={counts[starved]}) with empty parts (width 0). Provide "
            f">= {parts_per_class} samples per class or lower "
            f"parts_per_class.")
    out = np.zeros((num_devices, width), dtype=np.int64)
    for k in range(num_devices):
        cs = rng.choice(classes, size=classes_per_device, replace=False)
        chosen = []
        for c in cs:
            part = parts[c][rng.integers(0, parts_per_class)]
            chosen.append(part[: width // classes_per_device])
        sel = np.concatenate(chosen)
        if len(sel) < width:  # pad by resampling (rare ragged tail)
            sel = np.concatenate([sel, rng.choice(sel, width - len(sel))])
        out[k] = sel
    return out


def device_label_histogram(labels: np.ndarray, partition: np.ndarray,
                           num_classes: int) -> np.ndarray:
    """(num_devices, num_classes) label counts — used in tests/fairness analysis."""
    K = partition.shape[0]
    out = np.zeros((K, num_classes), dtype=np.int64)
    for k in range(K):
        binc = np.bincount(labels[partition[k]], minlength=num_classes)
        out[k] = binc[:num_classes]
    return out
