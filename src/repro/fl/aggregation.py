"""Server-side aggregation.

``fedavg``: data-size-weighted average of device models (McMahan 2017),
operating on a pytree whose leaves carry a leading device axis (the output of
the vmap'd local trainer). ``fedavg_compressed`` aggregates top-k sparsified
deltas with server-side decompression — the FL-plane gradient-compression
path. The per-device compress/decompress is vmapped over the device axis and
the decompression itself is a weighted scatter-add (``repro.kernels``:
Pallas kernel on TPU, jnp fallback elsewhere) — no dense per-device delta is
ever materialized. ``fedavg_compressed_loop`` keeps the historical
one-device-at-a-time path as the semantics reference.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.optim.compression import _leaf_topk, topk_compress, topk_decompress

PyTree = Any


def fedavg(stacked_params: PyTree, weights: jnp.ndarray) -> PyTree:
    """weights: (n_devices,) — normalized inside. Zero-weight lanes (bucket
    padding, dropped devices) contribute exactly nothing."""
    w = weights / jnp.maximum(weights.sum(), 1e-12)

    def avg(leaf):
        wshape = (-1,) + (1,) * (leaf.ndim - 1)
        return jnp.sum(leaf * w.reshape(wshape), axis=0)

    return jax.tree_util.tree_map(avg, stacked_params)


# ---- robust aggregation (fault screening) ----
#
# THE rejection rule — one definition, two implementations (the jitted
# ``rejection_mask`` the fused round runs, and the numpy
# ``rejection_mask_host`` reference it is parity-tested against):
#
#   participating = weight > 0
#   finite_i      = every leaf of device i's update is finite
#   norm_i        = || update_i - global ||_2   (float32, over all leaves)
#   med           = lower median of norm over participating finite devices
#   keep_i        = participating_i & finite_i & (norm_i <= mult * med + eps)
#
# The masked-median threshold adapts to the cohort's own update scale, so
# no absolute norm bound needs tuning; ``mult`` (TrainSpec.reject_mult)
# sets how many times the typical update a device may move before being
# called corrupted.

_REJECT_EPS = 1e-6


def _delta_sq_norms(global_params: PyTree, stacked_params: PyTree,
                    xp) -> Any:
    """(n,) squared delta norms in float32, leaf order = tree_flatten."""
    g_leaves = jax.tree_util.tree_leaves(global_params)
    s_leaves = jax.tree_util.tree_leaves(stacked_params)
    sq = None
    for g, s in zip(g_leaves, s_leaves):
        d = xp.asarray(s, xp.float32) - xp.asarray(g, xp.float32)[None]
        n = d.shape[0]
        part = xp.sum(d.reshape(n, -1) ** 2, axis=1)
        sq = part if sq is None else sq + part
    return sq


def _all_finite(stacked_params: PyTree, xp) -> Any:
    fin = None
    for s in jax.tree_util.tree_leaves(stacked_params):
        n = s.shape[0]
        part = xp.all(xp.isfinite(xp.asarray(s, xp.float32)).reshape(n, -1),
                      axis=1)
        fin = part if fin is None else fin & part
    return fin


def rejection_mask(global_params: PyTree, stacked_params: PyTree,
                   weights: jnp.ndarray,
                   mult: jnp.ndarray) -> jnp.ndarray:
    """(n,) bool keep mask under THE rejection rule (jit-safe)."""
    part = weights > 0
    finite = _all_finite(stacked_params, jnp)
    sq = _delta_sq_norms(global_params, stacked_params, jnp)
    norm = jnp.sqrt(sq)
    valid = part & finite
    ranked = jnp.sort(jnp.where(valid, norm, jnp.inf))
    cnt = valid.sum()
    med = ranked[jnp.maximum(cnt - 1, 0) // 2]  # lower median
    # Median-of-one degenerate: a single surviving lane IS its own median,
    # so the threshold test is vacuous (and with mult < 1 would reject the
    # only update we have) — keep it unconditionally.
    # NaN norms compare False, but keep the finite guard explicit.
    return part & finite & ((norm <= mult * med + _REJECT_EPS) | (cnt <= 1))


def rejection_mask_host(global_params: PyTree, stacked_params: PyTree,
                        weights: np.ndarray,
                        mult: float) -> np.ndarray:
    """Numpy reference of ``rejection_mask`` — the parity contract the
    fused round's in-jit screening is tested against."""
    weights = np.asarray(weights)
    part = weights > 0
    with np.errstate(invalid="ignore", over="ignore"):
        finite = np.asarray(_all_finite(stacked_params, np))
        norm = np.sqrt(np.asarray(
            _delta_sq_norms(global_params, stacked_params, np)))
    valid = part & finite
    if not valid.any():
        return np.zeros_like(part)
    if int(valid.sum()) == 1:
        # Median-of-one: the sole survivor is its own median — keep it.
        return valid.copy()
    med = np.sort(norm[valid])[(int(valid.sum()) - 1) // 2]
    with np.errstate(invalid="ignore"):
        ok = norm <= float(mult) * med + _REJECT_EPS
    return part & finite & np.where(np.isnan(norm), False, ok)


def robust_fedavg(global_params: PyTree, stacked_params: PyTree,
                  weights: jnp.ndarray,
                  mult: jnp.ndarray) -> Tuple[PyTree, jnp.ndarray]:
    """FedAvg over the lanes that survive the rejection rule.

    Rejected lanes are ZEROED before averaging (a NaN lane with zero
    weight would still poison ``sum(leaf * w)``), and when every lane is
    rejected the previous global params are returned unchanged (the round
    aggregates nothing rather than zeroing the model). Returns
    ``(new_params, keep_mask)``.
    """
    ok = rejection_mask(global_params, stacked_params, weights, mult)
    okf = ok.astype(jnp.float32)

    def zero_nan(leaf):
        shape = (-1,) + (1,) * (leaf.ndim - 1)
        return jnp.where(jnp.broadcast_to(ok.reshape(shape), leaf.shape),
                         leaf, jnp.zeros((), leaf.dtype))

    cleaned = jax.tree_util.tree_map(zero_nan, stacked_params)
    avg = fedavg(cleaned, weights * okf)
    any_kept = (weights * okf).sum() > 0
    new = jax.tree_util.tree_map(
        lambda a, g: jnp.where(any_kept, a, g), avg, global_params)
    return new, ok


def fedavg_compressed(global_params: PyTree, stacked_params: PyTree,
                      weights: jnp.ndarray, ratio: float,
                      impl: Optional[str] = None) -> PyTree:
    """Devices upload top-k sparsified DELTAS; the server averages them.

    Vectorized: per leaf, every device's top-k runs in one vmapped call and
    the weighted decompress-accumulate is one scatter-add over the (n, k)
    sparse stream (``impl`` selects the kernel path: ref | pallas |
    interpret; None -> the kernels-package default). Equivalent communication
    model to production FL compression; the return is the new global model.
    """
    w = weights / jnp.maximum(weights.sum(), 1e-12)

    def per_leaf(g, s):
        n = s.shape[0]
        flat = (s - g[None]).reshape(n, -1)            # (n, size) deltas
        k = int(max(1, round(ratio * g.size)))
        vals, idx = jax.vmap(lambda row: _leaf_topk(row, k))(flat)
        agg = ops.scatter_add(vals, idx, w, g.size, impl=impl)
        return g + agg.astype(g.dtype).reshape(g.shape)

    return jax.tree_util.tree_map(per_leaf, global_params, stacked_params)


def fedavg_compressed_loop(global_params: PyTree, stacked_params: PyTree,
                           weights: jnp.ndarray, ratio: float) -> PyTree:
    """Pre-vectorization reference: Python loop over devices, one dense
    decompressed delta per device. Kept as the numerical-equivalence contract
    for ``fedavg_compressed`` (see tests/test_fl.py)."""
    n = weights.shape[0]
    w = weights / jnp.maximum(weights.sum(), 1e-12)

    def one_device(i):
        delta = jax.tree_util.tree_map(
            lambda s, g: s[i] - g, stacked_params, global_params)
        (vals, idx), _ = topk_compress(delta, ratio)
        return topk_decompress(vals, idx, global_params)

    agg = one_device(0)
    agg = jax.tree_util.tree_map(lambda d: d * w[0], agg)
    for i in range(1, n):
        d_i = one_device(i)
        agg = jax.tree_util.tree_map(lambda a, d: a + d * w[i], agg, d_i)
    return jax.tree_util.tree_map(lambda g, d: g + d, global_params, agg)
