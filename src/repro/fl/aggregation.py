"""Server-side aggregation.

``fedavg``: data-size-weighted average of device models (McMahan 2017),
operating on a pytree whose leaves carry a leading device axis (the output of
the vmap'd local trainer). ``fedavg_compressed`` aggregates top-k sparsified
deltas with server-side decompression — the FL-plane gradient-compression
path.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.optim.compression import topk_compress, topk_decompress

PyTree = Any


def fedavg(stacked_params: PyTree, weights: jnp.ndarray) -> PyTree:
    """weights: (n_devices,) — normalized inside."""
    w = weights / jnp.maximum(weights.sum(), 1e-12)

    def avg(leaf):
        wshape = (-1,) + (1,) * (leaf.ndim - 1)
        return jnp.sum(leaf * w.reshape(wshape), axis=0)

    return jax.tree_util.tree_map(avg, stacked_params)


def fedavg_compressed(global_params: PyTree, stacked_params: PyTree,
                      weights: jnp.ndarray, ratio: float) -> PyTree:
    """Devices upload top-k sparsified DELTAS; the server averages them.

    Equivalent communication model to production FL compression; the return
    is the new global model.
    """
    n = weights.shape[0]
    w = weights / jnp.maximum(weights.sum(), 1e-12)

    def one_device(i):
        delta = jax.tree_util.tree_map(
            lambda s, g: s[i] - g, stacked_params, global_params)
        (vals, idx), _ = topk_compress(delta, ratio)
        return topk_decompress(vals, idx, global_params)

    agg = one_device(0)
    agg = jax.tree_util.tree_map(lambda d: d * w[0], agg)
    for i in range(1, n):
        d_i = one_device(i)
        agg = jax.tree_util.tree_map(lambda a, d: a + d * w[i], agg, d_i)
    return jax.tree_util.tree_map(lambda g, d: g + d, global_params, agg)
