"""Server-side aggregation.

``fedavg``: data-size-weighted average of device models (McMahan 2017),
operating on a pytree whose leaves carry a leading device axis (the output of
the vmap'd local trainer). ``fedavg_compressed`` aggregates top-k sparsified
deltas with server-side decompression — the FL-plane gradient-compression
path. The per-device compress/decompress is vmapped over the device axis and
the decompression itself is a weighted scatter-add (``repro.kernels``:
Pallas kernel on TPU, jnp fallback elsewhere) — no dense per-device delta is
ever materialized. ``fedavg_compressed_loop`` keeps the historical
one-device-at-a-time path as the semantics reference.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.optim.compression import _leaf_topk, topk_compress, topk_decompress

PyTree = Any


def fedavg(stacked_params: PyTree, weights: jnp.ndarray) -> PyTree:
    """weights: (n_devices,) — normalized inside. Zero-weight lanes (bucket
    padding, dropped devices) contribute exactly nothing."""
    w = weights / jnp.maximum(weights.sum(), 1e-12)

    def avg(leaf):
        wshape = (-1,) + (1,) * (leaf.ndim - 1)
        return jnp.sum(leaf * w.reshape(wshape), axis=0)

    return jax.tree_util.tree_map(avg, stacked_params)


def fedavg_compressed(global_params: PyTree, stacked_params: PyTree,
                      weights: jnp.ndarray, ratio: float,
                      impl: Optional[str] = None) -> PyTree:
    """Devices upload top-k sparsified DELTAS; the server averages them.

    Vectorized: per leaf, every device's top-k runs in one vmapped call and
    the weighted decompress-accumulate is one scatter-add over the (n, k)
    sparse stream (``impl`` selects the kernel path: ref | pallas |
    interpret; None -> the kernels-package default). Equivalent communication
    model to production FL compression; the return is the new global model.
    """
    w = weights / jnp.maximum(weights.sum(), 1e-12)

    def per_leaf(g, s):
        n = s.shape[0]
        flat = (s - g[None]).reshape(n, -1)            # (n, size) deltas
        k = int(max(1, round(ratio * g.size)))
        vals, idx = jax.vmap(lambda row: _leaf_topk(row, k))(flat)
        agg = ops.scatter_add(vals, idx, w, g.size, impl=impl)
        return g + agg.astype(g.dtype).reshape(g.shape)

    return jax.tree_util.tree_map(per_leaf, global_params, stacked_params)


def fedavg_compressed_loop(global_params: PyTree, stacked_params: PyTree,
                           weights: jnp.ndarray, ratio: float) -> PyTree:
    """Pre-vectorization reference: Python loop over devices, one dense
    decompressed delta per device. Kept as the numerical-equivalence contract
    for ``fedavg_compressed`` (see tests/test_fl.py)."""
    n = weights.shape[0]
    w = weights / jnp.maximum(weights.sum(), 1e-12)

    def one_device(i):
        delta = jax.tree_util.tree_map(
            lambda s, g: s[i] - g, stacked_params, global_params)
        (vals, idx), _ = topk_compress(delta, ratio)
        return topk_decompress(vals, idx, global_params)

    agg = one_device(0)
    agg = jax.tree_util.tree_map(lambda d: d * w[0], agg)
    for i in range(1, n):
        d_i = one_device(i)
        agg = jax.tree_util.tree_map(lambda a, d: a + d * w[i], agg, d_i)
    return jax.tree_util.tree_map(lambda g, d: g + d, global_params, agg)
