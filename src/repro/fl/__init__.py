"""Federated substrate: partitioning, fused vmap'd local training, FedAvg,
runtimes (fused recompile-free engine + unfused baseline + synthetic)."""

from repro.fl.partition import iid_partition, noniid_partition
from repro.fl.aggregation import (fedavg, fedavg_compressed,
                                  fedavg_compressed_loop)
from repro.fl.runtime import (FLJobRuntime, FusedMultiRuntime, MultiRuntime,
                              SyntheticRuntime, bucket_for, default_buckets)

__all__ = [
    "iid_partition",
    "noniid_partition",
    "fedavg",
    "fedavg_compressed",
    "fedavg_compressed_loop",
    "FLJobRuntime",
    "FusedMultiRuntime",
    "MultiRuntime",
    "SyntheticRuntime",
    "bucket_for",
    "default_buckets",
]
