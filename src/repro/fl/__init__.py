"""Federated substrate: partitioning, vmap'd local training, FedAvg, runtimes."""

from repro.fl.partition import iid_partition, noniid_partition
from repro.fl.aggregation import fedavg, fedavg_compressed
from repro.fl.runtime import FLJobRuntime, SyntheticRuntime

__all__ = [
    "iid_partition",
    "noniid_partition",
    "fedavg",
    "fedavg_compressed",
    "FLJobRuntime",
    "SyntheticRuntime",
]
