"""Job runtimes: what actually happens when a round's devices "train".

``FLJobRuntime`` — REAL training, faithful to the paper's testbed: each
scheduled device runs ``local_epochs`` of minibatch SGD on its own partition
(vmap over devices — the testbed's 12-GPU simulation collapsed onto vectorized
lanes), the server FedAvg-aggregates by data size, and accuracy is measured on
a held-out set. Wall-clock is simulated by the engine; learning is real.

``SyntheticRuntime`` — closed-form convergence model for scheduler-only
studies and fast tests: accuracy follows a saturating curve whose CEILING is
set by label coverage of the devices scheduled so far (non-IID: each device
holds 2 of C classes, so starving devices starves classes — the mechanism the
paper's fairness term addresses) and whose RATE follows Formula 13.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import JobConfig, ModelConfig
from repro.fl.aggregation import fedavg
from repro.models.cnn_zoo import cnn_apply, cnn_init, cnn_loss_and_accuracy


@functools.partial(jax.jit, static_argnames=("cfg", "epochs", "batch_size", "lr"))
def _local_train_one(params, cfg: ModelConfig, x, y, epochs: int,
                     batch_size: int, lr: float):
    """SGD local update of one device. x: (W, ...), y: (W,)."""
    W = x.shape[0]
    steps = max(W // batch_size, 1)
    xb = x[: steps * batch_size].reshape(steps, batch_size, *x.shape[1:])
    yb = y[: steps * batch_size].reshape(steps, batch_size)

    def loss_fn(p, bx, by):
        logits = cnn_apply(p, cfg, bx)
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, by[:, None], axis=1).mean()

    def step(p, batch):
        bx, by = batch
        g = jax.grad(loss_fn)(p, bx, by)
        return jax.tree_util.tree_map(lambda pp, gg: pp - lr * gg, p, g), ()

    def epoch(p, _):
        p, _ = jax.lax.scan(step, p, (xb, yb))
        return p, ()

    params, _ = jax.lax.scan(epoch, params, None, length=epochs)
    return params


_local_train_batch = jax.jit(
    jax.vmap(_local_train_one, in_axes=(None, None, 0, 0, None, None, None)),
    static_argnames=("cfg", "epochs", "batch_size", "lr"))


class FLJobRuntime:
    """Runtime for ONE job (the engine holds one per job via ``MultiRuntime``)."""

    def __init__(self, job: JobConfig, x: np.ndarray, y: np.ndarray,
                 partition: np.ndarray, eval_x: np.ndarray, eval_y: np.ndarray,
                 seed: int = 0):
        self.job = job
        self.cfg = job.model
        self.x, self.y = jnp.asarray(x), jnp.asarray(y.astype(np.int32))
        self.partition = partition
        self.eval_x, self.eval_y = jnp.asarray(eval_x), jnp.asarray(eval_y.astype(np.int32))
        self.params = cnn_init(self.cfg, seed=seed)
        self._eval = jax.jit(functools.partial(cnn_loss_and_accuracy, cfg=self.cfg))

    def run_round(self, job_id: int, device_ids: np.ndarray, round_idx: int
                  ) -> Dict[str, float]:
        idx = self.partition[np.asarray(device_ids)]          # (n, W)
        dev_x = self.x[jnp.asarray(idx)]                      # (n, W, ...)
        dev_y = self.y[jnp.asarray(idx)]
        locals_ = _local_train_batch(
            self.params, self.cfg, dev_x, dev_y,
            self.job.local_epochs, self.job.batch_size, self.job.lr)
        weights = jnp.asarray(idx.shape[1] * np.ones(len(device_ids)), jnp.float32)
        self.params = fedavg(locals_, weights)
        loss, acc = self._eval(self.params, x=self.eval_x, y=self.eval_y)
        return {"loss": float(loss), "accuracy": float(acc)}


class MultiRuntime:
    """Adapter: one FLJobRuntime per job behind the engine's JobRuntime protocol."""

    def __init__(self, runtimes):
        self.runtimes = list(runtimes)

    def run_round(self, job_id: int, device_ids: np.ndarray, round_idx: int):
        return self.runtimes[job_id].run_round(job_id, device_ids, round_idx)


DEFAULT_B0 = 0.15  # Formula 13 convergence rate when a job doesn't set one


class SyntheticRuntime:
    """Closed-form convergence: ceiling from class coverage, rate from Formula 13.

    acc_m(r) = ceiling_m * (1 - 1/(b0_m * r_eff + 1))  with r_eff the round
    count and ceiling_m = base + (1 - base) * coverage^p. coverage = fraction
    of the job's label classes seen in scheduled devices so far. Under IID
    (classes_per_device == num_classes) the ceiling is ~1 regardless, matching
    the paper's observation that fairness matters most under non-IID.

    ``b0`` is a scalar shared by all jobs or a (num_jobs,) array of per-job
    rates, so job complexity ordering (LeNet > CNN > VGG) converges at
    genuinely different speeds; ``None`` entries fall back to ``DEFAULT_B0``.
    """

    def __init__(self, num_jobs: int, num_devices: int, num_classes: int = 10,
                 classes_per_device: int = 2, b0=DEFAULT_B0,
                 base: float = 0.35, power: float = 1.5, seed: int = 0,
                 noise: float = 0.004):
        rng = np.random.default_rng(seed)
        self.num_classes = num_classes
        if num_devices > 4096:
            # Fleet pools: batched sampling-without-replacement (random keys
            # + argpartition) — one vectorized draw instead of num_devices
            # sequential rng.choice calls (milliseconds at K=100k). Same
            # distribution as the sequential draw; realizations differ, so
            # paper-scale pools keep the historical per-device stream below.
            keys = rng.random((num_devices, num_classes))
            self.device_classes = np.argpartition(
                keys, classes_per_device - 1, axis=1)[:, :classes_per_device]
        else:
            self.device_classes = np.stack([
                rng.choice(num_classes, size=classes_per_device, replace=False)
                for _ in range(num_devices)])
        self.seen = [np.zeros(num_classes, dtype=np.float64) for _ in range(num_jobs)]
        self.rounds = np.zeros(num_jobs, dtype=np.int64)
        if np.ndim(b0) > 0:
            b0 = np.array([DEFAULT_B0 if v is None else float(v) for v in b0])
            if b0.shape != (num_jobs,):
                raise ValueError(f"b0 has shape {b0.shape}, expected ({num_jobs},)")
        self.b0, self.base, self.power = b0, base, power
        self.noise = noise
        self.rng = rng

    def run_round(self, job_id: int, device_ids: np.ndarray, round_idx: int):
        hit = self.device_classes[np.asarray(device_ids)].ravel()
        np.add.at(self.seen[job_id], hit, 1.0)
        self.rounds[job_id] += 1
        # Coverage = 1 - TV(seen-class distribution, uniform): schedulers that
        # starve devices starve their classes and cap below the uniform optimum.
        s = self.seen[job_id]
        p = s / max(s.sum(), 1e-9)
        tv = 0.5 * float(np.abs(p - 1.0 / self.num_classes).sum())
        cov = 1.0 - tv
        ceiling = self.base + (1 - self.base) * cov ** self.power
        r = float(self.rounds[job_id])
        b = np.asarray(self.b0, dtype=np.float64)
        b0 = float(b[job_id] if b.ndim else b)
        acc = ceiling * (1 - 1 / (b0 * r + 1.0))
        acc = float(np.clip(acc + self.rng.normal(0, self.noise), 0, 1))
        loss = float(-np.log(max(acc, 1e-3)))
        return {"loss": loss, "accuracy": acc}
