"""Job runtimes: what actually happens when a round's devices "train".

``FusedMultiRuntime`` — the fused, recompile-free training engine (the
default real-training path). Three ideas compound:

- **Bucketed cohort shapes.** The engine's over-provisioning, straggler
  drops, and fault injection change the cohort size ``n`` from round to
  round; a jit specialized on ``n`` recompiles every time it moves. Cohorts
  are padded up to a small set of power-of-two buckets with zero-weight
  masks, so each (job config, bucket, eval?) triple compiles exactly once
  and 20 rounds of jittery cohort sizes cost at most ``len(buckets)``
  compiles (``2 * len(buckets)`` when ``eval_every > 1`` puts both the
  eval and no-eval step variants in play).
- **One fused jitted step per round.** Device shards are gathered from
  device-resident ``(x, y, partition)`` arrays INSIDE jit, local SGD runs
  vmapped over the cohort lane, FedAvg uses mask-weighted REAL per-device
  partition sizes, and held-out eval happens in the same donated-params
  compiled call. ``eval_every`` skips the eval branch entirely on non-eval
  rounds (the engine then sees the last evaluated metrics).
- **Cross-job batched execution.** Jobs sharing a model config stack onto
  one extra vmap lane; the engine announces realized cohorts at launch time
  (``begin_round``) and the first result demand flushes every pending round
  of the group in ONE dispatch — with M jobs in flight, steady state batches
  up to M rounds per compiled call.

``FLJobRuntime`` — the historical one-job path (kept as the unfused
baseline benchmarks compare against): same math, but a fresh compile per
cohort size, host round-trips for the partition gather, and eager per-leaf
FedAvg dispatches.

``SyntheticRuntime`` — closed-form convergence model for scheduler-only
studies and fast tests: accuracy follows a saturating curve whose CEILING is
set by label coverage of the devices scheduled so far (non-IID: each device
holds 2 of C classes, so starving devices starves classes — the mechanism the
paper's fairness term addresses) and whose RATE follows Formula 13.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import JobConfig, ModelConfig
from repro.fl.aggregation import fedavg, robust_fedavg
from repro.models.cnn_zoo import cnn_apply, cnn_init, cnn_loss_and_accuracy
from repro.monitoring.trace import counter, span


@functools.partial(jax.jit, static_argnames=("cfg", "epochs", "batch_size", "lr"))
def _local_train_one(params, cfg: ModelConfig, x, y, epochs: int,
                     batch_size: int, lr: float):
    """SGD local update of one device. x: (W, ...), y: (W,). Devices holding
    fewer than ``batch_size`` samples train on one full-shard batch."""
    W = x.shape[0]
    if W == 0:
        # Width-0 shard (an empty device): nothing to train on — the local
        # update is the identity. Static-shape branch, so jit-safe.
        return params
    batch_size = min(batch_size, W)
    steps = max(W // batch_size, 1)
    xb = x[: steps * batch_size].reshape(steps, batch_size, *x.shape[1:])
    yb = y[: steps * batch_size].reshape(steps, batch_size)

    def loss_fn(p, bx, by):
        logits = cnn_apply(p, cfg, bx)
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, by[:, None], axis=1).mean()

    def step(p, batch):
        bx, by = batch
        g = jax.grad(loss_fn)(p, bx, by)
        return jax.tree_util.tree_map(lambda pp, gg: pp - lr * gg, p, g), ()

    def epoch(p, _):
        p, _ = jax.lax.scan(step, p, (xb, yb))
        return p, ()

    params, _ = jax.lax.scan(epoch, params, None, length=epochs)
    return params


_local_train_batch = jax.jit(
    jax.vmap(_local_train_one, in_axes=(None, None, 0, 0, None, None, None)),
    static_argnames=("cfg", "epochs", "batch_size", "lr"))


# ---- cohort-size buckets ----

def default_buckets(num_devices: int, lo: int = 4) -> Tuple[int, ...]:
    """Powers of two from ``lo`` up, capped by (and always including) the
    pool size, so any cohort 1..num_devices maps to a bucket."""
    out, b = [], lo
    while b < num_devices:
        out.append(b)
        b *= 2
    out.append(num_devices)
    return tuple(sorted(set(out)))


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n (buckets must be sorted and cover n)."""
    for b in buckets:
        if b >= n:
            return b
    raise ValueError(f"cohort of {n} exceeds the largest bucket {buckets[-1]}")


# ---- the fused per-round step (one compiled call per (config, bucket)) ----

def _inject_corruption(p, locals_, corrupt, corrupt_mode: str, corrupt_scale):
    """Overwrite corrupted lanes' uploads: all-NaN params (``"nan"``) or a
    delta blown up by ``corrupt_scale`` (``"scale"``). ``corrupt``: (B,)."""

    def leaf(g, l):
        c = jnp.broadcast_to(
            corrupt.reshape((-1,) + (1,) * (l.ndim - 1)), l.shape)
        if corrupt_mode == "nan":
            bad = jnp.full_like(l, jnp.nan)
        else:
            bad = g[None] + corrupt_scale.astype(l.dtype) * (l - g[None])
        return jnp.where(c, bad, l)

    return jax.tree_util.tree_map(leaf, p, locals_)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "epochs", "batch_size", "lr", "do_eval",
                     "robust", "corrupt_mode"),
    donate_argnums=(0,))
def _fused_group_round(params, dev_ids, mask, active, x, y, partition, sizes,
                       eval_x, eval_y, corrupt, reject_mult, corrupt_scale,
                       cfg: ModelConfig, epochs: int,
                       batch_size: int, lr: float, do_eval: bool,
                       robust: bool, corrupt_mode: str):
    """Gather + local SGD + masked FedAvg + (optional) eval, fused.

    ``params``: (J, ...) stacked pytree (donated); ``dev_ids``: (J, B) padded
    cohorts; ``mask``: (J, B) 1/0 participation; ``active``: (J,) lanes with a
    pending round (inactive lanes keep their params bit-for-bit);
    ``x``/``y``: (J, N, ...) device-resident datasets; ``partition``:
    (J, K, W) index matrices; ``sizes``: (J, K) real per-device partition
    sizes (the FedAvg weights); ``eval_x``/``eval_y``: (J, E, ...) held-out
    sets. Returns (new_params, loss (J,), acc (J,), rejected (J,)) —
    loss/acc are NaN when ``do_eval`` is False (the branch is skipped
    entirely, not masked).

    ``robust`` (static) turns on in-jit fault screening: ``corrupt`` (J, B)
    lanes upload injected garbage (``corrupt_mode``/``corrupt_scale`` — the
    faults axis's corrupted-update model), then aggregation rejects
    non-finite and norm-outlier updates against a ``reject_mult`` x
    masked-median threshold (``repro.fl.aggregation.robust_fedavg``).
    ``rejected`` counts screened-out participating lanes per job (0 when
    ``robust`` is False — the plain path is compiled unchanged).
    """

    def one(p, ids, m, cj, xj, yj, pj, sj):
        idx = pj[ids]                                    # (B, W) in-jit gather
        dev_x, dev_y = xj[idx], yj[idx]                  # (B, W, ...)
        locals_ = jax.vmap(
            _local_train_one,
            in_axes=(None, None, 0, 0, None, None, None))(
                p, cfg, dev_x, dev_y, epochs, batch_size, lr)
        w = m * sj[ids]                                  # masked real sizes
        if not robust:
            return fedavg(locals_, w), jnp.zeros((), jnp.float32)
        locals_ = _inject_corruption(p, locals_, cj > 0, corrupt_mode,
                                     corrupt_scale)
        agg, ok = robust_fedavg(p, locals_, w, reject_mult)
        rej = jnp.sum((m > 0) & ~ok).astype(jnp.float32)
        return agg, rej

    J = active.shape[0]
    if J == 1:
        # Single-job group: drop the job lane entirely. The batched-matmul
        # forms the lane induces reduce in a different tiling order than the
        # plain matmuls (1-ULP drift that SGD amplifies); lane-free dispatch
        # keeps single-job groups BITWISE equal to the unfused baseline.
        lane0 = lambda tree: jax.tree_util.tree_map(lambda l: l[0], tree)
        relane = lambda tree: jax.tree_util.tree_map(lambda l: l[None], tree)
        new, rej = one(lane0(params), dev_ids[0], mask[0], corrupt[0], x[0],
                       y[0], partition[0], sizes[0])
        new, rejected = relane(new), rej[None]
    else:
        new, rejected = jax.vmap(one)(params, dev_ids, mask, corrupt, x, y,
                                      partition, sizes)
    keep = lambda nl, ol: jnp.where(
        active.reshape((-1,) + (1,) * (nl.ndim - 1)), nl, ol)
    new = jax.tree_util.tree_map(keep, new, params)
    if do_eval:
        if J == 1:
            l0, a0 = cnn_loss_and_accuracy(
                jax.tree_util.tree_map(lambda l: l[0], new), cfg,
                eval_x[0], eval_y[0])
            loss, acc = l0[None], a0[None]
        else:
            loss, acc = jax.vmap(
                lambda p, ex, ey: cnn_loss_and_accuracy(p, cfg, ex, ey))(
                    new, eval_x, eval_y)
    else:
        loss = jnp.full(active.shape, jnp.nan, jnp.float32)
        acc = jnp.full(active.shape, jnp.nan, jnp.float32)
    return new, loss, acc, rejected


@dataclasses.dataclass
class _FusedGroup:
    """Jobs sharing (model arch, local hyperparams, data shapes): one stacked
    param lane, one compiled step."""

    cfg: ModelConfig                 # canonical (name-stripped) config
    epochs: int
    batch_size: int
    lr: float
    job_ids: List[int]
    lane: Dict[int, int]             # job_id -> lane index
    params: object                   # (J, ...) stacked pytree
    x: jnp.ndarray                   # (J, N, ...)
    y: jnp.ndarray                   # (J, N)
    partition: jnp.ndarray           # (J, K, W) int32
    sizes: jnp.ndarray               # (J, K) f32
    eval_x: jnp.ndarray              # (J, E, ...)
    eval_y: jnp.ndarray              # (J, E)


class FusedMultiRuntime:
    """Fused, recompile-free multi-job runtime behind the engine protocol.

    ``begin_round`` (called by the engine at LAUNCH time with the realized
    survivor cohort) queues work; ``run_round`` (called at FINISH time)
    flushes every queued round — grouped by model config, padded to one
    shared cohort bucket, executed in one compiled dispatch per group — and
    returns that job's metrics. Works standalone too: ``run_round`` without a
    prior ``begin_round`` queues-and-flushes synchronously.

    ``datasets``: per-job ``(x, y, partition, eval_x, eval_y)`` tuples (or
    6-tuples with trailing per-device ``partition_sizes``). ``eval_every``:
    evaluate every k-th round of a job; skipped rounds report the last
    evaluated metrics (stale by < k rounds — target detection lags
    accordingly). A flush evaluates the whole group if ANY flushed lane is
    due (fresh metrics are used for every lane in that case).

    ``robust`` turns on in-jit fault screening (``TrainSpec.robust``):
    the runtime takes over corrupted-upload handling from the engine
    (``handles_corruption``) — it re-draws each round's corrupt mask from
    ``fault_engine`` (the replayable keyed schedule, so engine and runtime
    agree with zero plumbing), injects the garbage uploads itself, and
    rejects non-finite/outlier updates inside the fused round at a
    ``reject_mult`` x masked-median norm threshold. Per-round rejection
    counts ride on the metrics dict (``"rejected"``) and accumulate in
    ``rejected_total``.
    """

    def __init__(self, jobs: Sequence[JobConfig], datasets: Sequence[tuple],
                 seed: int = 0, buckets: Optional[Sequence[int]] = None,
                 eval_every: int = 1, robust: bool = False,
                 reject_mult: float = 4.0, fault_engine=None):
        if len(jobs) != len(datasets):
            raise ValueError("one dataset tuple per job required")
        if eval_every < 1:
            raise ValueError(f"eval_every must be >= 1, got {eval_every}")
        self.eval_every = int(eval_every)
        self.robust = bool(robust)
        self.reject_mult = float(reject_mult)
        self.fault_engine = fault_engine
        self.rejected_total = 0.0
        # Cumulative jit recompiles of the fused step (tracked per flush
        # from the jit cache size; bucketing should keep this O(#buckets)).
        self.recompiles = 0
        self._queued: Dict[int, tuple] = {}      # job -> (ids, round_idx)
        self._results: Dict[tuple, dict] = {}    # (job, round) -> metrics
        self._last: Dict[int, dict] = {}         # job -> last evaluated
        self.groups: List[_FusedGroup] = []
        self._group_of: Dict[int, _FusedGroup] = {}

        by_key: Dict[tuple, list] = {}
        for jid, (job, ds) in enumerate(zip(jobs, datasets)):
            x, y, part, ex, ey = ds[:5]
            psz = ds[5] if len(ds) > 5 else None
            canon = dataclasses.replace(job.model, name="")
            key = (canon, job.local_epochs, job.batch_size, job.lr,
                   np.shape(x), np.shape(part), np.shape(ex))
            by_key.setdefault(key, []).append((jid, job, x, y, part, ex, ey,
                                               psz))

        num_devices = None
        for key, members in by_key.items():
            canon, epochs, bs, lr = key[0], key[1], key[2], key[3]
            job_ids = [m[0] for m in members]
            lane = {jid: i for i, jid in enumerate(job_ids)}
            params = jax.tree_util.tree_map(
                lambda *leaves: jnp.stack(leaves),
                *[cnn_init(canon, seed=seed + m[0]) for m in members])
            K, W = np.shape(members[0][4])
            num_devices = K if num_devices is None else max(num_devices, K)
            sizes = np.stack([
                np.full(K, W, np.float32) if m[7] is None
                else np.asarray(m[7], np.float32) for m in members])
            grp = _FusedGroup(
                cfg=canon, epochs=epochs, batch_size=bs, lr=lr,
                job_ids=job_ids, lane=lane, params=params,
                x=jnp.stack([jnp.asarray(m[2]) for m in members]),
                y=jnp.stack([jnp.asarray(m[3].astype(np.int32))
                             for m in members]),
                partition=jnp.stack([jnp.asarray(m[4].astype(np.int32))
                                     for m in members]),
                sizes=jnp.asarray(sizes),
                eval_x=jnp.stack([jnp.asarray(m[5]) for m in members]),
                eval_y=jnp.stack([jnp.asarray(m[6].astype(np.int32))
                                  for m in members]))
            self.groups.append(grp)
            for jid in job_ids:
                self._group_of[jid] = grp
        self.buckets = (tuple(sorted(set(buckets))) if buckets is not None
                        else default_buckets(num_devices))
        if self.buckets[-1] < num_devices:
            self.buckets = self.buckets + (num_devices,)

    # ---- engine protocol ----

    @property
    def handles_corruption(self) -> bool:
        """Robust mode screens corrupted uploads inside aggregation, so the
        engine must NOT oracle-discard them from the cohort."""
        return self.robust

    def begin_round(self, job_id: int, device_ids: np.ndarray,
                    round_idx: int) -> None:
        """Announce a launched round's REALIZED cohort (post drop/failure).
        Pure bookkeeping — training runs at the next flush."""
        self._queued[job_id] = (np.asarray(device_ids, np.int64), round_idx)

    def run_round(self, job_id: int, device_ids: np.ndarray, round_idx: int
                  ) -> Dict[str, float]:
        key = (job_id, round_idx)
        ids = np.asarray(device_ids, np.int64)
        if key not in self._results:
            queued = self._queued.get(job_id)
            if (queued is None or queued[1] != round_idx
                    or not np.array_equal(queued[0], ids)):
                # No announcement, or the announced cohort drifted: the
                # demanded cohort wins (nothing has been computed yet).
                self.begin_round(job_id, ids, round_idx)
            self._flush()
        rec, trained_ids, rej = self._results.pop(key)
        if not np.array_equal(trained_ids, ids):
            raise ValueError(
                f"job {job_id} round {round_idx} was trained on the cohort "
                f"announced via begin_round, which differs from the one "
                f"passed to run_round: {trained_ids} vs {ids}")
        # Sync happens HERE, per demand — a flush dispatches every pending
        # group asynchronously, so other jobs' rounds keep computing while
        # this one's metrics transfer and the engine does its bookkeeping.
        with span("metrics_sync", job=job_id, round=round_idx):
            _, loss, acc, ln = rec
            out = {"loss": float(loss[ln]), "accuracy": float(acc[ln])}
            if self.robust:
                out["rejected"] = float(rej[ln])
                self.rejected_total += out["rejected"]
        return out

    # ---- execution ----

    def _flush(self) -> None:
        queued, self._queued = self._queued, {}
        for grp in self.groups:
            pend = [(jid,) + queued[jid] for jid in grp.job_ids
                    if jid in queued]
            if not pend:
                continue
            J = len(grp.job_ids)
            B = bucket_for(max(len(ids) for _, ids, _ in pend), self.buckets)
            dev_ids = np.zeros((J, B), np.int32)
            mask = np.zeros((J, B), np.float32)
            corrupt = np.zeros((J, B), np.float32)
            active = np.zeros((J,), bool)
            do_eval = any(r % self.eval_every == 0 or jid not in self._last
                          for jid, _, r in pend)
            for jid, ids, r in pend:
                ln = grp.lane[jid]
                dev_ids[ln, : len(ids)] = ids
                mask[ln, : len(ids)] = 1.0
                active[ln] = True
                if self.robust and self.fault_engine is not None:
                    # The SAME keyed draw the engine made for this round.
                    corrupt[ln, : len(ids)] = self.fault_engine.corrupt_mask(
                        jid, r, ids)
            fspec = getattr(self.fault_engine, "spec", None)
            cache_size = getattr(_fused_group_round, "_cache_size", None)
            before = cache_size() if cache_size is not None else 0
            with span("fused_round", jobs=len(pend), bucket=B,
                      eval=bool(do_eval)):
                grp.params, loss, acc, rej = _fused_group_round(
                    grp.params, jnp.asarray(dev_ids), jnp.asarray(mask),
                    jnp.asarray(active), grp.x, grp.y, grp.partition,
                    grp.sizes, grp.eval_x, grp.eval_y, jnp.asarray(corrupt),
                    jnp.float32(self.reject_mult),
                    jnp.float32(fspec.corrupt_scale if fspec is not None
                                else 1.0),
                    cfg=grp.cfg, epochs=grp.epochs,
                    batch_size=grp.batch_size, lr=grp.lr, do_eval=do_eval,
                    robust=self.robust,
                    corrupt_mode=(fspec.corrupt_mode if fspec is not None
                                  else "nan"))
            if cache_size is not None:
                grew = cache_size() - before
                if grew > 0:
                    self.recompiles += grew
                    counter("jit_recompiles", self.recompiles)
            for jid, ids, r in pend:
                ln = grp.lane[jid]
                if do_eval:
                    # Unsynced device arrays: materialized at demand time.
                    rec = ("eval", loss, acc, ln)
                    self._last[jid] = rec
                else:
                    rec = self._last[jid]  # immutable snapshot (stale by < k)
                # The trained cohort rides along so a demand with a DIFFERENT
                # cohort fails loudly instead of mis-attributing metrics.
                self._results[(jid, r)] = (rec, ids, rej)

    # ---- introspection (tests / checkpointing) ----

    def params_of(self, job_id: int):
        """Unstacked param pytree of one job's lane."""
        grp = self._group_of[job_id]
        ln = grp.lane[job_id]
        return jax.tree_util.tree_map(lambda leaf: leaf[ln], grp.params)


class FLJobRuntime:
    """Unfused runtime for ONE job — the historical baseline path.

    Recompiles ``_local_train_batch`` for every distinct cohort size, gathers
    partitions through the host, and runs FedAvg eagerly; kept as the
    reference ``benchmarks/bench_train.py`` measures the fused engine
    against. FedAvg weights are the REAL per-device partition sizes
    (``partition_sizes``; defaults to the fixed partition width, under which
    all weights are equal).
    """

    def __init__(self, job: JobConfig, x: np.ndarray, y: np.ndarray,
                 partition: np.ndarray, eval_x: np.ndarray, eval_y: np.ndarray,
                 seed: int = 0, partition_sizes: Optional[np.ndarray] = None):
        self.job = job
        self.cfg = job.model
        self.x, self.y = jnp.asarray(x), jnp.asarray(y.astype(np.int32))
        self.partition = partition
        if partition_sizes is None:
            partition_sizes = np.full(partition.shape[0], partition.shape[1])
        self.partition_sizes = np.asarray(partition_sizes, np.float64)
        if self.partition_sizes.shape != (partition.shape[0],):
            raise ValueError(
                f"partition_sizes has shape {self.partition_sizes.shape}, "
                f"expected ({partition.shape[0]},)")
        self.eval_x, self.eval_y = jnp.asarray(eval_x), jnp.asarray(eval_y.astype(np.int32))
        self.params = cnn_init(self.cfg, seed=seed)
        self._eval = jax.jit(functools.partial(cnn_loss_and_accuracy, cfg=self.cfg))

    def run_round(self, job_id: int, device_ids: np.ndarray, round_idx: int
                  ) -> Dict[str, float]:
        idx = self.partition[np.asarray(device_ids)]          # (n, W)
        dev_x = self.x[jnp.asarray(idx)]                      # (n, W, ...)
        dev_y = self.y[jnp.asarray(idx)]
        locals_ = _local_train_batch(
            self.params, self.cfg, dev_x, dev_y,
            self.job.local_epochs, self.job.batch_size, self.job.lr)
        weights = jnp.asarray(self.partition_sizes[np.asarray(device_ids)],
                              jnp.float32)
        self.params = fedavg(locals_, weights)
        loss, acc = self._eval(self.params, x=self.eval_x, y=self.eval_y)
        return {"loss": float(loss), "accuracy": float(acc)}


class MultiRuntime:
    """Adapter: one FLJobRuntime per job behind the engine's JobRuntime protocol."""

    def __init__(self, runtimes):
        self.runtimes = list(runtimes)

    def run_round(self, job_id: int, device_ids: np.ndarray, round_idx: int):
        return self.runtimes[job_id].run_round(job_id, device_ids, round_idx)


DEFAULT_B0 = 0.15  # Formula 13 convergence rate when a job doesn't set one


class SyntheticRuntime:
    """Closed-form convergence: ceiling from class coverage, rate from Formula 13.

    acc_m(r) = ceiling_m * (1 - 1/(b0_m * r_eff + 1))  with r_eff the round
    count and ceiling_m = base + (1 - base) * coverage^p. coverage = fraction
    of the job's label classes seen in scheduled devices so far. Under IID
    (classes_per_device == num_classes) the ceiling is ~1 regardless, matching
    the paper's observation that fairness matters most under non-IID.

    ``b0`` is a scalar shared by all jobs or a (num_jobs,) array of per-job
    rates, so job complexity ordering (LeNet > CNN > VGG) converges at
    genuinely different speeds; ``None`` entries fall back to ``DEFAULT_B0``.
    """

    def __init__(self, num_jobs: int, num_devices: int, num_classes: int = 10,
                 classes_per_device: int = 2, b0=DEFAULT_B0,
                 base: float = 0.35, power: float = 1.5, seed: int = 0,
                 noise: float = 0.004):
        rng = np.random.default_rng(seed)
        self.num_classes = num_classes
        if num_devices > 4096:
            # Fleet pools: batched sampling-without-replacement (random keys
            # + argpartition) — one vectorized draw instead of num_devices
            # sequential rng.choice calls (milliseconds at K=100k). Same
            # distribution as the sequential draw; realizations differ, so
            # paper-scale pools keep the historical per-device stream below.
            keys = rng.random((num_devices, num_classes))
            self.device_classes = np.argpartition(
                keys, classes_per_device - 1, axis=1)[:, :classes_per_device]
        else:
            self.device_classes = np.stack([
                rng.choice(num_classes, size=classes_per_device, replace=False)
                for _ in range(num_devices)])
        self.seen = [np.zeros(num_classes, dtype=np.float64) for _ in range(num_jobs)]
        self.rounds = np.zeros(num_jobs, dtype=np.int64)
        if np.ndim(b0) > 0:
            b0 = np.array([DEFAULT_B0 if v is None else float(v) for v in b0])
            if b0.shape != (num_jobs,):
                raise ValueError(f"b0 has shape {b0.shape}, expected ({num_jobs},)")
        self.b0, self.base, self.power = b0, base, power
        self.noise = noise
        self.rng = rng

    def add_job(self, job_id: int, config=None, b0: Optional[float] = None
                ) -> None:
        """Dynamic job admission (scheduler-service hook): grow the per-job
        coverage/round state by one row. ``job_id`` must be the next index
        (or an existing row, which is RESET — a readmitted tenant starts a
        fresh model; its scheduler history transfers separately). A per-job
        ``b0`` promotes a scalar rate to a per-job array on first use."""
        if job_id > len(self.seen):
            raise ValueError(f"add_job out of order: job_id {job_id} with "
                             f"{len(self.seen)} existing jobs")
        if b0 is None and config is not None:
            b0 = getattr(config, "b0", None)
        if job_id == len(self.seen):
            self.seen.append(np.zeros(self.num_classes, dtype=np.float64))
            self.rounds = np.concatenate([self.rounds, np.zeros(1, np.int64)])
            if b0 is not None:
                b = np.asarray(self.b0, dtype=np.float64)
                if b.ndim == 0:
                    b = np.full(len(self.seen) - 1, float(b))
                self.b0 = np.concatenate([b, [float(b0)]])
            elif np.ndim(self.b0) > 0:
                self.b0 = np.concatenate([self.b0, [DEFAULT_B0]])
        else:
            self.seen[job_id][:] = 0.0
            self.rounds[job_id] = 0
            if b0 is not None:
                b = np.asarray(self.b0, dtype=np.float64)
                if b.ndim == 0:
                    b = np.full(len(self.seen), float(b))
                b[job_id] = float(b0)
                self.b0 = b

    def state_dict(self) -> Dict[str, np.ndarray]:
        """Array state for crash-consistent checkpointing (rng state rides
        separately in the manifest's JSON half)."""
        return {
            "seen": np.stack(self.seen) if self.seen
            else np.zeros((0, self.num_classes)),
            "rounds": self.rounds.copy(),
            "b0": np.asarray(self.b0, dtype=np.float64),
        }

    def load_state_dict(self, state: Dict[str, np.ndarray]) -> None:
        seen = np.asarray(state["seen"], dtype=np.float64)
        if seen.shape[0] != len(self.seen):
            raise ValueError(
                f"checkpoint has {seen.shape[0]} jobs, runtime has "
                f"{len(self.seen)} — re-add jobs before loading")
        self.seen = [seen[i].copy() for i in range(seen.shape[0])]
        self.rounds = np.asarray(state["rounds"], dtype=np.int64).copy()
        b0 = np.asarray(state["b0"], dtype=np.float64)
        self.b0 = float(b0) if b0.ndim == 0 else b0.copy()

    def run_round(self, job_id: int, device_ids: np.ndarray, round_idx: int):
        hit = self.device_classes[np.asarray(device_ids)].ravel()
        np.add.at(self.seen[job_id], hit, 1.0)
        self.rounds[job_id] += 1
        # Coverage = 1 - TV(seen-class distribution, uniform): schedulers that
        # starve devices starve their classes and cap below the uniform optimum.
        s = self.seen[job_id]
        p = s / max(s.sum(), 1e-9)
        tv = 0.5 * float(np.abs(p - 1.0 / self.num_classes).sum())
        cov = 1.0 - tv
        ceiling = self.base + (1 - self.base) * cov ** self.power
        r = float(self.rounds[job_id])
        b = np.asarray(self.b0, dtype=np.float64)
        b0 = float(b[job_id] if b.ndim else b)
        acc = ceiling * (1 - 1 / (b0 * r + 1.0))
        acc = float(np.clip(acc + self.rng.normal(0, self.noise), 0, 1))
        loss = float(-np.log(max(acc, 1e-3)))
        return {"loss": loss, "accuracy": acc}
