"""repro — MJ-FL: Efficient Device Scheduling with Multi-Job Federated Learning.

A production-grade multi-pod JAX framework reproducing and extending
Zhou, Liu et al., "Efficient Device Scheduling with Multi-Job Federated
Learning" (AAAI'22). The paper's contribution (multi-job device scheduling
with a time+fairness cost model, BODS and RLDS schedulers) lives in
``repro.core``; the surrounding substrate (models, optimizers, data,
checkpointing, sharded launch) makes it deployable.
"""

__version__ = "0.1.0"
