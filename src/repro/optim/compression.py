"""Gradient compression: top-k sparsification with error feedback.

Used on the FL plane (device->server uploads) and available cross-pod as a
distributed-optimization trick. ``topk_compress`` returns (values, indices)
of the k largest-magnitude entries per leaf; the residual is carried in an
error-feedback accumulator so compression bias vanishes over steps
(Karimireddy et al. 2019).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class ErrorFeedbackState(NamedTuple):
    residual: PyTree


def _leaf_topk(x: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.reshape(-1)
    k = max(1, min(k, flat.shape[0]))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_compress(grads: PyTree, ratio: float,
                  ef: Optional[ErrorFeedbackState] = None):
    """Compress each leaf to ceil(ratio * size) entries. Returns
    ((values, indices, shapes) pytrees, new_ef)."""
    if ef is not None:
        grads = jax.tree_util.tree_map(lambda g, r: g + r, grads, ef.residual)

    def per_leaf(g):
        k = int(max(1, round(ratio * g.size)))
        v, i = _leaf_topk(g, k)
        return (v, i)

    comp = jax.tree_util.tree_map(per_leaf, grads)
    values = jax.tree_util.tree_map(lambda c: c[0], comp, is_leaf=lambda x: isinstance(x, tuple))
    indices = jax.tree_util.tree_map(lambda c: c[1], comp, is_leaf=lambda x: isinstance(x, tuple))

    def residual(g, v, i):
        flat = g.reshape(-1)
        flat = flat.at[i].set(0.0)
        return flat.reshape(g.shape)

    new_ef = ErrorFeedbackState(
        jax.tree_util.tree_map(residual, grads, values, indices))
    return (values, indices), new_ef


def topk_decompress(values: PyTree, indices: PyTree, like: PyTree) -> PyTree:
    def per_leaf(v, i, g):
        flat = jnp.zeros(g.size, g.dtype)
        flat = flat.at[i].set(v.astype(g.dtype))
        return flat.reshape(g.shape)

    return jax.tree_util.tree_map(per_leaf, values, indices, like)
