"""Optimizers as (init_fn, update_fn) pairs over arbitrary pytrees.

update_fn(grads, state, params) -> (updates, new_state); caller applies
``params + updates``. All state lives in pytrees so it shards/checkpoints
like params. Adafactor implements factored second moments (row/col RMS) so
trillion-parameter jobs (kimi-k2) keep optimizer state sublinear in the
largest matrix dimension product.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import OptimizerConfig

PyTree = Any


class OptState(NamedTuple):
    step: jnp.ndarray
    inner: PyTree


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jnp.ndarray]:
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


def sgd(lr: float):
    def init(params):
        return OptState(jnp.zeros((), jnp.int32), ())

    def update(grads, state, params=None):
        updates = jax.tree_util.tree_map(lambda g: -lr * g, grads)
        return updates, OptState(state.step + 1, ())

    return init, update


def momentum(lr: float, beta: float = 0.9):
    def init(params):
        m = jax.tree_util.tree_map(jnp.zeros_like, params)
        return OptState(jnp.zeros((), jnp.int32), m)

    def update(grads, state, params=None):
        m = jax.tree_util.tree_map(lambda mm, g: beta * mm + g, state.inner, grads)
        updates = jax.tree_util.tree_map(lambda mm: -lr * mm, m)
        return updates, OptState(state.step + 1, m)

    return init, update


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0):
    return adamw(lr, b1, b2, eps, weight_decay)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0,
          lr_schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None):
    def init(params):
        m = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        v = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), (m, v))

    def update(grads, state, params):
        step = state.step + 1
        cur_lr = lr if lr_schedule is None else lr * lr_schedule(step)
        m, v = state.inner
        m = jax.tree_util.tree_map(
            lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32), m, grads)
        v = jax.tree_util.tree_map(
            lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32)), v, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(mm, vv, p):
            u = -cur_lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
            if weight_decay:
                u = u - cur_lr * weight_decay * p.astype(jnp.float32)
            return u.astype(p.dtype)

        updates = jax.tree_util.tree_map(upd, m, v, params)
        return updates, OptState(step, (m, v))

    return init, update


def adafactor(lr: float = 1e-3, eps: float = 1e-30, decay: float = 0.8,
              clip_threshold: float = 1.0, weight_decay: float = 0.0):
    """Factored second-moment optimizer (Shazeer & Stern 2018), momentum-free.

    For an (..., r, c) matrix keeps row/col RMS accumulators of shapes
    (..., r) and (..., c): O(r+c) state instead of O(r*c). Vectors keep a
    full accumulator (cheap).
    """

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def per_leaf(p):
            if _factored(p):
                return (jnp.zeros(p.shape[:-1], jnp.float32),      # row: reduce last dim
                        jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))  # col
            return (jnp.zeros_like(p, jnp.float32), None)

        acc = jax.tree_util.tree_map(per_leaf, params, is_leaf=lambda x: isinstance(x, jnp.ndarray))
        return OptState(jnp.zeros((), jnp.int32), acc)

    def update(grads, state, params):
        step = state.step + 1
        beta = 1.0 - step.astype(jnp.float32) ** (-decay)

        def per_leaf(acc, g, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if _factored(p):
                row, col = acc
                row = beta * row + (1 - beta) * g2.mean(axis=-1)
                col = beta * col + (1 - beta) * g2.mean(axis=-2)
                # rank-1 reconstruction of the second moment
                rfac = row / jnp.maximum(row.mean(axis=-1, keepdims=True), eps)
                u = g32 / (jnp.sqrt(rfac)[..., None] * jnp.sqrt(col)[..., None, :] + 1e-12)
                new_acc = (row, col)
            else:
                full, _ = acc
                full = beta * full + (1 - beta) * g2
                u = g32 / (jnp.sqrt(full) + 1e-12)
                new_acc = (full, None)
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            u = -lr * u
            if weight_decay:
                u = u - lr * weight_decay * p.astype(jnp.float32)
            return u.astype(p.dtype), new_acc

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_a = treedef.flatten_up_to(state.inner)
        out = [per_leaf(a, g, p) for a, g, p in zip(flat_a, flat_g, flat_p)]
        updates = treedef.unflatten([o[0] for o in out])
        acc = treedef.unflatten([o[1] for o in out])
        return updates, OptState(step, acc)

    return init, update


def make_optimizer(cfg: OptimizerConfig):
    """Resolve an OptimizerConfig into (init, update)."""
    if cfg.name == "sgd":
        return sgd(cfg.lr)
    if cfg.name == "momentum":
        return momentum(cfg.lr, cfg.momentum)
    if cfg.name in ("adam", "adamw"):
        return adamw(cfg.lr, cfg.b1, cfg.b2, cfg.eps, cfg.weight_decay)
    if cfg.name == "adafactor":
        return adafactor(cfg.lr, weight_decay=cfg.weight_decay)
    raise KeyError(f"unknown optimizer {cfg.name!r}")
