"""Learning-rate schedules (multiplicative factors over step)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        return final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))

    return fn


def warmup_cosine(warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    cos = cosine_schedule(max(total_steps - warmup_steps, 1), final_frac)

    def fn(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup_steps, 1)
        return jnp.where(s < warmup_steps, warm, cos(step - warmup_steps))

    return fn
