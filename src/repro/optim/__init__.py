"""Optimizers (pure JAX, optax-style (init, update) pairs) + compression."""

from repro.optim.optimizers import (
    OptState,
    adafactor,
    adam,
    adamw,
    clip_by_global_norm,
    make_optimizer,
    momentum,
    sgd,
)
from repro.optim.compression import topk_compress, topk_decompress, ErrorFeedbackState
from repro.optim.schedule import cosine_schedule, warmup_cosine

__all__ = [
    "OptState",
    "adafactor",
    "adam",
    "adamw",
    "clip_by_global_norm",
    "make_optimizer",
    "momentum",
    "sgd",
    "topk_compress",
    "topk_decompress",
    "ErrorFeedbackState",
    "cosine_schedule",
    "warmup_cosine",
]
