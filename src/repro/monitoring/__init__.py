"""Monitoring: the repo-wide observability layer.

- ``trace``   — zero-cost-when-disabled span tracer with Chrome/Perfetto
  trace-event JSON export (``span("schedule")``, counters, instants);
  instruments the engine, the fused FL runtime, the fused searchers, and
  the scheduler service.
- ``bus``     — synchronous pub/sub ``EventBus`` carrying engine
  ``round``/``round_begin``/``job_done`` and serve lifecycle events to
  sinks.
- ``metrics`` — ``MetricsLogger`` JSONL sink (batched flushing) +
  ``StepTimer``.
- ``audit``   — ``SchedulerAudit`` per-decision log (estimated vs realized
  cost, degraded rounds, scheduler name).
- ``session`` — ``ObsSpec`` (the spec's ``obs`` axis) + ``ObsSession``
  (declarative wiring: ``--set obs.trace_path=trace.json`` on any run).
- ``report``  — per-phase wall-clock breakdowns, run diffs, and BENCH_*.json
  regression checks (``python -m repro.monitoring report``).
"""

from repro.monitoring.audit import SchedulerAudit
from repro.monitoring.bus import EventBus
from repro.monitoring.metrics import MetricsLogger, StepTimer
from repro.monitoring.session import ObsSession, ObsSpec
from repro.monitoring.trace import Tracer, span

__all__ = ["MetricsLogger", "StepTimer", "SchedulerAudit", "EventBus",
           "ObsSession", "ObsSpec", "Tracer", "span"]
