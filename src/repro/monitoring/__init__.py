"""Monitoring: structured metrics + scheduler decision audit logs."""

from repro.monitoring.metrics import MetricsLogger, StepTimer
from repro.monitoring.audit import SchedulerAudit

__all__ = ["MetricsLogger", "StepTimer", "SchedulerAudit"]
