"""Tiny synchronous pub/sub event bus wiring engine/serve lifecycle events
to monitoring sinks.

The engine publishes ``round`` (the finished ``RoundRecord``),
``round_begin`` (launch-time dict: job, round index, realized cohort size,
estimated cost) and ``job_done``; the scheduler service adds
``serve.admit`` / ``serve.depart`` / ``serve.queue_wait`` /
``serve.churn`` / ``serve.checkpoint``. ``MetricsLogger.on_round`` and
``SchedulerAudit.on_round`` are the shipped sinks
(``repro.monitoring.session.ObsSession`` subscribes them declaratively
from the spec's ``obs`` axis); anything callable can subscribe.

Sinks are isolated: a raising sink is counted (``bus.errors``) and warned
about once per (topic, sink), never allowed to break the publishing hot
path — monitoring must not take down the run it observes.
"""

from __future__ import annotations

import warnings
from typing import Any, Callable, Dict, List, Tuple

Sink = Callable[[Any], None]


class EventBus:
    def __init__(self):
        self._subs: Dict[str, List[Sink]] = {}
        self.errors = 0
        self._warned: set = set()

    def subscribe(self, topic: str, sink: Sink) -> Sink:
        """Register ``sink`` for ``topic``; returns the sink (decorator
        friendly). Sinks fire synchronously in subscription order."""
        self._subs.setdefault(topic, []).append(sink)
        return sink

    def unsubscribe(self, topic: str, sink: Sink) -> bool:
        """Remove ``sink`` from ``topic``; True if it was subscribed."""
        subs = self._subs.get(topic, [])
        if sink in subs:
            subs.remove(sink)
            return True
        return False

    def topics(self) -> Tuple[str, ...]:
        return tuple(sorted(t for t, subs in self._subs.items() if subs))

    def publish(self, topic: str, payload: Any = None) -> int:
        """Deliver ``payload`` to every sink of ``topic``; returns the number
        of successful deliveries. Sink exceptions are swallowed (warned once,
        counted) so monitoring can never crash the engine."""
        delivered = 0
        for sink in self._subs.get(topic, ()):
            try:
                sink(payload)
                delivered += 1
            except Exception as e:  # noqa: BLE001 - sink isolation by design
                self.errors += 1
                key = (topic, id(sink))
                if key not in self._warned:
                    self._warned.add(key)
                    warnings.warn(
                        f"event-bus sink {getattr(sink, '__name__', sink)!r} "
                        f"failed on topic {topic!r}: {e!r} (suppressing "
                        "further warnings for this sink)", RuntimeWarning)
        return delivered
