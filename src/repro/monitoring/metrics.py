"""Structured metrics logging (JSONL) + step timing.

Production loops emit one JSONL record per step; dashboards/tools tail the
file. ``flush_every`` batches writes: the file is opened BLOCK-buffered and
flushed explicitly every N records (N=1, the default, keeps the historical
crash-safe line-at-a-time behavior). ``close()`` always flushes the tail;
both the logger and ``SchedulerAudit`` are context managers so no run leaks
an open file handle. ``StepTimer`` keeps an EMA of step time and flags
stragglers (steps > k x EMA) — the host-side counterpart of the engine's
device-level straggler mitigation.

``MetricsLogger.on_round`` is the engine sink: subscribe it to an
``EventBus`` ``round`` topic (``repro.monitoring.session`` does this from
the spec's ``obs`` axis) and every finished ``RoundRecord`` becomes one
JSONL row — the input half of ``python -m repro.monitoring report``.
"""

from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Optional


class MetricsLogger:
    def __init__(self, path: str, flush_every: int = 1):
        if flush_every < 1:
            raise ValueError(f"flush_every must be >= 1, got {flush_every}")
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        # Block-buffered on purpose: the explicit flush below is the ONLY
        # flush cadence, so flush_every genuinely batches small writes
        # (buffering=1 would flush every line and make the knob dead code).
        self._f = open(path, "a")
        self._flush_every = flush_every
        self._n = 0

    def log(self, step: int, metrics: Dict[str, Any], **extra) -> None:
        rec = {"step": step, "t": time.time(), **metrics, **extra}
        self._f.write(json.dumps(rec, default=float) + "\n")
        self._n += 1
        if self._n % self._flush_every == 0:
            self._f.flush()

    def on_round(self, rec) -> None:
        """Event-bus sink: one JSONL row per finished ``RoundRecord``."""
        self.log(rec.round_idx, {
            "job": rec.job, "t_start": rec.t_start, "t_end": rec.t_end,
            "round_time": rec.round_time, "cost": rec.cost,
            "fairness": rec.fairness, "loss": rec.loss,
            "accuracy": rec.accuracy, "est_cost": rec.est_cost,
            "degraded": bool(rec.degraded),
            "rung": getattr(rec, "rung", None),
            "decision_ms": getattr(rec, "decision_ms", None),
            "n_devices": int(len(rec.device_ids)),
            "n_dropped": int(len(rec.dropped))})

    def flush(self) -> None:
        if not self._f.closed:
            self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()

    def __enter__(self) -> "MetricsLogger":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


class StepTimer:
    """EMA step timer with straggler detection."""

    def __init__(self, ema: float = 0.9, straggler_factor: float = 3.0):
        self.ema_s: Optional[float] = None
        self._alpha = ema
        self._factor = straggler_factor
        self._t0: Optional[float] = None
        self.stragglers = 0

    def __enter__(self):
        self._t0 = time.time()
        return self

    def __exit__(self, *exc):
        dt = time.time() - self._t0
        if self.ema_s is not None and dt > self._factor * self.ema_s:
            self.stragglers += 1
        self.ema_s = dt if self.ema_s is None else (
            self._alpha * self.ema_s + (1 - self._alpha) * dt)
        self.last_s = dt
        return False
