"""Structured metrics logging (JSONL) + step timing.

Production loops emit one JSONL record per step (append-only, crash-safe:
each line is flushed); dashboards/tools tail the file. ``StepTimer`` keeps an
EMA of step time and flags stragglers (steps > k x EMA) — the host-side
counterpart of the engine's device-level straggler mitigation.
"""

from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Optional


class MetricsLogger:
    def __init__(self, path: str, flush_every: int = 1):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "a", buffering=1)
        self._flush_every = flush_every
        self._n = 0

    def log(self, step: int, metrics: Dict[str, Any], **extra) -> None:
        rec = {"step": step, "t": time.time(), **metrics, **extra}
        self._f.write(json.dumps(rec, default=float) + "\n")
        self._n += 1
        if self._n % self._flush_every == 0:
            self._f.flush()

    def close(self) -> None:
        self._f.close()


class StepTimer:
    """EMA step timer with straggler detection."""

    def __init__(self, ema: float = 0.9, straggler_factor: float = 3.0):
        self.ema_s: Optional[float] = None
        self._alpha = ema
        self._factor = straggler_factor
        self._t0: Optional[float] = None
        self.stragglers = 0

    def __enter__(self):
        self._t0 = time.time()
        return self

    def __exit__(self, *exc):
        dt = time.time() - self._t0
        if self.ema_s is not None and dt > self._factor * self.ema_s:
            self.stragglers += 1
        self.ema_s = dt if self.ema_s is None else (
            self._alpha * self.ema_s + (1 - self._alpha) * dt)
        self.last_s = dt
        return False
