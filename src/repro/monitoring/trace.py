"""Zero-cost-when-disabled span tracer with Chrome/Perfetto JSON export.

The repo's headline claims are about TIME — where a round's wall-clock goes
(scheduler search vs dispatch vs jitted train step vs aggregation vs eval) —
so the hot paths carry ``span(...)`` markers that compile down to a single
attribute check when tracing is off:

    from repro.monitoring.trace import span

    with span("schedule", job=m):
        plan = scheduler.schedule(ctx)

Enabled, each span records one Chrome trace-event "complete" event
(``ph="X"``: name, ts, dur, pid, tid, args) into an in-memory buffer;
``save(path)`` writes ``{"traceEvents": [...]}`` which loads directly in
Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``. Spans nest by
construction — complete events on the same thread track nest by ts/dur in
the viewer — and are thread-safe (one buffer, GIL-atomic appends; tid
disambiguates tracks).

Disabled (the default), ``span()`` returns a shared no-op context manager
without allocating anything, and ``counter``/``instant`` return
immediately: no RNG is touched, no arrays are built, so traced and
untraced runs execute the SAME computation (``benchmarks/bench_obs.py``
gates enabled-vs-disabled engine records bitwise and overhead <= 3%).

Ownership: instrumented library code uses the module-global tracer via
``span``/``counter``/``instant``; ``repro.monitoring.session.ObsSession``
(the ``obs`` spec axis) enables it for the duration of a run and writes the
trace on close. Tests can also drive a private ``Tracer`` instance.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional


class _NoopSpan:
    """Shared do-nothing context manager returned when tracing is off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP = _NoopSpan()


class _Span:
    """A live span: records one complete event on exit."""

    __slots__ = ("_tracer", "_name", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        self._tracer._emit_complete(self._name, self._t0,
                                    time.perf_counter_ns(), self._args)
        return False


class Tracer:
    """In-memory trace-event collector (one per process is the norm)."""

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self._events: List[dict] = []
        self._lock = threading.Lock()
        self._pid = os.getpid()

    # ---- recording ----

    def span(self, name: str, **args):
        """Context manager timing a block; no-op (shared singleton, zero
        allocation) when disabled."""
        if not self.enabled:
            return _NOOP
        return _Span(self, name, args)

    def counter(self, name: str, value: float, **args) -> None:
        """Chrome counter event (renders as a stacked track in Perfetto)."""
        if not self.enabled:
            return
        with self._lock:
            self._events.append({
                "name": name, "ph": "C",
                "ts": time.perf_counter_ns() / 1e3,
                "pid": self._pid, "tid": threading.get_ident(),
                "args": {name: value, **args}})

    def instant(self, name: str, **args) -> None:
        """Chrome instant event (a vertical marker; thread-scoped)."""
        if not self.enabled:
            return
        with self._lock:
            self._events.append({
                "name": name, "ph": "i", "s": "t",
                "ts": time.perf_counter_ns() / 1e3,
                "pid": self._pid, "tid": threading.get_ident(),
                "args": args})

    def _emit_complete(self, name: str, t0_ns: int, t1_ns: int,
                       args: Dict[str, Any]) -> None:
        with self._lock:
            self._events.append({
                "name": name, "ph": "X",
                "ts": t0_ns / 1e3, "dur": (t1_ns - t0_ns) / 1e3,
                "pid": self._pid, "tid": threading.get_ident(),
                "args": args})

    # ---- lifecycle / export ----

    def clear(self) -> None:
        with self._lock:
            self._events = []

    @property
    def num_events(self) -> int:
        return len(self._events)

    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def to_dict(self, process_name: str = "repro") -> dict:
        """Chrome trace-event JSON object (Perfetto-loadable)."""
        meta = [{"name": "process_name", "ph": "M", "pid": self._pid,
                 "tid": 0, "args": {"name": process_name}}]
        return {"traceEvents": meta + self.events(),
                "displayTimeUnit": "ms"}

    def save(self, path: str, process_name: str = "repro") -> None:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_dict(process_name), f)
            f.write("\n")


# ---- the module-global tracer the instrumented hot paths talk to ----

_GLOBAL = Tracer()


def get_tracer() -> Tracer:
    return _GLOBAL


def enabled() -> bool:
    return _GLOBAL.enabled


def enable() -> None:
    _GLOBAL.enabled = True


def disable() -> None:
    _GLOBAL.enabled = False


def span(name: str, **args):
    """``with span("schedule", job=m): ...`` — global-tracer span. The
    disabled fast path is one attribute check + a shared singleton."""
    if not _GLOBAL.enabled:
        return _NOOP
    return _Span(_GLOBAL, name, args)


def counter(name: str, value: float, **args) -> None:
    _GLOBAL.counter(name, value, **args)


def instant(name: str, **args) -> None:
    _GLOBAL.instant(name, **args)


def save(path: str, process_name: str = "repro") -> None:
    _GLOBAL.save(path, process_name)


def clear() -> None:
    _GLOBAL.clear()
