"""Monitoring CLI: per-phase wall-clock reports from traces.

  python -m repro.monitoring report trace.json
  python -m repro.monitoring report trace.json --metrics metrics.jsonl
  python -m repro.monitoring report trace.json --diff other_trace.json
  python -m repro.monitoring report trace.json --check-bench BENCH_obs.json
  python -m repro.monitoring report trace.json --check-bench .   # all BENCH_*.json

Generate the inputs with the spec's ``obs`` axis on any run::

  python -m repro.experiment.cli preset quickstart \\
      --set obs.trace_path=trace.json --set obs.metrics_path=metrics.jsonl

``--check-bench`` exits non-zero on a phase-level regression (current p50
above the baseline's recorded phase p50 by more than ``--tolerance``) or
when any named BENCH_*.json carries recorded gate failures.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.monitoring import report as rpt


def cmd_report(args) -> int:
    events = rpt.load_trace(args.trace)
    stats = rpt.phase_stats(events)
    if not stats:
        print(f"{args.trace}: no complete ('X') span events")
        return 1
    print(f"== {args.trace} ==")
    print(rpt.format_table(stats))
    cov = rpt.coverage(stats)
    rps = rpt.rounds_per_sec(stats)
    line = [f"recompiles={rpt.recompile_count(events)}"]
    if cov is not None:
        line.insert(0, f"engine span coverage {cov * 100:.1f}%")
    if rps is not None:
        line.append(f"rounds/sec={rps:.1f}")
    print("  " + "  ".join(line))

    if args.metrics:
        metrics = rpt.load_metrics(args.metrics)
        print("\nper-job summary (metrics JSONL):")
        for job, s in rpt.per_job_summary(metrics).items():
            print(f"  job {job}: rounds={s['rounds']:4d} "
                  f"mean_cost={s['mean_cost']:.3f} "
                  f"mean_fairness={s['mean_fairness']:.3f} "
                  f"final_acc={s['final_accuracy']:.3f} "
                  f"degraded={s['degraded_rounds']}")
        slo = rpt.slo_summary(metrics)
        if slo is not None:
            print(f"\nslo ladder ({slo['decisions']} decisions, "
                  f"{slo['degraded_decisions']} degraded):")
            for rung, s in slo["rungs"].items():
                tail = (f" p50={s['p50_ms']:.2f}ms p99={s['p99_ms']:.2f}ms"
                        if "p50_ms" in s else "")
                print(f"  rung {rung:12s} n={s['count']:5d}{tail}")

    rc = 0
    if args.diff:
        other = rpt.phase_stats(rpt.load_trace(args.diff))
        print(f"\n== diff vs {args.diff} (ratio > 1: {args.diff} slower) ==")
        print(f"{'phase':24s} {'p50_ms (this)':>14s} {'p50_ms (other)':>15s} "
              f"{'ratio':>7s}")
        for name, d in rpt.diff_phases(stats, other).items():
            print(f"{name:24s} {d['p50_ms_a']:14.3f} {d['p50_ms_b']:15.3f} "
                  f"{d['p50_ratio']:7.2f}")

    if args.check_bench:
        failures = rpt.check_bench(stats, args.check_bench,
                                   tolerance=args.tolerance)
        if failures:
            print("\nREGRESSIONS:")
            for f in failures:
                print(f"  {f}")
            rc = 1
        else:
            print(f"\nbench check clean ({', '.join(args.check_bench)})")

    if args.json:
        out = rpt.summarize(args.trace, metrics_path=args.metrics)
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"\nreport JSON -> {args.json}")
    return rc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.monitoring", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("report", help="per-phase wall-clock breakdown of a "
                                      "trace (+ optional diff / bench check)")
    p.add_argument("trace", help="Chrome/Perfetto trace JSON "
                                 "(obs.trace_path output)")
    p.add_argument("--metrics", help="round-metrics JSONL "
                                     "(obs.metrics_path output)")
    p.add_argument("--diff", metavar="TRACE2",
                   help="second trace: print per-phase p50 ratios")
    p.add_argument("--check-bench", nargs="+", metavar="PATH",
                   help="BENCH_*.json files/dirs/globs: fail on phase-level "
                        "regressions or recorded gate failures")
    p.add_argument("--tolerance", type=float, default=0.5,
                   help="allowed fractional p50 slowdown vs a bench "
                        "baseline's phases (default 0.5 = 50%%)")
    p.add_argument("--json", metavar="OUT",
                   help="also write the full report as JSON")
    p.set_defaults(fn=cmd_report)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
