"""Regression-aware run reports from traces + metrics JSONL.

``python -m repro.monitoring report trace.json`` answers "where did the
wall-clock go": a per-phase breakdown table (count, total, p50/p99 per span
kind), span coverage of engine wall-clock, the jit recompile count, rounds
per second, and (given ``--metrics``) a per-job cost/fairness summary.
``--diff other_trace.json`` prints per-phase p50 deltas between two runs;
``--check-bench BENCH_obs.json [more BENCH_*.json ...]`` compares the
trace's phase p50s against the benchmark baseline's recorded phases
(tolerance-gated) and surfaces any ``gate.failures`` recorded inside the
repo's BENCH_*.json artifacts — phase-level regression checking as a CLI
one-liner.

All pure functions here (``phase_stats``, ``coverage``, ``diff_phases``,
``check_bench``) are importable for programmatic use; the CLI lives in
``repro.monitoring.__main__``.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

# Disjoint per-round engine phases (see core/multijob.py): their summed
# duration over an ``engine_run`` span is the covered wall-clock.
ENGINE_PHASES = ("ctx_build", "schedule", "dispatch", "aggregate", "record")
RECOMPILE_COUNTER = "jit_recompiles"


# ---- loading ----

def load_trace(path: str) -> List[dict]:
    """Chrome trace-event JSON -> event list (accepts both the
    ``{"traceEvents": [...]}`` object form and a bare array)."""
    with open(path) as f:
        d = json.load(f)
    return d["traceEvents"] if isinstance(d, dict) else d


def load_metrics(path: str) -> List[dict]:
    out = []
    with open(path) as f:
        for line in f:
            if line.strip():
                out.append(json.loads(line))
    return out


# ---- trace statistics ----

def phase_stats(events: List[dict]) -> Dict[str, dict]:
    """Per span-kind wall-clock stats from complete (``ph == "X"``) events."""
    durs: Dict[str, list] = {}
    for ev in events:
        if ev.get("ph") == "X":
            durs.setdefault(ev["name"], []).append(float(ev.get("dur", 0.0)))
    out = {}
    for name, d in sorted(durs.items()):
        a = np.asarray(d) / 1e3  # us -> ms
        out[name] = {
            "count": int(a.size),
            "total_ms": float(a.sum()),
            "mean_ms": float(a.mean()),
            "p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99)),
        }
    return out


def recompile_count(events: List[dict]) -> int:
    """Final value of the jit-recompile counter track (0 if absent)."""
    vals = [ev["args"].get(RECOMPILE_COUNTER, 0) for ev in events
            if ev.get("ph") == "C" and ev.get("name") == RECOMPILE_COUNTER]
    return int(max(vals)) if vals else 0


def coverage(stats: Dict[str, dict],
             phases: Tuple[str, ...] = ENGINE_PHASES,
             root: str = "engine_run") -> Optional[float]:
    """Fraction of the root span's wall-clock covered by the (disjoint)
    engine phase spans; None when the trace has no root span."""
    if root not in stats or stats[root]["total_ms"] <= 0.0:
        return None
    covered = sum(stats[p]["total_ms"] for p in phases if p in stats)
    return covered / stats[root]["total_ms"]


def rounds_per_sec(stats: Dict[str, dict],
                   root: str = "engine_run") -> Optional[float]:
    """Completed rounds (one ``record`` span each) per second of engine
    wall-clock."""
    if root not in stats or "record" not in stats:
        return None
    wall_s = stats[root]["total_ms"] / 1e3
    return stats["record"]["count"] / wall_s if wall_s > 0 else None


def per_job_summary(metrics: List[dict]) -> Dict[int, dict]:
    """Per-job cost/fairness rollup from a round-metrics JSONL."""
    by_job: Dict[int, list] = {}
    for m in metrics:
        if "job" in m:
            by_job.setdefault(int(m["job"]), []).append(m)
    out = {}
    for job, rows in sorted(by_job.items()):
        cost = np.asarray([r.get("cost", np.nan) for r in rows], dtype=float)
        fair = np.asarray([r.get("fairness", np.nan) for r in rows],
                          dtype=float)
        out[job] = {
            "rounds": len(rows),
            "mean_cost": float(np.nanmean(cost)) if cost.size else 0.0,
            "total_cost": float(np.nansum(cost)),
            "mean_fairness": float(np.nanmean(fair)) if fair.size else 0.0,
            "final_accuracy": float(rows[-1].get("accuracy", 0.0)),
            "degraded_rounds": sum(1 for r in rows if r.get("degraded")),
        }
    return out


def slo_summary(metrics: List[dict]) -> Optional[dict]:
    """Degradation-ladder rollup from round-metrics JSONL rows carrying the
    SLO axis' ``rung``/``decision_ms`` fields (see ``repro.serve.resilience``).
    None when no row has a rung — the run had no governor attached."""
    rows = [m for m in metrics if m.get("rung") is not None]
    if not rows:
        return None
    out: Dict[str, dict] = {}
    for rung in sorted({str(m["rung"]) for m in rows}):
        ms = np.asarray([float(m["decision_ms"]) for m in rows
                         if str(m["rung"]) == rung
                         and m.get("decision_ms") is not None])
        entry = {"count": sum(1 for m in rows if str(m["rung"]) == rung)}
        if ms.size:
            entry["p50_ms"] = float(np.percentile(ms, 50))
            entry["p99_ms"] = float(np.percentile(ms, 99))
        out[rung] = entry
    degraded = sum(1 for m in rows if str(m["rung"]) != "full")
    return {"rungs": out, "decisions": len(rows),
            "degraded_decisions": degraded}


# ---- rendering ----

def format_table(stats: Dict[str, dict], sort_by: str = "total_ms") -> str:
    lines = [f"{'phase':24s} {'count':>7s} {'total_ms':>10s} "
             f"{'mean_ms':>9s} {'p50_ms':>9s} {'p99_ms':>9s}"]
    for name, s in sorted(stats.items(), key=lambda kv: -kv[1][sort_by]):
        lines.append(f"{name:24s} {s['count']:7d} {s['total_ms']:10.2f} "
                     f"{s['mean_ms']:9.3f} {s['p50_ms']:9.3f} "
                     f"{s['p99_ms']:9.3f}")
    return "\n".join(lines)


def summarize(trace_path: str,
              metrics_path: Optional[str] = None) -> dict:
    """Everything the report prints, as one JSON-ready dict."""
    events = load_trace(trace_path)
    stats = phase_stats(events)
    out = {
        "trace": trace_path,
        "phases": stats,
        "coverage": coverage(stats),
        "recompiles": recompile_count(events),
        "rounds_per_sec": rounds_per_sec(stats),
    }
    if metrics_path:
        metrics = load_metrics(metrics_path)
        out["jobs"] = per_job_summary(metrics)
        slo = slo_summary(metrics)
        if slo is not None:
            out["slo"] = slo
    return out


# ---- regression checking ----

def diff_phases(a: Dict[str, dict], b: Dict[str, dict]) -> Dict[str, dict]:
    """Per-phase p50/total deltas of run b relative to run a (shared phases
    only). ``p50_ratio`` > 1 means b is slower."""
    out = {}
    for name in sorted(set(a) & set(b)):
        pa, pb = a[name], b[name]
        out[name] = {
            "p50_ms_a": pa["p50_ms"], "p50_ms_b": pb["p50_ms"],
            "p50_ratio": (pb["p50_ms"] / pa["p50_ms"]
                          if pa["p50_ms"] > 0 else float("inf")),
            "total_ms_a": pa["total_ms"], "total_ms_b": pb["total_ms"],
        }
    return out


def check_bench(stats: Dict[str, dict], bench_paths: List[str],
                tolerance: float = 0.5) -> List[str]:
    """Phase-level regression check against BENCH_*.json artifacts.

    Two sources of failure:
    - a baseline file carrying a ``phases`` block (``BENCH_obs.json``):
      any shared phase whose current p50 exceeds baseline * (1 + tolerance).
      The ``engine_run`` root is skipped — it scales with workload length,
      not per-round cost, so it never compares across runs of different
      sizes (per-phase p50s are per-round quantities and do).
    - any BENCH file whose ``gate.failures`` list is non-empty (the repo's
      benchmark gates record their own verdicts there).

    ``bench_paths`` entries may be files, directories (scanned for
    ``BENCH_*.json``), or globs. Returns human-readable failure strings
    (empty = clean).
    """
    paths: List[str] = []
    for p in bench_paths:
        if os.path.isdir(p):
            paths.extend(sorted(glob.glob(os.path.join(p, "BENCH_*.json"))))
        else:
            paths.extend(sorted(glob.glob(p)) or [p])
    failures = []
    for path in paths:
        try:
            with open(path) as f:
                bench = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            failures.append(f"{path}: unreadable ({e})")
            continue
        base = bench.get("phases")
        if isinstance(base, dict):
            for name in sorted(set(base) & set(stats) - {"engine_run"}):
                b50 = float(base[name].get("p50_ms", 0.0))
                cur = stats[name]["p50_ms"]
                if b50 > 0 and cur > b50 * (1.0 + tolerance):
                    failures.append(
                        f"{path}: phase {name!r} p50 {cur:.3f}ms exceeds "
                        f"baseline {b50:.3f}ms by more than "
                        f"{tolerance * 100:.0f}%")
        gate = bench.get("gate", {})
        for msg in gate.get("failures", []) or []:
            failures.append(f"{path}: recorded gate failure: {msg}")
    return failures
