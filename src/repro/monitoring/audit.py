"""Scheduler decision audit log.

Every scheduling decision is reconstructable: which devices were scheduled,
what it cost, the ESTIMATED vs realized cost (``est_cost`` is the
scheduler's Formula-2 estimate at decision time; ``cost - est_cost`` is the
residual the learned schedulers model), whether the round degraded to a
single-survivor aggregate, and the fairness state. Required for debugging
production scheduling regressions ("why did job 3 starve yesterday?") and
doubles as the data source for offline scheduler evaluation / RLDS
re-training.

``on_round`` is an event-bus sink (``repro.monitoring.bus``): subscribe it
to the engine's ``round`` topic — ``repro.monitoring.session.ObsSession``
wires this from the spec's ``obs.audit_path`` knob — or pass it directly as
``engine.run(on_round=audit.on_round)``. Context-manager use closes the
file handle deterministically.
"""

from __future__ import annotations

import json
import os
from typing import Optional

import numpy as np


class SchedulerAudit:
    def __init__(self, path: str, scheduler: Optional[str] = None):
        """``scheduler``: registry name stamped on every line so mixed-log
        analysis can attribute decisions (e.g. A/B across schedulers)."""
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        # Line-buffered: each decision lands on disk immediately (the audit
        # log is the crash post-mortem input, unlike the batched metrics).
        self._f = open(path, "a", buffering=1)
        self.scheduler = scheduler

    def on_round(self, rec) -> None:
        self._f.write(json.dumps({
            "job": rec.job,
            "round": rec.round_idx,
            "scheduler": self.scheduler,
            "t_start": rec.t_start,
            "t_end": rec.t_end,
            "round_time": rec.round_time,
            "cost": rec.cost,
            "est_cost": None if rec.est_cost is None else float(rec.est_cost),
            "fairness": rec.fairness,
            "degraded": bool(rec.degraded),
            "rung": getattr(rec, "rung", None),
            "decision_ms": (None if getattr(rec, "decision_ms", None) is None
                            else float(rec.decision_ms)),
            "loss": rec.loss,
            "accuracy": rec.accuracy,
            "devices": np.asarray(rec.device_ids).tolist(),
            "dropped": np.asarray(rec.dropped).tolist(),
        }) + "\n")

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "SchedulerAudit":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


def replay(path: str):
    """Load an audit log back into RoundRecord-like dicts."""
    out = []
    with open(path) as f:
        for line in f:
            if line.strip():
                out.append(json.loads(line))
    return out
