"""Scheduler decision audit log.

Every scheduling decision is reconstructable: which devices were available,
what the scheduler chose, the estimated vs realized cost, and the fairness
state. Required for debugging production scheduling regressions ("why did
job 3 starve yesterday?") and doubles as the data source for offline
scheduler evaluation / RLDS re-training.
"""

from __future__ import annotations

import json
import os
import time
from typing import Optional

import numpy as np

from repro.core.multijob import RoundRecord


class SchedulerAudit:
    def __init__(self, path: str):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "a", buffering=1)

    def on_round(self, rec: RoundRecord) -> None:
        self._f.write(json.dumps({
            "job": rec.job,
            "round": rec.round_idx,
            "t_start": rec.t_start,
            "t_end": rec.t_end,
            "round_time": rec.round_time,
            "cost": rec.cost,
            "fairness": rec.fairness,
            "loss": rec.loss,
            "accuracy": rec.accuracy,
            "devices": np.asarray(rec.device_ids).tolist(),
            "dropped": np.asarray(rec.dropped).tolist(),
        }) + "\n")

    def close(self) -> None:
        self._f.close()


def replay(path: str):
    """Load an audit log back into RoundRecord-like dicts."""
    out = []
    with open(path) as f:
        for line in f:
            if line.strip():
                out.append(json.loads(line))
    return out
