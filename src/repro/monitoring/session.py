"""The ``obs`` experiment axis: declarative observability wiring.

``ObsSpec`` is a frozen JSON-round-trippable sub-spec on ``ExperimentSpec``
(``--set obs.trace_path=trace.json``, ``obs.metrics_path``,
``obs.audit_path``, ``obs.enabled``) so ANY preset / CLI / bench run can
emit a Perfetto trace, a metrics JSONL, and a scheduler audit log without
code changes. Setting any output path implies ``enabled``.

``ObsSession`` is the live wiring ``ExperimentSpec.build()`` creates from
an active ``ObsSpec``: it turns on the global span tracer
(``repro.monitoring.trace``), builds an ``EventBus``, subscribes the
``MetricsLogger`` / ``SchedulerAudit`` sinks to the engine's ``round``
topic, and hangs itself plus the bus on the engine (``engine.obs``,
``engine.events``). ``close()`` writes the trace and closes every sink —
``Experiment.run`` and ``SchedulerService.run`` call it when the run ends.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.monitoring.audit import SchedulerAudit
from repro.monitoring.bus import EventBus
from repro.monitoring.metrics import MetricsLogger
from repro.monitoring import trace


@dataclasses.dataclass(frozen=True)
class ObsSpec:
    """Observability axis: where (and whether) a run reports.

    ``enabled`` force-enables the span tracer even with no ``trace_path``
    (the trace then stays in memory — ``repro.monitoring.trace.get_tracer``
    — for programmatic use); any non-None path implies enabled. ``trace_path``
    gets Chrome/Perfetto trace-event JSON (load it at
    https://ui.perfetto.dev); ``metrics_path`` gets one JSONL row per
    finished round (batched by ``flush_every``); ``audit_path`` gets the
    per-decision scheduler audit log.
    """

    enabled: bool = False
    trace_path: Optional[str] = None
    metrics_path: Optional[str] = None
    audit_path: Optional[str] = None
    flush_every: int = 1

    @property
    def active(self) -> bool:
        return bool(self.enabled or self.trace_path or self.metrics_path
                    or self.audit_path)


class ObsSession:
    """Live sinks + bus + tracer ownership for one observed run."""

    def __init__(self, spec: ObsSpec, scheduler: Optional[str] = None,
                 process_name: str = "repro"):
        self.spec = spec
        self.process_name = process_name
        self.bus = EventBus()
        self.metrics: Optional[MetricsLogger] = None
        self.audit: Optional[SchedulerAudit] = None
        self._closed = False
        if spec.metrics_path:
            self.metrics = MetricsLogger(spec.metrics_path,
                                         flush_every=spec.flush_every)
            self.bus.subscribe("round", self.metrics.on_round)
        if spec.audit_path:
            self.audit = SchedulerAudit(spec.audit_path, scheduler=scheduler)
            self.bus.subscribe("round", self.audit.on_round)
        # The tracer is module-global (the hot paths must not thread a
        # handle through every layer); the session owns enable/clear/save.
        self._trace = bool(spec.enabled or spec.trace_path)
        if self._trace:
            trace.get_tracer().clear()
            trace.enable()

    def attach(self, engine) -> "ObsSession":
        """Point the engine's publish hooks at this session's bus."""
        engine.events = self.bus
        engine.obs = self
        return self

    def close(self) -> None:
        """Write the trace (if a path was configured), release the global
        tracer, and close every sink. Idempotent."""
        if self._closed:
            return
        self._closed = True
        if self._trace:
            if self.spec.trace_path:
                trace.save(self.spec.trace_path,
                           process_name=self.process_name)
            trace.disable()
        if self.metrics is not None:
            self.metrics.close()
        if self.audit is not None:
            self.audit.close()

    def __enter__(self) -> "ObsSession":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False
