"""Observability benchmark: the tracing layer must be (nearly) free.

Three measurements, written to ``BENCH_obs.json`` (gates enforced in CI
bench-smoke):

1. **Enabled-vs-disabled overhead** — the same seeded quickstart workload
   run with the full ``obs`` axis on (span tracer + metrics JSONL +
   scheduler audit) vs off, interleaved trial-by-trial with alternating
   order so machine drift hits both arms equally; the overhead is the
   median of the paired wall-time ratios and must stay <= ``--max-overhead``
   (default 3%).
2. **Bitwise identity** — the traced and untraced runs' round records must
   be IDENTICAL field-for-field (spans touch no RNG and build no arrays,
   so observation must not perturb the computation).
3. **Span coverage** — the engine phase spans (``ctx_build``/``schedule``/
   ``dispatch``/``aggregate``/``record``) must cover >= ``--min-coverage``
   (default 90%) of the ``engine_run`` root span's wall-clock, so a trace
   actually accounts for where the time went.

The enabled run's per-phase stats land in the output's ``phases`` block,
which ``python -m repro.monitoring report --check-bench BENCH_obs.json``
uses as the regression baseline for later traces.

  PYTHONPATH=src python -m benchmarks.bench_obs           # full size
  PYTHONPATH=src python -m benchmarks.bench_obs --smoke   # CI-sized
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import tempfile
import time

import numpy as np


def _quickstart(max_rounds: int):
    from repro.experiment.presets import get_preset

    spec = get_preset("quickstart")
    return spec.replace(jobs=tuple(
        dataclasses.replace(j, max_rounds=max_rounds, target_metric=2.0)
        for j in spec.jobs))


def _timed_run(spec):
    ex = spec.build()
    t0 = time.perf_counter()
    res = ex.run()
    return time.perf_counter() - t0, res.records


def _records_identical(a, b) -> bool:
    if len(a) != len(b):
        return False
    for ra, rb in zip(a, b):
        da, db = dataclasses.asdict(ra), dataclasses.asdict(rb)
        for k, va in da.items():
            vb = db[k]
            if isinstance(va, np.ndarray):
                if not np.array_equal(va, vb):
                    return False
            elif va != vb and not (va is None and vb is None):
                return False
    return True


def bench_overhead(max_rounds: int, trials: int, outdir: str) -> dict:
    """Interleave untraced and fully-observed runs (alternating which goes
    first); overhead is the median of the per-trial paired ratios. The two
    arms share the spec seeds, so their round records must match bitwise."""
    spec_off = _quickstart(max_rounds)
    spec_on = spec_off.replace(obs={
        "trace_path": os.path.join(outdir, "trace.json"),
        "metrics_path": os.path.join(outdir, "metrics.jsonl"),
        "audit_path": os.path.join(outdir, "audit.jsonl")})

    # Warm the jit caches (scheduler search compiles) outside the timing.
    _timed_run(spec_off)

    t_off, t_on = [], []
    identical = True
    for t in range(trials):
        arms = [(spec_off, t_off), (spec_on, t_on)]
        if t % 2:
            arms.reverse()
        recs = {}
        for spec, bucket in arms:
            dt, r = _timed_run(spec)
            bucket.append(dt)
            recs[spec is spec_on] = r
        identical = identical and _records_identical(recs[False], recs[True])
    ratios = np.asarray(t_on) / np.asarray(t_off)
    return {"disabled_s": float(np.median(t_off)),
            "enabled_s": float(np.median(t_on)),
            "overhead": float(np.median(ratios)) - 1.0,
            "records_identical": identical,
            "trials": trials, "rounds_per_run": max_rounds}


def trace_report(outdir: str) -> dict:
    from repro.monitoring import report as rpt

    events = rpt.load_trace(os.path.join(outdir, "trace.json"))
    stats = rpt.phase_stats(events)
    return {"phases": stats,
            "coverage": rpt.coverage(stats),
            "recompiles": rpt.recompile_count(events),
            "rounds_per_sec": rpt.rounds_per_sec(stats)}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer trials/rounds)")
    ap.add_argument("--out", default="BENCH_obs.json")
    ap.add_argument("--max-overhead", type=float, default=0.03,
                    help="fail if full observability costs more than this "
                         "fraction of the untraced run (median paired wall)")
    ap.add_argument("--min-coverage", type=float, default=0.9,
                    help="fail if the engine phase spans cover less than "
                         "this fraction of the engine_run wall-clock")
    args = ap.parse_args(argv)

    # Longer runs amortize per-run fixed costs (session setup, trace write)
    # and more trials stabilize the paired median against machine noise.
    max_rounds, trials = (40, 5) if args.smoke else (80, 9)

    with tempfile.TemporaryDirectory(prefix="bench_obs_") as outdir:
        print("== enabled-vs-disabled overhead (paired, order-alternated) ==")
        ov = bench_overhead(max_rounds, trials, outdir)
        print(f"  disabled {ov['disabled_s'] * 1e3:8.1f}ms/run  "
              f"enabled {ov['enabled_s'] * 1e3:8.1f}ms/run  "
              f"overhead {ov['overhead'] * 100:+.2f}%  "
              f"records identical={ov['records_identical']}")

        print("== trace coverage (last enabled run) ==")
        rep = trace_report(outdir)
        cov = rep["coverage"]
        print(f"  coverage {cov * 100:.1f}%  recompiles={rep['recompiles']}  "
              f"rounds/sec={rep['rounds_per_sec']:.1f}")

    failures = []
    if ov["overhead"] > args.max_overhead:
        failures.append(f"obs overhead {ov['overhead'] * 100:.2f}% > "
                        f"{args.max_overhead * 100:.0f}% gate")
    if not ov["records_identical"]:
        failures.append("traced run's round records diverged from the "
                        "untraced run (observation perturbed the compute)")
    if cov is None or cov < args.min_coverage:
        failures.append(f"engine span coverage "
                        f"{(cov or 0.0) * 100:.1f}% < "
                        f"{args.min_coverage * 100:.0f}% gate")

    out = {"smoke": args.smoke, "overhead": ov, "phases": rep["phases"],
           "coverage": cov, "recompiles": rep["recompiles"],
           "rounds_per_sec": rep["rounds_per_sec"],
           "gate": {"max_overhead": args.max_overhead,
                    "min_coverage": args.min_coverage,
                    "failures": failures}}
    with open(args.out, "w") as fobj:
        json.dump(out, fobj, indent=2)
    print(f"\nwrote {args.out}")
    if failures:
        raise SystemExit("bench_obs regression gate FAILED:\n  "
                         + "\n  ".join(failures))


if __name__ == "__main__":
    main()
