"""Paper appendix ablation: the data-fairness term (beta) on vs off.

Claim under test: without fairness (beta=0) the scheduler degenerates toward
greedy/fast-device selection — faster rounds but an accuracy ceiling under
non-IID; with fairness both speed AND final accuracy hold.
Also sweeps the cost-combination form (the paper reports the linear
combination beats sum-of-squares and multiplicative variants).
"""

from __future__ import annotations

import numpy as np

from repro.config.base import ArchFamily, JobConfig, ModelConfig
from repro.core.cost import CostModel
from repro.core.devices import DevicePool
from repro.core.multijob import MultiJobEngine
from repro.core.schedulers import get_scheduler
from repro.fl.runtime import SyntheticRuntime


def _run(alpha, beta, seed=1):
    jobs = [JobConfig(job_id=i,
                      model=ModelConfig(name=f"j{i}", family=ArchFamily.CNN,
                                        cnn_spec=(("flatten",),),
                                        input_shape=(4, 4, 1), num_classes=10),
                      target_metric=0.8, max_rounds=150) for i in range(3)]
    pool = DevicePool.heterogeneous(100, 3, seed=seed)
    cm = CostModel(pool, alpha=alpha, beta=beta)
    cm.calibrate([5.0] * 3, n_sel=10)
    sched = get_scheduler("bods", cost_model=cm, seed=0)
    rt = SyntheticRuntime(num_jobs=3, num_devices=100, seed=2)
    eng = MultiJobEngine(jobs, pool, cm, sched, rt, n_sel=10)
    eng.run()
    s = eng.summary()
    acc = float(np.mean([v["best_accuracy"] for v in s.values()]))
    t2t = [v["time_to_target"] for v in s.values()]
    mk = max(v["makespan"] for v in s.values())
    rt_mean = float(np.mean([r.round_time for r in eng.records]))
    return acc, t2t, mk, rt_mean


def main():
    print("\n== Ablation: fairness term (BODS) ==")
    for alpha, beta, label in [(4.0, 0.25, "alpha=4, beta=0.25 (default)"),
                               (4.0, 0.0, "alpha=4, beta=0 (no fairness)"),
                               (0.0, 1.0, "alpha=0 (fairness only)")]:
        acc, t2t, mk, rt = _run(alpha, beta)
        hit = sum(t is not None for t in t2t)
        print(f"{label:34s} mean_best_acc={acc:.3f} jobs_hit_target={hit}/3 "
              f"makespan={mk/60:8.1f}min mean_round={rt:6.0f}s")
        print(f"CSV,ablation,{label.replace(' ', '_').replace(',', '')},"
              f"{acc:.4f},{hit},{mk:.0f}")


if __name__ == "__main__":
    main()
