"""Paper appendix ablation: the data-fairness term (beta) on vs off.

Claim under test: without fairness (beta=0) the scheduler degenerates toward
greedy/fast-device selection — faster rounds but an accuracy ceiling under
non-IID; with fairness both speed AND final accuracy hold.
Also sweeps the cost-combination form (the paper reports the linear
combination beats sum-of-squares and multiplicative variants).

Each (alpha, beta) cell is the same ``ExperimentSpec`` with a different
``CostSpec`` — the ablation axis is declarative.
"""

from __future__ import annotations

import numpy as np

from repro.experiment import CostSpec, ExperimentSpec, JobSpec, PoolSpec


def _run(alpha, beta, seed=1):
    spec = ExperimentSpec(
        name=f"ablation-a{alpha}-b{beta}",
        jobs=tuple(JobSpec(name=f"j{i}", target_metric=0.8, max_rounds=150)
                   for i in range(3)),
        pool=PoolSpec(num_devices=100, seed=seed),
        cost=CostSpec(alpha=alpha, beta=beta),
        scheduler="bods", runtime="synthetic",
        runtime_kwargs={"seed": 2}, n_sel=10)
    res = spec.run()
    s = res.summary
    acc = float(np.mean([v["best_accuracy"] for v in s.values()]))
    t2t = [v["time_to_target"] for v in s.values()]
    rt_mean = float(np.mean([r.round_time for r in res.records]))
    return acc, t2t, res.makespan, rt_mean


def main():
    print("\n== Ablation: fairness term (BODS) ==")
    for alpha, beta, label in [(4.0, 0.25, "alpha=4, beta=0.25 (default)"),
                               (4.0, 0.0, "alpha=4, beta=0 (no fairness)"),
                               (0.0, 1.0, "alpha=0 (fairness only)")]:
        acc, t2t, mk, rt = _run(alpha, beta)
        hit = sum(t is not None for t in t2t)
        print(f"{label:34s} mean_best_acc={acc:.3f} jobs_hit_target={hit}/3 "
              f"makespan={mk/60:8.1f}min mean_round={rt:6.0f}s")
        print(f"CSV,ablation,{label.replace(' ', '_').replace(',', '')},"
              f"{acc:.4f},{hit},{mk:.0f}")


if __name__ == "__main__":
    main()
