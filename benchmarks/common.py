"""Shared benchmark harness: one engine run per (group, distribution, scheduler)."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.config.base import ArchFamily, JobConfig, ModelConfig
from repro.core.cost import CostModel
from repro.core.devices import DevicePool
from repro.core.multijob import MultiJobEngine
from repro.core.schedulers import get_scheduler
from repro.fl.runtime import SyntheticRuntime

# Paper groups in scheduler-benchmark form: per-job complexity is encoded as
# (tau-equivalent compute weight, convergence rate, target). Complexity
# ordering follows the paper: LeNet < CNN < VGG; AlexNet < CNN-B < ResNet.
# (job, target_noniid, target_iid, convergence_rate). Non-IID targets sit
# ABOVE greedy's starvation ceiling (~0.73-0.76) and safely below the
# fair schedulers' ceiling so the paper's accuracy separation is the thing
# being measured, not seed luck at the asymptote.
GROUPS = {
    "A": [("vgg16", 0.54, 0.54, 0.06), ("cnn-a", 0.78, 0.79, 0.12),
          ("lenet5", 0.79, 0.84, 0.20)],
    "B": [("resnet18", 0.58, 0.59, 0.08), ("cnn-b", 0.72, 0.72, 0.12),
          ("alexnet", 0.78, 0.84, 0.18)],
}

SCHEDULERS = ["random", "fedcs", "genetic", "greedy", "bods", "rlds"]


def run_group(group: str, scheduler: str, non_iid: bool, seed: int = 1,
              num_devices: int = 100, n_sel: int = 10,
              max_rounds: int = 150) -> Dict:
    spec = GROUPS[group]
    jobs = []
    for i, (name, t_noniid, t_iid, rate) in enumerate(spec):
        mc = ModelConfig(name=name, family=ArchFamily.CNN,
                         cnn_spec=(("flatten",),), input_shape=(4, 4, 1),
                         num_classes=10)
        jobs.append(JobConfig(job_id=i, model=mc,
                              target_metric=t_noniid if non_iid else t_iid,
                              max_rounds=max_rounds, local_epochs=5))
    pool = DevicePool.heterogeneous(num_devices, len(jobs), seed=seed)
    cm = CostModel(pool, alpha=4.0, beta=0.25)
    cm.calibrate([5.0] * len(jobs), n_sel=n_sel)
    sched = get_scheduler(scheduler, cost_model=cm, seed=0)
    rt = SyntheticRuntime(num_jobs=len(jobs), num_devices=num_devices,
                          classes_per_device=(2 if non_iid else 10),
                          seed=2)
    # per-job convergence rates
    rt_rates = {i: spec[i][3] for i in range(len(spec))}
    rt.b0 = np.mean(list(rt_rates.values()))
    t0 = time.time()
    eng = MultiJobEngine(jobs, pool, cm, sched, rt, n_sel=n_sel)
    eng.run()
    out = {"wall_s": time.time() - t0, "summary": eng.summary(),
           "records": eng.records}
    return out


def fmt_time(t):
    return "/" if t is None else f"{t / 60:.1f}"
