"""Shared benchmark harness: one ``ExperimentSpec`` per (group, distribution,
scheduler) cell, materialized from the ``paper-group-*`` presets.

The group tables (per-job targets + convergence rates) live in
``repro.experiment.presets.PAPER_GROUPS`` — the single source of truth shared
with the CLI. ``run_group`` is now a thin wrapper: build the spec, run it,
return the legacy dict shape the table printers consume. Per-job convergence
rates flow through ``JobSpec.convergence_rate`` into the synthetic runtime's
per-job ``b0`` array (LeNet really does converge faster than VGG now).
"""

from __future__ import annotations

from typing import Dict

from repro.experiment.presets import PAPER_GROUPS, paper_group

GROUPS = PAPER_GROUPS

SCHEDULERS = ["random", "fedcs", "genetic", "greedy", "bods", "rlds"]


group_spec = paper_group  # the preset factory IS the benchmark spec factory


def run_group(group: str, scheduler: str, non_iid: bool, **kwargs) -> Dict:
    res = paper_group(group, scheduler=scheduler, non_iid=non_iid,
                      **kwargs).run()
    return {"wall_s": res.wall_s, "summary": res.summary,
            "records": res.records}


def fmt_time(t):
    return "/" if t is None else f"{t / 60:.1f}"
