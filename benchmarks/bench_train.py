"""Training-runtime benchmark: fused vs unfused vs pre-refactor rounds/sec.

Runs the ``real-fl-two-job`` preset (REAL vmap'd local SGD + FedAvg, paper
testbed in miniature) through three runtime arms:

- ``baseline`` — the PRE-REFACTOR training path, faithfully: per-job
  ``FLJobRuntime`` with the historical ``lax.conv_general_dilated`` +
  ``reduce_window`` model lowering (``set_conv_impl("lax")``), fresh XLA
  compile per distinct cohort size, host round-trips for the partition
  gather, eager per-leaf FedAvg.
- ``unfused`` — the same ``FLJobRuntime`` on the current model zoo (GEMM
  conv): the controlled ablation isolating what the FUSED ENGINE adds on
  top of the shared hot-path improvements.
- ``fused`` — ``FusedMultiRuntime``: bucketed cohort shapes (compile once
  per bucket), device-resident gather + SGD + masked FedAvg + eval in one
  donated-params jitted call, cross-job batched dispatch.

Two regimes are measured: ``steady`` (the preset as shipped — cohort size
pinned at n_sel) and ``varying`` (over-provisioning + fault injection, the
regime the paper's system model §(3)-(6) actually operates in, where the
survivor cohort changes every round and unspecialized jits recompile). Wall
time INCLUDES in-run compiles — recompile-free is the whole point.

The headline number is fused vs baseline (what this refactor bought end to
end); the CI regression gate is fused vs unfused (the fused engine must
never be slower than the per-job path it replaces). A parity check asserts
fused/unfused per-round accuracy agreement to 1e-4 at equal seeds (same
conv lowering, same schedule — the baseline arm is excluded because a
different conv lowering may legitimately flip an argmax by a sample).

  PYTHONPATH=src python -m benchmarks.bench_train            # full
  PYTHONPATH=src python -m benchmarks.bench_train --smoke    # CI-sized
  (writes BENCH_train.json; exits non-zero if fused < unfused throughput
  or parity fails)
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from repro.experiment import TrainSpec, get_preset
from repro.models.cnn_zoo import set_conv_impl

PARITY_TOL = 1e-4

ARMS = (
    ("baseline", dict(fused=False, conv_impl="lax")),
    ("unfused", dict(fused=False, conv_impl="gemm")),
    ("fused", dict(fused=True, conv_impl="gemm")),
)


def _bench_spec(rounds: int, varying: bool):
    """real-fl-two-job with targets pinned unreachable so every arm runs
    exactly ``rounds`` rounds per job (throughput is compared at equal work).
    """
    spec = get_preset("real-fl-two-job", rounds=rounds,
                      lenet_target=2.0, cnn_target=2.0)
    if varying:
        spec = spec.replace(name=spec.name + "-varying",
                            over_provision=1.6, failure_rate=0.15)
    return spec


def _run_arm(spec, fused: bool, conv_impl: str) -> dict:
    set_conv_impl(conv_impl)  # clears jit caches on flip: no cross-arm reuse
    try:
        spec = spec.replace(train=TrainSpec(fused=fused))
        exp = spec.build()  # data gen excluded; in-run compiles counted
        t0 = time.perf_counter()
        result = exp.run()
        wall = time.perf_counter() - t0
    finally:
        set_conv_impl("gemm")
    n = len(result.records)
    return {
        "fused": fused, "conv_impl": conv_impl, "rounds": n, "wall_s": wall,
        "rounds_per_sec": n / wall,
        "distinct_cohort_sizes": sorted({len(r.device_ids)
                                         for r in result.records}),
        "records": [(r.job, r.round_idx, float(r.accuracy))
                    for r in result.records],
    }


def bench_regime(regime: str, rounds: int) -> dict:
    spec = _bench_spec(rounds, varying=(regime == "varying"))
    print(f"== {regime}: {spec.name} ({rounds} rounds/job) ==")
    out = {"regime": regime, "spec_name": spec.name}
    records = {}
    for name, arm in ARMS:
        r = _run_arm(spec, **arm)
        records[name] = r.pop("records")
        out[name] = r
        print(f"  {name:8s}: {r['rounds']} rounds in {r['wall_s']:.1f}s "
              f"-> {r['rounds_per_sec']:.2f} rounds/s "
              f"(cohort sizes {r['distinct_cohort_sizes']})")
    out["speedup_vs_baseline"] = (out["fused"]["rounds_per_sec"]
                                  / out["baseline"]["rounds_per_sec"])
    out["speedup_vs_unfused"] = (out["fused"]["rounds_per_sec"]
                                 / out["unfused"]["rounds_per_sec"])
    print(f"  fused speedup: x{out['speedup_vs_baseline']:.2f} vs pre-PR "
          f"baseline, x{out['speedup_vs_unfused']:.2f} vs unfused")

    # Parity: fused and unfused ran the same seeds, conv lowering, and (with
    # pinned targets) the same schedule -> records must align round-for-round.
    fr, ur = sorted(records["fused"]), sorted(records["unfused"])
    if [r[:2] for r in fr] == [r[:2] for r in ur]:
        out["accuracy_max_diff"] = max(
            (abs(a[2] - b[2]) for a, b in zip(fr, ur)), default=0.0)
        print(f"  fused/unfused per-round accuracy max |diff|: "
              f"{out['accuracy_max_diff']:.2e}")
    else:
        out["accuracy_max_diff"] = None
        print("  WARNING: round traces diverged; no parity number")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer rounds)")
    ap.add_argument("--rounds", type=int, default=None,
                    help="rounds per job (default 12, smoke 6)")
    ap.add_argument("--out", default="BENCH_train.json")
    ap.add_argument("--min-speedup", type=float, default=0.9,
                    help="fail if fused/unfused rounds-per-sec in the "
                         "varying regime drops below this (default 0.9: "
                         "fused must at least match unfused, minus the "
                         "~10%% run-to-run noise of shared 2-core runners; "
                         "observed clean-machine range is x1.0-1.2)")
    args = ap.parse_args(argv)
    rounds = args.rounds or (6 if args.smoke else 12)

    regimes = [bench_regime("steady", rounds),
               bench_regime("varying", rounds)]
    headline = regimes[1]

    out = {"smoke": args.smoke, "rounds_per_job": rounds,
           "preset": "real-fl-two-job", "regimes": regimes,
           "headline_speedup_vs_baseline": headline["speedup_vs_baseline"],
           "headline_speedup_vs_unfused": headline["speedup_vs_unfused"]}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nwrote {args.out} (fused: x{headline['speedup_vs_baseline']:.2f}"
          f" vs pre-PR baseline, x{headline['speedup_vs_unfused']:.2f} vs "
          "unfused, varying regime)")

    failures = []
    if headline["speedup_vs_unfused"] < args.min_speedup:
        failures.append(
            f"fused throughput regressed: x{headline['speedup_vs_unfused']:.2f}"
            f" < required x{args.min_speedup:.2f} vs unfused (varying regime)")
    for reg in regimes:
        d = reg["accuracy_max_diff"]
        if d is None or d > PARITY_TOL:
            failures.append(
                f"fused/unfused accuracy parity failed in {reg['regime']}: "
                f"max |diff| = {d}")
    if failures:
        for msg in failures:
            print("FAIL:", msg, file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
