"""Paper Table 5: MJ-FL parallel execution vs sequential single-job FL.

Sequential baseline: each job runs ALONE on the full pool (FedAvg/random
selection), one after another; total time = sum of per-job times. MJ-FL runs
the same jobs in parallel on the shared pool. Both arms are the same
``ExperimentSpec`` with a different job tuple / scheduler name.
"""

from __future__ import annotations

from repro.experiment import ExperimentSpec, JobSpec, PoolSpec


def _spec(n_jobs: int, scheduler: str, seed: int = 1, n_sel: int = 10,
          target: float = 0.8, max_rounds: int = 150) -> ExperimentSpec:
    return ExperimentSpec(
        name=f"mj-vs-sj-{n_jobs}job-{scheduler}",
        jobs=tuple(JobSpec(name="job", target_metric=target,
                           max_rounds=max_rounds) for _ in range(n_jobs)),
        pool=PoolSpec(num_devices=100, seed=seed),
        scheduler=scheduler, runtime="synthetic",
        runtime_kwargs={"seed": 2}, n_sel=n_sel)


def main():
    print("\n== Table 5: MJ-FL (parallel) vs SJ-FL (sequential) ==")
    # Sequential: jobs one at a time; total = sum of makespans.
    seq_total = sum(_spec(1, "random", seed=1 + i).run().makespan
                    for i in range(3))
    rows = [("SJ-FL sequential (random)", seq_total)]
    for sched in ("random", "bods", "rlds"):
        rows.append((f"MJ-FL parallel ({sched})", _spec(3, sched).run().makespan))
    base = rows[0][1]
    for name, t in rows:
        print(f"{name:32s} total={t/60:9.1f} min  speedup_vs_seq={base/t:5.2f}x")
        print(f"CSV,mj_vs_sj,{name.replace(' ', '_')},{t:.0f},{base/t:.3f}")


if __name__ == "__main__":
    main()
