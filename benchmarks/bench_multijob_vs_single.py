"""Paper Table 5: MJ-FL parallel execution vs sequential single-job FL.

Sequential baseline: each job runs ALONE on the full pool (FedAvg/random
selection), one after another; total time = sum of per-job times. MJ-FL runs
the same jobs in parallel on the shared pool.
"""

from __future__ import annotations

import numpy as np

from repro.config.base import ArchFamily, JobConfig, ModelConfig
from repro.core.cost import CostModel
from repro.core.devices import DevicePool
from repro.core.multijob import MultiJobEngine
from repro.core.schedulers import get_scheduler
from repro.fl.runtime import SyntheticRuntime


def _jobs(n=3, target=0.8, max_rounds=150):
    mc = ModelConfig(name="job", family=ArchFamily.CNN, cnn_spec=(("flatten",),),
                     input_shape=(4, 4, 1), num_classes=10)
    return [JobConfig(job_id=i, model=mc, target_metric=target,
                      max_rounds=max_rounds) for i in range(n)]


def _run(jobs, scheduler, seed=1, n_sel=10):
    pool = DevicePool.heterogeneous(100, len(jobs), seed=seed)
    cm = CostModel(pool, alpha=4.0, beta=0.25)
    cm.calibrate([5.0] * len(jobs), n_sel=n_sel)
    sched = get_scheduler(scheduler, cost_model=cm, seed=0)
    rt = SyntheticRuntime(num_jobs=len(jobs), num_devices=100, seed=2)
    eng = MultiJobEngine(jobs, pool, cm, sched, rt, n_sel=n_sel)
    eng.run()
    return eng


def main():
    print("\n== Table 5: MJ-FL (parallel) vs SJ-FL (sequential) ==")
    # Sequential: jobs one at a time; total = sum of makespans.
    seq_total = 0.0
    for i in range(3):
        eng = _run(_jobs(1), "random", seed=1 + i)
        seq_total += max(v["makespan"] for v in eng.summary().values())
    rows = [("SJ-FL sequential (random)", seq_total)]
    for sched in ("random", "bods", "rlds"):
        eng = _run(_jobs(3), sched)
        mk = max(v["makespan"] for v in eng.summary().values())
        rows.append((f"MJ-FL parallel ({sched})", mk))
    base = rows[0][1]
    for name, t in rows:
        print(f"{name:32s} total={t/60:9.1f} min  speedup_vs_seq={base/t:5.2f}x")
        print(f"CSV,mj_vs_sj,{name.replace(' ', '_')},{t:.0f},{base/t:.3f}")


if __name__ == "__main__":
    main()
