"""Chaos smoke: CLI-level crash-consistency check for the scheduler service.

Drives ``python -m repro.serve`` as real subprocesses:

1. **Reference arm** — run an online spec WITH the faults axis end-to-end,
   dumping the engine's per-round records.
2. **Crash arm** — same spec with ``--checkpoint-dir``/``--checkpoint-every``
   and ``--crash-after N``: the process hard-kills itself with
   ``os._exit(137)`` mid-horizon (the ``kill -9`` equivalent — no cleanup,
   no flush), leaving only the atomically committed checkpoints behind.
3. **Resume arm** — ``--resume DIR`` restarts from the newest committed
   checkpoint and runs the remaining trace.

Gates (written to ``BENCH_chaos.json``, enforced in CI chaos-smoke):
- the crash arm really dies with exit code 137;
- the resumed run's full record trajectory is BIT-IDENTICAL to the
  uninterrupted reference (every field of every round, including device
  ids, dropped/corrupt sets, costs, and accuracies);
- every recorded metric is finite despite dropouts, crashes, stragglers,
  domain outages, and corrupted uploads.

  PYTHONPATH=src python -m benchmarks.chaos_smoke
  PYTHONPATH=src python -m benchmarks.chaos_smoke --overload

``--overload`` swaps the spec for the ``slo-overload`` preset (overload
traffic + faults + the full SLO resilience stack: degradation ladder,
shedding, circuit breakers, bounded retries, watchdog) and additionally
gates on the degradation histogram being non-empty — crash consistency
must hold WHILE the service is actively degrading, not just in steady
state. Output goes to ``BENCH_overload_chaos.json``.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile


def _serve(args, cwd):
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-m", "repro.serve"] + args,
                          cwd=cwd, env=env, capture_output=True, text=True)


def _spec_json(overload: bool = False) -> dict:
    from repro.experiment.presets import get_preset
    from repro.faults import FaultSpec

    if overload:
        # The slo-overload preset: arrivals ~3x faster than the drain rate
        # over a faulty fleet, with the queue-depth degradation ladder,
        # shedding, breakers, bounded retries, and the watchdog all armed —
        # and NO wall-clock deadline, so the trajectory (including fired
        # rungs) must replay bit-identically across kill -9 + resume.
        return get_preset("slo-overload", horizon=8_000.0).to_dict()
    spec = get_preset("online-smoke", scheduler="bods", num_devices=40,
                      horizon=10_000.0, interarrival=700.0)
    spec = spec.replace(faults=FaultSpec(
        seed=3, dropout_rate=0.1, crash_rate=0.002, straggler_rate=0.1,
        num_domains=4, domain_outage_rate=0.02, corrupt_rate=0.05))
    return spec.to_dict()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--overload", action="store_true",
                    help="run the slo-overload preset instead: overload "
                         "traffic + faults + the full resilience stack; "
                         "adds a non-empty-degradation-histogram gate")
    ap.add_argument("--out", default=None,
                    help="output JSON (default BENCH_chaos.json, or "
                         "BENCH_overload_chaos.json with --overload)")
    ap.add_argument("--crash-after", type=int, default=7)
    ap.add_argument("--checkpoint-every", type=int, default=3)
    args = ap.parse_args(argv)
    if args.out is None:
        args.out = ("BENCH_overload_chaos.json" if args.overload
                    else "BENCH_chaos.json")

    failures = []
    with tempfile.TemporaryDirectory() as tmp:
        spec_path = os.path.join(tmp, "spec.json")
        with open(spec_path, "w") as f:
            json.dump(_spec_json(args.overload), f)

        print("== reference arm (uninterrupted) ==")
        ref = _serve(["--spec", spec_path,
                      "--records-out", os.path.join(tmp, "ref.json")], tmp)
        if ref.returncode != 0:
            raise SystemExit(f"reference run failed:\n{ref.stderr}")

        print(f"== crash arm (--crash-after {args.crash_after}, "
              f"checkpoint every {args.checkpoint_every}) ==")
        ck = os.path.join(tmp, "ckpt")
        crash = _serve(["--spec", spec_path, "--checkpoint-dir", ck,
                        "--checkpoint-every", str(args.checkpoint_every),
                        "--crash-after", str(args.crash_after)], tmp)
        if crash.returncode != 137:
            failures.append(f"crash arm exited {crash.returncode}, "
                            f"expected 137 (kill -9 equivalent)\n"
                            f"{crash.stderr[-2000:]}")

        print("== resume arm (--resume) ==")
        res = _serve(["--resume", ck,
                      "--records-out", os.path.join(tmp, "res.json")], tmp)
        if res.returncode != 0:
            failures.append(f"resume run failed (exit {res.returncode}):\n"
                            f"{res.stderr[-2000:]}")

        records_ref = records_res = []
        rungs = {}
        if not failures:
            with open(os.path.join(tmp, "ref.json")) as f:
                records_ref = json.load(f)
            with open(os.path.join(tmp, "res.json")) as f:
                records_res = json.load(f)
            if records_ref != records_res:
                n = sum(1 for a, b in zip(records_ref, records_res)
                        if a != b)
                failures.append(
                    f"crash/resume trajectory DIVERGED from the "
                    f"uninterrupted reference: {len(records_ref)} vs "
                    f"{len(records_res)} rounds, {n} differing records")
            bad = [r for r in records_ref
                   for v in (r["accuracy"], r["loss"], r["round_time"])
                   if v is None or v != v or v in (float("inf"),
                                                  float("-inf"))]
            if bad:
                failures.append(f"{len(bad)} non-finite metrics under chaos")
            dropped = sum(len(r["dropped"]) for r in records_ref)
            corrupt = sum(len(r.get("corrupt_ids", []))
                          for r in records_ref)
            if dropped == 0 or corrupt == 0:
                failures.append(f"faults axis inert in chaos run "
                                f"(dropped={dropped}, corrupt={corrupt})")
            print(f"  {len(records_ref)} rounds bit-identical across "
                  f"kill -9 + resume; dropped={dropped} corrupt={corrupt}")
            if args.overload:
                for r in records_ref:
                    if r.get("rung") is not None:
                        rungs[r["rung"]] = rungs.get(r["rung"], 0) + 1
                degraded = sum(v for k, v in rungs.items() if k != "full")
                if degraded == 0:
                    failures.append(
                        "overload arm never degraded — the ladder was "
                        "inert (empty degradation histogram)")
                hist = " ".join(f"{k}={v}" for k, v in sorted(rungs.items()))
                print(f"  degradation histogram: {hist or 'EMPTY'}")

    out = {"overload": args.overload,
           "crash_after": args.crash_after,
           "checkpoint_every": args.checkpoint_every,
           "rounds": len(records_ref),
           "rung_counts": rungs,
           "gate": {"failures": failures}}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nwrote {args.out}")
    if failures:
        raise SystemExit("chaos_smoke FAILED:\n  " + "\n  ".join(failures))


if __name__ == "__main__":
    main()
