"""REAL-training benchmark (slow path, ~15-30 min on this container's CPU):
two-job groups with actual vmap'd local SGD + FedAvg under each scheduler.

  PYTHONPATH=src python -m benchmarks.bench_real_fl [--rounds 15]

The paper's Tables 1-2 setting in miniature: simulated wall-clock, REAL
accuracy. The scheduler-plane benchmark (bench_groups.py) is the fast
default; this one validates that the ordering holds under real learning.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.config.base import JobConfig
from repro.configs.paper_models import cnn_b, lenet5
from repro.core import CostModel, DevicePool, MultiJobEngine, get_scheduler
from repro.data.synthetic import make_classification_dataset
from repro.fl.partition import noniid_partition
from repro.fl.runtime import FLJobRuntime, MultiRuntime


def run(scheduler: str, rounds: int, devices: int = 40, seed: int = 5):
    jobs, runtimes = [], []
    for jid, (mk, target) in enumerate(((lenet5, 0.95), (cnn_b, 0.85))):
        cfg = mk()
        x, y = make_classification_dataset(8000, cfg.input_shape,
                                           cfg.num_classes, noise=1.2, seed=jid)
        ex, ey = make_classification_dataset(800, cfg.input_shape,
                                             cfg.num_classes, noise=1.2,
                                             seed=100 + jid)
        part = noniid_partition(y, devices, seed=jid)
        job = JobConfig(job_id=jid, model=cfg, target_metric=target,
                        max_rounds=rounds, local_epochs=3, batch_size=32,
                        lr=0.02)
        jobs.append(job)
        runtimes.append(FLJobRuntime(job, x, y, part, ex, ey, seed=jid))
    pool = DevicePool.heterogeneous(devices, len(jobs), seed=seed)
    cm = CostModel(pool, alpha=4.0, beta=0.25)
    cm.calibrate([3.0] * len(jobs), n_sel=5)
    eng = MultiJobEngine(jobs, pool, cm,
                         get_scheduler(scheduler, cost_model=cm, seed=0),
                         MultiRuntime(runtimes), n_sel=5)
    eng.run()
    return eng.summary()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--schedulers", default="random,greedy,bods")
    args = ap.parse_args()
    print("\n== Real-FL scheduler comparison (2 jobs, non-IID, "
          f"{args.rounds} rounds) ==")
    for sched in args.schedulers.split(","):
        s = run(sched, args.rounds)
        cells = " ".join(
            f"{n}: acc={v['best_accuracy']:.3f} t={v['makespan']/60:.0f}m"
            for n, v in s.items())
        print(f"{sched:8s} {cells}")
        for n, v in s.items():
            print(f"CSV,real_fl,{sched},{n},{v['best_accuracy']:.4f},"
                  f"{v['makespan']:.0f}")


if __name__ == "__main__":
    main()
