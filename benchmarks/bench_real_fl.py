"""REAL-training benchmark (slow path, ~15-30 min on this container's CPU):
two-job groups with actual vmap'd local SGD + FedAvg under each scheduler.

  PYTHONPATH=src python -m benchmarks.bench_real_fl [--rounds 15]

The paper's Tables 1-2 setting in miniature: simulated wall-clock, REAL
accuracy. The scheduler-plane benchmark (bench_groups.py) is the fast
default; this one validates that the ordering holds under real learning.
Each scheduler arm is the ``real-fl-two-job`` preset with a different
scheduler name.
"""

from __future__ import annotations

import argparse

from repro.experiment import get_preset


def run(scheduler: str, rounds: int, devices: int = 40, seed: int = 5):
    spec = get_preset("real-fl-two-job", scheduler=scheduler, rounds=rounds,
                      num_devices=devices, seed=seed,
                      lenet_target=0.95, cnn_target=0.85)
    return spec.run().summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--schedulers", default="random,greedy,bods")
    args = ap.parse_args()
    print("\n== Real-FL scheduler comparison (2 jobs, non-IID, "
          f"{args.rounds} rounds) ==")
    for sched in args.schedulers.split(","):
        s = run(sched, args.rounds)
        cells = " ".join(
            f"{n}: acc={v['best_accuracy']:.3f} t={v['makespan']/60:.0f}m"
            for n, v in s.items())
        print(f"{sched:8s} {cells}")
        for n, v in s.items():
            print(f"CSV,real_fl,{sched},{n},{v['best_accuracy']:.4f},"
                  f"{v['makespan']:.0f}")


if __name__ == "__main__":
    main()
